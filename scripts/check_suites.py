#!/usr/bin/env python3
"""Assert the bench suite list is identical everywhere it is spelled.

The suite -> (bench, schema, json) mapping is defined once, in
scripts/verify.sh's run_suite. But the suite *list* is necessarily
repeated: verify.sh's argument filter and full-run loop, ci.yml's
bench-smoke matrix, and nightly.yml's full-bench loop. A suite added
to one spot but not the others fails silently — the matrix just never
fans out over it, or the nightly never runs it — so this script makes
drift a hard CI error (the `tools` job runs it on every PR).

Also cross-checks that every suite has a check_bench.py schema, a
tracked-metric entry, and a committed baseline file, so a new suite
cannot land half-wired.

Exit 0 when everything agrees; prints every mismatch and exits 1
otherwise.
"""

import os
import re
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def read(rel):
    with open(os.path.join(ROOT, rel)) as f:
        return f.read()


def one_match(pattern, text, where):
    found = re.findall(pattern, text, re.MULTILINE)
    if len(found) != 1:
        raise SystemExit(
            f"check_suites: expected exactly one match for {pattern!r} in "
            f"{where}, found {len(found)} — the parser drifted from the file"
        )
    return found[0]


def verify_sh_lists(text):
    """The three spellings inside scripts/verify.sh itself."""
    arg_filter = one_match(
        r"^\s*([a-z0-9|]+)\) SUITES\+=", text, "verify.sh arg filter"
    ).split("|")
    # run_suite's case labels sit alone on their line: `    registry)`.
    case_labels = re.findall(r"^\s{4}([a-z0-9]+)\)\s*$", text, re.MULTILINE)
    full_loop = one_match(
        r"^\s*for s in ([a-z0-9 ]+); do", text, "verify.sh full-run loop"
    ).split()
    return {
        "verify.sh arg filter": arg_filter,
        "verify.sh run_suite cases": case_labels,
        "verify.sh bench loop": full_loop,
    }


def ci_matrix(text):
    row = one_match(r"^\s*suite: \[([a-z0-9, ]+)\]", text, "ci.yml matrix")
    return [s.strip() for s in row.split(",")]


def nightly_loop(text):
    row = one_match(
        r"^\s*for suite in ([a-z0-9 ]+); do", text, "nightly.yml loop"
    )
    return row.split()


def main():
    lists = verify_sh_lists(read("scripts/verify.sh"))
    lists["ci.yml bench-smoke matrix"] = ci_matrix(read(".github/workflows/ci.yml"))
    lists["nightly.yml bench loop"] = nightly_loop(read(".github/workflows/nightly.yml"))

    reference_name = "verify.sh run_suite cases"
    reference = lists[reference_name]
    ok = True
    if len(set(reference)) != len(reference):
        print(f"check_suites: duplicate suite in {reference_name}: {reference}")
        ok = False
    for name, suites in lists.items():
        if name == reference_name:
            continue
        if suites != reference:
            print(
                f"check_suites: {name} disagrees with {reference_name}:\n"
                f"  {name}: {suites}\n"
                f"  {reference_name}: {reference}"
            )
            ok = False

    # Every suite must be fully wired: schema, tracked metric, baseline.
    sys.dont_write_bytecode = True  # no __pycache__ litter in scripts/
    sys.path.insert(0, os.path.join(ROOT, "scripts"))
    import check_bench

    for suite in reference:
        if suite not in check_bench.SCHEMAS:
            print(f"check_suites: suite {suite!r} has no check_bench.py schema")
            ok = False
        if not check_bench.TRACKED.get(suite):
            print(f"check_suites: suite {suite!r} tracks no headline metric")
            ok = False
        baseline = f"bench_baselines/BENCH_{suite}.json"
        if not os.path.exists(os.path.join(ROOT, baseline)):
            print(f"check_suites: suite {suite!r} is missing {baseline}")
            ok = False
    for suite in sorted(set(check_bench.SCHEMAS) - set(reference)):
        print(
            f"check_suites: check_bench.py knows {suite!r} but no suite "
            f"runs it — dead schema or missing verify.sh wiring"
        )
        ok = False

    if not ok:
        return 1
    print(
        f"check_suites: OK — {len(reference)} suites consistent across "
        f"{len(lists)} spellings: {' '.join(reference)}"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main())
