#!/usr/bin/env python3
"""Validate a bench JSON and gate its headline metrics against a baseline.

Usage:
    check_bench.py <bench> <json>                      # schema check only
    check_bench.py <bench> <json> --compare <baseline> # + regression gate
    check_bench.py <bench> <json> --update-baselines <baseline>

<bench> is one of: pipeline | adaptive | multiedge | crossmodel | c10k |
chaos | cache | registry | threetier.

The schema checks replicate (and replace) the inline validators that
used to live in scripts/verify.sh; verify.sh keeps a grep fallback for
python3-less machines.

The regression gate compares *tracked headline metrics* — chosen to be
machine-normalized (speedup ratios, shed/retention fractions, p95
ratios) rather than absolute latencies — and fails when one regresses
more than REGRESSION_TOLERANCE against the committed baseline.
`--update-baselines` rewrites the baseline file from the current run
(for intentional changes; commit the result).
"""

import argparse
import json
import sys

REGRESSION_TOLERANCE = 0.15


# --------------------------------------------------------------------------
# Per-bench schemas (raise AssertionError on malformed output).
# --------------------------------------------------------------------------

def check_pipeline(doc):
    ab = doc.get("server_concurrency_ab")
    assert isinstance(ab, list) and ab, "server_concurrency_ab missing/empty"
    modes = {row.get("mode") for row in ab if "req_per_sec" in row}
    assert {"serialized", "sharded_batched"} <= modes, f"missing A/B arms: {modes}"
    assert "concurrency_speedup_8conn" in doc, "speedup field missing"
    return f"speedup_8conn={doc['concurrency_speedup_8conn']:.2f}x"


def check_adaptive(doc):
    phases = doc.get("scenario")
    assert isinstance(phases, list) and len(phases) == 3, "scenario must have 3 phases"
    names = [p.get("phase") for p in phases]
    assert names == ["baseline", "spike", "recovered"], f"phases: {names}"
    for p in phases:
        for k in ("requests", "p50_ms", "p95_ms", "final_cut_depth", "sheds"):
            assert k in p, f"phase {p.get('phase')}: missing {k}"
    assert doc.get("resolves", 0) >= 1, "the loop never re-solved"
    assert doc.get("sheds_observed", 0) >= 1, "the spike never shed"
    assert doc.get("shed_rate_spike", 0) > 0, "spike shed rate is zero"
    base, spike, rec = phases
    assert spike["final_cut_depth"] > base["final_cut_depth"], \
        "spike did not move the cut edge-ward"
    assert rec["final_cut_depth"] < spike["final_cut_depth"], \
        "recovery did not move the cut back"
    for k in ("p95_before_ms", "p95_spike_ms", "p95_after_ms"):
        assert k in doc, f"missing {k}"
    return (f"resolves={doc['resolves']}, shed_rate={doc['shed_rate_spike']:.2f}, "
            f"depths {base['final_cut_depth']}->{spike['final_cut_depth']}"
            f"->{rec['final_cut_depth']}")


def check_multiedge(doc):
    assert doc.get("tenants") == 3, "scenario is defined for 3 tenants"
    for arm in ("fair", "global"):
        a = doc.get(arm)
        assert isinstance(a, dict), f"missing arm {arm}"
        per_tenant = a.get("per_tenant")
        assert isinstance(per_tenant, list) and len(per_tenant) == 3, \
            f"{arm}: per_tenant must list 3 tenants"
        for t in per_tenant:
            for k in ("tenant", "role", "sent", "admitted", "sheds",
                      "shed_rate", "throughput_share", "served_p95_ms"):
                assert k in t, f"{arm}/{t.get('tenant')}: missing {k}"
            assert t["sent"] > 0, f"{arm}/{t.get('tenant')}: client never ran"
        for k in ("polite_retention", "polite_shed_rate", "flood_shed_rate",
                  "total_admitted"):
            assert k in a, f"{arm}: missing {k}"
    fair = doc["fair"]
    assert fair["polite_shed_rate"] < fair["flood_shed_rate"], \
        "fair admission let polite tenants shed at the flooder's rate"
    assert fair["polite_retention"] > 0.5, \
        f"polite retention collapsed: {fair['polite_retention']:.2f}"
    # The global arm is the pre-tenant path: over budget it sheds every
    # sheddable request, whoever sent it.
    assert doc["global"]["total_admitted"] == 0, \
        "global-budget arm admitted work while over budget"
    assert "fair_polite_retention" in doc and "fair_flood_shed_rate" in doc, \
        "headline metrics missing"
    return (f"polite retention={fair['polite_retention']:.2f}, "
            f"flood shed={fair['flood_shed_rate']:.2f}, "
            f"gain={doc.get('fairness_polite_throughput_gain', 0):.1f}x")


def check_crossmodel(doc):
    assert doc.get("fleet_models", 0) >= 2, "needs a multi-model fleet"
    arms = doc.get("arms")
    assert isinstance(arms, list) and arms, "arms missing/empty"
    by_mode = {a.get("mode"): a for a in arms if a.get("mode") is not None}
    assert {"xmodel_on", "xmodel_off", "padded"} <= set(by_mode), \
        f"missing arms: {sorted(by_mode)}"
    for mode, a in by_mode.items():
        for k in ("req_per_sec", "batches", "batched_requests", "batch_bypassed",
                  "mean_occupancy", "xmodel_batches"):
            assert k in a, f"{mode}: missing {k}"
    assert doc.get("bit_identical") is True, \
        "mixed batches were not verified bit-identical to solo execution"
    assert by_mode["xmodel_on"]["xmodel_batches"] > 0, \
        "signature keying never actually mixed models"
    # The regression this bench guards: identity keying degenerates
    # mixed-fleet traffic to bypass.
    assert by_mode["xmodel_off"]["xmodel_batches"] == 0, \
        "identity-keyed arm mixed models"
    pad = doc.get("pad")
    assert isinstance(pad, dict), "missing pad section"
    for k in ("req_per_sec", "padded_samples", "pad_waste_fraction", "xmodel_batches"):
        assert k in pad, f"pad: missing {k}"
    assert pad["padded_samples"] > 0, "the padded phase never stacked a padded batch"
    assert "pad_waste_max" in doc, "pad_waste_max missing (the waste bound needs it)"
    budget = doc["pad_waste_max"]
    assert 0.0 <= pad["pad_waste_fraction"] <= budget + 1e-9, \
        f"pad waste {pad['pad_waste_fraction']:.3f} exceeded the {budget} budget"
    for k in ("mixed_speedup_8conn", "mixed_occupancy", "bypass_fraction_off"):
        assert k in doc, f"missing {k}"
    return (f"mixed speedup={doc['mixed_speedup_8conn']:.2f}x, "
            f"occupancy={doc['mixed_occupancy']:.2f}, "
            f"pad_waste={pad['pad_waste_fraction']:.3f}")


def check_c10k(doc):
    if not doc.get("io_available", True):
        # Non-Linux host: the epoll reactor doesn't exist, the bench
        # emits a stub document, and there is nothing to gate.
        return "io_available=false (no epoll on this host)"
    scaling = doc.get("scaling")
    assert isinstance(scaling, list) and scaling, "scaling missing/empty"
    conns = [row.get("conns") for row in scaling]
    assert conns == sorted(conns), f"scaling rows out of order: {conns}"
    for row in scaling:
        for k in ("conns", "offered_rps", "req_per_sec", "served",
                  "p50_ms", "p99_ms", "busy", "errors"):
            assert k in row, f"scaling/{row.get('conns')}: missing {k}"
        assert row["served"] > 0, f"{row['conns']} conns: nothing served"
    assert scaling[-1]["conns"] == doc.get("target_conns"), \
        "largest scaling row does not reach target_conns"
    assert doc.get("max_conns_sustained", 0) >= doc["target_conns"], \
        (f"only {doc.get('max_conns_sustained')} of {doc['target_conns']} "
         f"connections sustained")
    ab = doc.get("low_fanin_ab")
    assert isinstance(ab, dict), "low_fanin_ab missing"
    for k in ("epoll_rps", "threads_rps", "epoll_vs_threads"):
        assert k in ab, f"low_fanin_ab: missing {k}"
    assert ab["epoll_rps"] > 0 and ab["threads_rps"] > 0, "an A/B arm served nothing"
    fc = doc.get("flash_crowd")
    assert isinstance(fc, dict), "flash_crowd missing"
    for k in ("polite_shed_rate", "flood_shed_rate", "polite_retention",
              "polite_sent", "flood_sent"):
        assert k in fc, f"flash_crowd: missing {k}"
    assert fc["polite_sent"] > 0 and fc["flood_sent"] > 0, "flash arm sent nothing"
    assert fc["flood_shed_rate"] > fc["polite_shed_rate"], \
        "admission shed the polite tenants at the flooder's rate"
    di = doc.get("diurnal")
    assert isinstance(di, dict), "diurnal missing"
    buckets = di.get("buckets")
    assert isinstance(buckets, list) and len(buckets) >= 4, "diurnal needs >=4 buckets"
    for b in buckets:
        assert "offered" in b and "served" in b, "diurnal bucket malformed"
    assert di.get("peak_trough_ratio", 0) > 1.5, \
        "diurnal cycle never actually swung the offered rate"
    return (f"{doc['max_conns_sustained']} conns sustained, "
            f"epoll/threads={ab['epoll_vs_threads']:.2f}, "
            f"flood shed={fc['flood_shed_rate']:.2f}")


def check_cache(doc):
    arms = doc.get("arms")
    assert isinstance(arms, list) and arms, "arms missing/empty"
    by_mode = {a.get("mode"): a for a in arms if a.get("mode") is not None}
    assert {"cache_off", "cache_on", "stampede"} <= set(by_mode), \
        f"missing arms: {sorted(by_mode)}"
    for mode in ("cache_off", "cache_on"):
        assert by_mode[mode].get("req_per_sec", 0) > 0, f"{mode}: nothing served"
    on = by_mode["cache_on"]
    for k in ("hits", "misses", "inflight_coalesced", "evictions"):
        assert k in on, f"cache_on: missing {k}"
    st = by_mode["stampede"]
    for k in ("rounds", "inflight_coalesced", "hits"):
        assert k in st, f"stampede: missing {k}"
    for k in ("zipf_speedup_8conn", "hit_rate", "coalesce_rate", "bytes_saved_frac"):
        assert k in doc, f"missing {k}"
    # The cache's raison d'être on Zipf traffic: repeats must actually
    # hit, and the stampede arm must actually coalesce.
    assert doc["hit_rate"] > 0, "Zipf traffic never hit the cache"
    assert doc["coalesce_rate"] > 0, "the stampede never parked a follower"
    assert doc.get("bit_identical") is True, \
        "cached replies were not verified bit-identical to solo execution"
    return (f"zipf speedup={doc['zipf_speedup_8conn']:.2f}x, "
            f"hit rate={doc['hit_rate']:.3f}, "
            f"coalesce rate={doc['coalesce_rate']:.3f}")


def check_registry(doc):
    for k in ("cold", "warm", "swap", "tamper", "warm_fetch_speedup", "chunks"):
        assert k in doc, f"missing {k}"
    assert doc["chunks"] > 0, "the manifest advertised no chunks"
    cold, warm = doc["cold"], doc["warm"]
    for sec, name in ((cold, "cold"), (warm, "warm")):
        for k in ("iters", "fetch_ms_p50", "fetch_ms_p95"):
            assert k in sec, f"{name}: missing {k}"
        assert sec["iters"] > 0, f"{name}: no iterations ran"
    assert "hit_rate" in warm, "warm: missing hit_rate"
    assert warm["hit_rate"] > 0, "the warm arm never hit the artifact cache"
    sw = doc["swap"]
    for k in ("requests", "dropped", "served_v1", "served_v2", "cutover_gap_ms",
              "steady_p95_ms", "bit_identical", "rollback_ok"):
        assert k in sw, f"swap: missing {k}"
    # The zero-downtime contract: no request drops or serves torn bytes
    # across the cut-over, the new version actually takes traffic, and
    # rollback restores the old one.
    assert sw["requests"] > 0, "the swap arm issued nothing"
    assert sw["dropped"] == 0, f"hot-swap dropped {sw['dropped']} request(s)"
    assert sw["served_v2"] > 0, "the cut-over never took effect"
    assert sw["bit_identical"] is True, \
        "a reply did not bit-match exactly one model version"
    assert sw["rollback_ok"] is True, "rollback did not restore the old version"
    ta = doc["tamper"]
    for k in ("attempts", "rejected", "tamper_reject_rate", "executed_tampered"):
        assert k in ta, f"tamper: missing {k}"
    assert ta["attempts"] > 0, "the tamper arm attempted nothing"
    assert ta["tamper_reject_rate"] >= 1.0 - 1e-9, \
        f"only {ta['tamper_reject_rate']:.3f} of tampered serves were rejected"
    assert ta["executed_tampered"] == 0, \
        "a tampered artifact or manifest reached execution"
    return (f"warm speedup={doc['warm_fetch_speedup']:.1f}x, "
            f"cutover gap={sw['cutover_gap_ms']:.2f}ms, "
            f"tamper reject={ta['tamper_reject_rate']:.3f}")


def check_chaos(doc):
    for k in ("availability", "served_bit_identity", "recovery_ms",
              "corruption", "blackout", "quarantine"):
        assert k in doc, f"missing {k}"
    # The contract: every request is answered (cloud or local failover)
    # and every answered request carries the fault-free full-model bits.
    assert doc["availability"] >= 1.0 - 1e-9, \
        f"availability {doc['availability']:.4f} < 1.0 — requests were dropped"
    assert doc["served_bit_identity"] is True, \
        "a served reply differed from the fault-free reference bits"
    # -1 is the bench's "cloud serving never resumed" sentinel.
    assert doc["recovery_ms"] >= 0.0, \
        "cloud serving never resumed after the blackout"
    assert doc["recovery_ms"] < 15_000.0, \
        f"recovery took {doc['recovery_ms']:.0f} ms (> 15 s bound)"
    co = doc["corruption"]
    for k in ("requests", "local_serves", "p50_ms", "p95_ms"):
        assert k in co, f"corruption: missing {k}"
    assert co["requests"] > 0, "corruption phase issued nothing"
    bl = doc["blackout"]
    for k in ("blackout_ms", "local_serves", "breaker_opens",
              "breaker_recloses", "deadline_overruns"):
        assert k in bl, f"blackout: missing {k}"
    assert bl["breaker_opens"] >= 1, "the blackout never opened the breaker"
    assert bl["breaker_recloses"] >= 1, "the breaker never re-closed"
    assert bl["local_serves"] >= 1, "no request was served locally during the outage"
    qu = doc["quarantine"]
    for k in ("quarantined", "readmitted", "shard_panics"):
        assert k in qu, f"quarantine: missing {k}"
    assert qu["quarantined"] >= 1, "the poisoned shard was never quarantined"
    assert qu["readmitted"] >= 1, "the quarantined shard was never re-admitted"
    return (f"availability={doc['availability']:.3f}, "
            f"recovery={doc['recovery_ms']:.0f}ms, "
            f"opens={bl['breaker_opens']}, quarantined={qu['quarantined']}")


def check_threetier(doc):
    for k in ("availability", "recovery_ms", "predicted", "three_tier",
              "two_tier", "outage"):
        assert k in doc, f"missing {k}"
    # The contract: every request across every phase (both measured
    # arms and the tier outage) is answered — the device↔cloud pair
    # must survive a middle-tier blackout via the fallback endpoint.
    assert doc["availability"] >= 1.0 - 1e-9, \
        f"availability {doc['availability']:.4f} < 1.0 — requests were dropped"
    # -1 is the bench's "serving never resumed" sentinel; like the
    # chaos suite's recovery, the value is wall-clock so the hard bound
    # is the gate, not a cross-machine ratio baseline.
    assert doc["recovery_ms"] >= 0.0, \
        "serving never resumed after the tier outage"
    assert doc["recovery_ms"] < 15_000.0, \
        f"recovery took {doc['recovery_ms']:.0f} ms (> 15 s bound)"
    pr = doc["predicted"]
    for k in ("device_class", "two_tier_ms", "three_tier_ms", "speedup"):
        assert k in pr, f"predicted: missing {k}"
    assert pr["two_tier_ms"] > 0 and pr["three_tier_ms"] > 0, \
        "predicted latencies must be positive"
    assert pr["speedup"] > 0, "predicted speedup malformed"
    for arm in ("three_tier", "two_tier"):
        a = doc[arm]
        for k in ("requests", "p50_ms", "p95_ms"):
            assert k in a, f"{arm}: missing {k}"
        assert a["requests"] > 0, f"{arm}: arm issued nothing"
        assert a["p50_ms"] > 0, f"{arm}: nothing was measured"
    assert doc["three_tier"].get("forwarded", 0) >= doc["three_tier"]["requests"], \
        "the middle tier never relayed the arm's requests"
    ou = doc["outage"]
    for k in ("served_through", "fallback_serves"):
        assert k in ou, f"outage: missing {k}"
    assert ou["fallback_serves"] >= 1, \
        "the outage was never served via the fallback endpoint"
    return (f"availability={doc['availability']:.3f}, "
            f"predicted speedup={pr['speedup']:.2f}x, "
            f"recovery={doc['recovery_ms']:.0f}ms")


# --------------------------------------------------------------------------
# Tracked headline metrics: name -> (extractor, direction).
# direction "higher" = regression when it drops; "lower" = when it grows.
# All are ratios/fractions so a committed baseline is meaningful across
# machines (absolute latencies are not).
# --------------------------------------------------------------------------

TRACKED = {
    "pipeline": {
        "concurrency_speedup_8conn":
            (lambda d: float(d["concurrency_speedup_8conn"]), "higher"),
    },
    "adaptive": {
        "spike_p95_ratio":
            (lambda d: float(d["p95_spike_ms"]) / max(float(d["p95_before_ms"]), 1e-9),
             "lower"),
    },
    "multiedge": {
        "fair_polite_retention":
            (lambda d: float(d["fair_polite_retention"]), "higher"),
        "fair_flood_shed_rate":
            (lambda d: float(d["fair_flood_shed_rate"]), "higher"),
    },
    # pad_waste_fraction is deliberately NOT tracked here: the engine's
    # pad_admits guard hard-caps it at pad_waste_max per batch (and the
    # schema asserts the bound), so a baseline gate on it could never
    # fire — the schema assertion is the real check.
    "crossmodel": {
        "mixed_speedup_8conn":
            (lambda d: float(d["mixed_speedup_8conn"]), "higher"),
        "mixed_occupancy":
            (lambda d: float(d["mixed_occupancy"]), "higher"),
    },
    # Stub documents from hosts without epoll report inf so the gate
    # can never false-fail there (the schema already waves them through).
    "c10k": {
        "epoll_vs_threads":
            (lambda d: float(d["low_fanin_ab"]["epoll_vs_threads"])
             if d.get("io_available", True) else float("inf"), "higher"),
        "flash_polite_retention":
            (lambda d: float(d["flash_crowd"]["polite_retention"])
             if d.get("io_available", True) else float("inf"), "higher"),
    },
    # recovery_ms is NOT tracked: it is wall-clock (breaker cooldown +
    # probe pacing), so the schema's hard 15 s bound is the real gate and
    # a cross-machine ratio baseline would be noise.
    "chaos": {
        "availability": (lambda d: float(d["availability"]), "higher"),
    },
    # hit_rate / coalesce_rate are schema-asserted > 0 but not gated:
    # both are fixed by the scripted Zipf schedule, so a ratio baseline
    # would only re-test the schedule. The speedup is the claim.
    "cache": {
        "zipf_speedup_8conn":
            (lambda d: float(d["zipf_speedup_8conn"]), "higher"),
    },
    # cutover_gap_ms / tamper_reject_rate are schema-asserted hard
    # bounds (0 drops, 100% reject), not ratios to trend — the speedup
    # is the only machine-normalized headline worth a baseline.
    "registry": {
        "warm_fetch_speedup":
            (lambda d: float(d["warm_fetch_speedup"]), "higher"),
    },
    # predicted.speedup is deterministic ILP output (schema-asserted
    # positive) and the measured p50/p95 are wall-clock — availability
    # is the one machine-normalized headline, pinned at 1.0 like chaos.
    "threetier": {
        "availability": (lambda d: float(d["availability"]), "higher"),
    },
}

SCHEMAS = {
    "pipeline": check_pipeline,
    "adaptive": check_adaptive,
    "multiedge": check_multiedge,
    "crossmodel": check_crossmodel,
    "c10k": check_c10k,
    "chaos": check_chaos,
    "cache": check_cache,
    "registry": check_registry,
    "threetier": check_threetier,
}


def tracked_metrics(bench, doc):
    return {name: fn(doc) for name, (fn, _) in TRACKED[bench].items()}


def compare(bench, doc, baseline_path):
    try:
        with open(baseline_path) as f:
            baseline = json.load(f)
    except FileNotFoundError:
        # Fail closed, not with a traceback: a missing baseline file is
        # a wiring error (typo, rename, suite added without committing
        # its baseline), and silently skipping it would leave the
        # regression gate green while guarding nothing. (A metric
        # missing from an *existing* baseline still skips per-metric
        # below — that is the intentional incremental-adoption path.)
        print(f"check_bench: baseline {baseline_path} not found — commit one "
              f"(--update-baselines) or fix the path", file=sys.stderr)
        return False
    failures = []
    for name, (fn, direction) in TRACKED[bench].items():
        if name not in baseline:
            print(f"check_bench: {name}: no baseline recorded, skipping gate")
            continue
        base, cur = float(baseline[name]), fn(doc)
        if direction == "higher":
            limit = base * (1.0 - REGRESSION_TOLERANCE)
            regressed = cur < limit
        else:
            limit = base * (1.0 + REGRESSION_TOLERANCE)
            regressed = cur > limit
        status = "REGRESSED" if regressed else "ok"
        print(f"check_bench: {name}: current={cur:.3f} baseline={base:.3f} "
              f"limit={limit:.3f} ({direction} is better) .. {status}")
        if regressed:
            failures.append(name)
    if failures:
        print(f"check_bench: REGRESSION in {bench}: {', '.join(failures)} "
              f"(>{REGRESSION_TOLERANCE:.0%} vs bench_baselines/; if intentional, "
              f"rerun with --update-baselines and commit)", file=sys.stderr)
        return False
    return True


def main():
    ap = argparse.ArgumentParser(description=__doc__,
                                 formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("bench", choices=sorted(SCHEMAS))
    ap.add_argument("json_path")
    ap.add_argument("--compare", metavar="BASELINE",
                    help="fail when a tracked metric regresses vs this baseline")
    ap.add_argument("--update-baselines", metavar="BASELINE",
                    help="write the current tracked metrics to this baseline file")
    args = ap.parse_args()

    with open(args.json_path) as f:
        doc = json.load(f)

    try:
        summary = SCHEMAS[args.bench](doc)
    except AssertionError as e:
        print(f"check_bench: {args.json_path} malformed: {e}", file=sys.stderr)
        return 1
    print(f"check_bench: {args.json_path} well-formed ({summary})")

    if args.update_baselines:
        with open(args.update_baselines, "w") as f:
            json.dump(tracked_metrics(args.bench, doc), f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"check_bench: wrote {args.update_baselines}")
        return 0

    if args.compare and not compare(args.bench, doc, args.compare):
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
