#!/usr/bin/env bash
# Tier-1 verification: format, build, test, lint. Run from anywhere.
#
#   scripts/verify.sh           # full gate
#   scripts/verify.sh --smoke   # + bench smoke: runs the serving
#                               # concurrency A/B, the control-plane
#                               # closed-loop scenario and the
#                               # multi-edge fairness scenario briefly;
#                               # each BENCH_*.json is validated by
#                               # scripts/check_bench.py and its
#                               # headline metrics gated against
#                               # bench_baselines/ (>15% regression
#                               # fails).
set -euo pipefail
cd "$(dirname "$0")/.."

SMOKE=0
for arg in "$@"; do
  case "$arg" in
    --smoke) SMOKE=1 ;;
    *) echo "verify.sh: unknown argument $arg" >&2; exit 2 ;;
  esac
done

if ! command -v cargo >/dev/null 2>&1; then
  echo "verify: rust toolchain not installed (cargo not found on PATH)." >&2
  echo "verify: install via https://rustup.rs or your distro package, then re-run." >&2
  exit 1
fi

if cargo fmt --version >/dev/null 2>&1; then
  echo "== cargo fmt --check =="
  cargo fmt --all --check
else
  echo "== cargo fmt --check == (rustfmt not installed; skipped)"
fi

echo "== cargo build --release =="
cargo build --release

echo "== cargo test -q =="
cargo test -q

echo "== cargo clippy --all-targets -- -D warnings =="
cargo clippy --all-targets -- -D warnings

# Run one bench in smoke mode and validate/gate its JSON.
#   smoke_bench <cargo-bench-name> <check_bench schema name> <json basename> <grep fallback terms...>
smoke_bench() {
  local bench="$1" schema="$2" json="$3"
  shift 3
  echo "== bench smoke: $bench --smoke =="
  rm -f "rust/$json" "$json"
  cargo bench --bench "$bench" -- --smoke
  # cargo bench runs with the package dir as cwd; accept either layout.
  local found=""
  for f in "rust/$json" "$json"; do
    [ -f "$f" ] && found="$f" && break
  done
  if [ -z "$found" ]; then
    echo "verify: $json was not emitted" >&2
    exit 1
  fi
  if command -v python3 >/dev/null 2>&1; then
    python3 scripts/check_bench.py "$schema" "$found" \
      --compare "bench_baselines/$json"
  else
    # No python3: at least require the headline fields to appear.
    for term in "$@"; do
      grep -q "$term" "$found"
    done
    echo "verify: $found emitted (python3 absent; grep-checked, regression gate skipped)"
  fi
}

if [ "$SMOKE" = 1 ]; then
  smoke_bench pipeline_hotpath pipeline BENCH_pipeline.json \
    '"server_concurrency_ab"' '"serialized"' '"sharded_batched"' \
    '"concurrency_speedup_8conn"'
  smoke_bench control_plane adaptive BENCH_adaptive.json \
    '"scenario"' '"spike"' '"sheds_observed"'
  smoke_bench multiedge multiedge BENCH_multiedge.json \
    '"fair_polite_retention"' '"flood_shed_rate"' '"per_tenant"'
fi

echo "verify: OK"
