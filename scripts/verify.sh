#!/usr/bin/env bash
# Tier-1 verification: format, build, test, lint. Run from anywhere.
#
#   scripts/verify.sh                  # full gate
#   scripts/verify.sh --smoke          # full gate + every bench smoke
#   scripts/verify.sh --smoke SUITE…   # ONLY the named bench smoke(s)
#                                      # (pipeline|adaptive|multiedge|
#                                      # crossmodel|c10k|chaos|cache|
#                                      # registry|threetier) — no
#                                      # build/
#                                      # test/
#                                      # clippy pass; cargo bench builds
#                                      # what it needs. This is what the
#                                      # CI bench matrix fans out over,
#                                      # and what you want locally when
#                                      # only one suite changed.
#   scripts/verify.sh --full SUITE…    # same, but the full (non-smoke)
#                                      # bench run — what the nightly
#                                      # workflow fans out over, so the
#                                      # suite → (bench, schema, json)
#                                      # mapping lives only here.
#
# Each bench run validates its BENCH_*.json with
# scripts/check_bench.py and gates the headline metrics against
# bench_baselines/ (>15% regression fails).
set -euo pipefail
cd "$(dirname "$0")/.."

SMOKE=0
FULL=0
SUITES=()
for arg in "$@"; do
  case "$arg" in
    --smoke) SMOKE=1 ;;
    --full) FULL=1 ;;
    pipeline|adaptive|multiedge|crossmodel|c10k|chaos|cache|registry|threetier) SUITES+=("$arg") ;;
    *) echo "verify.sh: unknown argument $arg" >&2; exit 2 ;;
  esac
done
if [ "$SMOKE" = 1 ] && [ "$FULL" = 1 ]; then
  echo "verify.sh: --smoke and --full are mutually exclusive" >&2
  exit 2
fi
if [ "${#SUITES[@]}" -gt 0 ] && [ "$SMOKE" = 0 ] && [ "$FULL" = 0 ]; then
  echo "verify.sh: a suite filter needs --smoke or --full" >&2
  exit 2
fi

if ! command -v cargo >/dev/null 2>&1; then
  echo "verify: rust toolchain not installed (cargo not found on PATH)." >&2
  echo "verify: install via https://rustup.rs or your distro package, then re-run." >&2
  exit 1
fi

# Run one bench (smoke unless --full) and validate/gate its JSON.
#   smoke_bench <cargo-bench-name> <check_bench schema name> <json basename> <grep fallback terms...>
smoke_bench() {
  local bench="$1" schema="$2" json="$3"
  shift 3
  rm -f "rust/$json" "$json"
  if [ "$FULL" = 1 ]; then
    echo "== bench full: $bench =="
    cargo bench --bench "$bench"
  else
    echo "== bench smoke: $bench --smoke =="
    cargo bench --bench "$bench" -- --smoke
  fi
  # cargo bench runs with the package dir as cwd; accept either layout.
  local found=""
  for f in "rust/$json" "$json"; do
    [ -f "$f" ] && found="$f" && break
  done
  if [ -z "$found" ]; then
    echo "verify: $json was not emitted" >&2
    exit 1
  fi
  if command -v python3 >/dev/null 2>&1; then
    python3 scripts/check_bench.py "$schema" "$found" \
      --compare "bench_baselines/$json"
  else
    # No python3: at least require the headline fields to appear.
    for term in "$@"; do
      grep -q "$term" "$found"
    done
    echo "verify: $found emitted (python3 absent; grep-checked, regression gate skipped)"
  fi
}

# Suite name -> smoke_bench invocation (the CI matrix fans out over
# these names; the grep terms are the python3-less fallback).
run_suite() {
  case "$1" in
    pipeline)
      smoke_bench pipeline_hotpath pipeline BENCH_pipeline.json \
        '"server_concurrency_ab"' '"serialized"' '"sharded_batched"' \
        '"concurrency_speedup_8conn"' ;;
    adaptive)
      smoke_bench control_plane adaptive BENCH_adaptive.json \
        '"scenario"' '"spike"' '"sheds_observed"' ;;
    multiedge)
      smoke_bench multiedge multiedge BENCH_multiedge.json \
        '"fair_polite_retention"' '"flood_shed_rate"' '"per_tenant"' ;;
    crossmodel)
      smoke_bench crossmodel crossmodel BENCH_crossmodel.json \
        '"mixed_speedup_8conn"' '"xmodel_on"' '"xmodel_off"' \
        '"pad_waste_fraction"' '"bit_identical"' ;;
    c10k)
      smoke_bench c10k c10k BENCH_c10k.json \
        '"scaling"' '"epoll_vs_threads"' '"flood_shed_rate"' \
        '"peak_trough_ratio"' ;;
    chaos)
      smoke_bench chaos chaos BENCH_chaos.json \
        '"availability"' '"served_bit_identity"' '"recovery_ms"' \
        '"quarantine"' ;;
    cache)
      smoke_bench logits_cache cache BENCH_cache.json \
        '"zipf_speedup_8conn"' '"hit_rate"' '"coalesce_rate"' \
        '"bit_identical"' ;;
    registry)
      smoke_bench registry registry BENCH_registry.json \
        '"warm_fetch_speedup"' '"cutover_gap_ms"' '"tamper_reject_rate"' \
        '"rollback_ok"' ;;
    threetier)
      smoke_bench threetier threetier BENCH_threetier.json \
        '"availability"' '"recovery_ms"' '"predicted"' \
        '"three_tier"' '"two_tier"' ;;
    *) echo "verify.sh: unknown suite $1" >&2; exit 2 ;;
  esac
}

if [ "${#SUITES[@]}" -gt 0 ]; then
  # Suite-filtered run: just the named bench(es).
  for s in "${SUITES[@]}"; do
    run_suite "$s"
  done
  echo "verify: OK (bench $([ "$FULL" = 1 ] && echo full || echo smoke): ${SUITES[*]})"
  exit 0
fi

if cargo fmt --version >/dev/null 2>&1; then
  echo "== cargo fmt --check =="
  cargo fmt --all --check
else
  echo "== cargo fmt --check == (rustfmt not installed; skipped)"
fi

echo "== cargo build --release =="
cargo build --release

echo "== cargo test -q =="
cargo test -q

echo "== cargo clippy --all-targets -- -D warnings =="
cargo clippy --all-targets -- -D warnings

if [ "$SMOKE" = 1 ] || [ "$FULL" = 1 ]; then
  for s in pipeline adaptive multiedge crossmodel c10k chaos cache registry threetier; do
    run_suite "$s"
  done
fi

echo "verify: OK"
