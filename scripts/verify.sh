#!/usr/bin/env bash
# Tier-1 verification: format, build, test, lint. Run from anywhere.
#
#   scripts/verify.sh           # full gate
#   scripts/verify.sh --smoke   # + bench smoke: runs the serving
#                               # concurrency A/B a few iterations and
#                               # checks BENCH_pipeline.json is emitted
#                               # and well-formed
set -euo pipefail
cd "$(dirname "$0")/.."

SMOKE=0
for arg in "$@"; do
  case "$arg" in
    --smoke) SMOKE=1 ;;
    *) echo "verify.sh: unknown argument $arg" >&2; exit 2 ;;
  esac
done

if cargo fmt --version >/dev/null 2>&1; then
  echo "== cargo fmt --check =="
  cargo fmt --all --check
else
  echo "== cargo fmt --check == (rustfmt not installed; skipped)"
fi

echo "== cargo build --release =="
cargo build --release

echo "== cargo test -q =="
cargo test -q

echo "== cargo clippy --all-targets -- -D warnings =="
cargo clippy --all-targets -- -D warnings

if [ "$SMOKE" = 1 ]; then
  echo "== bench smoke: pipeline_hotpath --smoke =="
  rm -f rust/BENCH_pipeline.json BENCH_pipeline.json
  cargo bench --bench pipeline_hotpath -- --smoke
  # cargo bench runs with the package dir as cwd; accept either layout.
  BENCH_JSON=""
  for f in rust/BENCH_pipeline.json BENCH_pipeline.json; do
    [ -f "$f" ] && BENCH_JSON="$f" && break
  done
  if [ -z "$BENCH_JSON" ]; then
    echo "verify: BENCH_pipeline.json was not emitted" >&2
    exit 1
  fi
  if command -v python3 >/dev/null 2>&1; then
    python3 - "$BENCH_JSON" <<'EOF'
import json, sys
doc = json.load(open(sys.argv[1]))
ab = doc.get("server_concurrency_ab")
assert isinstance(ab, list) and ab, "server_concurrency_ab missing/empty"
modes = {row.get("mode") for row in ab if "req_per_sec" in row}
assert {"serialized", "sharded_batched"} <= modes, f"missing A/B arms: {modes}"
assert "concurrency_speedup_8conn" in doc, "speedup field missing"
print(f"verify: {sys.argv[1]} well-formed "
      f"(speedup_8conn={doc['concurrency_speedup_8conn']:.2f}x)")
EOF
  else
    # No python3: at least require both A/B arms to appear in the JSON.
    grep -q '"server_concurrency_ab"' "$BENCH_JSON"
    grep -q '"serialized"' "$BENCH_JSON"
    grep -q '"sharded_batched"' "$BENCH_JSON"
    echo "verify: $BENCH_JSON emitted (python3 absent; grep-checked)"
  fi
fi

echo "verify: OK"
