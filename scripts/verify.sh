#!/usr/bin/env bash
# Tier-1 verification: format, build, test, lint. Run from anywhere.
#
#   scripts/verify.sh           # full gate
#   scripts/verify.sh --smoke   # + bench smoke: runs the serving
#                               # concurrency A/B a few iterations and
#                               # checks BENCH_pipeline.json is emitted
#                               # and well-formed, then runs the
#                               # control-plane closed-loop scenario and
#                               # validates BENCH_adaptive.json (re-solve
#                               # count, shed rate, per-phase p95)
set -euo pipefail
cd "$(dirname "$0")/.."

SMOKE=0
for arg in "$@"; do
  case "$arg" in
    --smoke) SMOKE=1 ;;
    *) echo "verify.sh: unknown argument $arg" >&2; exit 2 ;;
  esac
done

if cargo fmt --version >/dev/null 2>&1; then
  echo "== cargo fmt --check =="
  cargo fmt --all --check
else
  echo "== cargo fmt --check == (rustfmt not installed; skipped)"
fi

echo "== cargo build --release =="
cargo build --release

echo "== cargo test -q =="
cargo test -q

echo "== cargo clippy --all-targets -- -D warnings =="
cargo clippy --all-targets -- -D warnings

if [ "$SMOKE" = 1 ]; then
  echo "== bench smoke: pipeline_hotpath --smoke =="
  rm -f rust/BENCH_pipeline.json BENCH_pipeline.json
  cargo bench --bench pipeline_hotpath -- --smoke
  # cargo bench runs with the package dir as cwd; accept either layout.
  BENCH_JSON=""
  for f in rust/BENCH_pipeline.json BENCH_pipeline.json; do
    [ -f "$f" ] && BENCH_JSON="$f" && break
  done
  if [ -z "$BENCH_JSON" ]; then
    echo "verify: BENCH_pipeline.json was not emitted" >&2
    exit 1
  fi
  if command -v python3 >/dev/null 2>&1; then
    python3 - "$BENCH_JSON" <<'EOF'
import json, sys
doc = json.load(open(sys.argv[1]))
ab = doc.get("server_concurrency_ab")
assert isinstance(ab, list) and ab, "server_concurrency_ab missing/empty"
modes = {row.get("mode") for row in ab if "req_per_sec" in row}
assert {"serialized", "sharded_batched"} <= modes, f"missing A/B arms: {modes}"
assert "concurrency_speedup_8conn" in doc, "speedup field missing"
print(f"verify: {sys.argv[1]} well-formed "
      f"(speedup_8conn={doc['concurrency_speedup_8conn']:.2f}x)")
EOF
  else
    # No python3: at least require both A/B arms to appear in the JSON.
    grep -q '"server_concurrency_ab"' "$BENCH_JSON"
    grep -q '"serialized"' "$BENCH_JSON"
    grep -q '"sharded_batched"' "$BENCH_JSON"
    echo "verify: $BENCH_JSON emitted (python3 absent; grep-checked)"
  fi

  echo "== bench smoke: control_plane --smoke =="
  rm -f rust/BENCH_adaptive.json BENCH_adaptive.json
  cargo bench --bench control_plane -- --smoke
  ADAPTIVE_JSON=""
  for f in rust/BENCH_adaptive.json BENCH_adaptive.json; do
    [ -f "$f" ] && ADAPTIVE_JSON="$f" && break
  done
  if [ -z "$ADAPTIVE_JSON" ]; then
    echo "verify: BENCH_adaptive.json was not emitted" >&2
    exit 1
  fi
  if command -v python3 >/dev/null 2>&1; then
    python3 - "$ADAPTIVE_JSON" <<'EOF'
import json, sys
doc = json.load(open(sys.argv[1]))
phases = doc.get("scenario")
assert isinstance(phases, list) and len(phases) == 3, "scenario must have 3 phases"
names = [p.get("phase") for p in phases]
assert names == ["baseline", "spike", "recovered"], f"phases: {names}"
for p in phases:
    for k in ("requests", "p50_ms", "p95_ms", "final_cut_depth", "sheds"):
        assert k in p, f"phase {p.get('phase')}: missing {k}"
assert doc.get("resolves", 0) >= 1, "the loop never re-solved"
assert doc.get("sheds_observed", 0) >= 1, "the spike never shed"
assert doc.get("shed_rate_spike", 0) > 0, "spike shed rate is zero"
base, spike, rec = phases
assert spike["final_cut_depth"] > base["final_cut_depth"], \
    "spike did not move the cut edge-ward"
assert rec["final_cut_depth"] < spike["final_cut_depth"], \
    "recovery did not move the cut back"
for k in ("p95_before_ms", "p95_spike_ms", "p95_after_ms"):
    assert k in doc, f"missing {k}"
print(f"verify: {sys.argv[1]} well-formed "
      f"(resolves={doc['resolves']}, shed_rate={doc['shed_rate_spike']:.2f}, "
      f"depths {base['final_cut_depth']}→{spike['final_cut_depth']}→{rec['final_cut_depth']})")
EOF
  else
    grep -q '"scenario"' "$ADAPTIVE_JSON"
    grep -q '"spike"' "$ADAPTIVE_JSON"
    grep -q '"sheds_observed"' "$ADAPTIVE_JSON"
    echo "verify: $ADAPTIVE_JSON emitted (python3 absent; grep-checked)"
  fi
fi

echo "verify: OK"
