#!/usr/bin/env bash
# Tier-1 verification: build, test, lint. Run from anywhere.
set -euo pipefail
cd "$(dirname "$0")/.."

echo "== cargo build --release =="
cargo build --release

echo "== cargo test -q =="
cargo test -q

echo "== cargo clippy --all-targets -- -D warnings =="
cargo clippy --all-targets -- -D warnings

echo "verify: OK"
