#!/usr/bin/env bash
# Self-test for the CI gates themselves: prove that check_bench.py
# passes good output, fails malformed output, fails regressions, fails
# closed on a missing baseline, and that --update-baselines round-trips
# into a green --compare. Runs against committed fixtures under
# scripts/testdata/ — no cargo, no network, seconds of wall clock.
#
# The point: a gate that cannot fail is indistinguishable from a gate
# that passes. Every mutation CI relies on to catch regressions is
# exercised here on both sides.
#
#   scripts/test_gates.sh
set -euo pipefail
cd "$(dirname "$0")/.."

TD=scripts/testdata
TMP="$(mktemp -d)"
trap 'rm -rf "$TMP"' EXIT

fail() { echo "test_gates: FAIL: $*" >&2; exit 1; }

echo "== syntax: bash -n on the shell gates =="
bash -n scripts/verify.sh
bash -n scripts/test_gates.sh

echo "== syntax: py_compile on the python gates =="
python3 -m py_compile scripts/check_bench.py scripts/check_suites.py
rm -rf scripts/__pycache__  # py_compile's output; only the exit code matters

echo "== pass path: good JSON clears the schema =="
python3 scripts/check_bench.py registry "$TD/BENCH_registry_good.json" \
  || fail "good registry JSON was rejected"

echo "== fail path: malformed JSON is rejected =="
if python3 scripts/check_bench.py registry "$TD/BENCH_registry_malformed.json" \
    2>/dev/null; then
  fail "malformed registry JSON (dropped requests, tampered exec) passed"
fi

echo "== fail path: truncated JSON is rejected =="
head -c 40 "$TD/BENCH_registry_good.json" > "$TMP/truncated.json"
if python3 scripts/check_bench.py registry "$TMP/truncated.json" 2>/dev/null; then
  fail "truncated JSON passed"
fi

echo "== compare path: committed baseline gates the good run green =="
python3 scripts/check_bench.py registry "$TD/BENCH_registry_good.json" \
  --compare bench_baselines/BENCH_registry.json \
  || fail "good run regressed against the committed baseline"

echo "== regression path: inflated baseline must fail the gate =="
if python3 scripts/check_bench.py registry "$TD/BENCH_registry_good.json" \
    --compare "$TD/registry_regressed_baseline.json" 2>/dev/null; then
  fail "a >15% regression passed the --compare gate"
fi

echo "== fail-closed path: missing baseline file must fail =="
if python3 scripts/check_bench.py registry "$TD/BENCH_registry_good.json" \
    --compare "$TMP/no_such_baseline.json" 2>/dev/null; then
  fail "a missing baseline file passed --compare (gate guarded nothing)"
fi

echo "== update path: --update-baselines round-trips into green --compare =="
python3 scripts/check_bench.py registry "$TD/BENCH_registry_good.json" \
  --update-baselines "$TMP/rebase.json" \
  || fail "--update-baselines failed on good output"
grep -q '"warm_fetch_speedup"' "$TMP/rebase.json" \
  || fail "updated baseline is missing the tracked metric"
python3 scripts/check_bench.py registry "$TD/BENCH_registry_good.json" \
  --compare "$TMP/rebase.json" \
  || fail "a run compared against its own fresh baseline regressed"

echo "== drift check: suite lists agree across verify.sh / ci.yml / nightly.yml =="
python3 scripts/check_suites.py

echo "test_gates: OK"
