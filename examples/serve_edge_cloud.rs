//! End-to-end serving driver (the repo's headline validation run).
//!
//! Spawns a real cloud server (TCP, its own PJRT client), connects a
//! real edge client through a token-bucket-throttled uplink, serves a
//! batch of requests for each of the paper's four models + TinyConv,
//! and reports per-model latency percentiles, throughput, accuracy and
//! the decoupling decisions taken — against the PNG2Cloud baseline over
//! the same socket. Results are recorded in EXPERIMENTS.md §E11.
//!
//! Run: `cargo run --release --example serve_edge_cloud -- [--bw 125000]
//!       [--requests 32] [--models tinyconv,vgg16] [--delta-alpha 0.1]`

use std::sync::Arc;

use anyhow::Result;

use jalad::coordinator::{ControlPlane, DecisionEngine, Scale};
use jalad::ilp::Decision;
use jalad::metrics::Histogram;
use jalad::network::throttle::RateHandle;
use jalad::predictor::Tables;
use jalad::profiler::LatencyTables;
use jalad::runtime::{Executor, Manifest, SharedExecutor};
use jalad::server::{CloudServer, EdgeClient};
use jalad::util::bench::print_table;
use jalad::util::cli::Args;

fn main() -> Result<()> {
    jalad::util::logging::init();
    let args = Args::new("serve_edge_cloud", "end-to-end TCP edge/cloud serving driver")
        .opt("artifacts", "artifacts", "artifact directory")
        .opt("bw", "125000", "throttled uplink, bytes/second (125000 = 1 Mbps)")
        .opt("requests", "32", "requests per model")
        .opt("models", "tinyconv,vgg16,resnet50", "comma-separated models")
        .opt("delta-alpha", "0.10", "accuracy-loss bound Δα")
        .parse_env();

    let dir = args.get("artifacts").to_string();
    let bw = args.get_f64("bw");
    let n = args.get_usize("requests");
    let da = args.get_f64("delta-alpha");

    // Cloud process (thread): own PJRT client behind the TCP server.
    let cloud_exe = Arc::new(SharedExecutor::new(Manifest::load(&dir)?)?);
    let server = Arc::new(CloudServer::new(Arc::clone(&cloud_exe)));
    let (addr, _handle) = Arc::clone(&server).spawn("127.0.0.1:0")?;
    println!("cloud server on {addr}; uplink throttled to {bw:.0} B/s\n");

    // Edge process (this thread): its own PJRT client.
    let edge_exe = Executor::new(Manifest::load(&dir)?)?;

    let mut rows = Vec::new();
    for model in args.get("models").split(',').map(str::trim) {
        let tables = Tables::load_or_build(&edge_exe, model, &dir)?;
        let latency = LatencyTables::measured(&edge_exe, model, 3, 4.0)?;
        let engine = DecisionEngine::new(model, tables, latency, Scale::Measured, da)?;

        // --- JALAD over the socket ---
        let controller = ControlPlane::new(engine, bw);
        let rate = RateHandle::new(bw as u64);
        let mut edge =
            EdgeClient::connect(&edge_exe, model, addr, rate.clone(), controller)?;
        // Warm both PJRT compile caches (first-touch compilation would
        // otherwise dominate the percentiles of a short run).
        for id in 0..2 {
            let s = jalad::data::gen::sample_image(10_900 + id, 32);
            let _ = edge.infer(&s)?;
        }
        let mut hist = Histogram::new();
        let mut correct = 0usize;
        let mut tx_total = 0usize;
        let mut decision = Decision::CloudOnly;
        let t0 = std::time::Instant::now();
        for id in 0..n {
            let s = jalad::data::gen::sample_image(11_000 + id, 32);
            let r = edge.infer(&s)?;
            hist.record(r.breakdown.total());
            correct += r.correct as usize;
            tx_total += r.breakdown.tx_bytes;
            decision = r.decision;
        }
        let wall = t0.elapsed().as_secs_f64();

        // --- PNG2Cloud baseline over the same socket ---
        let engine2 = DecisionEngine::new(
            model,
            Tables::load_or_build(&edge_exe, model, &dir)?,
            LatencyTables::measured(&edge_exe, model, 3, 4.0)?,
            Scale::Measured,
            da,
        )?;
        let mut ctrl2 = ControlPlane::new(engine2, bw);
        ctrl2.resolve_at(f64::MAX); // force CloudOnly = PNG2Cloud
        let mut edge2 = EdgeClient::connect(&edge_exe, model, addr, rate, ctrl2)?;
        for id in 0..2 {
            let s = jalad::data::gen::sample_image(10_900 + id, 32);
            let _ = edge2.infer(&s)?;
        }
        let mut hist2 = Histogram::new();
        for id in 0..n {
            let s = jalad::data::gen::sample_image(11_000 + id, 32);
            let r = edge2.infer(&s)?;
            hist2.record(r.breakdown.total());
        }

        println!("[{model}] JALAD    {}", hist.summary(1e3, " ms"));
        println!("[{model}] PNG2Cloud {}", hist2.summary(1e3, " ms"));
        rows.push(vec![
            model.to_string(),
            format!("{:?}", decision),
            format!("{:.1}", hist.mean() * 1e3),
            format!("{:.1}", hist2.mean() * 1e3),
            format!("{:.2}x", hist2.mean() / hist.mean()),
            format!("{:.3}", correct as f64 / n as f64),
            format!("{:.0}", tx_total as f64 / n as f64),
            format!("{:.2}", n as f64 / wall),
        ]);
    }

    print_table(
        &format!("end-to-end serving @ {:.0} B/s, Δα = {da}", bw),
        &[
            "model",
            "decision",
            "jalad ms",
            "png2cloud ms",
            "speedup",
            "accuracy",
            "avg tx B",
            "req/s",
        ],
        &rows,
    );

    let stats_json = {
        let mut ctrl = ControlPlane::new(
            DecisionEngine::new(
                "tinyconv",
                Tables::load_or_build(&edge_exe, "tinyconv", &dir)?,
                LatencyTables::measured(&edge_exe, "tinyconv", 2, 4.0)?,
                Scale::Measured,
                da,
            )?,
            bw,
        );
        ctrl.resolve_at(bw);
        let mut e = EdgeClient::connect(
            &edge_exe,
            "tinyconv",
            addr,
            RateHandle::new(u64::MAX >> 1),
            ctrl,
        )?;
        e.stats()?
    };
    println!("\ncloud stats: {stats_json}");
    CloudServer::request_shutdown(addr);
    Ok(())
}
