//! Quickstart: the whole JALAD loop on TinyConv in under a minute.
//!
//! TinyConv's conv stages are the Pallas im2col-matmul kernel and the
//! quantizer is the Pallas quantize artifact, so this example exercises
//! the complete L1 → L2 → AOT → L3 chain on the request path:
//!
//! 1. load the AOT artifacts;
//! 2. calibrate (or load) the A_i(c)/S_i(c) predictor tables;
//! 3. profile per-stage latency on this host;
//! 4. solve the §III-E ILP at a few bandwidths and show how the
//!    decoupling point moves;
//! 5. run live requests through the decoupled pipeline.
//!
//! Run: `cargo run --release --example quickstart`

use anyhow::Result;

use jalad::coordinator::{DecisionEngine, LocalPipeline, Scale};
use jalad::network::SimChannel;
use jalad::predictor::Tables;
use jalad::profiler::LatencyTables;
use jalad::runtime::{Executor, Manifest};

fn main() -> Result<()> {
    jalad::util::logging::init();
    let dir = std::env::args().nth(1).unwrap_or_else(|| "artifacts".into());
    let model = "tinyconv";

    println!("== 1. loading artifacts from {dir}/ ==");
    let manifest = Manifest::load(&dir)?;
    let exe = Executor::new(manifest)?;
    let m = exe.manifest().model(model)?;
    println!("   {} stages, input {:?}", m.num_stages(), m.input_shape);

    println!("== 2. predictor tables (A_i(c), S_i(c)) ==");
    let tables = Tables::load_or_build(&exe, model, &dir)?;
    println!(
        "   base accuracy {:.3} on {} calibration samples",
        tables.base_accuracy, tables.samples
    );
    for i in 1..=tables.num_stages() {
        let row: Vec<String> = tables
            .c_grid
            .iter()
            .map(|&c| {
                format!(
                    "c{}:{:>5.0}B/{:.2}",
                    c,
                    tables.wire_bytes(i, c).unwrap(),
                    tables.acc_drop(i, c).unwrap()
                )
            })
            .collect();
        println!("   stage {i}: {}", row.join("  "));
    }

    println!("== 3. per-stage latency profile ==");
    let latency = LatencyTables::measured(&exe, model, 3, 4.0)?;
    for (i, (te, tc)) in latency.t_edge.iter().zip(&latency.t_cloud).enumerate() {
        println!("   cut@{}  T_E={:.2} ms  T_C={:.2} ms", i + 1, te * 1e3, tc * 1e3);
    }

    println!("== 4. ILP decisions across bandwidths (Δα = 0.10) ==");
    let engine = DecisionEngine::new(model, tables, latency, Scale::Measured, 0.10)?;
    for bw in [10_000.0, 50_000.0, 200_000.0, 1_000_000.0, 10_000_000.0] {
        let plan = engine.decide(bw);
        println!(
            "   BW {:>9.0} B/s → {:?}  predicted {:.2} ms, {:.0} B on wire",
            bw,
            plan.decision(),
            plan.latency * 1e3,
            plan.tx_bytes
        );
    }

    println!("== 5. live requests over a simulated 100 KB/s uplink ==");
    let mut pipe = LocalPipeline::new(&exe, model);
    let mut channel = SimChannel::constant(100_000.0);
    let plan = engine.decide(100_000.0);
    let mut correct = 0;
    let n = 12;
    for id in 0..n {
        let s = jalad::data::gen::sample_image(9500 + id, 32);
        let r = pipe.run(&s, plan.decision(), &mut channel)?;
        correct += r.correct as usize;
        println!(
            "   req {id:2}  pred={} label={}  {}",
            r.prediction,
            s.label,
            r.breakdown.summary()
        );
    }
    println!("   accuracy {correct}/{n}");
    println!("done — see examples/serve_edge_cloud.rs for the real TCP deployment.");
    Ok(())
}
