//! Adaptive re-decoupling under a varying uplink (live Fig. 8 demo).
//!
//! Drives the real TCP deployment with a bandwidth trace: a background
//! thread retunes the token-bucket rate following the trace while the
//! edge serves requests; the adaptation controller's EWMA estimate
//! drifts and re-solves the ILP, and the log shows the decoupling point
//! migrating with the link — §III-E's "adaptively use different
//! decoupling schemes" in action.
//!
//! Run: `cargo run --release --example adaptive_bandwidth --
//!       [--model vgg16] [--trace step] [--requests 48]`

use std::sync::Arc;

use anyhow::Result;

use jalad::coordinator::{ControlPlane, DecisionEngine, Scale};
use jalad::network::throttle::RateHandle;
use jalad::network::BandwidthTrace;
use jalad::predictor::Tables;
use jalad::profiler::LatencyTables;
use jalad::runtime::{Executor, Manifest, SharedExecutor};
use jalad::server::{CloudServer, EdgeClient};
use jalad::util::cli::Args;

fn main() -> Result<()> {
    jalad::util::logging::init();
    let args = Args::new("adaptive_bandwidth", "trace-driven adaptive re-decoupling demo")
        .opt("artifacts", "artifacts", "artifact directory")
        .opt("model", "tinyconv", "model to serve")
        .opt("trace", "step", "bandwidth trace: step | sine | walk")
        .opt("requests", "48", "total requests")
        .opt("delta-alpha", "0.10", "accuracy-loss bound Δα")
        .parse_env();

    let dir = args.get("artifacts").to_string();
    let model = args.get("model").to_string();
    let n = args.get_usize("requests");

    let trace = match args.get("trace") {
        "sine" => BandwidthTrace::sine(30_000.0, 1_000_000.0, 8.0, 60.0, 0.25),
        "walk" => BandwidthTrace::random_walk(42, 20_000.0, 2_000_000.0, 60.0, 0.5),
        _ => BandwidthTrace::step(40_000.0, 1_500_000.0, 6.0, 60.0),
    };

    let cloud_exe = Arc::new(SharedExecutor::new(Manifest::load(&dir)?)?);
    let server = Arc::new(CloudServer::new(cloud_exe));
    let (addr, _h) = Arc::clone(&server).spawn("127.0.0.1:0")?;

    let edge_exe = Executor::new(Manifest::load(&dir)?)?;
    let tables = Tables::load_or_build(&edge_exe, &model, &dir)?;
    let latency = LatencyTables::measured(&edge_exe, &model, 3, 4.0)?;
    let engine = DecisionEngine::new(
        &model,
        tables,
        latency,
        Scale::Measured,
        args.get_f64("delta-alpha"),
    )?;

    let initial_bw = trace.at(0.0);
    let rate = RateHandle::new(initial_bw as u64);

    // Trace driver: retune the live socket's token bucket.
    {
        let rate = rate.clone();
        let trace = trace.clone();
        std::thread::spawn(move || {
            let t0 = std::time::Instant::now();
            loop {
                let t = t0.elapsed().as_secs_f64();
                if t > trace.duration() + 5.0 {
                    return;
                }
                rate.set(trace.at(t) as u64);
                std::thread::sleep(std::time::Duration::from_millis(100));
            }
        });
    }

    let controller = ControlPlane::new(engine, initial_bw);
    let mut edge = EdgeClient::connect(&edge_exe, &model, addr, rate.clone(), controller)?;

    println!(
        "serving {n} requests for {model} under a '{}' trace ({:.0}..{:.0} B/s)\n",
        args.get("trace"),
        trace.points().iter().map(|p| p.1).fold(f64::INFINITY, f64::min),
        trace.points().iter().map(|p| p.1).fold(0.0, f64::max),
    );
    println!("{:>4} {:>10} {:>12} {:>22} {:>10} {:>8}", "req", "rate B/s", "est B/s", "decision", "ms", "replan");
    let t0 = std::time::Instant::now();
    for id in 0..n {
        let s = jalad::data::gen::sample_image(12_000 + id, 32);
        let r = edge.infer(&s)?;
        // Pace requests so the trace actually progresses, and actively
        // probe every few requests: logits-sized frames carry no
        // bandwidth signal (see server::edge::MIN_ESTIMATE_BYTES).
        std::thread::sleep(std::time::Duration::from_millis(300));
        let probed = if id % 3 == 2 { edge.probe_bandwidth(24 * 1024)? } else { false };
        println!(
            "{id:>4} {:>10} {:>12.0} {:>22} {:>10.1} {:>8}",
            rate.get(),
            edge.controller.bandwidth_estimate().unwrap_or(0.0),
            format!("{:?}", r.decision),
            r.breakdown.total() * 1e3,
            if r.replanned || probed { "YES" } else { "" }
        );
    }
    println!(
        "\n{} re-decouplings over {} requests in {:.1} s",
        edge.controller.resolves(),
        n,
        t0.elapsed().as_secs_f64()
    );
    CloudServer::request_shutdown(addr);
    Ok(())
}
