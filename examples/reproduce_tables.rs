//! Regenerate the paper's tables (II and III) plus the §III-E ILP
//! solve-time claim, in the paper's own simulation methodology
//! (`T = w·Q/F`, §IV-A) with *measured* compression ratios projected to
//! full scale. Also prints the Neurosurgeon-style no-compression
//! reference that motivates the paper (§V).
//!
//! Shape targets (not absolute numbers — our accuracy tables come from
//! the synthetic task): JALAD wins at 300 KB/s by large factors, wins
//! less at 1 MB/s, Origin2Cloud speedups ≈ PNG2Cloud × (PNG ratio),
//! ResNets gain more than VGGs, Tegra X2 gains exceed Tegra K1's.
//!
//! Run: `cargo run --release --example reproduce_tables`
//! (first run calibrates all four models; tables are cached)

use anyhow::Result;

use jalad::coordinator::{DecisionEngine, Scale};
use jalad::models::fullscale_stages;
use jalad::predictor::Tables;
use jalad::profiler::{DeviceModel, LatencyTables};
use jalad::runtime::{Executor, Manifest};
use jalad::util::bench::print_table;

const MODELS: [&str; 4] = ["vgg16", "vgg19", "resnet50", "resnet101"];
const DELTA_ALPHA: f64 = 0.10; // paper: "accuracy loss threshold Δα is set to 10%"

fn engines(
    exe: &Executor,
    dir: &str,
    edge: DeviceModel,
    cloud: DeviceModel,
) -> Result<Vec<DecisionEngine>> {
    MODELS
        .iter()
        .map(|m| {
            let tables = Tables::load_or_build(exe, m, dir)?;
            let latency = LatencyTables::analytic(m, edge, cloud).unwrap();
            DecisionEngine::new(m, tables, latency, Scale::Paper, DELTA_ALPHA)
        })
        .collect()
}

fn speedup_row(e: &DecisionEngine, bw: f64) -> (String, String, f64) {
    let plan = e.decide(bw);
    let jalad = plan.latency;
    let png = e.cloud_only_latency(e.image_png_bytes(), bw);
    let origin = e.cloud_only_latency(e.image_raw_bytes(), bw);
    (
        format!("{:.1}x/{:.1}x", png / jalad, origin / jalad),
        format!("{:?}", plan.decision()),
        jalad,
    )
}

fn main() -> Result<()> {
    jalad::util::logging::init();
    let dir = std::env::args().nth(1).unwrap_or_else(|| "artifacts".into());
    let exe = Executor::new(Manifest::load(&dir)?)?;

    // ---------------- Table II: speedup vs bandwidth ----------------
    // Paper testbed: 1080ti cloud, K620 edge.
    let engines_t2 =
        engines(&exe, &dir, DeviceModel::QUADRO_K620, DeviceModel::GTX_1080TI)?;
    let mut rows = Vec::new();
    for (m, e) in MODELS.iter().zip(&engines_t2) {
        let (s1m, d1m, _) = speedup_row(e, 1_000_000.0);
        let (s300k, d300k, _) = speedup_row(e, 300_000.0);
        rows.push(vec![m.to_string(), s1m, d1m, s300k, d300k]);
    }
    print_table(
        "Table II — execution speedup (PNG2Cloud/Origin2Cloud), Δα=10%",
        &["model", "1MBps", "decision@1M", "300KBps", "decision@300K"],
        &rows,
    );
    println!(
        "paper:  VGG16 1.4x/2.2x | 3.6x/6.0x   VGG19 1.1x/1.7x | 3.0x/4.9x\n\
         paper:  Res50 2.3x/3.7x | 7.2x/11.7x  Res101 1.5x/2.3x | 4.3x/6.9x"
    );

    // ---------------- Table III: edge compute power ----------------
    let mut rows = Vec::new();
    for edge in [DeviceModel::TEGRA_K1, DeviceModel::TEGRA_X2] {
        let engs = engines(&exe, &dir, edge, DeviceModel::CLOUD_12T)?;
        for (m, e) in MODELS.iter().zip(&engs) {
            let (s, d, lat) = speedup_row(e, 1_000_000.0);
            rows.push(vec![
                edge.name.to_string(),
                m.to_string(),
                s,
                d,
                format!("{:.1} ms", lat * 1e3),
            ]);
        }
    }
    print_table(
        "Table III — speedup by edge device (PNG2Cloud/Origin2Cloud), 1 MBps",
        &["edge", "model", "speedup", "decision", "jalad latency"],
        &rows,
    );
    println!(
        "paper:  K1: VGG16 1.0x/1.5x VGG19 1.0x/1.5x Res50 2.2x/3.7x Res101 1.4x/2.3x\n\
         paper:  X2: VGG16 3.4x/5.5x VGG19 2.9x/4.7x Res50 15.1x/25.1x Res101 9.0x/14.9x"
    );

    // ---------------- Neurosurgeon reference (§V) ----------------
    let mut rows = Vec::new();
    for (m, e) in MODELS.iter().zip(&engines_t2) {
        let fm = fullscale_stages(m).unwrap();
        let bw = 1_000_000.0;
        // Best no-compression cut: min over i of T_E + raw/bw + T_C.
        let (best_i, best) = (1..=fm.stages.len())
            .map(|i| {
                let t = e.latency.t_edge[i - 1]
                    + fm.stages[i - 1].out_elems as f64 * 4.0 / bw
                    + e.latency.t_cloud[i - 1];
                (i, t)
            })
            .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
            .unwrap();
        let jalad = e.decide(bw).latency;
        rows.push(vec![
            m.to_string(),
            format!("cut@{best_i}/{}", fm.stages.len()),
            format!("{:.1} ms", best * 1e3),
            format!("{:.1} ms", jalad * 1e3),
            format!("{:.1}x", best / jalad),
        ]);
    }
    print_table(
        "§V reference — Neurosurgeon-style partition without in-layer compression, 1 MBps",
        &["model", "best raw cut", "raw-cut latency", "jalad", "jalad gain"],
        &rows,
    );

    // ---------------- §III-E ILP solve time ----------------
    let e = &engines_t2[3]; // resnet101: largest instance (35×6 vars)
    let inst = e.instance(300_000.0);
    let t0 = std::time::Instant::now();
    let reps = 200;
    for _ in 0..reps {
        std::hint::black_box(inst.solve());
    }
    let per = t0.elapsed().as_secs_f64() / reps as f64;
    println!(
        "\nILP solve (resnet101, {} vars): {:.3} ms/solve — paper reports 1.77 ms on an i7-6800K",
        1 + inst.n * inst.c_max as usize,
        per * 1e3
    );
    Ok(())
}
