//! Regenerate the data series behind every figure in the paper's
//! evaluation (Figs. 2–8). Prints the series as aligned tables; the
//! shapes (who wins, where curves bend) are the reproduction targets —
//! see EXPERIMENTS.md for the paper-vs-measured record.
//!
//! Run: `cargo run --release --example reproduce_figures -- [--fig N]`

use anyhow::Result;

use jalad::coordinator::{DecisionEngine, Scale};
use jalad::models::fullscale_stages;
use jalad::predictor::{StabilityReport, Tables};
use jalad::profiler::{DeviceModel, LatencyTables};
use jalad::runtime::{Executor, Manifest};
use jalad::util::bench::print_table;
use jalad::util::cli::Args;

fn main() -> Result<()> {
    jalad::util::logging::init();
    let args = Args::new("reproduce_figures", "regenerate the paper's figure data")
        .opt("artifacts", "artifacts", "artifact directory")
        .opt("fig", "all", "figure number (2..8) or 'all'")
        .parse_env();
    let dir = args.get("artifacts").to_string();
    let exe = Executor::new(Manifest::load(&dir)?)?;
    let which = args.get("fig").to_string();
    let want = |n: &str| which == "all" || which == n;

    if want("2") {
        fig2(&exe)?;
    }
    if want("3") {
        fig3(&exe, &dir)?;
    }
    if want("4") {
        fig4(&exe, &dir)?;
    }
    if want("5") {
        fig5(&exe)?;
    }
    if want("6") {
        fig6(&exe, &dir)?;
    }
    if want("7") {
        fig7(&exe, &dir)?;
    }
    if want("8") {
        fig8(&exe, &dir)?;
    }
    Ok(())
}

/// Fig. 2 — in-layer data amplification across ResNet decoupling points.
fn fig2(exe: &Executor) -> Result<()> {
    let mut rows = Vec::new();
    for model in ["resnet50", "resnet101"] {
        let m = exe.manifest().model(model)?;
        let fm = fullscale_stages(model).unwrap();
        let input_scaled = 32 * 32 * 3; // 8-bit upload bytes
        for (k, s) in m.stages.iter().enumerate() {
            let scaled = s.out_elems * 4;
            let full = fm.stages[k].out_elems * 4;
            rows.push(vec![
                model.into(),
                s.name.clone(),
                format!("{:.1} KiB", scaled as f64 / 1024.0),
                format!("{:.1}x", scaled as f64 / input_scaled as f64),
                format!("{:.0} KiB", full as f64 / 1024.0),
                format!("{:.1}x", full as f64 / fm.input_rgb_bytes as f64),
            ]);
        }
    }
    print_table(
        "Fig. 2 — feature size per decoupling point vs 8-bit input (scaled | full-scale)",
        &["model", "stage", "scaled f32", "amp", "full f32", "amp"],
        &rows,
    );
    println!("paper: early ResNet features up to ~20x the input size — check the 'amp' columns.");
    Ok(())
}

/// Fig. 3 — compression performance of the feature codec per stage/c.
fn fig3(exe: &Executor, dir: &str) -> Result<()> {
    for model in ["vgg16", "resnet50"] {
        let t = Tables::load_or_build(exe, model, dir)?;
        let mut rows = Vec::new();
        for i in 1..=t.num_stages() {
            let mut row = vec![
                format!("{i}"),
                format!("{:.1}", t.raw_size[i - 1] / 1024.0),
            ];
            for &c in &[2u8, 4, 8] {
                let wire = t.wire_bytes(i, c)?;
                row.push(format!("{:.2} ({:.0}x)", wire / 1024.0, t.raw_size[i - 1] / wire));
            }
            row.push(format!("{:.2}", t.image_png_bytes / 1024.0));
            rows.push(row);
        }
        print_table(
            &format!("Fig. 3 — {model}: compressed in-layer sizes, KiB (ratio)"),
            &["stage", "raw f32", "c=2", "c=4", "c=8", "input png"],
            &rows,
        );
    }
    println!("paper: compression reduces feature maps to 1/10-1/100 of raw size.");
    Ok(())
}

/// Fig. 4 — accuracy loss A(c) versus bit-width c, all four models.
fn fig4(exe: &Executor, dir: &str) -> Result<()> {
    let mut rows = Vec::new();
    for model in ["vgg16", "vgg19", "resnet50", "resnet101"] {
        let t = Tables::load_or_build(exe, model, dir)?;
        let n = t.num_stages();
        let mut row = vec![model.to_string(), format!("{:.3}", t.base_accuracy)];
        for &c in &t.c_grid.clone() {
            // Mean drop across decoupling points (the figure's curve is
            // the model-level loss at each c).
            let mean: f64 =
                (1..=n).map(|i| t.acc_drop(i, c).unwrap()).sum::<f64>() / n as f64;
            row.push(format!("{:.3}", mean));
        }
        rows.push(row);
    }
    let t0 = Tables::load_or_build(exe, "vgg16", dir)?;
    let mut header = vec!["model".to_string(), "base acc".to_string()];
    header.extend(t0.c_grid.iter().map(|c| format!("A(c={c})")));
    let header_refs: Vec<&str> = header.iter().map(String::as_str).collect();
    print_table("Fig. 4 — mean accuracy drop vs quantization bits", &header_refs, &rows);
    println!("paper: c >= 4 already keeps the loss within 10%.");
    Ok(())
}

/// Fig. 5 — epoch stability of the predictors.
fn fig5(exe: &Executor) -> Result<()> {
    let mut rows = Vec::new();
    for model in ["tinyconv", "vgg16"] {
        let a = Tables::build(exe, model, 4096..4112, &[2, 4, 8])?;
        let b = Tables::build(exe, model, 4300..4316, &[2, 4, 8])?;
        let rep = StabilityReport::compare(&a, &b);
        rows.push(vec![
            model.into(),
            format!("{:.4}", rep.size_correlation),
            format!("{:.1}%", rep.max_size_rel_delta * 100.0),
            format!("{:.3}", rep.max_acc_delta),
        ]);
    }
    print_table(
        "Fig. 5 — predictor stability across disjoint calibration epochs",
        &["model", "size corr", "max size Δ", "max acc Δ"],
        &rows,
    );
    println!("paper: different epochs 'highly overlapped' → correlation ≈ 1, small deltas.");
    Ok(())
}

/// Fig. 6 — per-layer accuracy drop A_i(c=8) (and c=2 for contrast).
fn fig6(exe: &Executor, dir: &str) -> Result<()> {
    for model in ["vgg16", "resnet50"] {
        let t = Tables::load_or_build(exe, model, dir)?;
        let mut rows = Vec::new();
        for i in 1..=t.num_stages() {
            rows.push(vec![
                format!("{i}"),
                format!("{:.3}", t.acc_drop(i, 8)?),
                format!("{:.3}", t.acc_drop(i, 2)?),
                format!("{:.3}", t.acc_drop(i, 1)?),
            ]);
        }
        print_table(
            &format!("Fig. 6 — {model}: per-decoupling-point accuracy drop"),
            &["stage", "A_i(8)", "A_i(2)", "A_i(1)"],
            &rows,
        );
    }
    println!("paper: c=8 is near-lossless at every layer; low c hurts, especially early.");
    Ok(())
}

/// Fig. 7 — latency versus the accuracy threshold Δα.
fn fig7(exe: &Executor, dir: &str) -> Result<()> {
    let mut rows = Vec::new();
    for model in ["vgg16", "resnet50"] {
        let tables = Tables::load_or_build(exe, model, dir)?;
        for da in [0.0, 0.02, 0.05, 0.10, 0.20, 0.30] {
            let latency =
                LatencyTables::analytic(model, DeviceModel::TEGRA_X2, DeviceModel::CLOUD_12T)
                    .unwrap();
            let e = DecisionEngine::new(model, tables.clone(), latency, Scale::Paper, da)?;
            let plan = e.decide(1_000_000.0);
            rows.push(vec![
                model.into(),
                format!("{da:.2}"),
                format!("{:.1} ms", plan.latency * 1e3),
                format!("{:?}", plan.decision()),
                format!("{:.3}", plan.acc_drop),
            ]);
        }
    }
    print_table(
        "Fig. 7 — accuracy threshold vs latency (1 MBps, Tegra X2 edge)",
        &["model", "Δα", "latency", "decision", "drop"],
        &rows,
    );
    println!("paper: latency falls (or holds) as Δα loosens — lower bit-depths become legal.");
    Ok(())
}

/// Fig. 8 — execution latency under different edge-cloud bandwidths.
fn fig8(exe: &Executor, dir: &str) -> Result<()> {
    let model = "resnet50";
    let tables = Tables::load_or_build(exe, model, dir)?;
    let latency =
        LatencyTables::analytic(model, DeviceModel::QUADRO_K620, DeviceModel::GTX_1080TI)
            .unwrap();
    let e = DecisionEngine::new(model, tables, latency, Scale::Paper, 0.10)?;
    let mut rows = Vec::new();
    for bw_kbps in [50.0, 100.0, 200.0, 300.0, 500.0, 750.0, 1000.0, 1500.0, 2000.0] {
        let bw = bw_kbps * 1000.0;
        let plan = e.decide(bw);
        let png = e.cloud_only_latency(e.image_png_bytes(), bw);
        let origin = e.cloud_only_latency(e.image_raw_bytes(), bw);
        rows.push(vec![
            format!("{bw_kbps:.0}"),
            format!("{:.1}", plan.latency * 1e3),
            format!("{:.1}", png * 1e3),
            format!("{:.1}", origin * 1e3),
            format!("{:?}", plan.decision()),
        ]);
    }
    print_table(
        "Fig. 8 — resnet50 latency (ms) vs bandwidth (KB/s)",
        &["BW KB/s", "JALAD", "PNG2Cloud", "Origin2Cloud", "decision"],
        &rows,
    );
    println!(
        "paper: JALAD stays flat by re-decoupling; baselines blow up at low bandwidth;\n\
         at high bandwidth JALAD converges to the PNG2Cloud line."
    );
    Ok(())
}
