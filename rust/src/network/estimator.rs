//! EWMA bandwidth estimation from observed transfers.
//!
//! The adaptation controller (§III-E: "re-decouples the deep neural
//! network upon the edge-cloud network change") needs a running estimate
//! of the uplink. Each completed transfer contributes one throughput
//! observation; an exponentially weighted moving average smooths jitter,
//! and a relative-change trigger tells the controller when the estimate
//! moved enough to justify re-solving the ILP.

#[derive(Debug, Clone)]
pub struct BandwidthEstimator {
    alpha: f64,
    estimate: Option<f64>,
    /// Estimate at the time of the last `take_change` acknowledgement.
    acked: Option<f64>,
    observations: u64,
}

impl BandwidthEstimator {
    /// `alpha` ∈ (0,1]: weight of the newest observation (default 0.3).
    pub fn new(alpha: f64) -> Self {
        assert!(alpha > 0.0 && alpha <= 1.0);
        Self { alpha, estimate: None, acked: None, observations: 0 }
    }

    pub fn observe(&mut self, bytes: usize, seconds: f64) {
        if seconds <= 0.0 || bytes == 0 {
            return;
        }
        let sample = bytes as f64 / seconds;
        self.estimate = Some(match self.estimate {
            None => sample,
            Some(e) => e + self.alpha * (sample - e),
        });
        self.observations += 1;
    }

    pub fn bytes_per_sec(&self) -> Option<f64> {
        self.estimate
    }

    pub fn observations(&self) -> u64 {
        self.observations
    }

    /// True when the estimate drifted ≥ `rel_threshold` (e.g. 0.2 = 20%)
    /// from the last acknowledged value; acknowledging resets the baseline.
    ///
    /// The denominator is the acknowledged estimate guarded by a true
    /// epsilon (`f64::EPSILON`), not `max(1.0)`: clamping to 1 B/s
    /// silently rescaled the threshold for any baseline below one
    /// byte per second, so a trickle link could collapse by half
    /// without ever registering as drift.
    pub fn take_change(&mut self, rel_threshold: f64) -> Option<f64> {
        let est = self.estimate?;
        let drifted = match self.acked {
            None => true,
            Some(a) => (est - a).abs() / a.abs().max(f64::EPSILON) >= rel_threshold,
        };
        if drifted {
            self.acked = Some(est);
            Some(est)
        } else {
            None
        }
    }
}

impl Default for BandwidthEstimator {
    fn default() -> Self {
        Self::new(0.3)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn converges_to_constant_rate() {
        let mut e = BandwidthEstimator::new(0.3);
        for _ in 0..50 {
            e.observe(100_000, 0.1); // 1 MB/s
        }
        let bw = e.bytes_per_sec().unwrap();
        assert!((bw - 1e6).abs() / 1e6 < 0.01, "bw={bw}");
    }

    #[test]
    fn ignores_degenerate_samples() {
        let mut e = BandwidthEstimator::default();
        e.observe(0, 1.0);
        e.observe(100, 0.0);
        assert!(e.bytes_per_sec().is_none());
        assert_eq!(e.observations(), 0);
    }

    #[test]
    fn change_trigger_fires_on_drift() {
        let mut e = BandwidthEstimator::new(1.0); // no smoothing
        e.observe(1_000_000, 1.0);
        assert!(e.take_change(0.2).is_some(), "first estimate always fires");
        assert!(e.take_change(0.2).is_none(), "no drift yet");
        e.observe(1_050_000, 1.0); // +5%
        assert!(e.take_change(0.2).is_none());
        e.observe(300_000, 1.0); // big drop
        assert!(e.take_change(0.2).is_some());
    }

    #[test]
    fn sub_unit_estimates_still_detect_drift() {
        // Regression: the old `a.max(1.0)` denominator measured drift
        // against 1 B/s whenever the baseline was below it, so a
        // 0.1 B/s link halving to 0.05 B/s showed "5% drift" and never
        // fired. Relative drift is scale-free; it must fire at any
        // magnitude.
        let mut e = BandwidthEstimator::new(1.0); // no smoothing
        e.observe(1, 10.0); // 0.1 B/s
        assert!(e.take_change(0.2).is_some(), "first estimate always fires");
        e.observe(1, 20.0); // 0.05 B/s — 50% drift
        assert!(
            e.take_change(0.2).is_some(),
            "50% collapse on a sub-1 B/s link must register"
        );
        // And the threshold semantics match the >1 B/s regime exactly:
        // a 5% wiggle stays quiet at a 20% threshold.
        e.observe(1, 19.0); // ~0.0526 B/s, ~5% off the 0.05 baseline
        assert!(e.take_change(0.2).is_none());
    }

    #[test]
    fn smoothing_dampens_spikes() {
        let mut e = BandwidthEstimator::new(0.1);
        for _ in 0..20 {
            e.observe(1_000_000, 1.0);
        }
        e.observe(10_000_000, 1.0); // one spike
        let bw = e.bytes_per_sec().unwrap();
        assert!(bw < 2_500_000.0, "spike over-weighted: {bw}");
    }
}
