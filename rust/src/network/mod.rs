//! Edge↔cloud link modelling and control.
//!
//! * [`trace`] — bandwidth-over-time traces (constant, step, sine,
//!   random-walk, or parsed from file): the workload for Fig. 8;
//! * [`channel`] — the simulated channel (`T_trans = S/BW + rtt`) used by
//!   the in-process evaluation pipeline;
//! * [`throttle`] — token-bucket pacing for *real* sockets, giving the
//!   TCP deployment a controlled uplink like the paper's testbed;
//! * [`estimator`] — EWMA bandwidth estimation from observed transfers,
//!   feeding the adaptation controller (§III-E "re-decouples the deep
//!   neural network upon the edge-cloud network change").

pub mod channel;
pub mod estimator;
pub mod throttle;
pub mod trace;

pub use channel::SimChannel;
pub use estimator::BandwidthEstimator;
pub use throttle::ThrottledWriter;
pub use trace::BandwidthTrace;
