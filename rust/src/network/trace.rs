//! Bandwidth traces: BW as a function of time.
//!
//! Drives Fig. 8 (execution under different/varying edge-cloud
//! bandwidth) and the adaptation controller tests. All generators are
//! deterministic.

use crate::util::rng::XorShift64Star;

/// Piecewise-linear bandwidth trace, bytes/second over seconds.
#[derive(Debug, Clone)]
pub struct BandwidthTrace {
    /// (t_seconds, bytes_per_second), strictly increasing t, t[0] = 0.
    points: Vec<(f64, f64)>,
}

impl BandwidthTrace {
    pub fn constant(bps: f64) -> Self {
        Self { points: vec![(0.0, bps)] }
    }

    /// Step between two rates every `period` seconds.
    pub fn step(low: f64, high: f64, period: f64, total: f64) -> Self {
        let mut points = Vec::new();
        let mut t = 0.0;
        let mut hi = false;
        while t < total {
            points.push((t, if hi { high } else { low }));
            hi = !hi;
            t += period;
        }
        Self { points }
    }

    /// Sinusoid between `low` and `high` sampled every `dt`.
    pub fn sine(low: f64, high: f64, period: f64, total: f64, dt: f64) -> Self {
        let mid = (low + high) / 2.0;
        let amp = (high - low) / 2.0;
        let mut points = Vec::new();
        let mut t = 0.0;
        while t < total {
            points.push((t, mid + amp * (2.0 * std::f64::consts::PI * t / period).sin()));
            t += dt;
        }
        Self { points }
    }

    /// Multiplicative random walk within [low, high].
    pub fn random_walk(seed: u64, low: f64, high: f64, total: f64, dt: f64) -> Self {
        let mut rng = XorShift64Star::new(seed);
        let mut bw = (low * high).sqrt();
        let mut points = Vec::new();
        let mut t = 0.0;
        while t < total {
            points.push((t, bw));
            let f = 1.0 + 0.25 * (rng.next_f64() - 0.5);
            bw = (bw * f).clamp(low, high);
            t += dt;
        }
        Self { points }
    }

    /// Parse "t,bps" lines (seconds, bytes/second).
    pub fn parse(text: &str) -> Result<Self, String> {
        let mut points = Vec::new();
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let (a, b) = line
                .split_once(',')
                .ok_or_else(|| format!("line {}: expected 't,bps'", lineno + 1))?;
            let t: f64 = a.trim().parse().map_err(|e| format!("line {}: {e}", lineno + 1))?;
            let bw: f64 = b.trim().parse().map_err(|e| format!("line {}: {e}", lineno + 1))?;
            points.push((t, bw));
        }
        if points.is_empty() {
            return Err("empty trace".into());
        }
        if points.windows(2).any(|w| w[1].0 <= w[0].0) {
            return Err("timestamps must be strictly increasing".into());
        }
        Ok(Self { points })
    }

    /// Bandwidth at time `t` (step-hold between points).
    pub fn at(&self, t: f64) -> f64 {
        match self.points.iter().rev().find(|(pt, _)| *pt <= t) {
            Some((_, bw)) => *bw,
            None => self.points[0].1,
        }
    }

    pub fn duration(&self) -> f64 {
        self.points.last().map(|(t, _)| *t).unwrap_or(0.0)
    }

    pub fn points(&self) -> &[(f64, f64)] {
        &self.points
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constant_holds() {
        let tr = BandwidthTrace::constant(1e6);
        assert_eq!(tr.at(0.0), 1e6);
        assert_eq!(tr.at(100.0), 1e6);
    }

    #[test]
    fn step_alternates() {
        let tr = BandwidthTrace::step(1e5, 1e6, 10.0, 40.0);
        assert_eq!(tr.at(0.0), 1e5);
        assert_eq!(tr.at(10.0), 1e6);
        assert_eq!(tr.at(19.9), 1e6);
        assert_eq!(tr.at(20.0), 1e5);
    }

    #[test]
    fn sine_stays_in_band() {
        let tr = BandwidthTrace::sine(1e5, 1e6, 20.0, 60.0, 0.5);
        for (_, bw) in tr.points() {
            assert!((1e5 - 1.0..=1e6 + 1.0).contains(bw));
        }
    }

    #[test]
    fn random_walk_deterministic_and_bounded() {
        let a = BandwidthTrace::random_walk(7, 1e5, 2e6, 30.0, 1.0);
        let b = BandwidthTrace::random_walk(7, 1e5, 2e6, 30.0, 1.0);
        assert_eq!(a.points(), b.points());
        for (_, bw) in a.points() {
            assert!((1e5..=2e6).contains(bw));
        }
    }

    #[test]
    fn parse_roundtrip() {
        let tr = BandwidthTrace::parse("# comment\n0, 100000\n5.5, 300000\n").unwrap();
        assert_eq!(tr.at(0.0), 100000.0);
        assert_eq!(tr.at(6.0), 300000.0);
        assert!(BandwidthTrace::parse("").is_err());
        assert!(BandwidthTrace::parse("5,1\n3,1").is_err());
        assert!(BandwidthTrace::parse("nonsense").is_err());
    }

    #[test]
    fn before_first_point_clamps() {
        let tr = BandwidthTrace::parse("1.0, 500\n2.0, 900").unwrap();
        assert_eq!(tr.at(0.5), 500.0);
    }
}
