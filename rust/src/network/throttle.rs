//! Token-bucket pacing for real sockets.
//!
//! The paper's testbed controls the link between the edge and cloud
//! machines; our TCP deployment runs both on one host, so the edge
//! client writes through this pacer to emulate a configured uplink.
//! Burst capacity is one bucket's worth (default 64 KiB) — small enough
//! that multi-hundred-KiB feature frames see the configured rate.

use std::io::{self, Write};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Shared, adjustable rate in bytes/second (lets a trace-driver retune a
/// live connection).
#[derive(Debug, Clone)]
pub struct RateHandle(Arc<AtomicU64>);

impl RateHandle {
    pub fn new(bytes_per_sec: u64) -> Self {
        Self(Arc::new(AtomicU64::new(bytes_per_sec)))
    }
    pub fn set(&self, bytes_per_sec: u64) {
        self.0.store(bytes_per_sec.max(1), Ordering::Relaxed);
    }
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed).max(1)
    }
}

pub struct ThrottledWriter<W: Write> {
    inner: W,
    rate: RateHandle,
    bucket: f64,
    capacity: f64,
    last: Instant,
}

impl<W: Write> ThrottledWriter<W> {
    pub fn new(inner: W, rate: RateHandle) -> Self {
        Self::with_burst(inner, rate, 64 * 1024)
    }

    pub fn with_burst(inner: W, rate: RateHandle, burst_bytes: usize) -> Self {
        Self {
            inner,
            rate,
            bucket: burst_bytes as f64,
            capacity: burst_bytes as f64,
            last: Instant::now(),
        }
    }

    fn refill(&mut self) {
        let now = Instant::now();
        let dt = now.duration_since(self.last).as_secs_f64();
        self.last = now;
        self.bucket = (self.bucket + dt * self.rate.get() as f64).min(self.capacity);
    }

    pub fn into_inner(self) -> W {
        self.inner
    }
}

impl<W: Write> Write for ThrottledWriter<W> {
    fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
        self.refill();
        if self.bucket < 1.0 {
            // Sleep until at least one chunk of tokens accrues.
            let deficit = 1.0 - self.bucket;
            let wait = deficit / self.rate.get() as f64;
            std::thread::sleep(Duration::from_secs_f64(wait.min(0.1)));
            self.refill();
        }
        let allowed = (self.bucket.max(1.0) as usize).min(buf.len());
        let written = self.inner.write(&buf[..allowed])?;
        self.bucket -= written as f64;
        Ok(written)
    }

    fn flush(&mut self) -> io::Result<()> {
        self.inner.flush()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rate_is_respected() {
        let rate = RateHandle::new(1_000_000); // 1 MB/s
        let mut w = ThrottledWriter::with_burst(Vec::new(), rate, 16 * 1024);
        let data = vec![0u8; 300_000];
        let t0 = Instant::now();
        w.write_all(&data).unwrap();
        let dt = t0.elapsed().as_secs_f64();
        // 300 KB minus 16 KB burst at 1 MB/s ≈ 0.28 s.
        assert!(dt > 0.20, "too fast: {dt}");
        assert!(dt < 1.0, "too slow: {dt}");
        assert_eq!(w.into_inner().len(), 300_000);
    }

    #[test]
    fn rate_handle_is_live() {
        let rate = RateHandle::new(100);
        let r2 = rate.clone();
        r2.set(1_000_000_000);
        let mut w = ThrottledWriter::with_burst(Vec::new(), rate, 1024);
        let t0 = Instant::now();
        w.write_all(&vec![0u8; 200_000]).unwrap();
        assert!(t0.elapsed().as_secs_f64() < 0.5, "new rate not picked up");
    }

    #[test]
    fn zero_rate_clamped() {
        let rate = RateHandle::new(0);
        assert_eq!(rate.get(), 1);
    }
}
