//! Simulated edge↔cloud channel for the in-process evaluation pipeline.
//!
//! The paper's transmission model is `T_trans = S_i(c) / BW` (§III-D);
//! we add an optional fixed RTT term and trace-driven time variation.
//! The channel keeps a virtual clock so back-to-back transfers queue
//! behind each other like a real uplink.

use super::trace::BandwidthTrace;

#[derive(Debug, Clone)]
pub struct SimChannel {
    trace: BandwidthTrace,
    rtt: f64,
    /// Virtual time (seconds since channel creation).
    now: f64,
    /// Totals for metrics.
    pub bytes_sent: u64,
    pub transfers: u64,
}

impl SimChannel {
    pub fn new(trace: BandwidthTrace, rtt: f64) -> Self {
        Self { trace, rtt, now: 0.0, bytes_sent: 0, transfers: 0 }
    }

    pub fn constant(bytes_per_sec: f64) -> Self {
        Self::new(BandwidthTrace::constant(bytes_per_sec), 0.0)
    }

    /// Current bandwidth (bytes/s) at the virtual clock.
    pub fn bandwidth_now(&self) -> f64 {
        self.trace.at(self.now)
    }

    pub fn now(&self) -> f64 {
        self.now
    }

    /// Advance the virtual clock by non-transfer work (compute).
    pub fn advance(&mut self, seconds: f64) {
        self.now += seconds.max(0.0);
    }

    /// Transfer `bytes`; returns the transmission latency and advances
    /// the clock. Integrates across trace segments: a transfer started
    /// in a slow period finishes faster once the trace steps up.
    pub fn transmit(&mut self, bytes: usize) -> f64 {
        let start = self.now;
        let mut remaining = bytes as f64;
        let mut t = self.now;
        // Integrate in small steps relative to the trace granularity.
        const DT: f64 = 0.010;
        let mut guard = 0u64;
        while remaining > 0.0 {
            let bw = self.trace.at(t).max(1.0);
            let sent = bw * DT;
            if sent >= remaining {
                t += remaining / bw;
                remaining = 0.0;
            } else {
                remaining -= sent;
                t += DT;
            }
            guard += 1;
            if guard > 100_000_000 {
                break; // pathological trace; avoid infinite loop
            }
        }
        self.now = t + self.rtt;
        self.bytes_sent += bytes as u64;
        self.transfers += 1;
        self.now - start
    }

    /// Latency a transfer of `bytes` would take right now, without
    /// advancing the clock (what the decision engine predicts).
    pub fn predict(&self, bytes: f64) -> f64 {
        bytes / self.bandwidth_now().max(1.0) + self.rtt
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constant_channel_is_linear() {
        let mut ch = SimChannel::constant(1_000_000.0);
        let t = ch.transmit(500_000);
        assert!((t - 0.5).abs() < 1e-2, "t={t}");
        assert_eq!(ch.bytes_sent, 500_000);
    }

    #[test]
    fn rtt_added_once_per_transfer() {
        let mut ch = SimChannel::new(BandwidthTrace::constant(1e6), 0.050);
        let t = ch.transmit(1000);
        assert!((t - 0.051).abs() < 1e-2, "t={t}");
    }

    #[test]
    fn step_trace_speeds_up_mid_transfer() {
        // 1 MB at 100 KB/s would take 10 s, but the trace steps to
        // 1 MB/s at t=1 s: 100 KB in the first second, 900 KB in ~0.9 s.
        let tr = BandwidthTrace::parse("0, 100000\n1.0, 1000000").unwrap();
        let mut ch = SimChannel::new(tr, 0.0);
        let t = ch.transmit(1_000_000);
        assert!((t - 1.9).abs() < 0.05, "t={t}");
    }

    #[test]
    fn clock_advances_with_compute() {
        let tr = BandwidthTrace::parse("0, 100000\n1.0, 1000000").unwrap();
        let mut ch = SimChannel::new(tr, 0.0);
        ch.advance(2.0); // past the step
        assert_eq!(ch.bandwidth_now(), 1_000_000.0);
        let t = ch.transmit(1_000_000);
        assert!((t - 1.0).abs() < 0.05, "t={t}");
    }

    #[test]
    fn predict_matches_constant_transmit() {
        let ch = SimChannel::constant(250_000.0);
        assert!((ch.predict(1_000_000.0) - 4.0).abs() < 1e-9);
    }
}
