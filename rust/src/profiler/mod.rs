//! Latency profiling: measured per-stage wall clocks and the paper's
//! analytic FMAC/FLOPS device model (§IV-A).
//!
//! The paper profiles `T_E(i)` / `T_C(i)` once per deployment ("for a
//! specific device, the execution time tends to be stable … iteratively
//! decouple the DNN on each layer and log the execution time") and, for
//! devices it does not own, simulates `T = w · Q(x)/F` with published
//! FLOPS figures. Both paths live here:
//!
//! * [`device`] — device catalog with the paper's exact constants;
//! * [`measure`] — wall-clock stage profiles via the PJRT executor;
//! * [`latency`] — the `T_E`/`T_C` tables the decision engine consumes,
//!   built from either source, plus `w` regression (`util::stats`).

pub mod device;
pub mod latency;
pub mod measure;

pub use device::DeviceModel;
pub use latency::LatencyTables;
pub use measure::measure_stages;
