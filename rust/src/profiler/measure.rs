//! Wall-clock stage profiling through the PJRT executor.
//!
//! The paper's deployment-time initialization: run every stage a few
//! times on this device, keep the median. Compilation is excluded (the
//! executor's lazy cache is warmed by the first pass).

use anyhow::Result;

use crate::runtime::{Executor, Tensor};
use crate::util::stats;

/// Median per-stage seconds for stages 1..=N of `model`.
pub fn measure_stages(exe: &Executor, model: &str, reps: usize) -> Result<Vec<f64>> {
    let m = exe.manifest().model(model)?;
    let n = m.num_stages();
    let input_shape = m.input_shape.clone();
    let x0 = crate::data::gen::sample_image_shaped(0, 9999, &input_shape);

    // Forward once, caching activations (and warming the compile cache).
    let mut acts: Vec<Tensor> = Vec::with_capacity(n + 1);
    acts.push(x0);
    for i in 1..=n {
        let out = exe.run_stage(model, i, &acts[i - 1])?;
        acts.push(out.tensor);
    }

    let mut medians = Vec::with_capacity(n);
    for i in 1..=n {
        let mut samples = Vec::with_capacity(reps);
        for _ in 0..reps.max(1) {
            samples.push(exe.run_stage(model, i, &acts[i - 1])?.seconds);
        }
        medians.push(stats::percentile(&samples, 50.0));
    }
    Ok(medians)
}

/// Median full-forward seconds (cloud-only baseline path).
pub fn measure_full(exe: &Executor, model: &str, reps: usize) -> Result<f64> {
    let m = exe.manifest().model(model)?;
    let x0 = crate::data::gen::sample_image_shaped(0, 9999, &m.input_shape.clone());
    let _ = exe.run_full(model, &x0)?; // warm compile
    let mut samples = Vec::with_capacity(reps);
    for _ in 0..reps.max(1) {
        samples.push(exe.run_full(model, &x0)?.seconds);
    }
    Ok(stats::percentile(&samples, 50.0))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::Manifest;

    #[test]
    fn measures_positive_latencies() {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            return;
        }
        let exe = Executor::new(Manifest::load(dir).unwrap()).unwrap();
        let t = measure_stages(&exe, "tinyconv", 3).unwrap();
        assert_eq!(t.len(), 4);
        assert!(t.iter().all(|&s| s > 0.0));
        let full = measure_full(&exe, "tinyconv", 3).unwrap();
        assert!(full > 0.0);
    }
}
