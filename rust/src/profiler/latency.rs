//! The `T_E(i)` / `T_C(i)` tables the decision engine consumes (§III-D).
//!
//! Two construction paths, matching the paper's two experiment modes:
//!
//! * [`LatencyTables::analytic`] — paper-scale simulation: full-scale
//!   FMAC tables (`models::fullscale`) through the `T = w·Q/F` device
//!   model, for arbitrary (edge, cloud) device pairs (Table III, Fig 7/8);
//! * [`LatencyTables::measured`] — deployment mode: wall clocks of the
//!   scaled executables on this host, with an `edge_slowdown` factor
//!   modelling the weaker edge silicon (both "devices" are this CPU).

use anyhow::Result;

use super::device::DeviceModel;
use super::measure;
use crate::models::fullscale_stages;
use crate::runtime::Executor;

#[derive(Debug, Clone)]
pub struct LatencyTables {
    /// `t_edge[i-1]`: edge seconds through stages 1..=i.
    pub t_edge: Vec<f64>,
    /// `t_cloud[i-1]`: cloud seconds for stages i+1..=N.
    pub t_cloud: Vec<f64>,
    /// Cloud seconds for the whole model (i*=0 path).
    pub t_cloud_full: f64,
}

impl LatencyTables {
    /// Paper-scale analytic tables for `model` on a device pair.
    pub fn analytic(model: &str, edge: DeviceModel, cloud: DeviceModel) -> Option<Self> {
        let fm = fullscale_stages(model)?;
        let n = fm.stages.len();
        let mut t_edge = Vec::with_capacity(n);
        let mut t_cloud = Vec::with_capacity(n);
        for i in 1..=n {
            t_edge.push(edge.latency(fm.fmacs_to(i)));
            t_cloud.push(cloud.latency(fm.fmacs_from(i)));
        }
        Some(Self { t_edge, t_cloud, t_cloud_full: cloud.latency(fm.total_fmacs()) })
    }

    /// Measured tables from the scaled executables on this host.
    ///
    /// `edge_slowdown ≥ 1` scales the edge side (the paper's edge GPU is
    /// ~12× weaker than its cloud GPU; our single host plays both roles).
    pub fn measured(
        exe: &Executor,
        model: &str,
        reps: usize,
        edge_slowdown: f64,
    ) -> Result<Self> {
        let per_stage = measure::measure_stages(exe, model, reps)?;
        let full = measure::measure_full(exe, model, reps)?;
        let n = per_stage.len();
        let mut t_edge = Vec::with_capacity(n);
        let mut t_cloud = Vec::with_capacity(n);
        let mut acc = 0.0;
        for s in &per_stage {
            acc += s;
            t_edge.push(acc * edge_slowdown);
        }
        let total: f64 = per_stage.iter().sum();
        let mut tail = total;
        for s in &per_stage {
            tail -= s;
            t_cloud.push(tail);
        }
        Ok(Self { t_edge, t_cloud, t_cloud_full: full })
    }

    pub fn num_stages(&self) -> usize {
        self.t_edge.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn analytic_tables_are_monotone() {
        let t = LatencyTables::analytic(
            "vgg16",
            DeviceModel::TEGRA_X2,
            DeviceModel::CLOUD_12T,
        )
        .unwrap();
        assert_eq!(t.num_stages(), 16);
        for w in t.t_edge.windows(2) {
            assert!(w[0] <= w[1], "t_edge must be cumulative");
        }
        for w in t.t_cloud.windows(2) {
            assert!(w[0] >= w[1], "t_cloud must shrink as the cut moves later");
        }
        assert_eq!(t.t_cloud[t.num_stages() - 1], 0.0);
        // Full-cloud run beats edge-full run on a weaker edge device.
        assert!(t.t_cloud_full < t.t_edge[15]);
    }

    #[test]
    fn weaker_edge_scales_edge_only() {
        let x2 =
            LatencyTables::analytic("resnet50", DeviceModel::TEGRA_X2, DeviceModel::CLOUD_12T)
                .unwrap();
        let k1 =
            LatencyTables::analytic("resnet50", DeviceModel::TEGRA_K1, DeviceModel::CLOUD_12T)
                .unwrap();
        for (a, b) in x2.t_edge.iter().zip(&k1.t_edge) {
            assert!((b / a - 2.0e12 / 300.0e9).abs() < 1e-6);
        }
        assert_eq!(x2.t_cloud, k1.t_cloud);
    }

    #[test]
    fn unknown_model_none() {
        assert!(LatencyTables::analytic("tinyconv", DeviceModel::TEGRA_X2, DeviceModel::CLOUD_12T)
            .is_none());
    }
}
