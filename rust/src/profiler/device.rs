//! Device catalog for the analytic latency model (paper §IV-A).
//!
//! `T = w · Q / F`: `Q` FMACs, `F` device FLOPS, `w` a fitted slack
//! factor absorbing everything the roofline misses (kernel launch,
//! memory traffic, framework overhead). The constants below are the
//! paper's own: `F_C = 12 TFLOPs`, `F_E = 2 TFLOPs` (Tegra X2) or
//! `300 GFLOPs` (Tegra K1), `w_e = 1.1176`, `w_c = 2.1761` (regressed on
//! an NVIDIA 1080ti at `F = 10.5 TFLOPs`).

#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DeviceModel {
    pub name: &'static str,
    /// Peak FLOPS.
    pub flops: f64,
    /// Fitted slack factor w (≥ 1 in practice).
    pub w: f64,
}

pub const W_EDGE: f64 = 1.1176;
pub const W_CLOUD: f64 = 2.1761;

impl DeviceModel {
    pub const CLOUD_12T: DeviceModel =
        DeviceModel { name: "cloud-12T", flops: 12.0e12, w: W_CLOUD };
    pub const GTX_1080TI: DeviceModel =
        DeviceModel { name: "gtx-1080ti", flops: 10.5e12, w: W_CLOUD };
    pub const TEGRA_X2: DeviceModel =
        DeviceModel { name: "tegra-x2", flops: 2.0e12, w: W_EDGE };
    pub const TEGRA_K1: DeviceModel =
        DeviceModel { name: "tegra-k1", flops: 300.0e9, w: W_EDGE };
    /// Paper's edge testbed GPU (Quadro K620, ~0.86 TFLOPs fp32).
    pub const QUADRO_K620: DeviceModel =
        DeviceModel { name: "quadro-k620", flops: 0.86e12, w: W_EDGE };

    pub fn by_name(name: &str) -> Option<DeviceModel> {
        [
            Self::CLOUD_12T,
            Self::GTX_1080TI,
            Self::TEGRA_X2,
            Self::TEGRA_K1,
            Self::QUADRO_K620,
        ]
        .into_iter()
        .find(|d| d.name == name)
    }

    /// Simulated execution latency of `fmacs` multiply-accumulates.
    pub fn latency(&self, fmacs: u64) -> f64 {
        self.w * fmacs as f64 / self.flops
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::fullscale_stages;

    #[test]
    fn catalog_lookup() {
        assert_eq!(DeviceModel::by_name("tegra-k1"), Some(DeviceModel::TEGRA_K1));
        assert!(DeviceModel::by_name("gameboy").is_none());
    }

    #[test]
    fn latency_scales_inverse_flops() {
        let q = 1_000_000_000u64;
        let fast = DeviceModel::TEGRA_X2.latency(q);
        let slow = DeviceModel::TEGRA_K1.latency(q);
        assert!((slow / fast - 2.0e12 / 300.0e9).abs() < 1e-9);
    }

    #[test]
    fn paper_scale_sanity() {
        // VGG16 (15.5 GFMACs) on the 12T cloud: w·Q/F ≈ 2.8 ms — the
        // order of magnitude the paper's latency plots show for compute.
        let m = fullscale_stages("vgg16").unwrap();
        let t = DeviceModel::CLOUD_12T.latency(m.total_fmacs());
        assert!(t > 1e-3 && t < 10e-3, "t = {t}");
        // Same net on Tegra K1: ~58 ms — two orders slower.
        let tk1 = DeviceModel::TEGRA_K1.latency(m.total_fmacs());
        assert!(tk1 > 20e-3 && tk1 < 200e-3, "tk1 = {tk1}");
    }
}
