//! Accuracy- and size-predictor lookup tables (paper §III-C).
//!
//! "We build a lookup table A_i(c) to predict the accuracy loss and
//! compressed data size S_i(c) in a specific quantization bit c. …
//! trained on ILSVRC2012 … once the lookup table is built, we don't
//! need a twice build-up process."
//!
//! [`tables::Tables`] is that pair of lookup tables, built by sweeping
//! the calibration set through the stage executables with the rust
//! quantizer twin, persisted as JSON under `artifacts/tables/`, and
//! consumed by the decision engine. [`tables::StabilityReport`]
//! reproduces Fig. 5's epoch-overlap argument.

pub mod tables;

pub use tables::{StabilityReport, Tables};
