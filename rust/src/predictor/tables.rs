//! Building, persisting and querying the `A_i(c)` / `S_i(c)` tables.

use std::path::Path;

use anyhow::{anyhow, Context, Result};

use crate::compression::{feature, quant};
use crate::data::gen;
use crate::runtime::{Executor, Tensor};
use crate::util::json::Json;
use crate::util::stats;

/// Default calibration bit-grid (c values the ILP may choose from).
pub const DEFAULT_C_GRID: &[u8] = &[1, 2, 3, 4, 6, 8];
/// Default calibration set size.
pub const DEFAULT_SAMPLES: usize = 48;
/// First sample id of the calibration range (distinct from the training
/// ids 0..1023 and the eval ids 2048.. used at build time).
pub const CALIB_OFFSET: usize = 4096;

#[derive(Debug, Clone, PartialEq)]
pub struct Tables {
    pub model: String,
    pub c_grid: Vec<u8>,
    pub samples: usize,
    /// Top-1 accuracy of the un-quantized model on the calibration set.
    pub base_accuracy: f64,
    /// `acc[i-1][k]` = A_i(c_grid[k]): accuracy *drop* in [0,1].
    pub acc: Vec<Vec<f64>>,
    /// `size[i-1][k]` = S_i(c_grid[k]): mean wire bytes.
    pub size: Vec<Vec<f64>>,
    /// Raw f32 feature bytes per stage (Fig. 2's in-layer sizes).
    pub raw_size: Vec<f64>,
    /// Mean PNG-like-compressed input image bytes (cloud-only upload).
    pub image_png_bytes: f64,
    /// Mean raw 8-bit RGB input bytes (Origin2Cloud upload).
    pub image_raw_bytes: f64,
}

impl Tables {
    /// Sweep the calibration ids through the stage executables.
    ///
    /// For every sample: one clean forward (activations cached), then for
    /// each decoupling point `i` and bit-width `c`: quantize → measure
    /// wire size → dequantize → run the tail → score against the label.
    pub fn build(
        exe: &Executor,
        model: &str,
        sample_ids: impl Iterator<Item = usize> + Clone,
        c_grid: &[u8],
    ) -> Result<Self> {
        let m = exe.manifest().model(model)?;
        let n = m.num_stages();
        let input_shape = m.input_shape.clone();
        let hw = input_shape[1];
        let ids: Vec<usize> = sample_ids.collect();
        assert!(!ids.is_empty());

        let mut correct_base = 0usize;
        let mut correct = vec![vec![0usize; c_grid.len()]; n];
        let mut sizes = vec![vec![0f64; c_grid.len()]; n];
        let mut png_bytes = 0f64;
        let mut raw_bytes = 0f64;

        for &id in &ids {
            let s = gen::sample_image(id, hw);
            // Clean forward, caching every activation.
            let mut acts: Vec<Tensor> = Vec::with_capacity(n + 1);
            acts.push(s.image.clone());
            for i in 1..=n {
                acts.push(exe.run_stage(model, i, &acts[i - 1])?.tensor);
            }
            let base_pred = acts[n].argmax();
            if base_pred == s.label {
                correct_base += 1;
            }
            // Input-image upload sizes for the baselines.
            let rgb = gen::to_rgb8(&s.image);
            raw_bytes += rgb.len() as f64;
            let img8 = crate::compression::png::Image8::new(hw, hw, 3, rgb);
            png_bytes += crate::compression::png::encode(&img8).len() as f64;

            for i in 1..=n {
                for (k, &c) in c_grid.iter().enumerate() {
                    let q = quant::quantize(acts[i].data(), c);
                    sizes[i - 1][k] += feature::encoded_size(&q) as f64;
                    let deq = quant::dequantize(&q);
                    let mut cur = Tensor::new(acts[i].shape().to_vec(), deq);
                    for j in i + 1..=n {
                        cur = exe.run_stage(model, j, &cur)?.tensor;
                    }
                    if cur.argmax() == s.label {
                        correct[i - 1][k] += 1;
                    }
                }
            }
        }

        let nf = ids.len() as f64;
        let base_accuracy = correct_base as f64 / nf;
        let acc = correct
            .iter()
            .map(|row| {
                row.iter().map(|&c| (base_accuracy - c as f64 / nf).max(0.0)).collect()
            })
            .collect();
        let size = sizes
            .iter()
            .map(|row| row.iter().map(|&b| b / nf).collect())
            .collect();
        let raw_size = (1..=n).map(|i| m.stage_raw_bytes(i) as f64).collect();

        Ok(Self {
            model: model.to_string(),
            c_grid: c_grid.to_vec(),
            samples: ids.len(),
            base_accuracy,
            acc,
            size,
            raw_size,
            image_png_bytes: png_bytes / nf,
            image_raw_bytes: raw_bytes / nf,
        })
    }

    pub fn num_stages(&self) -> usize {
        self.acc.len()
    }

    fn c_index(&self, c: u8) -> Result<usize> {
        self.c_grid
            .iter()
            .position(|&g| g == c)
            .ok_or_else(|| anyhow!("c={c} not in calibration grid {:?}", self.c_grid))
    }

    /// A_i(c); stage i is 1-based.
    pub fn acc_drop(&self, i: usize, c: u8) -> Result<f64> {
        Ok(self.acc[i - 1][self.c_index(c)?])
    }

    /// S_i(c) in bytes; stage i is 1-based.
    pub fn wire_bytes(&self, i: usize, c: u8) -> Result<f64> {
        Ok(self.size[i - 1][self.c_index(c)?])
    }

    /// Compression ratio raw/wire at (i, c) — scale-invariant, used to
    /// project paper-scale feature sizes (DESIGN.md).
    pub fn compression_ratio(&self, i: usize, c: u8) -> Result<f64> {
        Ok(self.raw_size[i - 1] / self.wire_bytes(i, c)?)
    }

    // ---------------- persistence ----------------

    pub fn to_json(&self) -> Json {
        let vv = |rows: &Vec<Vec<f64>>| {
            Json::arr(rows.iter().map(|r| Json::arr(r.iter().map(|&x| Json::num(x)))))
        };
        Json::obj(vec![
            ("model", Json::str(&self.model)),
            ("c_grid", Json::arr(self.c_grid.iter().map(|&c| Json::num(c as f64)))),
            ("samples", Json::num(self.samples as f64)),
            ("base_accuracy", Json::num(self.base_accuracy)),
            ("acc", vv(&self.acc)),
            ("size", vv(&self.size)),
            ("raw_size", Json::arr(self.raw_size.iter().map(|&x| Json::num(x)))),
            ("image_png_bytes", Json::num(self.image_png_bytes)),
            ("image_raw_bytes", Json::num(self.image_raw_bytes)),
        ])
    }

    pub fn from_json(j: &Json) -> Result<Self> {
        let vv = |key: &str| -> Result<Vec<Vec<f64>>> {
            j.get(key)
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("missing {key}"))?
                .iter()
                .map(|r| {
                    r.as_arr()
                        .ok_or_else(|| anyhow!("bad row in {key}"))?
                        .iter()
                        .map(|x| x.as_f64().ok_or_else(|| anyhow!("bad num")))
                        .collect()
                })
                .collect()
        };
        Ok(Self {
            model: j
                .get("model")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("missing model"))?
                .to_string(),
            c_grid: j
                .get("c_grid")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("missing c_grid"))?
                .iter()
                .map(|x| x.as_u64().map(|v| v as u8).ok_or_else(|| anyhow!("bad c")))
                .collect::<Result<_>>()?,
            samples: j.get("samples").and_then(Json::as_u64).unwrap_or(0) as usize,
            base_accuracy: j.get("base_accuracy").and_then(Json::as_f64).unwrap_or(0.0),
            acc: vv("acc")?,
            size: vv("size")?,
            raw_size: j
                .get("raw_size")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("missing raw_size"))?
                .iter()
                .map(|x| x.as_f64().ok_or_else(|| anyhow!("bad num")))
                .collect::<Result<_>>()?,
            image_png_bytes: j.get("image_png_bytes").and_then(Json::as_f64).unwrap_or(0.0),
            image_raw_bytes: j.get("image_raw_bytes").and_then(Json::as_f64).unwrap_or(0.0),
        })
    }

    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        if let Some(dir) = path.as_ref().parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path.as_ref(), self.to_json().to_pretty()).context("writing tables")
    }

    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("reading {:?}", path.as_ref()))?;
        Self::from_json(&Json::parse(&text).map_err(|e| anyhow!("{e}"))?)
    }

    /// Load from `<dir>/tables/<model>.json`, or build and cache.
    pub fn load_or_build(exe: &Executor, model: &str, dir: impl AsRef<Path>) -> Result<Self> {
        let path = dir.as_ref().join("tables").join(format!("{model}.json"));
        if path.exists() {
            if let Ok(t) = Self::load(&path) {
                if t.model == model {
                    return Ok(t);
                }
            }
        }
        let ids = CALIB_OFFSET..CALIB_OFFSET + DEFAULT_SAMPLES;
        let t = Self::build(exe, model, ids, DEFAULT_C_GRID)?;
        t.save(&path)?;
        Ok(t)
    }
}

/// Fig. 5's epoch-stability evidence: tables from two disjoint sample
/// epochs should overlap tightly.
#[derive(Debug, Clone)]
pub struct StabilityReport {
    pub model: String,
    /// Max |ΔA_i(c)| across all (i, c).
    pub max_acc_delta: f64,
    /// Max relative size deviation across all (i, c).
    pub max_size_rel_delta: f64,
    /// Pearson correlation of the flattened size tables.
    pub size_correlation: f64,
}

impl StabilityReport {
    pub fn compare(a: &Tables, b: &Tables) -> Self {
        assert_eq!(a.c_grid, b.c_grid);
        assert_eq!(a.num_stages(), b.num_stages());
        let mut max_acc = 0f64;
        let mut max_size = 0f64;
        let mut xs = Vec::new();
        let mut ys = Vec::new();
        for i in 0..a.num_stages() {
            for k in 0..a.c_grid.len() {
                max_acc = max_acc.max((a.acc[i][k] - b.acc[i][k]).abs());
                let rel = (a.size[i][k] - b.size[i][k]).abs() / a.size[i][k].max(1.0);
                max_size = max_size.max(rel);
                xs.push(a.size[i][k]);
                ys.push(b.size[i][k]);
            }
        }
        Self {
            model: a.model.clone(),
            max_acc_delta: max_acc,
            max_size_rel_delta: max_size,
            size_correlation: stats::pearson(&xs, &ys),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn executor() -> Option<Executor> {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            return None;
        }
        Some(Executor::new(crate::runtime::Manifest::load(dir).unwrap()).unwrap())
    }

    #[test]
    fn build_on_tinyconv_and_query() {
        let Some(exe) = executor() else { return };
        let t = Tables::build(&exe, "tinyconv", 5000..5008, &[1, 4, 8]).unwrap();
        assert_eq!(t.num_stages(), 4);
        assert!(t.base_accuracy >= 0.5, "base acc {}", t.base_accuracy);
        // Sizes grow with c; accuracy drop shrinks with c (weakly).
        for i in 1..=4 {
            assert!(t.wire_bytes(i, 1).unwrap() <= t.wire_bytes(i, 8).unwrap());
            assert!(t.acc_drop(i, 1).unwrap() >= t.acc_drop(i, 8).unwrap() - 1e-9);
            assert!(t.compression_ratio(i, 4).unwrap() > 1.0);
        }
        assert!(t.image_png_bytes > 0.0 && t.image_png_bytes < t.image_raw_bytes * 1.2);
        assert!(t.acc_drop(1, 5).is_err(), "off-grid c must error");
    }

    #[test]
    fn json_roundtrip() {
        let Some(exe) = executor() else { return };
        let t = Tables::build(&exe, "tinyconv", 5000..5004, &[2, 8]).unwrap();
        let path = std::env::temp_dir().join("jalad_tables_test.json");
        t.save(&path).unwrap();
        let back = Tables::load(&path).unwrap();
        assert_eq!(t, back);
    }

    #[test]
    fn stability_between_epochs() {
        let Some(exe) = executor() else { return };
        let a = Tables::build(&exe, "tinyconv", 5000..5012, &[4, 8]).unwrap();
        let b = Tables::build(&exe, "tinyconv", 5100..5112, &[4, 8]).unwrap();
        let rep = StabilityReport::compare(&a, &b);
        // Fig. 5: different epochs "highly overlapped".
        assert!(rep.size_correlation > 0.99, "corr {}", rep.size_correlation);
        assert!(rep.max_size_rel_delta < 0.15, "size delta {}", rep.max_size_rel_delta);
        assert!(rep.max_acc_delta <= 0.35, "acc delta {}", rep.max_acc_delta);
    }
}
