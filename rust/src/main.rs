//! `jalad` — the leader CLI: calibrate, decide, serve, infer, profile.
//!
//! ```text
//! jalad calibrate --model vgg16            # build A_i(c)/S_i(c) tables
//! jalad decide --model vgg16 --bw 300000   # print the ILP plan
//! jalad serve-cloud --addr 127.0.0.1:7878  # run the cloud server
//! jalad serve-edge --addr 127.0.0.1:7800 --upstream 127.0.0.1:7878 --sim
//!                                           # middle tier: device → edge → cloud
//! jalad serve-registry --addr 127.0.0.1:7979   # signed-manifest model registry
//! jalad infer --model resnet50 --bw 125000 --requests 20
//! jalad infer --connect --sim --registry 127.0.0.1:7979   # model fetched+verified from the registry
//! jalad profile --model vgg16              # per-stage wall clocks
//! ```

use std::sync::Arc;

use anyhow::{anyhow, Result};

use jalad::coordinator::{ControlPlane, DecisionEngine, LocalPipeline, Scale};
use jalad::ilp::Decision;
use jalad::network::SimChannel;
use jalad::predictor::Tables;
use jalad::profiler::{measure_stages, DeviceModel, LatencyTables};
use jalad::runtime::{BatchConfig, Executor, ExecutorPool, Manifest};
use jalad::server::{CloudServer, IoModel, ServeConfig};
use jalad::util::cli::Args;

fn main() {
    jalad::util::logging::init();
    // One declared knob table (util::cli) composed from shared groups:
    // every subcommand accepts the same names with the same defaults,
    // and adding a knob is a one-line change in the group it belongs to.
    let args = Args::new(
        "jalad",
        "joint accuracy- and latency-aware deep structure decoupling (PADSW'18)",
    )
    .with_common_knobs()
    .with_serve_knobs()
    .with_edge_knobs()
    .with_tier_knobs()
    .parse_env();

    let command = args.positional().first().cloned().unwrap_or_else(|| {
        eprintln!("{}", args.usage());
        eprintln!(
            "COMMANDS: calibrate | decide | serve-cloud | serve-edge | serve-registry | infer | profile"
        );
        std::process::exit(2);
    });

    if let Err(e) = run(&command, &args) {
        eprintln!("jalad {command}: {e:#}");
        std::process::exit(1);
    }
}

fn engine(args: &Args, exe: &Executor) -> Result<DecisionEngine> {
    let model = args.get("model");
    let tables = Tables::load_or_build(exe, model, args.get("artifacts"))?;
    let (latency, scale) = if args.get_flag("paper-scale") {
        let edge = DeviceModel::by_name(args.get("edge-device"))
            .ok_or_else(|| anyhow!("unknown edge device"))?;
        let cloud = DeviceModel::by_name(args.get("cloud-device"))
            .ok_or_else(|| anyhow!("unknown cloud device"))?;
        (
            LatencyTables::analytic(model, edge, cloud)
                .ok_or_else(|| anyhow!("no full-scale table for {model}"))?,
            Scale::Paper,
        )
    } else {
        (LatencyTables::measured(exe, model, 3, 4.0)?, Scale::Measured)
    };
    DecisionEngine::new(model, tables, latency, scale, args.get_f64("delta-alpha"))
}

/// Assemble a [`ServeConfig`] from the shared serve knob group —
/// `serve-cloud` and `serve-edge` embed the identical server, so they
/// share this translation (and its validation) verbatim.
fn build_serve_config(args: &Args) -> Result<ServeConfig> {
    let admission_util = args.get_f64("admission-util");
    let xmodel = match args.get("xmodel-batch") {
        "on" | "true" | "1" => true,
        "off" | "false" | "0" => false,
        other => return Err(anyhow!("--xmodel-batch must be on|off, got {other:?}")),
    };
    let pad_waste_max = args.get_f64("pad-waste-max");
    if !(0.0..=1.0).contains(&pad_waste_max) {
        return Err(anyhow!("--pad-waste-max must be in 0..=1, got {pad_waste_max}"));
    }
    let cache_hit_cost = args.get_f64("cache-hit-cost");
    if !(0.0..=1.0).contains(&cache_hit_cost) {
        return Err(anyhow!("--cache-hit-cost must be in 0..=1, got {cache_hit_cost}"));
    }
    Ok(ServeConfig {
        workers: args.get_usize("workers"),
        batch: BatchConfig {
            max_batch: args.get_usize("max-batch").max(1),
            gather_window: std::time::Duration::from_micros(args.get_usize("gather-us") as u64),
            min_gather: std::time::Duration::from_micros(args.get_usize("gather-min-us") as u64),
            adaptive_gather: !args.get_flag("no-adaptive-gather"),
            enabled: !args.get_flag("no-batch"),
            xmodel,
            pad_waste_max,
            ..BatchConfig::default()
        },
        admission: jalad::server::AdmissionConfig {
            queue_p95_budget: std::time::Duration::from_millis(
                args.get_usize("admission-queue-ms") as u64,
            ),
            utilization_budget: if admission_util > 0.0 { admission_util } else { f64::INFINITY },
            deadline: std::time::Duration::from_millis(args.get_usize("deadline-ms") as u64),
            fair: args.get_flag("fair-admission"),
            tenant_budget: args.get_f64("tenant-budget"),
            ..jalad::server::AdmissionConfig::default()
        },
        pin_shards: args.get_flag("pin-shards"),
        io: IoModel::parse(args.get("io"))?,
        max_conns: args.get_usize("max-conns").max(1),
        idle_timeout: std::time::Duration::from_secs(args.get_usize("idle-timeout-s") as u64),
        watchdog_ms: args.get_usize("watchdog-ms") as u64,
        cache_bytes: args.get_usize("cache-bytes"),
        cache_hit_cost,
    })
}

/// Configure an [`jalad::server::EdgeClient`]'s hop knobs (deadline,
/// breaker, integrity, faults) from the shared edge knob group — used
/// by `infer --connect` and by the upstream link `serve-edge` embeds.
fn apply_edge_knobs(edge: &mut jalad::server::EdgeClient<'_>, args: &Args) -> Result<()> {
    edge.set_request_timeout(std::time::Duration::from_millis(
        args.get_usize("request-timeout-ms") as u64,
    ))?;
    edge.set_breaker_config(jalad::server::BreakerConfig {
        failure_threshold: args.get_usize("breaker-failures") as u32,
        cooldown: std::time::Duration::from_millis(args.get_usize("breaker-cooldown-ms") as u64),
        ..Default::default()
    });
    if !args.get("fault-plan").is_empty() {
        edge.set_fault_plan(Some(
            jalad::util::fault::FaultPlan::parse_arc(args.get("fault-plan"))
                .map_err(|e| anyhow!("--fault-plan: {e}"))?,
        ));
    }
    if args.get_flag("checked") {
        edge.set_checked(true);
    }
    Ok(())
}

fn run(command: &str, args: &Args) -> Result<()> {
    let dir = args.get("artifacts").to_string();
    match command {
        "calibrate" => {
            let exe = Executor::new(Manifest::load(&dir)?)?;
            let model = args.get("model");
            let t = Tables::load_or_build(&exe, model, &dir)?;
            println!(
                "{model}: {} stages, base accuracy {:.3}, c grid {:?} (cached under {dir}/tables)",
                t.num_stages(),
                t.base_accuracy,
                t.c_grid
            );
        }
        "decide" => {
            let exe = Executor::new(Manifest::load(&dir)?)?;
            let engine = engine(args, &exe)?;
            let bw = args.get_f64("bw");
            let plan = engine.decide(bw);
            println!(
                "model={} bw={:.0} B/s Δα={}: {:?}  latency={:.2} ms  acc_drop={:.3}  tx={:.0} B",
                args.get("model"),
                bw,
                args.get("delta-alpha"),
                plan.decision(),
                plan.latency * 1e3,
                plan.acc_drop,
                plan.tx_bytes
            );
        }
        "serve-cloud" => {
            let shards = args.get_usize("shards");
            let pool = if args.get_flag("sim") {
                ExecutorPool::new_sim(jalad::runtime::sim::sim_manifest(), shards)
            } else {
                ExecutorPool::new_pjrt(Manifest::load(&dir)?, shards)?
            };
            let cfg = build_serve_config(args)?;
            if !args.get("fault-plan").is_empty() {
                let plan = jalad::util::fault::FaultPlan::parse_arc(args.get("fault-plan"))
                    .map_err(|e| anyhow!("--fault-plan: {e}"))?;
                pool.set_exec_faults(Some(plan));
            }
            let io = cfg.io;
            let xmodel = cfg.batch.xmodel;
            let admission_on = cfg.admission.utilization_budget.is_finite()
                || !cfg.admission.queue_p95_budget.is_zero();
            let server = Arc::new(CloudServer::with_pool(pool, cfg));
            let (addr, handle) = Arc::clone(&server).spawn(args.get("addr"))?;
            println!(
                "cloud server on {addr}: {shards} shard(s), {} transport, max {} conns, \
                 max batch {}, gather {}..{} µs{}{}{}{}{} \
                 (Ctrl-C or a Shutdown frame stops it)",
                match io {
                    IoModel::Epoll => "epoll",
                    IoModel::Threads => "threads",
                },
                args.get_usize("max-conns").max(1),
                args.get_usize("max-batch"),
                args.get_usize("gather-min-us"),
                args.get_usize("gather-us"),
                if args.get_flag("no-batch") {
                    ", batching OFF"
                } else if !xmodel {
                    ", cross-model batching OFF"
                } else {
                    ""
                },
                if admission_on { ", admission ON" } else { "" },
                if args.get_flag("fair-admission") { ", fair admission ON" } else { "" },
                if args.get_usize("cache-bytes") > 0 { ", logits cache ON" } else { "" },
                if args.get_flag("pin-shards") { ", shard pinning ON" } else { "" },
            );
            handle.join().ok();
        }
        "serve-edge" => {
            // The middle-tier role for three-tier (device → edge →
            // cloud) topologies: this process embeds the same server
            // `serve-cloud` runs for the hop below, and every data
            // frame is offered to an `EdgeTier` that runs this tier's
            // stage span per its own multi-hop plan, then forwards
            // through an embedded `EdgeClient` toward --upstream. A
            // cloud that goes away degrades through the breaker to
            // local serving (the surviving device↔edge pair); the
            // upstream must be reachable at start, though.
            let upstream: std::net::SocketAddr = args
                .get("upstream")
                .parse()
                .map_err(|e| anyhow!("--upstream {}: {e}", args.get("upstream")))?;
            let sim = args.get_flag("sim");
            // The tier's forwarder hook is 'static (it outlives every
            // connection worker), so the upstream client's executor is
            // leaked once for the process lifetime.
            let exe: &'static Executor = if sim {
                Box::leak(Box::new(Executor::sim_with(jalad::runtime::sim::sim_manifest(), 8)))
            } else {
                Box::leak(Box::new(Executor::new(Manifest::load(&dir)?)?))
            };
            let (eng, model) = if sim {
                (DecisionEngine::sim_default(args.get_f64("delta-alpha"))?, "simnet".to_string())
            } else {
                (engine(args, exe)?, args.get("model").to_string())
            };
            let controller = ControlPlane::new(eng, args.get_f64("bw"));
            let rate = jalad::network::throttle::RateHandle::new(args.get_f64("bw") as u64);
            let mut client =
                jalad::server::EdgeClient::connect(exe, &model, upstream, rate, controller)?;
            apply_edge_knobs(&mut client, args)?;
            let tier = Arc::new(jalad::server::EdgeTier::new(exe, client));
            let shards = args.get_usize("shards");
            let pool = if sim {
                ExecutorPool::new_sim(jalad::runtime::sim::sim_manifest(), shards)
            } else {
                ExecutorPool::new_pjrt(Manifest::load(&dir)?, shards)?
            };
            let mut srv = CloudServer::with_pool(pool, build_serve_config(args)?);
            srv.set_forwarder(Arc::clone(&tier) as Arc<dyn jalad::server::TierForwarder>);
            let server = Arc::new(srv);
            tier.attach(&server);
            let (addr, handle) = Arc::clone(&server).spawn(args.get("addr"))?;
            println!(
                "edge tier on {addr} → upstream {upstream}: {shards} local shard(s), \
                 upstream hop at {:.0} B/s prior (Ctrl-C or a Shutdown frame stops it)",
                args.get_f64("bw"),
            );
            handle.join().ok();
        }
        "serve-registry" => {
            // Stand up the model-distribution control plane with the
            // two sim versions published (v1 active, v2 staged for
            // rollout) — enough to drive a full fetch/verify/hot-swap
            // cycle against `infer --connect --sim --registry`.
            let key = jalad::util::sign::SigKey::from_seed(args.get_usize("sign-seed") as u64);
            let reg = jalad::server::RegistryServer::new(key);
            reg.publish("v1", &jalad::runtime::sim::sim_manifest())?;
            reg.publish("v2", &jalad::runtime::sim::sim_manifest_v2())?;
            reg.activate("v1")?;
            let (addr, handle) = Arc::clone(&reg).spawn(args.get("addr"))?;
            println!(
                "model registry on {addr}: versions {:?}, active {:?} \
                 (a Shutdown frame stops it)",
                reg.versions(),
                reg.active_version().unwrap_or_default()
            );
            handle.join().ok();
        }
        "infer" if args.get_flag("connect") => {
            // Remote mode: a real EdgeClient over TCP against --addr,
            // with an optional explicit tenant identity — the client
            // half of the multi-edge serving story (`--sim` pairs with
            // `serve-cloud --sim`, no artifacts needed on either end).
            let addr: std::net::SocketAddr = args
                .get("addr")
                .parse()
                .map_err(|e| anyhow!("--addr {}: {e}", args.get("addr")))?;
            let sim = args.get_flag("sim");
            // A device-class profile plays a weaker device tier: the
            // sim backend burns that class's per-stage cost, and the
            // uplink prior is the class's constrained link.
            let devclass = match jalad::runtime::DeviceClass::by_name(args.get("device-class")) {
                Some(d) => Some(d),
                None if args.get("device-class").is_empty() => None,
                None => {
                    return Err(anyhow!("unknown --device-class {:?}", args.get("device-class")))
                }
            };
            let fanin = devclass.map(|d| d.fanin).unwrap_or(8);
            let exe = if sim && !args.get("registry").is_empty() {
                // Registry mode: the manifest arrives signed, every
                // chunk arrives content-verified, and only then does an
                // executor exist — nothing unverified can run.
                let cache = jalad::server::ArtifactCache::new(
                    args.get_usize("artifact-cache-bytes").max(1),
                );
                let key =
                    jalad::util::sign::SigKey::from_seed(args.get_usize("sign-seed") as u64);
                let mut rc =
                    jalad::server::RegistryClient::connect(args.get("registry"), key, cache)?;
                let pin = args.get("pin-version");
                let fetched =
                    rc.fetch_manifest(if pin.is_empty() { None } else { Some(pin) })?;
                for c in &fetched.chunks {
                    rc.fetch_chunk(c.hash)?;
                }
                println!(
                    "registry: verified manifest {:?} and {} chunk(s) ({} bytes cached)",
                    fetched.version,
                    fetched.chunks.len(),
                    rc.cache().bytes()
                );
                Executor::sim_with(fetched.manifest, fanin)
            } else if sim {
                Executor::sim_with(jalad::runtime::sim::sim_manifest(), fanin)
            } else {
                Executor::new(Manifest::load(&dir)?)?
            };
            let (eng, model) = if sim {
                (DecisionEngine::sim_default(args.get_f64("delta-alpha"))?, "simnet".to_string())
            } else {
                (engine(args, &exe)?, args.get("model").to_string())
            };
            let bw = devclass.map(|d| d.uplink_bps).unwrap_or_else(|| args.get_f64("bw"));
            let controller = ControlPlane::new(eng, bw);
            let rate = jalad::network::throttle::RateHandle::new(bw as u64);
            let mut edge = jalad::server::EdgeClient::connect(&exe, &model, addr, rate, controller)?;
            apply_edge_knobs(&mut edge, args)?;
            if !args.get("tenant").is_empty() {
                let t: u32 = args
                    .get("tenant")
                    .parse()
                    .map_err(|_| anyhow!("--tenant must be a u32"))?;
                edge.set_tenant(Some(t));
            }
            let shape = exe.manifest().model(&model)?.input_shape.clone();
            let mut correct = 0usize;
            let mut sheds = 0usize;
            let n = args.get_usize("requests");
            for id in 0..n {
                let s = jalad::data::gen::Sample {
                    image: jalad::data::gen::sample_image_shaped((9000 + id) % 16, 9000 + id, &shape),
                    label: (9000 + id) % 16,
                };
                let r = edge.infer(&s)?;
                correct += r.correct as usize;
                sheds += r.sheds;
                println!(
                    "req {id:3}  {:?}  sheds {}  {}{}",
                    r.decision,
                    r.sheds,
                    r.breakdown.summary(),
                    if r.served_locally { "  [local]" } else { "" }
                );
            }
            println!("accuracy {}/{n}, {} sheds absorbed", correct, sheds);
            println!("stats: {}", edge.stats()?);
        }
        "infer" => {
            let exe = Executor::new(Manifest::load(&dir)?)?;
            let eng = engine(args, &exe)?;
            let model = args.get("model");
            let mut pipe = LocalPipeline::new(&exe, model);
            let mut controller = ControlPlane::new(eng, args.get_f64("bw"));
            let mut channel = SimChannel::constant(args.get_f64("bw"));
            let mut correct = 0usize;
            let n = args.get_usize("requests");
            for id in 0..n {
                let s = jalad::data::gen::sample_image(9000 + id, 32);
                let plan = controller.plan().clone();
                let r = pipe.run(&s, plan.decision(), &mut channel)?;
                correct += r.correct as usize;
                println!("req {id:3}  {:?}  {}", r.decision, r.breakdown.summary());
            }
            println!("accuracy {}/{n}", correct);
        }
        "profile" => {
            let exe = Executor::new(Manifest::load(&dir)?)?;
            let model = args.get("model");
            let t = measure_stages(&exe, model, 5)?;
            println!("{model}: per-stage median seconds");
            for (i, s) in t.iter().enumerate() {
                println!("  stage {:2}  {:9.3} ms", i + 1, s * 1e3);
            }
            println!("  total    {:9.3} ms", t.iter().sum::<f64>() * 1e3);
        }
        other => {
            return Err(anyhow!(
                "unknown command {other:?} (calibrate|decide|serve-cloud|serve-edge|serve-registry|infer|profile)"
            ))
        }
    }
    Ok(())
}
