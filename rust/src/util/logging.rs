//! Leveled stderr logger with per-run monotonic timestamps.
//!
//! `JALAD_LOG=debug|info|warn|error` selects the level (default `info`).

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Debug = 0,
    Info = 1,
    Warn = 2,
    Error = 3,
}

static LEVEL: AtomicU8 = AtomicU8::new(1);
static START: OnceLock<Instant> = OnceLock::new();

fn start() -> Instant {
    *START.get_or_init(Instant::now)
}

/// Initialize from the environment; safe to call more than once.
pub fn init() {
    start();
    let lvl = match std::env::var("JALAD_LOG").as_deref() {
        Ok("debug") => Level::Debug,
        Ok("warn") => Level::Warn,
        Ok("error") => Level::Error,
        _ => Level::Info,
    };
    set_level(lvl);
}

pub fn set_level(l: Level) {
    LEVEL.store(l as u8, Ordering::Relaxed);
}

pub fn enabled(l: Level) -> bool {
    l as u8 >= LEVEL.load(Ordering::Relaxed)
}

pub fn log(l: Level, target: &str, msg: std::fmt::Arguments<'_>) {
    if !enabled(l) {
        return;
    }
    let t = start().elapsed();
    let tag = match l {
        Level::Debug => "DBG",
        Level::Info => "INF",
        Level::Warn => "WRN",
        Level::Error => "ERR",
    };
    eprintln!("[{:>9.3}s {} {}] {}", t.as_secs_f64(), tag, target, msg);
}

#[macro_export]
macro_rules! log_debug {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Debug, $target, format_args!($($arg)*))
    };
}
#[macro_export]
macro_rules! log_info {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Info, $target, format_args!($($arg)*))
    };
}
#[macro_export]
macro_rules! log_warn {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Warn, $target, format_args!($($arg)*))
    };
}
#[macro_export]
macro_rules! log_error {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Error, $target, format_args!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_filtering() {
        set_level(Level::Warn);
        assert!(!enabled(Level::Info));
        assert!(enabled(Level::Warn));
        assert!(enabled(Level::Error));
        set_level(Level::Info);
        assert!(enabled(Level::Info));
        assert!(!enabled(Level::Debug));
    }
}
