//! Tiny declarative CLI argument parser (no clap offline).
//!
//! Supports `--flag`, `--key value`, `--key=value`, positional args, and
//! auto-generated `--help`. Used by `rust/src/main.rs` and every example.
//!
//! The `with_*_knobs` builders below are the one declared knob table
//! for the `jalad` subcommands: `serve-cloud`, `serve-edge`,
//! `serve-registry` and `infer` all compose the same groups, so a knob
//! has one name, one default and one help string no matter which
//! subcommand reads it — adding a flag is a one-line change here, and
//! the `--help` coverage test pins that every accepted option is
//! documented.

use std::collections::BTreeMap;

#[derive(Debug, Clone)]
struct Spec {
    name: &'static str,
    help: &'static str,
    default: Option<String>,
    is_flag: bool,
}

/// Declarative argument set. Build with [`Args::new`], declare options,
/// then [`Args::parse`].
#[derive(Debug, Clone)]
pub struct Args {
    program: &'static str,
    about: &'static str,
    specs: Vec<Spec>,
    values: BTreeMap<&'static str, String>,
    flags: BTreeMap<&'static str, bool>,
    positional: Vec<String>,
}

impl Args {
    pub fn new(program: &'static str, about: &'static str) -> Self {
        Self {
            program,
            about,
            specs: Vec::new(),
            values: BTreeMap::new(),
            flags: BTreeMap::new(),
            positional: Vec::new(),
        }
    }

    /// `--name <value>` option with a default.
    pub fn opt(mut self, name: &'static str, default: &str, help: &'static str) -> Self {
        self.specs.push(Spec { name, help, default: Some(default.to_string()), is_flag: false });
        self.values.insert(name, default.to_string());
        self
    }

    /// Boolean `--name` flag (default false).
    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.specs.push(Spec { name, help, default: None, is_flag: true });
        self.flags.insert(name, false);
        self
    }

    /// Parse `std::env::args().skip(1)`-style input. On `--help`, prints
    /// usage and exits. Unknown options are an error.
    pub fn parse(self, argv: impl IntoIterator<Item = String>) -> Result<Args, String> {
        let mut me = self;
        let mut it = argv.into_iter().peekable();
        while let Some(arg) = it.next() {
            if arg == "--help" || arg == "-h" {
                eprintln!("{}", me.usage());
                std::process::exit(0);
            }
            if let Some(stripped) = arg.strip_prefix("--") {
                let (key, inline_val) = match stripped.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (stripped.to_string(), None),
                };
                let spec = me
                    .specs
                    .iter()
                    .find(|s| s.name == key)
                    .ok_or_else(|| format!("unknown option --{key}\n{}", me.usage()))?
                    .clone();
                if spec.is_flag {
                    if inline_val.is_some() {
                        return Err(format!("flag --{key} takes no value"));
                    }
                    *me.flags.get_mut(spec.name).unwrap() = true;
                } else {
                    let val = match inline_val {
                        Some(v) => v,
                        None => it
                            .next()
                            .ok_or_else(|| format!("option --{key} needs a value"))?,
                    };
                    *me.values.get_mut(spec.name).unwrap() = val;
                }
            } else {
                me.positional.push(arg);
            }
        }
        Ok(me)
    }

    /// Convenience: parse the real process arguments, exiting on error.
    pub fn parse_env(self) -> Args {
        match self.parse(std::env::args().skip(1)) {
            Ok(a) => a,
            Err(e) => {
                eprintln!("{e}");
                std::process::exit(2);
            }
        }
    }

    pub fn usage(&self) -> String {
        let mut s = format!("{} — {}\n\nOPTIONS:\n", self.program, self.about);
        for spec in &self.specs {
            let head = if spec.is_flag {
                format!("  --{}", spec.name)
            } else {
                format!("  --{} <v> [default: {}]", spec.name, spec.default.as_deref().unwrap())
            };
            s.push_str(&format!("{head:<44} {}\n", spec.help));
        }
        s
    }

    pub fn get(&self, name: &str) -> &str {
        self.values.get(name).map(|s| s.as_str()).unwrap_or_else(|| {
            panic!("option --{name} was never declared");
        })
    }
    pub fn get_flag(&self, name: &str) -> bool {
        *self.flags.get(name).unwrap_or(&false)
    }
    pub fn get_usize(&self, name: &str) -> usize {
        self.parse_num(name)
    }
    pub fn get_u64(&self, name: &str) -> u64 {
        self.parse_num(name)
    }
    pub fn get_f64(&self, name: &str) -> f64 {
        self.parse_num(name)
    }
    pub fn positional(&self) -> &[String] {
        &self.positional
    }

    fn parse_num<T: std::str::FromStr>(&self, name: &str) -> T {
        let raw = self.get(name);
        raw.parse().unwrap_or_else(|_| {
            eprintln!("--{name}: cannot parse {raw:?}");
            std::process::exit(2);
        })
    }

    /// Names of every declared option and flag, in declaration order —
    /// the `--help` coverage test iterates these against [`usage`].
    ///
    /// [`usage`]: Args::usage
    pub fn declared(&self) -> Vec<&'static str> {
        self.specs.iter().map(|s| s.name).collect()
    }

    // ---- the shared jalad knob table ------------------------------
    //
    // Each group is declared once and composed per subcommand; a knob
    // that two subcommands read (e.g. `--bw` for `infer`'s uplink and
    // `serve-edge`'s upstream hop) therefore cannot drift in name,
    // default, or help text.

    /// Knobs every subcommand reads: artifacts, model/plan selection,
    /// link bandwidth, server address, fault injection.
    pub fn with_common_knobs(self) -> Self {
        self.opt("artifacts", "artifacts", "artifact directory (make artifacts)")
            .opt("model", "vgg16", "model name (vgg16|vgg19|resnet50|resnet101|tinyconv)")
            .opt("bw", "125000", "bandwidth of this process's upstream hop, bytes/second")
            .opt("delta-alpha", "0.10", "accuracy-loss bound Δα")
            .opt("addr", "127.0.0.1:7878", "address this process serves on / connects to")
            .opt("edge-device", "tegra-x2", "edge device for paper-scale decisions")
            .opt("cloud-device", "cloud-12T", "cloud device for paper-scale decisions")
            .opt(
                "fault-plan",
                "",
                "deterministic fault spec, e.g. seed=7,corrupt=0.05,stall-p=0.1,stall-ms=200 (see util::fault)",
            )
            .flag("sim", "use the deterministic sim backend (no artifacts)")
            .flag("paper-scale", "use the paper's analytic FMAC/FLOPS latency model")
    }

    /// Knobs for the server role (`serve-cloud` and `serve-edge`, which
    /// embeds the same server for the hop below it).
    pub fn with_serve_knobs(self) -> Self {
        self.opt("shards", "2", "serve: independent executor shards (PJRT clients)")
            .opt("workers", "16", "serve: pooled connection workers")
            .opt("max-batch", "4", "serve: max requests coalesced per tail batch")
            .opt("gather-us", "1000", "serve: micro-batch gather window ceiling, microseconds")
            .opt("gather-min-us", "100", "serve: adaptive gather window floor, microseconds")
            .opt(
                "xmodel-batch",
                "on",
                "serve: coalesce signature-compatible tails across models (on|off)",
            )
            .opt(
                "pad-waste-max",
                "0.25",
                "serve: max padded-waste fraction for mixed-geometry batches (0 = exact geometry only)",
            )
            .opt(
                "admission-queue-ms",
                "0",
                "serve: shed (Busy) when windowed queue-wait p95 exceeds this, ms (0 = off)",
            )
            .opt(
                "admission-util",
                "0",
                "serve: shed (Busy) when busiest-shard utilization exceeds this, 0..1 (0 = off)",
            )
            .opt(
                "deadline-ms",
                "0",
                "serve: SLA deadline attached to admitted requests, ms (0 = none)",
            )
            .opt(
                "tenant-budget",
                "0",
                "serve: global admitted req/s under overload, water-filled across tenants (0 = auto)",
            )
            .opt(
                "io",
                "auto",
                "serve: socket transport — epoll reactor or blocking threads (threads|epoll|auto)",
            )
            .opt(
                "max-conns",
                "16384",
                "serve: refuse (Busy) connections past this many concurrently assigned",
            )
            .opt(
                "idle-timeout-s",
                "300",
                "serve: reap connections with no frame progress for this long, s (0 = never; epoll transport)",
            )
            .opt(
                "watchdog-ms",
                "0",
                "serve: quarantine a shard whose single run exceeds this, ms (0 = off)",
            )
            .opt(
                "cache-bytes",
                "0",
                "serve: content-addressed logits cache budget, bytes (0 = off)",
            )
            .opt(
                "cache-hit-cost",
                "0.1",
                "serve: fraction of a fair-admission credit a cached hit costs (rest is refunded)",
            )
            .flag(
                "fair-admission",
                "serve: per-tenant fair admission + tenant-aware batching when over budget",
            )
            .flag("no-batch", "serve: disable micro-batching (serialized tails)")
            .flag("no-adaptive-gather", "serve: always wait the full gather window")
            .flag("pin-shards", "serve: pin connection workers to their shard's core (Linux)")
    }

    /// Knobs for the client half of a hop (`infer --connect` and the
    /// upstream link `serve-edge` embeds): request pacing, breaker,
    /// integrity, registry-backed model fetch.
    pub fn with_edge_knobs(self) -> Self {
        self.opt("requests", "20", "request count for `infer`")
            .opt(
                "tenant",
                "",
                "explicit tenant id sent with every request (empty = per-connection)",
            )
            .opt(
                "request-timeout-ms",
                "30000",
                "per-request upstream transport deadline, ms (0 = none); overruns feed the breaker",
            )
            .opt(
                "breaker-failures",
                "3",
                "consecutive upstream faults that open the circuit breaker",
            )
            .opt(
                "breaker-cooldown-ms",
                "1000",
                "how long the breaker stays open before a half-open probe, ms",
            )
            .opt(
                "registry",
                "",
                "fetch the model from this registry address instead of the baked-in manifest (--sim)",
            )
            .opt(
                "pin-version",
                "",
                "pin to this registry version instead of the fleet active (--sim --registry)",
            )
            .opt(
                "artifact-cache-bytes",
                "67108864",
                "edge artifact cache budget, bytes (hash-keyed, LRU)",
            )
            .opt(
                "sign-seed",
                "42",
                "serve-registry / --registry: shared manifest-signing secret seed",
            )
            .opt(
                "device-class",
                "",
                "three-tier sim device profile (strong-phone|weak-phone|embedded; empty = calibrated edge)",
            )
            .flag(
                "checked",
                "CRC-checked data frames on the upstream hop (corruption detected and re-sent)",
            )
            .flag("connect", "infer: drive a real EdgeClient against --addr instead of the local pipeline")
    }

    /// Knobs only the middle tier reads (`serve-edge`).
    pub fn with_tier_knobs(self) -> Self {
        self.opt(
            "upstream",
            "127.0.0.1:7878",
            "serve-edge: the cloud address this tier forwards to (must be up at start)",
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(|x| x.to_string()).collect()
    }

    fn base() -> Args {
        Args::new("t", "test")
            .opt("model", "vgg16", "model name")
            .opt("bw", "1.0", "bandwidth MBps")
            .flag("verbose", "chatty")
    }

    #[test]
    fn defaults() {
        let a = base().parse(argv("")).unwrap();
        assert_eq!(a.get("model"), "vgg16");
        assert_eq!(a.get_f64("bw"), 1.0);
        assert!(!a.get_flag("verbose"));
    }

    #[test]
    fn values_and_flags() {
        let a = base().parse(argv("--model resnet50 --bw=0.3 --verbose pos1")).unwrap();
        assert_eq!(a.get("model"), "resnet50");
        assert_eq!(a.get_f64("bw"), 0.3);
        assert!(a.get_flag("verbose"));
        assert_eq!(a.positional(), &["pos1".to_string()]);
    }

    #[test]
    fn unknown_option_rejected() {
        assert!(base().parse(argv("--nope 1")).is_err());
    }

    #[test]
    fn missing_value_rejected() {
        assert!(base().parse(argv("--model")).is_err());
    }

    #[test]
    fn flag_with_value_rejected() {
        assert!(base().parse(argv("--verbose=1")).is_err());
    }

    /// Every knob the shared table accepts shows up in `--help` — the
    /// subcommands compose these groups verbatim, so this is the
    /// "no undocumented flag" guarantee for the whole CLI surface.
    #[test]
    fn help_covers_every_declared_knob() {
        let a = Args::new("jalad", "full knob table")
            .with_common_knobs()
            .with_serve_knobs()
            .with_edge_knobs()
            .with_tier_knobs();
        let usage = a.usage();
        let declared = a.declared();
        assert!(!declared.is_empty());
        for name in &declared {
            assert!(
                usage.contains(&format!("--{name}")),
                "--{name} accepted but missing from --help"
            );
        }
        // One name, one declaration: a knob reused by two subcommands
        // must come from one group, never be declared twice.
        let mut uniq = declared.clone();
        uniq.sort();
        uniq.dedup();
        assert_eq!(uniq.len(), declared.len(), "duplicate knob declaration");
    }

    /// The shared defaults parse to their typed values — a group
    /// refactor cannot silently change a default out from under a
    /// subcommand.
    #[test]
    fn shared_knob_defaults_hold() {
        let a = Args::new("jalad", "t")
            .with_common_knobs()
            .with_serve_knobs()
            .with_edge_knobs()
            .with_tier_knobs()
            .parse(argv(""))
            .unwrap();
        assert_eq!(a.get_f64("bw"), 125000.0);
        assert_eq!(a.get_f64("delta-alpha"), 0.10);
        assert_eq!(a.get_usize("shards"), 2);
        assert_eq!(a.get_usize("max-conns"), 16384);
        assert_eq!(a.get("upstream"), "127.0.0.1:7878");
        assert_eq!(a.get("device-class"), "");
        assert!(!a.get_flag("sim"));
    }
}
