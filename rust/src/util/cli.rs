//! Tiny declarative CLI argument parser (no clap offline).
//!
//! Supports `--flag`, `--key value`, `--key=value`, positional args, and
//! auto-generated `--help`. Used by `rust/src/main.rs` and every example.

use std::collections::BTreeMap;

#[derive(Debug, Clone)]
struct Spec {
    name: &'static str,
    help: &'static str,
    default: Option<String>,
    is_flag: bool,
}

/// Declarative argument set. Build with [`Args::new`], declare options,
/// then [`Args::parse`].
#[derive(Debug, Clone)]
pub struct Args {
    program: &'static str,
    about: &'static str,
    specs: Vec<Spec>,
    values: BTreeMap<&'static str, String>,
    flags: BTreeMap<&'static str, bool>,
    positional: Vec<String>,
}

impl Args {
    pub fn new(program: &'static str, about: &'static str) -> Self {
        Self {
            program,
            about,
            specs: Vec::new(),
            values: BTreeMap::new(),
            flags: BTreeMap::new(),
            positional: Vec::new(),
        }
    }

    /// `--name <value>` option with a default.
    pub fn opt(mut self, name: &'static str, default: &str, help: &'static str) -> Self {
        self.specs.push(Spec { name, help, default: Some(default.to_string()), is_flag: false });
        self.values.insert(name, default.to_string());
        self
    }

    /// Boolean `--name` flag (default false).
    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.specs.push(Spec { name, help, default: None, is_flag: true });
        self.flags.insert(name, false);
        self
    }

    /// Parse `std::env::args().skip(1)`-style input. On `--help`, prints
    /// usage and exits. Unknown options are an error.
    pub fn parse(self, argv: impl IntoIterator<Item = String>) -> Result<Args, String> {
        let mut me = self;
        let mut it = argv.into_iter().peekable();
        while let Some(arg) = it.next() {
            if arg == "--help" || arg == "-h" {
                eprintln!("{}", me.usage());
                std::process::exit(0);
            }
            if let Some(stripped) = arg.strip_prefix("--") {
                let (key, inline_val) = match stripped.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (stripped.to_string(), None),
                };
                let spec = me
                    .specs
                    .iter()
                    .find(|s| s.name == key)
                    .ok_or_else(|| format!("unknown option --{key}\n{}", me.usage()))?
                    .clone();
                if spec.is_flag {
                    if inline_val.is_some() {
                        return Err(format!("flag --{key} takes no value"));
                    }
                    *me.flags.get_mut(spec.name).unwrap() = true;
                } else {
                    let val = match inline_val {
                        Some(v) => v,
                        None => it
                            .next()
                            .ok_or_else(|| format!("option --{key} needs a value"))?,
                    };
                    *me.values.get_mut(spec.name).unwrap() = val;
                }
            } else {
                me.positional.push(arg);
            }
        }
        Ok(me)
    }

    /// Convenience: parse the real process arguments, exiting on error.
    pub fn parse_env(self) -> Args {
        match self.parse(std::env::args().skip(1)) {
            Ok(a) => a,
            Err(e) => {
                eprintln!("{e}");
                std::process::exit(2);
            }
        }
    }

    pub fn usage(&self) -> String {
        let mut s = format!("{} — {}\n\nOPTIONS:\n", self.program, self.about);
        for spec in &self.specs {
            let head = if spec.is_flag {
                format!("  --{}", spec.name)
            } else {
                format!("  --{} <v> [default: {}]", spec.name, spec.default.as_deref().unwrap())
            };
            s.push_str(&format!("{head:<44} {}\n", spec.help));
        }
        s
    }

    pub fn get(&self, name: &str) -> &str {
        self.values.get(name).map(|s| s.as_str()).unwrap_or_else(|| {
            panic!("option --{name} was never declared");
        })
    }
    pub fn get_flag(&self, name: &str) -> bool {
        *self.flags.get(name).unwrap_or(&false)
    }
    pub fn get_usize(&self, name: &str) -> usize {
        self.parse_num(name)
    }
    pub fn get_u64(&self, name: &str) -> u64 {
        self.parse_num(name)
    }
    pub fn get_f64(&self, name: &str) -> f64 {
        self.parse_num(name)
    }
    pub fn positional(&self) -> &[String] {
        &self.positional
    }

    fn parse_num<T: std::str::FromStr>(&self, name: &str) -> T {
        let raw = self.get(name);
        raw.parse().unwrap_or_else(|_| {
            eprintln!("--{name}: cannot parse {raw:?}");
            std::process::exit(2);
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(|x| x.to_string()).collect()
    }

    fn base() -> Args {
        Args::new("t", "test")
            .opt("model", "vgg16", "model name")
            .opt("bw", "1.0", "bandwidth MBps")
            .flag("verbose", "chatty")
    }

    #[test]
    fn defaults() {
        let a = base().parse(argv("")).unwrap();
        assert_eq!(a.get("model"), "vgg16");
        assert_eq!(a.get_f64("bw"), 1.0);
        assert!(!a.get_flag("verbose"));
    }

    #[test]
    fn values_and_flags() {
        let a = base().parse(argv("--model resnet50 --bw=0.3 --verbose pos1")).unwrap();
        assert_eq!(a.get("model"), "resnet50");
        assert_eq!(a.get_f64("bw"), 0.3);
        assert!(a.get_flag("verbose"));
        assert_eq!(a.positional(), &["pos1".to_string()]);
    }

    #[test]
    fn unknown_option_rejected() {
        assert!(base().parse(argv("--nope 1")).is_err());
    }

    #[test]
    fn missing_value_rejected() {
        assert!(base().parse(argv("--model")).is_err());
    }

    #[test]
    fn flag_with_value_rejected() {
        assert!(base().parse(argv("--verbose=1")).is_err());
    }
}
