//! Deterministic fault injection for chaos testing.
//!
//! A [`FaultPlan`] is parsed from a compact `key=value,...` spec
//! (`--fault-plan`), seeds an xorshift64* stream, and hands out
//! [`FaultInjector`]s that wrap byte streams ([`FaultyStream`]) or hook
//! executor shards. Every fault decision is drawn from the seeded RNG
//! or from wall-clock offsets fixed in the spec, so a chaos run with
//! the same seed and schedule reproduces the same fault sequence.
//!
//! Zero-cost when off: every call site holds an `Option<Arc<FaultPlan>>`
//! and the `None` path is a branch on a niche-optimized pointer.
//!
//! Supported spec keys (all optional; unknown keys are an error):
//!
//! | key              | meaning                                             |
//! |------------------|-----------------------------------------------------|
//! | `seed=N`         | RNG seed (default 1)                                |
//! | `corrupt=P`      | flip one byte per write with probability P          |
//! | `truncate=P`     | short-write (half the buffer) with probability P    |
//! | `reset=P`        | fail a write with `ConnectionReset` with prob. P    |
//! | `stall-p=P`      | sleep before a write with probability P             |
//! | `stall-ms=N`     | stall duration (default 200)                        |
//! | `dl-corrupt=P`   | flip one byte per *read* with probability P         |
//! | `dl-stall-p=P`   | sleep before a read with probability P              |
//! | `blackout-at-ms=N` | blackout window start, relative to plan creation  |
//! | `blackout-ms=N`  | blackout duration — writes are silently swallowed   |
//! | `slow-shard=I`   | executor hook: shard I sleeps `slow-ms` per run      |
//! | `slow-ms=N`      | slow-shard delay (default 100)                      |
//! | `panic-shard=I`  | executor hook: shard I panics `panic-count` times   |
//! | `panic-count=N`  | number of scripted panics (default 1)               |

use std::io::{self, Read, Write};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::util::rng::XorShift64Star;

/// Parsed, immutable fault schedule. Shared via `Arc`; the mutable RNG
/// state lives behind a mutex so one plan can serve several streams
/// while staying reproducible (decision order is then the arrival
/// order, which deterministic tests keep single-threaded).
#[derive(Debug)]
pub struct FaultPlan {
    pub seed: u64,
    pub corrupt_p: f64,
    pub truncate_p: f64,
    pub reset_p: f64,
    pub stall_p: f64,
    pub stall: Duration,
    /// Downlink faults, applied by the *reading* half of a wrapped
    /// stream (the edge's reply path): the uplink keys above only ever
    /// touch writes, so a downlink scenario needs its own knobs.
    pub dl_corrupt_p: f64,
    pub dl_stall_p: f64,
    pub blackout_at: Option<Duration>,
    pub blackout: Duration,
    pub slow_shard: Option<usize>,
    pub slow: Duration,
    pub panic_shard: Option<usize>,
    pub panic_count: u64,
    rng: Mutex<XorShift64Star>,
    born: Instant,
    panics_left: AtomicU64,
}

impl FaultPlan {
    /// Parse a `key=value,...` spec. Empty string → all-off plan.
    pub fn parse(spec: &str) -> Result<Self, String> {
        let mut seed = 1u64;
        let mut corrupt_p = 0.0;
        let mut truncate_p = 0.0;
        let mut reset_p = 0.0;
        let mut stall_p = 0.0;
        let mut stall_ms = 200u64;
        let mut dl_corrupt_p = 0.0;
        let mut dl_stall_p = 0.0;
        let mut blackout_at_ms: Option<u64> = None;
        let mut blackout_ms = 0u64;
        let mut slow_shard: Option<usize> = None;
        let mut slow_ms = 100u64;
        let mut panic_shard: Option<usize> = None;
        let mut panic_count = 1u64;
        for part in spec.split(',').map(str::trim).filter(|p| !p.is_empty()) {
            let (k, v) = part
                .split_once('=')
                .ok_or_else(|| format!("fault-plan entry `{part}` is not key=value"))?;
            let int = || v.parse::<u64>().map_err(|_| format!("bad integer in `{part}`"));
            let prob = || {
                v.parse::<f64>()
                    .ok()
                    .filter(|p| (0.0..=1.0).contains(p))
                    .ok_or_else(|| format!("bad probability in `{part}`"))
            };
            match k {
                "seed" => seed = int()?,
                "corrupt" => corrupt_p = prob()?,
                "truncate" => truncate_p = prob()?,
                "reset" => reset_p = prob()?,
                "stall-p" => stall_p = prob()?,
                "stall-ms" => stall_ms = int()?,
                "dl-corrupt" => dl_corrupt_p = prob()?,
                "dl-stall-p" => dl_stall_p = prob()?,
                "blackout-at-ms" => blackout_at_ms = Some(int()?),
                "blackout-ms" => blackout_ms = int()?,
                "slow-shard" => slow_shard = Some(int()? as usize),
                "slow-ms" => slow_ms = int()?,
                "panic-shard" => panic_shard = Some(int()? as usize),
                "panic-count" => panic_count = int()?,
                _ => return Err(format!("unknown fault-plan key `{k}`")),
            }
        }
        Ok(Self {
            seed,
            corrupt_p,
            truncate_p,
            reset_p,
            stall_p,
            stall: Duration::from_millis(stall_ms),
            dl_corrupt_p,
            dl_stall_p,
            blackout_at: blackout_at_ms.map(Duration::from_millis),
            blackout: Duration::from_millis(blackout_ms),
            slow_shard,
            slow: Duration::from_millis(slow_ms),
            panic_shard,
            panic_count,
            rng: Mutex::new(XorShift64Star::new(seed)),
            born: Instant::now(),
            panics_left: AtomicU64::new(panic_count),
        })
    }

    pub fn parse_arc(spec: &str) -> Result<Arc<Self>, String> {
        Self::parse(spec).map(Arc::new)
    }

    /// True iff any stream-level fault can ever fire.
    pub fn touches_stream(&self) -> bool {
        self.corrupt_p > 0.0
            || self.truncate_p > 0.0
            || self.reset_p > 0.0
            || self.stall_p > 0.0
            || self.dl_corrupt_p > 0.0
            || self.dl_stall_p > 0.0
            || self.blackout_at.is_some()
    }

    fn roll(&self, p: f64) -> bool {
        if p <= 0.0 {
            return false;
        }
        let mut rng = self.rng.lock().unwrap_or_else(|e| e.into_inner());
        rng.next_f64() <= p
    }

    fn pick(&self, n: u64) -> u64 {
        let mut rng = self.rng.lock().unwrap_or_else(|e| e.into_inner());
        rng.below(n.max(1))
    }

    /// Is the wall clock currently inside the scripted blackout window?
    pub fn in_blackout(&self) -> bool {
        match self.blackout_at {
            None => false,
            Some(at) => {
                let t = self.born.elapsed();
                t >= at && t < at + self.blackout
            }
        }
    }

    /// Executor hook, called with the shard index before a run. Sleeps
    /// for a scripted slow shard; panics for a scripted poisoned shard
    /// until its panic budget is spent (so readmission probes can
    /// eventually succeed).
    pub fn before_shard_run(&self, shard: usize) {
        if self.slow_shard == Some(shard) {
            std::thread::sleep(self.slow);
        }
        if self.panic_shard == Some(shard) {
            let left = self.panics_left.load(Ordering::Relaxed);
            if left > 0
                && self
                    .panics_left
                    .compare_exchange(left, left - 1, Ordering::Relaxed, Ordering::Relaxed)
                    .is_ok()
            {
                panic!("fault-plan: scripted panic on shard {shard}");
            }
        }
    }

    /// Scripted panics not yet fired (0 = shard behaves again).
    pub fn panics_remaining(&self) -> u64 {
        self.panics_left.load(Ordering::Relaxed)
    }
}

/// Wraps any `Read + Write` stream and applies the plan's stream
/// faults. Uplink keys (`corrupt`, `truncate`, `reset`, `stall-p`,
/// blackouts) hit *writes*; the `dl-*` keys hit *reads* — wrapping the
/// edge's reading half models a downlink that mangles the cloud's
/// replies in flight, without also perturbing the uplink under test.
pub struct FaultyStream<S> {
    inner: S,
    plan: Option<Arc<FaultPlan>>,
}

impl<S> FaultyStream<S> {
    pub fn new(inner: S, plan: Option<Arc<FaultPlan>>) -> Self {
        // An all-off plan is dropped up front so the hot path is a
        // single `None` check.
        let plan = plan.filter(|p| p.touches_stream());
        Self { inner, plan }
    }

    pub fn get_ref(&self) -> &S {
        &self.inner
    }

    pub fn get_mut(&mut self) -> &mut S {
        &mut self.inner
    }

    pub fn into_inner(self) -> S {
        self.inner
    }
}

impl<S: Read> Read for FaultyStream<S> {
    #[inline]
    fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
        let plan = match &self.plan {
            None => return self.inner.read(buf),
            Some(p) => p,
        };
        if plan.roll(plan.dl_stall_p) {
            std::thread::sleep(plan.stall);
        }
        let n = self.inner.read(buf)?;
        // Corrupt *after* the read: one byte of what actually arrived
        // flips, exactly mirroring the uplink `corrupt` fault.
        if n > 0 && plan.roll(plan.dl_corrupt_p) {
            let at = plan.pick(n as u64) as usize;
            buf[at] ^= 0xA5;
        }
        Ok(n)
    }
}

impl<S: Write> Write for FaultyStream<S> {
    fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
        let plan = match &self.plan {
            None => return self.inner.write(buf),
            Some(p) => p,
        };
        if plan.in_blackout() {
            // Swallow silently: bytes vanish on the wire, so the peer
            // sees a stall and the caller's read timeout has to fire —
            // the failure mode a breaker must detect, not an error the
            // caller could handle locally.
            return Ok(buf.len());
        }
        if plan.roll(plan.stall_p) {
            std::thread::sleep(plan.stall);
        }
        if plan.roll(plan.reset_p) {
            return Err(io::Error::new(io::ErrorKind::ConnectionReset, "fault-plan: scripted reset"));
        }
        if plan.roll(plan.truncate_p) && buf.len() > 1 {
            let half = buf.len() / 2;
            return self.inner.write(&buf[..half]);
        }
        if plan.roll(plan.corrupt_p) && !buf.is_empty() {
            let mut copy = buf.to_vec();
            let at = plan.pick(copy.len() as u64) as usize;
            copy[at] ^= 0xA5;
            return self.inner.write(&copy).map(|n| n.min(buf.len()));
        }
        self.inner.write(buf)
    }

    fn flush(&mut self) -> io::Result<()> {
        if let Some(plan) = &self.plan {
            if plan.in_blackout() {
                return Ok(());
            }
        }
        self.inner.flush()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_full_spec() {
        let p = FaultPlan::parse(
            "seed=42,corrupt=0.05,stall-p=0.02,stall-ms=200,reset=0.01,\
             blackout-at-ms=1000,blackout-ms=2000,slow-shard=1,slow-ms=100,\
             panic-shard=2,panic-count=3",
        )
        .unwrap();
        assert_eq!(p.seed, 42);
        assert!((p.corrupt_p - 0.05).abs() < 1e-12);
        assert_eq!(p.stall, Duration::from_millis(200));
        assert_eq!(p.blackout_at, Some(Duration::from_millis(1000)));
        assert_eq!(p.blackout, Duration::from_millis(2000));
        assert_eq!(p.slow_shard, Some(1));
        assert_eq!(p.panic_shard, Some(2));
        assert_eq!(p.panic_count, 3);
        assert!(p.touches_stream());
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(FaultPlan::parse("corrupt=1.5").is_err());
        assert!(FaultPlan::parse("frobnicate=1").is_err());
        assert!(FaultPlan::parse("corrupt").is_err());
        assert!(FaultPlan::parse("stall-ms=abc").is_err());
    }

    #[test]
    fn empty_spec_is_all_off() {
        let p = FaultPlan::parse("").unwrap();
        assert!(!p.touches_stream());
        assert!(!p.in_blackout());
    }

    #[test]
    fn corruption_is_deterministic() {
        let run = |seed: u64| -> Vec<u8> {
            let plan = FaultPlan::parse_arc(&format!("seed={seed},corrupt=0.5")).unwrap();
            let mut s = FaultyStream::new(Vec::<u8>::new(), Some(plan));
            for i in 0..32u8 {
                s.write_all(&[i; 8]).unwrap();
            }
            s.into_inner()
        };
        assert_eq!(run(7), run(7));
        assert_ne!(run(7), run(8));
        // With corrupt=0.5 over 32 writes, some byte must differ from
        // the clean stream.
        let clean: Vec<u8> = (0..32u8).flat_map(|i| [i; 8]).collect();
        assert_ne!(run(7), clean);
        assert_eq!(run(7).len(), clean.len());
    }

    #[test]
    fn downlink_corruption_hits_reads_not_writes() {
        let plan = FaultPlan::parse_arc("seed=5,dl-corrupt=1.0").unwrap();
        assert!(plan.touches_stream(), "dl faults must keep the wrapper installed");
        let clean: Vec<u8> = (0..64u8).collect();
        let mut s = FaultyStream::new(std::io::Cursor::new(clean.clone()), Some(plan));
        let mut got = vec![0u8; 64];
        let mut off = 0;
        while off < 64 {
            let n = s.read(&mut got[off..]).unwrap();
            assert!(n > 0);
            off += n;
        }
        assert_ne!(got, clean, "dl-corrupt=1.0 must flip a byte per read");
        // XOR 0xA5 twice restores: exactly one byte differs per read.
        let diffs = got.iter().zip(&clean).filter(|(a, b)| a != b).count();
        assert!(diffs >= 1);
        for (a, b) in got.iter().zip(&clean) {
            if a != b {
                assert_eq!(*a ^ 0xA5, *b, "corruption must be the scripted XOR");
            }
        }
    }

    #[test]
    fn downlink_corruption_is_deterministic() {
        let run = |seed: u64| -> Vec<u8> {
            let plan = FaultPlan::parse_arc(&format!("seed={seed},dl-corrupt=0.5")).unwrap();
            let data: Vec<u8> = (0..128u8).collect();
            let mut s = FaultyStream::new(std::io::Cursor::new(data), Some(plan));
            let mut out = vec![0u8; 128];
            let mut off = 0;
            while off < 128 {
                let n = s.read(&mut out[off..]).unwrap();
                if n == 0 {
                    break;
                }
                off += n;
            }
            out
        };
        assert_eq!(run(9), run(9));
        assert_ne!(run(9), run(10));
    }

    #[test]
    fn uplink_only_plan_leaves_reads_alone() {
        let plan = FaultPlan::parse_arc("seed=3,corrupt=1.0").unwrap();
        let clean: Vec<u8> = (0..32u8).collect();
        let mut s = FaultyStream::new(std::io::Cursor::new(clean.clone()), Some(plan));
        let mut got = vec![0u8; 32];
        let mut off = 0;
        while off < 32 {
            let n = s.read(&mut got[off..]).unwrap();
            assert!(n > 0);
            off += n;
        }
        assert_eq!(got, clean, "uplink corrupt must never touch the read path");
    }

    #[test]
    fn blackout_swallows_writes() {
        let plan = FaultPlan::parse_arc("blackout-at-ms=0,blackout-ms=60000").unwrap();
        assert!(plan.in_blackout());
        let mut s = FaultyStream::new(Vec::<u8>::new(), Some(plan));
        s.write_all(b"hello").unwrap();
        assert!(s.get_ref().is_empty(), "blackout must swallow bytes");
    }

    #[test]
    fn scripted_panic_budget_is_finite() {
        let plan = FaultPlan::parse("panic-shard=0,panic-count=2").unwrap();
        for _ in 0..2 {
            let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                plan.before_shard_run(0)
            }));
            assert!(r.is_err());
        }
        assert_eq!(plan.panics_remaining(), 0);
        plan.before_shard_run(0); // budget spent → no panic
        plan.before_shard_run(1); // other shards never panic
    }

    #[test]
    fn off_plan_is_dropped_by_stream() {
        let plan = FaultPlan::parse_arc("panic-shard=3").unwrap();
        let s = FaultyStream::new(Vec::<u8>::new(), Some(plan));
        assert!(s.plan.is_none(), "executor-only plan must not tax the stream");
    }
}
