//! Best-effort thread→core pinning (the `--pin-shards` satellite of
//! the NUMA roadmap item).
//!
//! Shard affinity is already connection-stable — connection `k` always
//! computes on shard `k % shards` — so pinning each shard's connection
//! workers to a stable core keeps that shard's compile cache, scratch
//! buffers and executor state warm in one core's (and one NUMA node's)
//! cache hierarchy instead of migrating under the scheduler.
//!
//! Callers address cores by **logical index into the process's allowed
//! CPU set** (read via `sched_getaffinity(2)`), not by raw CPU id — in
//! a cpuset-restricted container (CPUs 4–7, or a non-contiguous mask)
//! index 0 is the first CPU the process may actually run on, so
//! pinning keeps working exactly where it was previously a silent
//! no-op. On Linux this is raw `sched_setaffinity(2)` on the calling
//! thread (declared directly — the vendored dependency set has no
//! `libc` crate); everywhere else it is a no-op that reports `false`.
//! Failures are deliberately silent beyond the return value: pinning
//! is an optimization, never a correctness requirement.

/// Mirrors glibc's `cpu_set_t`: 1024 bits.
#[cfg(target_os = "linux")]
const MASK_WORDS: usize = 1024 / 64;

#[cfg(target_os = "linux")]
extern "C" {
    fn sched_setaffinity(pid: i32, cpusetsize: usize, mask: *const u64) -> i32;
    fn sched_getaffinity(pid: i32, cpusetsize: usize, mask: *mut u64) -> i32;
}

/// The CPU ids this process is allowed to run on, ascending. Empty
/// when the mask cannot be read (treat as "pinning unavailable").
#[cfg(target_os = "linux")]
pub fn allowed_cpus() -> Vec<usize> {
    let mut mask = [0u64; MASK_WORDS];
    // pid 0 = the calling thread.
    if unsafe { sched_getaffinity(0, MASK_WORDS * 8, mask.as_mut_ptr()) } != 0 {
        return Vec::new();
    }
    (0..MASK_WORDS * 64).filter(|&c| (mask[c / 64] >> (c % 64)) & 1 == 1).collect()
}

#[cfg(not(target_os = "linux"))]
pub fn allowed_cpus() -> Vec<usize> {
    Vec::new()
}

/// Pin the calling thread to the `index`-th allowed CPU (modulo the
/// allowed count). Returns whether the kernel accepted the mask;
/// always `false` on non-Linux targets or when the allowed set cannot
/// be read.
#[cfg(target_os = "linux")]
pub fn pin_to_core(index: usize) -> bool {
    let allowed = allowed_cpus();
    if allowed.is_empty() {
        return false;
    }
    let cpu = allowed[index % allowed.len()];
    let mut mask = [0u64; MASK_WORDS];
    mask[cpu / 64] |= 1u64 << (cpu % 64);
    unsafe { sched_setaffinity(0, MASK_WORDS * 8, mask.as_ptr()) == 0 }
}

#[cfg(not(target_os = "linux"))]
pub fn pin_to_core(_index: usize) -> bool {
    false
}

/// Cores available to this process (≥ 1).
pub fn available_cores() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn available_cores_is_positive() {
        assert!(available_cores() >= 1);
    }

    #[test]
    fn pin_is_best_effort_and_survivable() {
        // Index 0 maps to the first CPU this process may run on, so on
        // Linux the pin must succeed even inside a cpuset-restricted
        // container; elsewhere it reports false. Either way the thread
        // keeps running.
        let ok = pin_to_core(0);
        if cfg!(target_os = "linux") {
            assert_eq!(allowed_cpus().is_empty(), !ok, "pin must track the allowed set");
        } else {
            assert!(!ok);
        }
        // Indices wrap into the allowed set instead of corrupting
        // memory or targeting a forbidden CPU.
        let _ = pin_to_core(usize::MAX - 3);
    }

    #[cfg(target_os = "linux")]
    #[test]
    fn allowed_cpus_is_sane() {
        let cpus = allowed_cpus();
        assert!(!cpus.is_empty(), "a running test always has ≥1 allowed CPU");
        assert!(cpus.windows(2).all(|w| w[0] < w[1]), "ascending, unique");
        assert!(cpus.len() <= 1024);
    }
}
