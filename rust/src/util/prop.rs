//! Mini property-testing harness (no proptest offline).
//!
//! Deterministic: every property runs `CASES` cases from a fixed seed;
//! on failure the failing case index and a debug rendering of the input
//! are reported, and a bounded shrink loop tries to find a smaller
//! counterexample for `Vec` inputs.
//!
//! ```ignore
//! prop::check("huffman roundtrip", prop::vec_u8(0..=255, 0..4096), |bytes| {
//!     let enc = encode(&bytes);
//!     decode(&enc) == bytes
//! });
//! ```

use super::rng::XorShift64Star;

pub const CASES: usize = 128;
const SEED: u64 = 0x7A1AD; // "JALAD"

/// A generator of random values of type `T`.
pub struct Gen<T> {
    f: Box<dyn Fn(&mut XorShift64Star) -> T>,
}

impl<T: 'static> Gen<T> {
    pub fn new(f: impl Fn(&mut XorShift64Star) -> T + 'static) -> Self {
        Self { f: Box::new(f) }
    }
    pub fn sample(&self, rng: &mut XorShift64Star) -> T {
        (self.f)(rng)
    }
    pub fn map<U: 'static>(self, g: impl Fn(T) -> U + 'static) -> Gen<U> {
        Gen::new(move |rng| g(self.sample(rng)))
    }
}

/// u64 uniform in [lo, hi].
pub fn u64_in(lo: u64, hi: u64) -> Gen<u64> {
    assert!(lo <= hi);
    Gen::new(move |r| lo + r.below(hi - lo + 1))
}

/// usize uniform in [lo, hi].
pub fn usize_in(lo: usize, hi: usize) -> Gen<usize> {
    u64_in(lo as u64, hi as u64).map(|x| x as usize)
}

/// f32 uniform in [lo, hi).
pub fn f32_in(lo: f32, hi: f32) -> Gen<f32> {
    Gen::new(move |r| lo + (hi - lo) * (r.next_f64() as f32))
}

/// Standard normal f32 scaled by `scale`.
pub fn f32_gauss(scale: f32) -> Gen<f32> {
    Gen::new(move |r| (r.next_gaussian_pair().0 as f32) * scale)
}

/// Vec with length in `len` and elements from `elem`.
pub fn vec_of<T: 'static>(elem: Gen<T>, min_len: usize, max_len: usize) -> Gen<Vec<T>> {
    Gen::new(move |r| {
        let n = min_len + r.below((max_len - min_len + 1) as u64) as usize;
        (0..n).map(|_| elem.sample(r)).collect()
    })
}

/// Vec<u8> with arbitrary bytes.
pub fn bytes(min_len: usize, max_len: usize) -> Gen<Vec<u8>> {
    vec_of(u64_in(0, 255).map(|x| x as u8), min_len, max_len)
}

/// Sparse f32 feature-map-like vectors: mostly zeros (post-ReLU
/// statistics), occasional positive spikes — the distribution JALAD's
/// codec actually sees.
pub fn sparse_features(min_len: usize, max_len: usize) -> Gen<Vec<f32>> {
    Gen::new(move |r| {
        let n = min_len + r.below((max_len - min_len + 1) as u64) as usize;
        (0..n)
            .map(|_| {
                if r.next_f64() < 0.6 {
                    0.0
                } else {
                    (r.next_gaussian_pair().0.abs() * 3.0) as f32
                }
            })
            .collect()
    })
}

/// Pair generator.
pub fn pair<A: 'static, B: 'static>(a: Gen<A>, b: Gen<B>) -> Gen<(A, B)> {
    Gen::new(move |r| (a.sample(r), b.sample(r)))
}

/// Run `prop` on `CASES` random cases; panic with diagnostics on failure.
pub fn check<T: std::fmt::Debug + Clone + 'static>(
    name: &str,
    gen: Gen<T>,
    prop: impl Fn(&T) -> bool,
) {
    check_n(name, gen, prop, CASES)
}

pub fn check_n<T: std::fmt::Debug + Clone + 'static>(
    name: &str,
    gen: Gen<T>,
    prop: impl Fn(&T) -> bool,
    cases: usize,
) {
    let mut rng = XorShift64Star::new(SEED ^ fxhash(name));
    for case in 0..cases {
        let input = gen.sample(&mut rng);
        if !prop(&input) {
            let rendered = format!("{:?}", input);
            let shown: String = rendered.chars().take(400).collect();
            panic!(
                "property {name:?} failed at case {case}/{cases}\ninput (truncated): {shown}"
            );
        }
    }
}

/// Shrinking variant for Vec inputs: halves the failing vector while the
/// property keeps failing, then reports the minimal found slice.
pub fn check_vec<T: std::fmt::Debug + Clone + 'static>(
    name: &str,
    gen: Gen<Vec<T>>,
    prop: impl Fn(&Vec<T>) -> bool,
) {
    let mut rng = XorShift64Star::new(SEED ^ fxhash(name));
    for case in 0..CASES {
        let input = gen.sample(&mut rng);
        if !prop(&input) {
            let mut minimal = input.clone();
            loop {
                let mut shrunk = false;
                for keep in [minimal.len() / 2, minimal.len().saturating_sub(1)] {
                    if keep == 0 || keep >= minimal.len() {
                        continue;
                    }
                    let head: Vec<T> = minimal[..keep].to_vec();
                    if !prop(&head) {
                        minimal = head;
                        shrunk = true;
                        break;
                    }
                    let tail: Vec<T> = minimal[minimal.len() - keep..].to_vec();
                    if !prop(&tail) {
                        minimal = tail;
                        shrunk = true;
                        break;
                    }
                }
                if !shrunk {
                    break;
                }
            }
            let rendered = format!("{:?}", minimal);
            let shown: String = rendered.chars().take(400).collect();
            panic!(
                "property {name:?} failed at case {case}; shrunk to len {}: {shown}",
                minimal.len()
            );
        }
    }
}

fn fxhash(s: &str) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property() {
        check("add commutes", pair(u64_in(0, 1000), u64_in(0, 1000)), |(a, b)| a + b == b + a);
    }

    #[test]
    #[should_panic(expected = "property")]
    fn failing_property_panics() {
        check("always false eventually", u64_in(0, 100), |x| *x < 95);
    }

    #[test]
    fn shrinker_reduces() {
        let r = std::panic::catch_unwind(|| {
            check_vec("has no 7", vec_of(u64_in(0, 10), 0, 64), |v| !v.contains(&7));
        });
        let msg = *r.unwrap_err().downcast::<String>().unwrap();
        // The minimal counterexample is a single [7].
        assert!(msg.contains("len 1"), "msg: {msg}");
    }

    #[test]
    fn generators_respect_bounds() {
        let mut rng = XorShift64Star::new(5);
        let g = usize_in(3, 9);
        for _ in 0..1000 {
            let v = g.sample(&mut rng);
            assert!((3..=9).contains(&v));
        }
        let vg = bytes(2, 5);
        for _ in 0..200 {
            let v = vg.sample(&mut rng);
            assert!((2..=5).contains(&v.len()));
        }
    }
}
