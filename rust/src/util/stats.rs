//! Small numeric statistics helpers shared by the bench harness, the
//! profiler and the predictor (mean/std/percentiles/linear regression).

/// Mean of a slice (0.0 for empty input).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation.
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// p-th percentile (0..=100) by linear interpolation on a sorted copy.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (v[hi] - v[lo]) * (rank - lo as f64)
    }
}

/// Least-squares fit `y ≈ a·x + b`; returns (a, b).
///
/// Used by the profiler to regress measured stage latency against FMACs,
/// mirroring the paper's `w_e`/`w_c` fitting (§IV-A).
pub fn linear_fit(xs: &[f64], ys: &[f64]) -> (f64, f64) {
    assert_eq!(xs.len(), ys.len());
    if xs.is_empty() {
        return (0.0, 0.0);
    }
    let mx = mean(xs);
    let my = mean(ys);
    let mut num = 0.0;
    let mut den = 0.0;
    for (x, y) in xs.iter().zip(ys) {
        num += (x - mx) * (y - my);
        den += (x - mx) * (x - mx);
    }
    if den == 0.0 {
        return (0.0, my);
    }
    let a = num / den;
    (a, my - a * mx)
}

/// Pearson correlation coefficient.
pub fn pearson(xs: &[f64], ys: &[f64]) -> f64 {
    let mx = mean(xs);
    let my = mean(ys);
    let mut num = 0.0;
    let mut dx = 0.0;
    let mut dy = 0.0;
    for (x, y) in xs.iter().zip(ys) {
        num += (x - mx) * (y - my);
        dx += (x - mx) * (x - mx);
        dy += (y - my) * (y - my);
    }
    if dx == 0.0 || dy == 0.0 {
        return 0.0;
    }
    num / (dx.sqrt() * dy.sqrt())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_std() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((std_dev(&xs) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn percentiles() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn fit_exact_line() {
        let xs = [0.0, 1.0, 2.0, 3.0];
        let ys = [1.0, 3.0, 5.0, 7.0];
        let (a, b) = linear_fit(&xs, &ys);
        assert!((a - 2.0).abs() < 1e-12);
        assert!((b - 1.0).abs() < 1e-12);
        assert!((pearson(&xs, &ys) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn degenerate_inputs() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
        let (a, b) = linear_fit(&[1.0, 1.0], &[2.0, 4.0]);
        assert_eq!(a, 0.0);
        assert_eq!(b, 3.0);
    }
}
