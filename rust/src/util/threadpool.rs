//! Fixed-size thread pool with a simple MPMC job queue (no tokio offline).
//!
//! Serves the cloud server's request concurrency and the calibration
//! sweeps. Jobs are `FnOnce() + Send`; `scope`-style joining is provided
//! by [`ThreadPool::run_all`] which blocks until every submitted closure
//! in the batch finished.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

struct Queue {
    jobs: Mutex<(std::collections::VecDeque<Job>, bool)>,
    cv: Condvar,
}

pub struct ThreadPool {
    queue: Arc<Queue>,
    workers: Vec<JoinHandle<()>>,
    in_flight: Arc<(Mutex<usize>, Condvar)>,
}

impl ThreadPool {
    pub fn new(threads: usize) -> Self {
        let queue = Arc::new(Queue {
            jobs: Mutex::new((std::collections::VecDeque::new(), false)),
            cv: Condvar::new(),
        });
        let in_flight = Arc::new((Mutex::new(0usize), Condvar::new()));
        let workers = (0..threads.max(1))
            .map(|_| {
                let q = Arc::clone(&queue);
                let fl = Arc::clone(&in_flight);
                std::thread::spawn(move || loop {
                    let job = {
                        let mut g = q.jobs.lock().unwrap();
                        loop {
                            if let Some(j) = g.0.pop_front() {
                                break j;
                            }
                            if g.1 {
                                return; // shut down
                            }
                            g = q.cv.wait(g).unwrap();
                        }
                    };
                    // A panicking job must neither kill the worker nor
                    // leave `wait_idle` hanging on its in-flight count —
                    // the cloud server runs whole connections as jobs.
                    if std::panic::catch_unwind(std::panic::AssertUnwindSafe(job)).is_err() {
                        crate::log_warn!("threadpool", "job panicked; worker continues");
                    }
                    let (lock, cv) = &*fl;
                    let mut n = lock.lock().unwrap();
                    *n -= 1;
                    if *n == 0 {
                        cv.notify_all();
                    }
                })
            })
            .collect();
        Self { queue, workers, in_flight }
    }

    /// Pool sized to the machine (cores, capped to 16).
    pub fn default_size() -> Self {
        let n = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
        Self::new(n.min(16))
    }

    pub fn submit<F: FnOnce() + Send + 'static>(&self, f: F) {
        let (lock, _) = &*self.in_flight;
        *lock.lock().unwrap() += 1;
        let mut g = self.queue.jobs.lock().unwrap();
        g.0.push_back(Box::new(f));
        self.queue.cv.notify_one();
    }

    /// Block until every previously submitted job completed.
    pub fn wait_idle(&self) {
        let (lock, cv) = &*self.in_flight;
        let mut n = lock.lock().unwrap();
        while *n > 0 {
            n = cv.wait(n).unwrap();
        }
    }

    /// Run a batch of closures to completion (convenience wrapper).
    pub fn run_all<F: FnOnce() + Send + 'static>(&self, jobs: Vec<F>) {
        for j in jobs {
            self.submit(j);
        }
        self.wait_idle();
    }

    /// Map `f` over `items` in parallel, preserving order.
    pub fn par_map<T, R, F>(&self, items: Vec<T>, f: F) -> Vec<R>
    where
        T: Send + 'static,
        R: Send + Default + Clone + 'static,
        F: Fn(T) -> R + Send + Sync + 'static,
    {
        let n = items.len();
        let results = Arc::new(Mutex::new(vec![R::default(); n]));
        let f = Arc::new(f);
        let done = Arc::new(AtomicUsize::new(0));
        for (i, item) in items.into_iter().enumerate() {
            let results = Arc::clone(&results);
            let f = Arc::clone(&f);
            let done = Arc::clone(&done);
            self.submit(move || {
                let r = f(item);
                results.lock().unwrap()[i] = r;
                done.fetch_add(1, Ordering::SeqCst);
            });
        }
        self.wait_idle();
        assert_eq!(done.load(Ordering::SeqCst), n);
        Arc::try_unwrap(results).ok().unwrap().into_inner().unwrap()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        {
            let mut g = self.queue.jobs.lock().unwrap();
            g.1 = true;
        }
        self.queue.cv.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.submit(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn par_map_preserves_order() {
        let pool = ThreadPool::new(8);
        let out = pool.par_map((0..50).collect::<Vec<_>>(), |x| x * x);
        assert_eq!(out, (0..50).map(|x| x * x).collect::<Vec<_>>());
    }

    #[test]
    fn panicking_job_does_not_poison_pool() {
        let pool = ThreadPool::new(2);
        let done = Arc::new(AtomicUsize::new(0));
        pool.submit(|| panic!("boom"));
        for _ in 0..10 {
            let d = Arc::clone(&done);
            pool.submit(move || {
                d.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle(); // must not hang
        assert_eq!(done.load(Ordering::SeqCst), 10);
    }

    #[test]
    fn drop_joins_cleanly() {
        let pool = ThreadPool::new(2);
        pool.submit(|| std::thread::sleep(std::time::Duration::from_millis(10)));
        drop(pool); // must not hang or panic
    }
}
