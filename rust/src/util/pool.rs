//! Pooled per-session scratch buffers for the request hot path.
//!
//! Every hop of the decoupled request path (quantize → entropy-code →
//! proto frame → decode) used to allocate fresh `Vec`s per request. A
//! [`Scratch`] bundles the reusable buffers one session or connection
//! needs; a [`BufPool`] hands them out RAII-style ([`PooledScratch`]
//! returns its scratch on drop) so short-lived connections amortize
//! buffer growth across each other. Hit/miss counters feed the serving
//! metrics and the zero-allocation assertion in
//! `benches/pipeline_hotpath.rs`.
//!
//! Locking is one uncontended mutex around the free list — check-out /
//! check-in happen once per *connection*, not per request, so this is
//! nowhere near the hot path.

use std::ops::{Deref, DerefMut};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::compression::feature::CodecScratch;

/// The reusable buffers one session/connection owns. Field roles follow
/// the request path: `wire` holds the outgoing encoded frame, `frame`
/// the incoming proto payload, `values` the (de)quantized integers,
/// `floats` dequantized activations or logits, and `codec` the entropy
/// coder's rebuildable tables.
#[derive(Debug, Default)]
pub struct Scratch {
    pub wire: Vec<u8>,
    pub frame: Vec<u8>,
    pub values: Vec<u16>,
    pub floats: Vec<f32>,
    pub codec: CodecScratch,
}

impl Scratch {
    pub fn new() -> Self {
        Self::default()
    }

    /// Reset contents, keep capacity (what makes reuse worthwhile).
    pub fn clear(&mut self) {
        self.wire.clear();
        self.frame.clear();
        self.values.clear();
        self.floats.clear();
    }

    /// Lend the float buffer across an ownership boundary (the
    /// micro-batch engine takes activations by move and returns the
    /// logits in the same allocation). Pair with
    /// [`Scratch::restore_floats`]; while lent, `floats` is an empty
    /// stand-in Vec, so a failed handoff costs at most one fresh
    /// allocation on the next request.
    pub fn lend_floats(&mut self) -> Vec<f32> {
        std::mem::take(&mut self.floats)
    }

    /// Take a buffer back after a [`Scratch::lend_floats`] round trip
    /// (contents are the callee's output — typically logits).
    pub fn restore_floats(&mut self, floats: Vec<f32>) {
        self.floats = floats;
    }

    /// Bytes currently reserved across the plain buffers (capacity
    /// telemetry for the stats endpoint).
    pub fn reserved_bytes(&self) -> usize {
        self.wire.capacity()
            + self.frame.capacity()
            + self.values.capacity() * 2
            + self.floats.capacity() * 4
    }
}

/// Point-in-time pool counters.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PoolStats {
    /// `get()` satisfied from the free list (a warm scratch).
    pub hits: u64,
    /// `get()` that had to construct a fresh scratch.
    pub misses: u64,
    /// Scratches checked back in (drops beyond `max_idle` are not).
    pub returned: u64,
    /// Free-list length right now.
    pub idle: usize,
}

impl PoolStats {
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

/// A shared pool of [`Scratch`] buffers.
#[derive(Debug)]
pub struct BufPool {
    free: Mutex<Vec<Scratch>>,
    max_idle: usize,
    hits: AtomicU64,
    misses: AtomicU64,
    returned: AtomicU64,
}

impl BufPool {
    /// A pool keeping at most `max_idle` warm scratches; excess returns
    /// are dropped so one burst does not pin memory forever.
    pub fn new(max_idle: usize) -> Arc<Self> {
        Arc::new(Self {
            free: Mutex::new(Vec::new()),
            max_idle: max_idle.max(1),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            returned: AtomicU64::new(0),
        })
    }

    /// Check out a scratch; it returns to the pool when dropped.
    pub fn get(self: &Arc<Self>) -> PooledScratch {
        let reused = self.free.lock().unwrap().pop();
        let scratch = match reused {
            Some(s) => {
                self.hits.fetch_add(1, Ordering::Relaxed);
                s
            }
            None => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                Scratch::new()
            }
        };
        PooledScratch { scratch: Some(scratch), pool: Arc::clone(self) }
    }

    fn put(&self, mut s: Scratch) {
        s.clear();
        let mut free = self.free.lock().unwrap();
        if free.len() < self.max_idle {
            free.push(s);
            self.returned.fetch_add(1, Ordering::Relaxed);
        }
    }

    pub fn stats(&self) -> PoolStats {
        PoolStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            returned: self.returned.load(Ordering::Relaxed),
            idle: self.free.lock().unwrap().len(),
        }
    }
}

/// RAII guard over a checked-out [`Scratch`].
pub struct PooledScratch {
    scratch: Option<Scratch>,
    pool: Arc<BufPool>,
}

impl PooledScratch {
    /// Keep the scratch permanently (it will not return to the pool).
    pub fn detach(mut self) -> Scratch {
        self.scratch.take().expect("scratch present until drop")
    }
}

impl Deref for PooledScratch {
    type Target = Scratch;
    fn deref(&self) -> &Scratch {
        self.scratch.as_ref().expect("scratch present until drop")
    }
}

impl DerefMut for PooledScratch {
    fn deref_mut(&mut self) -> &mut Scratch {
        self.scratch.as_mut().expect("scratch present until drop")
    }
}

impl Drop for PooledScratch {
    fn drop(&mut self) {
        if let Some(s) = self.scratch.take() {
            self.pool.put(s);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn drop_returns_to_pool_and_keeps_capacity() {
        let pool = BufPool::new(4);
        {
            let mut s = pool.get();
            s.wire.reserve(4096);
            s.values.extend_from_slice(&[1, 2, 3]);
        }
        let s = pool.get();
        assert!(s.wire.capacity() >= 4096, "capacity not retained");
        assert!(s.values.is_empty(), "stale contents not cleared");
        let st = pool.stats();
        assert_eq!((st.hits, st.misses, st.returned), (1, 1, 1));
    }

    #[test]
    fn max_idle_bounds_free_list() {
        let pool = BufPool::new(2);
        let all: Vec<_> = (0..5).map(|_| pool.get()).collect();
        drop(all);
        let st = pool.stats();
        assert_eq!(st.idle, 2);
        assert_eq!(st.misses, 5);
        assert_eq!(st.returned, 2);
    }

    #[test]
    fn lend_restore_roundtrip_keeps_allocation() {
        let pool = BufPool::new(2);
        let mut s = pool.get();
        s.floats.reserve(1024);
        s.floats.extend_from_slice(&[1.0, 2.0]);
        let ptr = s.floats.as_ptr();
        let mut lent = s.lend_floats();
        assert!(s.floats.is_empty() && s.floats.capacity() == 0, "stand-in must be empty");
        lent.clear();
        lent.extend_from_slice(&[9.0; 16]); // the callee's "logits"
        s.restore_floats(lent);
        assert_eq!(s.floats.as_ptr(), ptr, "handoff must reuse the same allocation");
        assert_eq!(s.floats.len(), 16);
    }

    #[test]
    fn detach_keeps_scratch_out() {
        let pool = BufPool::new(4);
        let s = pool.get().detach();
        drop(s);
        assert_eq!(pool.stats().idle, 0);
    }

    #[test]
    fn concurrent_checkout_is_consistent() {
        let pool = BufPool::new(16);
        let handles: Vec<_> = (0..8)
            .map(|_| {
                let pool = Arc::clone(&pool);
                std::thread::spawn(move || {
                    for _ in 0..100 {
                        let mut s = pool.get();
                        s.floats.push(1.0);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        let st = pool.stats();
        assert_eq!(st.hits + st.misses, 800);
        assert!(st.idle <= 16);
    }

    #[test]
    fn hit_rate_steady_state_is_one() {
        let pool = BufPool::new(2);
        drop(pool.get()); // miss, warms the pool
        for _ in 0..99 {
            drop(pool.get()); // all hits
        }
        assert!(pool.stats().hit_rate() > 0.98);
    }
}
