//! Dependency-free detached signatures for registry manifests.
//!
//! The vendor set has no crypto crate, so this is a *keyed* integrity
//! tag in the HMAC shape — two nested [`Hasher128`] passes over an
//! inner- and outer-padded 32-byte key — rather than an asymmetric
//! signature. The trust model matches how the fleet deploys: the
//! registry and its edges share a provisioning secret (the
//! `--sign-seed` knob), an edge accepts a manifest only when the tag
//! verifies under that secret, and anything that flipped a byte in
//! transit — or a registry that doesn't hold the secret — is rejected
//! before a single stage executes. Swapping this construction for a
//! real asymmetric scheme later only changes this module: the
//! sign/verify call sites and the detached-tag wire format stay.
//!
//! Determinism contract: the tag is a pure function of (key bytes,
//! message bytes), stable across processes — manifests signed by one
//! registry process verify in any edge process.

use super::hash::{Hash128, Hasher128};

/// Key material length. 32 bytes so the two HMAC pads fully cover the
/// hasher's 8-byte word lanes several times over.
pub const KEY_LEN: usize = 32;

const IPAD: u8 = 0x36;
const OPAD: u8 = 0x5C;

/// A detached signature: the 128-bit keyed tag of a message.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Signature(pub Hash128);

impl Signature {
    /// Wire encoding: `[hi u64 LE][lo u64 LE]` — 16 bytes, prepended
    /// to a signed manifest payload.
    pub const WIRE_LEN: usize = 16;

    pub fn to_wire(self) -> [u8; Self::WIRE_LEN] {
        let mut b = [0u8; Self::WIRE_LEN];
        b[..8].copy_from_slice(&self.0.hi.to_le_bytes());
        b[8..].copy_from_slice(&self.0.lo.to_le_bytes());
        b
    }

    pub fn from_wire(b: &[u8]) -> Option<Signature> {
        if b.len() < Self::WIRE_LEN {
            return None;
        }
        Some(Signature(Hash128 {
            hi: u64::from_le_bytes(b[..8].try_into().unwrap()),
            lo: u64::from_le_bytes(b[8..16].try_into().unwrap()),
        }))
    }

    pub fn to_hex(self) -> String {
        self.0.to_hex()
    }
}

/// The shared signing/verifying secret.
#[derive(Clone)]
pub struct SigKey {
    key: [u8; KEY_LEN],
}

impl std::fmt::Debug for SigKey {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        // Never print key material.
        write!(f, "SigKey(..)")
    }
}

impl SigKey {
    pub fn from_bytes(key: [u8; KEY_LEN]) -> Self {
        Self { key }
    }

    /// Expand a small provisioning seed (the `--sign-seed` CLI knob)
    /// into full-width key material by chained hashing.
    pub fn from_seed(seed: u64) -> Self {
        let mut key = [0u8; KEY_LEN];
        let mut state = Hash128 { hi: seed ^ 0x6A09_E667_F3BC_C908, lo: seed.rotate_left(17) };
        for block in key.chunks_mut(16) {
            let mut h = Hasher128::new();
            h.write(&state.hi.to_le_bytes());
            h.write(&state.lo.to_le_bytes());
            h.write(b"jalad-registry-key");
            state = h.finish();
            block[..8].copy_from_slice(&state.hi.to_le_bytes());
            block[8..].copy_from_slice(&state.lo.to_le_bytes());
        }
        Self { key }
    }

    /// Sign `msg`: `H((K ^ opad) || H((K ^ ipad) || msg))`.
    pub fn sign(&self, msg: &[u8]) -> Signature {
        let mut inner = Hasher128::new();
        let mut pad = [0u8; KEY_LEN];
        for (p, k) in pad.iter_mut().zip(&self.key) {
            *p = k ^ IPAD;
        }
        inner.write(&pad);
        inner.write(msg);
        let inner_tag = inner.finish();

        let mut outer = Hasher128::new();
        for (p, k) in pad.iter_mut().zip(&self.key) {
            *p = k ^ OPAD;
        }
        outer.write(&pad);
        outer.write(&inner_tag.hi.to_le_bytes());
        outer.write(&inner_tag.lo.to_le_bytes());
        Signature(outer.finish())
    }

    /// Verify a detached signature. The comparison accumulates every
    /// differing bit before deciding, so it does not early-exit on the
    /// first mismatching byte.
    pub fn verify(&self, msg: &[u8], sig: Signature) -> bool {
        let want = self.sign(msg).0;
        let diff = (want.hi ^ sig.0.hi) | (want.lo ^ sig.0.lo);
        diff == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sign_verify_roundtrip() {
        let key = SigKey::from_seed(7);
        let sig = key.sign(b"manifest bytes");
        assert!(key.verify(b"manifest bytes", sig));
        assert_eq!(sig, key.sign(b"manifest bytes"), "tag must be deterministic");
    }

    #[test]
    fn any_flipped_message_bit_fails_verification() {
        let key = SigKey::from_seed(42);
        let msg: Vec<u8> = (0..64u8).collect();
        let sig = key.sign(&msg);
        for i in 0..msg.len() {
            for bit in 0..8 {
                let mut m = msg.clone();
                m[i] ^= 1 << bit;
                assert!(!key.verify(&m, sig), "flip byte {i} bit {bit} still verified");
            }
        }
    }

    #[test]
    fn wrong_key_fails_verification() {
        let sig = SigKey::from_seed(1).sign(b"msg");
        assert!(!SigKey::from_seed(2).verify(b"msg", sig));
        // Nearby seeds diverge too (the seed expansion avalanches).
        assert!(!SigKey::from_seed(0).verify(b"msg", sig));
    }

    #[test]
    fn tampered_signature_fails_verification() {
        let key = SigKey::from_seed(9);
        let sig = key.sign(b"msg");
        let mut wire = sig.to_wire();
        for i in 0..wire.len() {
            wire[i] ^= 0x01;
            let bad = Signature::from_wire(&wire).unwrap();
            assert!(!key.verify(b"msg", bad), "flipped sig byte {i} still verified");
            wire[i] ^= 0x01;
        }
    }

    #[test]
    fn wire_roundtrip() {
        let sig = SigKey::from_seed(3).sign(b"abc");
        let wire = sig.to_wire();
        assert_eq!(Signature::from_wire(&wire), Some(sig));
        assert_eq!(Signature::from_wire(&wire[..15]), None, "short wire must not parse");
        assert_eq!(sig.to_hex().len(), 32);
    }

    #[test]
    fn key_expansion_fills_every_block() {
        // Regression guard: both 16-byte halves of the expanded key
        // must be populated and distinct (a chaining bug that repeats
        // or zeroes a block would weaken the pads silently).
        let a = SigKey::from_seed(11);
        assert_ne!(&a.key[..16], &a.key[16..]);
        assert!(a.key.iter().any(|&b| b != 0));
    }
}
