//! Minimal readiness-driven I/O reactor over raw `epoll(7)` +
//! `eventfd(2)` (the event-driven-server roadmap rung).
//!
//! The vendored dependency set has no `libc`/`mio`/`tokio`, so the four
//! syscalls the cloud server's reactor needs are declared directly —
//! the same raw-extern idiom as [`affinity`](super::affinity). Scope is
//! deliberately small: level-triggered registration keyed by a caller
//! `u64` token, a blocking `wait` with EINTR retry, and a thread-safe
//! [`Reactor::wake`] (an `eventfd` write) so worker threads can unpark
//! the event loop when a completion is ready. Wake events are drained
//! inside [`Reactor::wait`] and never surface as [`Event`]s — a wake
//! may therefore return an empty event batch, which is exactly what a
//! "check your queues" signal means.
//!
//! Level-triggered (not edge-triggered) on purpose: a handler that
//! stops reading mid-buffer (e.g. one-request-in-flight per
//! connection) gets re-notified on the next `wait` instead of hanging
//! on bytes it already received, so the correctness argument never
//! depends on exhaustive draining.
//!
//! Off Linux, [`Reactor::new`] returns an error and the cloud server
//! falls back to its threadpool transport ([`Reactor::available`] lets
//! callers pick defaults up front).

use std::io;
use std::time::Duration;

/// Token value reserved for the internal wake `eventfd`; never use it
/// for a registration of your own.
pub const WAKE_TOKEN: u64 = u64::MAX;

/// Which readiness a registration asks for (error/hangup are always
/// reported regardless).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct Interest {
    pub readable: bool,
    pub writable: bool,
}

impl Interest {
    pub const READ: Interest = Interest { readable: true, writable: false };
    pub const WRITE: Interest = Interest { readable: false, writable: true };
    pub const NONE: Interest = Interest { readable: false, writable: false };
}

/// One readiness notification out of [`Reactor::wait`].
#[derive(Debug, Clone, Copy)]
pub struct Event {
    pub token: u64,
    pub readable: bool,
    pub writable: bool,
    /// Peer hung up or the fd errored — the owner should drive its
    /// read path to observe the EOF/error and close.
    pub hangup: bool,
}

#[cfg(target_os = "linux")]
mod imp {
    use super::{Event, Interest, WAKE_TOKEN};
    use std::fs::File;
    use std::io::{self, Read, Write};
    use std::os::unix::io::{AsRawFd, FromRawFd, RawFd};
    use std::time::Duration;

    const EPOLL_CLOEXEC: i32 = 0o2000000;
    const EPOLL_CTL_ADD: i32 = 1;
    const EPOLL_CTL_DEL: i32 = 2;
    const EPOLL_CTL_MOD: i32 = 3;
    const EPOLLIN: u32 = 0x001;
    const EPOLLOUT: u32 = 0x004;
    const EPOLLERR: u32 = 0x008;
    const EPOLLHUP: u32 = 0x010;
    const EPOLLRDHUP: u32 = 0x2000;
    const EFD_CLOEXEC: i32 = 0o2000000;
    const EFD_NONBLOCK: i32 = 0o4000;

    /// Mirrors the kernel's `struct epoll_event`. On x86 the kernel ABI
    /// packs it (no padding between `events` and `data`); other
    /// architectures use natural alignment.
    #[cfg_attr(any(target_arch = "x86", target_arch = "x86_64"), repr(C, packed))]
    #[cfg_attr(not(any(target_arch = "x86", target_arch = "x86_64")), repr(C))]
    #[derive(Clone, Copy)]
    struct EpollEvent {
        events: u32,
        data: u64,
    }

    extern "C" {
        fn epoll_create1(flags: i32) -> i32;
        fn epoll_ctl(epfd: i32, op: i32, fd: i32, event: *mut EpollEvent) -> i32;
        fn epoll_wait(epfd: i32, events: *mut EpollEvent, maxevents: i32, timeout: i32) -> i32;
        fn eventfd(initval: u32, flags: i32) -> i32;
    }

    /// Max events drained per `epoll_wait` call; a busier set is simply
    /// picked up by the next call (level-triggered, nothing is lost).
    const WAIT_BATCH: usize = 256;

    pub struct Reactor {
        /// Owns the epoll fd (closed on drop).
        ep: File,
        /// Owns the wake eventfd (nonblocking; read and written through
        /// the same fd).
        wake: File,
    }

    impl Reactor {
        pub fn new() -> io::Result<Self> {
            let epfd = unsafe { epoll_create1(EPOLL_CLOEXEC) };
            if epfd < 0 {
                return Err(io::Error::last_os_error());
            }
            // From here the File owns (and on error paths closes) epfd.
            let ep = unsafe { File::from_raw_fd(epfd) };
            let wfd = unsafe { eventfd(0, EFD_CLOEXEC | EFD_NONBLOCK) };
            if wfd < 0 {
                return Err(io::Error::last_os_error());
            }
            let wake = unsafe { File::from_raw_fd(wfd) };
            let me = Self { ep, wake };
            me.ctl(EPOLL_CTL_ADD, me.wake.as_raw_fd(), WAKE_TOKEN, Interest::READ)?;
            Ok(me)
        }

        fn ctl(&self, op: i32, fd: RawFd, token: u64, interest: Interest) -> io::Result<()> {
            let mut events = EPOLLRDHUP;
            if interest.readable {
                events |= EPOLLIN;
            }
            if interest.writable {
                events |= EPOLLOUT;
            }
            let mut ev = EpollEvent { events, data: token };
            // DEL ignores the event but pre-2.6.9 kernels required it
            // non-null, so always pass the pointer.
            if unsafe { epoll_ctl(self.ep.as_raw_fd(), op, fd, &mut ev) } != 0 {
                return Err(io::Error::last_os_error());
            }
            Ok(())
        }

        /// Start watching `fd` under `token`.
        pub fn register(&self, fd: RawFd, token: u64, interest: Interest) -> io::Result<()> {
            self.ctl(EPOLL_CTL_ADD, fd, token, interest)
        }

        /// Change an existing registration's interest set.
        pub fn rearm(&self, fd: RawFd, token: u64, interest: Interest) -> io::Result<()> {
            self.ctl(EPOLL_CTL_MOD, fd, token, interest)
        }

        /// Stop watching `fd`.
        pub fn deregister(&self, fd: RawFd) -> io::Result<()> {
            self.ctl(EPOLL_CTL_DEL, fd, 0, Interest::NONE)
        }

        /// Unpark a concurrent [`Reactor::wait`] from any thread.
        pub fn wake(&self) {
            // A full counter (u64::MAX pending wakes) means the loop is
            // already guaranteed to wake; WouldBlock here is success.
            let _ = (&self.wake).write(&1u64.to_le_bytes());
        }

        fn drain_wake(&self) {
            let mut buf = [0u8; 8];
            // Nonblocking: one read resets the counter; loop in case a
            // racing waker re-arms between read and return (harmless
            // either way — the next wait would just spin once).
            while (&self.wake).read(&mut buf).is_ok() {}
        }

        /// Block until something is ready (or `timeout` passes), then
        /// append the readiness batch to `out` (cleared first). A
        /// cross-thread [`Reactor::wake`] may produce an empty batch —
        /// that is the caller's cue to check its own queues. `None`
        /// blocks indefinitely.
        pub fn wait(&self, out: &mut Vec<Event>, timeout: Option<Duration>) -> io::Result<usize> {
            out.clear();
            let ms: i32 = match timeout {
                // Round up so a sub-millisecond timeout cannot busy-spin.
                Some(d) => {
                    let mut ms = d.as_millis();
                    if d.subsec_nanos() % 1_000_000 != 0 {
                        ms += 1;
                    }
                    ms.min(i32::MAX as u128) as i32
                }
                None => -1,
            };
            let mut evs = [EpollEvent { events: 0, data: 0 }; WAIT_BATCH];
            loop {
                let n = unsafe {
                    epoll_wait(self.ep.as_raw_fd(), evs.as_mut_ptr(), WAIT_BATCH as i32, ms)
                };
                if n < 0 {
                    let e = io::Error::last_os_error();
                    if e.kind() == io::ErrorKind::Interrupted {
                        continue;
                    }
                    return Err(e);
                }
                for ev in evs.iter().take(n as usize) {
                    // Copy out of the (possibly packed) struct first.
                    let (bits, token) = (ev.events, ev.data);
                    if token == WAKE_TOKEN {
                        self.drain_wake();
                        continue;
                    }
                    out.push(Event {
                        token,
                        // ERR/HUP count as readable+writable so owners
                        // attempt I/O and observe the failure instead
                        // of waiting forever on an interest that can
                        // no longer fire.
                        readable: bits & (EPOLLIN | EPOLLRDHUP | EPOLLHUP | EPOLLERR) != 0,
                        writable: bits & (EPOLLOUT | EPOLLERR | EPOLLHUP) != 0,
                        hangup: bits & (EPOLLRDHUP | EPOLLHUP | EPOLLERR) != 0,
                    });
                }
                return Ok(out.len());
            }
        }
    }

    /// Best-effort `RLIMIT_NOFILE` raise toward `want` (capped by the
    /// hard limit); returns the soft limit now in effect. The C10K
    /// bench calls this before opening its fleet and clamps its
    /// connection count to what it actually got.
    #[cfg(target_pointer_width = "64")]
    pub fn raise_nofile_limit(want: u64) -> u64 {
        // glibc's rlim_t is unsigned long — u64 on 64-bit targets (the
        // 32-bit layout differs, hence the pointer-width gate).
        #[repr(C)]
        struct Rlimit {
            cur: u64,
            max: u64,
        }
        extern "C" {
            fn getrlimit(resource: i32, rlim: *mut Rlimit) -> i32;
            fn setrlimit(resource: i32, rlim: *const Rlimit) -> i32;
        }
        const RLIMIT_NOFILE: i32 = 7;
        let mut lim = Rlimit { cur: 0, max: 0 };
        if unsafe { getrlimit(RLIMIT_NOFILE, &mut lim) } != 0 {
            return 1024; // the historic default; callers only size off this
        }
        if lim.cur >= want {
            return lim.cur;
        }
        let raised = Rlimit { cur: want.min(lim.max), max: lim.max };
        if unsafe { setrlimit(RLIMIT_NOFILE, &raised) } == 0 {
            raised.cur
        } else {
            lim.cur
        }
    }

    #[cfg(not(target_pointer_width = "64"))]
    pub fn raise_nofile_limit(_want: u64) -> u64 {
        1024
    }
}

#[cfg(not(target_os = "linux"))]
mod imp {
    use super::{Event, Interest};
    use std::io;
    use std::time::Duration;

    /// Stub: constructing a reactor off Linux always fails and callers
    /// fall back to the threadpool transport.
    pub struct Reactor {
        _priv: (),
    }

    #[cfg(unix)]
    type RawFd = std::os::unix::io::RawFd;
    #[cfg(not(unix))]
    type RawFd = i32;

    fn unsupported() -> io::Error {
        io::Error::new(io::ErrorKind::Unsupported, "epoll reactor requires Linux")
    }

    impl Reactor {
        pub fn new() -> io::Result<Self> {
            Err(unsupported())
        }

        pub fn register(&self, _fd: RawFd, _token: u64, _interest: Interest) -> io::Result<()> {
            Err(unsupported())
        }

        pub fn rearm(&self, _fd: RawFd, _token: u64, _interest: Interest) -> io::Result<()> {
            Err(unsupported())
        }

        pub fn deregister(&self, _fd: RawFd) -> io::Result<()> {
            Err(unsupported())
        }

        pub fn wake(&self) {}

        pub fn wait(&self, out: &mut Vec<Event>, _timeout: Option<Duration>) -> io::Result<usize> {
            out.clear();
            Err(unsupported())
        }
    }

    pub fn raise_nofile_limit(_want: u64) -> u64 {
        1024
    }
}

pub use imp::{raise_nofile_limit, Reactor};

impl Reactor {
    /// Can this host run the epoll transport at all? (Linux only.)
    pub const fn available() -> bool {
        cfg!(target_os = "linux")
    }
}

#[allow(unused)]
fn _assert_thread_safe(r: &Reactor) -> &(dyn Sync + Send) {
    r
}

#[cfg(all(test, target_os = "linux"))]
mod tests {
    use super::*;
    use std::io::Write;
    use std::net::{TcpListener, TcpStream};
    use std::os::unix::io::AsRawFd;
    use std::sync::Arc;
    use std::time::Instant;

    #[test]
    fn listener_readiness_and_tokens() {
        let r = Reactor::new().unwrap();
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        listener.set_nonblocking(true).unwrap();
        r.register(listener.as_raw_fd(), 7, Interest::READ).unwrap();
        let mut events = Vec::new();
        // Nothing pending yet: a short wait returns empty.
        r.wait(&mut events, Some(Duration::from_millis(10))).unwrap();
        assert!(events.is_empty());
        let _client = TcpStream::connect(listener.local_addr().unwrap()).unwrap();
        r.wait(&mut events, Some(Duration::from_secs(5))).unwrap();
        assert!(
            events.iter().any(|e| e.token == 7 && e.readable),
            "pending accept must surface as readable: {events:?}"
        );
        r.deregister(listener.as_raw_fd()).unwrap();
        let _client2 = TcpStream::connect(listener.local_addr().unwrap()).unwrap();
        r.wait(&mut events, Some(Duration::from_millis(20))).unwrap();
        assert!(events.is_empty(), "deregistered fd must not report: {events:?}");
    }

    #[test]
    fn rearm_toggles_writability() {
        let r = Reactor::new().unwrap();
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let client = TcpStream::connect(listener.local_addr().unwrap()).unwrap();
        let (server_side, _) = listener.accept().unwrap();
        server_side.set_nonblocking(true).unwrap();
        r.register(server_side.as_raw_fd(), 1, Interest::READ).unwrap();
        let mut events = Vec::new();
        r.wait(&mut events, Some(Duration::from_millis(10))).unwrap();
        assert!(events.is_empty(), "idle read-only socket must be quiet");
        // An idle connected socket is immediately writable once asked.
        r.rearm(server_side.as_raw_fd(), 1, Interest::WRITE).unwrap();
        r.wait(&mut events, Some(Duration::from_secs(5))).unwrap();
        assert!(events.iter().any(|e| e.token == 1 && e.writable), "{events:?}");
        // And data arriving surfaces as readable after re-arming back.
        r.rearm(server_side.as_raw_fd(), 1, Interest::READ).unwrap();
        (&client).write_all(b"x").unwrap();
        r.wait(&mut events, Some(Duration::from_secs(5))).unwrap();
        assert!(events.iter().any(|e| e.token == 1 && e.readable), "{events:?}");
    }

    #[test]
    fn cross_thread_wake_unblocks_wait() {
        let r = Arc::new(Reactor::new().unwrap());
        let waker = Arc::clone(&r);
        let t0 = Instant::now();
        let h = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(50));
            waker.wake();
        });
        let mut events = Vec::new();
        // Blocking wait: only the wake can end it (generous cap so a
        // broken wake fails the test instead of hanging it).
        r.wait(&mut events, Some(Duration::from_secs(30))).unwrap();
        assert!(t0.elapsed() < Duration::from_secs(29), "wait must end on wake, not timeout");
        assert!(events.is_empty(), "wake is internal, never an Event: {events:?}");
        h.join().unwrap();
        // Coalesced wakes drain in one go; the next wait is quiet.
        r.wake();
        r.wake();
        r.wait(&mut events, Some(Duration::from_millis(5))).unwrap();
        r.wait(&mut events, Some(Duration::from_millis(5))).unwrap();
        assert!(events.is_empty());
    }

    #[test]
    fn nofile_limit_is_queryable() {
        let cur = raise_nofile_limit(256);
        assert!(cur >= 256 || cur > 0, "soft limit must come back: {cur}");
        // Asking again for what we already have is a no-op success.
        assert!(raise_nofile_limit(cur) >= cur);
    }
}
