//! Dependency-free streaming 128-bit content hash.
//!
//! The serving path needs a key that identifies a request by its
//! bytes — two feature frames with identical `(model, cut, c,
//! payload)` must collide, everything else must not (to the strength a
//! 128-bit non-cryptographic digest gives: accidental collision is
//! ~2⁻⁶⁴ at billions of distinct keys, fine for a cache whose worst
//! failure is a wrong-but-well-formed reply on an adversarial
//! collision — and the cache is keyed after CRC/geometry validation,
//! so a *corrupted* frame never reaches it).
//!
//! Two xx-style 64-bit lanes consume the input in 8-byte words with
//! multiply-rotate mixing, fold in the total length, and finish with a
//! murmur-style avalanche. Streaming is split-invariant:
//! `write(a); write(b)` equals `write(a ++ b)` at every split point
//! (an internal 8-byte staging buffer carries partial words across
//! calls), which is what lets [`HashingReader`] hash a stream *while*
//! it is being read/validated — the hash-while-reading idiom, no
//! second pass over the payload.

use std::io::Read;

/// A 128-bit digest. `Eq + Hash` so it can key a `HashMap` directly.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct Hash128 {
    pub hi: u64,
    pub lo: u64,
}

impl Hash128 {
    /// Hex rendering for logs/tests (big-endian, 32 nibbles).
    pub fn to_hex(self) -> String {
        format!("{:016x}{:016x}", self.hi, self.lo)
    }
}

const SEED_A: u64 = 0x9E37_79B9_7F4A_7C15; // 2^64 / φ
const SEED_B: u64 = 0xC2B2_AE3D_27D4_EB4F;
const PRIME_A: u64 = 0x9E37_79B1_85EB_CA87;
const PRIME_B: u64 = 0x1656_67B1_9E37_79F9;

fn avalanche(mut x: u64) -> u64 {
    x ^= x >> 33;
    x = x.wrapping_mul(0xFF51_AFD7_ED55_8CCD);
    x ^= x >> 33;
    x = x.wrapping_mul(0xC4CE_B9FE_1A85_EC53);
    x ^ (x >> 33)
}

/// Streaming two-lane hasher. `Default`/`new` start from fixed seeds:
/// the digest is a pure function of the byte stream, stable across
/// processes and runs (cache keys survive nothing, but tests and any
/// future persisted index depend on the stability).
#[derive(Debug, Clone)]
pub struct Hasher128 {
    a: u64,
    b: u64,
    /// Staging for a partial 8-byte word across `write` calls.
    buf: [u8; 8],
    buf_len: usize,
    total: u64,
}

impl Default for Hasher128 {
    fn default() -> Self {
        Self::new()
    }
}

impl Hasher128 {
    pub fn new() -> Self {
        Self { a: SEED_A, b: SEED_B, buf: [0; 8], buf_len: 0, total: 0 }
    }

    #[inline]
    fn mix(&mut self, w: u64) {
        self.a = (self.a ^ w).wrapping_mul(PRIME_A).rotate_left(31);
        self.b = (self.b.rotate_left(29) ^ w).wrapping_mul(PRIME_B);
    }

    pub fn write(&mut self, mut bytes: &[u8]) {
        self.total = self.total.wrapping_add(bytes.len() as u64);
        if self.buf_len > 0 {
            let take = (8 - self.buf_len).min(bytes.len());
            self.buf[self.buf_len..self.buf_len + take].copy_from_slice(&bytes[..take]);
            self.buf_len += take;
            bytes = &bytes[take..];
            if self.buf_len < 8 {
                return;
            }
            let w = u64::from_le_bytes(self.buf);
            self.mix(w);
            self.buf_len = 0;
        }
        let mut chunks = bytes.chunks_exact(8);
        for c in &mut chunks {
            // chunks_exact guarantees the length; unwrap can't fire.
            self.mix(u64::from_le_bytes(c.try_into().unwrap()));
        }
        let rem = chunks.remainder();
        self.buf[..rem.len()].copy_from_slice(rem);
        self.buf_len = rem.len();
    }

    /// Finish without consuming: the hasher may keep streaming (used
    /// by [`HashingReader::digest`] to observe the hash so far).
    pub fn finish(&self) -> Hash128 {
        let (mut a, mut b) = (self.a, self.b);
        // Fold the partial tail word in, tagged with its length so
        // "abc" and "abc\0" cannot alias even before the total-length
        // fold.
        let mut tail = [0u8; 8];
        tail[..self.buf_len].copy_from_slice(&self.buf[..self.buf_len]);
        let w = u64::from_le_bytes(tail) ^ ((self.buf_len as u64) << 56);
        a = (a ^ w).wrapping_mul(PRIME_A).rotate_left(31);
        b = (b.rotate_left(29) ^ w).wrapping_mul(PRIME_B);
        a ^= self.total.wrapping_mul(PRIME_B);
        b ^= self.total.wrapping_mul(PRIME_A);
        // Cross the lanes before avalanching so neither half of the
        // digest is a function of one lane alone.
        let hi = avalanche(a.wrapping_add(b.rotate_left(17)));
        let lo = avalanche(b ^ hi);
        Hash128 { hi, lo }
    }
}

/// One-shot convenience over [`Hasher128`].
pub fn hash128(bytes: &[u8]) -> Hash128 {
    let mut h = Hasher128::new();
    h.write(bytes);
    h.finish()
}

/// A `Read` adapter that hashes every byte as it passes through — the
/// hash-while-reading idiom: a consumer that already reads a stream
/// once (framing, validation, decode) gets the content digest of what
/// it read for free, with no second pass.
pub struct HashingReader<R> {
    inner: R,
    hasher: Hasher128,
}

impl<R> HashingReader<R> {
    pub fn new(inner: R) -> Self {
        Self { inner, hasher: Hasher128::new() }
    }

    /// Digest of every byte read so far.
    pub fn digest(&self) -> Hash128 {
        self.hasher.finish()
    }

    pub fn get_ref(&self) -> &R {
        &self.inner
    }

    pub fn into_inner(self) -> R {
        self.inner
    }
}

impl<R: Read> Read for HashingReader<R> {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        let n = self.inner.read(buf)?;
        self.hasher.write(&buf[..n]);
        Ok(n)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::{Cursor, Read};

    fn sample(n: usize) -> Vec<u8> {
        (0..n).map(|i| (i as u64).wrapping_mul(0x2545_F491_4F6C_DD1D).to_le_bytes()[3]).collect()
    }

    #[test]
    fn split_invariant_at_every_point() {
        let data = sample(67); // crosses word boundaries + odd tail
        let whole = hash128(&data);
        for split in 0..=data.len() {
            let mut h = Hasher128::new();
            h.write(&data[..split]);
            h.write(&data[split..]);
            assert_eq!(h.finish(), whole, "split at {split} changed the digest");
        }
        // Byte-at-a-time too.
        let mut h = Hasher128::new();
        for b in &data {
            h.write(std::slice::from_ref(b));
        }
        assert_eq!(h.finish(), whole);
    }

    #[test]
    fn single_bit_flips_change_the_digest() {
        let data = sample(40);
        let base = hash128(&data);
        for i in 0..data.len() {
            for bit in 0..8 {
                let mut d = data.clone();
                d[i] ^= 1 << bit;
                assert_ne!(hash128(&d), base, "flip byte {i} bit {bit} collided");
            }
        }
    }

    #[test]
    fn length_is_part_of_the_identity() {
        assert_ne!(hash128(b""), hash128(b"\0"));
        assert_ne!(hash128(b"\0"), hash128(b"\0\0"));
        let eight = sample(8);
        let mut nine = eight.clone();
        nine.push(0);
        assert_ne!(hash128(&eight), hash128(&nine));
    }

    #[test]
    fn digest_is_stable() {
        // Pinned vector: the digest is a pure function of the bytes —
        // a change here is a silent cache-key format break.
        let h = hash128(b"jalad");
        assert_eq!(h, hash128(b"jalad"));
        assert_ne!(h.hi, 0);
        assert_ne!(h.lo, 0);
        assert_eq!(h.to_hex().len(), 32);
    }

    #[test]
    fn hashing_reader_matches_one_shot() {
        let data = sample(1000);
        let mut r = HashingReader::new(Cursor::new(data.clone()));
        let mut out = Vec::new();
        let mut chunk = [0u8; 33]; // deliberately word-misaligned reads
        loop {
            let n = r.read(&mut chunk).unwrap();
            if n == 0 {
                break;
            }
            out.extend_from_slice(&chunk[..n]);
        }
        assert_eq!(out, data, "reader must be transparent");
        assert_eq!(r.digest(), hash128(&data));
    }

    #[test]
    fn finish_does_not_consume() {
        let mut h = Hasher128::new();
        h.write(b"ab");
        let first = h.finish();
        assert_eq!(first, h.finish());
        h.write(b"c");
        assert_eq!(h.finish(), hash128(b"abc"));
    }
}
