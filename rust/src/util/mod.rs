//! From-scratch utility substrates.
//!
//! The offline vendor set ships only `xla` + `anyhow`, so the crates a
//! serving system would normally lean on (serde, clap, criterion,
//! proptest, tokio, rand) are reimplemented here at the scale this
//! project needs. Each is a deliberate deliverable with its own tests.

pub mod affinity;
pub mod bench;
pub mod cli;
pub mod fault;
pub mod hash;
pub mod json;
pub mod logging;
pub mod once_map;
pub mod pool;
pub mod prop;
pub mod reactor;
pub mod rng;
pub mod sign;
pub mod stats;
pub mod threadpool;
