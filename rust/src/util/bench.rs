//! Criterion-like benchmark harness for `cargo bench` (harness = false).
//!
//! Auto-calibrates iteration counts to a target measurement time, warms
//! up, reports mean / p50 / p95 and throughput, and can emit the paper's
//! table rows. `cargo bench` filters benches by substring argument just
//! like criterion (`cargo bench -- huffman`).

use std::time::{Duration, Instant};

use super::stats;

pub struct Bencher {
    filter: Option<String>,
    target: Duration,
    results: Vec<BenchResult>,
}

#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p95_ns: f64,
    pub throughput: Option<(f64, &'static str)>,
}

impl Default for Bencher {
    fn default() -> Self {
        Self::from_env()
    }
}

impl Bencher {
    /// Reads the cargo-bench CLI: any non-flag argument is a substring
    /// filter; `--quick` shortens the target time (CI), and `--smoke`
    /// (the verify.sh smoke mode) shortens it further — benches that
    /// drive their own iteration counts also check [`Bencher::smoke`].
    pub fn from_env() -> Self {
        let mut filter = None;
        let mut target = Duration::from_millis(400);
        for arg in std::env::args().skip(1) {
            match arg.as_str() {
                "--bench" | "--test" => {} // cargo passes these through
                "--quick" => target = Duration::from_millis(60),
                "--smoke" => target = Duration::from_millis(30),
                s if !s.starts_with('-') => filter = Some(s.to_string()),
                _ => {}
            }
        }
        Self { filter, target, results: Vec::new() }
    }

    /// True when `--smoke` was passed: emit well-formed results as fast
    /// as possible (CI wiring check, not a measurement).
    pub fn smoke() -> bool {
        std::env::args().any(|a| a == "--smoke")
    }

    fn enabled(&self, name: &str) -> bool {
        self.filter.as_deref().map(|f| name.contains(f)).unwrap_or(true)
    }

    /// Benchmark a closure; returns the result (also stored for summary).
    pub fn bench<F: FnMut()>(&mut self, name: &str, mut f: F) -> Option<BenchResult> {
        self.bench_with_throughput(name, None, &mut f)
    }

    /// Benchmark with a bytes-per-iteration throughput annotation.
    pub fn bench_bytes<F: FnMut()>(&mut self, name: &str, bytes: usize, mut f: F) -> Option<BenchResult> {
        self.bench_with_throughput(name, Some((bytes as f64, "B")), &mut f)
    }

    /// Benchmark with an items-per-iteration throughput annotation.
    pub fn bench_items<F: FnMut()>(
        &mut self,
        name: &str,
        items: f64,
        unit: &'static str,
        mut f: F,
    ) -> Option<BenchResult> {
        self.bench_with_throughput(name, Some((items, unit)), &mut f)
    }

    fn bench_with_throughput<F: FnMut()>(
        &mut self,
        name: &str,
        per_iter: Option<(f64, &'static str)>,
        f: &mut F,
    ) -> Option<BenchResult> {
        if !self.enabled(name) {
            return None;
        }
        // Calibrate: find an iteration count that takes ≥ target/10.
        let mut iters_per_sample: u64 = 1;
        loop {
            let t0 = Instant::now();
            for _ in 0..iters_per_sample {
                f();
            }
            let el = t0.elapsed();
            if el >= self.target / 10 || iters_per_sample >= 1 << 24 {
                break;
            }
            iters_per_sample = (iters_per_sample * 4).min(1 << 24);
        }
        // Measure: collect ~10 samples.
        let mut samples_ns = Vec::with_capacity(12);
        let deadline = Instant::now() + self.target;
        while Instant::now() < deadline || samples_ns.len() < 3 {
            let t0 = Instant::now();
            for _ in 0..iters_per_sample {
                f();
            }
            samples_ns.push(t0.elapsed().as_nanos() as f64 / iters_per_sample as f64);
            if samples_ns.len() >= 30 {
                break;
            }
        }
        let mean = stats::mean(&samples_ns);
        let result = BenchResult {
            name: name.to_string(),
            iters: iters_per_sample * samples_ns.len() as u64,
            mean_ns: mean,
            p50_ns: stats::percentile(&samples_ns, 50.0),
            p95_ns: stats::percentile(&samples_ns, 95.0),
            throughput: per_iter.map(|(n, u)| (n / (mean / 1e9), u)),
        };
        println!("{}", format_result(&result));
        self.results.push(result.clone());
        Some(result)
    }

    /// Print a closing summary (call at the end of the bench main).
    pub fn finish(&self) {
        println!("\n{} benchmark(s) run.", self.results.len());
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }
}

pub fn format_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{:.1} ns", ns)
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

fn format_result(r: &BenchResult) -> String {
    let tp = match r.throughput {
        Some((v, "B")) => format!("  ({:.1} MiB/s)", v / (1024.0 * 1024.0)),
        Some((v, u)) => format!("  ({:.1} {}/s)", v, u),
        None => String::new(),
    };
    format!(
        "{:<44} mean {:>10}  p50 {:>10}  p95 {:>10}{}",
        r.name,
        format_ns(r.mean_ns),
        format_ns(r.p50_ns),
        format_ns(r.p95_ns),
        tp
    )
}

/// Render a paper-style table (used by the table benches to print the
/// same rows the paper reports).
pub fn print_table(title: &str, header: &[&str], rows: &[Vec<String>]) {
    println!("\n=== {title} ===");
    let mut widths: Vec<usize> = header.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(cell.len());
            }
        }
    }
    let fmt_row = |cells: &[String]| -> String {
        cells
            .iter()
            .enumerate()
            .map(|(i, c)| format!("{:<w$}", c, w = widths.get(i).copied().unwrap_or(8)))
            .collect::<Vec<_>>()
            .join("  ")
    };
    println!("{}", fmt_row(&header.iter().map(|s| s.to_string()).collect::<Vec<_>>()));
    println!("{}", "-".repeat(widths.iter().sum::<usize>() + 2 * widths.len()));
    for row in rows {
        println!("{}", fmt_row(row));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let mut b = Bencher { filter: None, target: Duration::from_millis(20), results: vec![] };
        let r = b
            .bench("spin", || {
                std::hint::black_box((0..100).sum::<u64>());
            })
            .unwrap();
        assert!(r.mean_ns > 0.0);
        assert!(r.iters > 0);
    }

    #[test]
    fn filter_skips() {
        let mut b = Bencher {
            filter: Some("xyz".into()),
            target: Duration::from_millis(5),
            results: vec![],
        };
        assert!(b.bench("abc", || {}).is_none());
        assert!(b.bench("has_xyz_inside", || {}).is_some());
    }

    #[test]
    fn ns_formatting() {
        assert_eq!(format_ns(12.3), "12.3 ns");
        assert_eq!(format_ns(1234.0), "1.23 µs");
        assert_eq!(format_ns(12_345_678.0), "12.35 ms");
    }
}
