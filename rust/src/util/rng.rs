//! xorshift64* PRNG + Box-Muller gaussians.
//!
//! Bit-identical to `python/compile/data.py::XorShift64Star` — the data
//! generator contract between build-time python and the rust runtime
//! depends on both sides drawing the same streams (see `data::gen`).

#[derive(Debug, Clone)]
pub struct XorShift64Star {
    s: u64,
}

impl XorShift64Star {
    pub fn new(seed: u64) -> Self {
        Self { s: if seed == 0 { 0x2545F4914F6CDD1D } else { seed } }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let mut s = self.s;
        s ^= s >> 12;
        s ^= s << 25;
        s ^= s >> 27;
        self.s = s;
        s.wrapping_mul(0x2545F4914F6CDD1D)
    }

    /// Uniform in (0, 1]: top 53 bits / 2^53, never exactly 0.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        ((self.next_u64() >> 11) + 1) as f64 / (1u64 << 53) as f64
    }

    /// Uniform in [0, n).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        // Modulo bias is < 2^-40 for the n used here (≤ millions).
        self.next_u64() % n
    }

    /// Box-Muller pair of standard normals.
    pub fn next_gaussian_pair(&mut self) -> (f64, f64) {
        let u1 = self.next_f64();
        let u2 = self.next_f64();
        let r = (-2.0 * u1.ln()).sqrt();
        let th = 2.0 * std::f64::consts::PI * u2;
        (r * th.cos(), r * th.sin())
    }

    /// Fill `n` f32 standard normals — same draw order as the python twin.
    pub fn fill_gaussian(&mut self, n: usize) -> Vec<f32> {
        let mut out = vec![0f32; n];
        let mut i = 0;
        while i + 1 < n {
            let (a, b) = self.next_gaussian_pair();
            out[i] = a as f32;
            out[i + 1] = b as f32;
            i += 2;
        }
        if n % 2 == 1 {
            out[n - 1] = self.next_gaussian_pair().0 as f32;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = XorShift64Star::new(42);
        let mut b = XorShift64Star::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn zero_seed_is_remapped() {
        let mut r = XorShift64Star::new(0);
        assert_ne!(r.next_u64(), 0);
    }

    #[test]
    fn uniform_range() {
        let mut r = XorShift64Star::new(7);
        for _ in 0..10_000 {
            let u = r.next_f64();
            assert!(u > 0.0 && u <= 1.0);
        }
    }

    #[test]
    fn gaussian_moments() {
        let mut r = XorShift64Star::new(1234);
        let xs = r.fill_gaussian(100_000);
        let mean = xs.iter().map(|x| *x as f64).sum::<f64>() / xs.len() as f64;
        let var =
            xs.iter().map(|x| (*x as f64 - mean).powi(2)).sum::<f64>() / xs.len() as f64;
        assert!(mean.abs() < 0.02, "mean {}", mean);
        assert!((var - 1.0).abs() < 0.03, "var {}", var);
    }

    /// Golden values locked against the python implementation
    /// (`tests/test_data.py::test_rng_golden` holds the same constants).
    #[test]
    fn golden_cross_language() {
        let mut r = XorShift64Star::new(1);
        let got: Vec<u64> = (0..4).map(|_| r.next_u64()).collect();
        assert_eq!(
            got,
            vec![
                0x47e4ce4b896cdd1d,
                0xabcfa6a8e079651d,
                0xb9d10d8feb731f57,
                0x4db418a0bb1b019d,
            ]
        );
        let mut r2 = XorShift64Star::new(1);
        assert!((r2.next_f64() - 0.2808350500503596).abs() < 1e-15);
        assert!((r2.next_f64() - 0.6711372530266765).abs() < 1e-15);
    }
}
