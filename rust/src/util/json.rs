//! Minimal JSON: parser + pretty/compact serializer.
//!
//! Used for `artifacts/manifest.json` (written by `python/compile/aot.py`)
//! and for persisting the predictor lookup tables. Supports the full JSON
//! grammar except `\u` surrogate pairs outside the BMP are passed through
//! unvalidated. Numbers parse to f64 (manifest integers fit exactly).

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // -- typed accessors (None on type mismatch / missing key) ----------
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }
    pub fn idx(&self, i: usize) -> Option<&Json> {
        match self {
            Json::Arr(a) => a.get(i),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().filter(|n| *n >= 0.0 && n.fract() == 0.0).map(|n| n as u64)
    }
    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().filter(|n| n.fract() == 0.0).map(|n| n as i64)
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Path access: `j.path(&["models", "0", "stages"])`.
    pub fn path(&self, keys: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for k in keys {
            cur = match cur {
                Json::Obj(m) => m.get(*k)?,
                Json::Arr(a) => a.get(k.parse::<usize>().ok()?)?,
                _ => return None,
            };
        }
        Some(cur)
    }

    // -- builders --------------------------------------------------------
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }
    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }
    pub fn str(s: &str) -> Json {
        Json::Str(s.to_string())
    }

    /// Compact serialization.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Pretty serialization with 1-space indent (matches python's
    /// `json.dump(indent=1)` closely enough for diffing).
    pub fn to_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(1), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9.0e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{}", n));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    v.write(out, indent, depth + 1);
                }
                if !a.is_empty() {
                    newline(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !m.is_empty() {
                    newline(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for ch in s.chars() {
        match ch {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.i, msg: msg.to_string() }
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{}'", word)))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self.peek().is_some_and(|c| c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while self.peek().is_some_and(|c| c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.i += 1;
            }
            while self.peek().is_some_and(|c| c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        text.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i + 1..self.i + 5]).unwrap();
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar (multi-byte safe).
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let ch = rest.chars().next().unwrap();
                    s.push(ch);
                    self.i += ch.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(j.path(&["a", "2", "b"]).unwrap().as_str(), Some("x"));
        assert_eq!(j.get("c"), Some(&Json::Null));
        assert_eq!(j.get("a").unwrap().as_arr().unwrap().len(), 3);
    }

    #[test]
    fn parse_escapes_and_unicode() {
        let j = Json::parse(r#""a\n\t\"\\ é ü""#).unwrap();
        assert_eq!(j.as_str(), Some("a\n\t\"\\ é ü"));
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"k":[1,2.5,"s",true,null],"m":{"x":-1}}"#;
        let j = Json::parse(src).unwrap();
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
        let j3 = Json::parse(&j.to_pretty()).unwrap();
        assert_eq!(j, j3);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"unterminated").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn typed_accessors() {
        let j = Json::parse(r#"{"n": 7, "f": 1.5, "neg": -2}"#).unwrap();
        assert_eq!(j.get("n").unwrap().as_u64(), Some(7));
        assert_eq!(j.get("f").unwrap().as_u64(), None);
        assert_eq!(j.get("neg").unwrap().as_i64(), Some(-2));
        assert_eq!(j.get("neg").unwrap().as_u64(), None);
    }
}
