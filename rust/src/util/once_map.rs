//! A concurrent build-exactly-once map: the compile-cache primitive.
//!
//! `HashMap` + "check, miss, build, insert" has a classic race: two
//! threads both miss and both build the same (expensive) artifact.
//! [`OnceMap::get_or_try_build`] closes it with a per-key in-flight
//! marker — the first thread to miss becomes the builder, later threads
//! park on a condvar until the value lands. A failed build releases the
//! key so a later caller can retry (errors are not cached), and a
//! builder that *panics* also releases it (unwind guard) instead of
//! wedging every waiter forever.
//!
//! The build closure runs **outside** the map lock, so building one key
//! never blocks lookups or builds of other keys.

use std::borrow::Borrow;
use std::collections::HashMap;
use std::hash::Hash;
use std::sync::{Condvar, Mutex};

use anyhow::Result;

#[derive(Debug)]
enum Slot<V> {
    Ready(V),
    Building,
}

/// Map from `K` to a cached `V` where each key's value is built at most
/// once even under concurrent first access. `V: Clone` — store an `Arc`
/// for expensive values.
#[derive(Debug)]
pub struct OnceMap<K, V> {
    slots: Mutex<HashMap<K, Slot<V>>>,
    cv: Condvar,
}

impl<K: Eq + Hash + Clone, V: Clone> Default for OnceMap<K, V> {
    fn default() -> Self {
        Self::new()
    }
}

impl<K: Eq + Hash + Clone, V: Clone> OnceMap<K, V> {
    pub fn new() -> Self {
        Self { slots: Mutex::new(HashMap::new()), cv: Condvar::new() }
    }

    /// Number of *ready* values (in-flight builds are not counted).
    pub fn len(&self) -> usize {
        self.slots
            .lock()
            .unwrap()
            .values()
            .filter(|s| matches!(s, Slot::Ready(_)))
            .count()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// The ready value for `key`, if any (never waits on a builder).
    /// Borrowed-key lookup (`&str` against `String` keys) so the hit
    /// path allocates nothing.
    pub fn get<Q>(&self, key: &Q) -> Option<V>
    where
        K: Borrow<Q>,
        Q: Hash + Eq + ?Sized,
    {
        match self.slots.lock().unwrap().get(key) {
            Some(Slot::Ready(v)) => Some(v.clone()),
            _ => None,
        }
    }

    /// Fetch `key`, building it with `build` on first access. At most
    /// one build runs per key at a time; concurrent callers park until
    /// it lands. On `Err` the builder gets the error and the key is
    /// released — a parked waiter then claims the build and retries
    /// with its own closure (errors are never cached). The key is only
    /// cloned-to-owned on the build path; hits borrow it.
    pub fn get_or_try_build<Q>(&self, key: &Q, build: impl FnOnce() -> Result<V>) -> Result<V>
    where
        K: Borrow<Q>,
        Q: Hash + Eq + ToOwned<Owned = K> + ?Sized,
    {
        {
            let mut slots = self.slots.lock().unwrap();
            loop {
                match slots.get(key) {
                    Some(Slot::Ready(v)) => return Ok(v.clone()),
                    Some(Slot::Building) => {
                        slots = self.cv.wait(slots).unwrap();
                        // Re-inspect: the build finished (Ready), failed
                        // (absent — claim it below), or is still going.
                    }
                    None => {
                        slots.insert(key.to_owned(), Slot::Building);
                        break; // we are the builder
                    }
                }
            }
        }

        // Build outside the lock. The guard un-claims the key if `build`
        // panics, so waiters fail over to rebuilding instead of hanging.
        struct Unclaim<'a, K: Eq + Hash + Clone, V: Clone, Q: Hash + Eq + ?Sized>
        where
            K: Borrow<Q>,
        {
            map: &'a OnceMap<K, V>,
            key: &'a Q,
            armed: bool,
        }
        impl<K: Eq + Hash + Clone, V: Clone, Q: Hash + Eq + ?Sized> Drop for Unclaim<'_, K, V, Q>
        where
            K: Borrow<Q>,
        {
            fn drop(&mut self) {
                if self.armed {
                    self.map.slots.lock().unwrap().remove(self.key);
                    self.map.cv.notify_all();
                }
            }
        }
        let mut guard = Unclaim { map: self, key, armed: true };
        let built = build();
        match built {
            Ok(v) => {
                guard.armed = false;
                let mut slots = self.slots.lock().unwrap();
                slots.insert(key.to_owned(), Slot::Ready(v.clone()));
                drop(slots);
                self.cv.notify_all();
                Ok(v)
            }
            // The guard's drop releases the key and wakes waiters.
            Err(e) => Err(e),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::{Arc, Barrier};

    #[test]
    fn builds_once_under_race() {
        let map = Arc::new(OnceMap::<String, usize>::new());
        let builds = Arc::new(AtomicUsize::new(0));
        let start = Arc::new(Barrier::new(8));
        let handles: Vec<_> = (0..8)
            .map(|_| {
                let map = Arc::clone(&map);
                let builds = Arc::clone(&builds);
                let start = Arc::clone(&start);
                std::thread::spawn(move || {
                    start.wait(); // all threads miss "simultaneously"
                    map.get_or_try_build("stage_1.hlo", || {
                        builds.fetch_add(1, Ordering::SeqCst);
                        // A slow compile widens the race window.
                        std::thread::sleep(std::time::Duration::from_millis(20));
                        Ok(42usize)
                    })
                    .unwrap()
                })
            })
            .collect();
        for h in handles {
            assert_eq!(h.join().unwrap(), 42);
        }
        assert_eq!(builds.load(Ordering::SeqCst), 1, "artifact compiled more than once");
        assert_eq!(map.len(), 1);
    }

    #[test]
    fn distinct_keys_build_independently() {
        let map = OnceMap::<usize, usize>::new();
        for k in 0..10 {
            assert_eq!(map.get_or_try_build(&k, || Ok(k * k)).unwrap(), k * k);
        }
        assert_eq!(map.len(), 10);
        assert_eq!(map.get(&3), Some(9));
        assert_eq!(map.get(&99), None);
    }

    #[test]
    fn failed_build_releases_key_for_retry() {
        let map = OnceMap::<u8, u8>::new();
        assert!(map.get_or_try_build(&1, || Err(anyhow::anyhow!("boom"))).is_err());
        assert_eq!(map.len(), 0, "errors must not be cached");
        assert_eq!(map.get_or_try_build(&1, || Ok(7)).unwrap(), 7);
    }

    #[test]
    fn waiters_survive_builder_failure() {
        let map = Arc::new(OnceMap::<u8, u8>::new());
        let attempts = Arc::new(AtomicUsize::new(0));
        let handles: Vec<_> = (0..6)
            .map(|_| {
                let map = Arc::clone(&map);
                let attempts = Arc::clone(&attempts);
                std::thread::spawn(move || {
                    map.get_or_try_build(&9, || {
                        let n = attempts.fetch_add(1, Ordering::SeqCst);
                        std::thread::sleep(std::time::Duration::from_millis(5));
                        if n == 0 {
                            Err(anyhow::anyhow!("first build fails"))
                        } else {
                            Ok(3)
                        }
                    })
                })
            })
            .collect();
        let ok = handles.into_iter().filter_map(|h| h.join().unwrap().ok());
        // At least one caller (the retrier) must see the value; nobody hangs.
        assert!(ok.count() >= 1);
        assert_eq!(map.get(&9), Some(3));
    }

    #[test]
    fn builder_panic_does_not_wedge_waiters() {
        let map = Arc::new(OnceMap::<u8, u8>::new());
        let start = Arc::new(Barrier::new(2));
        let m2 = Arc::clone(&map);
        let s2 = Arc::clone(&start);
        let panicker = std::thread::spawn(move || {
            let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                m2.get_or_try_build(&5, || {
                    s2.wait(); // let the waiter queue up behind us
                    std::thread::sleep(std::time::Duration::from_millis(10));
                    panic!("compile crashed")
                })
            }));
        });
        start.wait();
        // This call either waits out the panicking builder and then
        // builds itself, or arrives after the key was released.
        let v = map.get_or_try_build(&5, || Ok(11)).unwrap();
        assert_eq!(v, 11);
        panicker.join().unwrap();
    }
}
