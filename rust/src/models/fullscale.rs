//! Paper-scale analytic stage tables: FMACs and activation sizes for
//! VGG-16/19 and ResNet-50/101 at 224×224 / ImageNet widths.
//!
//! Stage granularity matches §III-A (and our exported artifacts): one
//! stage per conv/fc layer for VGG (pool fused into the closing conv of
//! a block), one per res-unit for ResNet (stem and head are stages).
//! The FMAC counts agree with the usual published figures (VGG-16 ≈
//! 15.5 GFMACs, ResNet-50 ≈ 4.1 GFMACs — see the `totals_match_published`
//! test), which is what the paper's `T = w·Q/F` device model consumes.

/// One decoupling stage of the full-scale model.
#[derive(Debug, Clone)]
pub struct FullStage {
    pub name: String,
    /// Multiply-accumulate operations in this stage.
    pub fmacs: u64,
    /// Elements of the stage's output activation (batch 1).
    pub out_elems: u64,
}

#[derive(Debug, Clone)]
pub struct FullModel {
    pub name: &'static str,
    pub input_elems: u64,
    /// 8-bit RGB input file size (the paper's raw upload), bytes.
    pub input_rgb_bytes: u64,
    pub stages: Vec<FullStage>,
}

impl FullModel {
    pub fn total_fmacs(&self) -> u64 {
        self.stages.iter().map(|s| s.fmacs).sum()
    }

    /// Cumulative FMACs through stage i (1-based); i=0 → 0.
    pub fn fmacs_to(&self, i: usize) -> u64 {
        self.stages[..i].iter().map(|s| s.fmacs).sum()
    }

    /// FMACs of stages i+1..N.
    pub fn fmacs_from(&self, i: usize) -> u64 {
        self.stages[i..].iter().map(|s| s.fmacs).sum()
    }
}

/// Conv stage computing on an `hw`×`hw` grid with a `k`×`k` kernel.
fn conv(name: &str, hw: u64, k: u64, cin: u64, cout: u64) -> FullStage {
    FullStage {
        name: name.to_string(),
        fmacs: hw * hw * k * k * cin * cout,
        out_elems: hw * hw * cout,
    }
}

fn fc(name: &str, nin: u64, nout: u64) -> FullStage {
    FullStage { name: name.to_string(), fmacs: nin * nout, out_elems: nout }
}

fn vgg(name: &'static str, blocks: &[(u64, u64)]) -> FullModel {
    let mut stages = Vec::new();
    let mut hw = 224u64;
    let mut cin = 3u64;
    for (bi, &(convs, ch)) in blocks.iter().enumerate() {
        for ci in 0..convs {
            let last = ci == convs - 1;
            // conv computes at `hw`; the closing pool shrinks the
            // activation that would be shipped across the cut.
            let mut s = conv(
                &format!("conv{}_{}{}", bi + 1, ci + 1, if last { "_pool" } else { "" }),
                hw,
                3,
                cin,
                ch,
            );
            if last {
                hw /= 2;
                s.out_elems = hw * hw * ch;
            }
            stages.push(s);
            cin = ch;
        }
    }
    // 7·7·512 = 25088 → 4096 → 4096 → 1000
    stages.push(fc("fc1", hw * hw * cin, 4096));
    stages.push(fc("fc2", 4096, 4096));
    stages.push(fc("logits", 4096, 1000));
    FullModel {
        name,
        input_elems: 224 * 224 * 3,
        input_rgb_bytes: 224 * 224 * 3,
        stages,
    }
}

fn resnet(name: &'static str, groups: &[(u64, u64, u64)]) -> FullModel {
    let mut stages = Vec::new();
    // Stem: 7x7/2 conv (112²·64) + 3x3/2 maxpool → 56²·64.
    stages.push(FullStage {
        name: "stem".into(),
        fmacs: 112 * 112 * 7 * 7 * 3 * 64,
        out_elems: 56 * 56 * 64,
    });
    let mut hw = 56u64;
    let mut cin = 64u64;
    for (gi, &(units, width, first_stride)) in groups.iter().enumerate() {
        let cout = width * 4;
        for ui in 0..units {
            let stride = if ui == 0 { first_stride } else { 1 };
            let out_hw = hw / stride;
            let project = stride != 1 || cin != cout;
            let mut fmacs = hw * hw * cin * width; // 1x1 (computed pre-stride)
            fmacs += out_hw * out_hw * 9 * width * width; // 3x3 (strided)
            fmacs += out_hw * out_hw * width * cout; // 1x1 expand
            if project {
                fmacs += out_hw * out_hw * cin * cout;
            }
            stages.push(FullStage {
                name: format!("unit{}_{}", gi + 1, ui + 1),
                fmacs,
                out_elems: out_hw * out_hw * cout,
            });
            cin = cout;
            hw = out_hw;
        }
    }
    stages.push(fc("head", cin, 1000));
    FullModel { name, input_elems: 224 * 224 * 3, input_rgb_bytes: 224 * 224 * 3, stages }
}

/// Paper-scale stage table by model name (same names as the manifest).
pub fn fullscale_stages(model: &str) -> Option<FullModel> {
    match model {
        "vgg16" => Some(vgg("vgg16", &[(2, 64), (2, 128), (3, 256), (3, 512), (3, 512)])),
        "vgg19" => Some(vgg("vgg19", &[(2, 64), (2, 128), (4, 256), (4, 512), (4, 512)])),
        "resnet50" => {
            Some(resnet("resnet50", &[(3, 64, 1), (4, 128, 2), (6, 256, 2), (3, 512, 2)]))
        }
        "resnet101" => {
            Some(resnet("resnet101", &[(3, 64, 1), (4, 128, 2), (23, 256, 2), (3, 512, 2)]))
        }
        // tinyconv has no paper-scale twin; simulation uses scaled FMACs.
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn totals_match_published() {
        // Published FMAC figures (±5%): VGG16 15.5G, VGG19 19.6G,
        // ResNet50 4.1G, ResNet101 7.8G.
        let cases = [
            ("vgg16", 15.5e9),
            ("vgg19", 19.6e9),
            ("resnet50", 4.1e9),
            ("resnet101", 7.8e9),
        ];
        for (name, want) in cases {
            let m = fullscale_stages(name).unwrap();
            let got = m.total_fmacs() as f64;
            let ratio = got / want;
            assert!(
                (0.90..=1.10).contains(&ratio),
                "{name}: {:.2}G vs published {:.2}G",
                got / 1e9,
                want / 1e9
            );
        }
    }

    #[test]
    fn stage_counts_match_decoupling_points() {
        assert_eq!(fullscale_stages("vgg16").unwrap().stages.len(), 16);
        assert_eq!(fullscale_stages("vgg19").unwrap().stages.len(), 19);
        assert_eq!(fullscale_stages("resnet50").unwrap().stages.len(), 18);
        assert_eq!(fullscale_stages("resnet101").unwrap().stages.len(), 35);
    }

    #[test]
    fn amplification_exists_in_early_layers() {
        // Paper Fig. 2: early in-layer features dwarf the 8-bit input.
        for name in ["vgg16", "resnet50"] {
            let m = fullscale_stages(name).unwrap();
            let amp = m.stages[0].out_elems as f64 * 4.0 / m.input_rgb_bytes as f64;
            assert!(amp > 5.0, "{name}: amplification {amp}");
        }
    }

    #[test]
    fn cumulative_splits_are_consistent() {
        let m = fullscale_stages("resnet50").unwrap();
        for i in 0..=m.stages.len() {
            assert_eq!(m.fmacs_to(i) + m.fmacs_from(i), m.total_fmacs());
        }
    }

    #[test]
    fn unknown_model_is_none() {
        assert!(fullscale_stages("tinyconv").is_none());
    }
}
