//! Model metadata: the paper-scale (224×224, full-width) analytic stage
//! tables used by the latency simulation, alongside the scaled execution
//! models described by the artifact manifest.
//!
//! The paper's own simulation experiments (§IV-A) estimate device time
//! as `T = w · Q(x)/F` from per-layer FMAC counts `Q`; [`fullscale`]
//! reconstructs those counts for VGG-16/19 and ResNet-50/101 exactly as
//! published (224×224 inputs, ImageNet widths), stage-aligned with our
//! scaled executables so measured compression ratios can be projected
//! onto paper-scale feature sizes.

pub mod fullscale;

pub use fullscale::{fullscale_stages, FullStage, FullModel};
