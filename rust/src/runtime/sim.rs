//! Deterministic simulated inference backend.
//!
//! The PJRT path needs exported artifacts and an `xla_extension`
//! runtime; this backend needs neither — it computes every stage with a
//! fixed pseudo-conv mixing function on the host, so the serving stack
//! (executor pool, micro-batch engine, cloud server, benches, tests)
//! can run end to end in any build. It is *not* a model: it is a
//! deterministic stand-in with the same shapes, the same calling
//! conventions and a tunable compute cost, which is exactly what the
//! concurrency/batching work needs to measure scheduling behavior
//! without GPU/PJRT variance.
//!
//! Determinism contract (load-bearing for the batching engine's
//! byte-identity property): a stage's output depends only on the stage
//! metadata and its input buffer, every float op happens in a fixed
//! order, and running a sample alone or inside a stacked batch is the
//! same code path per sample. Two executions of the same request are
//! bit-for-bit equal.

use std::collections::HashSet;
use std::path::PathBuf;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::{anyhow, Result};

use super::artifacts::{CodecArtifacts, Manifest, ModelManifest, StageManifest};

/// Default per-output-element fan-in (multiply-accumulates). The knob
/// that sets how much CPU a simulated stage burns.
pub const DEFAULT_FANIN: usize = 64;

/// A named device-tier compute/uplink profile for three-tier sims: the
/// fleet below an edge site is heterogeneous, and the multi-hop planner
/// (`ilp::MultiHopInstance`) wants each hop's compute rate and
/// bandwidth in the same units as the calibrated tables. `tier_scale`
/// multiplies the profiled per-stage edge latency (2.0 = this device
/// runs a stage twice as slowly as the calibrated edge device);
/// `fanin` is the matching sim-backend cost so wall-clock behavior
/// tracks the plan's model; `uplink_bps` is the device→edge link.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DeviceClass {
    pub name: &'static str,
    /// Sim-backend fan-in for executors playing this device.
    pub fanin: usize,
    /// Per-stage latency multiplier vs the calibrated edge device.
    pub tier_scale: f64,
    /// Device→edge uplink, bytes/sec.
    pub uplink_bps: f64,
}

impl DeviceClass {
    /// Look up a profile by name (CLI surface: `--device-class`).
    pub fn by_name(name: &str) -> Option<&'static DeviceClass> {
        DEVICE_CLASSES.iter().find(|d| d.name == name)
    }
}

/// The stock three-tier fleet: a strong phone close to edge-device
/// parity, a weak phone at ~4× stage cost on a constrained uplink, and
/// an embedded sensor node that can barely run head stages at all.
/// Scales are relative to the calibrated tables, so they compose with
/// any model's profile.
pub const DEVICE_CLASSES: &[DeviceClass] = &[
    DeviceClass { name: "strong-phone", fanin: 96, tier_scale: 1.5, uplink_bps: 2_000_000.0 },
    DeviceClass { name: "weak-phone", fanin: 256, tier_scale: 4.0, uplink_bps: 400_000.0 },
    DeviceClass { name: "embedded", fanin: 1024, tier_scale: 16.0, uplink_bps: 120_000.0 },
];

/// Host-side simulated compute engine. Cheap to construct; holds only
/// the fan-in knob and the set of "warmed" artifacts (so
/// `cached_count` parity with the PJRT compile cache holds in stats).
#[derive(Debug)]
pub struct SimBackend {
    fanin: usize,
    warmed: Mutex<HashSet<String>>,
    /// Lock-free mirror of `warmed.len()`; shared (`Arc`) so stats
    /// endpoints can read it without any executor lock.
    warmed_len: Arc<AtomicUsize>,
}

/// Per-stage seed for the mixing function (Knuth multiplicative hash).
#[inline]
fn stage_seed(stage: &StageManifest) -> u64 {
    (stage.index as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15) ^ 0xA5A5_5A5A
}

/// Per-output-element base hash.
#[inline]
fn out_base(sseed: u64, j: usize) -> u64 {
    (j as u64).wrapping_mul(0x2545_F491_4F6C_DD1D) ^ sseed
}

/// One tap: input index + weight in [-1, 1) for `(j, k)`. The single
/// source of truth for the mixing function — both the single-sample
/// and the batched kernel derive taps here, so they cannot drift.
#[inline]
fn tap(jbase: u64, k: usize, n_in: usize) -> (usize, f32) {
    let h = jbase.wrapping_add((k as u64).wrapping_mul(0x9E37_79B9));
    let idx = (h % n_in as u64) as usize;
    let w = ((h >> 40) & 0xFFFF) as f32 / 32768.0 - 1.0;
    (idx, w)
}

/// Fan-in normalization + leaky-ReLU, shared by both kernels.
#[inline]
fn finalize(acc: f32, inv: f32) -> f32 {
    let a = acc * inv;
    if a > 0.0 {
        a
    } else {
        0.1 * a
    }
}

impl SimBackend {
    pub fn new(fanin: usize) -> Self {
        Self {
            fanin: fanin.max(1),
            warmed: Mutex::new(HashSet::new()),
            warmed_len: Arc::new(AtomicUsize::new(0)),
        }
    }

    /// Shared handle to the warm-artifact count (lock-free reads).
    pub fn warmed_handle(&self) -> Arc<AtomicUsize> {
        Arc::clone(&self.warmed_len)
    }

    pub fn fanin(&self) -> usize {
        self.fanin
    }

    /// Artifacts "compiled" so far (first-touch set, mirrors the PJRT
    /// compile cache for the stats endpoint). Lock-free — safe to call
    /// from a stats path while every shard is mid-inference.
    pub fn warmed_count(&self) -> usize {
        self.warmed_len.load(Ordering::Relaxed)
    }

    pub fn warm(&self, artifact: &str) {
        let mut w = self.warmed.lock().unwrap();
        if !w.contains(artifact) {
            w.insert(artifact.to_string());
            self.warmed_len.store(w.len(), Ordering::Relaxed);
        }
    }

    /// One stage forward: `input` (flat, `in_shape` elements) →
    /// `out` (flat, `out_shape` elements). Pseudo-conv: every output
    /// element accumulates `fanin` strided input taps against a
    /// deterministic weight derived from (stage, output, tap) indices,
    /// normalized by the fan-in, then a leaky-ReLU keeps magnitudes
    /// bounded across deep chains.
    pub fn stage_into(&self, stage: &StageManifest, input: &[f32], out: &mut Vec<f32>) -> Result<()> {
        let n_in = input.len();
        let n_out: usize = stage.out_shape.iter().product();
        if n_in == 0 {
            return Err(anyhow!("sim stage {} on empty input", stage.index));
        }
        self.warm(&stage.artifact);
        let inv = 1.0f32 / self.fanin as f32;
        let sseed = stage_seed(stage);
        out.clear();
        out.reserve(n_out);
        for j in 0..n_out {
            let jbase = out_base(sseed, j);
            let mut acc = 0.0f32;
            for k in 0..self.fanin {
                let (idx, w) = tap(jbase, k, n_in);
                acc += input[idx] * w;
            }
            out.push(finalize(acc, inv));
        }
        Ok(())
    }

    /// One stage forward for a whole stacked batch, amortizing the tap
    /// and weight derivation (the expensive per-`(j,k)` hash) across
    /// every sample — the sim analog of a batched kernel re-using its
    /// loaded weights. Per-sample results are **bit-identical** to
    /// [`SimBackend::stage_into`]: each sample's accumulator sees the
    /// same addends in the same `k` order, then the same finalize.
    /// `stacked` is the reusable staging buffer (`B × out_elems`);
    /// each sample's `Vec` is replaced in place by its stage output.
    pub fn stage_batch_into(
        &self,
        stage: &StageManifest,
        samples: &mut [Vec<f32>],
        stacked: &mut Vec<f32>,
    ) -> Result<()> {
        let b = samples.len();
        if b == 0 {
            return Ok(());
        }
        let n_in = samples[0].len();
        for (s, sample) in samples.iter().enumerate() {
            if sample.len() != n_in || n_in == 0 {
                return Err(anyhow!("sim batch stage {}: sample {s} length mismatch", stage.index));
            }
        }
        let n_out: usize = stage.out_shape.iter().product();
        self.warm(&stage.artifact);
        let inv = 1.0f32 / self.fanin as f32;
        let sseed = stage_seed(stage);
        stacked.clear();
        stacked.resize(b * n_out, 0.0);
        for j in 0..n_out {
            let jbase = out_base(sseed, j);
            for k in 0..self.fanin {
                let (idx, w) = tap(jbase, k, n_in);
                // One tap derivation, B fused multiply-adds.
                for (s, sample) in samples.iter().enumerate() {
                    stacked[s * n_out + j] += sample[idx] * w;
                }
            }
        }
        for (s, sample) in samples.iter_mut().enumerate() {
            sample.clear();
            sample.extend(
                stacked[s * n_out..(s + 1) * n_out].iter().map(|&acc| finalize(acc, inv)),
            );
        }
        Ok(())
    }

    /// [`SimBackend::stage_batch_into`] over a batch whose samples may
    /// have **heterogeneous lengths** — the padded leading geometry of
    /// a cross-model batch, where every member shares the stage's index
    /// and output geometry but tail-start activations differ in size.
    /// Samples are grouped by length and each group runs the batched
    /// kernel (taps depend on `n_in`, so amortization happens within a
    /// length group); per-sample results stay **bit-identical** to
    /// [`SimBackend::stage_into`] — each sample's accumulator sees the
    /// same addends in the same `k` order, then the same finalize.
    pub fn stage_batch_padded_into(
        &self,
        stage: &StageManifest,
        samples: &mut [Vec<f32>],
        stacked: &mut Vec<f32>,
    ) -> Result<()> {
        let b = samples.len();
        if b == 0 {
            return Ok(());
        }
        let n0 = samples[0].len();
        if samples.iter().all(|s| s.len() == n0) {
            // Uniform batch: the plain stacked kernel, no grouping cost.
            return self.stage_batch_into(stage, samples, stacked);
        }
        let mut lens: Vec<usize> = samples.iter().map(Vec::len).collect();
        lens.sort_unstable();
        lens.dedup();
        if lens.first() == Some(&0) {
            return Err(anyhow!("sim padded batch stage {}: empty sample", stage.index));
        }
        // Member indices per length group, computed once — the tap
        // loops below touch only their group's samples instead of
        // re-testing every sample's length per tap.
        let groups: Vec<(usize, Vec<usize>)> = lens
            .iter()
            .map(|&n_in| {
                let idxs = samples
                    .iter()
                    .enumerate()
                    .filter(|(_, s)| s.len() == n_in)
                    .map(|(s, _)| s)
                    .collect();
                (n_in, idxs)
            })
            .collect();
        let n_out: usize = stage.out_shape.iter().product();
        self.warm(&stage.artifact);
        let inv = 1.0f32 / self.fanin as f32;
        let sseed = stage_seed(stage);
        stacked.clear();
        stacked.resize(b * n_out, 0.0);
        for (n_in, members) in &groups {
            for j in 0..n_out {
                let jbase = out_base(sseed, j);
                for k in 0..self.fanin {
                    let (idx, w) = tap(jbase, k, *n_in);
                    // One tap derivation per length group, one fused
                    // multiply-add per member of that group.
                    for &s in members {
                        stacked[s * n_out + j] += samples[s][idx] * w;
                    }
                }
            }
        }
        for (s, sample) in samples.iter_mut().enumerate() {
            sample.clear();
            sample.extend(
                stacked[s * n_out..(s + 1) * n_out].iter().map(|&acc| finalize(acc, inv)),
            );
        }
        Ok(())
    }

    /// Run stages `from..=to` (1-based, inclusive) of `model` over a
    /// flat buffer, ping-ponging between `cur` and `tmp`; the final
    /// activation ends in `cur`. Both buffers keep their capacity, so a
    /// warm caller performs no allocation.
    pub fn run_chain_into(
        &self,
        model: &ModelManifest,
        from: usize,
        to: usize,
        cur: &mut Vec<f32>,
        tmp: &mut Vec<f32>,
    ) -> Result<()> {
        for i in from..=to {
            let stage = model
                .stages
                .get(i - 1)
                .ok_or_else(|| anyhow!("{} has {} stages, asked {i}", model.name, model.stages.len()))?;
            let expect: usize = stage.in_shape.iter().product();
            if cur.len() != expect {
                return Err(anyhow!(
                    "{} stage {i} expects {} elements, got {}",
                    model.name,
                    expect,
                    cur.len()
                ));
            }
            self.stage_into(stage, cur, tmp)?;
            std::mem::swap(cur, tmp);
        }
        Ok(())
    }
}

/// Build one sim model from `(stage name, in_shape, out_shape)` specs,
/// registering its quant/dequant codec geometries as it goes.
fn sim_model(
    name: &str,
    specs: &[(&str, Vec<usize>, Vec<usize>)],
    quant: &mut std::collections::BTreeMap<usize, String>,
    dequant: &mut std::collections::BTreeMap<Vec<usize>, String>,
) -> ModelManifest {
    let mut stages = Vec::new();
    for (idx, (stage_name, in_shape, out_shape)) in specs.iter().enumerate() {
        let out_elems: usize = out_shape.iter().product();
        quant.insert(out_elems, format!("sim_quant_{out_elems}.hlo.txt"));
        dequant.insert(out_shape.clone(), format!("sim_dequant_{out_elems}.hlo.txt"));
        stages.push(StageManifest {
            index: idx,
            name: stage_name.to_string(),
            artifact: format!("{name}_stage_{idx:02}.hlo.txt"),
            in_shape: in_shape.clone(),
            out_shape: out_shape.clone(),
            out_elems,
            // Rough pseudo-conv cost, only consumed by the ILP tables.
            fmacs_scaled: (out_elems * DEFAULT_FANIN) as u64,
        });
    }
    let num_classes: usize = specs.last().map(|(_, _, o)| o.iter().product()).unwrap_or(0);
    ModelManifest {
        name: name.to_string(),
        input_shape: specs.first().map(|(_, i, _)| i.clone()).unwrap_or_default(),
        num_classes,
        full_artifact: format!("{name}_full.hlo.txt"),
        stages,
    }
}

/// A synthetic manifest for the sim backend: one model (`simnet`, four
/// stages, 16 classes) with internally consistent shapes and codec
/// entries for every stage geometry. Mirrors what `make artifacts`
/// exports, minus the artifact files nobody reads in sim mode.
pub fn sim_manifest() -> Manifest {
    let mut quant = std::collections::BTreeMap::new();
    let mut dequant = std::collections::BTreeMap::new();
    let model = sim_model(
        "simnet",
        &[
            ("conv1", vec![1, 16, 16, 3], vec![1, 16, 16, 16]),
            ("conv2", vec![1, 16, 16, 16], vec![1, 8, 8, 32]),
            ("conv3", vec![1, 8, 8, 32], vec![1, 4, 4, 64]),
            ("head", vec![1, 4, 4, 64], vec![1, 16]),
        ],
        &mut quant,
        &mut dequant,
    );
    Manifest {
        dir: PathBuf::from("sim"),
        c_max: 8,
        num_classes: 16,
        source_digest: "sim-backend".to_string(),
        models: vec![model],
        codecs: CodecArtifacts { quant, dequant },
    }
}

/// The "retrained" successor to [`sim_manifest`] for hot-swap tests:
/// the *same* `simnet` serving contract (input `[1,16,16,3]`, 16
/// classes) with wider internal stages. The sim backend's stage kernel
/// is a pure function of stage index and flat in/out element counts,
/// so widening the hidden shapes is what makes v2's logits genuinely
/// differ bit-wise from v1's — renaming stages alone would not (and a
/// swap test built on renames would assert nothing).
pub fn sim_manifest_v2() -> Manifest {
    let mut quant = std::collections::BTreeMap::new();
    let mut dequant = std::collections::BTreeMap::new();
    let model = sim_model(
        "simnet",
        &[
            ("conv1", vec![1, 16, 16, 3], vec![1, 16, 16, 24]),
            ("conv2", vec![1, 16, 16, 24], vec![1, 8, 8, 48]),
            ("conv3", vec![1, 8, 8, 48], vec![1, 4, 4, 96]),
            ("head", vec![1, 4, 4, 96], vec![1, 16]),
        ],
        &mut quant,
        &mut dequant,
    );
    Manifest {
        dir: PathBuf::from("sim"),
        c_max: 8,
        num_classes: 16,
        source_digest: "sim-backend-v2".to_string(),
        models: vec![model],
        codecs: CodecArtifacts { quant, dequant },
    }
}

/// A synthetic **mixed-fleet** manifest: `fleet0..fleet{n-1}` are
/// heterogeneous edge halves (each stage-1 input geometry differs)
/// sharing one cloud tail — their tails from stage 2 onward have
/// *identical* [`TailSignature`](super::artifacts::TailSignature)s, the
/// cross-model coalescing case — plus `padnet`, whose stage-3 tail
/// matches the fleet's only **up to the padded leading geometry**
/// (smaller stage-3 input, same suffix): the pad-and-stack case.
/// `fleet0` is geometry-identical to [`sim_manifest`]'s `simnet`, so
/// solo references computed against either agree bit-for-bit.
pub fn sim_manifest_fleet(n: usize) -> Manifest {
    let mut quant = std::collections::BTreeMap::new();
    let mut dequant = std::collections::BTreeMap::new();
    // Per-model edge geometry: distinct stage-1 channel counts, all
    // converging on the shared [1,16,16,16] stage-1 output.
    let channels = [3usize, 4, 6, 8, 12, 16, 24, 32];
    let mut models = Vec::new();
    for i in 0..n.max(1) {
        let ch = channels[i % channels.len()] + 32 * (i / channels.len());
        models.push(sim_model(
            &format!("fleet{i}"),
            &[
                ("conv1", vec![1, 16, 16, ch], vec![1, 16, 16, 16]),
                ("conv2", vec![1, 16, 16, 16], vec![1, 8, 8, 32]),
                ("conv3", vec![1, 8, 8, 32], vec![1, 4, 4, 64]),
                ("head", vec![1, 4, 4, 64], vec![1, 16]),
            ],
            &mut quant,
            &mut dequant,
        ));
    }
    models.push(sim_model(
        "padnet",
        &[
            ("conv1", vec![1, 16, 16, 3], vec![1, 16, 16, 8]),
            ("conv2", vec![1, 16, 16, 8], vec![1, 6, 6, 32]),
            ("conv3", vec![1, 6, 6, 32], vec![1, 4, 4, 64]),
            ("head", vec![1, 4, 4, 64], vec![1, 16]),
        ],
        &mut quant,
        &mut dequant,
    ));
    Manifest {
        dir: PathBuf::from("sim"),
        c_max: 8,
        num_classes: 16,
        source_digest: "sim-backend-fleet".to_string(),
        models,
        codecs: CodecArtifacts { quant, dequant },
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn input_for(m: &ModelManifest, seed: u64) -> Vec<f32> {
        let n: usize = m.input_shape.iter().product();
        (0..n)
            .map(|i| {
                let h = (i as u64).wrapping_add(seed).wrapping_mul(0x9E37_79B9_7F4A_7C15);
                ((h >> 40) & 0xFFFF) as f32 / 6553.6
            })
            .collect()
    }

    #[test]
    fn manifest_shapes_chain() {
        let m = sim_manifest();
        let model = m.model("simnet").unwrap();
        assert_eq!(model.input_shape, model.stages[0].in_shape);
        for w in model.stages.windows(2) {
            assert_eq!(w[0].out_shape, w[1].in_shape);
        }
        for s in &model.stages {
            assert!(m.codecs.quant.contains_key(&s.out_elems));
            assert!(m.codecs.dequant.contains_key(&s.out_shape));
        }
        assert_eq!(m.model_id("simnet"), Some(0));
    }

    #[test]
    fn stages_are_deterministic() {
        let m = sim_manifest();
        let model = m.model("simnet").unwrap();
        let sim = SimBackend::new(16);
        let x = input_for(model, 7);
        let (mut a, mut t1) = (x.clone(), Vec::new());
        let (mut b, mut t2) = (x, Vec::new());
        sim.run_chain_into(model, 1, 4, &mut a, &mut t1).unwrap();
        sim.run_chain_into(model, 1, 4, &mut b, &mut t2).unwrap();
        assert_eq!(a.len(), 16);
        assert!(a.iter().zip(&b).all(|(p, q)| p.to_bits() == q.to_bits()));
        // Outputs stay finite and non-degenerate through the chain.
        assert!(a.iter().all(|v| v.is_finite()));
        assert!(a.iter().any(|v| *v != 0.0));
    }

    #[test]
    fn distinct_inputs_distinct_outputs() {
        let m = sim_manifest();
        let model = m.model("simnet").unwrap();
        let sim = SimBackend::new(16);
        let (mut a, mut ta) = (input_for(model, 1), Vec::new());
        let (mut b, mut tb) = (input_for(model, 2), Vec::new());
        sim.run_chain_into(model, 1, 4, &mut a, &mut ta).unwrap();
        sim.run_chain_into(model, 1, 4, &mut b, &mut tb).unwrap();
        assert_ne!(a, b);
    }

    #[test]
    fn batch_kernel_bit_identical_to_single_sample() {
        let m = sim_manifest();
        let model = m.model("simnet").unwrap();
        let sim = SimBackend::new(16);
        let stage = &model.stages[1];
        let n_in: usize = stage.in_shape.iter().product();
        let mut samples: Vec<Vec<f32>> = (0..5)
            .map(|s| {
                (0..n_in)
                    .map(|i| {
                        let h = ((i + s * 101) as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
                        ((h >> 40) & 0xFFFF) as f32 / 3276.8 - 5.0
                    })
                    .collect()
            })
            .collect();
        let singles: Vec<Vec<f32>> = samples
            .iter()
            .map(|x| {
                let mut out = Vec::new();
                sim.stage_into(stage, x, &mut out).unwrap();
                out
            })
            .collect();
        let mut stacked = Vec::new();
        sim.stage_batch_into(stage, &mut samples, &mut stacked).unwrap();
        for (s, (batched, single)) in samples.iter().zip(&singles).enumerate() {
            assert_eq!(batched.len(), single.len());
            assert!(
                batched.iter().zip(single).all(|(a, b)| a.to_bits() == b.to_bits()),
                "sample {s}: batched kernel diverged from single-sample kernel"
            );
        }
    }

    #[test]
    fn padded_batch_kernel_bit_identical_per_length_group() {
        // One stage geometry, samples of two different input lengths
        // (the padded leading geometry of a cross-model batch): every
        // sample must match its own single-sample kernel bit-for-bit.
        let m = sim_manifest_fleet(2);
        let stage = &m.model("fleet0").unwrap().stages[2]; // conv3: 2048 -> 1024
        let pad_stage = &m.model("padnet").unwrap().stages[2]; // conv3: 1152 -> 1024
        assert_eq!(stage.out_elems, pad_stage.out_elems);
        let sim = SimBackend::new(16);
        let lens = [2048usize, 1152, 2048, 1152, 1152];
        let mut samples: Vec<Vec<f32>> = lens
            .iter()
            .enumerate()
            .map(|(s, &n)| {
                (0..n)
                    .map(|i| {
                        let h = ((i + s * 131) as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
                        ((h >> 40) & 0xFFFF) as f32 / 3276.8 - 5.0
                    })
                    .collect()
            })
            .collect();
        let singles: Vec<Vec<f32>> = samples
            .iter()
            .map(|x| {
                let mut out = Vec::new();
                sim.stage_into(stage, x, &mut out).unwrap();
                out
            })
            .collect();
        let mut stacked = Vec::new();
        sim.stage_batch_padded_into(stage, &mut samples, &mut stacked).unwrap();
        for (s, (batched, single)) in samples.iter().zip(&singles).enumerate() {
            assert_eq!(batched.len(), single.len());
            assert!(
                batched.iter().zip(single).all(|(a, b)| a.to_bits() == b.to_bits()),
                "sample {s} (len {}): padded kernel diverged from single-sample kernel",
                lens[s]
            );
        }
        // Uniform batches route through the plain stacked kernel and
        // agree with it exactly.
        let mut uniform: Vec<Vec<f32>> = (0..3).map(|_| samples_seed(stage, 9)).collect();
        let mut uniform2 = uniform.clone();
        let mut st2 = Vec::new();
        sim.stage_batch_padded_into(stage, &mut uniform, &mut stacked).unwrap();
        sim.stage_batch_into(stage, &mut uniform2, &mut st2).unwrap();
        assert_eq!(uniform, uniform2);
    }

    fn samples_seed(stage: &StageManifest, seed: usize) -> Vec<f32> {
        let n: usize = stage.in_shape.iter().product();
        (0..n)
            .map(|i| {
                let h = ((i + seed * 977) as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
                ((h >> 44) & 0xFFF) as f32 / 409.6
            })
            .collect()
    }

    #[test]
    fn fleet_manifest_shapes_chain_and_share_tails() {
        let m = sim_manifest_fleet(4);
        assert_eq!(m.models.len(), 5, "4 fleet models + padnet");
        for model in &m.models {
            assert_eq!(model.input_shape, model.stages[0].in_shape);
            for w in model.stages.windows(2) {
                assert_eq!(w[0].out_shape, w[1].in_shape, "model {}", model.name);
            }
            for s in &model.stages {
                assert!(m.codecs.quant.contains_key(&s.out_elems));
                assert!(m.codecs.dequant.contains_key(&s.out_shape));
            }
        }
        // fleet0 is geometry-identical to the single-model simnet.
        let simnet = sim_manifest();
        let (a, b) = (m.model("fleet0").unwrap(), simnet.model("simnet").unwrap());
        for (sa, sb) in a.stages.iter().zip(&b.stages) {
            assert_eq!((sa.in_shape.clone(), sa.out_shape.clone()), (sb.in_shape.clone(), sb.out_shape.clone()));
        }
    }

    #[test]
    fn batch_kernel_rejects_ragged_batch() {
        let m = sim_manifest();
        let model = m.model("simnet").unwrap();
        let sim = SimBackend::new(4);
        let n_in: usize = model.stages[0].in_shape.iter().product();
        let mut samples = vec![vec![1.0f32; n_in], vec![1.0f32; n_in - 1]];
        let mut stacked = Vec::new();
        assert!(sim.stage_batch_into(&model.stages[0], &mut samples, &mut stacked).is_err());
    }

    #[test]
    fn shape_mismatch_rejected() {
        let m = sim_manifest();
        let model = m.model("simnet").unwrap();
        let sim = SimBackend::new(4);
        let mut bad = vec![0.0f32; 5];
        let mut tmp = Vec::new();
        assert!(sim.run_chain_into(model, 1, 1, &mut bad, &mut tmp).is_err());
    }

    #[test]
    fn warm_set_counts_first_touch_only() {
        let m = sim_manifest();
        let model = m.model("simnet").unwrap();
        let sim = SimBackend::new(4);
        let mut x = input_for(model, 3);
        let mut tmp = Vec::new();
        sim.run_chain_into(model, 1, 2, &mut x, &mut tmp).unwrap();
        assert_eq!(sim.warmed_count(), 2);
        let mut y = input_for(model, 4);
        sim.run_chain_into(model, 1, 2, &mut y, &mut tmp).unwrap();
        assert_eq!(sim.warmed_count(), 2, "re-runs must not grow the warm set");
    }
}
