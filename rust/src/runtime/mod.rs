//! Inference runtime: load AOT HLO-text artifacts and execute them on
//! the request path (the L3 half of the AOT bridge; see DESIGN.md).
//!
//! * [`tensor`] — host-side `Tensor` (shape + contiguous f32 buffer);
//! * [`artifacts`] — `artifacts/manifest.json` parsing and path lookup;
//! * [`executor`] — one inference lane: a PJRT CPU client with a lazy,
//!   race-free compile cache (HLO text parsed and compiled on first
//!   use, exactly once even under concurrent misses), or the
//!   deterministic [`sim`] backend behind the same API; typed helpers
//!   for the stage / quant / dequant / full-model calling conventions
//!   plus the batched-tail entry point;
//! * [`sim`] — artifact-free deterministic host compute (serving
//!   benches, contention tests, PJRT-less builds);
//! * [`pool`] — [`pool::ExecutorPool`]: N independently-locked
//!   executors (one backend instance each), affinity-addressed, with
//!   per-shard utilization counters;
//! * [`batch`] — [`batch::BatchEngine`]: coalesces concurrent
//!   signature-compatible tail requests (across models — keying is
//!   structural, with a pad-and-stack path for matching suffixes
//!   behind a waste budget) into one executor acquisition behind a
//!   bounded gather window; lone requests bypass with zero added
//!   latency.
//!
//! Interchange is HLO *text*: jax ≥ 0.5 serialized protos use 64-bit ids
//! that xla_extension 0.5.1 rejects; the text parser reassigns ids.

pub mod artifacts;
pub mod batch;
pub mod executor;
pub mod pool;
pub mod sim;
pub mod tensor;

pub use artifacts::{CodecArtifacts, Manifest, ModelManifest, StageManifest, TailSignature};
pub use batch::{BatchConfig, BatchEngine, SignatureStat};
pub use executor::{Executor, SharedExecutor, StageOutput};
pub use pool::{ExecutorPool, HealthStats, ShardStats};
pub use sim::{DeviceClass, DEVICE_CLASSES};
pub use tensor::Tensor;
