//! PJRT runtime: load AOT HLO-text artifacts and execute them on the
//! request path (the L3 half of the AOT bridge; see DESIGN.md).
//!
//! * [`tensor`] — host-side `Tensor` (shape + contiguous f32 buffer);
//! * [`artifacts`] — `artifacts/manifest.json` parsing and path lookup;
//! * [`executor`] — a PJRT CPU client with a lazy compile cache: HLO text
//!   is parsed and compiled on first use, cached thereafter (one
//!   executable per stage / codec kernel), plus typed helpers for the
//!   stage / quant / dequant / full-model calling conventions.
//!
//! Interchange is HLO *text*: jax ≥ 0.5 serialized protos use 64-bit ids
//! that xla_extension 0.5.1 rejects; the text parser reassigns ids.

pub mod artifacts;
pub mod executor;
pub mod tensor;

pub use artifacts::{CodecArtifacts, Manifest, ModelManifest, StageManifest};
pub use executor::{Executor, SharedExecutor, StageOutput};
pub use tensor::Tensor;
