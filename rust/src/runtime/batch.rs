//! Micro-batched tail inference over an [`ExecutorPool`].
//!
//! Under load, many connections ask the cloud for the same work shape:
//! "finish `model` from stage `i`". The [`BatchEngine`] coalesces
//! concurrent requests whose tails share a **geometry signature** into
//! one executor acquisition: the first arriver becomes the batch
//! **leader** and waits a short gather window (or until the batch
//! fills); later arrivers join as **followers** and park until the
//! leader scatters their logits back. The quantization width `c` is
//! *not* part of the key — dequantization already happened natively on
//! the connection worker, so by the time a request reaches the engine
//! it is plain f32 activations and requests of any `c` batch together.
//!
//! Keying is **structural, not identity-based**: batches key on a
//! [`TailSignature`] class (tail-start geometry, per-stage shapes,
//! dtype) rather than on `(model, tail-start)`, so a mixed fleet whose
//! heterogeneous models share a cloud tail still fills batches — the
//! leader runs the gathered mixed-model set as one batched program
//! ([`Executor::run_tail_batch_multi`](super::executor::Executor::run_tail_batch_multi)),
//! per-sample bit-identical to solo execution, and scatters logits back
//! per request. Tails whose signatures differ only in the tail-start
//! activation size share a **padded** class: they pad-and-stack into
//! one batch whose leading storage is sized to the largest member,
//! guarded by [`BatchConfig::pad_waste_max`] so padding never exceeds
//! the waste budget. Incompatible signatures (including equal
//! out-shapes at different tail depths) never share a batch, and
//! before cross-model coalescing activates the engine *probes* the
//! pool ([`ExecutorPool::probe_xmodel_compat`]) — a backend that
//! cannot reproduce solo bits in a mixed batch falls back to the
//! pre-signature identity keying.
//!
//! Latency contract: a request that observes **no other request with
//! the same key in flight** bypasses the queue entirely and runs
//! directly on its affinity shard — an unloaded server adds zero
//! batching latency, and heterogeneous traffic (every connection
//! cutting at a different stage) never pays a gather window for
//! followers that cannot exist. The window only ever delays requests
//! whose shape-mates are genuinely concurrent — exactly when batching
//! pays.
//!
//! The window itself is **adaptive**: it scales between
//! [`BatchConfig::min_gather`] and [`BatchConfig::gather_window`] with
//! an EWMA of recent effective occupancy, so a lightly loaded server
//! bounds its worst-case added latency near the floor while a
//! saturated one waits long enough to fill batches. And the per-key
//! queue is **deadline-ordered** rather than FIFO where it matters:
//! each member may carry an SLA deadline, and a gathering leader never
//! sleeps past the earliest one — a latency-critical request jumps the
//! window instead of queueing behind it
//! ([`BatchEngine::infer_tail_deadline`]).
//!
//! Buffer discipline: inputs are **moved** in (`Vec<f32>`, usually
//! lent out of a connection's `util::pool::Scratch` via
//! `Scratch::lend_floats`) and each is transformed in place into that
//! request's logits — across the batch boundary no activation or logit
//! is copied into a staging buffer, and the caller gets its own
//! allocation back to restore into its scratch.
//!
//! Robustness: a request with the wrong activation length is rejected
//! by the server *before* enqueueing (a malformed request must not
//! poison its batchmates); if the tail itself fails, every request in
//! that batch gets the error; if a leader panics mid-batch, a guard
//! marks the batch failed so followers return an error instead of
//! parking forever.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use super::artifacts::{Manifest, TailSignature};
use super::pool::ExecutorPool;
use crate::metrics::BatchMetrics;

/// Knobs for the micro-batch scheduler (the README's serving knobs).
#[derive(Debug, Clone, Copy)]
pub struct BatchConfig {
    /// Coalesce at most this many requests per executor acquisition.
    pub max_batch: usize,
    /// The longest a leader waits for followers before running anyway
    /// (the adaptive window's ceiling).
    pub gather_window: Duration,
    /// The adaptive window's floor: what a leader waits under light
    /// load, when a full batch is unlikely anyway.
    pub min_gather: Duration,
    /// Scale the gather window with recent batch occupancy: shrink
    /// toward `min_gather` under light load, grow toward
    /// `gather_window` under saturation. `false` always waits the full
    /// `gather_window` (the pre-adaptive behavior).
    pub adaptive_gather: bool,
    /// `false` turns the engine into a pass-through (every request
    /// runs directly on its affinity shard) — the serialized arm of
    /// the scaling A/B. Even when `true`, coalescing only activates on
    /// a batch-capable pool ([`ExecutorPool::batch_capable`]); on a
    /// serial-batch backend the engine passes through regardless.
    pub enabled: bool,
    /// Tenant-aware dequeue: cap how many slots of one gathering batch
    /// a single tenant may take to `max(1, max_batch / distinct
    /// in-flight tenants on the key)`, so one tenant's backlog cannot
    /// monopolize a full gather window while another tenant's request
    /// is concurrent. A capped request starts (or joins) another batch
    /// for the same key instead of waiting. `false` (the default)
    /// keeps the pre-tenant first-come-first-served fill — and with a
    /// single tenant in flight the cap is `max_batch`, so enabling it
    /// changes nothing until a second tenant shows up.
    pub tenant_fair: bool,
    /// Coalesce shape-compatible tails **across models**: batches key
    /// on a structural [`TailSignature`] class instead of `(model,
    /// tail-start)` identity, so a heterogeneous fleet sharing a cloud
    /// tail still fills batches. Activation additionally requires a
    /// batch-capable pool and a passed compatibility probe
    /// ([`ExecutorPool::probe_xmodel_compat`]); `false` restores the
    /// identity keying exactly.
    pub xmodel: bool,
    /// Pad-and-stack waste budget for cross-model batches whose
    /// members' *leading* geometry differs: a join is refused when the
    /// batch's padded leading storage would exceed this wasted
    /// fraction. `0.0` disables the padded path entirely — only
    /// exact-geometry tails share a class, and a padded candidate
    /// bypasses instead of batching.
    pub pad_waste_max: f64,
}

impl Default for BatchConfig {
    fn default() -> Self {
        // max_batch deliberately stays below typical shard counts:
        // under an 8-connection burst, two batches of 4 on two shards
        // beat one batch of 8 on one shard whenever per-sample compute
        // is near-linear in batch size.
        Self {
            max_batch: 4,
            gather_window: Duration::from_micros(1000),
            min_gather: Duration::from_micros(100),
            adaptive_gather: true,
            enabled: true,
            tenant_fair: false,
            xmodel: true,
            pad_waste_max: 0.25,
        }
    }
}

/// Batch keys are interned signature-class ids (indices into the
/// engine's [`SigTable`]).
type ClassId = u32;

/// One coalescing class: the `(model, tail-start)` routes whose tails
/// share a signature, plus lifetime serving counters (the stats
/// endpoint's per-signature observables).
struct SigClass {
    /// Member routes as `(model_id, from)`.
    members: Vec<(u16, u16)>,
    /// Each member's leading geometry, parallel to `members`.
    leads: Vec<usize>,
    /// Smallest / largest leading geometry among members — these differ
    /// only for padded classes.
    lead_min: usize,
    lead_max: usize,
    requests: AtomicU64,
    batches: AtomicU64,
}

impl SigClass {
    fn new() -> Self {
        Self {
            members: Vec::new(),
            leads: Vec::new(),
            lead_min: usize::MAX,
            lead_max: 0,
            requests: AtomicU64::new(0),
            batches: AtomicU64::new(0),
        }
    }
}

/// Route → class table, derived once from the pool's manifest.
struct SigTable {
    /// Class id per model per tail start (index `from - 1`; `from`
    /// ranges `1..=N+1`, the last being the identity tail).
    route: Vec<Vec<ClassId>>,
    classes: Vec<SigClass>,
}

impl SigTable {
    /// `xmodel = false` keys every route to its own class — the
    /// pre-signature `(model, tail-start)` identity keying, bit for
    /// bit. `padded` erases the leading geometry from the interning
    /// key so pad-and-stack classes form.
    fn build(manifest: &Manifest, xmodel: bool, padded: bool) -> Self {
        let mut classes: Vec<SigClass> = Vec::new();
        let mut interned: HashMap<TailSignature, ClassId> = HashMap::new();
        let mut route = Vec::with_capacity(manifest.models.len());
        for (mi, m) in manifest.models.iter().enumerate() {
            let mut per_model = Vec::with_capacity(m.num_stages() + 1);
            for from in 1..=m.num_stages() + 1 {
                let sig = m.tail_signature(from);
                let lead = sig.lead_elems;
                let id = if xmodel {
                    let key = if padded { sig.padded() } else { sig };
                    *interned.entry(key).or_insert_with(|| {
                        classes.push(SigClass::new());
                        (classes.len() - 1) as ClassId
                    })
                } else {
                    classes.push(SigClass::new());
                    (classes.len() - 1) as ClassId
                };
                let c = &mut classes[id as usize];
                c.members.push((mi as u16, from as u16));
                c.leads.push(lead);
                c.lead_min = c.lead_min.min(lead);
                c.lead_max = c.lead_max.max(lead);
                per_model.push(id);
            }
            route.push(per_model);
        }
        Self { route, classes }
    }

    fn class_of(&self, model: u16, from: usize) -> Option<ClassId> {
        self.route.get(model as usize)?.get(from.checked_sub(1)?).copied()
    }

    /// Compatibility-probe pairs: one pair from an exact-geometry
    /// class (uniform leads) *and* one pair with differing leads from
    /// a padded class, when each exists — a backend must prove the
    /// pad-and-stack execution path bit-exact too, not just the
    /// uniform one. Empty for single-model manifests with no shared
    /// class.
    fn probe_pairs(&self) -> Vec<((u16, usize), (u16, usize))> {
        let pair = |a: (u16, u16), b: (u16, u16)| ((a.0, a.1 as usize), (b.0, b.1 as usize));
        let mut out = Vec::new();
        if let Some(c) = self
            .classes
            .iter()
            .find(|c| c.members.len() >= 2 && c.lead_min == c.lead_max)
        {
            out.push(pair(c.members[0], c.members[1]));
        }
        if let Some(c) = self.classes.iter().find(|c| c.lead_min != c.lead_max) {
            if let Some(j) = c.leads.iter().position(|&l| l != c.leads[0]) {
                out.push(pair(c.members[0], c.members[j]));
            }
        }
        out
    }
}

/// In-flight census of one signature class: per tenant, per leading
/// geometry. The lead breakdown exists for the gathering leader's
/// early-fire check — a member whose lead the pad-waste guard would
/// refuse can never seat in the leader's batch, so the leader must not
/// sleep out its window waiting for it.
#[derive(Default)]
struct ClassCensus {
    /// tenant → (lead_elems → in-flight count).
    tenants: HashMap<u64, HashMap<usize, usize>>,
}

impl ClassCensus {
    fn add(&mut self, tenant: u64, lead: usize) {
        *self.tenants.entry(tenant).or_default().entry(lead).or_insert(0) += 1;
    }

    fn remove(&mut self, tenant: u64, lead: usize) {
        if let Some(leads) = self.tenants.get_mut(&tenant) {
            if let Some(c) = leads.get_mut(&lead) {
                *c -= 1;
                if *c == 0 {
                    leads.remove(&lead);
                }
            }
            if leads.is_empty() {
                self.tenants.remove(&tenant);
            }
        }
    }

    fn total(&self) -> usize {
        self.tenants.values().map(|leads| leads.values().sum::<usize>()).sum()
    }

    fn is_empty(&self) -> bool {
        self.tenants.is_empty()
    }
}

/// Point-in-time serving counters of one signature class (stats
/// endpoint row).
#[derive(Debug, Clone)]
pub struct SignatureStat {
    /// Member routes rendered `model@from`.
    pub members: Vec<String>,
    pub lead_min: usize,
    pub lead_max: usize,
    pub requests: u64,
    pub batches: u64,
}

#[derive(Default)]
struct CellState {
    inputs: Vec<Vec<f32>>,
    /// Tenant of each member, parallel to `inputs` (the tenant-aware
    /// dequeue's per-batch share accounting).
    tenants: Vec<u64>,
    /// `(model_id, from)` of each member, parallel to `inputs` — a
    /// signature class may gather tails from several models, and the
    /// leader needs every member's route to execute the mixed batch.
    routes: Vec<(u16, u16)>,
    outputs: Vec<Option<Vec<f32>>>,
    /// No more joins (leader is draining, or the batch filled).
    closed: bool,
    /// Results (or the error) are in; waiters may collect.
    done: bool,
    error: Option<String>,
    /// When the leader started executing — lets every member compute
    /// its own exact queue wait.
    exec_start: Option<Instant>,
    /// Earliest deadline across the gathered members. The per-key
    /// queue is deadline-ordered rather than FIFO in the sense that
    /// matters: the most urgent member, not arrival order, dictates
    /// when the batch fires (a leader never sleeps a gather window
    /// past anyone's deadline).
    min_deadline: Option<Instant>,
}

impl CellState {
    fn absorb_deadline(&mut self, deadline: Option<Instant>) {
        if let Some(d) = deadline {
            self.min_deadline = Some(match self.min_deadline {
                Some(cur) => cur.min(d),
                None => d,
            });
        }
    }
}

struct BatchCell {
    state: Mutex<CellState>,
    cv: Condvar,
}

impl BatchCell {
    fn with_first(
        input: Vec<f32>,
        tenant: u64,
        route: (u16, u16),
        deadline: Option<Instant>,
    ) -> Self {
        Self {
            state: Mutex::new(CellState {
                inputs: vec![input],
                tenants: vec![tenant],
                routes: vec![route],
                min_deadline: deadline,
                ..CellState::default()
            }),
            cv: Condvar::new(),
        }
    }
}

/// Marks a cell failed-and-done on drop unless defused — the leader's
/// unwind safety net for its followers.
struct FailBatchGuard {
    cell: Arc<BatchCell>,
    armed: bool,
}

impl Drop for FailBatchGuard {
    fn drop(&mut self) {
        if self.armed {
            let mut st = self.cell.state.lock().unwrap();
            if !st.done {
                st.error = Some("batch leader panicked before scattering results".into());
                st.done = true;
                self.cell.cv.notify_all();
            }
        }
    }
}

pub struct BatchEngine {
    pool: Arc<ExecutorPool>,
    cfg: BatchConfig,
    /// `cfg.enabled` gated on [`ExecutorPool::batch_capable`]: a
    /// backend that executes batch members serially (PJRT on batch-1
    /// artifacts) gains nothing from coalescing and loses the shard
    /// parallelism, so the engine passes everything through.
    coalesce: bool,
    /// Cross-model coalescing active: `cfg.xmodel`, gated on
    /// `coalesce` and on the pool passing the signature compatibility
    /// probe at construction. Off, the signature table degenerates to
    /// one class per `(model, tail-start)` — identity keying, bit for
    /// bit.
    xmodel: bool,
    /// Route → signature-class table: the batch key space.
    sigs: SigTable,
    /// Open/draining cells per signature class, arrival order. Usually
    /// one cell; the tenant-aware dequeue or the pad-waste guard may
    /// open a second when a join is refused on the first (its leader
    /// runs concurrently).
    pending: Mutex<HashMap<ClassId, Vec<Arc<BatchCell>>>>,
    /// Requests currently inside the engine, **per signature class,
    /// tenant and leading geometry** — the total is the
    /// zero-latency-bypass census (per-class so traffic with no
    /// signature-mates never waits a gather window it cannot fill),
    /// the distinct-tenant count sets the per-batch slot cap when
    /// `cfg.tenant_fair` is on, and the per-lead counts let a
    /// gathering leader ignore members the pad-waste guard would
    /// refuse anyway.
    key_counts: Mutex<HashMap<ClassId, ClassCensus>>,
    /// Per-tenant queue-wait sink (the cloud server's registry);
    /// `None` outside a serving context.
    tenants: Option<Arc<crate::metrics::TenantRegistry>>,
    /// EWMA of recent effective occupancy (batch sizes and bypasses
    /// alike — a bypass is an occupancy-1 event), stored as f64 bits
    /// in an atomic so the bypass fast path never takes a shared lock
    /// for it. This is the saturation signal the adaptive gather
    /// window scales with: near 1 the server is lightly loaded and
    /// leaders fire after `min_gather`; near `max_batch` it is
    /// saturated and waiting the full window keeps filling batches.
    occupancy_ewma: std::sync::atomic::AtomicU64,
    pub metrics: BatchMetrics,
}

impl BatchEngine {
    pub fn new(pool: Arc<ExecutorPool>, cfg: BatchConfig) -> Arc<Self> {
        Self::with_tenants(pool, cfg, None)
    }

    /// [`BatchEngine::new`] with a per-tenant metrics sink: every
    /// request's queue wait is recorded under its tenant as well as in
    /// the global histogram (the fairness observable).
    pub fn with_tenants(
        pool: Arc<ExecutorPool>,
        cfg: BatchConfig,
        tenants: Option<Arc<crate::metrics::TenantRegistry>>,
    ) -> Arc<Self> {
        let coalesce = cfg.enabled && cfg.max_batch > 1 && pool.batch_capable();
        let mut xmodel = cfg.xmodel && coalesce;
        let mut sigs = SigTable::build(pool.manifest(), xmodel, cfg.pad_waste_max > 0.0);
        if xmodel {
            // Trust nothing about the backend's mixed-batch behavior:
            // for every shared-class shape that could go live — an
            // exact-geometry pair and, when padded classes exist, a
            // differing-lead pair (the pad-and-stack path) — execute
            // the probe and compare against solo bits. A failed (or
            // erroring) probe falls back to identity keying — slower,
            // never wrong. Single-model manifests have no shared class
            // and skip the probe entirely.
            for (a, b) in sigs.probe_pairs() {
                if !pool.probe_xmodel_compat(a, b) {
                    crate::log_warn!(
                        "batch",
                        "cross-model compatibility probe failed for {a:?} vs {b:?}; \
                         falling back to identity batch keying"
                    );
                    xmodel = false;
                    sigs = SigTable::build(pool.manifest(), false, false);
                    break;
                }
            }
        }
        Arc::new(Self {
            pool,
            cfg,
            coalesce,
            xmodel,
            sigs,
            pending: Mutex::new(HashMap::new()),
            key_counts: Mutex::new(HashMap::new()),
            tenants,
            occupancy_ewma: std::sync::atomic::AtomicU64::new(1.0f64.to_bits()),
            metrics: BatchMetrics::default(),
        })
    }

    /// Whether cross-model (signature-keyed) coalescing is live:
    /// requires `cfg.xmodel`, a batch-capable pool, and a passed
    /// compatibility probe.
    pub fn xmodel_active(&self) -> bool {
        self.xmodel
    }

    /// Per-signature-class serving counters, one row per class that
    /// has seen traffic (the stats endpoint's per-signature report).
    pub fn signature_stats(&self) -> Vec<SignatureStat> {
        let models = &self.pool.manifest().models;
        self.sigs
            .classes
            .iter()
            .filter(|c| c.requests.load(Ordering::Relaxed) > 0)
            .map(|c| SignatureStat {
                members: c
                    .members
                    .iter()
                    .map(|&(mi, from)| {
                        let name = models
                            .get(mi as usize)
                            .map(|m| m.name.as_str())
                            .unwrap_or("?");
                        format!("{name}@{from}")
                    })
                    .collect(),
                lead_min: c.lead_min,
                lead_max: c.lead_max,
                requests: c.requests.load(Ordering::Relaxed),
                batches: c.batches.load(Ordering::Relaxed),
            })
            .collect()
    }

    /// Record one request's queue wait globally and, when a registry
    /// is attached, under its tenant.
    fn record_queue_wait(&self, tenant: u64, secs: f64) {
        self.metrics.queue_wait.record(secs);
        if let Some(reg) = &self.tenants {
            reg.get(tenant).queue_wait.record(secs);
        }
    }

    /// Recent effective occupancy (EWMA over batches and bypasses).
    pub fn occupancy_ewma(&self) -> f64 {
        f64::from_bits(self.occupancy_ewma.load(std::sync::atomic::Ordering::Relaxed))
    }

    /// The gather window a leader starting now would use: scaled
    /// between `min_gather` and `gather_window` by recent occupancy
    /// when adaptive, the configured window otherwise.
    pub fn effective_gather_window(&self) -> Duration {
        if !self.cfg.adaptive_gather || self.cfg.max_batch <= 1 {
            return self.cfg.gather_window;
        }
        let floor = self.cfg.min_gather.min(self.cfg.gather_window);
        let occ = self.occupancy_ewma();
        // Map occupancy 1 → 0 saturation, max_batch → 1.
        let denom = (self.cfg.max_batch - 1).max(1) as f64;
        let sat = ((occ - 1.0) / denom).clamp(0.0, 1.0);
        floor + Duration::from_secs_f64((self.cfg.gather_window - floor).as_secs_f64() * sat)
    }

    fn note_occupancy(&self, occupancy: usize) {
        use std::sync::atomic::Ordering::Relaxed;
        // CAS loop keeps concurrent updates exact; contention is rare
        // (one update per batch or bypass) and each attempt is a few
        // float ops.
        let _ = self.occupancy_ewma.fetch_update(Relaxed, Relaxed, |bits| {
            let e = f64::from_bits(bits);
            Some((e + 0.2 * (occupancy as f64 - e)).to_bits())
        });
    }

    pub fn config(&self) -> BatchConfig {
        self.cfg
    }

    pub fn pool(&self) -> &Arc<ExecutorPool> {
        &self.pool
    }

    /// Finish inference for one request: run stages `from..=N` of the
    /// model on `input` (a flat, already-dequantized activation) and
    /// return its logits. The returned `Vec` is the same allocation,
    /// transformed in place — hand it back to the scratch it came from.
    pub fn infer_tail(
        &self,
        affinity: usize,
        model_id: u16,
        from: usize,
        input: Vec<f32>,
    ) -> Result<Vec<f32>> {
        self.infer_tail_deadline(affinity, model_id, from, input, None)
    }

    /// [`BatchEngine::infer_tail`] with an SLA deadline. A gathering
    /// leader never sleeps past the earliest deadline among its
    /// members — a latency-critical request jumps the gather window
    /// instead of queueing FIFO behind it (deadline-ordered firing).
    /// The tenant defaults to the affinity (one implicit tenant per
    /// connection, which is a no-op unless `tenant_fair` is on).
    pub fn infer_tail_deadline(
        &self,
        affinity: usize,
        model_id: u16,
        from: usize,
        input: Vec<f32>,
        deadline: Option<Instant>,
    ) -> Result<Vec<f32>> {
        self.infer_tail_for(affinity, model_id, from, input, deadline, affinity as u64)
    }

    /// [`BatchEngine::infer_tail_deadline`] with an explicit tenant
    /// identity — the serving entry point. When `cfg.tenant_fair` is
    /// on, a tenant may take at most `max(1, max_batch / distinct
    /// in-flight tenants)` slots of one gathering batch; a capped
    /// request opens (or joins) another batch for the same key, so a
    /// flood of one tenant's backlog can never occupy every slot of
    /// the window another tenant is waiting on.
    pub fn infer_tail_for(
        &self,
        affinity: usize,
        model_id: u16,
        from: usize,
        input: Vec<f32>,
        deadline: Option<Instant>,
        tenant: u64,
    ) -> Result<Vec<f32>> {
        if !self.coalesce {
            self.metrics.record_bypass();
            return self.run_single(affinity, model_id, from, input, tenant);
        }

        // Route → signature class. A route outside the manifest (bad
        // model id, `from = 0`, absurd depth) has no class: run it
        // single so the executor reports the precise error — exactly
        // what the identity-keyed engine did.
        let Some(key) = self.sigs.class_of(model_id, from) else {
            self.metrics.record_bypass();
            return self.run_single(affinity, model_id, from, input, tenant);
        };
        self.sigs.classes[key as usize].requests.fetch_add(1, Ordering::Relaxed);
        // Per-class in-flight census, decremented on every exit path.
        // The decrement also wakes any leader gathering on this class —
        // its early-fire check compares batch size against the census,
        // so a departing peer (e.g. a bypasser that was never going to
        // join) must not leave it sleeping out the window.
        struct KeyGuard<'a> {
            engine: &'a BatchEngine,
            key: ClassId,
            tenant: u64,
            lead: usize,
        }
        impl Drop for KeyGuard<'_> {
            fn drop(&mut self) {
                {
                    let mut counts = self.engine.key_counts.lock().unwrap();
                    if let Some(census) = counts.get_mut(&self.key) {
                        census.remove(self.tenant, self.lead);
                        if census.is_empty() {
                            counts.remove(&self.key);
                        }
                    }
                }
                // Locks are taken strictly one at a time here (counts,
                // then pending, then cell) — no cycle with the
                // pending→cell or cell→counts orderings. The notify
                // happens under each cell's state lock so it cannot
                // land between a leader's census check and its park
                // (the leader holds that lock from check to wait).
                let cells: Vec<Arc<BatchCell>> = self
                    .engine
                    .pending
                    .lock()
                    .unwrap()
                    .get(&self.key)
                    .cloned()
                    .unwrap_or_default();
                for cell in cells {
                    let _st = cell.state.lock().unwrap();
                    cell.cv.notify_all();
                }
            }
        }
        let in_len = input.len();
        let peers = {
            let mut counts = self.key_counts.lock().unwrap();
            let census = counts.entry(key).or_default();
            let prev = census.total();
            census.add(tenant, in_len);
            prev
        };
        let _guard = KeyGuard { engine: self, key, tenant, lead: in_len };

        // No shape-mate in flight: the direct path. No queue, no
        // window — single-request latency is untouched, and mixed-key
        // traffic never waits for followers that cannot exist.
        if peers == 0 {
            self.metrics.record_bypass();
            self.note_occupancy(1);
            return self.run_single(affinity, model_id, from, input, tenant);
        }

        let enqueued = Instant::now();

        enum Role {
            Leader(Arc<BatchCell>),
            Follower(Arc<BatchCell>, usize),
        }
        // A tenant's slot cap per gathering batch: unlimited unless
        // tenant fairness is on, then an equal split of the batch over
        // the tenants in flight for this key (min 1 — everyone can
        // always make progress). With one tenant in flight the cap is
        // max_batch, i.e. the pre-tenant fill exactly.
        let cap = if self.cfg.tenant_fair {
            let distinct = self.key_tenants(&key).max(1);
            (self.cfg.max_batch / distinct).max(1)
        } else {
            usize::MAX
        };
        // Lock order everywhere: pending map, then cell state.
        let role = {
            let mut map = self.pending.lock().unwrap();
            let cells = map.entry(key).or_default();
            let mut input = Some(input);
            // (cell, slot, batch-now-full) when a join succeeded.
            let mut joined: Option<(Arc<BatchCell>, usize, bool)> = None;
            let mut capped = false;
            for cell in cells.iter() {
                let mut st = cell.state.lock().unwrap();
                if st.closed {
                    // A leader is draining this cell; try the next.
                    continue;
                }
                if st.tenants.iter().filter(|&&t| t == tenant).count() >= cap {
                    // This tenant already holds its share of this
                    // batch's slots: leave them for other tenants and
                    // gather in a fresh batch instead. Counted once
                    // per refused request, not once per cell scanned.
                    if !capped {
                        capped = true;
                        self.metrics.record_tenant_cap();
                    }
                    continue;
                }
                if !pad_admits(&st.inputs, in_len, self.cfg.pad_waste_max) {
                    // Pad-and-stack guard: seating this member would
                    // push the batch's padded leading storage past the
                    // waste budget — gather in a fresh batch instead.
                    // (Members of an exact-keyed class all share one
                    // leading geometry, so the waste there is always
                    // zero and this never trips.)
                    continue;
                }
                st.inputs.push(input.take().expect("input consumed once"));
                st.tenants.push(tenant);
                st.routes.push((model_id, from as u16));
                st.absorb_deadline(deadline);
                let slot = st.inputs.len() - 1;
                let full = st.inputs.len() >= self.cfg.max_batch;
                if full {
                    // Batch is full: close it.
                    st.closed = true;
                }
                // Wake the leader on every join — it re-checks
                // fullness *and* the per-key census, so it can fire
                // as soon as everyone who could join has joined.
                cell.cv.notify_all();
                drop(st);
                joined = Some((Arc::clone(cell), slot, full));
                break;
            }
            match joined {
                Some((cell, slot, full)) => {
                    if full {
                        // Full batches leave the open list so late
                        // arrivals start a fresh one.
                        cells.retain(|c| !Arc::ptr_eq(c, &cell));
                    }
                    Role::Follower(cell, slot)
                }
                None => {
                    let cell = Arc::new(BatchCell::with_first(
                        input.take().expect("input once"),
                        tenant,
                        (model_id, from as u16),
                        deadline,
                    ));
                    cells.push(Arc::clone(&cell));
                    Role::Leader(cell)
                }
            }
        };

        match role {
            Role::Leader(cell) => self.lead(cell, key, enqueued, tenant),
            Role::Follower(cell, slot) => self.follow(cell, slot, enqueued, tenant),
        }
    }

    /// Leader: gather followers for up to the (adaptive) window — but
    /// never past the earliest member deadline — detach the cell, run
    /// the whole batch in one shard acquisition (routed to the
    /// least-busy shard so concurrent batches spread across the pool),
    /// scatter results.
    fn lead(
        &self,
        cell: Arc<BatchCell>,
        key: ClassId,
        enqueued: Instant,
        tenant: u64,
    ) -> Result<Vec<f32>> {
        let window = self.effective_gather_window();
        self.metrics.record_gather_window(window);
        let gather_until = Instant::now() + window;
        let mut deadline_fired = false;
        {
            let mut st = cell.state.lock().unwrap();
            loop {
                if st.closed || st.inputs.len() >= self.cfg.max_batch {
                    break;
                }
                // Fire early once everyone who *could* join has: the
                // per-class census counts every same-class request
                // inside the engine (including this leader), excluding
                // members whose leading geometry the pad-waste guard
                // would refuse for *this* batch and capping per tenant
                // when tenant fairness is on — a flooder's requests
                // beyond its slot cap, or a lead that cannot pad into
                // this batch, can never seat here, so a leader must
                // not sleep out the window waiting for them.
                // (Cell→counts lock order; counts is never held while
                // acquiring a cell, so this cannot deadlock. The check
                // is a latency heuristic: firing "early" only means a
                // late joiner starts its own batch.)
                if st.inputs.len() >= self.key_seatable(&key, &st.inputs) {
                    break;
                }
                // Deadline-ordered firing: the most urgent member, not
                // arrival order, dictates when the batch runs.
                let until = match st.min_deadline {
                    Some(d) if d < gather_until => d,
                    _ => gather_until,
                };
                let now = Instant::now();
                if now >= until {
                    deadline_fired = until < gather_until;
                    break;
                }
                let (g, _) = cell.cv.wait_timeout(st, until - now).unwrap();
                st = g;
            }
        }
        if deadline_fired {
            self.metrics.record_deadline_clamp();
        }
        // Detach from the map (map→cell order) so late arrivals start a
        // fresh batch, then close and take the gathered inputs.
        {
            let mut map = self.pending.lock().unwrap();
            if let Some(cells) = map.get_mut(&key) {
                cells.retain(|c| !Arc::ptr_eq(c, &cell));
                if cells.is_empty() {
                    map.remove(&key);
                }
            }
        }
        let (mut inputs, routes) = {
            let mut st = cell.state.lock().unwrap();
            st.closed = true;
            st.exec_start = Some(Instant::now());
            (std::mem::take(&mut st.inputs), std::mem::take(&mut st.routes))
        };

        let mut guard = FailBatchGuard { cell: Arc::clone(&cell), armed: true };
        self.metrics.record_batch(inputs.len());
        self.sigs.classes[key as usize].batches.fetch_add(1, Ordering::Relaxed);
        // Cross-model + padding observability: how often signature
        // keying actually mixed models, and how much leading storage
        // the pad-and-stack path wasted doing it.
        if routes.iter().any(|r| r.0 != routes[0].0) {
            self.metrics.record_xmodel_batch();
        }
        let max_lead = inputs.iter().map(Vec::len).max().unwrap_or(0);
        let padded = inputs.iter().filter(|v| v.len() < max_lead).count();
        if padded > 0 {
            let sum_lead: usize = inputs.iter().map(Vec::len).sum();
            let stacked = inputs.len() * max_lead;
            self.metrics.record_padding(padded as u64, (stacked - sum_lead) as u64, stacked as u64);
        }
        self.note_occupancy(inputs.len());
        self.record_queue_wait(tenant, enqueued.elapsed().as_secs_f64());
        let result = if routes.iter().all(|&r| r == routes[0]) {
            // Homogeneous batch: the single-model path.
            let (model_id, from) = (routes[0].0, routes[0].1 as usize);
            self.run_batch(None, model_id, from, &mut inputs)
        } else {
            let rs: Vec<(u16, usize)> =
                routes.iter().map(|&(m, f)| (m, f as usize)).collect();
            self.run_batch_multi(&rs, &mut inputs)
        };

        let mut st = cell.state.lock().unwrap();
        let mine = match result {
            Ok(()) => {
                let mut outs: Vec<Option<Vec<f32>>> =
                    inputs.into_iter().map(Some).collect();
                let mine = outs[0].take().expect("leader slot");
                st.outputs = outs;
                Ok(mine)
            }
            Err(e) => {
                st.error = Some(format!("{e:#}"));
                Err(e)
            }
        };
        st.done = true;
        guard.armed = false;
        drop(st);
        cell.cv.notify_all();
        mine
    }

    /// Follower: park until the leader scatters, then take our slot.
    fn follow(
        &self,
        cell: Arc<BatchCell>,
        slot: usize,
        enqueued: Instant,
        tenant: u64,
    ) -> Result<Vec<f32>> {
        let mut st = cell.state.lock().unwrap();
        while !st.done {
            st = cell.cv.wait(st).unwrap();
        }
        if let Some(start) = st.exec_start {
            let wait = start.saturating_duration_since(enqueued);
            drop(st);
            self.record_queue_wait(tenant, wait.as_secs_f64());
            st = cell.state.lock().unwrap();
        }
        if let Some(e) = &st.error {
            return Err(anyhow!("batched tail failed: {e}"));
        }
        st.outputs
            .get_mut(slot)
            .and_then(Option::take)
            .ok_or_else(|| anyhow!("batch result slot {slot} missing"))
    }

    /// Same-class requests currently inside the engine that could
    /// still seat in the leader's batch (whose gathered inputs are
    /// `gathered`): members whose leading geometry the pad-waste guard
    /// would refuse are excluded, and with tenant fairness on each
    /// tenant's count is clamped to its slot cap — the bound a
    /// gathering leader compares its batch size against. (Identical to
    /// the raw census for an exact-geometry class with `tenant_fair`
    /// off, so the pre-signature early-fire behavior is unchanged.
    /// Still a latency heuristic — composition changes as members
    /// join — but one that never leaves a leader sleeping a window for
    /// a member that structurally cannot seat.)
    fn key_seatable(&self, key: &ClassId, gathered: &[Vec<f32>]) -> usize {
        let counts = self.key_counts.lock().unwrap();
        let Some(census) = counts.get(key) else { return 0 };
        let budget = self.cfg.pad_waste_max;
        let cap = if self.cfg.tenant_fair {
            (self.cfg.max_batch / census.tenants.len().max(1)).max(1)
        } else {
            usize::MAX
        };
        census
            .tenants
            .values()
            .map(|leads| {
                let eligible: usize = leads
                    .iter()
                    .filter(|&(&lead, _)| pad_admits(gathered, lead, budget))
                    .map(|(_, &c)| c)
                    .sum();
                eligible.min(cap)
            })
            .sum()
    }

    /// Distinct tenants with same-class requests inside the engine.
    fn key_tenants(&self, key: &ClassId) -> usize {
        self.key_counts.lock().unwrap().get(key).map(|c| c.tenants.len()).unwrap_or(0)
    }

    /// Bypass path: one request straight through its affinity shard.
    /// The wait for the shard lock is recorded as queue wait — on
    /// backends where everything bypasses (PJRT batch-1 artifacts,
    /// `--no-batch`), shard-lock contention *is* the queue, and it
    /// must feed the same windowed p95 the admission budget and the
    /// edge's `CloudLoad.queue_wait` term consume.
    fn run_single(
        &self,
        affinity: usize,
        model_id: u16,
        from: usize,
        input: Vec<f32>,
        tenant: u64,
    ) -> Result<Vec<f32>> {
        let mut batch = [input];
        self.run_batch(Some((affinity, tenant)), model_id, from, &mut batch)?;
        let [out] = batch;
        Ok(out)
    }

    /// One shard acquisition for the whole batch. `Some((affinity,
    /// tenant))` pins the caller's connection-affine shard (bypass
    /// path, keeps its compile cache hot); `None` routes to the
    /// least-busy shard (batch leaders, so simultaneous batches
    /// parallelize).
    fn run_batch(
        &self,
        affinity: Option<(usize, u64)>,
        model_id: u16,
        from: usize,
        batch: &mut [Vec<f32>],
    ) -> Result<()> {
        let model = &self
            .pool
            .manifest()
            .models
            .get(model_id as usize)
            .ok_or_else(|| anyhow!("bad model id {model_id}"))?
            .name;
        match affinity {
            Some((a, tenant)) => {
                // Bypass: time-to-closure-start = shard-lock wait.
                // (Leaders record their own gather wait in `lead`.)
                let t0 = Instant::now();
                self.pool.run_on(a, |e| {
                    self.record_queue_wait(tenant, t0.elapsed().as_secs_f64());
                    e.run_tail_batch(model, from, batch)
                })?
            }
            None => self.pool.run_on_least_busy(|e| e.run_tail_batch(model, from, batch))?,
        };
        Ok(())
    }

    /// One least-busy shard acquisition for a whole **mixed-model**
    /// batch: the executor runs it as one batched program, per-sample
    /// bit-identical to solo execution.
    fn run_batch_multi(&self, routes: &[(u16, usize)], batch: &mut [Vec<f32>]) -> Result<()> {
        self.pool.run_on_least_busy(|e| e.run_tail_batch_multi(routes, batch))?;
        Ok(())
    }
}

/// Would seating a member with `len` leading elements keep the batch's
/// pad-and-stack waste within `budget`? Waste is the fraction of the
/// stacked leading storage (`B × max_lead`) that is padding. Members
/// of an exact-geometry batch all share one lead, so their waste is
/// always 0 and any budget (including 0) admits them.
fn pad_admits(members: &[Vec<f32>], len: usize, budget: f64) -> bool {
    let max = members.iter().map(Vec::len).max().unwrap_or(0).max(len);
    if max == 0 {
        return true;
    }
    let stacked = (members.len() + 1) * max;
    let sum: usize = members.iter().map(Vec::len).sum::<usize>() + len;
    (stacked - sum) as f64 <= budget * stacked as f64 + 1e-9
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::sim::sim_manifest;
    use crate::runtime::Executor;

    fn engine(shards: usize, cfg: BatchConfig) -> Arc<BatchEngine> {
        BatchEngine::new(ExecutorPool::new_sim_with(sim_manifest(), shards, 8), cfg)
    }

    fn activation(seed: usize, elems: usize) -> Vec<f32> {
        (0..elems)
            .map(|i| {
                let h = ((i + seed * 7919) as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
                ((h >> 44) & 0xFFF) as f32 / 409.6
            })
            .collect()
    }

    fn serial_reference(from: usize, input: &[f32]) -> Vec<f32> {
        let exe = Executor::sim_with(sim_manifest(), 8);
        let mut batch = vec![input.to_vec()];
        exe.run_tail_batch("simnet", from, &mut batch).unwrap();
        batch.pop().unwrap()
    }

    #[test]
    fn uncontended_request_bypasses_queue() {
        let eng = engine(2, BatchConfig::default());
        let m = sim_manifest();
        let elems = m.model("simnet").unwrap().stages[1].out_elems;
        let input = activation(1, elems);
        let out = eng.infer_tail(0, 0, 3, input.clone()).unwrap();
        assert_eq!(out.len(), 16);
        let (batches, _, bypassed, _) = eng.metrics.snapshot();
        assert_eq!((batches, bypassed), (0, 1), "a lone request must not queue");
        assert_eq!(
            out.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            serial_reference(3, &input).iter().map(|v| v.to_bits()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn contended_requests_match_serial_bit_for_bit() {
        let eng = engine(4, BatchConfig {
            max_batch: 4,
            gather_window: Duration::from_millis(5),
            min_gather: Duration::from_millis(5),
            ..BatchConfig::default()
        });
        let m = sim_manifest();
        let elems = m.model("simnet").unwrap().stages[1].out_elems;
        let start = Arc::new(std::sync::Barrier::new(8));
        let handles: Vec<_> = (0..8)
            .map(|t| {
                let eng = Arc::clone(&eng);
                let start = Arc::clone(&start);
                let input = activation(t, elems);
                std::thread::spawn(move || {
                    start.wait();
                    let mut outs = Vec::new();
                    for _ in 0..16 {
                        outs.push(eng.infer_tail(t, 0, 3, input.clone()).unwrap());
                    }
                    (t, outs)
                })
            })
            .collect();
        for h in handles {
            let (t, outs) = h.join().unwrap();
            let expected = serial_reference(3, &activation(t, elems));
            for out in outs {
                assert!(
                    out.iter().zip(&expected).all(|(a, b)| a.to_bits() == b.to_bits()),
                    "thread {t}: batched logits diverged from serial"
                );
            }
        }
        let (batches, batched, bypassed, max_occ) = eng.metrics.snapshot();
        assert_eq!(batched + bypassed, 8 * 16, "every request accounted exactly once");
        // With 8 threads in a barrier-aligned burst, at least some
        // requests must actually have coalesced.
        assert!(batches > 0, "no batches formed under contention");
        assert!(max_occ >= 2, "batches never held more than one request");
        assert!(eng.metrics.queue_wait.snapshot().len() as u64 >= batched);
    }

    #[test]
    fn different_keys_never_coalesce_or_wait() {
        // Four threads, four distinct tail-start keys, all concurrent:
        // every request must bypass (peers census is per key), so no
        // batch forms and nobody pays a gather window.
        let eng = engine(4, BatchConfig {
            max_batch: 4,
            gather_window: Duration::from_millis(100), // would hurt if waited
            min_gather: Duration::from_millis(100),
            ..BatchConfig::default()
        });
        let m = sim_manifest();
        let start = Arc::new(std::sync::Barrier::new(4));
        let t0 = std::time::Instant::now();
        let handles: Vec<_> = (0..4)
            .map(|t| {
                let eng = Arc::clone(&eng);
                let start = Arc::clone(&start);
                let from = t + 2; // tail starts 2..=5, all distinct
                let elems = m.model("simnet").unwrap().stages[t].out_elems;
                std::thread::spawn(move || {
                    start.wait();
                    for k in 0..4 {
                        eng.infer_tail(t, 0, from, activation(t * 10 + k, elems)).unwrap();
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        let (batches, _, bypassed, _) = eng.metrics.snapshot();
        assert_eq!(batches, 0, "distinct keys must never share a batch");
        assert_eq!(bypassed, 16);
        // 16 small tails finish in µs; a regression to global-census
        // bypass would wait ≥4 windows (400 ms) per thread.
        assert!(
            t0.elapsed() < Duration::from_millis(300),
            "mixed-key traffic appears to have waited for gather windows"
        );
    }

    #[test]
    fn disabled_engine_is_pass_through() {
        let eng = engine(1, BatchConfig { enabled: false, ..BatchConfig::default() });
        let m = sim_manifest();
        let elems = m.model("simnet").unwrap().stages[0].out_elems;
        let out = eng.infer_tail(0, 0, 2, activation(3, elems)).unwrap();
        assert_eq!(out.len(), 16);
        let (batches, _, bypassed, _) = eng.metrics.snapshot();
        assert_eq!(batches, 0);
        assert_eq!(bypassed, 1);
    }

    #[test]
    fn adaptive_window_tracks_occupancy() {
        let cfg = BatchConfig {
            max_batch: 4,
            gather_window: Duration::from_micros(1000),
            min_gather: Duration::from_micros(100),
            adaptive_gather: true,
            ..BatchConfig::default()
        };
        let eng = engine(2, cfg);
        // Fresh engine assumes light load: window sits at the floor.
        assert_eq!(eng.effective_gather_window(), cfg.min_gather);
        // Saturate the occupancy signal: window grows toward the cap.
        for _ in 0..50 {
            eng.note_occupancy(4);
        }
        let saturated = eng.effective_gather_window();
        assert!(
            saturated > Duration::from_micros(900),
            "saturated window stayed at {saturated:?}"
        );
        // Light load again: decays back toward the floor.
        for _ in 0..50 {
            eng.note_occupancy(1);
        }
        let light = eng.effective_gather_window();
        assert!(light < Duration::from_micros(200), "light-load window stuck at {light:?}");
        // Adaptation off: always the configured window, whatever the
        // occupancy history says.
        let fixed = engine(2, BatchConfig { adaptive_gather: false, ..cfg });
        for _ in 0..50 {
            fixed.note_occupancy(4);
        }
        assert_eq!(fixed.effective_gather_window(), cfg.gather_window);
    }

    #[test]
    fn expired_deadline_fires_without_gathering() {
        // Concurrent same-key requests, a huge fixed window, and an
        // already-expired deadline on each: whatever role each request
        // lands in, nobody may sleep out the 2 s window. (The census
        // early-fire covers the both-joined case; the deadline bound
        // covers a leader whose census stays ahead of its cell — e.g.
        // members of a previous full batch still draining.)
        let eng = engine(2, BatchConfig {
            max_batch: 4,
            gather_window: Duration::from_secs(2),
            min_gather: Duration::from_secs(2),
            adaptive_gather: false,
            enabled: true,
            ..BatchConfig::default()
        });
        let m = sim_manifest();
        let elems = m.model("simnet").unwrap().stages[1].out_elems;
        let start = Arc::new(std::sync::Barrier::new(2));
        let t0 = std::time::Instant::now();
        let handles: Vec<_> = (0..2)
            .map(|t| {
                let eng = Arc::clone(&eng);
                let start = Arc::clone(&start);
                let input = activation(t, elems);
                std::thread::spawn(move || {
                    start.wait();
                    let past = Instant::now() - Duration::from_millis(1);
                    eng.infer_tail_deadline(t, 0, 3, input, Some(past)).unwrap()
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert!(
            t0.elapsed() < Duration::from_millis(500),
            "a leader slept a 2 s window past an expired deadline"
        );
    }

    #[test]
    fn tenant_cap_shares_a_batch_between_tenants() {
        // Tenant fairness on, long window: a burst of one tenant's
        // backlog plus a second tenant, all same-key. With two tenants
        // in flight the per-batch cap is max_batch/2 = 2, so the
        // flooder's 6 requests cannot fill a single batch — joins past
        // the cap are refused (and open a fresh batch) — and every
        // request still completes bit-identically. Batch formation is
        // timing-dependent (a lone first arrival legitimately
        // bypasses), so the cap observation retries a few bursts; the
        // correctness assertions hold on every attempt.
        let mut capped_total = 0u64;
        for _attempt in 0..3 {
            let eng = engine(4, BatchConfig {
                max_batch: 4,
                gather_window: Duration::from_millis(50),
                min_gather: Duration::from_millis(50),
                adaptive_gather: false,
                tenant_fair: true,
                ..BatchConfig::default()
            });
            let m = sim_manifest();
            let elems = m.model("simnet").unwrap().stages[1].out_elems;
            let start = Arc::new(std::sync::Barrier::new(8));
            let handles: Vec<_> = (0..8)
                .map(|t| {
                    let eng = Arc::clone(&eng);
                    let start = Arc::clone(&start);
                    let input = activation(t, elems);
                    // Threads 0..6 are tenant 100 (the backlog); 6 and
                    // 7 are tenant 200.
                    let tenant = if t < 6 { 100 } else { 200 };
                    std::thread::spawn(move || {
                        start.wait();
                        let out =
                            eng.infer_tail_for(t, 0, 3, input.clone(), None, tenant).unwrap();
                        (t, input, out)
                    })
                })
                .collect();
            for h in handles {
                let (t, input, out) = h.join().unwrap();
                let expected = serial_reference(3, &input);
                assert!(
                    out.iter().zip(&expected).all(|(a, b)| a.to_bits() == b.to_bits()),
                    "thread {t}: tenant-capped logits diverged from serial"
                );
            }
            let (_, batched, bypassed, max_occ) = eng.metrics.snapshot();
            assert_eq!(batched + bypassed, 8, "every request served exactly once");
            assert!(max_occ <= 4);
            capped_total +=
                eng.metrics.tenant_capped.load(std::sync::atomic::Ordering::Relaxed);
            if capped_total >= 1 {
                break;
            }
        }
        assert!(
            capped_total >= 1,
            "6 same-tenant joins against a cap of 2 never hit the cap in 3 bursts"
        );
    }

    #[test]
    fn tenant_fair_off_is_unchanged_for_distinct_tenants() {
        // Fairness off: tenants are ignored and a same-key burst fills
        // one batch first-come-first-served, exactly the pre-tenant
        // behavior (no cap events ever).
        let eng = engine(2, BatchConfig {
            max_batch: 4,
            gather_window: Duration::from_millis(20),
            min_gather: Duration::from_millis(20),
            adaptive_gather: false,
            ..BatchConfig::default()
        });
        let m = sim_manifest();
        let elems = m.model("simnet").unwrap().stages[1].out_elems;
        let start = Arc::new(std::sync::Barrier::new(4));
        let handles: Vec<_> = (0..4)
            .map(|t| {
                let eng = Arc::clone(&eng);
                let start = Arc::clone(&start);
                let input = activation(t, elems);
                std::thread::spawn(move || {
                    start.wait();
                    eng.infer_tail_for(t, 0, 3, input, None, 1000 + t as u64).unwrap()
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(
            eng.metrics.tenant_capped.load(std::sync::atomic::Ordering::Relaxed),
            0,
            "tenant cap must be inert when tenant_fair is off"
        );
    }

    #[test]
    fn tail_past_last_stage_returns_input() {
        let eng = engine(1, BatchConfig::default());
        let logits = vec![0.5f32; 16];
        let out = eng.infer_tail(0, 0, 5, logits.clone()).unwrap();
        assert_eq!(out, logits);
    }

    #[test]
    fn bad_model_id_errors() {
        let eng = engine(1, BatchConfig::default());
        assert!(eng.infer_tail(0, 42, 2, vec![0.0; 8]).is_err());
    }

    #[test]
    fn bad_activation_length_errors_without_hanging() {
        let eng = engine(2, BatchConfig::default());
        assert!(eng.infer_tail(0, 0, 2, vec![0.0; 3]).is_err());
        // Engine still serves afterwards.
        let m = sim_manifest();
        let elems = m.model("simnet").unwrap().stages[0].out_elems;
        assert!(eng.infer_tail(0, 0, 2, activation(9, elems)).is_ok());
    }
}
