//! Sharded inference: a pool of independently-locked executors.
//!
//! One [`SharedExecutor`] is one serialized inference lane — fine for a
//! single edge device, a bottleneck for a cloud server whose connection
//! workers all funnel through the same mutex. An [`ExecutorPool`] holds
//! `N` executors (one backend instance each: N PJRT clients, or N sim
//! engines), each behind its *own* mutex, so tails from different
//! requests genuinely run in parallel. Callers pick a shard by
//! **affinity** (the cloud server uses the connection id), which keeps
//! one connection's requests on one shard — its compile cache stays
//! hot and cross-shard cache duplication is bounded to the artifacts a
//! shard actually serves.
//!
//! Per-shard run/busy counters feed the stats endpoint's shard
//! utilization report — the observable that tells an operator whether
//! the shard count, not the transport, is the throughput ceiling.

use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::Result;

use super::artifacts::Manifest;
use super::executor::{Executor, SharedExecutor};
use crate::util::fault::FaultPlan;
use crate::util::rng::XorShift64Star;

struct Shard {
    exe: Arc<SharedExecutor>,
    /// Completed executor acquisitions on this shard.
    runs: AtomicU64,
    /// Total nanoseconds spent holding this shard's lock.
    busy_ns: AtomicU64,
    /// Callers currently holding (or queued on) this shard's lock.
    active: AtomicU64,
    /// Routed around while true (panicked, or tripped the latency
    /// watchdog); a background probe re-admits it.
    quarantined: AtomicBool,
}

/// Point-in-time utilization of one shard.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ShardStats {
    pub runs: u64,
    pub busy_seconds: f64,
    pub quarantined: bool,
}

/// Pool-lifetime self-healing counters (stats JSON: `quarantined` /
/// `readmitted` and friends).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct HealthStats {
    /// Shards currently routed around.
    pub quarantined_now: usize,
    /// Quarantine events since the pool was built.
    pub quarantined: u64,
    /// Successful re-admissions after a background probe.
    pub readmitted: u64,
    /// Quarantines caused by the latency watchdog (subset).
    pub watchdog_trips: u64,
    /// Quarantines caused by a shard panic (subset).
    pub panics: u64,
}

/// Shared mutable health state, split from the pool so detached probe
/// threads can outlive (or be outlived by) the pool itself.
#[derive(Default)]
struct Health {
    quarantined_now: AtomicUsize,
    quarantined: AtomicU64,
    readmitted: AtomicU64,
    watchdog_trips: AtomicU64,
    panics: AtomicU64,
}

/// How long a quarantined shard rests before each re-admission probe
/// (nominal; each nap is multiplied by a ±50% jitter draw so a mass
/// quarantine — every shard tripped by one overload spike — does not
/// re-probe in lockstep and re-create the spike).
const PROBE_COOLDOWN: Duration = Duration::from_millis(200);

/// Canary-probe jitter fraction: each probe nap is drawn uniformly
/// from `PROBE_COOLDOWN × (1±this)`.
const PROBE_JITTER: f64 = 0.5;

pub struct ExecutorPool {
    shards: Vec<Arc<Shard>>,
    manifest: Manifest,
    /// Whether this backend executes a stacked batch better than
    /// serially (see [`ExecutorPool::batch_capable`]).
    batch_capable: bool,
    health: Arc<Health>,
    /// Latency watchdog threshold in ms; 0 disables it. A run that
    /// holds a shard longer than this quarantines the shard.
    watchdog_ms: AtomicU64,
    /// Deterministic chaos hook (slow/panicking shard). The flag keeps
    /// the no-faults hot path to one relaxed atomic load.
    faults_on: AtomicBool,
    faults: Mutex<Option<Arc<FaultPlan>>>,
}

impl ExecutorPool {
    /// A pool of `n` PJRT-backed executors, each with its own client
    /// and compile cache. Not batch-capable yet: stage artifacts are
    /// batch-1 programs, so a coalesced batch would execute its
    /// samples serially under one shard lock — worse than letting the
    /// shards run them in parallel. Flips when batched artifacts are
    /// exported (ROADMAP).
    pub fn new_pjrt(manifest: Manifest, n: usize) -> Result<Arc<Self>> {
        let mut shards = Vec::new();
        for _ in 0..n.max(1) {
            shards.push(Arc::new(SharedExecutor::new(manifest.clone())?));
        }
        Ok(Self::from_shards(manifest, shards, false))
    }

    /// A pool of `n` simulated executors (no artifacts needed).
    pub fn new_sim(manifest: Manifest, n: usize) -> Arc<Self> {
        Self::new_sim_with(manifest, n, super::sim::DEFAULT_FANIN)
    }

    /// [`ExecutorPool::new_sim`] with an explicit sim compute fan-in.
    pub fn new_sim_with(manifest: Manifest, n: usize, fanin: usize) -> Arc<Self> {
        let shards = (0..n.max(1))
            .map(|_| {
                Arc::new(SharedExecutor::from_executor(Executor::sim_with(
                    manifest.clone(),
                    fanin,
                )))
            })
            .collect();
        Self::from_shards(manifest, shards, true)
    }

    /// Wrap one existing executor as a single-shard pool (the
    /// compatibility path for callers that built a [`SharedExecutor`]
    /// themselves, and the "serialized" arm of the scaling A/B).
    pub fn from_shared(exe: Arc<SharedExecutor>) -> Arc<Self> {
        let manifest = exe.manifest_clone();
        let capable = exe.with(|e| e.is_sim());
        Self::from_shards(manifest, vec![exe], capable)
    }

    fn from_shards(
        manifest: Manifest,
        exes: Vec<Arc<SharedExecutor>>,
        batch_capable: bool,
    ) -> Arc<Self> {
        Arc::new(Self {
            shards: exes
                .into_iter()
                .map(|exe| {
                    Arc::new(Shard {
                        exe,
                        runs: AtomicU64::new(0),
                        busy_ns: AtomicU64::new(0),
                        active: AtomicU64::new(0),
                        quarantined: AtomicBool::new(false),
                    })
                })
                .collect(),
            manifest,
            batch_capable,
            health: Arc::new(Health::default()),
            watchdog_ms: AtomicU64::new(0),
            faults_on: AtomicBool::new(false),
            faults: Mutex::new(None),
        })
    }

    /// Arm (or disarm, ms = 0) the per-run latency watchdog.
    pub fn set_watchdog_ms(&self, ms: u64) {
        self.watchdog_ms.store(ms, Ordering::Relaxed);
    }

    /// Install the executor-level chaos hook (`slow-shard` /
    /// `panic-shard` in a fault plan). `None` removes it.
    pub fn set_exec_faults(&self, plan: Option<Arc<FaultPlan>>) {
        let on = plan.is_some();
        *self.faults.lock().unwrap_or_else(|e| e.into_inner()) = plan;
        self.faults_on.store(on, Ordering::Release);
    }

    fn fault_plan(&self) -> Option<Arc<FaultPlan>> {
        if !self.faults_on.load(Ordering::Acquire) {
            return None;
        }
        self.faults.lock().unwrap_or_else(|e| e.into_inner()).clone()
    }

    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// True when the backend genuinely amortizes work across a stacked
    /// batch (sim's batched kernel; PJRT once batched artifacts
    /// exist). The batch engine only coalesces on capable pools —
    /// otherwise batching would serialize compute that independent
    /// shards run in parallel.
    pub fn batch_capable(&self) -> bool {
        self.batch_capable
    }

    /// The manifest every shard was built from.
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Run `f` with exclusive access to the shard `affinity` maps to,
    /// recording the hold time in that shard's utilization counters.
    /// Quarantined shards are routed around (next healthy shard, so a
    /// connection's affinity stays stable while the fleet is healthy);
    /// with every shard quarantined the affinity shard serves anyway —
    /// degraded beats unavailable.
    pub fn run_on<R>(&self, affinity: usize, f: impl FnOnce(&Executor) -> R) -> R {
        self.run_on_shard(self.route(affinity % self.shards.len()), f)
    }

    /// First non-quarantined shard at or after `idx` (wrapping); `idx`
    /// itself when none is healthy. One relaxed load when nothing is
    /// quarantined.
    fn route(&self, idx: usize) -> usize {
        if self.health.quarantined_now.load(Ordering::Relaxed) == 0 {
            return idx;
        }
        let n = self.shards.len();
        (0..n)
            .map(|k| (idx + k) % n)
            .find(|&i| !self.shards[i].quarantined.load(Ordering::Relaxed))
            .unwrap_or(idx)
    }

    /// Run `f` on the shard with the fewest callers in flight (ties
    /// break toward the least cumulative busy time). Batch leaders use
    /// this so concurrent batches spread across shards instead of
    /// piling onto one connection's affinity shard.
    pub fn run_on_least_busy<R>(&self, f: impl FnOnce(&Executor) -> R) -> R {
        let healthy_only = self.health.quarantined_now.load(Ordering::Relaxed) > 0;
        let idx = self
            .shards
            .iter()
            .enumerate()
            .filter(|(_, s)| !healthy_only || !s.quarantined.load(Ordering::Relaxed))
            .min_by_key(|(_, s)| {
                (s.active.load(Ordering::Relaxed), s.busy_ns.load(Ordering::Relaxed))
            })
            .map(|(i, _)| i)
            .unwrap_or(0);
        self.run_on_shard(idx, f)
    }

    fn run_on_shard<R>(&self, idx: usize, f: impl FnOnce(&Executor) -> R) -> R {
        // Decrement `active` on unwind too — a leaked count would make
        // least-busy routing shun this shard forever.
        struct ActiveGuard<'a>(&'a AtomicU64);
        impl Drop for ActiveGuard<'_> {
            fn drop(&mut self) {
                self.0.fetch_sub(1, Ordering::SeqCst);
            }
        }
        let shard = &self.shards[idx];
        shard.active.fetch_add(1, Ordering::SeqCst);
        let _active = ActiveGuard(&shard.active);
        let plan = self.fault_plan();
        let t0 = Instant::now();
        // A panic — scripted by the fault hook or organic from the
        // backend — quarantines the shard, then resumes unwinding so
        // callers (batch-leader guards, the epoll completion Drop) see
        // exactly the panic they already handle. `SharedExecutor::with`
        // clears mutex poison, so the shard stays probe-able.
        let out = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            if let Some(p) = &plan {
                p.before_shard_run(idx);
            }
            shard.exe.with(f)
        }));
        let held = t0.elapsed();
        shard.busy_ns.fetch_add(held.as_nanos() as u64, Ordering::Relaxed);
        shard.runs.fetch_add(1, Ordering::Relaxed);
        match out {
            Ok(r) => {
                let watchdog = self.watchdog_ms.load(Ordering::Relaxed);
                if watchdog > 0 && held > Duration::from_millis(watchdog) {
                    self.health.watchdog_trips.fetch_add(1, Ordering::Relaxed);
                    self.quarantine(idx);
                }
                r
            }
            Err(payload) => {
                self.health.panics.fetch_add(1, Ordering::Relaxed);
                self.quarantine(idx);
                std::panic::resume_unwind(payload)
            }
        }
    }

    /// Quarantine shard `idx` (idempotent) and detach a probe thread
    /// that re-admits it once a trial run survives. In-flight work on
    /// the shard drains naturally — the probe queues on the same lock,
    /// so re-admission cannot overtake a still-running request.
    fn quarantine(&self, idx: usize) {
        let shard = &self.shards[idx];
        if shard
            .quarantined
            .compare_exchange(false, true, Ordering::SeqCst, Ordering::SeqCst)
            .is_err()
        {
            return; // already quarantined; its probe thread is running
        }
        self.health.quarantined.fetch_add(1, Ordering::Relaxed);
        self.health.quarantined_now.fetch_add(1, Ordering::SeqCst);
        let shard = Arc::clone(shard);
        let health = Arc::clone(&self.health);
        let plan = self.fault_plan();
        let watchdog = self.watchdog_ms.load(Ordering::Relaxed);
        std::thread::Builder::new()
            .name(format!("shard-probe-{idx}"))
            .spawn(move || {
                // Desynchronise canary probes: a correlated fault that
                // quarantines several shards at once must not have them
                // all hammer the executor on the same 200 ms beat. Each
                // probe thread draws its naps from a private XorShift
                // stream seeded off the shard index.
                let mut rng =
                    XorShift64Star::new(0x9E37_79B9_7F4A_7C15u64.wrapping_mul(idx as u64 + 1));
                loop {
                    let nap = PROBE_COOLDOWN
                        .mul_f64(1.0 + PROBE_JITTER * (2.0 * rng.next_f64() - 1.0));
                    std::thread::sleep(nap);
                    let t0 = Instant::now();
                    let ok = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                        if let Some(p) = &plan {
                            p.before_shard_run(idx);
                        }
                        // Acquiring the lock is the probe: it drains any
                        // in-flight holder and proves the lane responds.
                        shard.exe.with(|_| ());
                    }))
                    .is_ok()
                        && (watchdog == 0 || t0.elapsed() <= Duration::from_millis(watchdog));
                    if ok {
                        shard.quarantined.store(false, Ordering::SeqCst);
                        health.quarantined_now.fetch_sub(1, Ordering::SeqCst);
                        health.readmitted.fetch_add(1, Ordering::Relaxed);
                        return;
                    }
                }
            })
            .expect("spawn shard probe thread");
    }

    /// Current self-healing counters.
    pub fn health_stats(&self) -> HealthStats {
        HealthStats {
            quarantined_now: self.health.quarantined_now.load(Ordering::SeqCst),
            quarantined: self.health.quarantined.load(Ordering::Relaxed),
            readmitted: self.health.readmitted.load(Ordering::Relaxed),
            watchdog_trips: self.health.watchdog_trips.load(Ordering::Relaxed),
            panics: self.health.panics.load(Ordering::Relaxed),
        }
    }

    /// Per-signature compatibility probe: verify — by *executing*, not
    /// assuming — that this pool's backend serves a mixed-model batch
    /// of the two tail routes bit-identically to running each solo. The
    /// batch engine calls this once at construction with a pair of
    /// routes that share a signature class before enabling cross-model
    /// coalescing; any error or bit divergence answers `false` and the
    /// engine falls back to identity keying. Non-batch-capable pools
    /// (PJRT on batch-1 artifacts) answer `false` without running —
    /// they never coalesce at all.
    ///
    /// The probe runs on shard 0 and warms the artifacts it touches,
    /// exactly as the first real request to each route would.
    pub fn probe_xmodel_compat(&self, a: (u16, usize), b: (u16, usize)) -> bool {
        if !self.batch_capable {
            return false;
        }
        let lead = |route: (u16, usize)| -> Option<Vec<f32>> {
            let m = self.manifest.models.get(route.0 as usize)?;
            let n: usize = match m.stages.get(route.1.wrapping_sub(1)) {
                Some(s) => s.in_shape.iter().product(),
                None if route.1 == m.num_stages() + 1 => m.num_classes,
                None => return None,
            };
            Some(
                (0..n)
                    .map(|i| {
                        let h = ((i + 1 + route.0 as usize * 63) as u64)
                            .wrapping_mul(0x9E37_79B9_7F4A_7C15);
                        ((h >> 44) & 0xFFF) as f32 / 409.6 - 2.0
                    })
                    .collect(),
            )
        };
        let (Some(xa), Some(xb)) = (lead(a), lead(b)) else { return false };
        let solo = |route: (u16, usize), x: &[f32]| -> Option<Vec<f32>> {
            let mut one = vec![x.to_vec()];
            self.run_on(0, |e| e.run_tail_batch_multi(&[route], &mut one)).ok()?;
            one.pop()
        };
        let (Some(sa), Some(sb)) = (solo(a, &xa), solo(b, &xb)) else { return false };
        let mut mixed = vec![xa, xb];
        if self.run_on(0, |e| e.run_tail_batch_multi(&[a, b], &mut mixed)).is_err() {
            return false;
        }
        let bits = |v: &[f32]| v.iter().map(|f| f.to_bits()).collect::<Vec<u32>>();
        bits(&mixed[0]) == bits(&sa) && bits(&mixed[1]) == bits(&sb)
    }

    /// Callers currently holding or queued on any shard's lock — the
    /// "work in flight right now" signal (admission control uses it to
    /// distinguish a stalled window from an idle one).
    pub fn active_count(&self) -> u64 {
        self.shards.iter().map(|s| s.active.load(Ordering::SeqCst)).sum()
    }

    /// Compiled artifacts summed across shards (each shard has its own
    /// cache, so the sum counts per-shard duplicates — by design).
    pub fn cached_count(&self) -> usize {
        self.shards.iter().map(|s| s.exe.cached_count()).sum()
    }

    /// Cumulative compile seconds summed across shards.
    pub fn compile_seconds(&self) -> f64 {
        self.shards.iter().map(|s| s.exe.with(|e| e.compile_seconds())).sum()
    }

    pub fn shard_stats(&self) -> Vec<ShardStats> {
        self.shards
            .iter()
            .map(|s| ShardStats {
                runs: s.runs.load(Ordering::Relaxed),
                busy_seconds: s.busy_ns.load(Ordering::Relaxed) as f64 / 1e9,
                quarantined: s.quarantined.load(Ordering::Relaxed),
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::sim::sim_manifest;
    use crate::runtime::Tensor;

    #[test]
    fn affinity_is_stable_modulo_shards() {
        let pool = ExecutorPool::new_sim_with(sim_manifest(), 3, 4);
        assert_eq!(pool.shard_count(), 3);
        for conn in 0..9 {
            pool.run_on(conn, |_| ());
        }
        let stats = pool.shard_stats();
        // 9 connections over 3 shards, round-robin by id: 3 runs each.
        assert!(stats.iter().all(|s| s.runs == 3), "{stats:?}");
    }

    #[test]
    fn shards_compute_independently_and_identically() {
        let pool = ExecutorPool::new_sim_with(sim_manifest(), 4, 8);
        let shape = pool.manifest().model("simnet").unwrap().input_shape.clone();
        let x = crate::data::gen::sample_image_shaped(0, 5, &shape);
        let outs: Vec<Tensor> = (0..4)
            .map(|a| pool.run_on(a, |e| e.run_full("simnet", &x).unwrap().tensor))
            .collect();
        for o in &outs[1..] {
            assert!(o
                .data()
                .iter()
                .zip(outs[0].data())
                .all(|(p, q)| p.to_bits() == q.to_bits()));
        }
    }

    #[test]
    fn parallel_shards_serve_concurrently() {
        let pool = ExecutorPool::new_sim_with(sim_manifest(), 4, 16);
        let shape = pool.manifest().model("simnet").unwrap().input_shape.clone();
        let handles: Vec<_> = (0..8)
            .map(|t| {
                let pool = Arc::clone(&pool);
                let shape = shape.clone();
                std::thread::spawn(move || {
                    let x = crate::data::gen::sample_image_shaped(t % 4, t, &shape);
                    for _ in 0..10 {
                        pool.run_on(t, |e| e.run_full("simnet", &x).unwrap());
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        let total: u64 = pool.shard_stats().iter().map(|s| s.runs).sum();
        assert_eq!(total, 80);
    }

    #[test]
    fn least_busy_spreads_concurrent_work() {
        let pool = ExecutorPool::new_sim_with(sim_manifest(), 4, 16);
        let shape = pool.manifest().model("simnet").unwrap().input_shape.clone();
        let handles: Vec<_> = (0..8)
            .map(|t| {
                let pool = Arc::clone(&pool);
                let shape = shape.clone();
                std::thread::spawn(move || {
                    let x = crate::data::gen::sample_image_shaped(t % 4, t, &shape);
                    for _ in 0..12 {
                        pool.run_on_least_busy(|e| e.run_full("simnet", &x).unwrap());
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        let stats = pool.shard_stats();
        let total: u64 = stats.iter().map(|s| s.runs).sum();
        assert_eq!(total, 96);
        let used = stats.iter().filter(|s| s.runs > 0).count();
        assert!(used >= 2, "least-busy routing never left shard 0: {stats:?}");
    }

    #[test]
    fn xmodel_probe_accepts_compatible_and_rejects_incompatible_routes() {
        let pool = ExecutorPool::new_sim_with(crate::runtime::sim::sim_manifest_fleet(2), 2, 8);
        // Shared-signature pair (exact) and padded pair: both verify.
        assert!(pool.probe_xmodel_compat((0, 2), (1, 2)));
        assert!(pool.probe_xmodel_compat((0, 3), (2, 3)), "padnet padded pair");
        // Structurally incompatible (different depths) or bogus routes:
        // the probe must answer false, not panic.
        assert!(!pool.probe_xmodel_compat((0, 2), (0, 3)));
        assert!(!pool.probe_xmodel_compat((0, 2), (99, 2)));
        assert!(!pool.probe_xmodel_compat((0, 0), (1, 0)));
    }

    #[test]
    fn from_shared_is_single_shard() {
        let exe = Arc::new(SharedExecutor::from_executor(Executor::sim_with(sim_manifest(), 4)));
        let pool = ExecutorPool::from_shared(exe);
        assert_eq!(pool.shard_count(), 1);
        assert_eq!(pool.manifest().models.len(), 1);
    }

    /// Block until `cond` holds or ~3 s pass (probe threads pace
    /// themselves on `PROBE_COOLDOWN`, so health transitions are
    /// eventually-consistent).
    fn wait_for(mut cond: impl FnMut() -> bool) -> bool {
        for _ in 0..300 {
            if cond() {
                return true;
            }
            std::thread::sleep(Duration::from_millis(10));
        }
        false
    }

    #[test]
    fn panicking_shard_is_quarantined_routed_around_and_readmitted() {
        let pool = ExecutorPool::new_sim_with(sim_manifest(), 3, 4);
        pool.set_exec_faults(Some(FaultPlan::parse_arc("panic-shard=1,panic-count=1").unwrap()));

        // The scripted panic fires on the first run routed to shard 1
        // and must propagate to the caller.
        let hit = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.run_on(1, |_| ());
        }));
        assert!(hit.is_err(), "scripted panic must unwind to the caller");
        let h = pool.health_stats();
        assert_eq!((h.panics, h.quarantined, h.quarantined_now), (1, 1, 1));
        assert!(pool.shard_stats()[1].quarantined);

        // Affinity 1 now routes to the next healthy shard (2), and the
        // quarantined shard takes no traffic.
        let before = pool.shard_stats();
        for _ in 0..4 {
            pool.run_on(1, |_| ());
        }
        let after = pool.shard_stats();
        assert_eq!(after[1].runs, before[1].runs, "quarantined shard must take no traffic");
        assert_eq!(after[2].runs, before[2].runs + 4);

        // The panic budget is spent, so the background probe readmits.
        assert!(
            wait_for(|| pool.health_stats().quarantined_now == 0),
            "shard must be readmitted once the probe survives: {:?}",
            pool.health_stats()
        );
        assert_eq!(pool.health_stats().readmitted, 1);
        assert!(!pool.shard_stats()[1].quarantined);
        // And affinity routing is back to normal.
        let before = pool.shard_stats();
        pool.run_on(1, |_| ());
        assert_eq!(pool.shard_stats()[1].runs, before[1].runs + 1);
    }

    #[test]
    fn watchdog_quarantines_slow_shard_and_probe_keeps_it_out() {
        let pool = ExecutorPool::new_sim_with(sim_manifest(), 2, 4);
        pool.set_watchdog_ms(40);
        pool.set_exec_faults(Some(FaultPlan::parse_arc("slow-shard=0,slow-ms=120").unwrap()));

        // The run completes (slow, not broken) but trips the watchdog.
        pool.run_on(0, |_| ());
        let h = pool.health_stats();
        assert_eq!((h.watchdog_trips, h.quarantined_now), (1, 1));

        // The shard is still slow, so probes keep failing: after a few
        // cooldowns it must remain quarantined and unrouted.
        std::thread::sleep(Duration::from_millis(500));
        assert_eq!(pool.health_stats().quarantined_now, 1);
        assert_eq!(pool.health_stats().readmitted, 0);
        let before = pool.shard_stats();
        pool.run_on(0, |_| ());
        assert_eq!(pool.shard_stats()[1].runs, before[1].runs + 1);
    }

    #[test]
    fn all_quarantined_still_serves() {
        let pool = ExecutorPool::new_sim_with(sim_manifest(), 1, 4);
        pool.set_exec_faults(Some(FaultPlan::parse_arc("panic-shard=0,panic-count=1").unwrap()));
        let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| pool.run_on(0, |_| ())));
        assert_eq!(pool.health_stats().quarantined_now, 1);
        // Degraded beats unavailable: the only shard serves anyway.
        let shape = pool.manifest().model("simnet").unwrap().input_shape.clone();
        let x = crate::data::gen::sample_image_shaped(0, 5, &shape);
        pool.run_on(0, |e| e.run_full("simnet", &x).unwrap());
        assert!(wait_for(|| pool.health_stats().readmitted == 1));
    }

    #[test]
    fn least_busy_skips_quarantined_shards() {
        let pool = ExecutorPool::new_sim_with(sim_manifest(), 2, 4);
        pool.set_exec_faults(Some(FaultPlan::parse_arc("slow-shard=0,slow-ms=60").unwrap()));
        pool.set_watchdog_ms(20);
        pool.run_on(0, |_| ()); // trips the watchdog on shard 0
        pool.set_exec_faults(None);
        let before = pool.shard_stats();
        for _ in 0..3 {
            pool.run_on_least_busy(|_| ());
        }
        let after = pool.shard_stats();
        assert_eq!(after[0].runs, before[0].runs, "least-busy must skip the quarantined shard");
        assert_eq!(after[1].runs, before[1].runs + 3);
    }
}
