//! Stage executor with a lazy, race-free compile cache and two
//! interchangeable backends.
//!
//! One `Executor` wraps one inference backend:
//! * **PJRT** ([`Executor::new`]) — one PJRT CPU client; HLO text
//!   artifacts compile on first use and are cached. Compilation is tens
//!   of milliseconds per stage while execution is micro/milliseconds,
//!   so the cache is what keeps re-decoupling cheap: switching
//!   `(i*, c)` never recompiles anything already seen. The cache is a
//!   [`OnceMap`], so two threads that miss the same artifact
//!   concurrently compile it exactly once (the loser waits).
//! * **Sim** ([`Executor::sim`]) — the deterministic host-compute
//!   stand-in from [`super::sim`]; needs no artifacts and no PJRT
//!   runtime, used by the serving benches/tests and available as a
//!   backend for the sharded cloud engine.
//!
//! Calling conventions (all lowered with `return_tuple=True`):
//! * stage:   (x: f32[in_shape])                  -> (y,)
//! * full:    (x: f32[input_shape])               -> (logits,)
//! * quant:   (x: f32[n], c: f32[])               -> (y, lo, hi)
//! * dequant: (y: f32[n], lo, hi, c: f32[])       -> (x̂[out_shape],)
//!
//! [`Executor::run_tail_batch`] is the micro-batch entry point: it runs
//! the tail of the network for a whole batch of flat activations in one
//! call (one lock acquisition when reached through [`SharedExecutor`]),
//! replacing each input buffer with its logits in place.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{anyhow, Context, Result};

use super::artifacts::Manifest;
use super::sim::SimBackend;
use super::tensor::Tensor;
use crate::compression::quant::{self, Quantized};
use crate::util::once_map::OnceMap;

enum Backend {
    Pjrt(xla::PjRtClient),
    Sim(SimBackend),
}

pub struct Executor {
    backend: Backend,
    manifest: Manifest,
    cache: OnceMap<String, Arc<xla::PjRtLoadedExecutable>>,
    /// Lock-free mirror of the PJRT cache size, shared out through
    /// [`Executor::compiled_handle`] so stats endpoints never queue
    /// behind in-flight compute to read it.
    compiled: Arc<AtomicUsize>,
    /// Cumulative compile time, for the metrics endpoint.
    compile_seconds: Mutex<f64>,
    /// Reusable staging buffer for the sim batched-tail kernel. The
    /// executor is already exclusively held whenever it runs (shard
    /// mutex), so this lock is uncontended — it exists only to give
    /// `&self` interior mutability while keeping the buffer's
    /// capacity across requests (no per-request allocation inside the
    /// shard lock).
    tail_scratch: Mutex<Vec<f32>>,
}

/// Output of a stage execution plus its wall-clock cost.
#[derive(Debug, Clone)]
pub struct StageOutput {
    pub tensor: Tensor,
    pub seconds: f64,
}

impl Executor {
    /// PJRT-backed executor (the production path; needs artifacts).
    pub fn new(manifest: Manifest) -> Result<Self> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e}"))?;
        Ok(Self {
            backend: Backend::Pjrt(client),
            manifest,
            cache: OnceMap::new(),
            compiled: Arc::new(AtomicUsize::new(0)),
            compile_seconds: Mutex::new(0.0),
            tail_scratch: Mutex::new(Vec::new()),
        })
    }

    /// Simulated executor (deterministic host compute, no artifacts).
    pub fn sim(manifest: Manifest) -> Self {
        Self::sim_with(manifest, super::sim::DEFAULT_FANIN)
    }

    /// [`Executor::sim`] with an explicit per-element fan-in — the knob
    /// for how much CPU each simulated stage burns.
    pub fn sim_with(manifest: Manifest, fanin: usize) -> Self {
        Self {
            backend: Backend::Sim(SimBackend::new(fanin)),
            manifest,
            cache: OnceMap::new(),
            compiled: Arc::new(AtomicUsize::new(0)),
            compile_seconds: Mutex::new(0.0),
            tail_scratch: Mutex::new(Vec::new()),
        }
    }

    /// Shared handle to the compiled/warmed-artifact count — readable
    /// without locking the executor (stats never wait on inference).
    pub fn compiled_handle(&self) -> Arc<AtomicUsize> {
        match &self.backend {
            Backend::Pjrt(_) => Arc::clone(&self.compiled),
            Backend::Sim(sim) => sim.warmed_handle(),
        }
    }

    pub fn is_sim(&self) -> bool {
        matches!(self.backend, Backend::Sim(_))
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn compile_seconds(&self) -> f64 {
        *self.compile_seconds.lock().unwrap()
    }

    /// Fetch-or-compile the executable for an artifact file name.
    /// Concurrent first accesses compile exactly once: the `OnceMap`
    /// holds a per-key in-flight marker, so the second thread parks
    /// until the first finishes instead of compiling a duplicate.
    fn executable(&self, file: &str) -> Result<Arc<xla::PjRtLoadedExecutable>> {
        let Backend::Pjrt(client) = &self.backend else {
            return Err(anyhow!("sim backend has no PJRT executables"));
        };
        self.cache.get_or_try_build(file, || {
            let path = self.manifest.artifact_path(file);
            let t0 = Instant::now();
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
            )
            .map_err(|e| anyhow!("parsing HLO text {path:?}: {e}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .map_err(|e| anyhow!("compiling {file}: {e}"))?;
            *self.compile_seconds.lock().unwrap() += t0.elapsed().as_secs_f64();
            self.compiled.fetch_add(1, Ordering::Relaxed);
            Ok(Arc::new(exe))
        })
    }

    /// Warm the cache for a set of artifacts (server startup).
    pub fn precompile(&self, files: &[&str]) -> Result<()> {
        for f in files {
            match &self.backend {
                Backend::Pjrt(_) => {
                    self.executable(f)?;
                }
                Backend::Sim(sim) => sim.warm(f),
            }
        }
        Ok(())
    }

    pub fn cached_count(&self) -> usize {
        match &self.backend {
            Backend::Pjrt(_) => self.cache.len(),
            Backend::Sim(sim) => sim.warmed_count(),
        }
    }

    fn run(&self, file: &str, inputs: &[xla::Literal]) -> Result<xla::Literal> {
        let exe = self.executable(file)?;
        let result = exe
            .execute::<xla::Literal>(inputs)
            .map_err(|e| anyhow!("executing {file}: {e}"))?;
        result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching result of {file}: {e}"))
    }

    /// Run stage `i` (1-based) of `model` on an activation.
    pub fn run_stage(&self, model: &str, i: usize, x: &Tensor) -> Result<StageOutput> {
        let m = self.manifest.model(model)?;
        let stage = m
            .stages
            .get(i - 1)
            .ok_or_else(|| anyhow!("{model} has {} stages, asked {i}", m.stages.len()))?;
        if x.shape() != stage.in_shape.as_slice() {
            return Err(anyhow!(
                "{model} stage {i} expects {:?}, got {:?}",
                stage.in_shape,
                x.shape()
            ));
        }
        let t0 = Instant::now();
        let tensor = match &self.backend {
            Backend::Pjrt(_) => {
                let out = self.run(&stage.artifact, &[x.to_literal()])?;
                let lit =
                    out.to_tuple1().map_err(|e| anyhow!("stage output unwrap: {e}"))?;
                Tensor::from_literal(&lit)?
            }
            Backend::Sim(sim) => {
                let mut out = Vec::new();
                sim.stage_into(stage, x.data(), &mut out)?;
                Tensor::new(stage.out_shape.clone(), out)
            }
        };
        Ok(StageOutput { tensor, seconds: t0.elapsed().as_secs_f64() })
    }

    /// Run stages `from..=to` (1-based, inclusive) sequentially.
    pub fn run_stages(
        &self,
        model: &str,
        from: usize,
        to: usize,
        x: &Tensor,
    ) -> Result<StageOutput> {
        let mut cur = x.clone();
        let mut total = 0.0;
        for i in from..=to {
            let out = self.run_stage(model, i, &cur)?;
            cur = out.tensor;
            total += out.seconds;
        }
        Ok(StageOutput { tensor: cur, seconds: total })
    }

    /// Whole-model forward (cloud-only baselines, i* = 0).
    pub fn run_full(&self, model: &str, x: &Tensor) -> Result<StageOutput> {
        let m = self.manifest.model(model)?;
        match &self.backend {
            Backend::Pjrt(_) => {
                let t0 = Instant::now();
                let out = self.run(&m.full_artifact, &[x.to_literal()])?;
                let lit = out.to_tuple1().map_err(|e| anyhow!("full output unwrap: {e}"))?;
                Ok(StageOutput {
                    tensor: Tensor::from_literal(&lit)?,
                    seconds: t0.elapsed().as_secs_f64(),
                })
            }
            // Sim has no separate fused-forward program: the stage chain
            // *is* the full model (and is bit-identical to it).
            Backend::Sim(sim) => {
                sim.warm(&m.full_artifact);
                self.run_stages(model, 1, m.num_stages(), x)
            }
        }
    }

    /// Run the tail `from..=N` of `model` for a whole batch of flat
    /// activations in one call. Each `Vec` in `batch` holds one
    /// sample's stage-`from-1` output and is replaced in place by that
    /// sample's logits (capacity reused — nothing is returned by
    /// allocation). `from > N` is the "cut at the last stage" case: the
    /// activations already are the logits, so the batch is untouched.
    ///
    /// Per-sample results are bit-identical to running
    /// [`Executor::run_stages`] on each sample alone: the sim backend
    /// walks the stacked batch stage-major but applies the identical
    /// per-sample kernel, and the PJRT backend executes the (batch-1)
    /// stage executables back to back — batching there amortizes lock
    /// acquisition and scheduling, not the MACs, until batched
    /// artifacts are exported (see ROADMAP).
    pub fn run_tail_batch(
        &self,
        model: &str,
        from: usize,
        batch: &mut [Vec<f32>],
    ) -> Result<f64> {
        let m = self.manifest.model(model)?;
        let n = m.num_stages();
        if from == 0 {
            return Err(anyhow!("tail stages are 1-based; from=0 is the whole model"));
        }
        if from > n {
            return Ok(0.0);
        }
        let expect: usize = m.stages[from - 1].in_shape.iter().product();
        for (s, sample) in batch.iter().enumerate() {
            if sample.len() != expect {
                return Err(anyhow!(
                    "{model} tail from stage {from}: sample {s} has {} elements, expected {expect}",
                    sample.len()
                ));
            }
        }
        let t0 = Instant::now();
        match &self.backend {
            Backend::Sim(sim) => {
                // Stage-major over the stacked batch: one pass per stage
                // derives each tap/weight once and applies it to every
                // sample (the batched kernel). The staging buffer is
                // the executor's reusable scratch — capacity persists
                // across requests, so the warm path allocates nothing
                // inside the shard lock.
                let mut stacked = self.tail_scratch.lock().unwrap();
                for i in from..=n {
                    sim.stage_batch_into(&m.stages[i - 1], batch, &mut stacked)?;
                }
            }
            Backend::Pjrt(_) => {
                let in_shape = m.stages[from - 1].in_shape.clone();
                for sample in batch.iter_mut() {
                    // Move the activation into a Tensor and chain stages
                    // by value — no clone of the full activation inside
                    // the shard lock (run_stages would start with one).
                    let mut cur = Tensor::new(in_shape.clone(), std::mem::take(sample));
                    for i in from..=n {
                        cur = self.run_stage(model, i, &cur)?.tensor;
                    }
                    *sample = cur.into_data();
                }
            }
        }
        Ok(t0.elapsed().as_secs_f64())
    }

    /// [`Executor::run_tail_batch`] over a **mixed-model** sample set:
    /// `routes[i] = (model_id, from)` names sample `i`'s tail. The
    /// engine only builds such batches from tails whose
    /// [`TailSignature`](super::artifacts::TailSignature)s share a
    /// coalescing class, but the executor re-validates structurally —
    /// every lockstep position must agree on stage index and output
    /// geometry (the stage kernel is fully determined by those plus the
    /// sample's own input length) — and errors out rather than compute
    /// something silently wrong.
    ///
    /// Sim backend: one batched program — stage-major over the whole
    /// mixed batch, the padded kernel grouping samples by leading
    /// length where geometries differ; per-sample results are
    /// bit-identical to running each sample's own tail alone. PJRT:
    /// batch-1 programs back to back per sample (the pool reports
    /// `batch_capable = false` there, so the engine never builds mixed
    /// batches for it; this arm exists for API completeness).
    pub fn run_tail_batch_multi(
        &self,
        routes: &[(u16, usize)],
        batch: &mut [Vec<f32>],
    ) -> Result<f64> {
        if routes.len() != batch.len() {
            return Err(anyhow!(
                "mixed tail batch: {} routes for {} samples",
                routes.len(),
                batch.len()
            ));
        }
        let models = &self.manifest.models;
        let by_id = |model_id: u16| {
            models.get(model_id as usize).ok_or_else(|| anyhow!("bad model id {model_id}"))
        };
        let Some(&first) = routes.first() else { return Ok(0.0) };
        if routes.iter().all(|&r| r == first) {
            // Homogeneous batch: the single-model path (fast, and the
            // same code lone requests take).
            return self.run_tail_batch(&by_id(first.0)?.name, first.1, batch);
        }
        // Resolve each sample's remaining stage list and validate its
        // own leading geometry before any compute.
        let mut tails: Vec<&[super::artifacts::StageManifest]> = Vec::with_capacity(routes.len());
        for (s, &(model_id, from)) in routes.iter().enumerate() {
            let m = by_id(model_id)?;
            if from == 0 {
                return Err(anyhow!("tail stages are 1-based; from=0 is the whole model"));
            }
            let tail = if from > m.num_stages() { &[][..] } else { &m.stages[from - 1..] };
            if let Some(stage) = tail.first() {
                let expect: usize = stage.in_shape.iter().product();
                if batch[s].len() != expect {
                    return Err(anyhow!(
                        "{} tail from stage {from}: sample {s} has {} elements, expected {expect}",
                        m.name,
                        batch[s].len()
                    ));
                }
            }
            tails.push(tail);
        }
        let steps = tails[0].len();
        if tails.iter().any(|t| t.len() != steps) {
            return Err(anyhow!("mixed tail batch: members have different tail depths"));
        }
        let t0 = Instant::now();
        match &self.backend {
            Backend::Sim(sim) => {
                let mut stacked = self.tail_scratch.lock().unwrap();
                for step in 0..steps {
                    let rep = &tails[0][step];
                    for (s, tail) in tails.iter().enumerate() {
                        let stage = &tail[step];
                        if stage.index != rep.index || stage.out_elems != rep.out_elems {
                            return Err(anyhow!(
                                "mixed tail batch: sample {s} stage {} ({} elems out) is not \
                                 signature-compatible with stage {} ({} elems out)",
                                stage.index,
                                stage.out_elems,
                                rep.index,
                                rep.out_elems
                            ));
                        }
                        // Keep cached_count parity with solo execution:
                        // each member's own artifact counts as warmed.
                        sim.warm(&stage.artifact);
                    }
                    sim.stage_batch_padded_into(rep, batch, &mut stacked)?;
                }
            }
            Backend::Pjrt(_) => {
                for (s, &(model_id, from)) in routes.iter().enumerate() {
                    let mut one = [std::mem::take(&mut batch[s])];
                    self.run_tail_batch(&by_id(model_id)?.name, from, &mut one)?;
                    let [out] = one;
                    batch[s] = out;
                }
            }
        }
        Ok(t0.elapsed().as_secs_f64())
    }

    /// Quantize via the exported L1 Pallas kernel: (x[n], c) → Quantized.
    pub fn run_quant(&self, x: &Tensor, c: u8) -> Result<Quantized> {
        let n = x.len();
        let file = self
            .manifest
            .codecs
            .quant
            .get(&n)
            .ok_or_else(|| anyhow!("no quant artifact for n={n}"))?;
        if let Backend::Sim(sim) = &self.backend {
            // The rust twin computes the same quantization the Pallas
            // kernel does (`pallas_quant_matches_rust_twin` asserts
            // exact value equality when artifacts exist), so sim mode
            // routes straight through it.
            sim.warm(file);
            return Ok(quant::quantize(x.data(), c));
        }
        let flat = x.clone().flattened();
        let out = self.run(file, &[flat.to_literal(), Tensor::scalar(c as f32).to_literal()])?;
        let (y, lo, hi) = out.to_tuple3().map_err(|e| anyhow!("quant unwrap: {e}"))?;
        let values: Vec<u16> =
            y.to_vec::<f32>()?.into_iter().map(|v| v as u16).collect();
        Ok(Quantized {
            values,
            lo: lo.get_first_element::<f32>()?,
            hi: hi.get_first_element::<f32>()?,
            c,
        })
    }

    /// Dequantize via the exported L1 Pallas kernel into `shape`.
    pub fn run_dequant(&self, q: &Quantized, shape: &[usize]) -> Result<Tensor> {
        self.run_dequant_parts(&q.values, q.lo, q.hi, q.c, shape)
    }

    /// [`Executor::run_dequant`] over borrowed parts — lets servers keep
    /// decoded values in a pooled buffer instead of building a
    /// [`Quantized`] per request. (The serving hot path no longer comes
    /// through here at all: the cloud server dequantizes natively on the
    /// connection worker via `quant::dequantize_into` before the tail —
    /// this entry point remains for the codec cross-checks and any
    /// caller that wants the kernel itself.)
    pub fn run_dequant_parts(
        &self,
        values: &[u16],
        lo: f32,
        hi: f32,
        c: u8,
        shape: &[usize],
    ) -> Result<Tensor> {
        let file = self
            .manifest
            .codecs
            .dequant
            .get(shape)
            .ok_or_else(|| anyhow!("no dequant artifact for shape {shape:?}"))?;
        if let Backend::Sim(sim) = &self.backend {
            sim.warm(file);
            let mut out = Vec::new();
            quant::dequantize_into(values, lo, hi, c, &mut out);
            return Ok(Tensor::new(shape.to_vec(), out));
        }
        let y: Vec<f32> = values.iter().map(|&v| v as f32).collect();
        let yt = Tensor::new(vec![y.len()], y);
        let out = self.run(
            file,
            &[
                yt.to_literal(),
                Tensor::scalar(lo).to_literal(),
                Tensor::scalar(hi).to_literal(),
                Tensor::scalar(c as f32).to_literal(),
            ],
        )?;
        let lit = out.to_tuple1().map_err(|e| anyhow!("dequant unwrap: {e}"))?;
        Tensor::from_literal(&lit).context("dequant output")
    }
}

/// Thread-safe wrapper: serializes all backend access behind one mutex.
///
/// The `xla` crate's handles are `Rc` + raw pointers (not `Send`), but
/// every object lives strictly inside [`Executor`] — its public API only
/// traffics in plain-rust `Tensor`/`Quantized` values, and literals are
/// created/destroyed inside the locked region. With exclusive access
/// enforced by the mutex no `Rc` refcount or XLA object is ever touched
/// from two threads at once, which makes the `Send + Sync` assertion
/// sound. One `SharedExecutor` is one serialized inference lane; the
/// cloud engine scales out with a [`super::pool::ExecutorPool`] of
/// independently-locked lanes.
pub struct SharedExecutor {
    inner: Mutex<Executor>,
    /// Compile-cache size handle grabbed at construction: stats reads
    /// (`cached_count`) never wait on the inference lock.
    compiled: Arc<AtomicUsize>,
}

unsafe impl Send for SharedExecutor {}
unsafe impl Sync for SharedExecutor {}

impl SharedExecutor {
    pub fn new(manifest: Manifest) -> Result<Self> {
        Ok(Self::from_executor(Executor::new(manifest)?))
    }

    pub fn from_executor(exe: Executor) -> Self {
        let compiled = exe.compiled_handle();
        Self { inner: Mutex::new(exe), compiled }
    }

    /// Run `f` with exclusive access to the executor.
    ///
    /// Poison-tolerant: a panic inside one closure (a poisoned shard
    /// under fault injection, or a backend bug) must not condemn the
    /// lane forever — the executor holds no partially-mutated rust
    /// state across a panic (XLA handles are created and destroyed
    /// within a single call), so clearing the poison is sound, and the
    /// pool's quarantine machinery decides whether the lane keeps
    /// serving.
    pub fn with<R>(&self, f: impl FnOnce(&Executor) -> R) -> R {
        let g = self.inner.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
        f(&g)
    }

    pub fn run_stage(&self, model: &str, i: usize, x: &Tensor) -> Result<StageOutput> {
        self.with(|e| e.run_stage(model, i, x))
    }

    pub fn run_full(&self, model: &str, x: &Tensor) -> Result<StageOutput> {
        self.with(|e| e.run_full(model, x))
    }

    pub fn run_quant(&self, x: &Tensor, c: u8) -> Result<Quantized> {
        self.with(|e| e.run_quant(x, c))
    }

    pub fn run_dequant(&self, q: &Quantized, shape: &[usize]) -> Result<Tensor> {
        self.with(|e| e.run_dequant(q, shape))
    }

    pub fn run_dequant_parts(
        &self,
        values: &[u16],
        lo: f32,
        hi: f32,
        c: u8,
        shape: &[usize],
    ) -> Result<Tensor> {
        self.with(|e| e.run_dequant_parts(values, lo, hi, c, shape))
    }

    /// One lock acquisition for a whole micro-batch tail.
    pub fn run_tail_batch(&self, model: &str, from: usize, batch: &mut [Vec<f32>]) -> Result<f64> {
        self.with(|e| e.run_tail_batch(model, from, batch))
    }

    /// One lock acquisition for a whole mixed-model micro-batch tail.
    pub fn run_tail_batch_multi(
        &self,
        routes: &[(u16, usize)],
        batch: &mut [Vec<f32>],
    ) -> Result<f64> {
        self.with(|e| e.run_tail_batch_multi(routes, batch))
    }

    pub fn manifest_clone(&self) -> Manifest {
        self.with(|e| e.manifest().clone())
    }

    /// Compiled-artifact count without taking the inference lock — a
    /// Stats frame must never queue behind a long compile or batch.
    pub fn cached_count(&self) -> usize {
        self.compiled.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    //! PJRT tests run against the real artifacts and skip silently when
    //! `make artifacts` has not run yet; sim tests always run.
    use super::*;
    use crate::compression::quant;
    use crate::runtime::sim::sim_manifest;

    fn executor() -> Option<Executor> {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            return None;
        }
        Some(Executor::new(Manifest::load(dir).unwrap()).unwrap())
    }

    fn input_for(exe: &Executor, model: &str) -> Tensor {
        let shape = exe.manifest().model(model).unwrap().input_shape.clone();
        crate::data::gen::sample_image_shaped(0, 0, &shape)
    }

    #[test]
    fn stage_chain_matches_full_forward() {
        let Some(exe) = executor() else { return };
        for model in ["tinyconv", "vgg16"] {
            let x = input_for(&exe, model);
            let n = exe.manifest().model(model).unwrap().num_stages();
            let chained = exe.run_stages(model, 1, n, &x).unwrap().tensor;
            let full = exe.run_full(model, &x).unwrap().tensor;
            assert_eq!(chained.shape(), full.shape());
            for (a, b) in chained.data().iter().zip(full.data()) {
                assert!((a - b).abs() < 1e-3, "{model}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn pallas_quant_matches_rust_twin() {
        let Some(exe) = executor() else { return };
        let x = input_for(&exe, "tinyconv");
        let mid = exe.run_stage("tinyconv", 1, &x).unwrap().tensor;
        for c in [1u8, 4, 8] {
            let via_pjrt = exe.run_quant(&mid, c).unwrap();
            let via_rust = quant::quantize(mid.data(), c);
            assert_eq!(via_pjrt.values, via_rust.values, "c={c}");
            assert!((via_pjrt.lo - via_rust.lo).abs() < 1e-6);
            assert!((via_pjrt.hi - via_rust.hi).abs() < 1e-6);
        }
    }

    /// The serving path dequantizes through the rust twin
    /// (`quant::dequantize_into` on the connection worker) instead of
    /// the L1 dequant artifact; this pins the two implementations
    /// together so kernel drift can't silently change served logits.
    /// Tolerance is a tight epsilon, not bit equality — XLA may fuse
    /// the affine multiply-add differently, and anything beyond ~1 ulp
    /// of the scale means a formula divergence, which this catches.
    #[test]
    fn pallas_dequant_matches_rust_twin() {
        let Some(exe) = executor() else { return };
        let x = input_for(&exe, "tinyconv");
        let mid = exe.run_stage("tinyconv", 1, &x).unwrap().tensor;
        for c in [1u8, 4, 8, 12] {
            let q = exe.run_quant(&mid, c).unwrap();
            let via_pjrt = exe.run_dequant(&q, mid.shape()).unwrap();
            let via_rust = quant::dequantize(&q);
            assert_eq!(via_pjrt.len(), via_rust.len());
            let scale = (q.hi - q.lo).abs().max(1.0);
            for (i, (a, b)) in via_pjrt.data().iter().zip(&via_rust).enumerate() {
                assert!(
                    (a - b).abs() <= scale * 1e-6,
                    "c={c} elem {i}: artifact {a} vs twin {b} — dequant kernels diverged"
                );
            }
        }
    }

    #[test]
    fn pallas_dequant_roundtrip() {
        let Some(exe) = executor() else { return };
        let x = input_for(&exe, "tinyconv");
        let mid = exe.run_stage("tinyconv", 1, &x).unwrap().tensor;
        let q = exe.run_quant(&mid, 8).unwrap();
        let back = exe.run_dequant(&q, mid.shape()).unwrap();
        assert_eq!(back.shape(), mid.shape());
        let bound = quant::error_bound(q.lo, q.hi, 8) * 1.001;
        for (a, b) in mid.data().iter().zip(back.data()) {
            assert!((a - b).abs() <= bound);
        }
    }

    #[test]
    fn compile_cache_hits() {
        let Some(exe) = executor() else { return };
        let x = input_for(&exe, "tinyconv");
        let _ = exe.run_stage("tinyconv", 1, &x).unwrap();
        let cached = exe.cached_count();
        let _ = exe.run_stage("tinyconv", 1, &x).unwrap();
        assert_eq!(exe.cached_count(), cached, "second run must not compile");
    }

    #[test]
    fn shape_mismatch_rejected() {
        let Some(exe) = executor() else { return };
        let bad = Tensor::zeros(vec![1, 2, 2, 3]);
        assert!(exe.run_stage("tinyconv", 1, &bad).is_err());
    }

    // ---- sim backend (always runs) ----

    fn sim_exe() -> Executor {
        Executor::sim_with(sim_manifest(), 16)
    }

    fn sim_input(exe: &Executor) -> Tensor {
        let shape = exe.manifest().model("simnet").unwrap().input_shape.clone();
        crate::data::gen::sample_image_shaped(1, 2, &shape)
    }

    #[test]
    fn sim_stage_chain_matches_full_forward_exactly() {
        let exe = sim_exe();
        let x = sim_input(&exe);
        let n = exe.manifest().model("simnet").unwrap().num_stages();
        let chained = exe.run_stages("simnet", 1, n, &x).unwrap().tensor;
        let full = exe.run_full("simnet", &x).unwrap().tensor;
        assert_eq!(chained.shape(), full.shape());
        assert!(chained
            .data()
            .iter()
            .zip(full.data())
            .all(|(a, b)| a.to_bits() == b.to_bits()));
    }

    #[test]
    fn sim_tail_batch_bit_identical_to_serial() {
        let exe = sim_exe();
        let m = exe.manifest().model("simnet").unwrap().clone();
        let x = sim_input(&exe);
        let mid = exe.run_stage("simnet", 1, &x).unwrap().tensor;
        // Serial reference: stages 2..=4 one sample at a time.
        let serial = exe.run_stages("simnet", 2, 4, &mid).unwrap().tensor;
        // Batched: four copies (and one perturbed sample) through the
        // batch entry point.
        let mut perturbed = mid.data().to_vec();
        perturbed[0] += 1.0;
        let serial_p = exe
            .run_stages("simnet", 2, 4, &Tensor::new(m.stages[0].out_shape.clone(), perturbed.clone()))
            .unwrap()
            .tensor;
        let mut batch = vec![
            mid.data().to_vec(),
            perturbed,
            mid.data().to_vec(),
            mid.data().to_vec(),
        ];
        exe.run_tail_batch("simnet", 2, &mut batch).unwrap();
        for (bi, expected) in [(0, &serial), (1, &serial_p), (2, &serial), (3, &serial)] {
            assert_eq!(batch[bi].len(), expected.data().len());
            assert!(
                batch[bi]
                    .iter()
                    .zip(expected.data())
                    .all(|(a, b)| a.to_bits() == b.to_bits()),
                "sample {bi} diverged from serial"
            );
        }
    }

    #[test]
    fn sim_mixed_model_tail_batch_bit_identical_to_solo() {
        use crate::runtime::sim::sim_manifest_fleet;
        let exe = Executor::sim_with(sim_manifest_fleet(3), 16);
        let mk = |model: &str, from: usize, seed: usize| -> Vec<f32> {
            let m = exe.manifest().model(model).unwrap();
            let n: usize = m.stages[from - 1].in_shape.iter().product();
            (0..n)
                .map(|i| {
                    let h = ((i + seed * 4099) as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
                    ((h >> 44) & 0xFFF) as f32 / 409.6
                })
                .collect()
        };
        // Exact-signature mix (fleet0/fleet1/fleet2 tails from stage 2)
        // plus a padded mix (fleet0 vs padnet from stage 3).
        for routes in [
            vec![(0u16, 2usize), (1, 2), (2, 2), (0, 2)],
            vec![(0u16, 3usize), (3, 3), (0, 3), (3, 3)],
        ] {
            let inputs: Vec<Vec<f32>> = routes
                .iter()
                .enumerate()
                .map(|(s, &(mid, from))| {
                    mk(&exe.manifest().models[mid as usize].name.clone(), from, s + 7)
                })
                .collect();
            let solos: Vec<Vec<f32>> = routes
                .iter()
                .zip(&inputs)
                .map(|(&(mid, from), x)| {
                    let name = exe.manifest().models[mid as usize].name.clone();
                    let mut one = vec![x.clone()];
                    exe.run_tail_batch(&name, from, &mut one).unwrap();
                    one.pop().unwrap()
                })
                .collect();
            let mut batch = inputs;
            exe.run_tail_batch_multi(&routes, &mut batch).unwrap();
            for (s, (mixed, solo)) in batch.iter().zip(&solos).enumerate() {
                assert_eq!(mixed.len(), solo.len());
                assert!(
                    mixed.iter().zip(solo).all(|(a, b)| a.to_bits() == b.to_bits()),
                    "routes {routes:?} sample {s}: mixed batch diverged from solo"
                );
            }
        }
    }

    #[test]
    fn sim_mixed_tail_batch_rejects_incompatible_structure() {
        use crate::runtime::sim::sim_manifest_fleet;
        let exe = Executor::sim_with(sim_manifest_fleet(2), 8);
        let n3: usize = exe.manifest().models[0].stages[2].in_shape.iter().product();
        let n4: usize = exe.manifest().models[0].stages[3].in_shape.iter().product();
        // Different tail depths (same head out-shape!) must be refused.
        let mut batch = vec![vec![0.1f32; n3], vec![0.1f32; n4]];
        assert!(exe.run_tail_batch_multi(&[(0, 3), (0, 4)], &mut batch).is_err());
        // Bad sample length against its own model's lead geometry.
        let mut batch = vec![vec![0.1f32; n3], vec![0.1f32; 5]];
        assert!(exe.run_tail_batch_multi(&[(0, 3), (1, 3)], &mut batch).is_err());
        // Route/batch arity mismatch and bad model id.
        let mut batch = vec![vec![0.1f32; n3]];
        assert!(exe.run_tail_batch_multi(&[(0, 3), (1, 3)], &mut batch).is_err());
        assert!(exe.run_tail_batch_multi(&[(42, 3)], &mut batch).is_err());
    }

    #[test]
    fn sim_tail_batch_past_last_stage_is_identity() {
        let exe = sim_exe();
        let logits = vec![1.0f32, -2.0, 3.0];
        let mut batch = vec![logits.clone()];
        exe.run_tail_batch("simnet", 5, &mut batch).unwrap();
        assert_eq!(batch[0], logits);
    }

    #[test]
    fn sim_tail_batch_rejects_bad_sample_length() {
        let exe = sim_exe();
        let mut batch = vec![vec![0.0f32; 3]];
        assert!(exe.run_tail_batch("simnet", 2, &mut batch).is_err());
    }

    #[test]
    fn sim_quant_dequant_route_through_rust_twin() {
        let exe = sim_exe();
        let x = sim_input(&exe);
        let mid = exe.run_stage("simnet", 1, &x).unwrap().tensor;
        let q = exe.run_quant(&mid, 6).unwrap();
        assert_eq!(q, quant::quantize(mid.data(), 6));
        let back = exe.run_dequant(&q, mid.shape()).unwrap();
        assert_eq!(back.data(), quant::dequantize(&q).as_slice());
    }
}
