//! PJRT executor with a lazy compile cache.
//!
//! One `Executor` wraps one PJRT CPU client (the paper's edge device or
//! cloud server — each process owns one). HLO text artifacts compile on
//! first use and are cached; compilation is tens of milliseconds per
//! stage while execution is micro/milliseconds, so the cache is what
//! keeps re-decoupling cheap: switching `(i*, c)` never recompiles
//! anything already seen.
//!
//! Calling conventions (all lowered with `return_tuple=True`):
//! * stage:   (x: f32[in_shape])                  -> (y,)
//! * full:    (x: f32[input_shape])               -> (logits,)
//! * quant:   (x: f32[n], c: f32[])               -> (y, lo, hi)
//! * dequant: (y: f32[n], lo, hi, c: f32[])       -> (x̂[out_shape],)

use std::collections::HashMap;
use std::sync::Mutex;
use std::time::Instant;

use anyhow::{anyhow, Context, Result};

use super::artifacts::Manifest;
use super::tensor::Tensor;
use crate::compression::quant::Quantized;

pub struct Executor {
    client: xla::PjRtClient,
    manifest: Manifest,
    cache: Mutex<HashMap<String, std::sync::Arc<xla::PjRtLoadedExecutable>>>,
    /// Cumulative compile time, for the metrics endpoint.
    compile_seconds: Mutex<f64>,
}

/// Output of a stage execution plus its wall-clock cost.
#[derive(Debug, Clone)]
pub struct StageOutput {
    pub tensor: Tensor,
    pub seconds: f64,
}

impl Executor {
    pub fn new(manifest: Manifest) -> Result<Self> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e}"))?;
        Ok(Self {
            client,
            manifest,
            cache: Mutex::new(HashMap::new()),
            compile_seconds: Mutex::new(0.0),
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn compile_seconds(&self) -> f64 {
        *self.compile_seconds.lock().unwrap()
    }

    /// Fetch-or-compile the executable for an artifact file name.
    fn executable(&self, file: &str) -> Result<std::sync::Arc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.lock().unwrap().get(file) {
            return Ok(std::sync::Arc::clone(exe));
        }
        let path = self.manifest.artifact_path(file);
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow!("parsing HLO text {path:?}: {e}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {file}: {e}"))?;
        let exe = std::sync::Arc::new(exe);
        *self.compile_seconds.lock().unwrap() += t0.elapsed().as_secs_f64();
        self.cache.lock().unwrap().insert(file.to_string(), std::sync::Arc::clone(&exe));
        Ok(exe)
    }

    /// Warm the cache for a set of artifacts (server startup).
    pub fn precompile(&self, files: &[&str]) -> Result<()> {
        for f in files {
            self.executable(f)?;
        }
        Ok(())
    }

    pub fn cached_count(&self) -> usize {
        self.cache.lock().unwrap().len()
    }

    fn run(&self, file: &str, inputs: &[xla::Literal]) -> Result<xla::Literal> {
        let exe = self.executable(file)?;
        let result = exe
            .execute::<xla::Literal>(inputs)
            .map_err(|e| anyhow!("executing {file}: {e}"))?;
        result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching result of {file}: {e}"))
    }

    /// Run stage `i` (1-based) of `model` on an activation.
    pub fn run_stage(&self, model: &str, i: usize, x: &Tensor) -> Result<StageOutput> {
        let m = self.manifest.model(model)?;
        let stage = m
            .stages
            .get(i - 1)
            .ok_or_else(|| anyhow!("{model} has {} stages, asked {i}", m.stages.len()))?;
        if x.shape() != stage.in_shape.as_slice() {
            return Err(anyhow!(
                "{model} stage {i} expects {:?}, got {:?}",
                stage.in_shape,
                x.shape()
            ));
        }
        let t0 = Instant::now();
        let out = self.run(&stage.artifact.clone(), &[x.to_literal()])?;
        let lit = out.to_tuple1().map_err(|e| anyhow!("stage output unwrap: {e}"))?;
        let tensor = Tensor::from_literal(&lit)?;
        Ok(StageOutput { tensor, seconds: t0.elapsed().as_secs_f64() })
    }

    /// Run stages `from..=to` (1-based, inclusive) sequentially.
    pub fn run_stages(
        &self,
        model: &str,
        from: usize,
        to: usize,
        x: &Tensor,
    ) -> Result<StageOutput> {
        let mut cur = x.clone();
        let mut total = 0.0;
        for i in from..=to {
            let out = self.run_stage(model, i, &cur)?;
            cur = out.tensor;
            total += out.seconds;
        }
        Ok(StageOutput { tensor: cur, seconds: total })
    }

    /// Whole-model forward (cloud-only baselines, i* = 0).
    pub fn run_full(&self, model: &str, x: &Tensor) -> Result<StageOutput> {
        let m = self.manifest.model(model)?;
        let t0 = Instant::now();
        let out = self.run(&m.full_artifact.clone(), &[x.to_literal()])?;
        let lit = out.to_tuple1().map_err(|e| anyhow!("full output unwrap: {e}"))?;
        Ok(StageOutput { tensor: Tensor::from_literal(&lit)?, seconds: t0.elapsed().as_secs_f64() })
    }

    /// Quantize via the exported L1 Pallas kernel: (x[n], c) → Quantized.
    pub fn run_quant(&self, x: &Tensor, c: u8) -> Result<Quantized> {
        let n = x.len();
        let file = self
            .manifest
            .codecs
            .quant
            .get(&n)
            .ok_or_else(|| anyhow!("no quant artifact for n={n}"))?
            .clone();
        let flat = x.clone().flattened();
        let out = self.run(&file, &[flat.to_literal(), Tensor::scalar(c as f32).to_literal()])?;
        let (y, lo, hi) = out.to_tuple3().map_err(|e| anyhow!("quant unwrap: {e}"))?;
        let values: Vec<u16> =
            y.to_vec::<f32>()?.into_iter().map(|v| v as u16).collect();
        Ok(Quantized {
            values,
            lo: lo.get_first_element::<f32>()?,
            hi: hi.get_first_element::<f32>()?,
            c,
        })
    }

    /// Dequantize via the exported L1 Pallas kernel into `shape`.
    pub fn run_dequant(&self, q: &Quantized, shape: &[usize]) -> Result<Tensor> {
        self.run_dequant_parts(&q.values, q.lo, q.hi, q.c, shape)
    }

    /// [`Executor::run_dequant`] over borrowed parts — lets servers keep
    /// decoded values in a pooled buffer instead of building a
    /// [`Quantized`] per request.
    pub fn run_dequant_parts(
        &self,
        values: &[u16],
        lo: f32,
        hi: f32,
        c: u8,
        shape: &[usize],
    ) -> Result<Tensor> {
        let file = self
            .manifest
            .codecs
            .dequant
            .get(shape)
            .ok_or_else(|| anyhow!("no dequant artifact for shape {shape:?}"))?
            .clone();
        let y: Vec<f32> = values.iter().map(|&v| v as f32).collect();
        let yt = Tensor::new(vec![y.len()], y);
        let out = self.run(
            &file,
            &[
                yt.to_literal(),
                Tensor::scalar(lo).to_literal(),
                Tensor::scalar(hi).to_literal(),
                Tensor::scalar(c as f32).to_literal(),
            ],
        )?;
        let lit = out.to_tuple1().map_err(|e| anyhow!("dequant unwrap: {e}"))?;
        Tensor::from_literal(&lit).context("dequant output")
    }
}

/// Thread-safe wrapper: serializes all PJRT access behind one mutex.
///
/// The `xla` crate's handles are `Rc` + raw pointers (not `Send`), but
/// every object lives strictly inside [`Executor`] — its public API only
/// traffics in plain-rust `Tensor`/`Quantized` values, and literals are
/// created/destroyed inside the locked region. With exclusive access
/// enforced by the mutex no `Rc` refcount or XLA object is ever touched
/// from two threads at once, which makes the `Send + Sync` assertion
/// sound. CPU inference is compute-bound, so serialization costs little;
/// scale out with one `SharedExecutor` per worker if needed.
pub struct SharedExecutor {
    inner: Mutex<Executor>,
}

unsafe impl Send for SharedExecutor {}
unsafe impl Sync for SharedExecutor {}

impl SharedExecutor {
    pub fn new(manifest: Manifest) -> Result<Self> {
        Ok(Self { inner: Mutex::new(Executor::new(manifest)?) })
    }

    pub fn from_executor(exe: Executor) -> Self {
        Self { inner: Mutex::new(exe) }
    }

    /// Run `f` with exclusive access to the executor.
    pub fn with<R>(&self, f: impl FnOnce(&Executor) -> R) -> R {
        let g = self.inner.lock().unwrap();
        f(&g)
    }

    pub fn run_stage(&self, model: &str, i: usize, x: &Tensor) -> Result<StageOutput> {
        self.with(|e| e.run_stage(model, i, x))
    }

    pub fn run_full(&self, model: &str, x: &Tensor) -> Result<StageOutput> {
        self.with(|e| e.run_full(model, x))
    }

    pub fn run_quant(&self, x: &Tensor, c: u8) -> Result<Quantized> {
        self.with(|e| e.run_quant(x, c))
    }

    pub fn run_dequant(&self, q: &Quantized, shape: &[usize]) -> Result<Tensor> {
        self.with(|e| e.run_dequant(q, shape))
    }

    pub fn run_dequant_parts(
        &self,
        values: &[u16],
        lo: f32,
        hi: f32,
        c: u8,
        shape: &[usize],
    ) -> Result<Tensor> {
        self.with(|e| e.run_dequant_parts(values, lo, hi, c, shape))
    }

    pub fn manifest_clone(&self) -> Manifest {
        self.with(|e| e.manifest().clone())
    }

    pub fn cached_count(&self) -> usize {
        self.with(|e| e.cached_count())
    }
}

#[cfg(test)]
mod tests {
    //! Integration-grade tests against the real artifacts; every test
    //! skips silently when `make artifacts` has not run yet.
    use super::*;
    use crate::compression::quant;

    fn executor() -> Option<Executor> {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            return None;
        }
        Some(Executor::new(Manifest::load(dir).unwrap()).unwrap())
    }

    fn input_for(exe: &Executor, model: &str) -> Tensor {
        let shape = exe.manifest().model(model).unwrap().input_shape.clone();
        crate::data::gen::sample_image_shaped(0, 0, &shape)
    }

    #[test]
    fn stage_chain_matches_full_forward() {
        let Some(exe) = executor() else { return };
        for model in ["tinyconv", "vgg16"] {
            let x = input_for(&exe, model);
            let n = exe.manifest().model(model).unwrap().num_stages();
            let chained = exe.run_stages(model, 1, n, &x).unwrap().tensor;
            let full = exe.run_full(model, &x).unwrap().tensor;
            assert_eq!(chained.shape(), full.shape());
            for (a, b) in chained.data().iter().zip(full.data()) {
                assert!((a - b).abs() < 1e-3, "{model}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn pallas_quant_matches_rust_twin() {
        let Some(exe) = executor() else { return };
        let x = input_for(&exe, "tinyconv");
        let mid = exe.run_stage("tinyconv", 1, &x).unwrap().tensor;
        for c in [1u8, 4, 8] {
            let via_pjrt = exe.run_quant(&mid, c).unwrap();
            let via_rust = quant::quantize(mid.data(), c);
            assert_eq!(via_pjrt.values, via_rust.values, "c={c}");
            assert!((via_pjrt.lo - via_rust.lo).abs() < 1e-6);
            assert!((via_pjrt.hi - via_rust.hi).abs() < 1e-6);
        }
    }

    #[test]
    fn pallas_dequant_roundtrip() {
        let Some(exe) = executor() else { return };
        let x = input_for(&exe, "tinyconv");
        let mid = exe.run_stage("tinyconv", 1, &x).unwrap().tensor;
        let q = exe.run_quant(&mid, 8).unwrap();
        let back = exe.run_dequant(&q, mid.shape()).unwrap();
        assert_eq!(back.shape(), mid.shape());
        let bound = quant::error_bound(q.lo, q.hi, 8) * 1.001;
        for (a, b) in mid.data().iter().zip(back.data()) {
            assert!((a - b).abs() <= bound);
        }
    }

    #[test]
    fn compile_cache_hits() {
        let Some(exe) = executor() else { return };
        let x = input_for(&exe, "tinyconv");
        let _ = exe.run_stage("tinyconv", 1, &x).unwrap();
        let cached = exe.cached_count();
        let _ = exe.run_stage("tinyconv", 1, &x).unwrap();
        assert_eq!(exe.cached_count(), cached, "second run must not compile");
    }

    #[test]
    fn shape_mismatch_rejected() {
        let Some(exe) = executor() else { return };
        let bad = Tensor::zeros(vec![1, 2, 2, 3]);
        assert!(exe.run_stage("tinyconv", 1, &bad).is_err());
    }
}
