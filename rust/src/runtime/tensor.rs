//! Host-side dense f32 tensor: shape + contiguous row-major buffer.
//!
//! The thin currency between pipeline stages, the feature codec and the
//! PJRT boundary. Deliberately minimal — all heavy math happens inside
//! compiled XLA executables; the coordinator only reshapes, flattens and
//! shuttles buffers.

#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl Tensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Self {
        assert_eq!(
            shape.iter().product::<usize>(),
            data.len(),
            "shape {:?} does not match buffer length {}",
            shape,
            data.len()
        );
        Self { shape, data }
    }

    pub fn zeros(shape: Vec<usize>) -> Self {
        let n = shape.iter().product();
        Self { shape, data: vec![0.0; n] }
    }

    pub fn scalar(v: f32) -> Self {
        Self { shape: vec![], data: vec![v] }
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_data(self) -> Vec<f32> {
        self.data
    }

    /// Reinterpret with a new shape of identical element count.
    pub fn reshaped(mut self, shape: Vec<usize>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), self.data.len());
        self.shape = shape;
        self
    }

    /// Flatten to 1-D.
    pub fn flattened(self) -> Self {
        let n = self.data.len();
        self.reshaped(vec![n])
    }

    /// Index of the maximum element (ties → first). Logits → class id.
    pub fn argmax(&self) -> usize {
        let mut best = 0;
        let mut bv = f32::NEG_INFINITY;
        for (i, &v) in self.data.iter().enumerate() {
            if v > bv {
                bv = v;
                best = i;
            }
        }
        best
    }

    /// Raw byte size of the f32 buffer (the paper's "original" size).
    pub fn byte_size(&self) -> usize {
        self.data.len() * 4
    }

    /// To an XLA literal of this shape.
    ///
    /// Single-copy construction straight into the target shape (§Perf
    /// log: the earlier `vec1` + `reshape` pair did two literal
    /// allocations and copies per PJRT call).
    pub fn to_literal(&self) -> xla::Literal {
        let bytes = unsafe {
            std::slice::from_raw_parts(self.data.as_ptr() as *const u8, self.data.len() * 4)
        };
        xla::Literal::create_from_shape_and_untyped_data(
            xla::ElementType::F32,
            &self.shape,
            bytes,
        )
        .expect("literal construction")
    }

    /// From an XLA literal (must be f32).
    pub fn from_literal(lit: &xla::Literal) -> anyhow::Result<Self> {
        let shape = lit.array_shape()?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        let data = lit.to_vec::<f32>()?;
        Ok(Self::new(dims, data))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_views() {
        let t = Tensor::new(vec![2, 3], vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert_eq!(t.shape(), &[2, 3]);
        assert_eq!(t.len(), 6);
        assert_eq!(t.byte_size(), 24);
        let f = t.clone().flattened();
        assert_eq!(f.shape(), &[6]);
    }

    #[test]
    #[should_panic(expected = "does not match")]
    fn bad_shape_panics() {
        Tensor::new(vec![2, 2], vec![1.0]);
    }

    #[test]
    fn argmax_ties_first() {
        let t = Tensor::new(vec![4], vec![1.0, 5.0, 5.0, 0.0]);
        assert_eq!(t.argmax(), 1);
    }

    #[test]
    fn literal_roundtrip() {
        let t = Tensor::new(vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let lit = t.to_literal();
        let back = Tensor::from_literal(&lit).unwrap();
        assert_eq!(back, t);
    }

    #[test]
    fn scalar_literal_roundtrip() {
        let t = Tensor::scalar(7.5);
        let lit = t.to_literal();
        assert_eq!(lit.get_first_element::<f32>().unwrap(), 7.5);
    }
}
