//! `artifacts/manifest.json` parsing: what `python/compile/aot.py` wrote.
//!
//! The manifest is the single contract between the build-time python
//! side and this runtime: model stage graphs (shapes, FMACs, artifact
//! file names) and the shared quant/dequant codec kernels keyed by
//! tensor geometry.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use crate::util::json::Json;

#[derive(Debug, Clone)]
pub struct StageManifest {
    pub index: usize,
    pub name: String,
    pub artifact: String,
    pub in_shape: Vec<usize>,
    pub out_shape: Vec<usize>,
    pub out_elems: usize,
    pub fmacs_scaled: u64,
}

#[derive(Debug, Clone)]
pub struct ModelManifest {
    pub name: String,
    pub input_shape: Vec<usize>,
    pub num_classes: usize,
    pub full_artifact: String,
    pub stages: Vec<StageManifest>,
}

impl ModelManifest {
    /// Number of decoupling points N.
    pub fn num_stages(&self) -> usize {
        self.stages.len()
    }

    /// Raw f32 feature bytes at stage `i` (1-based), the paper's
    /// "original feature map" size in Fig. 2/3.
    pub fn stage_raw_bytes(&self, i: usize) -> usize {
        self.stages[i - 1].out_elems * 4
    }
}

#[derive(Debug, Clone)]
pub struct CodecArtifacts {
    /// quant artifact file by flat element count.
    pub quant: BTreeMap<usize, String>,
    /// dequant artifact file by exact output shape.
    pub dequant: BTreeMap<Vec<usize>, String>,
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub c_max: u8,
    pub num_classes: usize,
    pub source_digest: String,
    pub models: Vec<ModelManifest>,
    pub codecs: CodecArtifacts,
}

fn shape_of(j: &Json) -> Result<Vec<usize>> {
    j.as_arr()
        .ok_or_else(|| anyhow!("shape is not an array"))?
        .iter()
        .map(|d| d.as_u64().map(|v| v as usize).ok_or_else(|| anyhow!("bad shape dim")))
        .collect()
}

impl Manifest {
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        let j = Json::parse(&text).map_err(|e| anyhow!("parsing {path:?}: {e}"))?;

        let mut models = Vec::new();
        for m in j.get("models").and_then(Json::as_arr).unwrap_or(&[]) {
            let mut stages = Vec::new();
            for s in m.get("stages").and_then(Json::as_arr).unwrap_or(&[]) {
                stages.push(StageManifest {
                    index: s.get("index").and_then(Json::as_u64).unwrap_or(0) as usize,
                    name: s
                        .get("name")
                        .and_then(Json::as_str)
                        .unwrap_or_default()
                        .to_string(),
                    artifact: s
                        .get("artifact")
                        .and_then(Json::as_str)
                        .ok_or_else(|| anyhow!("stage missing artifact"))?
                        .to_string(),
                    in_shape: shape_of(s.get("in_shape").ok_or_else(|| anyhow!("in_shape"))?)?,
                    out_shape: shape_of(
                        s.get("out_shape").ok_or_else(|| anyhow!("out_shape"))?,
                    )?,
                    out_elems: s.get("out_elems").and_then(Json::as_u64).unwrap_or(0) as usize,
                    fmacs_scaled: s.get("fmacs_scaled").and_then(Json::as_u64).unwrap_or(0),
                });
            }
            models.push(ModelManifest {
                name: m
                    .get("name")
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow!("model missing name"))?
                    .to_string(),
                input_shape: shape_of(
                    m.get("input_shape").ok_or_else(|| anyhow!("input_shape"))?,
                )?,
                num_classes: m.get("num_classes").and_then(Json::as_u64).unwrap_or(0) as usize,
                full_artifact: m
                    .get("full_artifact")
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow!("model missing full_artifact"))?
                    .to_string(),
                stages,
            });
        }

        let mut quant = BTreeMap::new();
        for q in j.path(&["codecs", "quant"]).and_then(Json::as_arr).unwrap_or(&[]) {
            quant.insert(
                q.get("elems").and_then(Json::as_u64).unwrap_or(0) as usize,
                q.get("artifact").and_then(Json::as_str).unwrap_or_default().to_string(),
            );
        }
        let mut dequant = BTreeMap::new();
        for d in j.path(&["codecs", "dequant"]).and_then(Json::as_arr).unwrap_or(&[]) {
            dequant.insert(
                shape_of(d.get("shape").ok_or_else(|| anyhow!("dequant shape"))?)?,
                d.get("artifact").and_then(Json::as_str).unwrap_or_default().to_string(),
            );
        }

        Ok(Self {
            dir,
            c_max: j.get("c_max").and_then(Json::as_u64).unwrap_or(8) as u8,
            num_classes: j.get("num_classes").and_then(Json::as_u64).unwrap_or(16) as usize,
            source_digest: j
                .get("source_digest")
                .and_then(Json::as_str)
                .unwrap_or_default()
                .to_string(),
            models,
            codecs: CodecArtifacts { quant, dequant },
        })
    }

    pub fn model(&self, name: &str) -> Result<&ModelManifest> {
        self.models
            .iter()
            .find(|m| m.name == name)
            .ok_or_else(|| anyhow!("model {name:?} not in manifest"))
    }

    /// Numeric model id used in wire frames (stable: manifest order).
    pub fn model_id(&self, name: &str) -> Option<u16> {
        self.models.iter().position(|m| m.name == name).map(|i| i as u16)
    }

    pub fn artifact_path(&self, file: &str) -> PathBuf {
        self.dir.join(file)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "version": 1, "c_max": 8, "num_classes": 16, "source_digest": "abc",
      "models": [{
        "name": "m", "input_shape": [1, 4, 4, 3], "num_classes": 16,
        "full_artifact": "m_full.hlo.txt",
        "stages": [
          {"index": 0, "name": "s0", "artifact": "m_stage_00.hlo.txt",
           "in_shape": [1,4,4,3], "out_shape": [1,4,4,8], "out_elems": 128,
           "fmacs_scaled": 3456, "hlo_bytes": 10}
        ]
      }],
      "codecs": {
        "quant": [{"elems": 128, "artifact": "quant_128.hlo.txt"}],
        "dequant": [{"shape": [1,4,4,8], "elems": 128, "artifact": "dq.hlo.txt"}]
      }
    }"#;

    #[test]
    fn parses_sample() {
        let dir = std::env::temp_dir().join("jalad_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), SAMPLE).unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.c_max, 8);
        assert_eq!(m.models.len(), 1);
        let model = m.model("m").unwrap();
        assert_eq!(model.num_stages(), 1);
        assert_eq!(model.stages[0].out_elems, 128);
        assert_eq!(model.stage_raw_bytes(1), 512);
        assert_eq!(m.codecs.quant[&128], "quant_128.hlo.txt");
        assert_eq!(m.codecs.dequant[&vec![1usize, 4, 4, 8]], "dq.hlo.txt");
        assert_eq!(m.model_id("m"), Some(0));
        assert!(m.model("nope").is_err());
    }

    #[test]
    fn missing_dir_is_helpful() {
        let err = Manifest::load("/nonexistent/path").unwrap_err();
        assert!(format!("{err:#}").contains("make artifacts"));
    }

    /// Against the real exported manifest when present (skips otherwise).
    #[test]
    fn parses_real_manifest_if_present() {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            return;
        }
        let m = Manifest::load(&dir).unwrap();
        assert!(m.models.len() >= 5, "expected 5 models, got {}", m.models.len());
        for model in &m.models {
            assert!(!model.stages.is_empty());
            // stage chain shapes must be consistent
            for w in model.stages.windows(2) {
                assert_eq!(w[0].out_shape, w[1].in_shape, "model {}", model.name);
            }
            // every stage's quant/dequant geometry must exist in codecs
            for s in &model.stages {
                assert!(
                    m.codecs.quant.contains_key(&s.out_elems),
                    "missing quant_{} for {}",
                    s.out_elems,
                    model.name
                );
                assert!(m.codecs.dequant.contains_key(&s.out_shape));
            }
        }
    }
}
