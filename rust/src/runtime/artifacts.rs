//! `artifacts/manifest.json` parsing: what `python/compile/aot.py` wrote.
//!
//! The manifest is the single contract between the build-time python
//! side and this runtime: model stage graphs (shapes, FMACs, artifact
//! file names) and the shared quant/dequant codec kernels keyed by
//! tensor geometry.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use crate::util::json::Json;

#[derive(Debug, Clone)]
pub struct StageManifest {
    pub index: usize,
    pub name: String,
    pub artifact: String,
    pub in_shape: Vec<usize>,
    pub out_shape: Vec<usize>,
    pub out_elems: usize,
    pub fmacs_scaled: u64,
}

#[derive(Debug, Clone)]
pub struct ModelManifest {
    pub name: String,
    pub input_shape: Vec<usize>,
    pub num_classes: usize,
    pub full_artifact: String,
    pub stages: Vec<StageManifest>,
}

/// Structural geometry signature of a model tail `from..=N` — what the
/// batch engine keys coalescing on instead of model identity.
///
/// Two tails with **equal** signatures compute the same function on the
/// sim backend (each stage's kernel is fully determined by its index
/// and flat in/out element counts), so requests from *different models*
/// whose tails match stage-for-stage can gather into one batched
/// program and still scatter per-sample bit-identical logits.
/// [`TailSignature::padded`] erases the leading geometry: tails that
/// match everywhere except the tail-start activation size share a
/// *padded* class — they can stack into one batch whose leading storage
/// is padded to the largest member (the pad-and-stack path), at a waste
/// the engine budgets with `pad_waste_max`.
///
/// The stage **index** is part of every per-stage entry deliberately:
/// a one-stage tail over `[1,16]` at depth 4 and a two-stage tail
/// ending in the same `[1,16]` head are different functions, so equal
/// out-shapes must never coalesce across tail-start depths.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct TailSignature {
    /// Element type of every activation buffer in the tail. Always
    /// `"f32"` today; in the signature so a future mixed-precision
    /// export can never coalesce across dtypes by accident.
    pub dtype: &'static str,
    /// Flat element count of the tail-start activation (the leading
    /// geometry; what the pad-and-stack path pads).
    pub lead_elems: usize,
    /// One `(stage index, in_elems, out_elems)` triple per tail stage.
    /// Empty for the identity tail (`from = N + 1`), whose geometry is
    /// `lead_elems` alone.
    pub stages: Vec<(usize, usize, usize)>,
}

impl TailSignature {
    /// The signature with the leading geometry erased — the coalescing
    /// class of the pad-and-stack path. Tails equal under this key
    /// differ (at most) in how large their tail-start activation is;
    /// everything downstream of the first stage is identical.
    pub fn padded(&self) -> TailSignature {
        let mut s = self.clone();
        s.lead_elems = 0;
        if let Some(first) = s.stages.first_mut() {
            first.1 = 0;
        }
        s
    }
}

impl ModelManifest {
    /// Number of decoupling points N.
    pub fn num_stages(&self) -> usize {
        self.stages.len()
    }

    /// The [`TailSignature`] of stages `from..=N` (1-based). `from`
    /// past the last stage yields the identity tail: the activation
    /// already is the logits, and its geometry is the class-count.
    pub fn tail_signature(&self, from: usize) -> TailSignature {
        let stages: Vec<(usize, usize, usize)> = self
            .stages
            .iter()
            .skip(from.saturating_sub(1))
            .map(|s| {
                (s.index, s.in_shape.iter().product(), s.out_shape.iter().product())
            })
            .collect();
        let lead_elems = stages
            .first()
            .map(|&(_, n_in, _)| n_in)
            .unwrap_or_else(|| self.stages.last().map(|s| s.out_elems).unwrap_or(0));
        TailSignature { dtype: "f32", lead_elems, stages }
    }

    /// Raw f32 feature bytes at stage `i` (1-based), the paper's
    /// "original feature map" size in Fig. 2/3.
    pub fn stage_raw_bytes(&self, i: usize) -> usize {
        self.stages[i - 1].out_elems * 4
    }
}

#[derive(Debug, Clone)]
pub struct CodecArtifacts {
    /// quant artifact file by flat element count.
    pub quant: BTreeMap<usize, String>,
    /// dequant artifact file by exact output shape.
    pub dequant: BTreeMap<Vec<usize>, String>,
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub c_max: u8,
    pub num_classes: usize,
    pub source_digest: String,
    pub models: Vec<ModelManifest>,
    pub codecs: CodecArtifacts,
}

fn shape_of(j: &Json) -> Result<Vec<usize>> {
    j.as_arr()
        .ok_or_else(|| anyhow!("shape is not an array"))?
        .iter()
        .map(|d| d.as_u64().map(|v| v as usize).ok_or_else(|| anyhow!("bad shape dim")))
        .collect()
}

impl Manifest {
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        let j = Json::parse(&text).map_err(|e| anyhow!("parsing {path:?}: {e}"))?;
        Self::from_json(dir, &j)
    }

    /// Assemble a [`Manifest`] from an already-parsed JSON document.
    ///
    /// This is the same structural contract `manifest.json` follows,
    /// factored out of [`Manifest::load`] so a manifest that arrived
    /// over the wire (the registry path, where the bytes were
    /// signature-verified first) assembles through the identical code
    /// as one read off disk. `dir` is where relative `artifact` file
    /// names resolve; for registry-assembled manifests it names the
    /// artifact cache root rather than a build output.
    pub fn from_json(dir: PathBuf, j: &Json) -> Result<Self> {
        let mut models = Vec::new();
        for m in j.get("models").and_then(Json::as_arr).unwrap_or(&[]) {
            let mut stages = Vec::new();
            for s in m.get("stages").and_then(Json::as_arr).unwrap_or(&[]) {
                stages.push(StageManifest {
                    index: s.get("index").and_then(Json::as_u64).unwrap_or(0) as usize,
                    name: s
                        .get("name")
                        .and_then(Json::as_str)
                        .unwrap_or_default()
                        .to_string(),
                    artifact: s
                        .get("artifact")
                        .and_then(Json::as_str)
                        .ok_or_else(|| anyhow!("stage missing artifact"))?
                        .to_string(),
                    in_shape: shape_of(s.get("in_shape").ok_or_else(|| anyhow!("in_shape"))?)?,
                    out_shape: shape_of(
                        s.get("out_shape").ok_or_else(|| anyhow!("out_shape"))?,
                    )?,
                    out_elems: s.get("out_elems").and_then(Json::as_u64).unwrap_or(0) as usize,
                    fmacs_scaled: s.get("fmacs_scaled").and_then(Json::as_u64).unwrap_or(0),
                });
            }
            models.push(ModelManifest {
                name: m
                    .get("name")
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow!("model missing name"))?
                    .to_string(),
                input_shape: shape_of(
                    m.get("input_shape").ok_or_else(|| anyhow!("input_shape"))?,
                )?,
                num_classes: m.get("num_classes").and_then(Json::as_u64).unwrap_or(0) as usize,
                full_artifact: m
                    .get("full_artifact")
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow!("model missing full_artifact"))?
                    .to_string(),
                stages,
            });
        }

        let mut quant = BTreeMap::new();
        for q in j.path(&["codecs", "quant"]).and_then(Json::as_arr).unwrap_or(&[]) {
            quant.insert(
                q.get("elems").and_then(Json::as_u64).unwrap_or(0) as usize,
                q.get("artifact").and_then(Json::as_str).unwrap_or_default().to_string(),
            );
        }
        let mut dequant = BTreeMap::new();
        for d in j.path(&["codecs", "dequant"]).and_then(Json::as_arr).unwrap_or(&[]) {
            dequant.insert(
                shape_of(d.get("shape").ok_or_else(|| anyhow!("dequant shape"))?)?,
                d.get("artifact").and_then(Json::as_str).unwrap_or_default().to_string(),
            );
        }

        Ok(Self {
            dir,
            c_max: j.get("c_max").and_then(Json::as_u64).unwrap_or(8) as u8,
            num_classes: j.get("num_classes").and_then(Json::as_u64).unwrap_or(16) as usize,
            source_digest: j
                .get("source_digest")
                .and_then(Json::as_str)
                .unwrap_or_default()
                .to_string(),
            models,
            codecs: CodecArtifacts { quant, dequant },
        })
    }

    pub fn model(&self, name: &str) -> Result<&ModelManifest> {
        self.models
            .iter()
            .find(|m| m.name == name)
            .ok_or_else(|| anyhow!("model {name:?} not in manifest"))
    }

    /// Numeric model id used in wire frames (stable: manifest order).
    pub fn model_id(&self, name: &str) -> Option<u16> {
        self.models.iter().position(|m| m.name == name).map(|i| i as u16)
    }

    pub fn artifact_path(&self, file: &str) -> PathBuf {
        self.dir.join(file)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "version": 1, "c_max": 8, "num_classes": 16, "source_digest": "abc",
      "models": [{
        "name": "m", "input_shape": [1, 4, 4, 3], "num_classes": 16,
        "full_artifact": "m_full.hlo.txt",
        "stages": [
          {"index": 0, "name": "s0", "artifact": "m_stage_00.hlo.txt",
           "in_shape": [1,4,4,3], "out_shape": [1,4,4,8], "out_elems": 128,
           "fmacs_scaled": 3456, "hlo_bytes": 10}
        ]
      }],
      "codecs": {
        "quant": [{"elems": 128, "artifact": "quant_128.hlo.txt"}],
        "dequant": [{"shape": [1,4,4,8], "elems": 128, "artifact": "dq.hlo.txt"}]
      }
    }"#;

    #[test]
    fn parses_sample() {
        let dir = std::env::temp_dir().join("jalad_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), SAMPLE).unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.c_max, 8);
        assert_eq!(m.models.len(), 1);
        let model = m.model("m").unwrap();
        assert_eq!(model.num_stages(), 1);
        assert_eq!(model.stages[0].out_elems, 128);
        assert_eq!(model.stage_raw_bytes(1), 512);
        assert_eq!(m.codecs.quant[&128], "quant_128.hlo.txt");
        assert_eq!(m.codecs.dequant[&vec![1usize, 4, 4, 8]], "dq.hlo.txt");
        assert_eq!(m.model_id("m"), Some(0));
        assert!(m.model("nope").is_err());
    }

    #[test]
    fn tail_signatures_encode_depth_and_lead_geometry() {
        let fleet = crate::runtime::sim::sim_manifest_fleet(3);
        let a = fleet.model("fleet0").unwrap();
        let b = fleet.model("fleet1").unwrap();
        let pad = fleet.model("padnet").unwrap();
        // Different edge halves, identical cloud tails: exact equality
        // from stage 2 onward.
        assert_ne!(a.tail_signature(1), b.tail_signature(1), "stage-1 geometries differ");
        assert_eq!(a.tail_signature(2), b.tail_signature(2));
        assert_eq!(a.tail_signature(4), b.tail_signature(4));
        // Same out shape, different tail-start depth: never equal, even
        // padded (the per-stage indices disagree).
        assert_ne!(a.tail_signature(3), a.tail_signature(4));
        assert_ne!(a.tail_signature(3).padded(), a.tail_signature(4).padded());
        // padnet's stage-3 tail matches fleet0's only up to the padded
        // leading geometry.
        assert_ne!(a.tail_signature(3), pad.tail_signature(3));
        assert_eq!(a.tail_signature(3).padded(), pad.tail_signature(3).padded());
        assert!(a.tail_signature(3).lead_elems > pad.tail_signature(3).lead_elems);
        // Identity tails: no stages, geometry = class count.
        let id = a.tail_signature(a.num_stages() + 1);
        assert!(id.stages.is_empty());
        assert_eq!(id.lead_elems, a.num_classes);
        assert_eq!(id, b.tail_signature(b.num_stages() + 1));
    }

    #[test]
    fn missing_dir_is_helpful() {
        let err = Manifest::load("/nonexistent/path").unwrap_err();
        assert!(format!("{err:#}").contains("make artifacts"));
    }

    /// Against the real exported manifest when present (skips otherwise).
    #[test]
    fn parses_real_manifest_if_present() {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            return;
        }
        let m = Manifest::load(&dir).unwrap();
        assert!(m.models.len() >= 5, "expected 5 models, got {}", m.models.len());
        for model in &m.models {
            assert!(!model.stages.is_empty());
            // stage chain shapes must be consistent
            for w in model.stages.windows(2) {
                assert_eq!(w[0].out_shape, w[1].in_shape, "model {}", model.name);
            }
            // every stage's quant/dequant geometry must exist in codecs
            for s in &model.stages {
                assert!(
                    m.codecs.quant.contains_key(&s.out_elems),
                    "missing quant_{} for {}",
                    s.out_elems,
                    model.name
                );
                assert!(m.codecs.dequant.contains_key(&s.out_shape));
            }
        }
    }
}
