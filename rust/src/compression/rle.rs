//! Zero-run-length coding for sparse integer streams.
//!
//! Post-ReLU quantized feature maps are mostly zeros (paper Fig. 1's
//! sparsity observation); the JPEG-like codec's zig-zagged coefficients
//! likewise. Encoding: each nonzero value `v` is emitted as the symbol
//! pair (run_of_zeros_before_it, v); trailing zeros are one EOB marker.
//!
//! The output is a `u16` symbol stream meant to be fed into the Huffman
//! coder: symbol = run (0..=MAX_RUN) interleaved with the value stream.

pub const MAX_RUN: u16 = 255;
pub const EOB: u16 = MAX_RUN + 1; // end-of-block marker in the run alphabet

/// Encode to (runs, values): `runs` holds zero-run lengths / EOB,
/// `values` holds the nonzero magnitudes aligned with non-EOB runs.
pub fn encode(xs: &[u16]) -> (Vec<u16>, Vec<u16>) {
    let mut runs = Vec::new();
    let mut values = Vec::new();
    let mut run = 0u16;
    for &x in xs {
        if x == 0 {
            run += 1;
            if run == MAX_RUN {
                // Emit a maximal run with a literal zero to reset.
                runs.push(MAX_RUN);
                values.push(0);
                run = 0;
            }
        } else {
            runs.push(run);
            values.push(x);
            run = 0;
        }
    }
    runs.push(EOB);
    (runs, values)
}

/// Decode; `n` is the expected output length (trailing zeros restored).
pub fn decode(runs: &[u16], values: &[u16], n: usize) -> Result<Vec<u16>, &'static str> {
    let mut out = Vec::with_capacity(n);
    let mut vi = 0;
    for &r in runs {
        if r == EOB {
            if out.len() > n {
                return Err("rle overflow");
            }
            out.resize(n, 0);
            return Ok(out);
        }
        if r > MAX_RUN {
            return Err("bad run symbol");
        }
        for _ in 0..r {
            out.push(0);
        }
        let v = *values.get(vi).ok_or("missing value")?;
        vi += 1;
        out.push(v);
        if out.len() > n {
            return Err("rle overflow");
        }
    }
    Err("missing EOB")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    fn roundtrip(xs: &[u16]) -> bool {
        let (runs, values) = encode(xs);
        decode(&runs, &values, xs.len()).as_deref() == Ok(xs)
    }

    #[test]
    fn all_zeros_is_one_symbol() {
        let xs = vec![0u16; 10_000];
        let (runs, values) = encode(&xs);
        // 10000/255 max-run resets + EOB.
        assert!(runs.len() <= 10_000 / MAX_RUN as usize + 2);
        assert!(values.len() <= runs.len());
        assert!(roundtrip(&xs));
    }

    #[test]
    fn empty() {
        assert!(roundtrip(&[]));
    }

    #[test]
    fn dense_data() {
        let xs: Vec<u16> = (1..=300).collect();
        assert!(roundtrip(&xs));
    }

    #[test]
    fn truncated_values_rejected() {
        let (runs, mut values) = encode(&[0, 5, 0, 7]);
        values.pop();
        assert!(decode(&runs, &values, 4).is_err());
    }

    #[test]
    fn prop_roundtrip_sparse() {
        prop::check(
            "rle roundtrip sparse",
            prop::vec_of(
                prop::pair(prop::u64_in(0, 9), prop::u64_in(1, 255)).map(|(z, v)| {
                    if z < 7 {
                        0u16
                    } else {
                        v as u16
                    }
                }),
                0,
                5000,
            ),
            |xs| roundtrip(xs),
        );
    }
}
