//! PNG-like lossless image codec for the PNG2Cloud baseline (§IV-A).
//!
//! Same structure as real PNG: per-row filter selection (None / Sub / Up
//! / Average / Paeth, minimum-sum-of-absolute-values heuristic) followed
//! by the deflate-like entropy stage. Not a .png container — both ends
//! are ours — but the compression ratio lands in PNG's usual band, which
//! is all the baseline needs (DESIGN.md substitution table).

use super::deflate;
use super::huffman::HuffError;

/// Interleaved 8-bit image, row-major, `channels` ∈ {1, 3}.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Image8 {
    pub w: usize,
    pub h: usize,
    pub channels: usize,
    pub data: Vec<u8>,
}

impl Image8 {
    pub fn new(w: usize, h: usize, channels: usize, data: Vec<u8>) -> Self {
        assert_eq!(data.len(), w * h * channels);
        Self { w, h, channels, data }
    }
    pub fn row(&self, y: usize) -> &[u8] {
        let stride = self.w * self.channels;
        &self.data[y * stride..(y + 1) * stride]
    }
}

#[inline]
fn paeth(a: i32, b: i32, c: i32) -> i32 {
    let p = a + b - c;
    let (pa, pb, pc) = ((p - a).abs(), (p - b).abs(), (p - c).abs());
    if pa <= pb && pa <= pc {
        a
    } else if pb <= pc {
        b
    } else {
        c
    }
}

fn filter_row(filter: u8, row: &[u8], prev: &[u8], bpp: usize, out: &mut Vec<u8>) {
    for i in 0..row.len() {
        let a = if i >= bpp { row[i - bpp] as i32 } else { 0 };
        let b = prev[i] as i32;
        let c = if i >= bpp { prev[i - bpp] as i32 } else { 0 };
        let pred = match filter {
            0 => 0,
            1 => a,
            2 => b,
            3 => (a + b) / 2,
            4 => paeth(a, b, c),
            _ => unreachable!(),
        };
        out.push((row[i] as i32).wrapping_sub(pred) as u8);
    }
}

fn unfilter_row(filter: u8, coded: &[u8], prev: &[u8], bpp: usize) -> Vec<u8> {
    let mut row = Vec::with_capacity(coded.len());
    for i in 0..coded.len() {
        let a = if i >= bpp { row[i - bpp] as i32 } else { 0 };
        let b = prev[i] as i32;
        let c = if i >= bpp { prev[i - bpp] as i32 } else { 0 };
        let pred = match filter {
            0 => 0,
            1 => a,
            2 => b,
            3 => (a + b) / 2,
            4 => paeth(a, b, c),
            _ => 0,
        };
        row.push((coded[i] as i32).wrapping_add(pred) as u8);
    }
    row
}

/// Encode. Layout: [w u16][h u16][channels u8][filters h×u8][deflate payload].
pub fn encode(img: &Image8) -> Vec<u8> {
    let stride = img.w * img.channels;
    let bpp = img.channels;
    let mut filters = Vec::with_capacity(img.h);
    let mut filtered = Vec::with_capacity(img.data.len());
    let zero_row = vec![0u8; stride];
    let mut scratch: Vec<u8> = Vec::with_capacity(stride);

    for y in 0..img.h {
        let row = img.row(y);
        let prev = if y == 0 { &zero_row[..] } else { img.row(y - 1) };
        // Pick the filter minimizing sum of |signed residual| (PNG heuristic).
        let mut best = (u64::MAX, 0u8, Vec::new());
        for f in 0..=4u8 {
            scratch.clear();
            filter_row(f, row, prev, bpp, &mut scratch);
            let cost: u64 = scratch.iter().map(|&b| (b as i8).unsigned_abs() as u64).sum();
            if cost < best.0 {
                best = (cost, f, scratch.clone());
            }
        }
        filters.push(best.1);
        filtered.extend_from_slice(&best.2);
    }

    let payload = deflate::compress(&filtered);
    let mut out = Vec::with_capacity(9 + img.h + payload.len());
    out.extend_from_slice(&(img.w as u16).to_le_bytes());
    out.extend_from_slice(&(img.h as u16).to_le_bytes());
    out.push(img.channels as u8);
    out.extend_from_slice(&filters);
    out.extend_from_slice(&payload);
    out
}

pub fn decode(bytes: &[u8]) -> Result<Image8, HuffError> {
    if bytes.len() < 5 {
        return Err(HuffError::Truncated);
    }
    let w = u16::from_le_bytes([bytes[0], bytes[1]]) as usize;
    let h = u16::from_le_bytes([bytes[2], bytes[3]]) as usize;
    let channels = bytes[4] as usize;
    if channels == 0 || channels > 4 {
        return Err(HuffError::BadHeader);
    }
    let filters = bytes.get(5..5 + h).ok_or(HuffError::Truncated)?.to_vec();
    let filtered = deflate::decompress(&bytes[5 + h..])?;
    let stride = w * channels;
    if filtered.len() != stride * h {
        return Err(HuffError::Truncated);
    }

    let mut data = Vec::with_capacity(filtered.len());
    let zero_row = vec![0u8; stride];
    for y in 0..h {
        let coded = &filtered[y * stride..(y + 1) * stride];
        let prev: Vec<u8> =
            if y == 0 { zero_row.clone() } else { data[(y - 1) * stride..y * stride].to_vec() };
        let row = unfilter_row(filters[y], coded, &prev, channels);
        data.extend_from_slice(&row);
    }
    Ok(Image8 { w, h, channels, data })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::XorShift64Star;

    fn smooth_image(seed: u64, w: usize, h: usize) -> Image8 {
        // Smooth gradients: the regime where filters + deflate win.
        let mut rng = XorShift64Star::new(seed);
        let (ox, oy) = (rng.below(64) as f32, rng.below(64) as f32);
        let mut data = Vec::with_capacity(w * h * 3);
        for y in 0..h {
            for x in 0..w {
                for ch in 0..3 {
                    let v = 128.0
                        + 60.0 * (((x as f32 + ox) / 9.0 + ch as f32).sin())
                        + 50.0 * (((y as f32 + oy) / 7.0).cos());
                    data.push(v.clamp(0.0, 255.0) as u8);
                }
            }
        }
        Image8::new(w, h, 3, data)
    }

    #[test]
    fn roundtrip_smooth() {
        let img = smooth_image(1, 32, 32);
        let enc = encode(&img);
        assert_eq!(decode(&enc).unwrap(), img);
        // Smooth content must compress well below raw size.
        assert!(enc.len() < img.data.len() / 2, "{} vs {}", enc.len(), img.data.len());
    }

    #[test]
    fn roundtrip_noise() {
        let mut rng = XorShift64Star::new(9);
        let data: Vec<u8> = (0..32 * 32 * 3).map(|_| rng.below(256) as u8).collect();
        let img = Image8::new(32, 32, 3, data);
        assert_eq!(decode(&encode(&img)).unwrap(), img);
    }

    #[test]
    fn paeth_matches_spec() {
        assert_eq!(paeth(0, 0, 0), 0);
        assert_eq!(paeth(10, 20, 30), 10); // p = 0; pa=10 smallest → a
        assert_eq!(paeth(100, 3, 1), 100); // p = 102; pa=2 smallest → a
        assert_eq!(paeth(3, 100, 1), 100); // p = 102; pb=2 smallest → b
        assert_eq!(paeth(50, 60, 2), 60); // p = 108; pb=48 < pa=58 → b
    }

    #[test]
    fn grayscale_roundtrip() {
        let data: Vec<u8> = (0..16 * 16).map(|i| (i % 251) as u8).collect();
        let img = Image8::new(16, 16, 1, data);
        assert_eq!(decode(&encode(&img)).unwrap(), img);
    }

    #[test]
    fn truncated_rejected() {
        let img = smooth_image(2, 16, 16);
        let enc = encode(&img);
        assert!(decode(&enc[..10]).is_err());
    }

    #[test]
    fn prop_roundtrip_random_sizes() {
        prop::check(
            "png-like roundtrip",
            prop::pair(prop::usize_in(1, 24), prop::usize_in(1, 24)),
            |(w, h)| {
                let mut rng = XorShift64Star::new((w * 31 + h) as u64);
                let data: Vec<u8> = (0..w * h * 3).map(|_| rng.below(256) as u8).collect();
                let img = Image8::new(*w, *h, 3, data);
                decode(&encode(&img)).as_ref() == Ok(&img)
            },
        );
    }
}
