//! Greedy LZ77 with hash-chain match search (window 32 KiB, match 3..258)
//! — the dictionary half of the deflate-like container in [`super::deflate`].

pub const WINDOW: usize = 32 * 1024;
pub const MIN_MATCH: usize = 3;
pub const MAX_MATCH: usize = 258;
const HASH_BITS: u32 = 15;
const MAX_CHAIN: usize = 64;

#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Token {
    Literal(u8),
    /// (length in 3..=258, distance in 1..=32768)
    Match { len: u16, dist: u16 },
}

#[inline]
fn hash3(b: &[u8]) -> usize {
    let v = (b[0] as u32) | ((b[1] as u32) << 8) | ((b[2] as u32) << 16);
    (v.wrapping_mul(0x9E3779B1) >> (32 - HASH_BITS)) as usize
}

/// Tokenize `data` greedily. Deterministic; no lazy matching (good-enough
/// ratios for the PNG-like baseline at much lower complexity).
pub fn compress(data: &[u8]) -> Vec<Token> {
    let n = data.len();
    let mut tokens = Vec::with_capacity(n / 2 + 8);
    let mut head = vec![usize::MAX; 1 << HASH_BITS];
    let mut prev = vec![usize::MAX; n];
    let mut i = 0;
    while i < n {
        let mut best_len = 0usize;
        let mut best_dist = 0usize;
        if i + MIN_MATCH <= n {
            let h = hash3(&data[i..]);
            let mut cand = head[h];
            let mut chain = 0;
            while cand != usize::MAX && i - cand <= WINDOW && chain < MAX_CHAIN {
                let max_len = (n - i).min(MAX_MATCH);
                let mut l = 0;
                while l < max_len && data[cand + l] == data[i + l] {
                    l += 1;
                }
                if l > best_len {
                    best_len = l;
                    best_dist = i - cand;
                    if l >= MAX_MATCH {
                        break;
                    }
                }
                cand = prev[cand];
                chain += 1;
            }
            // Insert current position into the chain.
            prev[i] = head[h];
            head[h] = i;
        }
        if best_len >= MIN_MATCH {
            tokens.push(Token::Match { len: best_len as u16, dist: best_dist as u16 });
            // Insert the skipped positions so later matches can reference them.
            let end = (i + best_len).min(n.saturating_sub(MIN_MATCH - 1));
            let mut j = i + 1;
            while j < end {
                let h = hash3(&data[j..]);
                prev[j] = head[h];
                head[h] = j;
                j += 1;
            }
            i += best_len;
        } else {
            tokens.push(Token::Literal(data[i]));
            i += 1;
        }
    }
    tokens
}

/// Expand tokens back to bytes.
pub fn decompress(tokens: &[Token]) -> Result<Vec<u8>, &'static str> {
    let mut out: Vec<u8> = Vec::new();
    for t in tokens {
        match *t {
            Token::Literal(b) => out.push(b),
            Token::Match { len, dist } => {
                let dist = dist as usize;
                let len = len as usize;
                if dist == 0 || dist > out.len() {
                    return Err("bad match distance");
                }
                let start = out.len() - dist;
                for k in 0..len {
                    let b = out[start + k];
                    out.push(b);
                }
            }
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    fn roundtrip(data: &[u8]) -> bool {
        decompress(&compress(data)).as_deref() == Ok(data)
    }

    #[test]
    fn empty_and_small() {
        assert!(roundtrip(b""));
        assert!(roundtrip(b"a"));
        assert!(roundtrip(b"ab"));
        assert!(roundtrip(b"abc"));
    }

    #[test]
    fn repeated_data_produces_matches() {
        let data: Vec<u8> = b"abcabcabcabcabcabcabcabc".to_vec();
        let tokens = compress(&data);
        assert!(tokens.iter().any(|t| matches!(t, Token::Match { .. })));
        assert!(roundtrip(&data));
    }

    #[test]
    fn overlapping_match() {
        // "aaaa..." forces dist=1 overlapping copies.
        let data = vec![b'a'; 500];
        let tokens = compress(&data);
        assert!(tokens.len() < 10, "tokens {}", tokens.len());
        assert!(roundtrip(&data));
    }

    #[test]
    fn bad_distance_rejected() {
        assert!(decompress(&[Token::Match { len: 3, dist: 1 }]).is_err());
    }

    #[test]
    fn prop_roundtrip_random() {
        prop::check("lz77 roundtrip random", prop::bytes(0, 2000), |d| roundtrip(d));
    }

    #[test]
    fn prop_roundtrip_lowentropy() {
        prop::check(
            "lz77 roundtrip low-entropy",
            prop::vec_of(prop::u64_in(0, 3).map(|x| x as u8), 0, 4000),
            |d| roundtrip(d),
        );
    }
}
