//! Deflate-like container: LZ77 tokens entropy-coded with two canonical
//! Huffman alphabets (literal/length + distance).
//!
//! Not bit-compatible with RFC 1951 — both ends are ours — but it uses
//! the same alphabet construction (length/distance bucketed into
//! base+extra-bits symbols), so compression ratios land in the same band
//! as real DEFLATE. Backs the PNG-like baseline codec and is available
//! as an optional second stage of the feature codec.

use super::bitio::{BitReader, BitWriter};
use super::huffman::{Decoder, Encoder, HuffError};
use super::lz77::{self, Token};

/// Literal/length alphabet: 0..=255 literals, 256 = end, 257..=285 length buckets.
const SYM_END: usize = 256;
const LEN_SYMS: usize = 286;
const DIST_SYMS: usize = 30;

// RFC 1951 length buckets: (base, extra_bits) for symbols 257..285.
const LEN_BASE: [u16; 29] = [
    3, 4, 5, 6, 7, 8, 9, 10, 11, 13, 15, 17, 19, 23, 27, 31, 35, 43, 51, 59, 67, 83, 99, 115,
    131, 163, 195, 227, 258,
];
const LEN_EXTRA: [u8; 29] =
    [0, 0, 0, 0, 0, 0, 0, 0, 1, 1, 1, 1, 2, 2, 2, 2, 3, 3, 3, 3, 4, 4, 4, 4, 5, 5, 5, 5, 0];

const DIST_BASE: [u16; 30] = [
    1, 2, 3, 4, 5, 7, 9, 13, 17, 25, 33, 49, 65, 97, 129, 193, 257, 385, 513, 769, 1025, 1537,
    2049, 3073, 4097, 6145, 8193, 12289, 16385, 24577,
];
const DIST_EXTRA: [u8; 30] = [
    0, 0, 0, 0, 1, 1, 2, 2, 3, 3, 4, 4, 5, 5, 6, 6, 7, 7, 8, 8, 9, 9, 10, 10, 11, 11, 12, 12,
    13, 13,
];

fn len_symbol(len: u16) -> (usize, u16, u8) {
    debug_assert!((3..=258).contains(&len));
    let mut s = 28;
    for i in 0..29 {
        if len < LEN_BASE[i] {
            s = i - 1;
            break;
        }
        if len == LEN_BASE[i] {
            s = i;
            break;
        }
        s = i;
    }
    (257 + s, len - LEN_BASE[s], LEN_EXTRA[s])
}

fn dist_symbol(dist: u16) -> (usize, u16, u8) {
    debug_assert!(dist >= 1);
    let mut s = DIST_SYMS - 1;
    for i in 0..DIST_SYMS {
        if (dist as u32) < DIST_BASE[i] as u32 {
            s = i - 1;
            break;
        }
        s = i;
    }
    (s, dist - DIST_BASE[s], DIST_EXTRA[s])
}

/// Compress bytes; output layout:
/// [orig_len u32][litlen lengths 286×u4][dist lengths 30×u4][payload bits].
pub fn compress(data: &[u8]) -> Vec<u8> {
    let tokens = lz77::compress(data);

    let mut lit_freq = vec![0u64; LEN_SYMS];
    let mut dist_freq = vec![0u64; DIST_SYMS];
    lit_freq[SYM_END] = 1;
    for t in &tokens {
        match *t {
            Token::Literal(b) => lit_freq[b as usize] += 1,
            Token::Match { len, dist } => {
                lit_freq[len_symbol(len).0] += 1;
                dist_freq[dist_symbol(dist).0] += 1;
            }
        }
    }
    // Guarantee at least one distance code so the decoder table is valid.
    if dist_freq.iter().all(|&f| f == 0) {
        dist_freq[0] = 1;
    }

    let lit_enc = Encoder::from_freqs(&lit_freq);
    let dist_enc = Encoder::from_freqs(&dist_freq);

    let mut out = Vec::new();
    let mut w = BitWriter::over(&mut out);
    w.write(data.len() as u64, 32);
    for &l in lit_enc.lengths() {
        w.write(l as u64, 4);
    }
    for &l in dist_enc.lengths() {
        w.write(l as u64, 4);
    }
    for t in &tokens {
        match *t {
            Token::Literal(b) => lit_enc.encode(&mut w, b as usize),
            Token::Match { len, dist } => {
                let (ls, lex, leb) = len_symbol(len);
                lit_enc.encode(&mut w, ls);
                w.write(lex as u64, leb as u32);
                let (ds, dex, deb) = dist_symbol(dist);
                dist_enc.encode(&mut w, ds);
                w.write(dex as u64, deb as u32);
            }
        }
    }
    lit_enc.encode(&mut w, SYM_END);
    w.finish();
    out
}

pub fn decompress(bytes: &[u8]) -> Result<Vec<u8>, HuffError> {
    let mut r = BitReader::new(bytes);
    let orig_len = r.read(32)? as usize;
    let mut lit_lengths = vec![0u8; LEN_SYMS];
    for l in lit_lengths.iter_mut() {
        *l = r.read(4)? as u8;
    }
    let mut dist_lengths = vec![0u8; DIST_SYMS];
    for l in dist_lengths.iter_mut() {
        *l = r.read(4)? as u8;
    }
    let lit_dec = Decoder::from_lengths(&lit_lengths)?;
    let dist_dec = Decoder::from_lengths(&dist_lengths)?;

    let mut out: Vec<u8> = Vec::with_capacity(orig_len);
    loop {
        let sym = lit_dec.decode(&mut r)? as usize;
        if sym < 256 {
            out.push(sym as u8);
        } else if sym == SYM_END {
            break;
        } else {
            let li = sym - 257;
            if li >= 29 {
                return Err(HuffError::BadCode);
            }
            let len = LEN_BASE[li] as usize + r.read(LEN_EXTRA[li] as u32)? as usize;
            let ds = dist_dec.decode(&mut r)? as usize;
            if ds >= DIST_SYMS {
                return Err(HuffError::BadCode);
            }
            let dist = DIST_BASE[ds] as usize + r.read(DIST_EXTRA[ds] as u32)? as usize;
            if dist == 0 || dist > out.len() {
                return Err(HuffError::BadCode);
            }
            let start = out.len() - dist;
            for k in 0..len {
                let b = out[start + k];
                out.push(b);
            }
        }
        if out.len() > orig_len {
            return Err(HuffError::BadCode);
        }
    }
    if out.len() != orig_len {
        return Err(HuffError::Truncated);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    fn roundtrip(data: &[u8]) -> bool {
        decompress(&compress(data)).as_deref() == Ok(data)
    }

    #[test]
    fn empty() {
        assert!(roundtrip(b""));
    }

    #[test]
    fn text_compresses() {
        // The fixed header (286+30 length nibbles ≈ 162 B) means only
        // inputs comfortably above ~200 B can shrink; use a long text.
        let data: Vec<u8> =
            b"the quick brown fox jumps over the lazy dog. ".repeat(30).to_vec();
        let c = compress(&data);
        assert!(c.len() < data.len() / 3, "{} vs {}", c.len(), data.len());
        assert!(roundtrip(&data));
    }

    #[test]
    fn len_symbol_buckets() {
        assert_eq!(len_symbol(3), (257, 0, 0));
        assert_eq!(len_symbol(10), (264, 0, 0));
        assert_eq!(len_symbol(11), (265, 0, 1));
        assert_eq!(len_symbol(12), (265, 1, 1));
        assert_eq!(len_symbol(258), (285, 0, 0));
    }

    #[test]
    fn dist_symbol_buckets() {
        assert_eq!(dist_symbol(1), (0, 0, 0));
        assert_eq!(dist_symbol(4), (3, 0, 0));
        assert_eq!(dist_symbol(5), (4, 0, 1));
        assert_eq!(dist_symbol(24577), (29, 0, 13));
        assert_eq!(dist_symbol(32768), (29, 8191, 13));
    }

    #[test]
    fn corrupt_stream_never_panics() {
        // Bit-flip every byte position in turn: decompress must return
        // (Ok or Err) without panicking or looping.
        let data: Vec<u8> = (0..400u32).map(|i| (i * 7 % 256) as u8).collect();
        let c = compress(&data);
        for pos in 0..c.len() {
            let mut bad = c.clone();
            bad[pos] ^= 0x55;
            let _ = decompress(&bad);
        }
    }

    #[test]
    fn prop_roundtrip_random() {
        prop::check("deflate roundtrip random", prop::bytes(0, 4000), |d| roundtrip(d));
    }

    #[test]
    fn prop_roundtrip_structured() {
        prop::check(
            "deflate roundtrip structured",
            prop::vec_of(prop::u64_in(0, 7).map(|x| (x * 31) as u8), 0, 6000),
            |d| roundtrip(d),
        );
    }
}
