//! JPEG-like lossy image codec for the JPEG2Cloud baseline (§IV-A).
//!
//! Classic pipeline: RGB → YCbCr, per-channel 8×8 DCT-II, quantization by
//! the Annex-K luma table scaled by a quality factor, zig-zag scan,
//! zero-run-length coding, canonical Huffman. No chroma subsampling and
//! no .jfif container — it only has to produce realistic lossy sizes and
//! distortions for the baseline comparison (DESIGN.md deviation 3).

use super::huffman;
use super::png::Image8;
use super::rle;

/// JPEG Annex K luminance quantization table (quality 50 reference).
const QTABLE: [i32; 64] = [
    16, 11, 10, 16, 24, 40, 51, 61, //
    12, 12, 14, 19, 26, 58, 60, 55, //
    14, 13, 16, 24, 40, 57, 69, 56, //
    14, 17, 22, 29, 51, 87, 80, 62, //
    18, 22, 37, 56, 68, 109, 103, 77, //
    24, 35, 55, 64, 81, 104, 113, 92, //
    49, 64, 78, 87, 103, 121, 120, 101, //
    72, 92, 95, 98, 112, 100, 103, 99,
];

fn scaled_qtable(quality: u8) -> [i32; 64] {
    let q = quality.clamp(1, 100) as i32;
    let scale = if q < 50 { 5000 / q } else { 200 - 2 * q };
    let mut t = [0i32; 64];
    for i in 0..64 {
        t[i] = ((QTABLE[i] * scale + 50) / 100).max(1);
    }
    t
}

/// Zig-zag order of an 8×8 block.
const ZIGZAG: [usize; 64] = [
    0, 1, 8, 16, 9, 2, 3, 10, 17, 24, 32, 25, 18, 11, 4, 5, 12, 19, 26, 33, 40, 48, 41, 34,
    27, 20, 13, 6, 7, 14, 21, 28, 35, 42, 49, 56, 57, 50, 43, 36, 29, 22, 15, 23, 30, 37, 44,
    51, 58, 59, 52, 45, 38, 31, 39, 46, 53, 60, 61, 54, 47, 55, 62, 63,
];

fn dct8(input: &[f32; 64]) -> [f32; 64] {
    let mut out = [0f32; 64];
    for u in 0..8 {
        for v in 0..8 {
            let cu = if u == 0 { std::f32::consts::FRAC_1_SQRT_2 } else { 1.0 };
            let cv = if v == 0 { std::f32::consts::FRAC_1_SQRT_2 } else { 1.0 };
            let mut sum = 0f32;
            for x in 0..8 {
                for y in 0..8 {
                    sum += input[x * 8 + y]
                        * ((2 * x + 1) as f32 * u as f32 * std::f32::consts::PI / 16.0).cos()
                        * ((2 * y + 1) as f32 * v as f32 * std::f32::consts::PI / 16.0).cos();
                }
            }
            out[u * 8 + v] = 0.25 * cu * cv * sum;
        }
    }
    out
}

fn idct8(input: &[f32; 64]) -> [f32; 64] {
    let mut out = [0f32; 64];
    for x in 0..8 {
        for y in 0..8 {
            let mut sum = 0f32;
            for u in 0..8 {
                for v in 0..8 {
                    let cu = if u == 0 { std::f32::consts::FRAC_1_SQRT_2 } else { 1.0 };
                    let cv = if v == 0 { std::f32::consts::FRAC_1_SQRT_2 } else { 1.0 };
                    sum += cu
                        * cv
                        * input[u * 8 + v]
                        * ((2 * x + 1) as f32 * u as f32 * std::f32::consts::PI / 16.0).cos()
                        * ((2 * y + 1) as f32 * v as f32 * std::f32::consts::PI / 16.0).cos();
                }
            }
            out[x * 8 + y] = 0.25 * sum;
        }
    }
    out
}

fn rgb_to_ycbcr(r: f32, g: f32, b: f32) -> (f32, f32, f32) {
    (
        0.299 * r + 0.587 * g + 0.114 * b,
        -0.168736 * r - 0.331264 * g + 0.5 * b + 128.0,
        0.5 * r - 0.418688 * g - 0.081312 * b + 128.0,
    )
}

fn ycbcr_to_rgb(y: f32, cb: f32, cr: f32) -> (f32, f32, f32) {
    let cb = cb - 128.0;
    let cr = cr - 128.0;
    (y + 1.402 * cr, y - 0.344136 * cb - 0.714136 * cr, y + 1.772 * cb)
}

/// Signed coefficient → zig-zag-mapped unsigned symbol (value folding).
#[inline]
fn fold(v: i32) -> u16 {
    if v >= 0 {
        (v as u16) << 1
    } else {
        (((-v) as u16) << 1) | 1
    }
}

#[inline]
fn unfold(s: u16) -> i32 {
    if s & 1 == 0 {
        (s >> 1) as i32
    } else {
        -((s >> 1) as i32)
    }
}

/// Encode. Layout: [w u16][h u16][quality u8][3 channel sections:
/// runs-block, values-block (huffman blocks from `huffman::encode_block`)].
pub fn encode(img: &Image8, quality: u8) -> Vec<u8> {
    assert_eq!(img.channels, 3, "jpeg-like codec expects RGB");
    let qt = scaled_qtable(quality);
    let bw = img.w.div_ceil(8);
    let bh = img.h.div_ceil(8);

    // Channel-planar YCbCr, edge-replicated to 8x8 multiples.
    let mut planes = vec![vec![0f32; bw * 8 * bh * 8]; 3];
    for y in 0..bh * 8 {
        for x in 0..bw * 8 {
            let sy = y.min(img.h - 1);
            let sx = x.min(img.w - 1);
            let p = (sy * img.w + sx) * 3;
            let (yy, cb, cr) = rgb_to_ycbcr(
                img.data[p] as f32,
                img.data[p + 1] as f32,
                img.data[p + 2] as f32,
            );
            let idx = y * bw * 8 + x;
            planes[0][idx] = yy;
            planes[1][idx] = cb;
            planes[2][idx] = cr;
        }
    }

    let mut out = Vec::new();
    out.extend_from_slice(&(img.w as u16).to_le_bytes());
    out.extend_from_slice(&(img.h as u16).to_le_bytes());
    out.push(quality);

    for plane in &planes {
        let mut symbols: Vec<u16> = Vec::new();
        for by in 0..bh {
            for bx in 0..bw {
                let mut block = [0f32; 64];
                for i in 0..8 {
                    for j in 0..8 {
                        block[i * 8 + j] = plane[(by * 8 + i) * bw * 8 + bx * 8 + j] - 128.0;
                    }
                }
                let coeffs = dct8(&block);
                for (k, &zz) in ZIGZAG.iter().enumerate() {
                    let q = (coeffs[zz] / qt[zz] as f32).round() as i32;
                    symbols.push(fold(q));
                    let _ = k;
                }
            }
        }
        let (runs, values) = rle::encode(&symbols);
        for section in [&runs, &values] {
            let alphabet = section.iter().copied().max().unwrap_or(0) as usize + 1;
            let block = huffman::encode_block(section, alphabet.max(2));
            out.extend_from_slice(&(block.len() as u32).to_le_bytes());
            out.extend_from_slice(&block);
        }
    }
    out
}

pub fn decode(bytes: &[u8]) -> Result<Image8, &'static str> {
    if bytes.len() < 5 {
        return Err("truncated header");
    }
    let w = u16::from_le_bytes([bytes[0], bytes[1]]) as usize;
    let h = u16::from_le_bytes([bytes[2], bytes[3]]) as usize;
    let quality = bytes[4];
    let qt = scaled_qtable(quality);
    let bw = w.div_ceil(8);
    let bh = h.div_ceil(8);
    let ncoef = bw * bh * 64;

    let mut pos = 5usize;
    let mut read_block = |pos: &mut usize| -> Result<Vec<u16>, &'static str> {
        let len = u32::from_le_bytes(
            bytes.get(*pos..*pos + 4).ok_or("truncated")?.try_into().unwrap(),
        ) as usize;
        *pos += 4;
        let blk = bytes.get(*pos..*pos + len).ok_or("truncated")?;
        *pos += len;
        huffman::decode_block(blk).map_err(|_| "bad huffman block")
    };

    let mut planes = Vec::with_capacity(3);
    for _ in 0..3 {
        let runs = read_block(&mut pos)?;
        let values = read_block(&mut pos)?;
        let symbols = rle::decode(&runs, &values, ncoef)?;
        let mut plane = vec![0f32; bw * 8 * bh * 8];
        for by in 0..bh {
            for bx in 0..bw {
                let base = (by * bw + bx) * 64;
                let mut coeffs = [0f32; 64];
                for (k, &zz) in ZIGZAG.iter().enumerate() {
                    coeffs[zz] = unfold(symbols[base + k]) as f32 * qt[zz] as f32;
                }
                let block = idct8(&coeffs);
                for i in 0..8 {
                    for j in 0..8 {
                        plane[(by * 8 + i) * bw * 8 + bx * 8 + j] = block[i * 8 + j] + 128.0;
                    }
                }
            }
        }
        planes.push(plane);
    }

    let mut data = Vec::with_capacity(w * h * 3);
    for y in 0..h {
        for x in 0..w {
            let idx = y * bw * 8 + x;
            let (r, g, b) = ycbcr_to_rgb(planes[0][idx], planes[1][idx], planes[2][idx]);
            data.push(r.clamp(0.0, 255.0) as u8);
            data.push(g.clamp(0.0, 255.0) as u8);
            data.push(b.clamp(0.0, 255.0) as u8);
        }
    }
    Ok(Image8 { w, h, channels: 3, data })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::XorShift64Star;

    fn gradient_image(w: usize, h: usize) -> Image8 {
        let mut data = Vec::with_capacity(w * h * 3);
        for y in 0..h {
            for x in 0..w {
                data.push(((x * 255) / w.max(1)) as u8);
                data.push(((y * 255) / h.max(1)) as u8);
                data.push((((x + y) * 127) / (w + h).max(1)) as u8);
            }
        }
        Image8::new(w, h, 3, data)
    }

    #[test]
    fn dct_idct_identity() {
        let mut rng = XorShift64Star::new(3);
        let mut block = [0f32; 64];
        for v in block.iter_mut() {
            *v = rng.below(256) as f32 - 128.0;
        }
        let rec = idct8(&dct8(&block));
        for (a, b) in block.iter().zip(&rec) {
            assert!((a - b).abs() < 1e-2, "{a} vs {b}");
        }
    }

    #[test]
    fn fold_unfold() {
        for v in [-300, -1, 0, 1, 2, 500] {
            assert_eq!(unfold(fold(v)), v);
        }
    }

    #[test]
    fn smooth_image_compresses_lossily() {
        let img = gradient_image(32, 32);
        let enc = encode(&img, 50);
        assert!(enc.len() < img.data.len() / 2, "{} bytes", enc.len());
        let dec = decode(&enc).unwrap();
        assert_eq!((dec.w, dec.h), (32, 32));
        // Lossy but close: mean abs error under ~8 gray levels.
        let mae: f64 = img
            .data
            .iter()
            .zip(&dec.data)
            .map(|(a, b)| (*a as f64 - *b as f64).abs())
            .sum::<f64>()
            / img.data.len() as f64;
        assert!(mae < 8.0, "mae {mae}");
    }

    #[test]
    fn quality_controls_size() {
        let img = gradient_image(32, 32);
        let hi = encode(&img, 90).len();
        let lo = encode(&img, 10).len();
        assert!(lo < hi, "q10 {lo} vs q90 {hi}");
    }

    #[test]
    fn non_multiple_of_8_sizes() {
        for (w, h) in [(9, 13), (17, 8), (7, 7)] {
            let img = gradient_image(w, h);
            let dec = decode(&encode(&img, 50)).unwrap();
            assert_eq!((dec.w, dec.h, dec.data.len()), (w, h, w * h * 3));
        }
    }
}
