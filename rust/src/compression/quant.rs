//! Rust twin of the L1 Pallas affine quantizer (paper §III-B).
//!
//! The edge pipeline normally quantizes through the exported Pallas
//! artifact (so L1 genuinely sits on the request path); this module is
//! the same arithmetic on host buffers, used by the calibration sweeps
//! (thousands of invocations), by tests cross-checking the PJRT kernel,
//! and as a fallback when an artifact is absent.
//!
//! ```text
//! y_i = clip(round((2^c - 1) · (x_i − min) / (max − min)), 0, 2^c−1)
//! ```

/// Quantization result: integer values (stored u16; c ≤ 16) + range.
#[derive(Debug, Clone, PartialEq)]
pub struct Quantized {
    pub values: Vec<u16>,
    pub lo: f32,
    pub hi: f32,
    pub c: u8,
}

/// Number of levels minus one for `c` bits.
#[inline]
pub fn qmax(c: u8) -> u32 {
    (1u32 << c) - 1
}

/// Single-pass min/max.
pub fn min_max(xs: &[f32]) -> (f32, f32) {
    let mut lo = f32::INFINITY;
    let mut hi = f32::NEG_INFINITY;
    for &x in xs {
        lo = lo.min(x);
        hi = hi.max(x);
    }
    if xs.is_empty() {
        (0.0, 0.0)
    } else {
        (lo, hi)
    }
}

/// Affine-quantize `xs` to `c` bits (1 ≤ c ≤ 16).
pub fn quantize(xs: &[f32], c: u8) -> Quantized {
    let mut values = Vec::new();
    let (lo, hi) = quantize_into(xs, c, &mut values);
    Quantized { values, lo, hi, c }
}

/// [`quantize`] into a caller-owned buffer (cleared, capacity reused);
/// returns the observed `(lo, hi)` range. The serving hot path's
/// quantize hop — allocation-free once the buffer is warm.
pub fn quantize_into(xs: &[f32], c: u8, out: &mut Vec<u16>) -> (f32, f32) {
    assert!((1..=16).contains(&c));
    let (lo, hi) = min_max(xs);
    let span = hi - lo;
    let levels = qmax(c) as f32;
    let scale = if span > 0.0 { levels / span } else { 0.0 };
    out.clear();
    out.reserve(xs.len());
    out.extend(xs.iter().map(|&x| {
        let y = ((x - lo) * scale).round();
        y.clamp(0.0, levels) as u16
    }));
    (lo, hi)
}

/// Inverse: x̂ = y / (2^c − 1) · (hi − lo) + lo.
pub fn dequantize(q: &Quantized) -> Vec<f32> {
    let mut out = Vec::new();
    dequantize_into(&q.values, q.lo, q.hi, q.c, &mut out);
    out
}

/// [`dequantize`] into a caller-owned buffer (cleared, capacity reused).
pub fn dequantize_into(values: &[u16], lo: f32, hi: f32, c: u8, out: &mut Vec<f32>) {
    let levels = qmax(c) as f32;
    let step = if levels > 0.0 { (hi - lo) / levels } else { 0.0 };
    out.clear();
    out.reserve(values.len());
    out.extend(values.iter().map(|&y| y as f32 * step + lo));
}

/// quantize→dequantize round trip (the distortion the cloud model sees).
pub fn fake_quant(xs: &[f32], c: u8) -> Vec<f32> {
    dequantize(&quantize(xs, c))
}

/// Max absolute reconstruction error bound: half a quantization step.
pub fn error_bound(lo: f32, hi: f32, c: u8) -> f32 {
    (hi - lo) / qmax(c) as f32 * 0.5
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn constant_input_roundtrips_exactly() {
        let xs = vec![3.25f32; 64];
        let q = quantize(&xs, 4);
        assert!(q.values.iter().all(|&v| v == 0));
        assert_eq!(dequantize(&q), xs);
    }

    #[test]
    fn endpoints_are_exact() {
        let xs = vec![-1.0, 0.5, 2.0];
        for c in 1..=8 {
            let q = quantize(&xs, c);
            let d = dequantize(&q);
            assert_eq!(d[0], -1.0);
            assert_eq!(d[2], 2.0);
        }
    }

    #[test]
    fn error_within_half_step() {
        let xs: Vec<f32> = (0..1000).map(|i| (i as f32 * 0.37).sin() * 5.0).collect();
        for c in 1..=12u8 {
            let (lo, hi) = min_max(&xs);
            let bound = error_bound(lo, hi, c) * 1.0001;
            let d = fake_quant(&xs, c);
            for (a, b) in xs.iter().zip(&d) {
                assert!((a - b).abs() <= bound, "c={c} err {}", (a - b).abs());
            }
        }
    }

    #[test]
    fn monotone_in_c() {
        let xs: Vec<f32> = (0..512).map(|i| ((i * 7919) % 101) as f32 / 10.0).collect();
        let mut prev = f32::INFINITY;
        for c in 1..=10u8 {
            let d = fake_quant(&xs, c);
            let err: f32 =
                xs.iter().zip(&d).map(|(a, b)| (a - b).abs()).fold(0.0, f32::max);
            assert!(err <= prev + 1e-6, "c={c}: {err} > {prev}");
            prev = err;
        }
    }

    #[test]
    fn values_fit_c_bits() {
        prop::check(
            "quantized values < 2^c",
            prop::pair(prop::sparse_features(1, 2048), prop::u64_in(1, 12)),
            |(xs, c)| {
                let q = quantize(xs, *c as u8);
                q.values.iter().all(|&v| (v as u32) <= qmax(*c as u8))
            },
        );
    }

    #[test]
    fn prop_into_matches_allocating() {
        prop::check(
            "quantize_into/dequantize_into ≡ legacy",
            prop::pair(prop::sparse_features(0, 1024), prop::u64_in(1, 12)),
            |(xs, c)| {
                let c = *c as u8;
                let q = quantize(xs, c);
                let mut values = vec![7u16; 3]; // stale contents must be cleared
                let (lo, hi) = quantize_into(xs, c, &mut values);
                let mut rec = vec![1.0f32];
                dequantize_into(&values, lo, hi, c, &mut rec);
                values == q.values && lo == q.lo && hi == q.hi && rec == dequantize(&q)
            },
        );
    }

    #[test]
    fn prop_reconstruction_bound() {
        prop::check(
            "dequantize within half step",
            prop::pair(prop::sparse_features(1, 1024), prop::u64_in(1, 10)),
            |(xs, c)| {
                let c = *c as u8;
                let (lo, hi) = min_max(xs);
                let bound = error_bound(lo, hi, c) * 1.0001 + 1e-6;
                let d = fake_quant(xs, c);
                xs.iter().zip(&d).all(|(a, b)| (a - b).abs() <= bound)
            },
        );
    }
}
