//! Feature-map wire codec — what the edge actually transmits (§III-B).
//!
//! Payload pipeline: c-bit quantized integers → canonical Huffman
//! (sparsity makes this win big) with a bit-packed fallback when Huffman
//! would expand (dense high-entropy maps at large c). A 24-byte header
//! carries everything the cloud needs to reconstruct:
//!
//! ```text
//! magic  u16  = 0x4A4C ("JL")
//! mode   u8   (0 = huffman, 1 = bitpack)
//! c      u8
//! n      u32  element count
//! lo     f32  affine range min
//! hi     f32  affine range max
//! stage  u16  decoupling stage index (for the cloud dispatcher)
//! model  u16  model id
//! len    u32  payload byte length
//! ```
//!
//! The streaming entry points ([`encode_into`] / [`decode_into`]) write
//! the header in place and backfill the payload length, so one reusable
//! output buffer plus a [`CodecScratch`] make the codec hop
//! allocation-free in steady state. The legacy allocating [`encode`] /
//! [`decode`] are thin wrappers producing byte-identical frames.

use super::bitio::{BitReader, BitWriter};
use super::huffman;
use super::quant::Quantized;

pub const MAGIC: u16 = 0x4A4C;
pub const HEADER_BYTES: usize = 24;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Mode {
    Huffman = 0,
    BitPack = 1,
}

#[derive(Debug, Clone, PartialEq)]
pub struct Frame {
    pub mode: Mode,
    pub c: u8,
    pub lo: f32,
    pub hi: f32,
    pub stage: u16,
    pub model: u16,
    pub values: Vec<u16>,
}

/// Frame metadata decoded by [`decode_into`] (the values land in the
/// caller's reusable buffer instead of an owned `Vec`).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Header {
    pub mode: Mode,
    pub c: u8,
    pub n: usize,
    pub lo: f32,
    pub hi: f32,
    pub stage: u16,
    pub model: u16,
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub enum CodecError {
    BadMagic,
    BadHeader,
    Truncated,
    Corrupt(&'static str),
}

impl std::fmt::Display for CodecError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{:?}", self)
    }
}
impl std::error::Error for CodecError {}

/// Reusable codec workspace: the symbol histogram plus rebuildable
/// Huffman encoder/decoder state. One per session or connection — with
/// it, [`encode_into`]/[`decode_into`] never touch the heap once warm.
#[derive(Debug)]
pub struct CodecScratch {
    freqs: Vec<u64>,
    encoder: huffman::Encoder,
    enc_ws: huffman::EncoderScratch,
    dec: huffman::DecodeScratch,
}

impl Default for CodecScratch {
    fn default() -> Self {
        Self {
            freqs: Vec::new(),
            encoder: huffman::Encoder::new_empty(),
            enc_ws: huffman::EncoderScratch::default(),
            dec: huffman::DecodeScratch::default(),
        }
    }
}

impl CodecScratch {
    pub fn new() -> Self {
        Self::default()
    }
}

/// Pack quantized values with plain c-bit fields (no entropy coding).
pub fn bitpack(values: &[u16], c: u8) -> Vec<u8> {
    let mut out = Vec::new();
    bitpack_into(values, c, &mut out);
    out
}

/// [`bitpack`] appending to a caller-owned buffer.
pub fn bitpack_into(values: &[u16], c: u8, out: &mut Vec<u8>) {
    let mut w = BitWriter::over(out);
    for &v in values {
        w.write(v as u64, c as u32);
    }
    w.finish();
}

pub fn bitunpack(bytes: &[u8], c: u8, n: usize) -> Result<Vec<u16>, CodecError> {
    let mut out = Vec::new();
    bitunpack_into(bytes, c, n, &mut out)?;
    Ok(out)
}

/// [`bitunpack`] into a caller-owned buffer (cleared, capacity reused).
pub fn bitunpack_into(bytes: &[u8], c: u8, n: usize, out: &mut Vec<u16>) -> Result<(), CodecError> {
    // Reject element counts the payload cannot hold before reserving
    // memory for them (untrusted header hardening).
    if (n as u64) * (c as u64) > bytes.len() as u64 * 8 {
        return Err(CodecError::Truncated);
    }
    let mut r = BitReader::new(bytes);
    out.clear();
    out.reserve(n);
    for _ in 0..n {
        out.push(r.read(c as u32).map_err(|_| CodecError::Truncated)? as u16);
    }
    Ok(())
}

/// Encode a quantized feature map into a self-describing wire frame.
pub fn encode(q: &Quantized, stage: u16, model: u16) -> Vec<u8> {
    let mut ws = CodecScratch::new();
    let mut out = Vec::new();
    encode_into(q, stage, model, &mut ws, &mut out);
    out
}

/// [`encode`] into a caller-owned buffer with reusable codec scratch.
pub fn encode_into(q: &Quantized, stage: u16, model: u16, ws: &mut CodecScratch, out: &mut Vec<u8>) {
    encode_parts_into(&q.values, q.c, q.lo, q.hi, stage, model, ws, out)
}

/// Core streaming encoder over borrowed parts (lets the caller keep the
/// quantized values in a pooled buffer rather than a `Quantized`).
///
/// Mode selection uses the exact size predictor (one histogram pass) so
/// only the winning representation is materialized — building both and
/// discarding one cost ~2× on the edge's encode path (§Perf log). Dense
/// high-entropy maps at large c fall back to plain bit-packing. The
/// header is written first and the payload streams straight after it;
/// the payload length is backfilled, so no intermediate payload buffer
/// exists (the seed path allocated and copied one per request).
#[allow(clippy::too_many_arguments)]
pub fn encode_parts_into(
    values: &[u16],
    c: u8,
    lo: f32,
    hi: f32,
    stage: u16,
    model: u16,
    ws: &mut CodecScratch,
    out: &mut Vec<u8>,
) {
    let alphabet = (1usize << c).max(2);
    let packed_bytes = (values.len() * c as usize).div_ceil(8);
    // The Huffman block header stores the alphabet in 16 bits, so a
    // c=16 alphabet (65536) cannot be represented — the seed silently
    // truncated it to 0 and produced an undecodable frame. Force the
    // bit-packed representation there (and skip the pointless histogram
    // + tree build entirely).
    let (mode, predicted_payload) = if alphabet > u16::MAX as usize {
        (Mode::BitPack, packed_bytes)
    } else {
        let CodecScratch { freqs, encoder, enc_ws, .. } = &mut *ws;
        freqs.clear();
        freqs.resize(alphabet, 0);
        for &v in values {
            freqs[v as usize] += 1;
        }
        encoder.rebuild_from_freqs(freqs, enc_ws);
        let payload_bits: u64 =
            freqs.iter().enumerate().map(|(s, &f)| f * encoder.cost_bits(s) as u64).sum();
        let header_bits = 16 + alphabet as u64 * 4 + 32;
        let huff_bytes = ((payload_bits + header_bits) as usize).div_ceil(8);
        if huff_bytes <= packed_bytes {
            (Mode::Huffman, huff_bytes)
        } else {
            (Mode::BitPack, packed_bytes)
        }
    };

    out.clear();
    out.reserve(HEADER_BYTES + predicted_payload);
    out.extend_from_slice(&MAGIC.to_le_bytes());
    out.push(mode as u8);
    out.push(c);
    out.extend_from_slice(&(values.len() as u32).to_le_bytes());
    out.extend_from_slice(&lo.to_le_bytes());
    out.extend_from_slice(&hi.to_le_bytes());
    out.extend_from_slice(&stage.to_le_bytes());
    out.extend_from_slice(&model.to_le_bytes());
    out.extend_from_slice(&[0u8; 4]); // payload length, backfilled below
    match mode {
        Mode::Huffman => huffman::encode_block_with_into(&ws.encoder, values, alphabet, out),
        Mode::BitPack => bitpack_into(values, c, out),
    }
    let plen = (out.len() - HEADER_BYTES) as u32;
    out[HEADER_BYTES - 4..HEADER_BYTES].copy_from_slice(&plen.to_le_bytes());
}

/// Size in bytes [`encode`] would produce, without producing it.
/// Used by the `S_i(c)` predictor builder (§III-C) on the calibration path.
pub fn encoded_size(q: &Quantized) -> usize {
    let alphabet = (1usize << q.c).max(2);
    let packed_bytes = (q.values.len() * q.c as usize).div_ceil(8);
    if alphabet > u16::MAX as usize {
        // c=16: Huffman unrepresentable — skip the 65k-entry histogram
        // and tree build entirely (mirrors encode_parts_into).
        return HEADER_BYTES + packed_bytes;
    }
    let mut freqs = vec![0u64; alphabet];
    for &v in &q.values {
        freqs[v as usize] += 1;
    }
    let enc = huffman::Encoder::from_freqs(&freqs);
    let payload_bits: u64 =
        freqs.iter().enumerate().map(|(s, &f)| f * enc.cost_bits(s) as u64).sum();
    let header_bits = 16 + alphabet as u64 * 4 + 32;
    let huff_bytes = ((payload_bits + header_bits) as usize).div_ceil(8);
    HEADER_BYTES + huff_bytes.min(packed_bytes)
}

/// Decode a wire frame. The caller dequantizes via `quant::dequantize`
/// or the PJRT dequant artifact.
pub fn decode(bytes: &[u8]) -> Result<Frame, CodecError> {
    let mut ws = CodecScratch::new();
    let mut values = Vec::new();
    let h = decode_into(bytes, &mut ws, &mut values)?;
    Ok(Frame {
        mode: h.mode,
        c: h.c,
        lo: h.lo,
        hi: h.hi,
        stage: h.stage,
        model: h.model,
        values,
    })
}

/// Read `(model, stage)` from a frame's fixed header without touching
/// the entropy-coded payload. The cloud's admission control uses this
/// to decide a shed *before* paying the Huffman decode — refusing work
/// must not cost a multi-megabyte decode on the very worker the server
/// is trying to protect. `None` when the bytes cannot be a valid frame
/// head (short / wrong magic); such frames proceed to the full decode
/// path and fail there with a precise error.
pub fn peek_route(bytes: &[u8]) -> Option<(u16, u16)> {
    if bytes.len() < HEADER_BYTES {
        return None;
    }
    if u16::from_le_bytes([bytes[0], bytes[1]]) != MAGIC {
        return None;
    }
    let stage = u16::from_le_bytes(bytes[16..18].try_into().unwrap());
    let model = u16::from_le_bytes(bytes[18..20].try_into().unwrap());
    Some((model, stage))
}

/// Total byte length the fixed header says this frame occupies
/// (header + declared payload length), without touching the payload.
/// `None` when the bytes cannot be a valid frame head (short / wrong
/// magic). The cloud server uses this to decide whether trailing bytes
/// (e.g. a tenant trailer) follow the frame — exactly, not
/// heuristically.
pub fn frame_len(bytes: &[u8]) -> Option<usize> {
    if bytes.len() < HEADER_BYTES || u16::from_le_bytes([bytes[0], bytes[1]]) != MAGIC {
        return None;
    }
    let plen = u32::from_le_bytes(bytes[20..24].try_into().unwrap()) as usize;
    Some(HEADER_BYTES + plen)
}

/// [`decode`] into a caller-owned values buffer with reusable scratch;
/// returns the frame metadata.
pub fn decode_into(
    bytes: &[u8],
    ws: &mut CodecScratch,
    values: &mut Vec<u16>,
) -> Result<Header, CodecError> {
    if bytes.len() < HEADER_BYTES {
        return Err(CodecError::Truncated);
    }
    let magic = u16::from_le_bytes([bytes[0], bytes[1]]);
    if magic != MAGIC {
        return Err(CodecError::BadMagic);
    }
    let mode = match bytes[2] {
        0 => Mode::Huffman,
        1 => Mode::BitPack,
        _ => return Err(CodecError::BadHeader),
    };
    let c = bytes[3];
    if !(1..=16).contains(&c) {
        return Err(CodecError::BadHeader);
    }
    let n = u32::from_le_bytes(bytes[4..8].try_into().unwrap()) as usize;
    let lo = f32::from_le_bytes(bytes[8..12].try_into().unwrap());
    let hi = f32::from_le_bytes(bytes[12..16].try_into().unwrap());
    let stage = u16::from_le_bytes(bytes[16..18].try_into().unwrap());
    let model = u16::from_le_bytes(bytes[18..20].try_into().unwrap());
    let plen = u32::from_le_bytes(bytes[20..24].try_into().unwrap()) as usize;
    let payload = bytes.get(HEADER_BYTES..HEADER_BYTES + plen).ok_or(CodecError::Truncated)?;

    match mode {
        Mode::Huffman => {
            huffman::decode_block_into(payload, &mut ws.dec, values)
                .map_err(|_| CodecError::Corrupt("huffman"))?;
            if values.len() != n {
                return Err(CodecError::Corrupt("length mismatch"));
            }
        }
        Mode::BitPack => bitunpack_into(payload, c, n, values)?,
    }
    let maxv = super::quant::qmax(c) as u16;
    if values.iter().any(|&v| v > maxv) {
        return Err(CodecError::Corrupt("value exceeds 2^c-1"));
    }
    Ok(Header { mode, c, n, lo, hi, stage, model })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compression::quant;
    use crate::util::prop;

    fn sample_features(n: usize) -> Vec<f32> {
        (0..n)
            .map(|i| if i % 3 == 0 { 0.0 } else { ((i * 2654435761) % 1000) as f32 / 100.0 })
            .collect()
    }

    #[test]
    fn roundtrip_all_c() {
        let xs = sample_features(4096);
        for c in 1..=8u8 {
            let q = quant::quantize(&xs, c);
            let wire = encode(&q, 7, 2);
            let frame = decode(&wire).unwrap();
            assert_eq!(frame.values, q.values, "c={c}");
            assert_eq!(frame.c, c);
            assert_eq!(frame.stage, 7);
            assert_eq!(frame.model, 2);
            assert_eq!(frame.lo, q.lo);
            assert_eq!(frame.hi, q.hi);
        }
    }

    #[test]
    fn frame_len_matches_encoded_length() {
        for (n, c) in [(64usize, 2u8), (4096, 4), (512, 16)] {
            let q = quant::quantize(&sample_features(n), c);
            let wire = encode(&q, 1, 0);
            assert_eq!(frame_len(&wire), Some(wire.len()), "n={n} c={c}");
            // Trailing bytes (e.g. a tenant trailer) don't change the
            // declared frame length.
            let mut extended = wire.clone();
            extended.extend_from_slice(&[1, 2, 3, 4, 5, 6, 7, 8]);
            assert_eq!(frame_len(&extended), Some(wire.len()));
        }
        assert_eq!(frame_len(&[0u8; 4]), None);
        let mut bad = encode(&quant::quantize(&sample_features(16), 4), 0, 0);
        bad[0] ^= 0xFF;
        assert_eq!(frame_len(&bad), None);
    }

    #[test]
    fn peek_route_reads_header_without_decode() {
        let q = quant::quantize(&sample_features(256), 4);
        let wire = encode(&q, 9, 3);
        assert_eq!(peek_route(&wire), Some((3, 9)));
        // Short or mis-tagged bytes are unpeekable, never misread.
        assert_eq!(peek_route(&wire[..HEADER_BYTES - 1]), None);
        let mut bad = wire.clone();
        bad[0] ^= 0xFF;
        assert_eq!(peek_route(&bad), None);
        // The peek agrees with the full decode on the same frame.
        let h = decode(&wire).unwrap();
        assert_eq!((h.model, h.stage), (3, 9));
    }

    #[test]
    fn encoded_size_is_exact() {
        let xs = sample_features(10_000);
        for c in [1u8, 2, 4, 8] {
            let q = quant::quantize(&xs, c);
            assert_eq!(encoded_size(&q), encode(&q, 0, 0).len(), "c={c}");
        }
    }

    #[test]
    fn sparse_maps_beat_bitpack() {
        // 95% zeros at c=8: Huffman ≈ n·0.3 bits ≪ bitpack n·8 bits.
        let xs: Vec<f32> =
            (0..20_000).map(|i| if i % 20 == 0 { (i % 97) as f32 } else { 0.0 }).collect();
        let q = quant::quantize(&xs, 8);
        let wire = encode(&q, 0, 0);
        assert!(wire.len() < 20_000 / 2, "wire {} bytes", wire.len());
        assert_eq!(decode(&wire).unwrap().values, q.values);
    }

    #[test]
    fn bad_magic_rejected() {
        let xs = sample_features(64);
        let mut wire = encode(&quant::quantize(&xs, 4), 0, 0);
        wire[0] = 0;
        assert_eq!(decode(&wire), Err(CodecError::BadMagic));
    }

    #[test]
    fn truncation_rejected() {
        let xs = sample_features(64);
        let wire = encode(&quant::quantize(&xs, 4), 0, 0);
        for cut in [0, 5, HEADER_BYTES, wire.len() - 1] {
            assert!(decode(&wire[..cut]).is_err(), "cut={cut}");
        }
    }

    #[test]
    fn c16_roundtrips_via_bitpack() {
        // At c=16 the Huffman header cannot hold the alphabet; the
        // codec must fall back to bit-packing and still round-trip.
        let xs = sample_features(512);
        let q = quant::quantize(&xs, 16);
        let wire = encode(&q, 1, 0);
        let frame = decode(&wire).unwrap();
        assert_eq!(frame.mode, Mode::BitPack);
        assert_eq!(frame.values, q.values);
        assert_eq!(encoded_size(&q), wire.len());
    }

    #[test]
    fn scratch_reuse_across_mixed_frames() {
        // One scratch serving frames of different c / size / mode must
        // not leak state between requests (the per-connection pattern).
        let mut ws = CodecScratch::new();
        let mut out = Vec::new();
        let mut values = Vec::new();
        for (n, c) in [(4096usize, 4u8), (64, 8), (10_000, 1), (333, 6)] {
            let xs = sample_features(n);
            let q = quant::quantize(&xs, c);
            encode_into(&q, 9, 3, &mut ws, &mut out);
            assert_eq!(out, encode(&q, 9, 3), "n={n} c={c}");
            let h = decode_into(&out, &mut ws, &mut values).unwrap();
            assert_eq!(values, q.values, "n={n} c={c}");
            assert_eq!((h.c, h.stage, h.model, h.lo, h.hi), (c, 9, 3, q.lo, q.hi));
        }
    }

    #[test]
    fn prop_into_matches_legacy() {
        // The acceptance property: streaming APIs are byte-identical to
        // the legacy allocating codec across random (c, n, lo, hi,
        // sparsity) inputs — lo/hi vary through a random affine map.
        prop::check(
            "encode_into/decode_into ≡ encode/decode",
            prop::pair(
                prop::pair(prop::sparse_features(0, 4096), prop::u64_in(1, 8)),
                prop::pair(prop::f32_in(-50.0, 50.0), prop::f32_in(0.1, 20.0)),
            ),
            |((xs, c), (offset, scale))| {
                let xs: Vec<f32> = xs.iter().map(|&x| x * scale + offset).collect();
                let q = quant::quantize(&xs, *c as u8);
                let legacy_wire = encode(&q, 3, 1);
                let mut ws = CodecScratch::new();
                let mut wire = Vec::new();
                encode_into(&q, 3, 1, &mut ws, &mut wire);
                if wire != legacy_wire {
                    return false;
                }
                let legacy_frame = decode(&legacy_wire).unwrap();
                let mut values = Vec::new();
                let h = decode_into(&wire, &mut ws, &mut values).unwrap();
                values == legacy_frame.values
                    && h.lo == legacy_frame.lo
                    && h.hi == legacy_frame.hi
                    && h.c == legacy_frame.c
                    && h.stage == legacy_frame.stage
                    && h.model == legacy_frame.model
                    && h.mode == legacy_frame.mode
            },
        );
    }

    #[test]
    fn prop_roundtrip() {
        prop::check(
            "feature frame roundtrip",
            prop::pair(prop::sparse_features(1, 4096), prop::u64_in(1, 8)),
            |(xs, c)| {
                let q = quant::quantize(xs, *c as u8);
                let frame = decode(&encode(&q, 3, 1)).unwrap();
                frame.values == q.values && frame.lo == q.lo && frame.hi == q.hi
            },
        );
    }

    #[test]
    fn prop_end_to_end_reconstruction_error() {
        prop::check(
            "wire roundtrip preserves quantizer error bound",
            prop::pair(prop::sparse_features(2, 2048), prop::u64_in(2, 8)),
            |(xs, c)| {
                let c = *c as u8;
                let q = quant::quantize(xs, c);
                let frame = decode(&encode(&q, 0, 0)).unwrap();
                let rq = quant::Quantized {
                    values: frame.values.clone(),
                    lo: frame.lo,
                    hi: frame.hi,
                    c: frame.c,
                };
                let rec = quant::dequantize(&rq);
                let bound = quant::error_bound(q.lo, q.hi, c) * 1.0001 + 1e-6;
                xs.iter().zip(&rec).all(|(a, b)| (a - b).abs() <= bound)
            },
        );
    }
}
