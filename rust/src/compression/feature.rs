//! Feature-map wire codec — what the edge actually transmits (§III-B).
//!
//! Payload pipeline: c-bit quantized integers → canonical Huffman
//! (sparsity makes this win big) with a bit-packed fallback when Huffman
//! would expand (dense high-entropy maps at large c). A 24-byte header
//! carries everything the cloud needs to reconstruct:
//!
//! ```text
//! magic  u16  = 0x4A4C ("JL")
//! mode   u8   (0 = huffman, 1 = bitpack)
//! c      u8
//! n      u32  element count
//! lo     f32  affine range min
//! hi     f32  affine range max
//! stage  u16  decoupling stage index (for the cloud dispatcher)
//! model  u16  model id
//! len    u32  payload byte length
//! ```

use super::bitio::{BitReader, BitWriter};
use super::huffman;
use super::quant::Quantized;

pub const MAGIC: u16 = 0x4A4C;
pub const HEADER_BYTES: usize = 24;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Mode {
    Huffman = 0,
    BitPack = 1,
}

#[derive(Debug, Clone, PartialEq)]
pub struct Frame {
    pub mode: Mode,
    pub c: u8,
    pub lo: f32,
    pub hi: f32,
    pub stage: u16,
    pub model: u16,
    pub values: Vec<u16>,
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub enum CodecError {
    BadMagic,
    BadHeader,
    Truncated,
    Corrupt(&'static str),
}

impl std::fmt::Display for CodecError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{:?}", self)
    }
}
impl std::error::Error for CodecError {}

/// Pack quantized values with plain c-bit fields (no entropy coding).
pub fn bitpack(values: &[u16], c: u8) -> Vec<u8> {
    let mut w = BitWriter::new();
    for &v in values {
        w.write(v as u64, c as u32);
    }
    w.finish()
}

pub fn bitunpack(bytes: &[u8], c: u8, n: usize) -> Result<Vec<u16>, CodecError> {
    let mut r = BitReader::new(bytes);
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        out.push(r.read(c as u32).map_err(|_| CodecError::Truncated)? as u16);
    }
    Ok(out)
}

/// Encode a quantized feature map into a self-describing wire frame.
///
/// Mode selection uses the exact size predictor (one histogram pass) so
/// only the winning representation is materialized — building both and
/// discarding one cost ~2× on the edge's encode path (§Perf log). Dense
/// high-entropy maps at large c fall back to plain bit-packing.
pub fn encode(q: &Quantized, stage: u16, model: u16) -> Vec<u8> {
    let alphabet = (1usize << q.c).max(2);
    let mut freqs = vec![0u64; alphabet];
    for &v in &q.values {
        freqs[v as usize] += 1;
    }
    let enc = huffman::Encoder::from_freqs(&freqs);
    let payload_bits: u64 =
        freqs.iter().enumerate().map(|(s, &f)| f * enc.cost_bits(s) as u64).sum();
    let header_bits = 16 + alphabet as u64 * 4 + 32;
    let huff_bytes = ((payload_bits + header_bits) as usize).div_ceil(8);
    let packed_bytes = (q.values.len() * q.c as usize).div_ceil(8);

    let (mode, payload) = if huff_bytes <= packed_bytes {
        (Mode::Huffman, huffman::encode_block_with(&enc, &q.values, alphabet))
    } else {
        (Mode::BitPack, bitpack(&q.values, q.c))
    };

    let mut out = Vec::with_capacity(HEADER_BYTES + payload.len());
    out.extend_from_slice(&MAGIC.to_le_bytes());
    out.push(mode as u8);
    out.push(q.c);
    out.extend_from_slice(&(q.values.len() as u32).to_le_bytes());
    out.extend_from_slice(&q.lo.to_le_bytes());
    out.extend_from_slice(&q.hi.to_le_bytes());
    out.extend_from_slice(&stage.to_le_bytes());
    out.extend_from_slice(&model.to_le_bytes());
    out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    out.extend_from_slice(&payload);
    out
}

/// Size in bytes [`encode`] would produce, without producing it.
/// Used by the `S_i(c)` predictor builder (§III-C) on the calibration path.
pub fn encoded_size(q: &Quantized) -> usize {
    let alphabet = (1usize << q.c).max(2);
    let mut freqs = vec![0u64; alphabet];
    for &v in &q.values {
        freqs[v as usize] += 1;
    }
    let enc = huffman::Encoder::from_freqs(&freqs);
    let payload_bits: u64 =
        freqs.iter().enumerate().map(|(s, &f)| f * enc.cost_bits(s) as u64).sum();
    let header_bits = 16 + alphabet as u64 * 4 + 32;
    let huff_bytes = ((payload_bits + header_bits) as usize).div_ceil(8);
    let packed_bytes = (q.values.len() * q.c as usize).div_ceil(8);
    HEADER_BYTES + huff_bytes.min(packed_bytes)
}

/// Decode a wire frame. The caller dequantizes via `quant::dequantize`
/// or the PJRT dequant artifact.
pub fn decode(bytes: &[u8]) -> Result<Frame, CodecError> {
    if bytes.len() < HEADER_BYTES {
        return Err(CodecError::Truncated);
    }
    let magic = u16::from_le_bytes([bytes[0], bytes[1]]);
    if magic != MAGIC {
        return Err(CodecError::BadMagic);
    }
    let mode = match bytes[2] {
        0 => Mode::Huffman,
        1 => Mode::BitPack,
        _ => return Err(CodecError::BadHeader),
    };
    let c = bytes[3];
    if !(1..=16).contains(&c) {
        return Err(CodecError::BadHeader);
    }
    let n = u32::from_le_bytes(bytes[4..8].try_into().unwrap()) as usize;
    let lo = f32::from_le_bytes(bytes[8..12].try_into().unwrap());
    let hi = f32::from_le_bytes(bytes[12..16].try_into().unwrap());
    let stage = u16::from_le_bytes(bytes[16..18].try_into().unwrap());
    let model = u16::from_le_bytes(bytes[18..20].try_into().unwrap());
    let plen = u32::from_le_bytes(bytes[20..24].try_into().unwrap()) as usize;
    let payload = bytes.get(HEADER_BYTES..HEADER_BYTES + plen).ok_or(CodecError::Truncated)?;

    let values = match mode {
        Mode::Huffman => {
            let v = huffman::decode_block(payload).map_err(|_| CodecError::Corrupt("huffman"))?;
            if v.len() != n {
                return Err(CodecError::Corrupt("length mismatch"));
            }
            v
        }
        Mode::BitPack => bitunpack(payload, c, n)?,
    };
    let maxv = super::quant::qmax(c) as u16;
    if values.iter().any(|&v| v > maxv) {
        return Err(CodecError::Corrupt("value exceeds 2^c-1"));
    }
    Ok(Frame { mode, c, lo, hi, stage, model, values })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compression::quant;
    use crate::util::prop;

    fn sample_features(n: usize) -> Vec<f32> {
        (0..n)
            .map(|i| if i % 3 == 0 { 0.0 } else { ((i * 2654435761) % 1000) as f32 / 100.0 })
            .collect()
    }

    #[test]
    fn roundtrip_all_c() {
        let xs = sample_features(4096);
        for c in 1..=8u8 {
            let q = quant::quantize(&xs, c);
            let wire = encode(&q, 7, 2);
            let frame = decode(&wire).unwrap();
            assert_eq!(frame.values, q.values, "c={c}");
            assert_eq!(frame.c, c);
            assert_eq!(frame.stage, 7);
            assert_eq!(frame.model, 2);
            assert_eq!(frame.lo, q.lo);
            assert_eq!(frame.hi, q.hi);
        }
    }

    #[test]
    fn encoded_size_is_exact() {
        let xs = sample_features(10_000);
        for c in [1u8, 2, 4, 8] {
            let q = quant::quantize(&xs, c);
            assert_eq!(encoded_size(&q), encode(&q, 0, 0).len(), "c={c}");
        }
    }

    #[test]
    fn sparse_maps_beat_bitpack() {
        // 95% zeros at c=8: Huffman ≈ n·0.3 bits ≪ bitpack n·8 bits.
        let xs: Vec<f32> =
            (0..20_000).map(|i| if i % 20 == 0 { (i % 97) as f32 } else { 0.0 }).collect();
        let q = quant::quantize(&xs, 8);
        let wire = encode(&q, 0, 0);
        assert!(wire.len() < 20_000 / 2, "wire {} bytes", wire.len());
        assert_eq!(decode(&wire).unwrap().values, q.values);
    }

    #[test]
    fn bad_magic_rejected() {
        let xs = sample_features(64);
        let mut wire = encode(&quant::quantize(&xs, 4), 0, 0);
        wire[0] = 0;
        assert_eq!(decode(&wire), Err(CodecError::BadMagic));
    }

    #[test]
    fn truncation_rejected() {
        let xs = sample_features(64);
        let wire = encode(&quant::quantize(&xs, 4), 0, 0);
        for cut in [0, 5, HEADER_BYTES, wire.len() - 1] {
            assert!(decode(&wire[..cut]).is_err(), "cut={cut}");
        }
    }

    #[test]
    fn prop_roundtrip() {
        prop::check(
            "feature frame roundtrip",
            prop::pair(prop::sparse_features(1, 4096), prop::u64_in(1, 8)),
            |(xs, c)| {
                let q = quant::quantize(xs, *c as u8);
                let frame = decode(&encode(&q, 3, 1)).unwrap();
                frame.values == q.values && frame.lo == q.lo && frame.hi == q.hi
            },
        );
    }

    #[test]
    fn prop_end_to_end_reconstruction_error() {
        prop::check(
            "wire roundtrip preserves quantizer error bound",
            prop::pair(prop::sparse_features(2, 2048), prop::u64_in(2, 8)),
            |(xs, c)| {
                let c = *c as u8;
                let q = quant::quantize(xs, c);
                let frame = decode(&encode(&q, 0, 0)).unwrap();
                let rq = quant::Quantized {
                    values: frame.values.clone(),
                    lo: frame.lo,
                    hi: frame.hi,
                    c: frame.c,
                };
                let rec = quant::dequantize(&rq);
                let bound = quant::error_bound(q.lo, q.hi, c) * 1.0001 + 1e-6;
                xs.iter().zip(&rec).all(|(a, b)| (a - b).abs() <= bound)
            },
        );
    }
}
