//! Compression substrates (all from scratch — no codec crates offline).
//!
//! JALAD's in-layer feature compression (paper §III-B) is
//! quantize → entropy-code. This module provides:
//!
//! * [`quant`] — the rust twin of the L1 Pallas affine quantizer (used on
//!   fast paths and to cross-check the PJRT kernel);
//! * [`bitio`] — LSB-first bit streams;
//! * [`huffman`] — canonical Huffman coding (the paper's entropy coder);
//! * [`lz77`] + [`deflate`] — a deflate-like LZ77+Huffman container,
//!   backing the PNG-like baseline codec;
//! * [`feature`] — the wire codec for quantized feature maps (what the
//!   edge actually transmits);
//! * [`png`] — PNG-like lossless image codec (PNG2Cloud baseline);
//! * [`jpeg`] — JPEG-like lossy image codec (JPEG2Cloud baseline);
//! * [`rle`] — zero-run-length coding used by the JPEG-like codec.

pub mod bitio;
pub mod deflate;
pub mod feature;
pub mod huffman;
pub mod jpeg;
pub mod lz77;
pub mod png;
pub mod quant;
pub mod rle;
