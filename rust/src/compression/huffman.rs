//! Canonical Huffman coding — JALAD's entropy coder (paper §III-B:
//! "We introduce Huffman Coding to further compress the quantized
//! integer feature maps").
//!
//! * Code lengths come from a binary heap merge; if the longest code
//!   exceeds [`MAX_BITS`] the frequencies are damped (`f/2+1`) and the
//!   tree rebuilt (zlib's classic trick — terminates quickly).
//! * Codes are *canonical*: only the length table is stored in the
//!   stream header, codes are reconstructed on both sides.
//! * Decoding is table-driven: one [`LOOKUP_BITS`]-wide table resolves
//!   most symbols in a single probe; longer codes fall back to the
//!   per-length canonical walk.

use super::bitio::{BitReader, BitWriter, OutOfBits};

pub const MAX_BITS: u32 = 15;
const LOOKUP_BITS: u32 = 10;

#[derive(Debug, Clone, PartialEq, Eq)]
pub enum HuffError {
    Truncated,
    BadHeader,
    BadCode,
}

impl std::fmt::Display for HuffError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{:?}", self)
    }
}

impl std::error::Error for HuffError {}

impl From<OutOfBits> for HuffError {
    fn from(_: OutOfBits) -> Self {
        HuffError::Truncated
    }
}

/// Compute canonical code lengths for `freqs` (0 freq → no code).
pub fn code_lengths(freqs: &[u64]) -> Vec<u8> {
    let mut freqs: Vec<u64> = freqs.to_vec();
    loop {
        let lengths = tree_lengths(&freqs);
        let max = lengths.iter().copied().max().unwrap_or(0);
        if (max as u32) <= MAX_BITS {
            return lengths;
        }
        // Damp and retry: flattens the distribution, shortening the tree.
        for f in freqs.iter_mut() {
            if *f > 0 {
                *f = *f / 2 + 1;
            }
        }
    }
}

fn tree_lengths(freqs: &[u64]) -> Vec<u8> {
    let n = freqs.len();
    let mut lengths = vec![0u8; n];
    let active: Vec<usize> = (0..n).filter(|&i| freqs[i] > 0).collect();
    match active.len() {
        0 => return lengths,
        1 => {
            lengths[active[0]] = 1;
            return lengths;
        }
        _ => {}
    }
    // Nodes: leaves 0..n, internal nodes appended. parent[] tracks the merge tree.
    let mut heap = std::collections::BinaryHeap::new();
    let mut parent: Vec<usize> = vec![usize::MAX; n];
    for &i in &active {
        heap.push(std::cmp::Reverse((freqs[i], i)));
    }
    let mut node_freq: Vec<u64> = freqs.to_vec();
    while heap.len() > 1 {
        let std::cmp::Reverse((fa, a)) = heap.pop().unwrap();
        let std::cmp::Reverse((fb, b)) = heap.pop().unwrap();
        let id = node_freq.len();
        node_freq.push(fa + fb);
        parent.push(usize::MAX);
        parent[a] = id;
        parent[b] = id;
        heap.push(std::cmp::Reverse((fa + fb, id)));
    }
    for &i in &active {
        let mut d = 0u8;
        let mut cur = i;
        while parent[cur] != usize::MAX {
            cur = parent[cur];
            d += 1;
        }
        lengths[i] = d;
    }
    lengths
}

/// Canonical code assignment: shorter codes first, ties by symbol index.
pub fn canonical_codes(lengths: &[u8]) -> Vec<u32> {
    let mut bl_count = [0u32; (MAX_BITS + 1) as usize];
    for &l in lengths {
        if l > 0 {
            bl_count[l as usize] += 1;
        }
    }
    let mut next_code = [0u32; (MAX_BITS + 2) as usize];
    let mut code = 0u32;
    for bits in 1..=MAX_BITS as usize {
        code = (code + bl_count[bits - 1]) << 1;
        next_code[bits] = code;
    }
    lengths
        .iter()
        .map(|&l| {
            if l == 0 {
                0
            } else {
                let c = next_code[l as usize];
                next_code[l as usize] += 1;
                c
            }
        })
        .collect()
}

/// Encoder: symbol → (code, length), written MSB-first within the code so
/// canonical ordering is preserved on the LSB-first bit stream.
///
/// Perf note (§Perf log): codes are bit-reversed once at construction —
/// doing `reverse_bits` per encoded symbol cost ~25% of encode time.
#[derive(Debug, Clone)]
pub struct Encoder {
    /// Pre-reversed codes, ready for the LSB-first writer.
    rev_codes: Vec<u32>,
    lengths: Vec<u8>,
}

impl Encoder {
    pub fn from_freqs(freqs: &[u64]) -> Self {
        let lengths = code_lengths(freqs);
        Self::from_lengths(lengths)
    }

    pub fn from_lengths(lengths: Vec<u8>) -> Self {
        let codes = canonical_codes(&lengths);
        let rev_codes = codes
            .iter()
            .zip(&lengths)
            .map(|(&c, &l)| if l == 0 { 0 } else { c.reverse_bits() >> (32 - l as u32) })
            .collect();
        Self { rev_codes, lengths }
    }

    pub fn lengths(&self) -> &[u8] {
        &self.lengths
    }

    #[inline]
    pub fn encode(&self, w: &mut BitWriter, sym: usize) {
        let len = self.lengths[sym] as u32;
        debug_assert!(len > 0, "encoding symbol {sym} with no code");
        w.write(self.rev_codes[sym] as u64, len);
    }

    /// Encoded size in bits of `sym` (for size prediction without coding).
    #[inline]
    pub fn cost_bits(&self, sym: usize) -> u32 {
        self.lengths[sym] as u32
    }
}

/// Table-driven decoder built from canonical lengths.
#[derive(Debug, Clone)]
pub struct Decoder {
    /// Fast path: LOOKUP_BITS-indexed (symbol, length); length 0 = miss.
    lookup: Vec<(u16, u8)>,
    /// Slow path: canonical per-length first-code/offset walk.
    count: [u32; (MAX_BITS + 1) as usize],
    first_code: [u32; (MAX_BITS + 1) as usize],
    first_index: [u32; (MAX_BITS + 1) as usize],
    symbols: Vec<u16>, // ordered by (length, symbol)
}

impl Decoder {
    pub fn from_lengths(lengths: &[u8]) -> Result<Self, HuffError> {
        if lengths.len() > u16::MAX as usize {
            return Err(HuffError::BadHeader);
        }
        let mut count = [0u32; (MAX_BITS + 1) as usize];
        for &l in lengths {
            if l as u32 > MAX_BITS {
                return Err(HuffError::BadHeader);
            }
            if l > 0 {
                count[l as usize] += 1;
            }
        }
        let mut symbols: Vec<u16> = Vec::new();
        for bits in 1..=MAX_BITS as usize {
            for (s, &l) in lengths.iter().enumerate() {
                if l as usize == bits {
                    symbols.push(s as u16);
                }
            }
        }
        let mut first_code = [0u32; (MAX_BITS + 1) as usize];
        let mut first_index = [0u32; (MAX_BITS + 1) as usize];
        let mut code = 0u32;
        let mut index = 0u32;
        for bits in 1..=MAX_BITS as usize {
            code = (code + count[bits - 1]) << 1;
            first_code[bits] = code;
            first_index[bits] = index;
            index += count[bits];
        }

        // Build the fast lookup table.
        let codes = canonical_codes(lengths);
        let mut lookup = vec![(0u16, 0u8); 1 << LOOKUP_BITS];
        for (s, &l) in lengths.iter().enumerate() {
            let l32 = l as u32;
            if l == 0 || l32 > LOOKUP_BITS {
                continue;
            }
            let rev = codes[s].reverse_bits() >> (32 - l32);
            let step = 1u32 << l32;
            let mut idx = rev;
            while idx < (1 << LOOKUP_BITS) {
                lookup[idx as usize] = (s as u16, l);
                idx += step;
            }
        }
        Ok(Self { lookup, count, first_code, first_index, symbols })
    }

    #[inline]
    pub fn decode(&self, r: &mut BitReader<'_>) -> Result<u16, HuffError> {
        let peeked = r.peek(LOOKUP_BITS) as usize;
        let (sym, len) = self.lookup[peeked];
        if len > 0 {
            r.consume(len as u32)?;
            return Ok(sym);
        }
        // Slow path: read bit by bit, walking the canonical ranges MSB-first.
        let mut code = 0u32;
        for bits in 1..=MAX_BITS as usize {
            code = (code << 1) | r.read(1)? as u32;
            if self.count[bits] > 0 {
                let offset = code.wrapping_sub(self.first_code[bits]);
                if offset < self.count[bits] {
                    return Ok(self.symbols[(self.first_index[bits] + offset) as usize]);
                }
            }
        }
        Err(HuffError::BadCode)
    }
}

/// One-shot convenience: encode `symbols` over alphabet size `alphabet`.
/// Stream layout: [alphabet: u16][lengths: alphabet × u4 packed][count: u32][payload].
pub fn encode_block(symbols: &[u16], alphabet: usize) -> Vec<u8> {
    let mut freqs = vec![0u64; alphabet];
    for &s in symbols {
        freqs[s as usize] += 1;
    }
    let enc = Encoder::from_freqs(&freqs);
    encode_block_with(&enc, symbols, alphabet)
}

/// [`encode_block`] with a prebuilt encoder (lets the caller reuse the
/// histogram it already computed for mode selection — see
/// `compression::feature::encode`).
pub fn encode_block_with(enc: &Encoder, symbols: &[u16], alphabet: usize) -> Vec<u8> {
    let mut w = BitWriter::new();
    w.write(alphabet as u64, 16);
    for &l in enc.lengths() {
        w.write(l as u64, 4); // MAX_BITS=15 fits in 4 bits
    }
    w.write(symbols.len() as u64, 32);
    for &s in symbols {
        enc.encode(&mut w, s as usize);
    }
    w.finish()
}

/// Inverse of [`encode_block`].
pub fn decode_block(bytes: &[u8]) -> Result<Vec<u16>, HuffError> {
    let mut r = BitReader::new(bytes);
    let alphabet = r.read(16)? as usize;
    if alphabet == 0 {
        return Err(HuffError::BadHeader);
    }
    let mut lengths = vec![0u8; alphabet];
    for l in lengths.iter_mut() {
        *l = r.read(4)? as u8;
    }
    let n = r.read(32)? as usize;
    let dec = Decoder::from_lengths(&lengths)?;
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        out.push(dec.decode(&mut r)?);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn kraft_inequality_holds() {
        let freqs: Vec<u64> = (0..256).map(|i| (i * i + 1) as u64).collect();
        let lengths = code_lengths(&freqs);
        let kraft: f64 =
            lengths.iter().filter(|&&l| l > 0).map(|&l| 2f64.powi(-(l as i32))).sum();
        assert!(kraft <= 1.0 + 1e-9, "kraft {kraft}");
        assert!(lengths.iter().all(|&l| (l as u32) <= MAX_BITS));
    }

    #[test]
    fn single_symbol_alphabet() {
        let symbols = vec![3u16; 100];
        let out = encode_block(&symbols, 8);
        assert_eq!(decode_block(&out).unwrap(), symbols);
    }

    #[test]
    fn empty_input() {
        let out = encode_block(&[], 4);
        assert_eq!(decode_block(&out).unwrap(), Vec::<u16>::new());
    }

    #[test]
    fn skewed_distribution_compresses() {
        // 90% zeros (post-ReLU-like): entropy ≈ 0.47 bits + header.
        let mut symbols = vec![0u16; 9000];
        symbols.extend(std::iter::repeat(5u16).take(1000));
        let out = encode_block(&symbols, 16);
        assert!(out.len() < 10_000 / 8 * 6, "len {}", out.len());
        assert_eq!(decode_block(&out).unwrap(), symbols);
    }

    #[test]
    fn decoder_rejects_bad_lengths() {
        assert!(Decoder::from_lengths(&[16, 1]).is_err());
    }

    #[test]
    fn truncated_stream_errors() {
        let symbols: Vec<u16> = (0..100).map(|i| (i % 7) as u16).collect();
        let out = encode_block(&symbols, 8);
        let cut = &out[..out.len() - 2];
        assert!(decode_block(cut).is_err());
    }

    #[test]
    fn prop_roundtrip() {
        prop::check(
            "huffman block roundtrip",
            prop::vec_of(prop::u64_in(0, 255).map(|x| x as u16), 0, 3000),
            |symbols| {
                let out = encode_block(symbols, 256);
                decode_block(&out).as_deref() == Ok(symbols.as_slice())
            },
        );
    }

    #[test]
    fn prop_long_codes_roundtrip() {
        // Exponential frequencies force maximum code lengths.
        let mut freqs = vec![0u64; 32];
        let mut f = 1u64;
        for i in 0..32 {
            freqs[i] = f;
            f = f.saturating_mul(3);
        }
        let enc = Encoder::from_freqs(&freqs);
        let dec = Decoder::from_lengths(enc.lengths()).unwrap();
        let mut w = BitWriter::new();
        for s in 0..32 {
            enc.encode(&mut w, s);
        }
        let bytes = w.finish();
        let mut r = BitReader::new(&bytes);
        for s in 0..32u16 {
            assert_eq!(dec.decode(&mut r).unwrap(), s);
        }
    }
}
