//! Canonical Huffman coding — JALAD's entropy coder (paper §III-B:
//! "We introduce Huffman Coding to further compress the quantized
//! integer feature maps").
//!
//! * Code lengths come from a binary heap merge; if the longest code
//!   exceeds [`MAX_BITS`] the frequencies are damped (`f/2+1`) and the
//!   tree rebuilt (zlib's classic trick — terminates quickly).
//! * Codes are *canonical*: only the length table is stored in the
//!   stream header, codes are reconstructed on both sides.
//! * Decoding is table-driven: one [`LOOKUP_BITS`]-wide table resolves
//!   most symbols in a single probe; longer codes fall back to the
//!   per-length canonical walk.
//! * Both [`Encoder`] and [`Decoder`] can be **rebuilt in place**
//!   ([`Encoder::rebuild_from_freqs`], [`Decoder::rebuild`]) so the
//!   serving hot path re-derives per-frame code tables without heap
//!   allocations once its scratch buffers are warm.

use super::bitio::{BitReader, BitWriter, OutOfBits};

pub const MAX_BITS: u32 = 15;
const LOOKUP_BITS: u32 = 10;

#[derive(Debug, Clone, PartialEq, Eq)]
pub enum HuffError {
    Truncated,
    BadHeader,
    BadCode,
}

impl std::fmt::Display for HuffError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{:?}", self)
    }
}

impl std::error::Error for HuffError {}

impl From<OutOfBits> for HuffError {
    fn from(_: OutOfBits) -> Self {
        HuffError::Truncated
    }
}

/// Reusable workspace for the length computation: the damped frequency
/// copy, the merge-tree parent links, node frequencies and the heap's
/// backing vector are all retained between builds.
#[derive(Debug, Default)]
pub struct EncoderScratch {
    damped: Vec<u64>,
    parent: Vec<u32>,
    node_freq: Vec<u64>,
    heap: Vec<std::cmp::Reverse<(u64, u32)>>,
}

/// Compute canonical code lengths for `freqs` into `lengths`
/// (0 freq → no code), reusing `ws` allocations.
pub fn code_lengths_into(freqs: &[u64], ws: &mut EncoderScratch, lengths: &mut Vec<u8>) {
    let EncoderScratch { damped, parent, node_freq, heap } = ws;
    damped.clear();
    damped.extend_from_slice(freqs);
    loop {
        tree_lengths_into(damped, parent, node_freq, heap, lengths);
        let max = lengths.iter().copied().max().unwrap_or(0);
        if (max as u32) <= MAX_BITS {
            return;
        }
        // Damp and retry: flattens the distribution, shortening the tree.
        for f in damped.iter_mut() {
            if *f > 0 {
                *f = *f / 2 + 1;
            }
        }
    }
}

/// Compute canonical code lengths for `freqs` (0 freq → no code).
pub fn code_lengths(freqs: &[u64]) -> Vec<u8> {
    let mut ws = EncoderScratch::default();
    let mut lengths = Vec::new();
    code_lengths_into(freqs, &mut ws, &mut lengths);
    lengths
}

fn tree_lengths_into(
    freqs: &[u64],
    parent: &mut Vec<u32>,
    node_freq: &mut Vec<u64>,
    heap_vec: &mut Vec<std::cmp::Reverse<(u64, u32)>>,
    lengths: &mut Vec<u8>,
) {
    let n = freqs.len();
    lengths.clear();
    lengths.resize(n, 0);
    heap_vec.clear();
    for (i, &f) in freqs.iter().enumerate() {
        if f > 0 {
            heap_vec.push(std::cmp::Reverse((f, i as u32)));
        }
    }
    match heap_vec.len() {
        0 => return,
        1 => {
            let std::cmp::Reverse((_, i)) = heap_vec[0];
            lengths[i as usize] = 1;
            return;
        }
        _ => {}
    }
    // Nodes: leaves 0..n, internal nodes appended. parent[] tracks the
    // merge tree. BinaryHeap::from / into_vec reuse the same backing
    // allocation, and merging pops two for every push, so the heap never
    // grows past its initial size.
    parent.clear();
    parent.resize(n, u32::MAX);
    node_freq.clear();
    node_freq.extend_from_slice(freqs);
    let mut heap = std::collections::BinaryHeap::from(std::mem::take(heap_vec));
    while heap.len() > 1 {
        let std::cmp::Reverse((fa, a)) = heap.pop().unwrap();
        let std::cmp::Reverse((fb, b)) = heap.pop().unwrap();
        let id = node_freq.len() as u32;
        node_freq.push(fa + fb);
        parent.push(u32::MAX);
        parent[a as usize] = id;
        parent[b as usize] = id;
        heap.push(std::cmp::Reverse((fa + fb, id)));
    }
    *heap_vec = heap.into_vec();
    for (i, &f) in freqs.iter().enumerate() {
        if f == 0 {
            continue;
        }
        let mut d = 0u8;
        let mut cur = i as u32;
        while parent[cur as usize] != u32::MAX {
            cur = parent[cur as usize];
            d += 1;
        }
        lengths[i] = d;
    }
}

/// Canonical code assignment: shorter codes first, ties by symbol index.
pub fn canonical_codes(lengths: &[u8]) -> Vec<u32> {
    let mut next_code = next_code_table(lengths);
    lengths
        .iter()
        .map(|&l| {
            if l == 0 {
                0
            } else {
                let c = next_code[l as usize];
                next_code[l as usize] += 1;
                c
            }
        })
        .collect()
}

/// First canonical code per length, from a length table.
fn next_code_table(lengths: &[u8]) -> [u32; (MAX_BITS + 2) as usize] {
    let mut bl_count = [0u32; (MAX_BITS + 1) as usize];
    for &l in lengths {
        if l > 0 {
            bl_count[l as usize] += 1;
        }
    }
    let mut next_code = [0u32; (MAX_BITS + 2) as usize];
    let mut code = 0u32;
    for bits in 1..=MAX_BITS as usize {
        code = (code + bl_count[bits - 1]) << 1;
        next_code[bits] = code;
    }
    next_code
}

/// Encoder: symbol → (code, length), written MSB-first within the code so
/// canonical ordering is preserved on the LSB-first bit stream.
///
/// Perf note (§Perf log): codes are bit-reversed once at construction —
/// doing `reverse_bits` per encoded symbol cost ~25% of encode time.
#[derive(Debug, Clone)]
pub struct Encoder {
    /// Pre-reversed codes, ready for the LSB-first writer.
    rev_codes: Vec<u32>,
    lengths: Vec<u8>,
}

impl Encoder {
    /// An empty encoder to be filled by [`Encoder::rebuild_from_freqs`].
    pub fn new_empty() -> Self {
        Self { rev_codes: Vec::new(), lengths: Vec::new() }
    }

    pub fn from_freqs(freqs: &[u64]) -> Self {
        let mut enc = Self::new_empty();
        let mut ws = EncoderScratch::default();
        enc.rebuild_from_freqs(freqs, &mut ws);
        enc
    }

    pub fn from_lengths(lengths: Vec<u8>) -> Self {
        let mut enc = Self { rev_codes: Vec::new(), lengths };
        enc.rebuild_codes();
        enc
    }

    /// Rebuild in place from a fresh histogram, reusing all allocations.
    pub fn rebuild_from_freqs(&mut self, freqs: &[u64], ws: &mut EncoderScratch) {
        code_lengths_into(freqs, ws, &mut self.lengths);
        self.rebuild_codes();
    }

    fn rebuild_codes(&mut self) {
        let mut next_code = next_code_table(&self.lengths);
        let lengths = &self.lengths;
        let rev_codes = &mut self.rev_codes;
        rev_codes.clear();
        for &l in lengths {
            if l == 0 {
                rev_codes.push(0);
            } else {
                let c = next_code[l as usize];
                next_code[l as usize] += 1;
                rev_codes.push(c.reverse_bits() >> (32 - l as u32));
            }
        }
    }

    pub fn lengths(&self) -> &[u8] {
        &self.lengths
    }

    #[inline]
    pub fn encode(&self, w: &mut BitWriter<'_>, sym: usize) {
        let len = self.lengths[sym] as u32;
        debug_assert!(len > 0, "encoding symbol {sym} with no code");
        w.write(self.rev_codes[sym] as u64, len);
    }

    /// Encoded size in bits of `sym` (for size prediction without coding).
    #[inline]
    pub fn cost_bits(&self, sym: usize) -> u32 {
        self.lengths[sym] as u32
    }
}

/// Table-driven decoder built from canonical lengths.
#[derive(Debug, Clone)]
pub struct Decoder {
    /// Fast path: LOOKUP_BITS-indexed (symbol, length); length 0 = miss.
    lookup: Vec<(u16, u8)>,
    /// Slow path: canonical per-length first-code/offset walk.
    count: [u32; (MAX_BITS + 1) as usize],
    first_code: [u32; (MAX_BITS + 1) as usize],
    first_index: [u32; (MAX_BITS + 1) as usize],
    symbols: Vec<u16>, // ordered by (length, symbol)
}

impl Decoder {
    /// An empty decoder to be filled by [`Decoder::rebuild`].
    pub fn new_empty() -> Self {
        Self {
            lookup: Vec::new(),
            count: [0; (MAX_BITS + 1) as usize],
            first_code: [0; (MAX_BITS + 1) as usize],
            first_index: [0; (MAX_BITS + 1) as usize],
            symbols: Vec::new(),
        }
    }

    pub fn from_lengths(lengths: &[u8]) -> Result<Self, HuffError> {
        let mut dec = Self::new_empty();
        dec.rebuild(lengths)?;
        Ok(dec)
    }

    /// Rebuild in place from canonical lengths, reusing allocations.
    pub fn rebuild(&mut self, lengths: &[u8]) -> Result<(), HuffError> {
        if lengths.len() > u16::MAX as usize {
            return Err(HuffError::BadHeader);
        }
        self.count = [0; (MAX_BITS + 1) as usize];
        for &l in lengths {
            if l as u32 > MAX_BITS {
                return Err(HuffError::BadHeader);
            }
            if l > 0 {
                self.count[l as usize] += 1;
            }
        }
        self.symbols.clear();
        for bits in 1..=MAX_BITS as usize {
            for (s, &l) in lengths.iter().enumerate() {
                if l as usize == bits {
                    self.symbols.push(s as u16);
                }
            }
        }
        self.first_code = [0; (MAX_BITS + 1) as usize];
        self.first_index = [0; (MAX_BITS + 1) as usize];
        let mut code = 0u32;
        let mut index = 0u32;
        for bits in 1..=MAX_BITS as usize {
            code = (code + self.count[bits - 1]) << 1;
            self.first_code[bits] = code;
            self.first_index[bits] = index;
            index += self.count[bits];
        }

        // Build the fast lookup table. Codes are assigned in canonical
        // order (every non-zero length consumes one), matching
        // `canonical_codes` without materializing the code vector.
        self.lookup.clear();
        self.lookup.resize(1 << LOOKUP_BITS, (0u16, 0u8));
        let mut next_code = next_code_table(lengths);
        for (s, &l) in lengths.iter().enumerate() {
            if l == 0 {
                continue;
            }
            let l32 = l as u32;
            let c = next_code[l as usize];
            next_code[l as usize] += 1;
            if l32 > LOOKUP_BITS {
                continue;
            }
            let rev = c.reverse_bits() >> (32 - l32);
            let step = 1u32 << l32;
            let mut idx = rev;
            while idx < (1 << LOOKUP_BITS) {
                self.lookup[idx as usize] = (s as u16, l);
                idx += step;
            }
        }
        Ok(())
    }

    #[inline]
    pub fn decode(&self, r: &mut BitReader<'_>) -> Result<u16, HuffError> {
        let peeked = r.peek(LOOKUP_BITS) as usize;
        let (sym, len) = self.lookup[peeked];
        if len > 0 {
            r.consume(len as u32)?;
            return Ok(sym);
        }
        // Slow path: read bit by bit, walking the canonical ranges MSB-first.
        let mut code = 0u32;
        for bits in 1..=MAX_BITS as usize {
            code = (code << 1) | r.read(1)? as u32;
            if self.count[bits] > 0 {
                let offset = code.wrapping_sub(self.first_code[bits]);
                if offset < self.count[bits] {
                    return Ok(self.symbols[(self.first_index[bits] + offset) as usize]);
                }
            }
        }
        Err(HuffError::BadCode)
    }
}

/// Reusable decode-side state: the header length table plus the
/// table-driven decoder it rebuilds. One per session/connection.
#[derive(Debug)]
pub struct DecodeScratch {
    lengths: Vec<u8>,
    decoder: Decoder,
}

impl Default for DecodeScratch {
    fn default() -> Self {
        Self { lengths: Vec::new(), decoder: Decoder::new_empty() }
    }
}

impl DecodeScratch {
    pub fn new() -> Self {
        Self::default()
    }
}

/// One-shot convenience: encode `symbols` over alphabet size `alphabet`.
/// Stream layout: [alphabet: u16][lengths: alphabet × u4 packed][count: u32][payload].
pub fn encode_block(symbols: &[u16], alphabet: usize) -> Vec<u8> {
    let mut freqs = vec![0u64; alphabet];
    for &s in symbols {
        freqs[s as usize] += 1;
    }
    let enc = Encoder::from_freqs(&freqs);
    encode_block_with(&enc, symbols, alphabet)
}

/// [`encode_block`] with a prebuilt encoder (lets the caller reuse the
/// histogram it already computed for mode selection — see
/// `compression::feature::encode`).
pub fn encode_block_with(enc: &Encoder, symbols: &[u16], alphabet: usize) -> Vec<u8> {
    let mut out = Vec::new();
    encode_block_with_into(enc, symbols, alphabet, &mut out);
    out
}

/// Streaming form of [`encode_block_with`]: appends the block to `out`
/// (no intermediate allocation — the request hot path's entropy hop).
pub fn encode_block_with_into(enc: &Encoder, symbols: &[u16], alphabet: usize, out: &mut Vec<u8>) {
    let mut w = BitWriter::over(out);
    w.write(alphabet as u64, 16);
    for &l in enc.lengths() {
        w.write(l as u64, 4); // MAX_BITS=15 fits in 4 bits
    }
    w.write(symbols.len() as u64, 32);
    for &s in symbols {
        enc.encode(&mut w, s as usize);
    }
    w.finish();
}

/// Inverse of [`encode_block`].
pub fn decode_block(bytes: &[u8]) -> Result<Vec<u16>, HuffError> {
    let mut ws = DecodeScratch::default();
    let mut out = Vec::new();
    decode_block_into(bytes, &mut ws, &mut out)?;
    Ok(out)
}

/// Streaming form of [`decode_block`]: decodes into `out`, reusing its
/// capacity and the scratch's decoder tables.
pub fn decode_block_into(
    bytes: &[u8],
    ws: &mut DecodeScratch,
    out: &mut Vec<u16>,
) -> Result<(), HuffError> {
    let mut r = BitReader::new(bytes);
    let alphabet = r.read(16)? as usize;
    if alphabet == 0 {
        return Err(HuffError::BadHeader);
    }
    ws.lengths.clear();
    ws.lengths.resize(alphabet, 0);
    for l in ws.lengths.iter_mut() {
        *l = r.read(4)? as u8;
    }
    let n = r.read(32)? as usize;
    // Every symbol costs ≥ 1 bit: reject counts the payload cannot hold
    // before reserving memory for them (untrusted header hardening).
    if n > r.remaining_bits() {
        return Err(HuffError::Truncated);
    }
    ws.decoder.rebuild(&ws.lengths)?;
    out.clear();
    out.reserve(n);
    for _ in 0..n {
        out.push(ws.decoder.decode(&mut r)?);
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn kraft_inequality_holds() {
        let freqs: Vec<u64> = (0..256).map(|i| (i * i + 1) as u64).collect();
        let lengths = code_lengths(&freqs);
        let kraft: f64 =
            lengths.iter().filter(|&&l| l > 0).map(|&l| 2f64.powi(-(l as i32))).sum();
        assert!(kraft <= 1.0 + 1e-9, "kraft {kraft}");
        assert!(lengths.iter().all(|&l| (l as u32) <= MAX_BITS));
    }

    #[test]
    fn single_symbol_alphabet() {
        let symbols = vec![3u16; 100];
        let out = encode_block(&symbols, 8);
        assert_eq!(decode_block(&out).unwrap(), symbols);
    }

    #[test]
    fn empty_input() {
        let out = encode_block(&[], 4);
        assert_eq!(decode_block(&out).unwrap(), Vec::<u16>::new());
    }

    #[test]
    fn skewed_distribution_compresses() {
        // 90% zeros (post-ReLU-like): entropy ≈ 0.47 bits + header.
        let mut symbols = vec![0u16; 9000];
        symbols.extend(std::iter::repeat(5u16).take(1000));
        let out = encode_block(&symbols, 16);
        assert!(out.len() < 10_000 / 8 * 6, "len {}", out.len());
        assert_eq!(decode_block(&out).unwrap(), symbols);
    }

    #[test]
    fn decoder_rejects_bad_lengths() {
        assert!(Decoder::from_lengths(&[16, 1]).is_err());
    }

    #[test]
    fn truncated_stream_errors() {
        let symbols: Vec<u16> = (0..100).map(|i| (i % 7) as u16).collect();
        let out = encode_block(&symbols, 8);
        let cut = &out[..out.len() - 2];
        assert!(decode_block(cut).is_err());
    }

    #[test]
    fn rebuilt_encoder_matches_fresh() {
        // Rebuild over several different histograms; each must match a
        // from-scratch construction exactly (codes and lengths).
        let mut enc = Encoder::new_empty();
        let mut ws = EncoderScratch::default();
        for seed in 1u64..6 {
            let freqs: Vec<u64> = (0..64).map(|i| (i as u64 * seed * 2654435761) % 97).collect();
            enc.rebuild_from_freqs(&freqs, &mut ws);
            let fresh = Encoder::from_freqs(&freqs);
            assert_eq!(enc.lengths(), fresh.lengths(), "seed {seed}");
            assert_eq!(enc.rev_codes, fresh.rev_codes, "seed {seed}");
        }
    }

    #[test]
    fn rebuilt_decoder_matches_fresh() {
        let mut dec = Decoder::new_empty();
        for seed in 1u64..6 {
            let freqs: Vec<u64> = (0..64).map(|i| (i as u64 * seed * 40503) % 31).collect();
            let lengths = code_lengths(&freqs);
            dec.rebuild(&lengths).unwrap();
            let fresh = Decoder::from_lengths(&lengths).unwrap();
            assert_eq!(dec.lookup, fresh.lookup, "seed {seed}");
            assert_eq!(dec.symbols, fresh.symbols, "seed {seed}");
            assert_eq!(dec.count, fresh.count, "seed {seed}");
        }
    }

    #[test]
    fn prop_into_matches_allocating() {
        prop::check(
            "encode_block_with_into ≡ encode_block_with",
            prop::vec_of(prop::u64_in(0, 255).map(|x| x as u16), 0, 2000),
            |symbols| {
                let mut freqs = vec![0u64; 256];
                for &s in symbols {
                    freqs[s as usize] += 1;
                }
                let enc = Encoder::from_freqs(&freqs);
                let legacy = encode_block_with(&enc, symbols, 256);
                let mut streamed = Vec::new();
                encode_block_with_into(&enc, symbols, 256, &mut streamed);
                let mut ws = DecodeScratch::default();
                let mut decoded = Vec::new();
                decode_block_into(&legacy, &mut ws, &mut decoded).unwrap();
                streamed == legacy && &decoded == symbols
            },
        );
    }

    #[test]
    fn prop_roundtrip() {
        prop::check(
            "huffman block roundtrip",
            prop::vec_of(prop::u64_in(0, 255).map(|x| x as u16), 0, 3000),
            |symbols| {
                let out = encode_block(symbols, 256);
                decode_block(&out).as_deref() == Ok(symbols.as_slice())
            },
        );
    }

    #[test]
    fn prop_long_codes_roundtrip() {
        // Exponential frequencies force maximum code lengths.
        let mut freqs = vec![0u64; 32];
        let mut f = 1u64;
        for i in 0..32 {
            freqs[i] = f;
            f = f.saturating_mul(3);
        }
        let enc = Encoder::from_freqs(&freqs);
        let dec = Decoder::from_lengths(enc.lengths()).unwrap();
        let mut bytes = Vec::new();
        let mut w = BitWriter::over(&mut bytes);
        for s in 0..32 {
            enc.encode(&mut w, s);
        }
        w.finish();
        let mut r = BitReader::new(&bytes);
        for s in 0..32u16 {
            assert_eq!(dec.decode(&mut r).unwrap(), s);
        }
    }
}
