//! LSB-first bit-level reader/writer over byte buffers.
//!
//! Shared by the Huffman coder, the c-bit packer in the feature codec and
//! the deflate-like container. LSB-first (like DEFLATE): the first bit
//! written lands in bit 0 of byte 0.
//!
//! The writer appends to a *borrowed* `Vec<u8>` so callers on the request
//! hot path can reuse one buffer across requests (see `util::pool`); the
//! bytes already in the buffer are preserved, which lets codecs lay down
//! a fixed header first and stream the payload straight after it.

#[derive(Debug)]
pub struct BitWriter<'a> {
    buf: &'a mut Vec<u8>,
    acc: u64,
    nbits: u32,
}

impl<'a> BitWriter<'a> {
    /// Start a bit stream that appends to `buf` (existing contents are
    /// kept untouched ahead of the stream).
    pub fn over(buf: &'a mut Vec<u8>) -> Self {
        Self { buf, acc: 0, nbits: 0 }
    }

    /// Write the low `n` bits of `value` (n ≤ 57).
    #[inline]
    pub fn write(&mut self, value: u64, n: u32) {
        debug_assert!(n <= 57);
        debug_assert!(n == 64 || value < (1u64 << n.max(1)) || n == 0);
        self.acc |= value << self.nbits;
        self.nbits += n;
        while self.nbits >= 8 {
            self.buf.push((self.acc & 0xff) as u8);
            self.acc >>= 8;
            self.nbits -= 8;
        }
    }

    /// Bits in the backing buffer plus any pending partial byte.
    pub fn bit_len(&self) -> usize {
        self.buf.len() * 8 + self.nbits as usize
    }

    /// Flush the partial byte (zero-padded). The stream's bytes are in
    /// the backing buffer; returns its total length in bytes.
    pub fn finish(self) -> usize {
        if self.nbits > 0 {
            self.buf.push((self.acc & 0xff) as u8);
        }
        self.buf.len()
    }
}

#[derive(Debug)]
pub struct BitReader<'a> {
    buf: &'a [u8],
    byte: usize,
    acc: u64,
    nbits: u32,
}

#[derive(Debug, PartialEq, Eq)]
pub struct OutOfBits;

impl std::fmt::Display for OutOfBits {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "bit stream exhausted")
    }
}
impl std::error::Error for OutOfBits {}

impl<'a> BitReader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        Self { buf, byte: 0, acc: 0, nbits: 0 }
    }

    #[inline]
    fn refill(&mut self) {
        while self.nbits <= 56 && self.byte < self.buf.len() {
            self.acc |= (self.buf[self.byte] as u64) << self.nbits;
            self.byte += 1;
            self.nbits += 8;
        }
    }

    /// Read `n` bits (n ≤ 57). Bits beyond the buffer are an error.
    #[inline]
    pub fn read(&mut self, n: u32) -> Result<u64, OutOfBits> {
        debug_assert!(n <= 57);
        if n == 0 {
            return Ok(0);
        }
        self.refill();
        if self.nbits < n {
            return Err(OutOfBits);
        }
        let v = self.acc & ((1u64 << n) - 1);
        self.acc >>= n;
        self.nbits -= n;
        Ok(v)
    }

    /// Peek up to `n` bits without consuming (short reads near the end
    /// return the available bits zero-padded).
    #[inline]
    pub fn peek(&mut self, n: u32) -> u64 {
        self.refill();
        if n == 0 {
            return 0;
        }
        self.acc & ((1u64 << n) - 1)
    }

    /// Consume `n` bits previously peeked.
    #[inline]
    pub fn consume(&mut self, n: u32) -> Result<(), OutOfBits> {
        if self.nbits < n {
            return Err(OutOfBits);
        }
        self.acc >>= n;
        self.nbits -= n;
        Ok(())
    }

    /// Bits still available.
    pub fn remaining_bits(&self) -> usize {
        (self.buf.len() - self.byte) * 8 + self.nbits as usize
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn roundtrip_simple() {
        let mut bytes = Vec::new();
        let mut w = BitWriter::over(&mut bytes);
        w.write(0b101, 3);
        w.write(0xff, 8);
        w.write(0, 1);
        w.write(0x1234, 16);
        w.finish();
        let mut r = BitReader::new(&bytes);
        assert_eq!(r.read(3).unwrap(), 0b101);
        assert_eq!(r.read(8).unwrap(), 0xff);
        assert_eq!(r.read(1).unwrap(), 0);
        assert_eq!(r.read(16).unwrap(), 0x1234);
    }

    #[test]
    fn lsb_first_layout() {
        let mut bytes = Vec::new();
        let mut w = BitWriter::over(&mut bytes);
        w.write(1, 1); // bit 0 of byte 0
        w.write(0, 6);
        w.write(1, 1); // bit 7 of byte 0
        w.finish();
        assert_eq!(bytes, vec![0b1000_0001]);
    }

    #[test]
    fn preserves_existing_prefix() {
        let mut bytes = vec![0xAA, 0xBB];
        let mut w = BitWriter::over(&mut bytes);
        w.write(0xCC, 8);
        let total = w.finish();
        assert_eq!(total, 3);
        assert_eq!(bytes, vec![0xAA, 0xBB, 0xCC]);
    }

    #[test]
    fn reused_buffer_keeps_capacity() {
        let mut bytes = Vec::new();
        for _ in 0..3 {
            bytes.clear();
            let mut w = BitWriter::over(&mut bytes);
            w.write(0x1F, 5);
            w.write(0x3FF, 10);
            w.finish();
            let mut r = BitReader::new(&bytes);
            assert_eq!(r.read(5).unwrap(), 0x1F);
            assert_eq!(r.read(10).unwrap(), 0x3FF);
        }
    }

    #[test]
    fn out_of_bits() {
        let bytes = [0u8; 1];
        let mut r = BitReader::new(&bytes);
        assert!(r.read(8).is_ok());
        assert_eq!(r.read(1), Err(OutOfBits));
    }

    #[test]
    fn peek_consume() {
        let mut b = Vec::new();
        let mut w = BitWriter::over(&mut b);
        w.write(0b1101, 4);
        w.finish();
        let mut r = BitReader::new(&b);
        assert_eq!(r.peek(4) & 0xf, 0b1101);
        r.consume(2).unwrap();
        assert_eq!(r.read(2).unwrap(), 0b11);
    }

    #[test]
    fn prop_roundtrip_random_widths() {
        prop::check(
            "bitio roundtrip",
            prop::vec_of(
                prop::pair(prop::u64_in(0, u32::MAX as u64), prop::u64_in(1, 32)),
                1,
                200,
            ),
            |items| {
                let mut bytes = Vec::new();
                let mut w = BitWriter::over(&mut bytes);
                for (v, n) in items {
                    w.write(v & ((1u64 << n) - 1), *n as u32);
                }
                w.finish();
                let mut r = BitReader::new(&bytes);
                items.iter().all(|(v, n)| r.read(*n as u32).unwrap() == v & ((1u64 << n) - 1))
            },
        );
    }
}
