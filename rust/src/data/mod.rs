//! Synthetic ILSVRC substitute (rust side).
//!
//! Mirrors `python/compile/data.py` exactly — same xorshift64* streams,
//! same prototype construction — so the calibration/test images the
//! runtime mints come from the same distribution the models were trained
//! on at build time (DESIGN.md substitution table).

pub mod gen;

pub use gen::{
    batch, from_rgb8, prototype, sample, sample_image, sample_image_shaped, to_rgb8, Sample,
    HW, NUM_CLASSES, SIGMA,
};
