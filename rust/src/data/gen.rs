//! Procedural dataset generator — bit-compatible with
//! `python/compile/data.py` (same PRNG, same seed layout, same bilinear
//! upsample), up to float rounding in libm (`ln`, `cos`): distributional
//! parity is the contract, and in practice values agree to ~1e-7.
//!
//! `K` classes; class prototype = 8×8×3 Gaussian grid bilinearly
//! upsampled to 32×32; sample = prototype + σ·noise. σ puts samples near
//! the class boundaries so quantization produces the paper's
//! accuracy/bit-width trade-off (see the python twin's rationale).

use crate::runtime::tensor::Tensor;
use crate::util::rng::XorShift64Star;

pub const NUM_CLASSES: usize = 16;
pub const HW: usize = 32;
pub const PROTO_RES: usize = 8;
/// Noise is smooth (drawn on NOISE_RES and upsampled) so the 8-bit
/// images stay PNG-compressible — see the python twin's rationale.
pub const NOISE_RES: usize = 8;
pub const SIGMA: f32 = 1.2;
pub const PROTO_SEED: u64 = 0x9E3779B97F4A7C15;
pub const SAMPLE_SEED: u64 = 0xD1B54A32D192ED03;

#[derive(Debug, Clone)]
pub struct Sample {
    /// (1, hw, hw, 3) model-space image.
    pub image: Tensor,
    pub label: usize,
}

/// Bilinear upsample (r, r, c) → (hw, hw, c), align_corners=False.
fn bilinear_upsample(grid: &[f32], r: usize, ch: usize, hw: usize) -> Vec<f32> {
    let scale = r as f64 / hw as f64;
    // Precompute per-axis lo index and fraction.
    let mut lo0 = vec![0usize; hw];
    let mut lo1 = vec![0usize; hw];
    let mut frac = vec![0f32; hw];
    for (i, ((l0, l1), fr)) in lo0.iter_mut().zip(&mut lo1).zip(&mut frac).enumerate() {
        let coord = (i as f64 + 0.5) * scale - 0.5;
        let fl = coord.floor();
        *fr = (coord - fl) as f32;
        let fl = fl as isize;
        *l0 = fl.clamp(0, r as isize - 1) as usize;
        *l1 = (fl + 1).clamp(0, r as isize - 1) as usize;
    }
    let mut out = vec![0f32; hw * hw * ch];
    for y in 0..hw {
        for x in 0..hw {
            for c in 0..ch {
                let g = |yy: usize, xx: usize| grid[(yy * r + xx) * ch + c];
                let top = g(lo0[y], lo0[x]) * (1.0 - frac[x]) + g(lo0[y], lo1[x]) * frac[x];
                let bot = g(lo1[y], lo0[x]) * (1.0 - frac[x]) + g(lo1[y], lo1[x]) * frac[x];
                out[(y * hw + x) * ch + c] = top * (1.0 - frac[y]) + bot * frac[y];
            }
        }
    }
    out
}

/// Class prototype field (hw, hw, 3).
pub fn prototype(class_id: usize, hw: usize) -> Vec<f32> {
    let mut rng =
        XorShift64Star::new(PROTO_SEED ^ (class_id as u64).wrapping_mul(0xA0761D6478BD642F));
    let grid = rng.fill_gaussian(PROTO_RES * PROTO_RES * 3);
    bilinear_upsample(&grid, PROTO_RES, 3, hw)
}

/// One labelled sample; returns (pixels hw·hw·3, label).
pub fn sample(class_id: usize, sample_id: usize, sigma: f32, hw: usize) -> (Vec<f32>, usize) {
    let mut rng = XorShift64Star::new(
        SAMPLE_SEED
            ^ (class_id as u64).wrapping_mul(0xE7037ED1A0B428DB)
            ^ (sample_id as u64).wrapping_mul(0x8EBC6AF09C88C6E3),
    );
    let grid = rng.fill_gaussian(NOISE_RES * NOISE_RES * 3);
    let noise = bilinear_upsample(&grid, NOISE_RES, 3, hw);
    // Normalize to unit RMS — exactly as the python twin does.
    let rms = (noise.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>()
        / noise.len() as f64)
        .sqrt()
        .max(1e-6) as f32;
    let mut img = prototype(class_id, hw);
    for (p, n) in img.iter_mut().zip(&noise) {
        *p += sigma * n / rms;
    }
    (img, class_id)
}

/// Deterministic id → sample mapping (same convention as python
/// `data.batch`): class = id % K, per-class sample index = id / K.
pub fn sample_image(id: usize, hw: usize) -> Sample {
    let (img, label) = sample(id % NUM_CLASSES, id / NUM_CLASSES, SIGMA, hw);
    Sample { image: Tensor::new(vec![1, hw, hw, 3], img), label }
}

/// Sample shaped to a model's manifest input (batch dim must be 1).
pub fn sample_image_shaped(class_id: usize, sample_id: usize, shape: &[usize]) -> Tensor {
    assert_eq!(shape.len(), 4);
    assert_eq!(shape[0], 1);
    assert_eq!(shape[3], 3);
    let hw = shape[1];
    let (img, _) = sample(class_id, sample_id, SIGMA, hw);
    Tensor::new(shape.to_vec(), img)
}

/// A batch of deterministic samples by id range.
pub fn batch(ids: impl Iterator<Item = usize>, hw: usize) -> Vec<Sample> {
    ids.map(|id| sample_image(id, hw)).collect()
}

/// Model-space f32 → 8-bit RGB (the file Origin2Cloud uploads).
/// Same affine constants as the python twin.
pub fn to_rgb8(img: &Tensor) -> Vec<u8> {
    img.data().iter().map(|&v| (v * 32.0 + 128.0).clamp(0.0, 255.0) as u8).collect()
}

/// Inverse of [`to_rgb8`] (what the cloud feeds the network).
pub fn from_rgb8(bytes: &[u8], shape: Vec<usize>) -> Tensor {
    let data: Vec<f32> = bytes.iter().map(|&b| (b as f32 - 128.0) / 32.0).collect();
    Tensor::new(shape, data)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_distinct() {
        let a = sample_image(5, HW);
        let b = sample_image(5, HW);
        let c = sample_image(6, HW);
        assert_eq!(a.image, b.image);
        assert_ne!(a.image, c.image);
        assert_eq!(a.label, 5 % NUM_CLASSES);
    }

    #[test]
    fn image_statistics_sane() {
        let s = sample_image(3, HW);
        let d = s.image.data();
        let mean = d.iter().sum::<f32>() / d.len() as f32;
        let var = d.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / d.len() as f32;
        assert!(mean.abs() < 0.6, "mean {mean}");
        // prototype (≲1) + sigma noise (1.44): total var around 1.5-2.5
        assert!(var > 0.8 && var < 4.0, "var {var}");
    }

    #[test]
    fn prototypes_differ_between_classes() {
        let p0 = prototype(0, HW);
        let p1 = prototype(1, HW);
        let dist: f32 =
            p0.iter().zip(&p1).map(|(a, b)| (a - b) * (a - b)).sum::<f32>() / p0.len() as f32;
        assert!(dist > 0.1, "classes too close: {dist}");
    }

    #[test]
    fn rgb8_roundtrip_error_small() {
        let s = sample_image(9, HW);
        let rgb = to_rgb8(&s.image);
        let back = from_rgb8(&rgb, s.image.shape().to_vec());
        // 1/32 per gray level → max error 1/64 + clipping tails.
        let mut big = 0;
        for (a, b) in s.image.data().iter().zip(back.data()) {
            if (a - b).abs() > 1.0 / 32.0 {
                big += 1;
            }
        }
        // Values beyond the ±4.0 representable band clip; with pixel std
        // ≈1.5 that is a sub-percent tail.
        assert!(big * 100 < s.image.len(), "{big} clipped of {}", s.image.len());
    }

    #[test]
    fn batch_labels_cycle() {
        let b = batch(0..32, HW);
        for (i, s) in b.iter().enumerate() {
            assert_eq!(s.label, i % NUM_CLASSES);
        }
    }

    /// Golden cross-language check: first pixels of prototype(0) match
    /// the python generator (values locked in tests/test_data.py).
    #[test]
    fn golden_prototype_values() {
        let p = prototype(0, HW);
        // Locked from python: see python/tests/test_data.py golden test.
        let got: Vec<f32> = p[..4].to_vec();
        let want = golden::PROTO0_FIRST4;
        for (g, w) in got.iter().zip(want) {
            assert!((g - w).abs() < 1e-5, "got {got:?}, want {want:?}");
        }
    }

    mod golden {
        /// Locked from the python twin; regenerate with
        /// `cd python && python -c "from compile.data import prototype;
        ///  print([float(x) for x in prototype(0).ravel()[:4]])"`.
        pub const PROTO0_FIRST4: [f32; 4] =
            [-1.1834038, 2.1171653, -0.91424388, -1.1834038];
    }
}
