//! The shared edge-side request path (sim/real unification).
//!
//! `coordinator::pipeline::LocalPipeline` (simulated channel) and
//! `server::edge::EdgeClient` (real TCP) used to each carry their own
//! copy of the edge half of a request — run head stages, L1-quantize the
//! cut feature map, entropy-code it into a wire frame. Both now drive
//! this `Session`, so the simulated and deployed paths execute literally
//! the same code; only the transport behind [`Session::wire`] differs.
//!
//! A `Session` owns a [`util::pool::Scratch`](crate::util::pool::Scratch):
//! the quantized values, the Huffman tables and the encoded wire frame
//! all live in reusable buffers, making the codec hop allocation-free in
//! steady state (asserted in `benches/pipeline_hotpath.rs`).

use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::compression::{feature, png, quant};
use crate::data::gen::Sample;
use crate::ilp::Decision;
use crate::metrics::Breakdown;
use crate::runtime::Executor;
use crate::util::pool::Scratch;

/// What [`Session::encode_request`] produced. The encoded bytes live in
/// the session scratch — borrow them via [`Session::wire`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EncodedRequest {
    /// A `compression::feature` frame for the decoupled path.
    Features { stage: u16, c: u8 },
    /// A PNG-compressed image for the cloud-only path.
    Image { hw: u16 },
}

/// One edge session: a model binding plus the per-session scratch the
/// encode path reuses request after request.
pub struct Session<'a> {
    exe: &'a Executor,
    model: String,
    model_id: u16,
    /// Use the exported Pallas quant artifact (true) or the rust twin
    /// (false). Identical numerics; the artifact path proves L1 on the
    /// request path, the twin is faster for large sweeps.
    pub use_pjrt_codec: bool,
    scratch: Scratch,
}

impl<'a> Session<'a> {
    /// Strict constructor: the model must be in the manifest (what a
    /// deployed edge requires — it sends the id on the wire).
    pub fn new(exe: &'a Executor, model: &str) -> Result<Self> {
        let model_id = exe
            .manifest()
            .model_id(model)
            .ok_or_else(|| anyhow!("model {model} not in manifest"))?;
        Ok(Self::with_model_id(exe, model, model_id))
    }

    /// Lenient constructor: unknown models fall back to id 0 and fail at
    /// run time instead (the historical `LocalPipeline` contract).
    pub fn lenient(exe: &'a Executor, model: &str) -> Self {
        let model_id = exe.manifest().model_id(model).unwrap_or(0);
        Self::with_model_id(exe, model, model_id)
    }

    fn with_model_id(exe: &'a Executor, model: &str, model_id: u16) -> Self {
        Self {
            exe,
            model: model.to_string(),
            model_id,
            use_pjrt_codec: true,
            scratch: Scratch::new(),
        }
    }

    pub fn model(&self) -> &str {
        &self.model
    }

    pub fn model_id(&self) -> u16 {
        self.model_id
    }

    pub fn executor(&self) -> &'a Executor {
        self.exe
    }

    /// The wire bytes produced by the last [`Session::encode_request`].
    pub fn wire(&self) -> &[u8] {
        &self.scratch.wire
    }

    /// Run the edge half of one request: head stages, L1 quantize,
    /// entropy-code into the session scratch. *Accumulates* into the
    /// edge-side fields of `bd` (`edge_compute`, `quantize`, `encode`)
    /// so a caller that re-encodes after a `Busy` shed keeps the cost
    /// of every attempt; transmission and the cloud half belong to the
    /// caller's transport.
    pub fn encode_request(
        &mut self,
        sample: &Sample,
        decision: Decision,
        bd: &mut Breakdown,
    ) -> Result<EncodedRequest> {
        match decision {
            Decision::CloudOnly => {
                let t0 = Instant::now();
                let hw = sample.image.shape()[1];
                let rgb = crate::data::gen::to_rgb8(&sample.image);
                let encoded = png::encode(&png::Image8::new(hw, hw, 3, rgb));
                self.scratch.wire.clear();
                self.scratch.wire.extend_from_slice(&encoded);
                bd.encode += t0.elapsed().as_secs_f64();
                Ok(EncodedRequest::Image { hw: hw as u16 })
            }
            Decision::Cut { i, c } => {
                let mut cur = sample.image.clone();
                for j in 1..=i {
                    let out = self.exe.run_stage(&self.model, j, &cur)?;
                    cur = out.tensor;
                    bd.edge_compute += out.seconds;
                }

                // --- edge: L1 quantize ---
                let t0 = Instant::now();
                let Scratch { wire, values, codec, .. } = &mut self.scratch;
                let q_pjrt;
                let (vals, lo, hi): (&[u16], f32, f32) = if self.use_pjrt_codec {
                    q_pjrt = self.exe.run_quant(&cur, c)?;
                    (&q_pjrt.values, q_pjrt.lo, q_pjrt.hi)
                } else {
                    let (lo, hi) = quant::quantize_into(cur.data(), c, values);
                    (&*values, lo, hi)
                };
                bd.quantize += t0.elapsed().as_secs_f64();

                // --- edge: entropy-code to the wire frame ---
                let t1 = Instant::now();
                feature::encode_parts_into(vals, c, lo, hi, i as u16, self.model_id, codec, wire);
                bd.encode += t1.elapsed().as_secs_f64();
                Ok(EncodedRequest::Features { stage: i as u16, c })
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::Manifest;

    fn executor() -> Option<Executor> {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            return None;
        }
        Some(Executor::new(Manifest::load(dir).unwrap()).unwrap())
    }

    #[test]
    fn encoded_frame_decodes_back() {
        let Some(exe) = executor() else { return };
        let mut s = Session::new(&exe, "tinyconv").unwrap();
        s.use_pjrt_codec = false;
        let sample = crate::data::gen::sample_image(100, 32);
        let mut bd = Breakdown::default();
        let req = s.encode_request(&sample, Decision::Cut { i: 1, c: 8 }, &mut bd).unwrap();
        assert_eq!(req, EncodedRequest::Features { stage: 1, c: 8 });
        let frame = feature::decode(s.wire()).unwrap();
        assert_eq!(frame.stage, 1);
        assert_eq!(frame.model, s.model_id());
        assert!(bd.edge_compute > 0.0);
    }

    #[test]
    fn repeated_requests_reuse_wire_buffer() {
        let Some(exe) = executor() else { return };
        let mut s = Session::new(&exe, "tinyconv").unwrap();
        s.use_pjrt_codec = false;
        let mut bd = Breakdown::default();
        let sample = crate::data::gen::sample_image(101, 32);
        s.encode_request(&sample, Decision::Cut { i: 1, c: 8 }, &mut bd).unwrap();
        let first = s.wire().to_vec();
        s.encode_request(&sample, Decision::Cut { i: 1, c: 8 }, &mut bd).unwrap();
        assert_eq!(s.wire(), &first[..], "same request must encode identically");
    }

    #[test]
    fn unknown_model_rejected_strictly() {
        let Some(exe) = executor() else { return };
        assert!(Session::new(&exe, "no-such-model").is_err());
        assert_eq!(Session::lenient(&exe, "no-such-model").model_id(), 0);
    }
}
