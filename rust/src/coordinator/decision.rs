//! Decision engine: predictor tables + latency tables + bandwidth → ILP
//! → `(i*, c)` plan (paper §III-E).
//!
//! Two scales, matching the paper's two experiment modes:
//!
//! * [`Scale::Measured`] — everything from this host: measured stage wall
//!   clocks, measured wire sizes of the scaled models. Drives the live
//!   TCP deployment and the in-process pipeline.
//! * [`Scale::Paper`] — the §IV-A simulation: full-scale FMACs through
//!   the `T = w·Q/F` device model, and wire sizes projected from the
//!   measured compression ratios onto full-scale activation counts
//!   (ratios are scale-invariant; DESIGN.md). Drives Tables II/III and
//!   Figs. 7/8.

use anyhow::{anyhow, Result};

use crate::ilp::{CloudLoad, Decision, JaladInstance, MultiHopInstance, Plan};
use crate::models::fullscale_stages;
use crate::predictor::Tables;
use crate::profiler::LatencyTables;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Scale {
    Measured,
    Paper,
}

#[derive(Debug, Clone)]
pub struct DecisionEngine {
    pub model: String,
    pub tables: Tables,
    pub latency: LatencyTables,
    pub scale: Scale,
    /// Accuracy-loss bound Δα.
    pub delta_alpha: f64,
    /// Per-stage wire sizes by grid c, pre-projected for `scale`.
    size: Vec<Vec<f64>>,
    image_bytes: f64,
}

impl DecisionEngine {
    pub fn new(
        model: &str,
        tables: Tables,
        latency: LatencyTables,
        scale: Scale,
        delta_alpha: f64,
    ) -> Result<Self> {
        let n = tables.num_stages();
        if latency.num_stages() != n {
            return Err(anyhow!(
                "latency tables have {} stages, predictor {}",
                latency.num_stages(),
                n
            ));
        }
        let (size, image_bytes) = match scale {
            Scale::Measured => (tables.size.clone(), tables.image_png_bytes),
            Scale::Paper => {
                let fm = fullscale_stages(model)
                    .ok_or_else(|| anyhow!("no full-scale table for {model}"))?;
                if fm.stages.len() != n {
                    return Err(anyhow!(
                        "full-scale stage count {} != manifest {}",
                        fm.stages.len(),
                        n
                    ));
                }
                // Project: S_full(i,c) = raw_full(i) / ratio_measured(i,c).
                let mut size = Vec::with_capacity(n);
                for i in 1..=n {
                    let raw_full = fm.stages[i - 1].out_elems as f64 * 4.0;
                    let mut row = Vec::with_capacity(tables.c_grid.len());
                    for &c in &tables.c_grid {
                        let ratio = tables.compression_ratio(i, c)?;
                        row.push(raw_full / ratio);
                    }
                    size.push(row);
                }
                // Input image: PNG ratio measured on our 32×32 synthetic
                // images projected onto the 224×224 raw size.
                let png_ratio = tables.image_raw_bytes / tables.image_png_bytes;
                (size, fm.input_rgb_bytes as f64 / png_ratio)
            }
        };
        Ok(Self {
            model: model.to_string(),
            tables,
            latency,
            scale,
            delta_alpha,
            size,
            image_bytes,
        })
    }

    /// A fully synthetic engine for the artifact-free sim backend
    /// (`runtime::sim`'s "simnet"): calibration-free tables with
    /// paper-shaped structure — sizes derived from the sim stages'
    /// real activation counts at compression ratios 8/4/2× for
    /// c = 2/4/8, accuracy drops that shrink with depth, an edge much
    /// slower than the cloud, and cloud stage times large enough that
    /// load inflation visibly moves the optimum. The closed-loop
    /// tests and the control-plane scenario bench run the *deployed*
    /// serving stack against this engine with zero artifacts.
    pub fn sim_default(delta_alpha: f64) -> Result<Self> {
        let manifest = crate::runtime::sim::sim_manifest();
        let model = manifest.model("simnet")?;
        let n = model.num_stages();
        let raw: Vec<f64> = model.stages.iter().map(|s| s.out_elems as f64 * 4.0).collect();
        let c_grid = vec![2u8, 4, 8];
        let size: Vec<Vec<f64>> = raw
            .iter()
            .map(|&r| c_grid.iter().map(|&c| r * c as f64 / 16.0).collect())
            .collect();
        let acc: Vec<Vec<f64>> = (0..n)
            .map(|i| {
                c_grid
                    .iter()
                    .map(|&c| match c {
                        2 => [0.12, 0.08, 0.05, 0.03].get(i).copied().unwrap_or(0.02),
                        4 => 0.01,
                        _ => 0.0,
                    })
                    .collect()
            })
            .collect();
        let tables = Tables {
            model: "simnet".into(),
            c_grid,
            samples: 16,
            base_accuracy: 0.9,
            acc,
            size,
            raw_size: raw,
            image_png_bytes: 600.0,
            image_raw_bytes: model.input_shape.iter().product::<usize>() as f64,
        };
        let latency = LatencyTables {
            t_edge: vec![0.010, 0.030, 0.070, 0.140],
            t_cloud: vec![0.012, 0.008, 0.004, 0.0],
            t_cloud_full: 0.014,
        };
        Self::new("simnet", tables, latency, Scale::Measured, delta_alpha)
    }

    pub fn num_stages(&self) -> usize {
        self.tables.num_stages()
    }

    /// Compressed input-image bytes for the cloud-only path at `scale`.
    pub fn image_png_bytes(&self) -> f64 {
        self.image_bytes
    }

    /// Raw (uncompressed 8-bit) input bytes at `scale`.
    pub fn image_raw_bytes(&self) -> f64 {
        match self.scale {
            Scale::Measured => self.tables.image_raw_bytes,
            Scale::Paper => {
                fullscale_stages(&self.model).map(|m| m.input_rgb_bytes as f64).unwrap_or(0.0)
            }
        }
    }

    /// Wire bytes the chosen plan ships for stage `i`, bit-width `c`.
    pub fn wire_bytes(&self, i: usize, c: u8) -> Result<f64> {
        let k = self
            .tables
            .c_grid
            .iter()
            .position(|&g| g == c)
            .ok_or_else(|| anyhow!("c={c} off-grid"))?;
        Ok(self.size[i - 1][k])
    }

    /// Materialize the load-free ILP instance at `bandwidth` (bytes/s).
    ///
    /// The ILP's c-axis is the calibration grid: variable `(i, k)` maps
    /// to bit-width `c_grid[k]`.
    pub fn instance(&self, bandwidth: f64) -> JaladInstance {
        self.instance_with_load(bandwidth, CloudLoad::default())
    }

    /// Materialize the ILP instance at `bandwidth` with a live cloud
    /// load term folded into `T_C` (the control plane's entry point).
    pub fn instance_with_load(&self, bandwidth: f64, load: CloudLoad) -> JaladInstance {
        let n = self.num_stages();
        JaladInstance {
            n,
            c_max: self.tables.c_grid.len() as u8,
            t_edge: self.latency.t_edge.clone(),
            t_cloud: self.latency.t_cloud.clone(),
            size: self.size.clone(),
            acc: self.tables.acc.clone(),
            image_bytes: self.image_bytes,
            t_cloud_full: self.latency.t_cloud_full,
            bandwidth,
            delta_alpha: self.delta_alpha,
            load,
        }
    }

    /// Solve at `bandwidth`; the plan's `c` is translated back from grid
    /// index to an actual bit-width.
    pub fn decide(&self, bandwidth: f64) -> Plan {
        self.decide_with_load(bandwidth, CloudLoad::default())
    }

    /// Solve at `bandwidth` under a live cloud load.
    pub fn decide_with_load(&self, bandwidth: f64, load: CloudLoad) -> Plan {
        let mut plan = self.instance_with_load(bandwidth, load).solve();
        self.translate_c(&mut plan);
        plan
    }

    /// Solve restricted to cuts at stage ≥ `min_i` (cloud-only
    /// excluded) — the forced edge-ward step after a `Busy` shed when
    /// the unconstrained optimum refuses to move. `None` when no such
    /// cut satisfies the accuracy bound.
    pub fn decide_edgeward(&self, bandwidth: f64, load: CloudLoad, min_i: usize) -> Option<Plan> {
        let mut plan = self.instance_with_load(bandwidth, load).solve_min_cut(min_i)?;
        self.translate_c(&mut plan);
        Some(plan)
    }

    /// Solve the three-tier device→edge→cloud instance: two hops with
    /// their own bandwidths, a device-class compute multiplier on the
    /// lowest tier and an edge-site multiplier on the middle one.
    pub fn decide_three_tier(
        &self,
        device_bw: f64,
        edge_bw: f64,
        load: CloudLoad,
        device_scale: f64,
        edge_scale: f64,
    ) -> Plan {
        let base = self.instance_with_load(edge_bw, load);
        let inst = MultiHopInstance::three_tier(base, device_bw, edge_bw, device_scale, edge_scale);
        let mut plan = inst.solve();
        self.translate_c(&mut plan);
        plan
    }

    /// Translate every cut's `c` from grid index back to a bit-width
    /// (raw-image cuts have no `c` to translate).
    fn translate_c(&self, plan: &mut Plan) {
        for cut in &mut plan.cuts {
            if cut.i > 0 {
                cut.c = self.tables.c_grid[cut.c as usize - 1];
            }
        }
    }

    /// Latency this engine predicts for a baseline that ships `bytes`
    /// and runs everything on the cloud.
    pub fn cloud_only_latency(&self, bytes: f64, bandwidth: f64) -> f64 {
        bytes / bandwidth + self.latency.t_cloud_full
    }
}

#[cfg(test)]
pub(crate) mod tests {
    use super::*;
    use crate::profiler::DeviceModel;

    /// Synthetic tables resembling a trained VGG16: sparse features,
    /// early layers quantize badly at c=1, fine at c≥4.
    pub(crate) fn fake_tables(model: &str, n: usize) -> Tables {
        let c_grid = vec![1u8, 2, 4, 8];
        let raw: Vec<f64> = (0..n)
            .map(|i| {
                // shrinking feature maps with stage depth
                (65536.0 / (1.0 + i as f64)).max(64.0)
            })
            .collect();
        let size = raw
            .iter()
            .map(|&r| {
                c_grid
                    .iter()
                    .map(|&c| r / 4.0 * c as f64 / 8.0 * 0.4) // ~2.5-20x ratio
                    .collect()
            })
            .collect();
        let acc = (0..n)
            .map(|i| {
                c_grid
                    .iter()
                    .map(|&c| match c {
                        1 => 0.4 / (1.0 + i as f64 * 0.2),
                        2 => 0.05 / (1.0 + i as f64 * 0.3),
                        _ => 0.0,
                    })
                    .collect()
            })
            .collect();
        Tables {
            model: model.into(),
            c_grid,
            samples: 16,
            base_accuracy: 0.9,
            acc,
            size,
            raw_size: raw,
            image_png_bytes: 1500.0,
            image_raw_bytes: 3072.0,
        }
    }

    fn engine(model: &str, da: f64) -> DecisionEngine {
        let n = fullscale_stages(model).unwrap().stages.len();
        let tables = fake_tables(model, n);
        let latency =
            LatencyTables::analytic(model, DeviceModel::TEGRA_X2, DeviceModel::CLOUD_12T)
                .unwrap();
        DecisionEngine::new(model, tables, latency, Scale::Paper, da).unwrap()
    }

    #[test]
    fn low_bandwidth_cuts_inside_network() {
        let e = engine("vgg16", 0.10);
        let plan = e.decide(300_000.0 / 8.0 * 8.0 * 0.3); // ~paper's 300KBps
        match plan.decision() {
            Decision::Cut { i, c } => {
                assert!(i >= 1);
                assert!(e.tables.c_grid.contains(&c));
            }
            Decision::CloudOnly => panic!("should not upload at 300 KB/s: {plan:?}"),
        }
        assert!(plan.acc_drop <= 0.10 + 1e-12);
    }

    #[test]
    fn high_bandwidth_converges_to_cloud() {
        // Fig. 8: "when the network condition is good, JALAD tends to
        // upload the raw PNG images to the cloud".
        let e = engine("vgg16", 0.10);
        let plan = e.decide(1e12);
        assert_eq!(plan.decision(), Decision::CloudOnly);
    }

    #[test]
    fn latency_decreases_with_looser_accuracy() {
        // Fig. 7: larger Δα → no worse latency.
        let bw = 125_000.0; // 1 Mbps
        let mut prev = f64::INFINITY;
        for da in [0.0, 0.02, 0.05, 0.10, 0.20, 0.30] {
            let plan = engine("vgg16", da).decide(bw);
            assert!(plan.latency <= prev + 1e-12, "Δα={da}: {} > {prev}", plan.latency);
            prev = plan.latency;
        }
    }

    #[test]
    fn paper_scale_projection_is_consistent() {
        let e = engine("resnet50", 0.1);
        // Paper-scale wire bytes must scale with full-scale activations.
        let w = e.wire_bytes(1, 8).unwrap();
        assert!(w > e.tables.size[0][3], "projection should inflate sizes");
        assert!(e.image_png_bytes() > 10_000.0, "224² png > 10 KB");
    }

    #[test]
    fn cloud_load_moves_the_decision_edgeward() {
        use crate::ilp::CloudLoad;
        let e = engine("vgg16", 0.10);
        let bw = 300_000.0;
        let idle = e.decide(bw);
        let loaded = e.decide_with_load(bw, CloudLoad::new(0.5, 0.95));
        let depth = |d: Decision| match d {
            Decision::CloudOnly => 0,
            Decision::Cut { i, .. } => i,
        };
        assert!(
            depth(loaded.decision()) >= depth(idle.decision()),
            "load must never move the cut cloud-ward: {idle:?} → {loaded:?}"
        );
        assert!(loaded.latency >= idle.latency, "load cannot make things faster");
        // decide == decide_with_load(idle): the legacy path is the
        // zero-load special case, bit-for-bit.
        assert_eq!(e.decide_with_load(bw, CloudLoad::default()), idle);
        // Forced edge-ward restriction honors min_i and the c grid.
        if let Decision::Cut { i, .. } = idle.decision() {
            if let Some(p) = e.decide_edgeward(bw, CloudLoad::default(), i + 1) {
                match p.decision() {
                    Decision::Cut { i: j, c } => {
                        assert!(j > i);
                        assert!(e.tables.c_grid.contains(&c));
                    }
                    Decision::CloudOnly => panic!("edge-ward decide picked cloud-only"),
                }
            }
        }
    }

    #[test]
    fn sim_engine_closes_the_loop_shapewise() {
        use crate::ilp::CloudLoad;
        let e = DecisionEngine::sim_default(0.10).unwrap();
        assert_eq!(e.num_stages(), 4);
        // Idle at 50 KB/s: the 600 B image upload wins.
        let idle = e.decide(50_000.0);
        assert_eq!(idle.decision(), Decision::CloudOnly, "{idle:?}");
        // A loaded cloud moves the cut strictly edge-ward…
        let spike = e.decide_with_load(50_000.0, CloudLoad::new(0.040, 0.9));
        match spike.decision() {
            Decision::Cut { i, .. } => assert!(i >= 2, "{spike:?}"),
            Decision::CloudOnly => panic!("spike must leave cloud-only: {spike:?}"),
        }
        // …and a saturated one parks at the logits-forward cut the
        // admission controller always admits.
        let busy = e.decide_with_load(50_000.0, CloudLoad::new(0.040, 0.97));
        assert_eq!(busy.decision(), Decision::Cut { i: 4, c: 2 }, "{busy:?}");
        // Bandwidth collapse (idle cloud) also ends at the deep cut.
        let slow = e.decide(3_000.0);
        assert_eq!(slow.decision(), Decision::Cut { i: 4, c: 2 }, "{slow:?}");
    }

    #[test]
    fn mismatched_tables_rejected() {
        let tables = fake_tables("vgg16", 7); // wrong N
        let latency =
            LatencyTables::analytic("vgg16", DeviceModel::TEGRA_X2, DeviceModel::CLOUD_12T)
                .unwrap();
        assert!(DecisionEngine::new("vgg16", tables, latency, Scale::Paper, 0.1).is_err());
    }
}
