//! In-process end-to-end pipeline over a simulated channel.
//!
//! Runs one request through the decoupled path exactly as the deployed
//! system would — the edge half through the shared
//! [`coordinator::session::Session`](super::session::Session) (the same
//! code `server::edge` drives over TCP), the simulated uplink, then
//! dequantization and the cloud tail — collecting a full latency
//! [`Breakdown`]. The simulated clock uses *measured* compute seconds
//! plus *modelled* transmission seconds, which is the paper's evaluation
//! methodology. Cloud-side decode reuses a per-pipeline scratch, so the
//! codec hop allocates nothing in steady state.

use std::time::Instant;

use anyhow::Result;

use crate::compression::{feature, png, quant};
use crate::coordinator::decision::DecisionEngine;
use crate::coordinator::session::{EncodedRequest, Session};
use crate::data::gen::Sample;
use crate::ilp::Decision;
use crate::metrics::Breakdown;
use crate::network::SimChannel;
use crate::runtime::{Executor, Tensor};
use crate::util::pool::Scratch;

/// Outcome of one request.
#[derive(Debug, Clone)]
pub struct RunResult {
    pub prediction: usize,
    pub correct: bool,
    pub decision: Decision,
    pub breakdown: Breakdown,
}

pub struct LocalPipeline<'a> {
    session: Session<'a>,
    /// Cloud-side decode scratch — kept apart from the session's
    /// edge-side scratch because in the deployment those buffers live on
    /// different hosts.
    cloud: Scratch,
    /// Use the exported Pallas quant/dequant artifacts (true) or the
    /// rust twin (false). Identical numerics; the artifact path proves
    /// L1 on the request path, the twin is faster for large sweeps.
    pub use_pjrt_codec: bool,
}

impl<'a> LocalPipeline<'a> {
    pub fn new(exe: &'a Executor, model: &str) -> Self {
        Self { session: Session::lenient(exe, model), cloud: Scratch::new(), use_pjrt_codec: true }
    }

    /// Execute `decision` for `sample` over `channel`.
    pub fn run(
        &mut self,
        sample: &Sample,
        decision: Decision,
        channel: &mut SimChannel,
    ) -> Result<RunResult> {
        self.session.use_pjrt_codec = self.use_pjrt_codec;
        let mut bd = Breakdown::default();

        // --- edge half: shared with the TCP deployment ---
        let req = self.session.encode_request(sample, decision, &mut bd)?;
        channel.advance(bd.edge_compute + bd.quantize + bd.encode);
        bd.tx_bytes = self.session.wire().len();
        bd.transmit = channel.transmit(bd.tx_bytes);

        // --- cloud half over the simulated link ---
        let prediction = match req {
            EncodedRequest::Image { .. } => {
                let t1 = Instant::now();
                let decoded =
                    png::decode(self.session.wire()).map_err(anyhow::Error::new)?;
                let x =
                    crate::data::gen::from_rgb8(&decoded.data, sample.image.shape().to_vec());
                bd.decode = t1.elapsed().as_secs_f64();
                let out = self.session.executor().run_full(self.session.model(), &x)?;
                bd.cloud_compute = out.seconds;
                channel.advance(bd.decode + bd.cloud_compute);
                out.tensor.argmax()
            }
            EncodedRequest::Features { .. } => {
                let exe = self.session.executor();
                let m = exe.manifest().model(self.session.model())?;
                let n = m.num_stages();

                // decode into the cloud scratch
                let t2 = Instant::now();
                let Scratch { values, codec, .. } = &mut self.cloud;
                let header = feature::decode_into(self.session.wire(), codec, values)
                    .map_err(anyhow::Error::new)?;
                bd.decode = t2.elapsed().as_secs_f64();

                // dequantize + tail stages
                let i = header.stage as usize;
                let out_shape = m.stages[i - 1].out_shape.clone();
                let t3 = Instant::now();
                let mut cur = if self.use_pjrt_codec {
                    exe.run_dequant_parts(values, header.lo, header.hi, header.c, &out_shape)?
                } else {
                    let mut rec = Vec::with_capacity(values.len());
                    quant::dequantize_into(values, header.lo, header.hi, header.c, &mut rec);
                    Tensor::new(out_shape, rec)
                };
                bd.dequantize = t3.elapsed().as_secs_f64();
                for j in i + 1..=n {
                    let out = exe.run_stage(self.session.model(), j, &cur)?;
                    cur = out.tensor;
                    bd.cloud_compute += out.seconds;
                }
                channel.advance(bd.decode + bd.dequantize + bd.cloud_compute);
                cur.argmax()
            }
        };

        Ok(RunResult {
            prediction,
            correct: prediction == sample.label,
            decision,
            breakdown: bd,
        })
    }

    /// Decide-and-run: what the deployed edge does per request.
    pub fn run_decided(
        &mut self,
        engine: &DecisionEngine,
        sample: &Sample,
        channel: &mut SimChannel,
    ) -> Result<RunResult> {
        let plan = engine.decide(channel.bandwidth_now());
        self.run(sample, plan.decision(), channel)
    }

    /// Closed-loop run: execute the control plane's current plan, then
    /// feed the observed (simulated) transfer back into it — the same
    /// loop `server::edge::EdgeClient` closes over real TCP, driven
    /// over the simulated channel. Cloud-load telemetry is the
    /// caller's to inject (`ControlPlane::observe_cloud_load`); the
    /// simulated channel carries no server. Returns the result and
    /// whether the plane re-decoupled off this transfer.
    ///
    /// Transfers below `server::edge::MIN_ESTIMATE_BYTES` are excluded
    /// from estimation for the same reason the TCP client excludes
    /// them: `SimChannel::transmit` includes the RTT, so a tiny frame's
    /// "throughput" is RTT-dominated noise — feeding it in collapses
    /// the EWMA and ratchets the plan into ever-deeper cuts.
    pub fn run_controlled(
        &mut self,
        control: &mut crate::coordinator::ControlPlane,
        sample: &Sample,
        channel: &mut SimChannel,
    ) -> Result<(RunResult, bool)> {
        let decision = control.plan().decision();
        let result = self.run(sample, decision, channel)?;
        let replanned = result.breakdown.tx_bytes >= crate::server::edge::MIN_ESTIMATE_BYTES
            && control
                .observe_transfer(result.breakdown.tx_bytes, result.breakdown.transmit.max(1e-9))
                .is_some();
        Ok((result, replanned))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::Manifest;

    fn executor() -> Option<Executor> {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            return None;
        }
        Some(Executor::new(Manifest::load(dir).unwrap()).unwrap())
    }

    #[test]
    fn cut_path_matches_clean_prediction_at_c8() {
        let Some(exe) = executor() else { return };
        let mut pipe = LocalPipeline::new(&exe, "tinyconv");
        let mut ch = SimChannel::constant(1e6);
        for id in 6000..6008 {
            let s = crate::data::gen::sample_image(id, 32);
            let clean = exe.run_full("tinyconv", &s.image).unwrap().tensor.argmax();
            let r = pipe.run(&s, Decision::Cut { i: 2, c: 8 }, &mut ch).unwrap();
            assert_eq!(r.prediction, clean, "id {id}: c=8 must not flip predictions");
            assert!(r.breakdown.tx_bytes > 0);
            assert!(r.breakdown.transmit > 0.0);
        }
    }

    #[test]
    fn cloud_only_matches_full_forward() {
        let Some(exe) = executor() else { return };
        let mut pipe = LocalPipeline::new(&exe, "tinyconv");
        let mut ch = SimChannel::constant(1e6);
        let s = crate::data::gen::sample_image(42, 32);
        let clean = exe.run_full("tinyconv", &s.image).unwrap().tensor.argmax();
        let r = pipe.run(&s, Decision::CloudOnly, &mut ch).unwrap();
        // PNG path is lossless up to the 8-bit RGB conversion the
        // baseline itself performs; tiny conversions may flip rare
        // borderline samples, but id 42 is stable.
        assert_eq!(r.prediction, clean);
        assert_eq!(r.decision, Decision::CloudOnly);
    }

    #[test]
    fn lower_c_ships_fewer_bytes() {
        let Some(exe) = executor() else { return };
        let mut pipe = LocalPipeline::new(&exe, "tinyconv");
        let s = crate::data::gen::sample_image(7, 32);
        let mut ch = SimChannel::constant(1e6);
        let b1 = pipe.run(&s, Decision::Cut { i: 1, c: 1 }, &mut ch).unwrap().breakdown;
        let b8 = pipe.run(&s, Decision::Cut { i: 1, c: 8 }, &mut ch).unwrap().breakdown;
        assert!(b1.tx_bytes < b8.tx_bytes, "{} !< {}", b1.tx_bytes, b8.tx_bytes);
    }

    #[test]
    fn rust_and_pjrt_codecs_agree() {
        let Some(exe) = executor() else { return };
        let mut p = LocalPipeline::new(&exe, "tinyconv");
        let s = crate::data::gen::sample_image(13, 32);
        let mut ch = SimChannel::constant(1e9);
        let a = p.run(&s, Decision::Cut { i: 2, c: 4 }, &mut ch).unwrap();
        p.use_pjrt_codec = false;
        let b = p.run(&s, Decision::Cut { i: 2, c: 4 }, &mut ch).unwrap();
        assert_eq!(a.prediction, b.prediction);
        assert_eq!(a.breakdown.tx_bytes, b.breakdown.tx_bytes);
    }
}
