//! In-process end-to-end pipeline over a simulated channel.
//!
//! Runs one request through the decoupled path exactly as the deployed
//! system would — edge stages through PJRT, the L1 Pallas quantizer
//! artifact, Huffman wire coding, the simulated uplink, dequantization
//! and the cloud tail — collecting a full latency [`Breakdown`]. The
//! simulated clock uses *measured* compute seconds plus *modelled*
//! transmission seconds, which is the paper's evaluation methodology.

use std::time::Instant;

use anyhow::Result;

use crate::compression::{feature, quant};
use crate::coordinator::decision::DecisionEngine;
use crate::data::gen::Sample;
use crate::ilp::Decision;
use crate::metrics::Breakdown;
use crate::network::SimChannel;
use crate::runtime::{Executor, Tensor};

/// Outcome of one request.
#[derive(Debug, Clone)]
pub struct RunResult {
    pub prediction: usize,
    pub correct: bool,
    pub decision: Decision,
    pub breakdown: Breakdown,
}

pub struct LocalPipeline<'a> {
    pub exe: &'a Executor,
    pub model: String,
    /// Use the exported Pallas quant/dequant artifacts (true) or the
    /// rust twin (false). Identical numerics; the artifact path proves
    /// L1 on the request path, the twin is faster for large sweeps.
    pub use_pjrt_codec: bool,
}

impl<'a> LocalPipeline<'a> {
    pub fn new(exe: &'a Executor, model: &str) -> Self {
        Self { exe, model: model.to_string(), use_pjrt_codec: true }
    }

    /// Execute `decision` for `sample` over `channel`.
    pub fn run(
        &self,
        sample: &Sample,
        decision: Decision,
        channel: &mut SimChannel,
    ) -> Result<RunResult> {
        match decision {
            Decision::CloudOnly => self.run_cloud_only(sample, channel),
            Decision::Cut { i, c } => self.run_cut(sample, i, c, channel),
        }
    }

    fn run_cloud_only(&self, sample: &Sample, channel: &mut SimChannel) -> Result<RunResult> {
        let mut bd = Breakdown::default();
        // Edge: PNG-compress the 8-bit image.
        let t0 = Instant::now();
        let hw = sample.image.shape()[1];
        let rgb = crate::data::gen::to_rgb8(&sample.image);
        let img8 = crate::compression::png::Image8::new(hw, hw, 3, rgb);
        let wire = crate::compression::png::encode(&img8);
        bd.encode = t0.elapsed().as_secs_f64();
        channel.advance(bd.encode);
        bd.tx_bytes = wire.len();
        bd.transmit = channel.transmit(wire.len());
        // Cloud: decode + full forward.
        let t1 = Instant::now();
        let decoded = crate::compression::png::decode(&wire).map_err(anyhow::Error::new)?;
        let x = crate::data::gen::from_rgb8(&decoded.data, sample.image.shape().to_vec());
        bd.decode = t1.elapsed().as_secs_f64();
        let out = self.exe.run_full(&self.model, &x)?;
        bd.cloud_compute = out.seconds;
        channel.advance(bd.decode + bd.cloud_compute);
        let prediction = out.tensor.argmax();
        Ok(RunResult {
            prediction,
            correct: prediction == sample.label,
            decision: Decision::CloudOnly,
            breakdown: bd,
        })
    }

    fn run_cut(
        &self,
        sample: &Sample,
        i: usize,
        c: u8,
        channel: &mut SimChannel,
    ) -> Result<RunResult> {
        let m = self.exe.manifest().model(&self.model)?;
        let n = m.num_stages();
        let model_id = self.exe.manifest().model_id(&self.model).unwrap_or(0);
        let mut bd = Breakdown::default();

        // --- edge: stages 1..=i ---
        let mut cur = sample.image.clone();
        for j in 1..=i {
            let out = self.exe.run_stage(&self.model, j, &cur)?;
            cur = out.tensor;
            bd.edge_compute += out.seconds;
        }

        // --- edge: L1 quantize ---
        let t0 = Instant::now();
        let q = if self.use_pjrt_codec {
            self.exe.run_quant(&cur, c)?
        } else {
            quant::quantize(cur.data(), c)
        };
        bd.quantize = t0.elapsed().as_secs_f64();

        // --- edge: entropy-code to the wire frame ---
        let t1 = Instant::now();
        let wire = feature::encode(&q, i as u16, model_id);
        bd.encode = t1.elapsed().as_secs_f64();

        channel.advance(bd.edge_compute + bd.quantize + bd.encode);
        bd.tx_bytes = wire.len();
        bd.transmit = channel.transmit(wire.len());

        // --- cloud: decode, dequantize, stages i+1..=N ---
        let t2 = Instant::now();
        let frame = feature::decode(&wire).map_err(anyhow::Error::new)?;
        bd.decode = t2.elapsed().as_secs_f64();
        let rq = quant::Quantized { values: frame.values, lo: frame.lo, hi: frame.hi, c };
        let out_shape = m.stages[i - 1].out_shape.clone();
        let t3 = Instant::now();
        let mut cur = if self.use_pjrt_codec {
            self.exe.run_dequant(&rq, &out_shape)?
        } else {
            Tensor::new(out_shape, quant::dequantize(&rq))
        };
        bd.dequantize = t3.elapsed().as_secs_f64();
        for j in i + 1..=n {
            let out = self.exe.run_stage(&self.model, j, &cur)?;
            cur = out.tensor;
            bd.cloud_compute += out.seconds;
        }
        channel.advance(bd.decode + bd.dequantize + bd.cloud_compute);

        let prediction = cur.argmax();
        Ok(RunResult {
            prediction,
            correct: prediction == sample.label,
            decision: Decision::Cut { i, c },
            breakdown: bd,
        })
    }

    /// Decide-and-run: what the deployed edge does per request.
    pub fn run_decided(
        &self,
        engine: &DecisionEngine,
        sample: &Sample,
        channel: &mut SimChannel,
    ) -> Result<RunResult> {
        let plan = engine.decide(channel.bandwidth_now());
        self.run(sample, plan.decision, channel)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::Manifest;

    fn executor() -> Option<Executor> {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            return None;
        }
        Some(Executor::new(Manifest::load(dir).unwrap()).unwrap())
    }

    #[test]
    fn cut_path_matches_clean_prediction_at_c8() {
        let Some(exe) = executor() else { return };
        let pipe = LocalPipeline::new(&exe, "tinyconv");
        let mut ch = SimChannel::constant(1e6);
        for id in 6000..6008 {
            let s = crate::data::gen::sample_image(id, 32);
            let clean = exe.run_full("tinyconv", &s.image).unwrap().tensor.argmax();
            let r = pipe.run(&s, Decision::Cut { i: 2, c: 8 }, &mut ch).unwrap();
            assert_eq!(r.prediction, clean, "id {id}: c=8 must not flip predictions");
            assert!(r.breakdown.tx_bytes > 0);
            assert!(r.breakdown.transmit > 0.0);
        }
    }

    #[test]
    fn cloud_only_matches_full_forward() {
        let Some(exe) = executor() else { return };
        let pipe = LocalPipeline::new(&exe, "tinyconv");
        let mut ch = SimChannel::constant(1e6);
        let s = crate::data::gen::sample_image(42, 32);
        let clean = exe.run_full("tinyconv", &s.image).unwrap().tensor.argmax();
        let r = pipe.run(&s, Decision::CloudOnly, &mut ch).unwrap();
        // PNG path is lossless up to the 8-bit RGB conversion the
        // baseline itself performs; tiny conversions may flip rare
        // borderline samples, but id 42 is stable.
        assert_eq!(r.prediction, clean);
        assert_eq!(r.decision, Decision::CloudOnly);
    }

    #[test]
    fn lower_c_ships_fewer_bytes() {
        let Some(exe) = executor() else { return };
        let pipe = LocalPipeline::new(&exe, "tinyconv");
        let s = crate::data::gen::sample_image(7, 32);
        let mut ch = SimChannel::constant(1e6);
        let b1 = pipe.run(&s, Decision::Cut { i: 1, c: 1 }, &mut ch).unwrap().breakdown;
        let b8 = pipe.run(&s, Decision::Cut { i: 1, c: 8 }, &mut ch).unwrap().breakdown;
        assert!(b1.tx_bytes < b8.tx_bytes, "{} !< {}", b1.tx_bytes, b8.tx_bytes);
    }

    #[test]
    fn rust_and_pjrt_codecs_agree() {
        let Some(exe) = executor() else { return };
        let mut p = LocalPipeline::new(&exe, "tinyconv");
        let s = crate::data::gen::sample_image(13, 32);
        let mut ch = SimChannel::constant(1e9);
        let a = p.run(&s, Decision::Cut { i: 2, c: 4 }, &mut ch).unwrap();
        p.use_pjrt_codec = false;
        let b = p.run(&s, Decision::Cut { i: 2, c: 4 }, &mut ch).unwrap();
        assert_eq!(a.prediction, b.prediction);
        assert_eq!(a.breakdown.tx_bytes, b.breakdown.tx_bytes);
    }
}
