//! The JALAD coordinator — the paper's system contribution at L3.
//!
//! * [`decision`] — builds the §III-E ILP from the predictor tables +
//!   latency tables + current bandwidth and solves for `(i*, c)`;
//! * [`session`] — the shared edge half of a request (head stages → L1
//!   quant → entropy-code into pooled scratch); both the simulated and
//!   the TCP deployments drive this one implementation;
//! * [`pipeline`] — executes a plan end-to-end in process over a
//!   simulated channel (a [`session::Session`] plus the simulated uplink
//!   and the cloud tail), with full latency breakdowns;
//! * [`baselines`] — Origin2Cloud / PNG2Cloud / JPEG2Cloud / edge-only /
//!   Neurosurgeon-style no-compression partitioning (§IV-A, §V);
//! * [`adaptive`] — the re-decoupling controller: EWMA bandwidth
//!   estimate drift triggers an ILP re-solve (§III-E);
//! * [`router`] — request queue + worker pool for the serving deployment.

pub mod adaptive;
pub mod baselines;
pub mod decision;
pub mod pipeline;
pub mod router;
pub mod session;

pub use adaptive::AdaptationController;
pub use baselines::Baseline;
pub use decision::{DecisionEngine, Scale};
pub use pipeline::{LocalPipeline, RunResult};
pub use router::{Router, RouterConfig};
pub use session::{EncodedRequest, Session};
