//! The JALAD coordinator — the paper's system contribution at L3.
//!
//! * [`decision`] — builds the §III-E ILP from the predictor tables +
//!   latency tables + current bandwidth and solves for `(i*, c)`;
//! * [`pipeline`] — executes a plan end-to-end in process over a
//!   simulated channel (edge stages → L1 quant → Huffman → transmit →
//!   dequant → cloud stages), with full latency breakdowns;
//! * [`baselines`] — Origin2Cloud / PNG2Cloud / JPEG2Cloud / edge-only /
//!   Neurosurgeon-style no-compression partitioning (§IV-A, §V);
//! * [`adaptive`] — the re-decoupling controller: EWMA bandwidth
//!   estimate drift triggers an ILP re-solve (§III-E);
//! * [`router`] — request queue + worker pool for the serving deployment.

pub mod adaptive;
pub mod baselines;
pub mod decision;
pub mod pipeline;
pub mod router;

pub use adaptive::AdaptationController;
pub use baselines::Baseline;
pub use decision::{DecisionEngine, Scale};
pub use pipeline::{LocalPipeline, RunResult};
pub use router::{Router, RouterConfig};
