//! The JALAD coordinator — the paper's system contribution at L3.
//!
//! * [`decision`] — builds the §III-E ILP from the predictor tables +
//!   latency tables + current bandwidth and solves for `(i*, c)`;
//! * [`session`] — the shared edge half of a request (head stages → L1
//!   quant → entropy-code into pooled scratch); both the simulated and
//!   the TCP deployments drive this one implementation;
//! * [`pipeline`] — executes a plan end-to-end in process over a
//!   simulated channel (a [`session::Session`] plus the simulated uplink
//!   and the cloud tail), with full latency breakdowns;
//! * [`baselines`] — Origin2Cloud / PNG2Cloud / JPEG2Cloud / edge-only /
//!   Neurosurgeon-style no-compression partitioning (§IV-A, §V);
//! * [`control`] — the live adaptation control plane: fuses the EWMA
//!   bandwidth estimate with the cloud's piggybacked load telemetry,
//!   re-solves on drift of either, and walks the cut edge-ward on
//!   `Busy` sheds (§III-E closed over link *and* server state);
//! * [`router`] — request queue + worker pool for the serving deployment.

pub mod baselines;
pub mod control;
pub mod decision;
pub mod pipeline;
pub mod router;
pub mod session;

pub use control::{cut_depth, ControlPlane};
pub use baselines::Baseline;
pub use decision::{DecisionEngine, Scale};
pub use pipeline::{LocalPipeline, RunResult};
pub use router::{Router, RouterConfig};
pub use session::{EncodedRequest, Session};
