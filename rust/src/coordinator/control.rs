//! The live adaptation control plane (§III-E, closed over *both* link
//! and server state).
//!
//! The paper's re-decoupling strategy says the edge should re-solve the
//! decoupling ILP "upon the edge-cloud network change"; partition
//! frameworks since (Auto-Split, Edgent) treat *server load* as an
//! equally first-class input. The [`ControlPlane`] fuses the two
//! signals:
//!
//! * **bandwidth** — the EWMA [`BandwidthEstimator`] fed by every
//!   completed transfer (unchanged from the original controller);
//! * **cloud load** — the [`CloudTelemetry`] block the cloud
//!   piggybacks on every logits reply (queue-wait p95, shard
//!   utilization, batch occupancy, admission state), smoothed into a
//!   [`CloudLoad`] that the ILP folds into `T_C(i)`.
//!
//! Drift of *either* signal past its threshold triggers a re-solve.
//! A `Busy` shed is the strongest load signal of all: the edge adopts
//! the refusal's telemetry immediately (fast attack — the smoothed
//! estimate only governs recovery) and, if the re-solve refuses to
//! move, forces the next-later cut via the exact min-cut-constrained
//! ILP. That is the §III-E prescription — under server pressure the
//! cut shifts edge-ward (later `i*`, smaller transfer, less cloud
//! compute) until the cloud admits the work again.
//!
//! One implementation serves every deployment shape: `LocalPipeline`
//! (simulated channel) drives it through
//! [`run_controlled`](super::pipeline::LocalPipeline::run_controlled),
//! `server::edge::EdgeClient` drives it over real TCP, and the
//! trace-replay tests drive it directly.

use crate::coordinator::decision::DecisionEngine;
use crate::ilp::{CloudLoad, Decision, Plan};
use crate::network::BandwidthEstimator;
use crate::server::proto::CloudTelemetry;

/// How edge-ward a decision is: cloud-only ships everything (depth 0),
/// a cut after stage `i` keeps `i` stages on the edge.
pub fn cut_depth(d: Decision) -> usize {
    match d {
        Decision::CloudOnly => 0,
        Decision::Cut { i, .. } => i,
    }
}

pub struct ControlPlane {
    pub engine: DecisionEngine,
    pub estimator: BandwidthEstimator,
    /// Relative bandwidth drift that triggers a re-solve (default 0.15).
    pub rel_threshold: f64,
    /// Cloud-load drift that triggers a re-solve (default 0.10):
    /// absolute change in utilization, or relative change in queue
    /// wait (with a floor so microsecond jitter near zero is inert).
    pub load_threshold: f64,
    /// EWMA weight for fusing incoming load telemetry (default 0.4:
    /// react within a couple of replies, ignore single-reply spikes).
    pub load_alpha: f64,
    /// Smoothed cloud-load estimate (what re-solves use).
    load: CloudLoad,
    /// Load at the last re-solve — the drift baseline.
    acked_load: CloudLoad,
    current: Plan,
    resolves: u64,
    plan_changes: u64,
    sheds_observed: u64,
    /// The last `Busy` refusal's per-tenant backoff hint, seconds
    /// (0 = no hint — a pre-tenant or non-fair cloud). The transport
    /// paces its shed retries with this instead of hammering an
    /// overloaded server.
    advised_backoff: f64,
    /// Circuit-breaker transitions this plane has reacted to, and the
    /// requests it served fully on-edge while the breaker was open.
    breaker_opens: u64,
    breaker_recloses: u64,
    local_serves: u64,
}

impl ControlPlane {
    pub fn new(engine: DecisionEngine, initial_bandwidth: f64) -> Self {
        let current = engine.decide(initial_bandwidth);
        let mut estimator = BandwidthEstimator::default();
        estimator.observe(initial_bandwidth as usize, 1.0);
        let _ = estimator.take_change(0.0);
        Self {
            engine,
            estimator,
            rel_threshold: 0.15,
            load_threshold: 0.10,
            load_alpha: 0.4,
            load: CloudLoad::default(),
            acked_load: CloudLoad::default(),
            current,
            resolves: 0,
            plan_changes: 0,
            sheds_observed: 0,
            advised_backoff: 0.0,
            breaker_opens: 0,
            breaker_recloses: 0,
            local_serves: 0,
        }
    }

    pub fn plan(&self) -> &Plan {
        &self.current
    }

    /// ILP re-solves performed (either signal's drift, or forced).
    pub fn resolves(&self) -> u64 {
        self.resolves
    }

    /// Re-solves whose decision differed from the plan they replaced.
    pub fn plan_changes(&self) -> u64 {
        self.plan_changes
    }

    /// `Busy` sheds this plane has reacted to.
    pub fn sheds_observed(&self) -> u64 {
        self.sheds_observed
    }

    /// The last shed's per-tenant backoff hint, seconds (0 = none).
    /// Fast attack, decayed on served replies: a hint from one refusal
    /// should pace the immediate retries, not every future request.
    pub fn advised_backoff(&self) -> f64 {
        self.advised_backoff
    }

    pub fn bandwidth_estimate(&self) -> Option<f64> {
        self.estimator.bytes_per_sec()
    }

    /// The smoothed cloud-load estimate currently steering `T_C`.
    pub fn cloud_load(&self) -> CloudLoad {
        self.load
    }

    /// Feed one completed transfer; returns the new plan if the
    /// controller re-decoupled (re-solved *and* the decision changed).
    pub fn observe_transfer(&mut self, bytes: usize, seconds: f64) -> Option<&Plan> {
        self.estimator.observe(bytes, seconds);
        if self.estimator.take_change(self.rel_threshold).is_some() {
            return self.resolve_now();
        }
        None
    }

    /// Feed a cloud-load observation (typically from piggybacked
    /// telemetry); returns the new plan if the drift re-decoupled.
    pub fn observe_cloud_load(&mut self, observed: CloudLoad) -> Option<&Plan> {
        let a = self.load_alpha;
        self.load = CloudLoad::new(
            self.load.queue_wait + a * (observed.queue_wait - self.load.queue_wait),
            self.load.utilization + a * (observed.utilization - self.load.utilization),
        );
        if self.load_drifted() {
            return self.resolve_now();
        }
        None
    }

    /// Feed a piggybacked telemetry block from a logits reply.
    pub fn observe_telemetry(&mut self, t: &CloudTelemetry) -> Option<&Plan> {
        // A served reply means this tenant is back inside its share:
        // decay the pacing hint so it only governs the shed episode.
        self.advised_backoff *= 0.5;
        if self.advised_backoff < 1e-4 {
            self.advised_backoff = 0.0;
        }
        self.observe_cloud_load(Self::telemetry_load(t))
    }

    /// React to a `Busy` shed: adopt the refusal's load verbatim (fast
    /// attack; the EWMA only smooths recovery), re-solve, and if the
    /// optimum refuses to move strictly edge-ward, force the next-later
    /// cut with the min-cut-constrained ILP. Returns the plan to retry
    /// with. Progress is guaranteed: each call either deepens the cut
    /// or leaves it at the deepest feasible stage.
    pub fn on_busy(&mut self, t: &CloudTelemetry) -> &Plan {
        self.sheds_observed += 1;
        // Sanitize before clamping: clamp() passes NaN through, and a
        // NaN hint would stick (the served-reply decay can never zero
        // it) and poison the stats JSON.
        let hint = f64::from(t.tenant_backoff_ms);
        self.advised_backoff = if hint.is_finite() { (hint / 1e3).clamp(0.0, 2.0) } else { 0.0 };
        let reported = Self::telemetry_load(t);
        self.load = CloudLoad::new(
            self.load.queue_wait.max(reported.queue_wait),
            self.load.utilization.max(reported.utilization),
        );
        let before = cut_depth(self.current.decision());
        let bw = self.bandwidth();
        let mut plan = self.engine.decide_with_load(bw, self.load);
        if cut_depth(plan.decision()) <= before {
            // The unconstrained optimum refused to move (or would move
            // cloud-ward — the one direction a shed must never take).
            // Force the next-later cut; at the deepest feasible stage,
            // hold depth rather than bounce back. Whatever wins is
            // committed exactly once, so one shed is one re-solve (and
            // at most one plan change) in the adaptation counters.
            if let Some(forced) = self
                .engine
                .decide_edgeward(bw, self.load, before + 1)
                .or_else(|| self.engine.decide_edgeward(bw, self.load, before.max(1)))
            {
                plan = forced;
            }
        }
        self.note_change(&plan);
        self.current = plan;
        self.resolves += 1;
        self.acked_load = self.load;
        &self.current
    }

    /// The cloud path's circuit breaker tripped open: park the plan at
    /// the deepest feasible cut (the `i=N` full-local configuration —
    /// Edgent's always-available fallback) so the session machinery
    /// keeps describing what the edge actually runs while the cloud is
    /// unreachable. Counted separately from load-driven re-solves.
    pub fn on_breaker_open(&mut self) -> &Plan {
        self.breaker_opens += 1;
        let n = self.engine.num_stages();
        if let Some(forced) = self.engine.decide_edgeward(self.bandwidth(), self.load, n) {
            self.note_change(&forced);
            self.current = forced;
            self.resolves += 1;
            self.acked_load = self.load;
        }
        &self.current
    }

    /// The breaker re-closed (a half-open probe succeeded): re-solve
    /// unconstrained so the cut walks back cloud-ward exactly as far as
    /// the current bandwidth/load signals justify — recovery is a
    /// re-solve, not a blind restore of the pre-outage plan.
    pub fn on_breaker_close(&mut self) -> &Plan {
        self.breaker_recloses += 1;
        let plan = self.engine.decide_with_load(self.bandwidth(), self.load);
        self.note_change(&plan);
        self.current = plan;
        self.resolves += 1;
        self.acked_load = self.load;
        &self.current
    }

    /// Breaker open events reacted to.
    pub fn breaker_opens(&self) -> u64 {
        self.breaker_opens
    }

    /// Breaker reclose events reacted to.
    pub fn breaker_recloses(&self) -> u64 {
        self.breaker_recloses
    }

    /// Requests served fully on-edge while the breaker was open.
    pub fn local_serves(&self) -> u64 {
        self.local_serves
    }

    /// Count one full-local serve (the transport calls this on every
    /// request it answers without the cloud).
    pub fn note_local_serve(&mut self) {
        self.local_serves += 1;
    }

    /// Force a re-solve at an externally known bandwidth (tests,
    /// traces). Keeps the current load signal in the instance.
    pub fn resolve_at(&mut self, bandwidth: f64) -> &Plan {
        let plan = self.engine.decide_with_load(bandwidth, self.load);
        self.note_change(&plan);
        self.current = plan;
        self.resolves += 1;
        self.acked_load = self.load;
        &self.current
    }

    fn bandwidth(&self) -> f64 {
        // The constructor seeds the estimator, so the estimate exists
        // for the whole life of the plane; the fallback is for safety.
        self.estimator.bytes_per_sec().unwrap_or(1.0)
    }

    /// Re-solve with the fused (bandwidth, load) signals; returns the
    /// plan when the decision changed.
    fn resolve_now(&mut self) -> Option<&Plan> {
        let plan = self.engine.decide_with_load(self.bandwidth(), self.load);
        let changed = plan.cuts != self.current.cuts;
        self.note_change(&plan);
        self.current = plan;
        self.resolves += 1;
        self.acked_load = self.load;
        if changed {
            Some(&self.current)
        } else {
            None
        }
    }

    fn note_change(&mut self, next: &Plan) {
        if next.cuts != self.current.cuts {
            self.plan_changes += 1;
        }
    }

    /// Has the smoothed load drifted past `load_threshold` since the
    /// last re-solve? Utilization compares absolutely (it is already a
    /// fraction); queue wait compares relatively with a 1 ms floor so
    /// near-zero jitter never triggers.
    fn load_drifted(&self) -> bool {
        let du = (self.load.utilization - self.acked_load.utilization).abs();
        if du >= self.load_threshold {
            return true;
        }
        let base = self.acked_load.queue_wait.abs().max(1e-3);
        (self.load.queue_wait - self.acked_load.queue_wait).abs() / base >= self.load_threshold
    }

    fn telemetry_load(t: &CloudTelemetry) -> CloudLoad {
        CloudLoad::new(
            (t.queue_wait_p95_ms as f64 / 1e3).max(0.0),
            (t.utilization as f64).clamp(0.0, 1.0),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::decision::{tests::fake_tables, Scale};
    use crate::ilp::Decision;
    use crate::models::fullscale_stages;
    use crate::profiler::{DeviceModel, LatencyTables};

    fn controller() -> ControlPlane {
        let model = "vgg16";
        let n = fullscale_stages(model).unwrap().stages.len();
        let engine = DecisionEngine::new(
            model,
            fake_tables(model, n),
            LatencyTables::analytic(model, DeviceModel::TEGRA_X2, DeviceModel::CLOUD_12T)
                .unwrap(),
            Scale::Paper,
            0.10,
        )
        .unwrap();
        ControlPlane::new(engine, 125_000.0)
    }

    #[test]
    fn stable_bandwidth_never_replans() {
        let mut c = controller();
        let before = c.resolves();
        for _ in 0..50 {
            // 125 KB/s steady — inside the threshold band.
            assert!(c.observe_transfer(12_500, 0.1).is_none());
        }
        assert_eq!(c.resolves(), before);
    }

    #[test]
    fn bandwidth_collapse_triggers_replan() {
        // Start fast enough that cloud-only wins (paper-scale 224² PNG is
        // ~73 KB, so "fast" means ≳13 MB/s), then collapse the link.
        let mut c = controller();
        c.resolve_at(1e8);
        let initial = c.plan().decision();
        assert_eq!(initial, Decision::CloudOnly, "100 MB/s should upload");
        // Collapse to 5 KB/s: EWMA needs a few observations to drift 15%.
        let mut changed = false;
        for _ in 0..40 {
            if c.observe_transfer(500, 0.1).is_some() {
                changed = true;
                break;
            }
        }
        assert!(changed, "controller never re-decoupled");
        assert_ne!(c.plan().decision(), initial);
        // At 5 KB/s the plan must be a deep cut with small wire size.
        match c.plan().decision() {
            Decision::Cut { i, .. } => assert!(i >= 1),
            Decision::CloudOnly => panic!("cloud-only at 5 KB/s is wrong"),
        }
    }

    #[test]
    fn bandwidth_recovery_returns_to_cloud() {
        let mut c = controller();
        c.resolve_at(5_000.0);
        let deep = c.plan().latency;
        let p = c.resolve_at(1e12).clone();
        assert_eq!(p.decision(), Decision::CloudOnly);
        assert!(p.latency < deep);
    }

    #[test]
    fn stable_load_never_replans() {
        let mut c = controller();
        // Settle the smoothed estimate on a fixed mild load, ack it…
        let mild = CloudLoad::new(0.002, 0.3);
        for _ in 0..20 {
            c.observe_cloud_load(mild);
        }
        let base = c.resolves();
        // …then keep reporting it: no drift, no re-solve.
        for _ in 0..50 {
            assert!(c.observe_cloud_load(mild).is_none());
        }
        assert_eq!(c.resolves(), base);
    }

    #[test]
    fn load_spike_resolves_and_recovers() {
        let mut c = controller();
        c.resolve_at(1e8);
        assert_eq!(c.plan().decision(), Decision::CloudOnly);
        let base_resolves = c.resolves();
        // A sustained utilization spike must trigger a re-solve within
        // a few replies (EWMA α=0.4 → 2 observations pass 0.10 drift).
        let spike = CloudLoad::new(0.050, 0.95);
        for _ in 0..10 {
            c.observe_cloud_load(spike);
        }
        assert!(c.resolves() > base_resolves, "load drift never re-solved");
        assert!(c.cloud_load().utilization > 0.5, "fusion never tracked the spike");
        // Recovery decays the estimate and re-solves back.
        for _ in 0..30 {
            c.observe_cloud_load(CloudLoad::default());
        }
        assert!(c.cloud_load().utilization < 0.05);
        assert_eq!(c.plan().decision(), Decision::CloudOnly, "idle cloud at 100 MB/s uploads");
    }

    #[test]
    fn busy_always_moves_edgeward_until_the_last_stage() {
        let mut c = controller();
        c.resolve_at(1e8);
        assert_eq!(cut_depth(c.plan().decision()), 0, "fast link starts cloud-only");
        let t = CloudTelemetry {
            queue_wait_p95_ms: 40.0,
            utilization: 0.97,
            batch_occupancy: 4.0,
            shedding: true,
            sheds: 1,
            tenant_backoff_ms: 0.0,
        };
        let n = c.engine.num_stages();
        let mut depth = 0;
        // Repeated sheds must walk the cut strictly edge-ward until it
        // parks at the deepest feasible stage — never oscillate back.
        for k in 0..n + 3 {
            let next = cut_depth(c.on_busy(&t).decision());
            assert!(
                next > depth || (next == depth && next == n) || depth == n,
                "shed {k}: depth went {depth} → {next}"
            );
            if next == depth {
                break;
            }
            depth = next;
        }
        assert!(depth >= 1, "busy never left cloud-only");
        assert!(c.sheds_observed() >= 1);
    }

    #[test]
    fn breaker_open_forces_full_local_and_close_walks_back() {
        let mut c = controller();
        // Drive the estimator to a fast link so the steady-state plan
        // is cloud-only.
        for _ in 0..40 {
            c.observe_transfer(10_000_000, 0.1);
        }
        assert_eq!(cut_depth(c.plan().decision()), 0, "fast link should upload");
        let n = c.engine.num_stages();

        let open = c.on_breaker_open().clone();
        assert_eq!(cut_depth(open.decision()), n, "open must park at the i=N cut");
        assert_eq!(c.breaker_opens(), 1);

        c.note_local_serve();
        c.note_local_serve();
        assert_eq!(c.local_serves(), 2);

        // Reclose re-solves from the live signals: the fast link is
        // still fast, so the cut walks all the way back cloud-ward.
        let closed = c.on_breaker_close().clone();
        assert_eq!(cut_depth(closed.decision()), 0, "reclose must walk the cut cloud-ward");
        assert_eq!(c.breaker_recloses(), 1);
    }

    #[test]
    fn backoff_hint_is_adopted_and_decays_when_served() {
        let mut c = controller();
        assert_eq!(c.advised_backoff(), 0.0, "no hint before any shed");
        let busy = CloudTelemetry {
            queue_wait_p95_ms: 10.0,
            utilization: 0.95,
            shedding: true,
            tenant_backoff_ms: 80.0,
            ..CloudTelemetry::default()
        };
        c.on_busy(&busy);
        assert!((c.advised_backoff() - 0.080).abs() < 1e-9, "hint must be adopted in seconds");
        // A hint-less shed (pre-tenant cloud) resets to the legacy
        // immediate-retry contract.
        c.on_busy(&CloudTelemetry { shedding: true, ..CloudTelemetry::default() });
        assert_eq!(c.advised_backoff(), 0.0);
        // Served replies halve the hint away: after a shed episode the
        // pacing must not tax steady-state traffic.
        c.on_busy(&busy);
        for _ in 0..16 {
            c.observe_telemetry(&CloudTelemetry::default());
        }
        assert_eq!(c.advised_backoff(), 0.0, "hint never decayed");
        // Hints are clamped to a sane ceiling (a garbled f32 cannot
        // stall the edge for minutes)…
        c.on_busy(&CloudTelemetry {
            shedding: true,
            tenant_backoff_ms: 1e9,
            ..CloudTelemetry::default()
        });
        assert!(c.advised_backoff() <= 2.0);
        // …and a NaN hint is dropped, never stored (clamp alone would
        // pass it through and it could then never decay away).
        c.on_busy(&CloudTelemetry {
            shedding: true,
            tenant_backoff_ms: f32::NAN,
            ..CloudTelemetry::default()
        });
        assert_eq!(c.advised_backoff(), 0.0);
    }
}
