//! Request router: bounded queue + worker pool + backpressure.
//!
//! The serving front of the edge device: requests (images) arrive, are
//! queued, and a small worker pool drives them through the pipeline.
//! Closed-loop per worker (PJRT CPU execution is compute-bound; more
//! in-flight than cores just queues), with explicit backpressure —
//! `submit` fails fast when the queue is full, which the paper's
//! edge-device framing (constrained devices) demands.

use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};

use crate::metrics::Counters;

#[derive(Debug, Clone)]
pub struct RouterConfig {
    pub queue_capacity: usize,
    pub workers: usize,
}

impl Default for RouterConfig {
    fn default() -> Self {
        Self { queue_capacity: 64, workers: 2 }
    }
}

#[derive(Debug, PartialEq, Eq)]
pub enum SubmitError {
    QueueFull,
    ShuttingDown,
}

struct Shared<T> {
    queue: Mutex<(VecDeque<T>, bool)>, // (items, shutting_down)
    cv: Condvar,
    capacity: usize,
}

/// Generic router: `T` is the request type; the handler runs on worker
/// threads. Results flow through the handler's own channel (closure
/// captures), keeping the router agnostic of the pipeline types.
pub struct Router<T: Send + 'static> {
    shared: Arc<Shared<T>>,
    workers: Vec<std::thread::JoinHandle<()>>,
    pub counters: Arc<Counters>,
}

impl<T: Send + 'static> Router<T> {
    pub fn new<F>(config: RouterConfig, handler: F) -> Self
    where
        F: Fn(T) + Send + Sync + 'static,
    {
        let shared = Arc::new(Shared {
            queue: Mutex::new((VecDeque::new(), false)),
            cv: Condvar::new(),
            capacity: config.queue_capacity,
        });
        let counters = Arc::new(Counters::default());
        let handler = Arc::new(handler);
        let workers = (0..config.workers.max(1))
            .map(|_| {
                let shared = Arc::clone(&shared);
                let handler = Arc::clone(&handler);
                let counters = Arc::clone(&counters);
                std::thread::spawn(move || loop {
                    let item = {
                        let mut g = shared.queue.lock().unwrap();
                        loop {
                            if let Some(it) = g.0.pop_front() {
                                shared.cv.notify_all();
                                break it;
                            }
                            if g.1 {
                                return;
                            }
                            g = shared.cv.wait(g).unwrap();
                        }
                    };
                    counters.inc_requests();
                    handler(item);
                })
            })
            .collect();
        Self { shared, workers, counters }
    }

    /// Enqueue; fails fast when the queue is full (backpressure).
    pub fn submit(&self, item: T) -> Result<(), SubmitError> {
        let mut g = self.shared.queue.lock().unwrap();
        if g.1 {
            return Err(SubmitError::ShuttingDown);
        }
        if g.0.len() >= self.shared.capacity {
            return Err(SubmitError::QueueFull);
        }
        g.0.push_back(item);
        self.shared.cv.notify_one();
        Ok(())
    }

    /// Block until the queue drains (workers may still be mid-request).
    pub fn wait_drained(&self) {
        let mut g = self.shared.queue.lock().unwrap();
        while !g.0.is_empty() {
            g = self.shared.cv.wait(g).unwrap();
        }
    }

    pub fn queue_len(&self) -> usize {
        self.shared.queue.lock().unwrap().0.len()
    }

    /// Stop accepting, finish queued items, join workers.
    pub fn shutdown(mut self) {
        {
            let mut g = self.shared.queue.lock().unwrap();
            g.1 = true;
        }
        self.shared.cv.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

impl<T: Send + 'static> Drop for Router<T> {
    fn drop(&mut self) {
        {
            let mut g = self.shared.queue.lock().unwrap();
            g.1 = true;
        }
        self.shared.cv.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn processes_all_submitted() {
        let done = Arc::new(AtomicUsize::new(0));
        let d2 = Arc::clone(&done);
        let router = Router::new(RouterConfig { queue_capacity: 128, workers: 4 }, move |_x: u32| {
            d2.fetch_add(1, Ordering::SeqCst);
        });
        for i in 0..100 {
            router.submit(i).unwrap();
        }
        router.shutdown();
        assert_eq!(done.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn backpressure_rejects_when_full() {
        let gate = Arc::new((Mutex::new(false), Condvar::new()));
        let g2 = Arc::clone(&gate);
        let router = Router::new(RouterConfig { queue_capacity: 2, workers: 1 }, move |_x: u32| {
            // Block the single worker until the gate opens.
            let (m, cv) = &*g2;
            let mut open = m.lock().unwrap();
            while !*open {
                open = cv.wait(open).unwrap();
            }
        });
        router.submit(0).unwrap(); // consumed by the worker (blocked)
        std::thread::sleep(std::time::Duration::from_millis(30));
        router.submit(1).unwrap();
        router.submit(2).unwrap();
        assert_eq!(router.submit(3), Err(SubmitError::QueueFull));
        let (m, cv) = &*gate;
        *m.lock().unwrap() = true;
        cv.notify_all();
        router.shutdown();
    }

    #[test]
    fn counters_track_requests() {
        let router = Router::new(RouterConfig::default(), |_x: u32| {});
        let counters = Arc::clone(&router.counters);
        for i in 0..10 {
            router.submit(i).unwrap();
        }
        router.shutdown();
        assert_eq!(counters.snapshot().0, 10);
    }
}
