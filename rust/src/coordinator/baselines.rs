//! The paper's baselines (§IV-A) plus two reference points from §V.
//!
//! * **Origin2Cloud** — ship the raw 8-bit RGB image, run everything on
//!   the cloud;
//! * **PNG2Cloud** — ship the losslessly compressed image ("the
//!   conventional cloud-based AI approach");
//! * **JPEG2Cloud** — ship a lossy-compressed image (quality-50);
//! * **EdgeOnly** — run the whole network on the edge device (§V's
//!   edge-based deployment);
//! * **NeurosurgeonNoCompress** — partition like [11] (Kang et al.):
//!   pick the best cut but ship *raw f32* features, no in-layer
//!   compression. This is the comparison that motivates the whole paper
//!   ("their partition point frequently falls on the first or the last
//!   layer").

use anyhow::Result;

use crate::compression::{jpeg, png};
use crate::coordinator::pipeline::RunResult;
use crate::data::gen::{self, Sample};
use crate::ilp::Decision;
use crate::metrics::Breakdown;
use crate::network::SimChannel;
use crate::profiler::LatencyTables;
use crate::runtime::Executor;
use std::time::Instant;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Baseline {
    Origin2Cloud,
    Png2Cloud,
    Jpeg2Cloud,
    EdgeOnly,
    NeurosurgeonNoCompress,
}

impl Baseline {
    pub const ALL: [Baseline; 5] = [
        Baseline::Origin2Cloud,
        Baseline::Png2Cloud,
        Baseline::Jpeg2Cloud,
        Baseline::EdgeOnly,
        Baseline::NeurosurgeonNoCompress,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            Baseline::Origin2Cloud => "Origin2Cloud",
            Baseline::Png2Cloud => "PNG2Cloud",
            Baseline::Jpeg2Cloud => "JPEG2Cloud",
            Baseline::EdgeOnly => "EdgeOnly",
            Baseline::NeurosurgeonNoCompress => "Neurosurgeon",
        }
    }

    /// Execute this baseline for one sample over the simulated channel.
    pub fn run(
        &self,
        exe: &Executor,
        model: &str,
        sample: &Sample,
        channel: &mut SimChannel,
    ) -> Result<RunResult> {
        let mut bd = Breakdown::default();
        let hw = sample.image.shape()[1];
        let prediction = match self {
            Baseline::Origin2Cloud => {
                let rgb = gen::to_rgb8(&sample.image);
                bd.tx_bytes = rgb.len();
                bd.transmit = channel.transmit(rgb.len());
                let x = gen::from_rgb8(&rgb, sample.image.shape().to_vec());
                let out = exe.run_full(model, &x)?;
                bd.cloud_compute = out.seconds;
                channel.advance(bd.cloud_compute);
                out.tensor.argmax()
            }
            Baseline::Png2Cloud => {
                let t0 = Instant::now();
                let rgb = gen::to_rgb8(&sample.image);
                let wire = png::encode(&png::Image8::new(hw, hw, 3, rgb));
                bd.encode = t0.elapsed().as_secs_f64();
                channel.advance(bd.encode);
                bd.tx_bytes = wire.len();
                bd.transmit = channel.transmit(wire.len());
                let t1 = Instant::now();
                let img = png::decode(&wire).map_err(anyhow::Error::new)?;
                bd.decode = t1.elapsed().as_secs_f64();
                let x = gen::from_rgb8(&img.data, sample.image.shape().to_vec());
                let out = exe.run_full(model, &x)?;
                bd.cloud_compute = out.seconds;
                channel.advance(bd.decode + bd.cloud_compute);
                out.tensor.argmax()
            }
            Baseline::Jpeg2Cloud => {
                let t0 = Instant::now();
                let rgb = gen::to_rgb8(&sample.image);
                let wire = jpeg::encode(&png::Image8::new(hw, hw, 3, rgb), 50);
                bd.encode = t0.elapsed().as_secs_f64();
                channel.advance(bd.encode);
                bd.tx_bytes = wire.len();
                bd.transmit = channel.transmit(wire.len());
                let t1 = Instant::now();
                let img = jpeg::decode(&wire).map_err(anyhow::Error::msg)?;
                bd.decode = t1.elapsed().as_secs_f64();
                let x = gen::from_rgb8(&img.data, sample.image.shape().to_vec());
                let out = exe.run_full(model, &x)?;
                bd.cloud_compute = out.seconds;
                channel.advance(bd.decode + bd.cloud_compute);
                out.tensor.argmax()
            }
            Baseline::EdgeOnly => {
                let m = exe.manifest().model(model)?;
                let n = m.num_stages();
                let out = exe.run_stages(model, 1, n, &sample.image)?;
                bd.edge_compute = out.seconds;
                channel.advance(bd.edge_compute);
                out.tensor.argmax()
            }
            Baseline::NeurosurgeonNoCompress => {
                // Best raw-feature cut under the current bandwidth —
                // Kang et al.'s search without in-layer compression.
                let m = exe.manifest().model(model)?;
                let n = m.num_stages();
                let bw = channel.bandwidth_now();
                // Pick i minimizing raw-size/bw (compute assumed equal
                // across cuts on this single host profile would need the
                // latency tables; raw bytes dominate at WAN bandwidths).
                let i = (1..=n)
                    .min_by(|&a, &b| {
                        let la = m.stage_raw_bytes(a) as f64 / bw;
                        let lb = m.stage_raw_bytes(b) as f64 / bw;
                        la.partial_cmp(&lb).unwrap()
                    })
                    .unwrap();
                let out = exe.run_stages(model, 1, i, &sample.image)?;
                bd.edge_compute = out.seconds;
                channel.advance(bd.edge_compute);
                let raw = out.tensor.byte_size();
                bd.tx_bytes = raw;
                bd.transmit = channel.transmit(raw);
                let tail = exe.run_stages(model, i + 1, n, &out.tensor);
                let (pred, secs) = match (i < n, tail) {
                    (true, Ok(t)) => (t.tensor.argmax(), t.seconds),
                    _ => (out.tensor.argmax(), 0.0),
                };
                bd.cloud_compute = secs;
                channel.advance(secs);
                pred
            }
        };
        Ok(RunResult {
            prediction,
            correct: prediction == sample.label,
            decision: Decision::CloudOnly,
            breakdown: bd,
        })
    }

    /// Analytic latency of this baseline at paper scale (for the table
    /// benches): `upload/BW + compute`.
    pub fn analytic_latency(
        &self,
        image_raw_bytes: f64,
        image_png_bytes: f64,
        latency: &LatencyTables,
        bandwidth: f64,
    ) -> f64 {
        match self {
            Baseline::Origin2Cloud => image_raw_bytes / bandwidth + latency.t_cloud_full,
            Baseline::Png2Cloud => image_png_bytes / bandwidth + latency.t_cloud_full,
            Baseline::Jpeg2Cloud => image_png_bytes * 0.4 / bandwidth + latency.t_cloud_full,
            Baseline::EdgeOnly => latency.t_edge[latency.num_stages() - 1],
            Baseline::NeurosurgeonNoCompress => {
                // handled by the bench with raw per-stage sizes
                f64::NAN
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::Manifest;

    fn executor() -> Option<Executor> {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            return None;
        }
        Some(Executor::new(Manifest::load(dir).unwrap()).unwrap())
    }

    #[test]
    fn all_baselines_run_and_mostly_agree() {
        let Some(exe) = executor() else { return };
        let s = crate::data::gen::sample_image(100, 32);
        let clean = exe.run_full("tinyconv", &s.image).unwrap().tensor.argmax();
        for b in Baseline::ALL {
            let mut ch = SimChannel::constant(1e6);
            let r = b.run(&exe, "tinyconv", &s, &mut ch).unwrap();
            // JPEG is lossy; all others must match the clean prediction.
            if b != Baseline::Jpeg2Cloud {
                assert_eq!(r.prediction, clean, "{}", b.name());
            }
        }
    }

    #[test]
    fn png_ships_fewer_bytes_than_origin() {
        let Some(exe) = executor() else { return };
        let s = crate::data::gen::sample_image(101, 32);
        let mut ch = SimChannel::constant(1e6);
        let orig = Baseline::Origin2Cloud.run(&exe, "tinyconv", &s, &mut ch).unwrap();
        let png = Baseline::Png2Cloud.run(&exe, "tinyconv", &s, &mut ch).unwrap();
        assert!(png.breakdown.tx_bytes < orig.breakdown.tx_bytes);
        let jpg = Baseline::Jpeg2Cloud.run(&exe, "tinyconv", &s, &mut ch).unwrap();
        assert!(jpg.breakdown.tx_bytes < png.breakdown.tx_bytes);
    }

    #[test]
    fn edge_only_ships_nothing() {
        let Some(exe) = executor() else { return };
        let s = crate::data::gen::sample_image(102, 32);
        let mut ch = SimChannel::constant(1e6);
        let r = Baseline::EdgeOnly.run(&exe, "tinyconv", &s, &mut ch).unwrap();
        assert_eq!(r.breakdown.tx_bytes, 0);
        assert_eq!(r.breakdown.transmit, 0.0);
    }
}
