//! Adaptive re-decoupling (§III-E): "our design re-decouples the deep
//! neural network upon the edge-cloud network change".
//!
//! The controller owns a [`DecisionEngine`] and a [`BandwidthEstimator`];
//! every completed transfer feeds the estimator, and when the EWMA
//! estimate drifts beyond a relative threshold the ILP is re-solved and
//! the plan swapped (edge and cloud "synchronize" — in our deployment the
//! wire frame is self-describing, so the cloud follows automatically).

use crate::coordinator::decision::DecisionEngine;
use crate::ilp::jalad::Plan;
use crate::network::BandwidthEstimator;

pub struct AdaptationController {
    pub engine: DecisionEngine,
    pub estimator: BandwidthEstimator,
    /// Relative bandwidth drift that triggers a re-solve (default 0.15).
    pub rel_threshold: f64,
    current: Plan,
    resolves: u64,
}

impl AdaptationController {
    pub fn new(engine: DecisionEngine, initial_bandwidth: f64) -> Self {
        let current = engine.decide(initial_bandwidth);
        let mut estimator = BandwidthEstimator::default();
        estimator.observe(initial_bandwidth as usize, 1.0);
        let _ = estimator.take_change(0.0);
        Self { engine, estimator, rel_threshold: 0.15, current, resolves: 0 }
    }

    pub fn plan(&self) -> &Plan {
        &self.current
    }

    pub fn resolves(&self) -> u64 {
        self.resolves
    }

    pub fn bandwidth_estimate(&self) -> Option<f64> {
        self.estimator.bytes_per_sec()
    }

    /// Feed one completed transfer; returns the new plan if the
    /// controller re-decoupled.
    pub fn observe_transfer(&mut self, bytes: usize, seconds: f64) -> Option<&Plan> {
        self.estimator.observe(bytes, seconds);
        if let Some(bw) = self.estimator.take_change(self.rel_threshold) {
            let plan = self.engine.decide(bw);
            let changed = plan.decision != self.current.decision;
            self.current = plan;
            self.resolves += 1;
            if changed {
                return Some(&self.current);
            }
        }
        None
    }

    /// Force a re-solve at an externally known bandwidth (tests, traces).
    pub fn resolve_at(&mut self, bandwidth: f64) -> &Plan {
        self.current = self.engine.decide(bandwidth);
        self.resolves += 1;
        &self.current
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::decision::{tests::fake_tables, Scale};
    use crate::ilp::Decision;
    use crate::models::fullscale_stages;
    use crate::profiler::{DeviceModel, LatencyTables};

    fn controller() -> AdaptationController {
        let model = "vgg16";
        let n = fullscale_stages(model).unwrap().stages.len();
        let engine = DecisionEngine::new(
            model,
            fake_tables(model, n),
            LatencyTables::analytic(model, DeviceModel::TEGRA_X2, DeviceModel::CLOUD_12T)
                .unwrap(),
            Scale::Paper,
            0.10,
        )
        .unwrap();
        AdaptationController::new(engine, 125_000.0)
    }

    #[test]
    fn stable_bandwidth_never_replans() {
        let mut c = controller();
        let before = c.resolves();
        for _ in 0..50 {
            // 125 KB/s steady — inside the threshold band.
            assert!(c.observe_transfer(12_500, 0.1).is_none());
        }
        assert_eq!(c.resolves(), before);
    }

    #[test]
    fn bandwidth_collapse_triggers_replan() {
        // Start fast enough that cloud-only wins (paper-scale 224² PNG is
        // ~73 KB, so "fast" means ≳13 MB/s), then collapse the link.
        let mut c = controller();
        c.resolve_at(1e8);
        let initial = c.plan().decision;
        assert_eq!(initial, Decision::CloudOnly, "100 MB/s should upload");
        // Collapse to 5 KB/s: EWMA needs a few observations to drift 15%.
        let mut changed = false;
        for _ in 0..40 {
            if c.observe_transfer(500, 0.1).is_some() {
                changed = true;
                break;
            }
        }
        assert!(changed, "controller never re-decoupled");
        assert_ne!(c.plan().decision, initial);
        // At 5 KB/s the plan must be a deep cut with small wire size.
        match c.plan().decision {
            Decision::Cut { i, .. } => assert!(i >= 1),
            Decision::CloudOnly => panic!("cloud-only at 5 KB/s is wrong"),
        }
    }

    #[test]
    fn bandwidth_recovery_returns_to_cloud() {
        let mut c = controller();
        c.resolve_at(5_000.0);
        let deep = c.plan().latency;
        let p = c.resolve_at(1e12).clone();
        assert_eq!(p.decision, Decision::CloudOnly);
        assert!(p.latency < deep);
    }
}
