//! # JALAD — Joint Accuracy- and Latency-Aware Deep Structure Decoupling
//!
//! Rust reproduction of *JALAD: Joint Accuracy- and Latency-Aware Deep
//! Structure Decoupling for Edge-Cloud Execution* (Li et al., IEEE
//! PADSW 2018). A pre-trained CNN is cut at a decoupling point `i*`:
//! stages `1..i*` run on the edge device, the stage-`i*` feature map is
//! affine-quantized to `c` bits, entropy-coded, shipped to the cloud,
//! dequantized and finished there. `(i*, c)` minimizes total latency
//! under a user accuracy-loss bound via a 0-1 ILP, and is re-solved as
//! bandwidth drifts.
//!
//! Three-layer architecture (see `DESIGN.md`):
//! * **L1** Pallas quantize/dequantize (+ conv) kernels — compiled AOT
//!   from python, executed here through PJRT;
//! * **L2** stage-sliced JAX models (VGG-16/19, ResNet-50/101,
//!   TinyConv) — one HLO artifact per decoupling point;
//! * **L3** this crate: the entire request path. Python never runs at
//!   request time.
//!
//! Crate map:
//! * [`runtime`] — PJRT client + deterministic sim backend, artifact
//!   registry, lazy (compile-exactly-once) stage executor, the sharded
//!   [`runtime::ExecutorPool`] and the micro-batching
//!   [`runtime::BatchEngine`] that form the cloud compute spine;
//! * [`compression`] — feature wire codec (bit-packing + canonical
//!   Huffman), LZ77/deflate, PNG-like and JPEG-like image codecs for the
//!   baselines;
//! * [`ilp`] — 0-1 branch-and-bound ILP solver + the paper's
//!   formulation;
//! * [`predictor`] — the `A_i(c)` / `S_i(c)` lookup tables (§III-C);
//! * [`profiler`] — measured stage latencies + the paper's analytic
//!   FMAC/FLOPS device model (§IV-A);
//! * [`network`] — simulated channels, bandwidth traces, token-bucket
//!   throttling, EWMA estimation;
//! * [`coordinator`] — decision engine (load-aware: `T_C(i)` carries
//!   the cloud's reported queue wait and utilization), the shared
//!   edge-side [`coordinator::session::Session`] (one implementation
//!   of the run-stages → quantize → entropy-code path driven by both
//!   the simulated pipeline and the TCP edge client), baselines, the
//!   live adaptation [`coordinator::ControlPlane`] (re-solves on
//!   bandwidth *or* cloud-load drift, walks the cut edge-ward on
//!   `Busy` sheds), request router;
//! * [`server`] — real TCP edge/cloud deployment over a throttled link;
//!   the cloud serves connections concurrently on `util::threadpool`
//!   with pooled per-connection scratch, native worker-side
//!   dequantization, sharded + micro-batched tail inference
//!   (adaptive gather window, deadline-ordered), shard-aware
//!   admission control (`Busy` sheds) and load telemetry piggybacked
//!   on every logits reply;
//! * [`models`] — stage metadata + full-scale analytic FMAC tables;
//! * [`data`] — the synthetic ILSVRC substitute (mirrors
//!   `python/compile/data.py`);
//! * [`metrics`] — latency histograms, serving counters, throughput;
//! * [`util`] — from-scratch substrates: JSON, CLI, bench harness,
//!   property testing, threadpool, pooled scratch buffers
//!   ([`util::pool`]), a build-exactly-once concurrent map
//!   ([`util::once_map`]) (the offline vendor set has no serde/clap/
//!   criterion/proptest/tokio).
//!
//! The request hot path is zero-copy in steady state: `compression`
//! exposes `*_into` APIs over borrowed buffers (`bitio::BitWriter`
//! appends to a borrowed `Vec`, `huffman`/`feature` encode and decode
//! into reusable scratch, `quant` has `quantize_into`/
//! `dequantize_into`), `server::proto` reads and writes frames through
//! caller-owned buffers, and sessions/connections hold their buffers in
//! `util::pool::Scratch` — so the codec + proto hops perform no heap
//! allocations once warm (asserted in `benches/pipeline_hotpath.rs`).

pub mod compression;
pub mod coordinator;
pub mod data;
pub mod ilp;
pub mod metrics;
pub mod models;
pub mod network;
pub mod predictor;
pub mod profiler;
pub mod runtime;
pub mod server;
pub mod util;

/// Quantization bit-widths the runtime supports: `c ∈ 1..=C_MAX`.
/// Must match `python/compile/aot.py::C_MAX` (manifest carries it too).
pub const C_MAX: u8 = 8;

/// Workspace-relative default artifact directory.
pub const DEFAULT_ARTIFACTS: &str = "artifacts";
