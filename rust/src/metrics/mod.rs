//! Serving metrics: latency histograms, counters, per-request breakdown.
//!
//! Everything the paper reports is a latency decomposition
//! (edge compute + transmission + cloud compute); [`Breakdown`] carries
//! those fields per request and [`Histogram`] aggregates distributions
//! for the server's stats endpoint and the bench harness. The
//! concurrent cloud server additionally uses [`SharedHistogram`]
//! (mutex-wrapped, recorded from connection workers) and [`Throughput`]
//! (a monotonic events-per-second meter), and the allocation-reuse side
//! of serving is tracked by `util::pool::PoolStats`.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use crate::util::stats;

/// Per-request latency decomposition, seconds.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct Breakdown {
    pub edge_compute: f64,
    pub quantize: f64,
    pub encode: f64,
    pub transmit: f64,
    pub decode: f64,
    pub dequantize: f64,
    pub cloud_compute: f64,
    /// Wire bytes actually shipped.
    pub tx_bytes: usize,
}

impl Breakdown {
    pub fn total(&self) -> f64 {
        self.edge_compute
            + self.quantize
            + self.encode
            + self.transmit
            + self.decode
            + self.dequantize
            + self.cloud_compute
    }

    pub fn summary(&self) -> String {
        format!(
            "total {:.2} ms (edge {:.2} + quant {:.2} + enc {:.2} + tx {:.2} + dec {:.2} + deq {:.2} + cloud {:.2}), {} B on wire",
            self.total() * 1e3,
            self.edge_compute * 1e3,
            self.quantize * 1e3,
            self.encode * 1e3,
            self.transmit * 1e3,
            self.decode * 1e3,
            self.dequantize * 1e3,
            self.cloud_compute * 1e3,
            self.tx_bytes
        )
    }
}

/// Reservoir-less latency histogram: stores all samples (evaluation runs
/// are bounded) and reports percentiles on demand.
#[derive(Debug, Clone, Default)]
pub struct Histogram {
    samples: Vec<f64>,
}

impl Histogram {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&mut self, v: f64) {
        self.samples.push(v);
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    pub fn mean(&self) -> f64 {
        stats::mean(&self.samples)
    }

    pub fn percentile(&self, p: f64) -> f64 {
        stats::percentile(&self.samples, p)
    }

    pub fn summary(&self, unit_scale: f64, unit: &str) -> String {
        format!(
            "n={} mean={:.2}{unit} p50={:.2}{unit} p95={:.2}{unit} p99={:.2}{unit}",
            self.len(),
            self.mean() * unit_scale,
            self.percentile(50.0) * unit_scale,
            self.percentile(95.0) * unit_scale,
            self.percentile(99.0) * unit_scale,
        )
    }
}

/// Cheap thread-safe counters for the servers.
///
/// The taxonomy is explicit so rate metrics don't lie: **data
/// requests** (`requests`, Features/Image — the work the paper's
/// latency model is about, and the only thing `req_per_sec` counts)
/// vs **control frames** (`control_frames`, Stats/Probe/Shutdown —
/// bookkeeping traffic) vs **protocol violations** (`malformed`,
/// unframeable or unknown-kind input). `data_bytes` counts
/// data-request payload bytes only, in whichever direction the
/// counting endpoint sees them (the cloud server reports its ingress
/// as `bytes_rx`); probe padding is deliberately split into
/// `probe_bytes` because a bandwidth probe is sized to saturate the
/// link and would otherwise dwarf the real number. `errors` counts
/// data requests that were well-framed but failed in handling.
#[derive(Debug, Default)]
pub struct Counters {
    pub requests: AtomicU64,
    pub errors: AtomicU64,
    pub data_bytes: AtomicU64,
    pub redecouples: AtomicU64,
    pub connections: AtomicU64,
    pub control_frames: AtomicU64,
    pub probe_bytes: AtomicU64,
    pub malformed: AtomicU64,
}

impl Counters {
    pub fn inc_requests(&self) {
        self.requests.fetch_add(1, Ordering::Relaxed);
    }
    pub fn inc_errors(&self) {
        self.errors.fetch_add(1, Ordering::Relaxed);
    }
    pub fn add_bytes(&self, n: u64) {
        self.data_bytes.fetch_add(n, Ordering::Relaxed);
    }
    pub fn inc_redecouples(&self) {
        self.redecouples.fetch_add(1, Ordering::Relaxed);
    }
    pub fn inc_connections(&self) {
        self.connections.fetch_add(1, Ordering::Relaxed);
    }
    pub fn inc_control(&self) {
        self.control_frames.fetch_add(1, Ordering::Relaxed);
    }
    pub fn add_probe_bytes(&self, n: u64) {
        self.probe_bytes.fetch_add(n, Ordering::Relaxed);
    }
    pub fn inc_malformed(&self) {
        self.malformed.fetch_add(1, Ordering::Relaxed);
    }
    pub fn connections(&self) -> u64 {
        self.connections.load(Ordering::Relaxed)
    }
    pub fn control(&self) -> u64 {
        self.control_frames.load(Ordering::Relaxed)
    }
    pub fn probe(&self) -> u64 {
        self.probe_bytes.load(Ordering::Relaxed)
    }
    pub fn malformed_count(&self) -> u64 {
        self.malformed.load(Ordering::Relaxed)
    }
    pub fn snapshot(&self) -> (u64, u64, u64, u64) {
        (
            self.requests.load(Ordering::Relaxed),
            self.errors.load(Ordering::Relaxed),
            self.data_bytes.load(Ordering::Relaxed),
            self.redecouples.load(Ordering::Relaxed),
        )
    }
}

/// A [`Histogram`] safe to record into from many connection workers.
/// One mutex: a record is nanoseconds next to a network hop.
#[derive(Debug, Default)]
pub struct SharedHistogram(Mutex<Histogram>);

impl SharedHistogram {
    pub fn record(&self, v: f64) {
        self.0.lock().unwrap().record(v);
    }

    pub fn snapshot(&self) -> Histogram {
        self.0.lock().unwrap().clone()
    }
}

/// Micro-batch scheduler telemetry: how full batches run, how many
/// requests bypassed the queue, and how long batched requests waited
/// between enqueue and execution start. Occupancy (mean/max batch
/// size) is the direct measure of whether the gather window is earning
/// its latency; queue-wait is that latency.
#[derive(Debug, Default)]
pub struct BatchMetrics {
    pub batches: AtomicU64,
    pub batched_requests: AtomicU64,
    pub bypassed: AtomicU64,
    pub max_occupancy: AtomicU64,
    /// Seconds from enqueue to batch execution start, per batched
    /// request.
    pub queue_wait: SharedHistogram,
}

impl BatchMetrics {
    pub fn record_batch(&self, occupancy: usize) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.batched_requests.fetch_add(occupancy as u64, Ordering::Relaxed);
        self.max_occupancy.fetch_max(occupancy as u64, Ordering::Relaxed);
    }

    pub fn record_bypass(&self) {
        self.bypassed.fetch_add(1, Ordering::Relaxed);
    }

    /// Mean requests per executed batch (0 when none ran).
    pub fn mean_occupancy(&self) -> f64 {
        let b = self.batches.load(Ordering::Relaxed);
        if b == 0 {
            0.0
        } else {
            self.batched_requests.load(Ordering::Relaxed) as f64 / b as f64
        }
    }

    /// (batches, batched_requests, bypassed, max_occupancy).
    pub fn snapshot(&self) -> (u64, u64, u64, u64) {
        (
            self.batches.load(Ordering::Relaxed),
            self.batched_requests.load(Ordering::Relaxed),
            self.bypassed.load(Ordering::Relaxed),
            self.max_occupancy.load(Ordering::Relaxed),
        )
    }
}

/// Monotonic events-per-second meter (requests, bytes) for serving
/// throughput reporting.
#[derive(Debug)]
pub struct Throughput {
    started: Instant,
    events: AtomicU64,
}

impl Default for Throughput {
    fn default() -> Self {
        Self::new()
    }
}

impl Throughput {
    pub fn new() -> Self {
        Self { started: Instant::now(), events: AtomicU64::new(0) }
    }

    pub fn observe(&self, n: u64) {
        self.events.fetch_add(n, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.events.load(Ordering::Relaxed)
    }

    pub fn per_second(&self) -> f64 {
        let dt = self.started.elapsed().as_secs_f64().max(1e-9);
        self.count() as f64 / dt
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn breakdown_total_sums_components() {
        let b = Breakdown {
            edge_compute: 0.01,
            quantize: 0.002,
            encode: 0.003,
            transmit: 0.1,
            decode: 0.001,
            dequantize: 0.002,
            cloud_compute: 0.005,
            tx_bytes: 123,
        };
        assert!((b.total() - 0.123).abs() < 1e-12);
        assert!(b.summary().contains("123 B"));
    }

    #[test]
    fn histogram_percentiles() {
        let mut h = Histogram::new();
        for i in 1..=100 {
            h.record(i as f64);
        }
        assert_eq!(h.len(), 100);
        assert!((h.mean() - 50.5).abs() < 1e-9);
        assert!((h.percentile(50.0) - 50.5).abs() < 1.0);
        assert!(h.percentile(99.0) > 98.0);
    }

    #[test]
    fn shared_histogram_records_concurrently() {
        let h = std::sync::Arc::new(SharedHistogram::default());
        let workers: Vec<_> = (0..4)
            .map(|t| {
                let h = std::sync::Arc::clone(&h);
                std::thread::spawn(move || {
                    for i in 0..100 {
                        h.record((t * 100 + i) as f64);
                    }
                })
            })
            .collect();
        for w in workers {
            w.join().unwrap();
        }
        assert_eq!(h.snapshot().len(), 400);
    }

    #[test]
    fn throughput_counts_events() {
        let t = Throughput::new();
        t.observe(10);
        t.observe(5);
        assert_eq!(t.count(), 15);
        assert!(t.per_second() > 0.0);
    }

    #[test]
    fn counter_taxonomy_is_disjoint() {
        let c = Counters::default();
        c.inc_requests();
        c.add_bytes(100);
        c.inc_control();
        c.inc_control();
        c.add_probe_bytes(1 << 20);
        c.inc_malformed();
        let (req, err, bytes, _) = c.snapshot();
        assert_eq!((req, err, bytes), (1, 0, 100), "probe/control must not leak into data");
        assert_eq!(c.control(), 2);
        assert_eq!(c.probe(), 1 << 20);
        assert_eq!(c.malformed_count(), 1);
    }

    #[test]
    fn batch_metrics_track_occupancy() {
        let m = BatchMetrics::default();
        assert_eq!(m.mean_occupancy(), 0.0);
        m.record_batch(4);
        m.record_batch(2);
        m.record_bypass();
        m.queue_wait.record(0.001);
        let (batches, reqs, bypassed, max) = m.snapshot();
        assert_eq!((batches, reqs, bypassed, max), (2, 6, 1, 4));
        assert!((m.mean_occupancy() - 3.0).abs() < 1e-12);
        assert_eq!(m.queue_wait.snapshot().len(), 1);
    }

    #[test]
    fn counters_are_threadsafe() {
        let c = std::sync::Arc::new(Counters::default());
        let hs: Vec<_> = (0..4)
            .map(|_| {
                let c = std::sync::Arc::clone(&c);
                std::thread::spawn(move || {
                    for _ in 0..1000 {
                        c.inc_requests();
                        c.add_bytes(10);
                    }
                })
            })
            .collect();
        for h in hs {
            h.join().unwrap();
        }
        let (req, _, bytes, _) = c.snapshot();
        assert_eq!(req, 4000);
        assert_eq!(bytes, 40_000);
    }
}
