//! Serving metrics: latency histograms, counters, per-request breakdown.
//!
//! Everything the paper reports is a latency decomposition
//! (edge compute + transmission + cloud compute); [`Breakdown`] carries
//! those fields per request and [`Histogram`] aggregates distributions
//! for the server's stats endpoint and the bench harness. The
//! concurrent cloud server additionally uses [`SharedHistogram`]
//! (mutex-wrapped, recorded from connection workers) and [`Throughput`]
//! (a monotonic events-per-second meter), and the allocation-reuse side
//! of serving is tracked by `util::pool::PoolStats`.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use crate::util::stats;

/// Per-request latency decomposition, seconds.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct Breakdown {
    pub edge_compute: f64,
    pub quantize: f64,
    pub encode: f64,
    pub transmit: f64,
    pub decode: f64,
    pub dequantize: f64,
    pub cloud_compute: f64,
    /// Wire bytes actually shipped.
    pub tx_bytes: usize,
}

impl Breakdown {
    pub fn total(&self) -> f64 {
        self.edge_compute
            + self.quantize
            + self.encode
            + self.transmit
            + self.decode
            + self.dequantize
            + self.cloud_compute
    }

    pub fn summary(&self) -> String {
        format!(
            "total {:.2} ms (edge {:.2} + quant {:.2} + enc {:.2} + tx {:.2} + dec {:.2} + deq {:.2} + cloud {:.2}), {} B on wire",
            self.total() * 1e3,
            self.edge_compute * 1e3,
            self.quantize * 1e3,
            self.encode * 1e3,
            self.transmit * 1e3,
            self.decode * 1e3,
            self.dequantize * 1e3,
            self.cloud_compute * 1e3,
            self.tx_bytes
        )
    }
}

/// Reservoir-less latency histogram: stores all samples (evaluation runs
/// are bounded) and reports percentiles on demand.
#[derive(Debug, Clone, Default)]
pub struct Histogram {
    samples: Vec<f64>,
}

impl Histogram {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&mut self, v: f64) {
        self.samples.push(v);
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    /// Percentile over samples recorded at index `from` onward — the
    /// sliding-window view used by cloud telemetry ("recent" queue
    /// wait, not lifetime). Empty windows report 0.
    pub fn tail_percentile(&self, from: usize, p: f64) -> f64 {
        if from >= self.samples.len() {
            return 0.0;
        }
        stats::percentile(&self.samples[from..], p)
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    pub fn mean(&self) -> f64 {
        stats::mean(&self.samples)
    }

    pub fn percentile(&self, p: f64) -> f64 {
        stats::percentile(&self.samples, p)
    }

    pub fn summary(&self, unit_scale: f64, unit: &str) -> String {
        format!(
            "n={} mean={:.2}{unit} p50={:.2}{unit} p95={:.2}{unit} p99={:.2}{unit}",
            self.len(),
            self.mean() * unit_scale,
            self.percentile(50.0) * unit_scale,
            self.percentile(95.0) * unit_scale,
            self.percentile(99.0) * unit_scale,
        )
    }
}

/// Cheap thread-safe counters for the servers.
///
/// The taxonomy is explicit so rate metrics don't lie: **data
/// requests** (`requests`, Features/Image — the work the paper's
/// latency model is about, and the only thing `req_per_sec` counts)
/// vs **control frames** (`control_frames`, Stats/Probe/Shutdown —
/// bookkeeping traffic) vs **protocol violations** (`malformed`,
/// unframeable or unknown-kind input). `data_bytes` counts
/// data-request payload bytes only, in whichever direction the
/// counting endpoint sees them (the cloud server reports its ingress
/// as `bytes_rx`); probe padding is deliberately split into
/// `probe_bytes` because a bandwidth probe is sized to saturate the
/// link and would otherwise dwarf the real number. `errors` counts
/// data requests that were well-framed but failed in handling;
/// `sheds` counts data requests admission control refused with a
/// `Busy` frame (they are *also* counted in `requests` — a shed is a
/// data request the server chose not to serve, not a protocol event);
/// `conn_sheds` counts whole connections refused at the accept
/// boundary by the `max_conns` guard (counted in `connections`, never
/// in `requests` — no frame of theirs was ever read).
#[derive(Debug, Default)]
pub struct Counters {
    pub requests: AtomicU64,
    pub errors: AtomicU64,
    pub data_bytes: AtomicU64,
    pub redecouples: AtomicU64,
    pub connections: AtomicU64,
    pub control_frames: AtomicU64,
    pub probe_bytes: AtomicU64,
    pub malformed: AtomicU64,
    pub sheds: AtomicU64,
    pub conn_sheds: AtomicU64,
    /// Connections the reactor closed for making no frame progress
    /// within the idle timeout (slow-loris defense; counted in
    /// `connections` too — they were accepted).
    pub idle_reaped: AtomicU64,
}

impl Counters {
    pub fn inc_requests(&self) {
        self.requests.fetch_add(1, Ordering::Relaxed);
    }
    pub fn inc_errors(&self) {
        self.errors.fetch_add(1, Ordering::Relaxed);
    }
    pub fn add_bytes(&self, n: u64) {
        self.data_bytes.fetch_add(n, Ordering::Relaxed);
    }
    pub fn inc_redecouples(&self) {
        self.redecouples.fetch_add(1, Ordering::Relaxed);
    }
    pub fn inc_connections(&self) {
        self.connections.fetch_add(1, Ordering::Relaxed);
    }
    pub fn inc_control(&self) {
        self.control_frames.fetch_add(1, Ordering::Relaxed);
    }
    pub fn add_probe_bytes(&self, n: u64) {
        self.probe_bytes.fetch_add(n, Ordering::Relaxed);
    }
    pub fn inc_malformed(&self) {
        self.malformed.fetch_add(1, Ordering::Relaxed);
    }
    pub fn inc_sheds(&self) {
        self.sheds.fetch_add(1, Ordering::Relaxed);
    }
    pub fn sheds(&self) -> u64 {
        self.sheds.load(Ordering::Relaxed)
    }
    pub fn inc_conn_sheds(&self) {
        self.conn_sheds.fetch_add(1, Ordering::Relaxed);
    }
    pub fn conn_sheds(&self) -> u64 {
        self.conn_sheds.load(Ordering::Relaxed)
    }
    pub fn inc_idle_reaped(&self) {
        self.idle_reaped.fetch_add(1, Ordering::Relaxed);
    }
    pub fn idle_reaped(&self) -> u64 {
        self.idle_reaped.load(Ordering::Relaxed)
    }
    pub fn connections(&self) -> u64 {
        self.connections.load(Ordering::Relaxed)
    }
    pub fn control(&self) -> u64 {
        self.control_frames.load(Ordering::Relaxed)
    }
    pub fn probe(&self) -> u64 {
        self.probe_bytes.load(Ordering::Relaxed)
    }
    pub fn malformed_count(&self) -> u64 {
        self.malformed.load(Ordering::Relaxed)
    }
    pub fn snapshot(&self) -> (u64, u64, u64, u64) {
        (
            self.requests.load(Ordering::Relaxed),
            self.errors.load(Ordering::Relaxed),
            self.data_bytes.load(Ordering::Relaxed),
            self.redecouples.load(Ordering::Relaxed),
        )
    }
}

/// Most recent samples a [`SharedHistogram`] retains. Serving
/// percentiles are computed over this sliding window — a long-lived
/// server's histograms stay bounded (64 KiB each) instead of growing
/// one f64 per request forever, and 8k samples is far more than any
/// percentile needs to be stable.
pub const SHARED_HISTOGRAM_CAP: usize = 8192;

/// A histogram safe to record into from many connection workers.
/// One mutex: a record is nanoseconds next to a network hop. Unlike
/// the unbounded [`Histogram`] (sized for bounded evaluation runs),
/// this retains only the last [`SHARED_HISTOGRAM_CAP`] samples — so
/// the serving stats endpoint's percentiles describe *recent*
/// behavior, which is also what an operator wants from a live server.
#[derive(Debug, Default)]
pub struct SharedHistogram(Mutex<SharedHistInner>);

#[derive(Debug, Default)]
struct SharedHistInner {
    /// The retained window, insertion order (front = oldest).
    ring: std::collections::VecDeque<f64>,
    /// Samples ever recorded (the window covers
    /// `total - ring.len() .. total`).
    total: usize,
}

impl SharedHistogram {
    pub fn record(&self, v: f64) {
        let mut h = self.0.lock().unwrap();
        if h.ring.len() == SHARED_HISTOGRAM_CAP {
            h.ring.pop_front();
        }
        h.ring.push_back(v);
        h.total += 1;
    }

    /// The retained window as a plain [`Histogram`] (bounded clone).
    pub fn snapshot(&self) -> Histogram {
        let h = self.0.lock().unwrap();
        Histogram { samples: h.ring.iter().copied().collect() }
    }

    /// Percentile over the samples recorded since total-count watermark
    /// `from`, computed under the histogram's own lock. Returns
    /// `(percentile, total)` so the caller carries `total` forward as
    /// its next window start (the load monitor's refresh path). If the
    /// window start has already been evicted from the ring, the
    /// retained suffix is used — the window can only get *more* recent,
    /// never resurrect old samples.
    pub fn tail_percentile(&self, from: usize, p: f64) -> (f64, usize) {
        let h = self.0.lock().unwrap();
        let start_total = h.total - h.ring.len();
        let skip = from.saturating_sub(start_total);
        if skip >= h.ring.len() {
            return (0.0, h.total);
        }
        let window: Vec<f64> = h.ring.iter().skip(skip).copied().collect();
        (stats::percentile(&window, p), h.total)
    }
}

/// Per-tenant serving counters: what one tenant was admitted, shed and
/// charged for, plus its own queue-wait distribution. One entry per
/// tenant in a [`TenantRegistry`]; the cloud server records
/// admit/shed/bytes on the connection worker and the batch engine
/// records each request's queue wait under the requester's tenant, so
/// the stats endpoint can report fairness per tenant, not just in
/// aggregate.
#[derive(Debug, Default)]
pub struct TenantCounters {
    pub admitted: AtomicU64,
    pub sheds: AtomicU64,
    pub bytes: AtomicU64,
    /// Admitted requests served from the logits cache (a subset of
    /// `admitted`): the accounting FairAdmission discounts, surfaced
    /// per tenant so a hot-key tenant's cheap traffic is visible.
    pub cache_hits: AtomicU64,
    /// Seconds from enqueue to execution start for this tenant's
    /// requests (bounded ring, same retention as the global histogram).
    pub queue_wait: SharedHistogram,
}

impl TenantCounters {
    pub fn inc_admitted(&self) {
        self.admitted.fetch_add(1, Ordering::Relaxed);
    }
    pub fn inc_sheds(&self) {
        self.sheds.fetch_add(1, Ordering::Relaxed);
    }
    pub fn add_bytes(&self, n: u64) {
        self.bytes.fetch_add(n, Ordering::Relaxed);
    }
    pub fn inc_cache_hits(&self) {
        self.cache_hits.fetch_add(1, Ordering::Relaxed);
    }
    pub fn cache_hits(&self) -> u64 {
        self.cache_hits.load(Ordering::Relaxed)
    }
    /// (admitted, sheds, bytes).
    pub fn snapshot(&self) -> (u64, u64, u64) {
        (
            self.admitted.load(Ordering::Relaxed),
            self.sheds.load(Ordering::Relaxed),
            self.bytes.load(Ordering::Relaxed),
        )
    }
}

/// Registry of per-tenant counters keyed by the server's internal
/// tenant id (explicit wire tenants and implicit per-connection
/// tenants live in disjoint key ranges). Lookups clone an `Arc` out
/// under a mutex held only for a map probe; the cloud server memoizes
/// its connection's entry (one u64 compare per request while the
/// tenant is stable), and the batch engine's per-request probe is no
/// heavier than the shared queue-wait histogram lock it records into.
#[derive(Debug, Default)]
pub struct TenantRegistry {
    tenants: Mutex<std::collections::BTreeMap<u64, std::sync::Arc<TenantCounters>>>,
}

impl TenantRegistry {
    pub fn get(&self, tenant: u64) -> std::sync::Arc<TenantCounters> {
        std::sync::Arc::clone(
            self.tenants.lock().unwrap().entry(tenant).or_default(),
        )
    }

    /// All tenants seen so far, in key order (stable stats output).
    pub fn snapshot(&self) -> Vec<(u64, std::sync::Arc<TenantCounters>)> {
        self.tenants
            .lock()
            .unwrap()
            .iter()
            .map(|(k, v)| (*k, std::sync::Arc::clone(v)))
            .collect()
    }

    pub fn len(&self) -> usize {
        self.tenants.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Micro-batch scheduler telemetry: how full batches run, how many
/// requests bypassed the queue, and how long batched requests waited
/// between enqueue and execution start. Occupancy (mean/max batch
/// size) is the direct measure of whether the gather window is earning
/// its latency; queue-wait is that latency.
#[derive(Debug, Default)]
pub struct BatchMetrics {
    pub batches: AtomicU64,
    pub batched_requests: AtomicU64,
    pub bypassed: AtomicU64,
    pub max_occupancy: AtomicU64,
    /// Seconds from enqueue to batch execution start, per batched
    /// request.
    pub queue_wait: SharedHistogram,
    /// Gauge: the adaptive gather window the last batch leader used,
    /// microseconds (equals the configured window when adaptation is
    /// off).
    pub gather_window_us: AtomicU64,
    /// Batches whose gather was cut short because a member's deadline
    /// would have expired inside the window (the deadline-ordered
    /// queue doing its job).
    pub deadline_clamped: AtomicU64,
    /// Joins refused by the tenant-aware dequeue because the tenant
    /// had already taken its share of the open batch's slots (the
    /// refused request starts its own batch instead of waiting).
    pub tenant_capped: AtomicU64,
    /// Executed batches that mixed requests from ≥2 distinct models —
    /// the cross-model (signature-keyed) coalescing actually earning
    /// its keep on heterogeneous-fleet traffic.
    pub xmodel_batches: AtomicU64,
    /// Batch members whose leading activation was smaller than their
    /// batch's padded leading geometry (pad-and-stack members).
    pub padded_samples: AtomicU64,
    /// Leading-geometry elements the pad-and-stack path stacked
    /// (`B × max_lead`, summed over padded batches)…
    pub pad_stacked_elems: AtomicU64,
    /// …and the subset of those that were padding. The ratio is the
    /// pad-waste gauge ([`BatchMetrics::pad_waste`]); the engine's
    /// `pad_waste_max` budget bounds it per batch, so the cumulative
    /// gauge can never exceed the budget either.
    pub pad_wasted_elems: AtomicU64,
}

impl BatchMetrics {
    pub fn record_batch(&self, occupancy: usize) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.batched_requests.fetch_add(occupancy as u64, Ordering::Relaxed);
        self.max_occupancy.fetch_max(occupancy as u64, Ordering::Relaxed);
    }

    pub fn record_bypass(&self) {
        self.bypassed.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_gather_window(&self, window: std::time::Duration) {
        self.gather_window_us.store(window.as_micros() as u64, Ordering::Relaxed);
    }

    pub fn record_deadline_clamp(&self) {
        self.deadline_clamped.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_tenant_cap(&self) {
        self.tenant_capped.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_xmodel_batch(&self) {
        self.xmodel_batches.fetch_add(1, Ordering::Relaxed);
    }

    /// Account one padded batch: `padded` members rode a padded slot,
    /// `wasted` of the `stacked` leading elements were padding.
    pub fn record_padding(&self, padded: u64, wasted: u64, stacked: u64) {
        self.padded_samples.fetch_add(padded, Ordering::Relaxed);
        self.pad_wasted_elems.fetch_add(wasted, Ordering::Relaxed);
        self.pad_stacked_elems.fetch_add(stacked, Ordering::Relaxed);
    }

    /// Cumulative pad-waste fraction over padded batches: wasted /
    /// stacked leading elements (0 when nothing ever padded).
    pub fn pad_waste(&self) -> f64 {
        let stacked = self.pad_stacked_elems.load(Ordering::Relaxed);
        if stacked == 0 {
            0.0
        } else {
            self.pad_wasted_elems.load(Ordering::Relaxed) as f64 / stacked as f64
        }
    }

    /// Mean requests per executed batch (0 when none ran).
    pub fn mean_occupancy(&self) -> f64 {
        let b = self.batches.load(Ordering::Relaxed);
        if b == 0 {
            0.0
        } else {
            self.batched_requests.load(Ordering::Relaxed) as f64 / b as f64
        }
    }

    /// (batches, batched_requests, bypassed, max_occupancy).
    pub fn snapshot(&self) -> (u64, u64, u64, u64) {
        (
            self.batches.load(Ordering::Relaxed),
            self.batched_requests.load(Ordering::Relaxed),
            self.bypassed.load(Ordering::Relaxed),
            self.max_occupancy.load(Ordering::Relaxed),
        )
    }
}

/// Logits-cache observables (see `server::cache::LogitsCache`). All
/// relaxed atomics bumped from connection workers; `snapshot` is the
/// stats-endpoint view. The taxonomy: a request that consults the
/// cache is exactly one of `hits` or `misses`; `inflight_coalesced`
/// counts requests that additionally *parked* behind an identical
/// in-flight miss (their eventual retrieval is counted in `hits`), so
/// coalesced ≤ hits and hits + misses = cache-consulting requests.
#[derive(Debug, Default)]
pub struct CacheMetrics {
    /// Requests answered from the cache (decode, dequantize and the
    /// executor all skipped).
    pub hits: AtomicU64,
    /// Requests that had to execute the tail (and, on success,
    /// published their logits).
    pub misses: AtomicU64,
    /// Requests that parked behind an identical in-flight miss instead
    /// of executing their own tail.
    pub inflight_coalesced: AtomicU64,
    /// Entries evicted to respect the byte budget.
    pub evictions: AtomicU64,
    /// Request payload bytes whose decode + execute was skipped
    /// (summed frame bytes of `hits`).
    pub bytes_saved: AtomicU64,
    /// Logits bytes served out of the cache (entry size × hits).
    pub hit_bytes: AtomicU64,
}

/// Point-in-time copy of [`CacheMetrics`] plus occupancy gauges.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub inflight_coalesced: u64,
    pub evictions: u64,
    pub bytes_saved: u64,
    pub hit_bytes: u64,
    /// Live entries across every segment.
    pub entries: u64,
    /// Charged bytes across every segment (≤ the configured budget).
    pub bytes: u64,
}

impl CacheMetrics {
    pub fn record_hit(&self, req_bytes: u64, logits_bytes: u64) {
        self.hits.fetch_add(1, Ordering::Relaxed);
        self.bytes_saved.fetch_add(req_bytes, Ordering::Relaxed);
        self.hit_bytes.fetch_add(logits_bytes, Ordering::Relaxed);
    }
    pub fn record_miss(&self) {
        self.misses.fetch_add(1, Ordering::Relaxed);
    }
    pub fn record_coalesced(&self) {
        self.inflight_coalesced.fetch_add(1, Ordering::Relaxed);
    }
    pub fn record_eviction(&self) {
        self.evictions.fetch_add(1, Ordering::Relaxed);
    }
    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }
    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }
    pub fn coalesced(&self) -> u64 {
        self.inflight_coalesced.load(Ordering::Relaxed)
    }
    /// Counter snapshot with the occupancy gauges supplied by the
    /// owning cache (the metrics struct itself has no segment view).
    pub fn snapshot(&self, entries: u64, bytes: u64) -> CacheStats {
        CacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            inflight_coalesced: self.inflight_coalesced.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
            bytes_saved: self.bytes_saved.load(Ordering::Relaxed),
            hit_bytes: self.hit_bytes.load(Ordering::Relaxed),
            entries,
            bytes,
        }
    }
}

/// Monotonic events-per-second meter (requests, bytes) for serving
/// throughput reporting.
#[derive(Debug)]
pub struct Throughput {
    started: Instant,
    events: AtomicU64,
}

impl Default for Throughput {
    fn default() -> Self {
        Self::new()
    }
}

impl Throughput {
    pub fn new() -> Self {
        Self { started: Instant::now(), events: AtomicU64::new(0) }
    }

    pub fn observe(&self, n: u64) {
        self.events.fetch_add(n, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.events.load(Ordering::Relaxed)
    }

    pub fn per_second(&self) -> f64 {
        let dt = self.started.elapsed().as_secs_f64().max(1e-9);
        self.count() as f64 / dt
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn breakdown_total_sums_components() {
        let b = Breakdown {
            edge_compute: 0.01,
            quantize: 0.002,
            encode: 0.003,
            transmit: 0.1,
            decode: 0.001,
            dequantize: 0.002,
            cloud_compute: 0.005,
            tx_bytes: 123,
        };
        assert!((b.total() - 0.123).abs() < 1e-12);
        assert!(b.summary().contains("123 B"));
    }

    #[test]
    fn histogram_percentiles() {
        let mut h = Histogram::new();
        for i in 1..=100 {
            h.record(i as f64);
        }
        assert_eq!(h.len(), 100);
        assert!((h.mean() - 50.5).abs() < 1e-9);
        assert!((h.percentile(50.0) - 50.5).abs() < 1.0);
        assert!(h.percentile(99.0) > 98.0);
    }

    #[test]
    fn shared_histogram_records_concurrently() {
        let h = std::sync::Arc::new(SharedHistogram::default());
        let workers: Vec<_> = (0..4)
            .map(|t| {
                let h = std::sync::Arc::clone(&h);
                std::thread::spawn(move || {
                    for i in 0..100 {
                        h.record((t * 100 + i) as f64);
                    }
                })
            })
            .collect();
        for w in workers {
            w.join().unwrap();
        }
        assert_eq!(h.snapshot().len(), 400);
    }

    #[test]
    fn throughput_counts_events() {
        let t = Throughput::new();
        t.observe(10);
        t.observe(5);
        assert_eq!(t.count(), 15);
        assert!(t.per_second() > 0.0);
    }

    #[test]
    fn counter_taxonomy_is_disjoint() {
        let c = Counters::default();
        c.inc_requests();
        c.add_bytes(100);
        c.inc_control();
        c.inc_control();
        c.add_probe_bytes(1 << 20);
        c.inc_malformed();
        let (req, err, bytes, _) = c.snapshot();
        assert_eq!((req, err, bytes), (1, 0, 100), "probe/control must not leak into data");
        assert_eq!(c.control(), 2);
        assert_eq!(c.probe(), 1 << 20);
        assert_eq!(c.malformed_count(), 1);
    }

    #[test]
    fn batch_metrics_track_occupancy() {
        let m = BatchMetrics::default();
        assert_eq!(m.mean_occupancy(), 0.0);
        m.record_batch(4);
        m.record_batch(2);
        m.record_bypass();
        m.queue_wait.record(0.001);
        let (batches, reqs, bypassed, max) = m.snapshot();
        assert_eq!((batches, reqs, bypassed, max), (2, 6, 1, 4));
        assert!((m.mean_occupancy() - 3.0).abs() < 1e-12);
        assert_eq!(m.queue_wait.snapshot().len(), 1);
    }

    #[test]
    fn tail_percentile_windows_the_histogram() {
        let mut h = Histogram::new();
        for i in 1..=100 {
            h.record(i as f64);
        }
        // Window = last 10 samples (91..=100): p95 sits near the top.
        assert!(h.tail_percentile(90, 95.0) > 98.0);
        // Full-history percentile is much lower — the window matters.
        assert!(h.percentile(95.0) < 97.0);
        assert_eq!(h.tail_percentile(100, 95.0), 0.0, "empty window is 0");
        assert_eq!(h.tail_percentile(500, 50.0), 0.0, "past-the-end window is 0");
        // The shared (lock-side, clone-free) variant agrees and
        // reports the total length for the next window start.
        let sh = SharedHistogram::default();
        for i in 1..=100 {
            sh.record(i as f64);
        }
        let (p, n) = sh.tail_percentile(90, 95.0);
        assert_eq!(n, 100);
        assert!(p > 98.0);
    }

    #[test]
    fn shared_histogram_is_bounded_and_window_survives_eviction() {
        let sh = SharedHistogram::default();
        // Overfill by half a capacity: retention must cap and keep the
        // *newest* samples.
        let n = SHARED_HISTOGRAM_CAP + SHARED_HISTOGRAM_CAP / 2;
        for i in 0..n {
            sh.record(i as f64);
        }
        let snap = sh.snapshot();
        assert_eq!(snap.len(), SHARED_HISTOGRAM_CAP, "retention must cap");
        assert_eq!(snap.percentile(100.0), (n - 1) as f64, "newest survive");
        assert_eq!(snap.percentile(0.0), (n - SHARED_HISTOGRAM_CAP) as f64, "oldest evicted");
        // A window whose start was evicted degrades to the retained
        // suffix instead of resurrecting stale data or panicking.
        let (p, total) = sh.tail_percentile(10, 0.0);
        assert_eq!(total, n);
        assert_eq!(p, (n - SHARED_HISTOGRAM_CAP) as f64);
        // A fully-evicted window (start beyond total) reports 0.
        assert_eq!(sh.tail_percentile(n + 5, 50.0).0, 0.0);
        // A recent window reads the true tail.
        let (p, _) = sh.tail_percentile(n - 10, 0.0);
        assert_eq!(p, (n - 10) as f64);
    }

    #[test]
    fn shed_counter_and_gather_gauge() {
        let c = Counters::default();
        c.inc_sheds();
        c.inc_sheds();
        assert_eq!(c.sheds(), 2);
        let m = BatchMetrics::default();
        m.record_gather_window(std::time::Duration::from_micros(250));
        assert_eq!(m.gather_window_us.load(Ordering::Relaxed), 250);
        m.record_deadline_clamp();
        assert_eq!(m.deadline_clamped.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn xmodel_and_padding_counters() {
        let m = BatchMetrics::default();
        assert_eq!(m.pad_waste(), 0.0, "no padding yet");
        m.record_xmodel_batch();
        // A 4-slot batch padded to 2048-elem leads holding two
        // 1152-elem members: 2 padded samples, 1792 of 8192 wasted.
        m.record_padding(2, 1792, 8192);
        assert_eq!(m.xmodel_batches.load(Ordering::Relaxed), 1);
        assert_eq!(m.padded_samples.load(Ordering::Relaxed), 2);
        assert!((m.pad_waste() - 1792.0 / 8192.0).abs() < 1e-12);
        // A second padded batch accumulates into the same gauge.
        m.record_padding(1, 896, 4096);
        assert!((m.pad_waste() - (1792.0 + 896.0) / (8192.0 + 4096.0)).abs() < 1e-12);
    }

    #[test]
    fn tenant_registry_tracks_per_tenant_counters() {
        let reg = TenantRegistry::default();
        assert!(reg.is_empty());
        let a = reg.get(1);
        a.inc_admitted();
        a.add_bytes(100);
        a.queue_wait.record(0.002);
        let b = reg.get(2);
        b.inc_sheds();
        // Same key returns the same entry (counters accumulate).
        reg.get(1).inc_admitted();
        assert_eq!(reg.len(), 2);
        let snap = reg.snapshot();
        assert_eq!(snap.len(), 2);
        assert_eq!(snap[0].0, 1);
        assert_eq!(snap[0].1.snapshot(), (2, 0, 100));
        assert_eq!(snap[1].1.snapshot(), (0, 1, 0));
        assert_eq!(snap[0].1.queue_wait.snapshot().len(), 1);
        // Concurrent get/record on one key never loses counts.
        let reg = std::sync::Arc::new(TenantRegistry::default());
        let hs: Vec<_> = (0..4)
            .map(|_| {
                let reg = std::sync::Arc::clone(&reg);
                std::thread::spawn(move || {
                    for _ in 0..500 {
                        reg.get(9).inc_admitted();
                    }
                })
            })
            .collect();
        for h in hs {
            h.join().unwrap();
        }
        assert_eq!(reg.get(9).snapshot().0, 2000);
    }

    #[test]
    fn cache_metrics_taxonomy() {
        let m = CacheMetrics::default();
        m.record_miss();
        m.record_coalesced();
        m.record_hit(512, 40);
        m.record_hit(512, 40);
        m.record_eviction();
        let s = m.snapshot(3, 1234);
        assert_eq!((s.hits, s.misses, s.inflight_coalesced, s.evictions), (2, 1, 1, 1));
        assert_eq!(s.bytes_saved, 1024);
        assert_eq!(s.hit_bytes, 80);
        assert_eq!((s.entries, s.bytes), (3, 1234));
        assert!(s.inflight_coalesced <= s.hits, "coalesced parks resolve as hits");
    }

    #[test]
    fn counters_are_threadsafe() {
        let c = std::sync::Arc::new(Counters::default());
        let hs: Vec<_> = (0..4)
            .map(|_| {
                let c = std::sync::Arc::clone(&c);
                std::thread::spawn(move || {
                    for _ in 0..1000 {
                        c.inc_requests();
                        c.add_bytes(10);
                    }
                })
            })
            .collect();
        for h in hs {
            h.join().unwrap();
        }
        let (req, _, bytes, _) = c.snapshot();
        assert_eq!(req, 4000);
        assert_eq!(bytes, 40_000);
    }
}
