//! Circuit breaker guarding the edge → cloud path.
//!
//! Classic three-state machine, deterministic and clock-injectable so
//! the transition table is unit-testable without sleeping:
//!
//! * **Closed** — requests flow to the cloud. Consecutive transport
//!   failures (io errors, timeouts, malformed replies) or per-request
//!   deadline overruns increment a strike counter; at
//!   `failure_threshold` the breaker opens.
//! * **Open** — the cloud path is skipped entirely (the edge serves
//!   full-local at the `i=N` cut). After `cooldown` the next
//!   `should_attempt` admits exactly one probe request (half-open).
//! * **Half-open** — probe outcomes decide: `probe_successes`
//!   consecutive successes reclose; any failure reopens and restarts
//!   the cooldown.
//!
//! A success in Closed resets the strike counter. Time is passed in by
//! the caller (`Instant::now()` in production, a scripted clock in
//! tests), so there is no hidden global state.

use std::time::{Duration, Instant};

use crate::util::rng::XorShift64Star;

/// Per-process seed counter so concurrently-built breakers draw
/// independent jitter streams (golden-ratio stride spreads the seeds).
static BREAKER_SEED: std::sync::atomic::AtomicU64 =
    std::sync::atomic::AtomicU64::new(0xC2B2_AE3D_27D4_EB4F);

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BreakerState {
    Closed,
    Open,
    HalfOpen,
}

#[derive(Debug, Clone, Copy)]
pub struct BreakerConfig {
    /// Consecutive failures in Closed that trip the breaker.
    pub failure_threshold: u32,
    /// How long Open lasts before a half-open probe is admitted.
    pub cooldown: Duration,
    /// Consecutive half-open successes required to reclose.
    pub probe_successes: u32,
    /// Multiplicative jitter on each Open cooldown, as a fraction: a
    /// trip at jitter `j` draws its cooldown uniformly from
    /// `cooldown × (1±j)`. A fleet of edges tripped by the same cloud
    /// outage would otherwise all probe in the same instant and
    /// re-create the overload they are backing off from. 0 disables
    /// (exact cooldowns — what the deterministic tests use).
    pub cooldown_jitter: f64,
}

impl Default for BreakerConfig {
    fn default() -> Self {
        Self {
            failure_threshold: 3,
            cooldown: Duration::from_secs(1),
            probe_successes: 1,
            cooldown_jitter: 0.5,
        }
    }
}

#[derive(Debug)]
pub struct CircuitBreaker {
    cfg: BreakerConfig,
    state: BreakerState,
    strikes: u32,
    probe_ok: u32,
    opened_at: Option<Instant>,
    /// The jittered cooldown drawn at the most recent trip (equals
    /// `cfg.cooldown` exactly when `cooldown_jitter` is 0).
    current_cooldown: Duration,
    /// Private jitter stream; never consulted when jitter is 0, so
    /// zero-jitter breakers stay bit-deterministic.
    jitter: XorShift64Star,
    /// True while the single half-open probe slot is checked out.
    probe_inflight: bool,
    // Lifetime counters for stats.
    opened: u64,
    half_opens: u64,
    reclosed: u64,
    overruns: u64,
}

impl CircuitBreaker {
    pub fn new(cfg: BreakerConfig) -> Self {
        let seed = BREAKER_SEED
            .fetch_add(0x9E37_79B9_7F4A_7C15, std::sync::atomic::Ordering::Relaxed);
        Self {
            cfg: BreakerConfig {
                failure_threshold: cfg.failure_threshold.max(1),
                cooldown: cfg.cooldown,
                probe_successes: cfg.probe_successes.max(1),
                cooldown_jitter: cfg.cooldown_jitter.clamp(0.0, 1.0),
            },
            state: BreakerState::Closed,
            strikes: 0,
            probe_ok: 0,
            opened_at: None,
            current_cooldown: cfg.cooldown,
            jitter: XorShift64Star::new(seed),
            probe_inflight: false,
            opened: 0,
            half_opens: 0,
            reclosed: 0,
            overruns: 0,
        }
    }

    pub fn state(&self) -> BreakerState {
        self.state
    }

    pub fn config(&self) -> BreakerConfig {
        self.cfg
    }

    /// May the caller attempt the cloud path right now?
    ///
    /// Closed → always. Open → only once the cooldown has elapsed, and
    /// then only one probe at a time (the slot is released by the
    /// probe's `record_success`/`record_failure`). The transition to
    /// HalfOpen happens here, when the probe is admitted.
    pub fn should_attempt(&mut self, now: Instant) -> bool {
        match self.state {
            BreakerState::Closed => true,
            BreakerState::HalfOpen => {
                if self.probe_inflight {
                    false
                } else {
                    self.probe_inflight = true;
                    true
                }
            }
            BreakerState::Open => {
                let due = self
                    .opened_at
                    .map(|t| now.duration_since(t) >= self.current_cooldown)
                    .unwrap_or(true);
                if due {
                    self.state = BreakerState::HalfOpen;
                    self.half_opens += 1;
                    self.probe_ok = 0;
                    self.probe_inflight = true;
                    true
                } else {
                    false
                }
            }
        }
    }

    /// Record a successful cloud round-trip (made after `should_attempt`
    /// returned true). Returns true when this success reclosed the
    /// breaker — the caller's cue to walk the cut back cloud-ward.
    pub fn record_success(&mut self, _now: Instant) -> bool {
        match self.state {
            BreakerState::Closed => {
                self.strikes = 0;
                false
            }
            BreakerState::HalfOpen => {
                self.probe_inflight = false;
                self.probe_ok += 1;
                if self.probe_ok >= self.cfg.probe_successes {
                    self.state = BreakerState::Closed;
                    self.strikes = 0;
                    self.opened_at = None;
                    self.reclosed += 1;
                    true
                } else {
                    false
                }
            }
            // A success can't arrive in Open: should_attempt refused.
            BreakerState::Open => false,
        }
    }

    /// Record a failed cloud round-trip. Returns true when this failure
    /// opened (or reopened) the breaker.
    pub fn record_failure(&mut self, now: Instant) -> bool {
        match self.state {
            BreakerState::Closed => {
                self.strikes += 1;
                if self.strikes >= self.cfg.failure_threshold {
                    self.trip(now);
                    true
                } else {
                    false
                }
            }
            BreakerState::HalfOpen => {
                self.probe_inflight = false;
                self.trip(now);
                true
            }
            BreakerState::Open => false,
        }
    }

    /// Record that a request exceeded its deadline. Counts as a failure
    /// *and* is tracked separately (deadline overruns are the breaker's
    /// reason to exist — a hung cloud produces only these).
    pub fn record_overrun(&mut self, now: Instant) -> bool {
        self.overruns += 1;
        self.record_failure(now)
    }

    fn trip(&mut self, now: Instant) {
        self.state = BreakerState::Open;
        self.opened_at = Some(now);
        // Each trip draws a fresh jittered cooldown in
        // `cooldown × (1±jitter)`: edges tripped together probe apart.
        self.current_cooldown = if self.cfg.cooldown_jitter > 0.0 {
            let spread = self.cfg.cooldown_jitter * (2.0 * self.jitter.next_f64() - 1.0);
            self.cfg.cooldown.mul_f64((1.0 + spread).max(0.0))
        } else {
            self.cfg.cooldown
        };
        self.strikes = 0;
        self.probe_ok = 0;
        self.opened += 1;
    }

    /// The cooldown drawn at the most recent trip (jitter included).
    pub fn current_cooldown(&self) -> Duration {
        self.current_cooldown
    }

    pub fn opened_count(&self) -> u64 {
        self.opened
    }

    pub fn half_open_count(&self) -> u64 {
        self.half_opens
    }

    pub fn reclosed_count(&self) -> u64 {
        self.reclosed
    }

    pub fn overrun_count(&self) -> u64 {
        self.overruns
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk(threshold: u32, cooldown_ms: u64, probes: u32) -> CircuitBreaker {
        // Jitter 0: these tests assert exact cooldown boundaries.
        CircuitBreaker::new(BreakerConfig {
            failure_threshold: threshold,
            cooldown: Duration::from_millis(cooldown_ms),
            probe_successes: probes,
            cooldown_jitter: 0.0,
        })
    }

    #[test]
    fn transition_table() {
        let t0 = Instant::now();
        let mut b = mk(3, 100, 1);
        assert_eq!(b.state(), BreakerState::Closed);

        // Two failures: still closed (threshold 3).
        assert!(!b.record_failure(t0));
        assert!(!b.record_failure(t0));
        assert_eq!(b.state(), BreakerState::Closed);

        // A success resets the strike counter.
        b.record_success(t0);
        assert!(!b.record_failure(t0));
        assert!(!b.record_failure(t0));
        assert_eq!(b.state(), BreakerState::Closed);

        // Third consecutive failure trips it.
        assert!(b.record_failure(t0));
        assert_eq!(b.state(), BreakerState::Open);
        assert_eq!(b.opened_count(), 1);

        // Open: attempts refused until the cooldown elapses.
        assert!(!b.should_attempt(t0 + Duration::from_millis(50)));
        assert_eq!(b.state(), BreakerState::Open);

        // Cooldown elapsed: one probe admitted, state is HalfOpen.
        let t1 = t0 + Duration::from_millis(100);
        assert!(b.should_attempt(t1));
        assert_eq!(b.state(), BreakerState::HalfOpen);
        assert_eq!(b.half_open_count(), 1);

        // Probe success recloses.
        assert!(b.record_success(t1));
        assert_eq!(b.state(), BreakerState::Closed);
        assert_eq!(b.reclosed_count(), 1);
    }

    #[test]
    fn half_open_failure_reopens_and_restarts_cooldown() {
        let t0 = Instant::now();
        let mut b = mk(1, 100, 1);
        assert!(b.record_failure(t0));
        let t1 = t0 + Duration::from_millis(100);
        assert!(b.should_attempt(t1));
        assert!(b.record_failure(t1)); // probe failed → reopen
        assert_eq!(b.state(), BreakerState::Open);
        assert_eq!(b.opened_count(), 2);
        // The cooldown restarted at t1, not t0.
        assert!(!b.should_attempt(t1 + Duration::from_millis(99)));
        assert!(b.should_attempt(t1 + Duration::from_millis(100)));
    }

    #[test]
    fn probe_pacing_single_slot() {
        let t0 = Instant::now();
        let mut b = mk(1, 0, 1);
        b.record_failure(t0);
        assert!(b.should_attempt(t0)); // cooldown 0 → immediate probe
        // While the probe is in flight, no second attempt is admitted.
        assert!(!b.should_attempt(t0));
        assert!(!b.should_attempt(t0 + Duration::from_secs(10)));
        // Probe resolves → slot released.
        b.record_success(t0);
        assert_eq!(b.state(), BreakerState::Closed);
        assert!(b.should_attempt(t0));
    }

    #[test]
    fn multi_probe_reclose() {
        let t0 = Instant::now();
        let mut b = mk(1, 0, 2);
        b.record_failure(t0);
        assert!(b.should_attempt(t0));
        assert!(!b.record_success(t0)); // 1/2 — still half-open
        assert_eq!(b.state(), BreakerState::HalfOpen);
        assert!(b.should_attempt(t0)); // next probe admitted
        assert!(b.record_success(t0)); // 2/2 — reclosed
        assert_eq!(b.state(), BreakerState::Closed);
    }

    #[test]
    fn overruns_count_separately_and_trip() {
        let t0 = Instant::now();
        let mut b = mk(2, 100, 1);
        assert!(!b.record_overrun(t0));
        assert!(b.record_overrun(t0));
        assert_eq!(b.state(), BreakerState::Open);
        assert_eq!(b.overrun_count(), 2);
        assert_eq!(b.opened_count(), 1);
    }

    #[test]
    fn closed_success_is_cheap_noop() {
        let t0 = Instant::now();
        let mut b = mk(3, 100, 1);
        for _ in 0..10 {
            assert!(b.should_attempt(t0));
            assert!(!b.record_success(t0));
        }
        assert_eq!(b.opened_count(), 0);
    }

    #[test]
    fn jittered_cooldowns_spread_within_the_band() {
        let mut b = CircuitBreaker::new(BreakerConfig {
            failure_threshold: 1,
            cooldown: Duration::from_millis(1000),
            probe_successes: 1,
            cooldown_jitter: 0.5,
        });
        let t0 = Instant::now();
        let mut seen = Vec::new();
        let mut t = t0;
        for _ in 0..50 {
            assert!(b.record_failure(t), "threshold 1 trips on every failure");
            let cd = b.current_cooldown();
            assert!(
                cd > Duration::from_millis(500) && cd <= Duration::from_millis(1500),
                "jittered cooldown {cd:?} escaped the ±50% band"
            );
            seen.push(cd);
            // Walk past the drawn cooldown so the probe is admitted,
            // then fail it to re-trip with a fresh draw.
            t += cd;
            assert!(b.should_attempt(t));
        }
        let min = seen.iter().min().unwrap();
        let max = seen.iter().max().unwrap();
        assert!(
            *max > *min,
            "50 trips drew identical cooldowns — jitter is not being applied"
        );
        // And the spread is real, not one-nanosecond noise: the band is
        // 1000 ms wide, 50 uniform draws should cover most of it.
        assert!(
            *max - *min > Duration::from_millis(300),
            "jitter spread {:?} is implausibly narrow",
            *max - *min
        );
    }

    #[test]
    fn zero_jitter_is_exact() {
        let mut b = mk(1, 100, 1);
        let t0 = Instant::now();
        for _ in 0..5 {
            assert!(b.record_failure(t0));
            assert_eq!(b.current_cooldown(), Duration::from_millis(100));
            assert!(b.should_attempt(t0 + Duration::from_millis(100)));
        }
    }

    #[test]
    fn zero_thresholds_are_clamped() {
        let t0 = Instant::now();
        let mut b = mk(0, 0, 0);
        assert!(b.record_failure(t0), "threshold clamps to 1");
        assert!(b.should_attempt(t0));
        assert!(b.record_success(t0), "probe count clamps to 1");
    }
}
