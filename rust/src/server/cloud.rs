//! The cloud server: one thread per connection, PJRT-backed inference.
//!
//! Handles two request kinds:
//! * `Features` — the decoupled path: decode the wire frame (its header
//!   names model + stage + c), dequantize through the L1 artifact, run
//!   stages `i*+1..N`, reply with logits;
//! * `Image` — the cloud-only path: decode the PNG-like image, run the
//!   full model.
//!
//! The wire frame being self-describing is what lets the edge
//! re-decouple unilaterally — the "synchronize" step of §III-E costs
//! nothing here.

use std::io::BufReader;
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use anyhow::{anyhow, Result};

use crate::compression::{feature, png, quant};
use crate::metrics::Counters;
use crate::runtime::{Manifest, SharedExecutor};
use crate::server::proto::Frame;
use crate::util::json::Json;

pub struct CloudServer {
    exe: Arc<SharedExecutor>,
    manifest: Manifest,
    pub counters: Arc<Counters>,
    stop: Arc<AtomicBool>,
}

impl CloudServer {
    pub fn new(exe: Arc<SharedExecutor>) -> Self {
        let manifest = exe.manifest_clone();
        Self {
            exe,
            manifest,
            counters: Arc::new(Counters::default()),
            stop: Arc::new(AtomicBool::new(false)),
        }
    }

    /// Bind and serve on a background thread; returns the local address
    /// and a join handle. `addr` like "127.0.0.1:0" picks a free port.
    pub fn spawn(self: Arc<Self>, addr: &str) -> Result<(std::net::SocketAddr, std::thread::JoinHandle<()>)> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        let me = Arc::clone(&self);
        let handle = std::thread::spawn(move || {
            for conn in listener.incoming() {
                if me.stop.load(Ordering::Relaxed) {
                    break;
                }
                match conn {
                    Ok(stream) => {
                        let me2 = Arc::clone(&me);
                        std::thread::spawn(move || {
                            if let Err(e) = me2.serve_conn(stream) {
                                crate::log_debug!("cloud", "connection ended: {e:#}");
                            }
                        });
                    }
                    Err(e) => {
                        crate::log_warn!("cloud", "accept error: {e}");
                    }
                }
            }
        });
        Ok((local, handle))
    }

    fn serve_conn(&self, stream: TcpStream) -> Result<()> {
        stream.set_nodelay(true).ok();
        let mut writer = stream.try_clone()?;
        let mut reader = BufReader::new(stream);
        loop {
            let frame = match Frame::read_from(&mut reader) {
                Ok(f) => f,
                Err(_) => return Ok(()), // peer closed
            };
            match frame {
                Frame::Features(bytes) => {
                    self.counters.inc_requests();
                    self.counters.add_bytes(bytes.len() as u64);
                    match self.handle_features(&bytes) {
                        Ok(logits) => Frame::Logits(logits).write_to(&mut writer)?,
                        Err(e) => {
                            self.counters.inc_errors();
                            Frame::Error(format!("{e:#}")).write_to(&mut writer)?
                        }
                    };
                }
                Frame::Image { model_id, hw: _, png } => {
                    self.counters.inc_requests();
                    self.counters.add_bytes(png.len() as u64);
                    match self.handle_image(model_id, &png) {
                        Ok(logits) => Frame::Logits(logits).write_to(&mut writer)?,
                        Err(e) => {
                            self.counters.inc_errors();
                            Frame::Error(format!("{e:#}")).write_to(&mut writer)?
                        }
                    };
                }
                Frame::Stats => {
                    let (req, err, bytes, _) = self.counters.snapshot();
                    let j = Json::obj(vec![
                        ("requests", Json::num(req as f64)),
                        ("errors", Json::num(err as f64)),
                        ("bytes_rx", Json::num(bytes as f64)),
                        ("compiled", Json::num(self.exe.cached_count() as f64)),
                    ]);
                    Frame::StatsReply(j.to_string().into_bytes()).write_to(&mut writer)?;
                }
                Frame::Probe(padding) => {
                    // Bandwidth probe: acknowledge immediately; the edge
                    // times the (throttled) upload of the padding.
                    self.counters.add_bytes(padding.len() as u64);
                    Frame::ProbeAck.write_to(&mut writer)?;
                }
                Frame::Shutdown => {
                    self.stop.store(true, Ordering::Relaxed);
                    // Unblock the accept loop with a dummy connection.
                    return Ok(());
                }
                other => {
                    Frame::Error(format!("unexpected frame {:?}", other.kind()))
                        .write_to(&mut writer)?;
                }
            }
        }
    }

    fn handle_features(&self, bytes: &[u8]) -> Result<Vec<f32>> {
        let frame = feature::decode(bytes).map_err(anyhow::Error::new)?;
        let model = self
            .manifest
            .models
            .get(frame.model as usize)
            .ok_or_else(|| anyhow!("bad model id {}", frame.model))?
            .name
            .clone();
        let m = self.manifest.model(&model)?;
        let i = frame.stage as usize;
        if i == 0 || i > m.num_stages() {
            return Err(anyhow!("bad stage {i}"));
        }
        let out_shape = m.stages[i - 1].out_shape.clone();
        let n = m.num_stages();
        let q = quant::Quantized {
            values: frame.values,
            lo: frame.lo,
            hi: frame.hi,
            c: frame.c,
        };
        // One locked region for the whole tail keeps per-request lock
        // traffic to a single acquisition.
        self.exe.with(|e| {
            let mut cur = e.run_dequant(&q, &out_shape)?;
            for j in i + 1..=n {
                cur = e.run_stage(&model, j, &cur)?.tensor;
            }
            Ok(cur.data().to_vec())
        })
    }

    fn handle_image(&self, model_id: u16, png_bytes: &[u8]) -> Result<Vec<f32>> {
        let model = self
            .manifest
            .models
            .get(model_id as usize)
            .ok_or_else(|| anyhow!("bad model id {model_id}"))?
            .name
            .clone();
        let m = self.manifest.model(&model)?;
        let img = png::decode(png_bytes).map_err(anyhow::Error::new)?;
        let x = crate::data::gen::from_rgb8(&img.data, m.input_shape.clone());
        Ok(self.exe.run_full(&model, &x)?.tensor.data().to_vec())
    }

    /// Ask a running server (possibly in another process) to stop.
    pub fn request_shutdown(addr: std::net::SocketAddr) {
        if let Ok(mut s) = TcpStream::connect(addr) {
            let _ = Frame::Shutdown.write_to(&mut s);
        }
        // One more connect unblocks the accept loop.
        let _ = TcpStream::connect(addr);
    }
}
