//! The cloud server: a `util::threadpool` worker per connection, a
//! sharded + micro-batched inference engine, pooled per-connection
//! scratch.
//!
//! Handles two request kinds:
//! * `Features` — the decoupled path: decode the wire frame (its header
//!   names model + stage + c) into the connection's scratch,
//!   dequantize **natively on the connection worker**
//!   (`quant::dequantize_into` — the executor's critical path never
//!   sees the dequant hop or its staging buffers), then hand the flat
//!   activation to the [`BatchEngine`] which finishes stages
//!   `i*+1..N` and returns the logits;
//! * `Image` — the cloud-only path: decode the PNG-like image, run the
//!   full model on the connection's affinity shard.
//!
//! Concurrency model — two selectable transports over one shared
//! frame-handling core ([`CloudServer::process_frame`], so their
//! observable behavior is identical by construction):
//!
//! * [`IoModel::Threads`] — the accept loop hands each connection to a
//!   fixed [`ThreadPool`]; when every pooled lane is parked on a
//!   long-lived connection, further connections run on dedicated
//!   overflow threads so control traffic (Stats/Shutdown) can never
//!   starve behind data connections;
//! * [`IoModel::Epoll`] (default on Linux) — one reactor thread
//!   multiplexes every connection over nonblocking sockets
//!   ([`server::epoll`](crate::server::epoll)), assembling frames
//!   incrementally and dispatching only *complete* data requests to
//!   the worker pool; the workers do pure compute, never block on a
//!   socket, and the thread count no longer bounds the connection
//!   count — 10K+ idle or slow connections cost one fd + one
//!   assembler each.
//!
//! Either way, past `max_conns` assigned connections the acceptor
//! answers with a `Busy` frame (telemetry attached, `conn_sheds`
//! counted) and closes — admission control at the accept boundary,
//! replacing any unbounded thread growth. Compute is an
//! [`ExecutorPool`] of independently-locked
//! executors — the connection id is the shard affinity — and
//! concurrent signature-compatible tails — across models, when their
//! tail geometries match (pad-and-stack for matching suffixes, within
//! a waste budget) — coalesce in the [`BatchEngine`] (one lock
//! acquisition per batch; lone requests bypass the queue).
//! Counters are atomics with an explicit taxonomy (data requests vs
//! control frames vs malformed input — see [`Counters`]); the
//! service-time and queue-wait histograms sit behind their own
//! mutexes. Every connection checks a
//! [`Scratch`](crate::util::pool::Scratch) out of a shared
//! [`BufPool`], so its codec + proto hops reuse warm buffers, and its
//! float buffer is *lent* through the batch engine and restored with
//! the logits in the same allocation.
//!
//! The wire frame being self-describing is what lets the edge
//! re-decouple unilaterally — the "synchronize" step of §III-E costs
//! nothing here. Malformed frames get an `Error` reply instead of a
//! dropped connection; only an unrecoverable length-prefix violation
//! closes the stream (it can no longer be framed).
//!
//! The server is also the sensor half of the live control plane: a
//! [`LoadMonitor`] samples queue-wait p95 (windowed), busiest-shard
//! utilization and batch occupancy into a [`CloudTelemetry`] block
//! that every logits reply piggybacks, and an [`AdmissionConfig`]
//! turns the same snapshot into shard-aware load shedding — when a
//! budget is exceeded, cuts short of the last stage get a `Busy`
//! refusal (carrying that telemetry) instead of queueing past the
//! SLA, while `i = N` logits-forwards stay admitted so the edge's
//! edge-ward march always terminates at a servable plan.

use std::io::BufReader;
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::compression::feature;
use crate::compression::png;
use crate::compression::quant;
use crate::metrics::{BatchMetrics, Counters, SharedHistogram, TenantCounters, TenantRegistry};
use crate::runtime::{BatchConfig, BatchEngine, ExecutorPool, Manifest, SharedExecutor};
use crate::server::admission::{FairAdmission, FairDecision};
use crate::server::cache::{LeadOrWait, LogitsCache};
use crate::server::proto::{self, CloudTelemetry, RecvFrame};
use crate::util::json::Json;
use crate::util::pool::{BufPool, Scratch};
use crate::util::threadpool::ThreadPool;

/// Default connection-worker count (the pooled serving lanes).
pub const DEFAULT_WORKERS: usize = 16;

/// Default cap on concurrently-assigned connections (the accept
/// guard). Generous — the epoll transport holds an idle connection for
/// one fd + one assembler — but finite, so a connection flood degrades
/// into polite `Busy` refusals instead of fd exhaustion.
pub const DEFAULT_MAX_CONNS: usize = 16 * 1024;

/// Which transport moves bytes between sockets and the frame core.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum IoModel {
    /// Blocking sockets, one (pooled) thread per connection.
    Threads,
    /// One nonblocking reactor thread multiplexes every connection;
    /// the worker pool only runs compute. Linux only.
    Epoll,
}

impl IoModel {
    /// The default for this host: the reactor where the syscalls
    /// exist, the portable thread-per-connection transport elsewhere.
    pub fn default_for_host() -> Self {
        if crate::util::reactor::Reactor::available() {
            IoModel::Epoll
        } else {
            IoModel::Threads
        }
    }

    /// Parse a `--io` CLI value.
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "threads" => Ok(IoModel::Threads),
            "epoll" => Ok(IoModel::Epoll),
            "auto" => Ok(Self::default_for_host()),
            other => Err(anyhow!("unknown io model {other:?} (want threads|epoll|auto)")),
        }
    }
}

/// Shard-aware admission control (§III-E consumed cloud-side): when
/// the compute spine is over budget, new data requests are refused
/// with a `Busy` frame *before* they queue past the latency budget,
/// and the refusal carries the telemetry the edge needs to
/// re-decouple edge-ward. Defaults disable shedding and deadlines —
/// admission is opt-in; telemetry piggybacking is always on (it costs
/// 19 bytes per reply).
#[derive(Debug, Clone, Copy)]
pub struct AdmissionConfig {
    /// Shed when the queue-wait p95 over the current sampling window
    /// exceeds this. `Duration::ZERO` disables the queue budget.
    pub queue_p95_budget: Duration,
    /// Shed when the busiest shard's busy fraction over the sampling
    /// window exceeds this. `INFINITY` disables the utilization
    /// budget.
    pub utilization_budget: f64,
    /// SLA deadline attached to every admitted tail request — the
    /// batch engine's deadline-ordered gather never sleeps past it.
    /// `Duration::ZERO` attaches none.
    pub deadline: Duration,
    /// How stale the sampled telemetry may be before it is recomputed
    /// (sampling touches every shard's counters; 50 ms of staleness is
    /// invisible to the control loop, which reacts over replies).
    pub refresh: Duration,
    /// Per-tenant fair admission: when the global budget trips, shed
    /// by deficit-weighted per-tenant shares
    /// ([`FairAdmission`](crate::server::admission::FairAdmission))
    /// instead of refusing every sheddable request. Also turns on the
    /// batch engine's tenant-aware dequeue. With fewer than two active
    /// tenants the decisions are identical to the global budget — and
    /// `false` (the default) never consults tenants at all.
    pub fair: bool,
    /// Global admitted-rate budget under overload, requests/second,
    /// split across active tenants by water-filling. 0 derives it from
    /// the recently-served rate. Only meaningful with `fair`.
    pub tenant_budget: f64,
}

impl Default for AdmissionConfig {
    fn default() -> Self {
        Self {
            queue_p95_budget: Duration::ZERO,
            utilization_budget: f64::INFINITY,
            deadline: Duration::ZERO,
            refresh: Duration::from_millis(50),
            fair: false,
            tenant_budget: 0.0,
        }
    }
}

impl AdmissionConfig {
    /// Is `t` over either budget?
    fn over_budget(&self, t: &CloudTelemetry) -> bool {
        (self.queue_p95_budget > Duration::ZERO
            && f64::from(t.queue_wait_p95_ms) > self.queue_p95_budget.as_secs_f64() * 1e3)
            || f64::from(t.utilization) > self.utilization_budget
    }
}

/// Serving configuration: transport lanes + compute batching +
/// admission control.
#[derive(Debug, Clone, Copy)]
pub struct ServeConfig {
    /// Pooled connection workers (overflow spawns dedicated threads).
    pub workers: usize,
    /// Micro-batch scheduler knobs (shard count comes from the pool).
    pub batch: BatchConfig,
    /// Load shedding + deadline + telemetry sampling knobs.
    pub admission: AdmissionConfig,
    /// Pin each connection worker to the core its affinity shard maps
    /// to (best-effort `sched_setaffinity`; no-op off Linux). Shard
    /// affinity is connection-stable, so this keeps one shard's work
    /// on one core's cache hierarchy. Threads transport only: under
    /// the reactor, workers take requests from every connection and a
    /// per-connection pin would be meaningless.
    pub pin_shards: bool,
    /// Socket transport (see [`IoModel`]).
    pub io: IoModel,
    /// Accept guard: past this many assigned connections, new arrivals
    /// get a `Busy` frame and a close instead of a thread or a
    /// reactor slot.
    pub max_conns: usize,
    /// Reactor-transport idle reaper: a connection with no frame
    /// progress for this long is deregistered and closed (counted as
    /// `idle_reaped`). `Duration::ZERO` disables reaping. The threads
    /// transport ignores it — a blocked thread is that transport's
    /// cost model, and `max_conns` still bounds it.
    pub idle_timeout: Duration,
    /// Per-run shard latency watchdog, ms (0 = off): a tail/full run
    /// that holds its shard longer than this quarantines the shard
    /// (see `ExecutorPool::set_watchdog_ms`).
    pub watchdog_ms: u64,
    /// Content-addressed logits cache budget, bytes (`--cache-bytes`).
    /// 0 (the default) disables the cache entirely — no hashing, no
    /// lookup, bit-identical to the pre-cache server.
    pub cache_bytes: usize,
    /// Under fair admission, the fraction of an admission credit a
    /// cached hit ends up costing — a hit never touched the executor,
    /// so the rest of the spent credit is refunded to the tenant
    /// (`--cache-hit-cost`). 1.0 means hits cost as much as misses.
    pub cache_hit_cost: f64,
}

/// Default reactor idle timeout (`--idle-timeout-s`).
pub const DEFAULT_IDLE_TIMEOUT: Duration = Duration::from_secs(300);

impl Default for ServeConfig {
    fn default() -> Self {
        Self {
            workers: DEFAULT_WORKERS,
            batch: BatchConfig::default(),
            admission: AdmissionConfig::default(),
            pin_shards: false,
            io: IoModel::default_for_host(),
            max_conns: DEFAULT_MAX_CONNS,
            idle_timeout: DEFAULT_IDLE_TIMEOUT,
            watchdog_ms: 0,
            cache_bytes: 0,
            cache_hit_cost: 0.1,
        }
    }
}

/// Samples the compute spine into a [`CloudTelemetry`] snapshot at a
/// bounded rate: windowed queue-wait p95 (samples since the previous
/// refresh), busiest-shard busy fraction over the wall-clock window,
/// and the batch engine's occupancy EWMA. Tests and the scenario
/// bench can inject a synthetic snapshot to drive the loop
/// deterministically.
///
/// Lock discipline: the per-request warm path is lock-free — an
/// `AtomicBool` gates the (rare) injected override and the cached
/// snapshot lives packed in two relaxed `AtomicU64`s behind an atomic
/// freshness stamp, so connection workers only contend on the refresh
/// mutex once per `cfg.refresh` interval. A reader racing a refresh
/// may mix fields from two adjacent snapshots (the two words are not
/// loaded atomically together); telemetry is a smoothed advisory
/// signal, so that tear is harmless by design.
struct LoadMonitor {
    cfg: AdmissionConfig,
    /// Time base for the freshness stamp.
    base: Instant,
    /// Nanoseconds-since-`base` until which the cached snapshot is
    /// fresh (0 = never sampled).
    fresh_until: AtomicU64,
    /// Packed cache word A: `[queue_wait_p95_ms f32 | utilization f32]`.
    cached_a: AtomicU64,
    /// Packed cache word B: `[batch_occupancy f32 | shedding u8]`.
    cached_b: AtomicU64,
    /// Fast gate for the injected override (true ⇔ injected is Some).
    injected_on: AtomicBool,
    injected: Mutex<Option<CloudTelemetry>>,
    refresh_state: Mutex<RefreshState>,
}

struct RefreshState {
    last_refresh: Option<Instant>,
    /// Per-shard busy seconds at the last refresh.
    prev_busy: Vec<f64>,
    /// Queue-wait histogram length at the last refresh (the window
    /// start for the next p95).
    qw_seen: usize,
    /// The last reported queue-wait p95 — held across windows that
    /// completed no work while requests were in flight (a stall must
    /// not read as "queue empty" and lift admission mid-overload).
    last_qw_ms: f64,
}

fn pack_a(t: &CloudTelemetry) -> u64 {
    ((t.queue_wait_p95_ms.to_bits() as u64) << 32) | t.utilization.to_bits() as u64
}

fn pack_b(t: &CloudTelemetry) -> u64 {
    ((t.batch_occupancy.to_bits() as u64) << 32) | t.shedding as u64
}

fn unpack(a: u64, b: u64) -> CloudTelemetry {
    CloudTelemetry {
        queue_wait_p95_ms: f32::from_bits((a >> 32) as u32),
        utilization: f32::from_bits(a as u32),
        batch_occupancy: f32::from_bits((b >> 32) as u32),
        shedding: b & 1 != 0,
        sheds: 0,
        tenant_backoff_ms: 0.0,
    }
}

impl LoadMonitor {
    fn new(cfg: AdmissionConfig, shards: usize) -> Self {
        Self {
            cfg,
            base: Instant::now(),
            fresh_until: AtomicU64::new(0),
            cached_a: AtomicU64::new(0),
            cached_b: AtomicU64::new(0),
            injected_on: AtomicBool::new(false),
            injected: Mutex::new(None),
            refresh_state: Mutex::new(RefreshState {
                last_refresh: None,
                prev_busy: vec![0.0; shards],
                qw_seen: 0,
                last_qw_ms: 0.0,
            }),
        }
    }

    /// Current telemetry, refreshed if stale. `sheds` is stamped from
    /// the live counter either way (it is one atomic load).
    fn sample(&self, pool: &ExecutorPool, engine: &BatchEngine, sheds: u64) -> CloudTelemetry {
        if self.injected_on.load(Ordering::Relaxed) {
            if let Some(mut t) = *self.injected.lock().unwrap() {
                t.shedding = t.shedding || self.cfg.over_budget(&t);
                t.sheds = sheds as u32;
                // The backoff hint is per-tenant, stamped on the Busy
                // reply path — never part of the sampled snapshot.
                t.tenant_backoff_ms = 0.0;
                return t;
            }
        }
        let now_n = self.base.elapsed().as_nanos() as u64;
        if now_n >= self.fresh_until.load(Ordering::Relaxed) {
            self.refresh_now(pool, engine);
        }
        let mut t = unpack(
            self.cached_a.load(Ordering::Relaxed),
            self.cached_b.load(Ordering::Relaxed),
        );
        t.sheds = sheds as u32;
        t
    }

    /// Slow path: recompute the snapshot under the refresh mutex.
    fn refresh_now(&self, pool: &ExecutorPool, engine: &BatchEngine) {
        let mut st = self.refresh_state.lock().unwrap();
        let now = Instant::now();
        // Herd guard: a worker that queued behind the refresher finds
        // the stamp already advanced and leaves.
        let now_n = self.base.elapsed().as_nanos() as u64;
        if now_n < self.fresh_until.load(Ordering::Relaxed) {
            return;
        }
        let wall = st
            .last_refresh
            .map(|at| now.duration_since(at).as_secs_f64())
            .unwrap_or(0.0)
            .max(1e-9);
        let mut util: f64 = 0.0;
        for (k, s) in pool.shard_stats().into_iter().enumerate() {
            if k < st.prev_busy.len() {
                util = util.max((s.busy_seconds - st.prev_busy[k]) / wall);
                st.prev_busy[k] = s.busy_seconds;
            }
        }
        // First sample has no window: report idle, start the clock.
        if st.last_refresh.is_none() {
            util = 0.0;
        }
        // Windowed p95 computed under the histogram's lock (bounded —
        // no clone of an unbounded sample vector per refresh). An
        // empty window is ambiguous: with work in flight it means the
        // engine is *stalled* (nothing started executing), and
        // reporting 0 there would lift queue-based admission at the
        // exact moment the queue is growing — hold the previous
        // estimate instead. With nothing in flight, empty really means
        // idle and the signal decays to 0.
        let (p95, total) = engine.metrics.queue_wait.tail_percentile(st.qw_seen, 95.0);
        let qw_ms = if total == st.qw_seen {
            if pool.active_count() > 0 {
                st.last_qw_ms
            } else {
                0.0
            }
        } else {
            p95 * 1e3
        };
        st.qw_seen = total;
        st.last_qw_ms = qw_ms;
        let mut t = CloudTelemetry {
            queue_wait_p95_ms: qw_ms as f32,
            utilization: util as f32,
            batch_occupancy: engine.occupancy_ewma() as f32,
            shedding: false,
            sheds: 0,
            tenant_backoff_ms: 0.0,
        };
        t.shedding = self.cfg.over_budget(&t);
        st.last_refresh = Some(now);
        self.cached_a.store(pack_a(&t), Ordering::Relaxed);
        self.cached_b.store(pack_b(&t), Ordering::Relaxed);
        self.fresh_until
            .store(now_n.saturating_add(self.cfg.refresh.as_nanos() as u64), Ordering::Relaxed);
    }

    fn inject(&self, t: Option<CloudTelemetry>) {
        let mut slot = self.injected.lock().unwrap();
        *slot = t;
        self.injected_on.store(slot.is_some(), Ordering::Relaxed);
        // Removing an injection must not leave a long-lived stale
        // cached window: force the next sample to refresh.
        if slot.is_none() {
            self.fresh_until.store(0, Ordering::Relaxed);
        }
    }
}

/// What the transport should do with the connection after
/// [`CloudServer::process_frame`] returns.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) enum FrameAction {
    /// Keep reading frames.
    Continue,
    /// Flush any pending reply bytes, then close the connection
    /// (clean EOF, unrecoverable framing violation, or Shutdown).
    Close,
}

/// Outcome of an admitted-or-shed data request.
enum Served {
    /// Logits are in the scratch's float buffer. `cached` marks a
    /// logits-cache hit (skipped decode, dequantize and the executor) —
    /// the reply bytes are identical either way, only the per-tenant
    /// accounting differs.
    Logits { cached: bool },
    /// Admission control refused; reply `Busy` with telemetry carrying
    /// the shed tenant's backoff hint (0 = no hint, the global-budget
    /// immediate-retry contract).
    Shed { backoff_ms: f32 },
}

/// A middle tier's relay hook: consulted for every data frame
/// (`Features`/`Image`) before local handling. `Some((kind, payload))`
/// is the reply the transport writes back verbatim; `None` falls
/// through to this process's own handlers — which is how a tier
/// degrades to serving locally when its upstream hop is down. The
/// payload passed in is the exact frame body (checked envelopes
/// already stripped), so a passthrough hop preserves request bytes
/// bit-for-bit.
pub trait TierForwarder: Send + Sync {
    fn forward(&self, kind: u8, frame: &[u8], conn_id: usize) -> Option<(u8, Vec<u8>)>;
    /// This tier's half of the stats document (rendered under the
    /// `"tier"` key — see [`crate::server::stats`]).
    fn tier_stats(&self) -> Json;
}

/// Internal tenant key: explicit wire tenants and implicit
/// per-connection tenants live in disjoint u64 ranges so a wire tenant
/// id can never collide with a connection id.
const EXPLICIT_TENANT_BIT: u64 = 1 << 32;

fn tenant_key(conn_id: usize, wire_tenant: Option<u32>) -> u64 {
    match wire_tenant {
        Some(t) => EXPLICIT_TENANT_BIT | t as u64,
        None => conn_id as u64,
    }
}

/// Human-readable tenant label for the stats JSON.
fn tenant_label(key: u64) -> String {
    if key & EXPLICIT_TENANT_BIT != 0 {
        format!("t:{}", key & (EXPLICIT_TENANT_BIT - 1))
    } else {
        format!("conn:{key}")
    }
}

pub struct CloudServer {
    engine: Arc<BatchEngine>,
    manifest: Manifest,
    pub(crate) cfg: ServeConfig,
    monitor: LoadMonitor,
    /// Per-tenant admitted/shed/bytes/queue-wait counters (explicit
    /// wire tenants and implicit per-connection tenants alike).
    tenants: Arc<TenantRegistry>,
    /// Deficit-weighted fair-share governor (consulted only when
    /// `admission.fair` and the global budget trips).
    fairness: FairAdmission,
    /// Content-addressed logits cache (`None` when `cache_bytes` is 0
    /// — the disabled path never hashes a frame).
    cache: Option<Arc<LogitsCache>>,
    /// Middle-tier relay (see [`TierForwarder`]); `None` means this
    /// process is a terminal tier and every data frame is handled
    /// locally — the pre-three-tier behavior, bit-identical.
    forwarder: Option<Arc<dyn TierForwarder>>,
    pub counters: Arc<Counters>,
    /// Per-request service time (frame read → reply written), seconds.
    pub service_hist: Arc<SharedHistogram>,
    /// Construction time — `req_per_sec` is derived from
    /// `counters.requests` over this, not tracked separately (one
    /// counter cannot desynchronize from itself).
    started: Instant,
    pub(crate) stop: Arc<AtomicBool>,
    pub(crate) scratch_pool: Arc<BufPool>,
    pub(crate) workers: ThreadPool,
    worker_count: usize,
    /// Connections currently assigned (queued or serving). Under the
    /// threads transport, reaching `worker_count` sends new
    /// connections to dedicated overflow threads so control frames
    /// (Stats/Shutdown) can never starve behind long-lived data
    /// connections parked on every worker; under either transport,
    /// reaching `cfg.max_conns` refuses them at accept.
    pub(crate) active_conns: Arc<AtomicUsize>,
    /// Monotonic connection ids — the shard affinity.
    pub(crate) conn_seq: Arc<AtomicUsize>,
}

impl CloudServer {
    /// Single-shard compatibility constructor: wraps one executor as a
    /// one-lane pool with default batching.
    pub fn new(exe: Arc<SharedExecutor>) -> Self {
        Self::with_pool(ExecutorPool::from_shared(exe), ServeConfig::default())
    }

    /// [`CloudServer::new`] with an explicit connection-worker count.
    pub fn with_workers(exe: Arc<SharedExecutor>, workers: usize) -> Self {
        Self::with_pool(
            ExecutorPool::from_shared(exe),
            ServeConfig { workers, ..ServeConfig::default() },
        )
    }

    /// The full constructor: a sharded executor pool plus serving
    /// knobs. This is the production path — shard count scales the
    /// compute half, `cfg.batch` tunes coalescing.
    pub fn with_pool(pool: Arc<ExecutorPool>, cfg: ServeConfig) -> Self {
        let manifest = pool.manifest().clone();
        let workers = cfg.workers.max(1);
        let monitor = LoadMonitor::new(cfg.admission, pool.shard_count());
        let tenants = Arc::new(TenantRegistry::default());
        // Fair admission implies the tenant-aware dequeue: the same
        // flood that exhausts a tenant's admission share must not also
        // monopolize gather windows.
        let mut batch_cfg = cfg.batch;
        batch_cfg.tenant_fair = batch_cfg.tenant_fair || cfg.admission.fair;
        pool.set_watchdog_ms(cfg.watchdog_ms);
        Self {
            engine: BatchEngine::with_tenants(pool, batch_cfg, Some(Arc::clone(&tenants))),
            manifest,
            fairness: FairAdmission::new(cfg.admission.tenant_budget),
            cache: if cfg.cache_bytes > 0 { Some(LogitsCache::new(cfg.cache_bytes)) } else { None },
            forwarder: None,
            tenants,
            cfg,
            monitor,
            counters: Arc::new(Counters::default()),
            service_hist: Arc::new(SharedHistogram::default()),
            started: Instant::now(),
            stop: Arc::new(AtomicBool::new(false)),
            scratch_pool: BufPool::new(workers),
            workers: ThreadPool::new(workers),
            worker_count: workers,
            active_conns: Arc::new(AtomicUsize::new(0)),
            conn_seq: Arc::new(AtomicUsize::new(0)),
        }
    }

    /// Scratch-pool counters (hit rate is the allocation-reuse metric).
    pub fn pool_stats(&self) -> crate::util::pool::PoolStats {
        self.scratch_pool.stats()
    }

    /// Micro-batch scheduler telemetry.
    pub fn batch_metrics(&self) -> &BatchMetrics {
        &self.engine.metrics
    }

    /// The compute pool behind the batch engine.
    pub fn executor_pool(&self) -> &Arc<ExecutorPool> {
        self.engine.pool()
    }

    /// The batch engine itself (cross-model/signature observables —
    /// `xmodel_active`, per-signature stats — for benches and tests).
    pub fn batch_engine(&self) -> &Arc<BatchEngine> {
        &self.engine
    }

    /// The logits cache, when `cache_bytes` enabled one (tests assert
    /// its counters and byte bound directly).
    pub fn cache(&self) -> Option<&Arc<LogitsCache>> {
        self.cache.as_ref()
    }

    /// The current cloud telemetry snapshot (what the next reply will
    /// piggyback).
    pub fn telemetry(&self) -> CloudTelemetry {
        self.monitor.sample(self.engine.pool(), &self.engine, self.counters.sheds())
    }

    /// Install the relay that turns this server into a middle tier
    /// (see [`crate::server::tier::EdgeTier`]): every data frame is
    /// offered to `fw` before local handling. Call before
    /// [`CloudServer::spawn`].
    pub fn set_forwarder(&mut self, fw: Arc<dyn TierForwarder>) {
        self.forwarder = Some(fw);
    }

    /// Override the sampled telemetry with a synthetic snapshot
    /// (`None` restores live sampling). The deterministic load hook
    /// for the closed-loop tests and the control-plane scenario bench:
    /// admission budgets are evaluated against the injected values, so
    /// an injected overload really sheds.
    pub fn inject_load(&self, t: Option<CloudTelemetry>) {
        self.monitor.inject(t);
    }

    /// Bind and serve on a background thread; returns the local address
    /// and a join handle. `addr` like "127.0.0.1:0" picks a free port.
    pub fn spawn(self: Arc<Self>, addr: &str) -> Result<(std::net::SocketAddr, std::thread::JoinHandle<()>)> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        let me = Arc::clone(&self);
        let handle = std::thread::spawn(move || match me.cfg.io {
            IoModel::Epoll => {
                // `epoll::serve` can only fail while setting the
                // reactor up (before any connection is accepted), so
                // falling back to the blocking transport is safe.
                if let Err(e) = super::epoll::serve(&me, &listener) {
                    crate::log_warn!(
                        "cloud",
                        "epoll reactor unavailable ({e:#}); using blocking accept loop"
                    );
                    Self::accept_loop_threads(&me, &listener);
                }
            }
            IoModel::Threads => Self::accept_loop_threads(&me, &listener),
        });
        Ok((local, handle))
    }

    /// The blocking transport: accept, then serve the whole connection
    /// on one (pooled or overflow) thread.
    fn accept_loop_threads(me: &Arc<Self>, listener: &TcpListener) {
        // The epoll fallback path may have left the listener
        // nonblocking; this loop needs `accept` to park.
        listener.set_nonblocking(false).ok();
        for conn in listener.incoming() {
            if me.stop.load(Ordering::Relaxed) {
                break;
            }
            match conn {
                Ok(stream) => {
                    me.counters.inc_connections();
                    let assigned = me.active_conns.fetch_add(1, Ordering::SeqCst);
                    if assigned >= me.cfg.max_conns {
                        me.active_conns.fetch_sub(1, Ordering::SeqCst);
                        me.refuse_connection(stream);
                        continue;
                    }
                    let me2 = Arc::clone(me);
                    let conn_id = me.conn_seq.fetch_add(1, Ordering::Relaxed);
                    let job = move || {
                        // Decrement on all exits, including panics (a
                        // leak here would eat into `max_conns` and
                        // push every later connection onto overflow
                        // threads).
                        struct Dec(Arc<AtomicUsize>);
                        impl Drop for Dec {
                            fn drop(&mut self) {
                                self.0.fetch_sub(1, Ordering::SeqCst);
                            }
                        }
                        let _dec = Dec(Arc::clone(&me2.active_conns));
                        if let Err(e) = me2.serve_conn(stream, conn_id) {
                            crate::log_debug!("cloud", "connection ended: {e:#}");
                        }
                    };
                    if assigned < me.worker_count {
                        me.workers.submit(job);
                    } else {
                        // All pooled lanes are parked on long-lived
                        // connections: overflow to a dedicated thread
                        // (bounded by `max_conns`) so this connection
                        // (possibly a Stats/Shutdown control frame) is
                        // served.
                        std::thread::spawn(job);
                    }
                }
                Err(e) => {
                    crate::log_warn!("cloud", "accept error: {e}");
                }
            }
        }
    }

    /// Accept-boundary shed: the connection count is at `max_conns`,
    /// so answer with a `Busy` frame carrying the current telemetry
    /// (shedding forced on — the edge re-decouples off it exactly like
    /// a per-request shed) and close. No thread, no reactor slot, no
    /// scratch is spent on the refused connection.
    pub(crate) fn refuse_connection(&self, mut stream: TcpStream) {
        self.counters.inc_conn_sheds();
        let mut t = self.telemetry();
        t.shedding = true;
        t.sheds = self.counters.sheds() as u32;
        let mut wire = Vec::with_capacity(64);
        t.encode_into(&mut wire);
        stream.set_nodelay(true).ok();
        // Best-effort: the reply races the peer's own timeout; if the
        // kernel can't take ~30 bytes the connection just closes.
        stream.set_nonblocking(false).ok();
        let _ = proto::write_frame_raw(&mut stream, proto::KIND_BUSY, &wire);
    }

    fn serve_conn(&self, stream: TcpStream, conn_id: usize) -> Result<()> {
        stream.set_nodelay(true).ok();
        if self.cfg.pin_shards {
            // Connection → shard → core *group*: the cores are
            // partitioned into one contiguous group per shard and a
            // shard's connection workers spread across its group — the
            // shard's working set stays on one cache/NUMA neighborhood
            // without collapsing the worker pool onto shard_count
            // cores (tail compute runs on these threads; one core per
            // shard would serialize it). Best-effort; failure is fine.
            let shards = self.engine.pool().shard_count();
            let cores = crate::util::affinity::available_cores();
            let shard = conn_id % shards;
            let group = (cores / shards).max(1);
            let core = (shard * group + (conn_id / shards) % group) % cores;
            crate::util::affinity::pin_to_core(core);
        }
        let mut writer = stream.try_clone()?;
        let mut reader = BufReader::new(stream);
        let mut scratch = self.scratch_pool.get();
        // One-entry memo for this connection's tenant counters: a
        // connection's tenant is stable in practice, so the warm path
        // is a u64 compare instead of a registry lock per request.
        let mut tenant_memo: Option<(u64, Arc<TenantCounters>)> = None;
        loop {
            let recv = match proto::read_frame_into(&mut reader, &mut scratch.frame) {
                Ok(r) => r,
                Err(_) => return Ok(()), // peer closed mid-frame
            };
            match self.process_frame(recv, conn_id, &mut scratch, &mut tenant_memo, &mut writer)? {
                FrameAction::Continue => {}
                FrameAction::Close => return Ok(()),
            }
        }
    }

    /// Handle one received frame: the transport-independent core both
    /// the blocking and the epoll server drive. The payload (for
    /// `Data`) is in `sc.frame`; replies go to `writer` — a blocking
    /// socket under [`IoModel::Threads`], an
    /// [`Outbox`](crate::server::proto::Outbox) or a detached reply
    /// buffer under [`IoModel::Epoll`]. Keeping every counter bump,
    /// admission decision and reply byte in here is what makes the two
    /// transports behaviorally identical by construction.
    pub(crate) fn process_frame(
        &self,
        recv: RecvFrame,
        conn_id: usize,
        sc: &mut Scratch,
        tenant_memo: &mut Option<(u64, Arc<TenantCounters>)>,
        writer: &mut impl std::io::Write,
    ) -> Result<FrameAction> {
        let mut kind = match recv {
            RecvFrame::Data(k) => k,
            RecvFrame::Eof => return Ok(FrameAction::Close),
            RecvFrame::Malformed { reason, resync } => {
                self.counters.inc_malformed();
                proto::write_frame_raw(writer, proto::KIND_ERROR, reason.as_bytes())?;
                if resync {
                    return Ok(FrameAction::Continue); // stream still framed; keep serving
                }
                return Ok(FrameAction::Close); // length prefix unusable; close
            }
        };
        if kind == proto::KIND_CHECKED {
            // Integrity envelope: verify the CRC and serve the inner
            // frame exactly as if it had arrived bare. A mismatch means
            // the uplink corrupted bytes in flight — the frame is
            // refused loudly (the edge re-sends) instead of letting the
            // entropy codec decode garbage into a wrong-but-served
            // prediction. The stream itself is still aligned.
            match proto::unwrap_checked(&sc.frame) {
                Ok((inner, off)) => {
                    sc.frame.drain(..off);
                    kind = inner;
                }
                Err(_) => {
                    self.counters.inc_malformed();
                    proto::write_frame_raw(
                        writer,
                        proto::KIND_ERROR,
                        proto::INTEGRITY_REJECT,
                    )?;
                    return Ok(FrameAction::Continue);
                }
            }
        }
        let t0 = Instant::now();
        // A middle tier consults its relay first: a forwarded reply is
        // written back verbatim and the local handlers never run.
        // `None` (upstream down, or the tier chose to absorb the work)
        // falls through to local handling — same counters, same
        // replies as a terminal cloud.
        if matches!(kind, proto::KIND_FEATURES | proto::KIND_IMAGE) {
            if let Some(fw) = &self.forwarder {
                if let Some((rk, payload)) = fw.forward(kind, &sc.frame, conn_id) {
                    self.note_data_request(sc.frame.len());
                    proto::write_frame_raw(writer, rk, &payload)?;
                    self.service_hist.record(t0.elapsed().as_secs_f64());
                    return Ok(FrameAction::Continue);
                }
            }
        }
        match kind {
            proto::KIND_FEATURES => {
                // Tenant identity rides an optional trailer; the
                // body left after stripping it is exactly the
                // pre-tenant frame (absent trailer ⇒ implicit
                // per-connection tenant, nothing stripped). The
                // codec header declares the frame's exact length,
                // so a trailer is looked for only in bytes beyond
                // it — a pre-tenant frame whose entropy payload
                // happens to end in trailer-looking bytes can
                // never be misread.
                let raw_len = sc.frame.len();
                let (body_len, wire_tenant) = match feature::frame_len(&sc.frame) {
                    Some(flen) if sc.frame.len() <= flen => (sc.frame.len(), None),
                    _ => proto::split_tenant_trailer(&sc.frame),
                };
                sc.frame.truncate(body_len);
                let tenant = tenant_key(conn_id, wire_tenant);
                let tc = self.tenant_counters(tenant_memo, tenant);
                tc.add_bytes(raw_len as u64);
                self.note_data_request(raw_len);
                if self.cfg.admission.fair {
                    self.fairness.note_arrival(tenant, t0);
                }
                let telemetry = self.telemetry();
                let deadline = self.request_deadline(t0);
                let result =
                    self.handle_features(conn_id, sc, telemetry.shedding, deadline, tenant);
                self.reply_data(writer, sc, t0, telemetry, result, &tc)?;
            }
            proto::KIND_IMAGE => {
                let raw_len = sc.frame.len();
                let (body_len, wire_tenant) = proto::split_tenant_trailer(&sc.frame);
                sc.frame.truncate(body_len);
                let tenant = tenant_key(conn_id, wire_tenant);
                let tc = self.tenant_counters(tenant_memo, tenant);
                tc.add_bytes(raw_len as u64);
                self.note_data_request(raw_len);
                if self.cfg.admission.fair {
                    self.fairness.note_arrival(tenant, t0);
                }
                let telemetry = self.telemetry();
                // Full-model work is the most expensive thing
                // admission can refuse; shed before decoding.
                let shed = if telemetry.shedding {
                    match self.fair_decision(tenant, t0) {
                        FairDecision::Admit => None,
                        FairDecision::Shed { backoff } => {
                            Some(backoff.as_secs_f64() as f32 * 1e3)
                        }
                        FairDecision::Global => Some(0.0),
                    }
                } else {
                    None
                };
                let result = match shed {
                    Some(backoff_ms) => Ok(Served::Shed { backoff_ms }),
                    None if sc.frame.len() < 4 => Err(anyhow!("short image frame")),
                    None => {
                        let model_id = u16::from_le_bytes([sc.frame[0], sc.frame[1]]);
                        let Scratch { frame, floats, .. } = sc;
                        self.handle_image(conn_id, model_id, &frame[4..], floats)
                            .map(|()| Served::Logits { cached: false })
                    }
                };
                self.reply_data(writer, sc, t0, telemetry, result, &tc)?;
            }
            proto::KIND_STATS => {
                self.counters.inc_control();
                let json = self.stats_json();
                proto::write_frame_raw(writer, proto::KIND_STATS_REPLY, json.as_bytes())?;
            }
            proto::KIND_PROBE => {
                // Bandwidth probe: acknowledge immediately; the edge
                // times the (throttled) upload of the padding. Probe
                // padding is accounted separately from data ingress
                // so req/bytes rates stay honest.
                self.counters.inc_control();
                self.counters.add_probe_bytes(sc.frame.len() as u64);
                proto::write_frame_raw(writer, proto::KIND_PROBE_ACK, &[])?;
            }
            proto::KIND_SHUTDOWN => {
                self.counters.inc_control();
                self.stop.store(true, Ordering::Relaxed);
                // The accept loop unblocks on the next connection
                // (`request_shutdown` makes one); the reactor notices
                // on its next wait tick.
                return Ok(FrameAction::Close);
            }
            other => {
                // Framed correctly but nonsensical here (e.g. a
                // Logits frame sent *to* the server).
                self.counters.inc_malformed();
                proto::write_frame_raw(
                    writer,
                    proto::KIND_ERROR,
                    format!("unexpected frame kind {other}").as_bytes(),
                )?;
            }
        }
        Ok(FrameAction::Continue)
    }

    /// This connection's tenant counters, through a one-entry memo:
    /// the registry mutex is only touched when the tenant changes
    /// (explicit wire tenants are connection-stable in practice).
    fn tenant_counters(
        &self,
        memo: &mut Option<(u64, Arc<TenantCounters>)>,
        tenant: u64,
    ) -> Arc<TenantCounters> {
        match memo {
            Some((k, tc)) if *k == tenant => Arc::clone(tc),
            _ => {
                let tc = self.tenants.get(tenant);
                *memo = Some((tenant, Arc::clone(&tc)));
                tc
            }
        }
    }

    /// Ingress accounting shared by every data-request kind.
    fn note_data_request(&self, payload_len: usize) {
        self.counters.inc_requests();
        self.counters.add_bytes(payload_len as u64);
    }

    /// The SLA deadline attached to a request arriving at `t0`, if
    /// admission configures one.
    fn request_deadline(&self, t0: Instant) -> Option<Instant> {
        if self.cfg.admission.deadline > Duration::ZERO {
            Some(t0 + self.cfg.admission.deadline)
        } else {
            None
        }
    }

    /// What fairness says about an over-budget, sheddable request.
    /// With `fair` off this is always [`FairDecision::Global`] — the
    /// caller sheds exactly as the pre-tenant server did.
    fn fair_decision(&self, tenant: u64, now: Instant) -> FairDecision {
        if self.cfg.admission.fair {
            self.fairness.decide(tenant, now)
        } else {
            FairDecision::Global
        }
    }

    /// Reply plumbing shared by every data-request kind: logits frame
    /// (with piggybacked telemetry) on success, `Busy` (+ shed
    /// counter) when admission refused, error frame (+ error counter)
    /// on failure. Served and failed requests land in the service
    /// histogram; sheds deliberately do not — a shed is the server
    /// refusing to pay service time, and folding its microseconds in
    /// would flatter p95 exactly when the server is struggling.
    fn reply_data(
        &self,
        writer: &mut impl std::io::Write,
        sc: &mut Scratch,
        t0: Instant,
        telemetry: CloudTelemetry,
        result: Result<Served>,
        tenant: &TenantCounters,
    ) -> Result<()> {
        match result {
            Ok(Served::Logits { cached }) => {
                proto::write_logits_frame_with(writer, &sc.floats, Some(&telemetry), &mut sc.wire)?;
                self.service_hist.record(t0.elapsed().as_secs_f64());
                tenant.inc_admitted();
                if cached {
                    tenant.inc_cache_hits();
                }
                if self.cfg.admission.fair {
                    // Completions are the auto budget's capacity signal.
                    self.fairness.note_served(Instant::now());
                }
            }
            Ok(Served::Shed { backoff_ms }) => {
                self.counters.inc_sheds();
                tenant.inc_sheds();
                let mut t = telemetry;
                t.shedding = true;
                t.sheds = self.counters.sheds() as u32;
                t.tenant_backoff_ms = backoff_ms;
                sc.wire.clear();
                t.encode_into(&mut sc.wire);
                proto::write_frame_raw(writer, proto::KIND_BUSY, &sc.wire)?;
            }
            Err(e) => {
                self.counters.inc_errors();
                proto::write_frame_raw(writer, proto::KIND_ERROR, format!("{e:#}").as_bytes())?;
                self.service_hist.record(t0.elapsed().as_secs_f64());
            }
        }
        Ok(())
    }

    /// The stats document served on `KIND_STATS`, rendered against
    /// [`stats::CLOUD_SCHEMA`](crate::server::stats::CLOUD_SCHEMA) —
    /// key drift is a debug panic, not a silent dashboard break.
    pub(crate) fn stats_json(&self) -> String {
        let (req, err, bytes, _) = self.counters.snapshot();
        let ps = self.scratch_pool.stats();
        let hist = self.service_hist.snapshot();
        let (p50, p95) = if hist.is_empty() {
            (0.0, 0.0)
        } else {
            (hist.percentile(50.0) * 1e3, hist.percentile(95.0) * 1e3)
        };
        let bm = &self.engine.metrics;
        let (batches, batched_requests, bypassed, max_occ) = bm.snapshot();
        let qw = bm.queue_wait.snapshot();
        let (qw50, qw95) = if qw.is_empty() {
            (0.0, 0.0)
        } else {
            (qw.percentile(50.0) * 1e3, qw.percentile(95.0) * 1e3)
        };
        let pool = self.engine.pool();
        let shards = pool
            .shard_stats()
            .into_iter()
            .map(|s| {
                Json::obj(vec![
                    ("runs", Json::num(s.runs as f64)),
                    ("busy_ms", Json::num(s.busy_seconds * 1e3)),
                    ("quarantined", Json::num(s.quarantined as u8 as f64)),
                ])
            })
            .collect();
        let health = pool.health_stats();
        let telemetry = self.telemetry();
        crate::server::stats::render(crate::server::stats::CLOUD_SCHEMA, vec![
            // Data-request taxonomy (see metrics::Counters): `requests`
            // counts Features/Image only; probes and stats queries land
            // in control_frames/probe_bytes.
            ("requests", Json::num(req as f64)),
            ("errors", Json::num(err as f64)),
            ("bytes_rx", Json::num(bytes as f64)),
            ("control_frames", Json::num(self.counters.control() as f64)),
            ("probe_bytes", Json::num(self.counters.probe() as f64)),
            ("malformed", Json::num(self.counters.malformed_count() as f64)),
            ("compiled", Json::num(pool.cached_count() as f64)),
            ("connections", Json::num(self.counters.connections() as f64)),
            ("conn_sheds", Json::num(self.counters.conn_sheds() as f64)),
            ("idle_reaped", Json::num(self.counters.idle_reaped() as f64)),
            // Shard self-healing: quarantine events, successful
            // re-admissions, and what tripped them.
            ("quarantined", Json::num(health.quarantined as f64)),
            ("quarantined_now", Json::num(health.quarantined_now as f64)),
            ("readmitted", Json::num(health.readmitted as f64)),
            ("watchdog_trips", Json::num(health.watchdog_trips as f64)),
            ("shard_panics", Json::num(health.panics as f64)),
            ("pool_hits", Json::num(ps.hits as f64)),
            ("pool_misses", Json::num(ps.misses as f64)),
            (
                "req_per_sec",
                Json::num(req as f64 / self.started.elapsed().as_secs_f64().max(1e-9)),
            ),
            ("service_p50_ms", Json::num(p50)),
            ("service_p95_ms", Json::num(p95)),
            // Compute-spine telemetry: shard utilization + batching.
            ("shard_count", Json::num(pool.shard_count() as f64)),
            ("shards", Json::arr(shards)),
            ("batches", Json::num(batches as f64)),
            ("batched_requests", Json::num(batched_requests as f64)),
            ("batch_bypassed", Json::num(bypassed as f64)),
            ("batch_mean_occupancy", Json::num(bm.mean_occupancy())),
            ("batch_max_occupancy", Json::num(max_occ as f64)),
            ("queue_wait_p50_ms", Json::num(qw50)),
            ("queue_wait_p95_ms", Json::num(qw95)),
            // Control-plane telemetry: what the next reply piggybacks,
            // plus the admission + adaptive-gather observables.
            ("sheds", Json::num(self.counters.sheds() as f64)),
            ("shedding", Json::num(telemetry.shedding as u8 as f64)),
            ("utilization", Json::num(f64::from(telemetry.utilization))),
            (
                "queue_wait_window_p95_ms",
                Json::num(f64::from(telemetry.queue_wait_p95_ms)),
            ),
            (
                "gather_window_us",
                Json::num(bm.gather_window_us.load(std::sync::atomic::Ordering::Relaxed) as f64),
            ),
            (
                "deadline_clamped",
                Json::num(bm.deadline_clamped.load(std::sync::atomic::Ordering::Relaxed) as f64),
            ),
            // Cross-model batching observables: whether signature
            // keying is live, how often batches actually mixed models,
            // what the pad-and-stack path wasted, and the per-signature
            // route census (classes that saw traffic only).
            ("xmodel_active", Json::num(self.engine.xmodel_active() as u8 as f64)),
            (
                "xmodel_batches",
                Json::num(bm.xmodel_batches.load(std::sync::atomic::Ordering::Relaxed) as f64),
            ),
            (
                "padded_samples",
                Json::num(bm.padded_samples.load(std::sync::atomic::Ordering::Relaxed) as f64),
            ),
            ("pad_waste", Json::num(bm.pad_waste())),
            (
                "signatures",
                Json::arr(
                    self.engine
                        .signature_stats()
                        .into_iter()
                        .map(|s| {
                            Json::obj(vec![
                                (
                                    "members",
                                    Json::arr(
                                        s.members.iter().map(|m| Json::str(m)).collect(),
                                    ),
                                ),
                                ("lead_min", Json::num(s.lead_min as f64)),
                                ("lead_max", Json::num(s.lead_max as f64)),
                                ("requests", Json::num(s.requests as f64)),
                                ("batches", Json::num(s.batches as f64)),
                            ])
                        })
                        .collect(),
                ),
            ),
            // Logits-cache observables: taxonomy counters + live
            // occupancy. Disabled (`cache_bytes = 0`) reports zeros
            // with `enabled = 0`, so dashboards need no special case.
            ("cache", {
                let cs = self.cache.as_ref().map(|c| c.stats()).unwrap_or_default();
                crate::server::stats::render(crate::server::stats::CACHE_SCHEMA, vec![
                    ("enabled", Json::num(self.cache.is_some() as u8 as f64)),
                    ("capacity_bytes", Json::num(self.cfg.cache_bytes as f64)),
                    ("hits", Json::num(cs.hits as f64)),
                    ("misses", Json::num(cs.misses as f64)),
                    ("inflight_coalesced", Json::num(cs.inflight_coalesced as f64)),
                    ("evictions", Json::num(cs.evictions as f64)),
                    ("bytes_saved", Json::num(cs.bytes_saved as f64)),
                    ("hit_bytes", Json::num(cs.hit_bytes as f64)),
                    ("entries", Json::num(cs.entries as f64)),
                    ("bytes", Json::num(cs.bytes as f64)),
                ])
            }),
            // Multi-edge fairness observables: per-tenant admission
            // outcomes + the tenant-aware dequeue's cap events.
            ("fair_admission", Json::num(self.cfg.admission.fair as u8 as f64)),
            ("active_tenants", Json::num(self.fairness.active_tenants(Instant::now()) as f64)),
            (
                "tenant_capped",
                Json::num(bm.tenant_capped.load(std::sync::atomic::Ordering::Relaxed) as f64),
            ),
            (
                "tenants",
                Json::arr(self.tenants.snapshot().into_iter().map(|(key, tc)| {
                    let (admitted, sheds, bytes) = tc.snapshot();
                    let qw = tc.queue_wait.snapshot();
                    let qw95 = if qw.is_empty() { 0.0 } else { qw.percentile(95.0) * 1e3 };
                    Json::obj(vec![
                        ("tenant", Json::str(&tenant_label(key))),
                        ("admitted", Json::num(admitted as f64)),
                        ("cache_hits", Json::num(tc.cache_hits() as f64)),
                        ("sheds", Json::num(sheds as f64)),
                        ("bytes_rx", Json::num(bytes as f64)),
                        ("queue_wait_p95_ms", Json::num(qw95)),
                    ])
                })),
            ),
            // Per-tier nesting: a middle tier reports its relay
            // counters (and its upstream hop's view) here; a terminal
            // cloud reports the inert same-shaped object.
            (
                "tier",
                match &self.forwarder {
                    Some(fw) => fw.tier_stats(),
                    None => crate::server::stats::cloud_tier_stats(),
                },
            ),
        ])
        .to_string()
    }

    /// Decode a feature frame, dequantize natively, and finish
    /// inference through the batch engine; the logits land in
    /// `scratch.floats` (reused). The float buffer is lent through the
    /// engine by move and restored as the same allocation.
    ///
    /// Shedding is shard-aware *and* cut-aware: when admission is over
    /// budget, cuts short of the last stage are refused (their tails
    /// are the compute being protected), but an `i = N` cut — the
    /// logits-forward whose tail is the identity — is always admitted.
    /// That keeps the control loop live under overload: the edge's
    /// edge-ward march terminates at a plan the cloud accepts, load
    /// drains, and the piggybacked telemetry then walks the cut back.
    fn handle_features(
        &self,
        conn_id: usize,
        scratch: &mut Scratch,
        shedding: bool,
        deadline: Option<Instant>,
        tenant: u64,
    ) -> Result<Served> {
        // Shed off the fixed header alone — refusing work must not pay
        // the entropy decode. Unpeekable frames fall through and fail
        // in the full decode with a precise error.
        let mut fair_charged = false;
        if shedding {
            if let Some((model, stage)) = feature::peek_route(&scratch.frame) {
                let sheddable = match self.manifest.models.get(model as usize) {
                    Some(m) => (stage as usize) < m.num_stages(),
                    None => true, // bogus model: not worth decoding while over budget
                };
                if sheddable {
                    // Fairness decides *who* the over-budget server
                    // refuses: a tenant inside its fair share is
                    // admitted anyway; one past it gets a Busy with a
                    // backoff hint. Without fairness (or with a single
                    // active tenant) this is the pre-tenant global
                    // shed, hint-less.
                    match self.fair_decision(tenant, Instant::now()) {
                        FairDecision::Admit => fair_charged = true,
                        FairDecision::Shed { backoff } => {
                            return Ok(Served::Shed {
                                backoff_ms: backoff.as_secs_f64() as f32 * 1e3,
                            })
                        }
                        FairDecision::Global => return Ok(Served::Shed { backoff_ms: 0.0 }),
                    }
                }
            }
        }
        // Cache consult: between admission (a shed above never reaches
        // here, so `Busy` outcomes are never cached) and the decode +
        // dequantize below (a hit skips both, and the executor). The
        // key is the content hash of the exact frame bytes — derivable
        // only when the declared frame length matches exactly, the
        // same validation the tenant-trailer split performed.
        if let Some(cache) = &self.cache {
            if let Some(key) = LogitsCache::key_for(&scratch.frame) {
                let req_bytes = scratch.frame.len();
                loop {
                    if let Some(hit) = cache.get(key, req_bytes) {
                        scratch.floats.clear();
                        scratch.floats.extend_from_slice(&hit);
                        if fair_charged {
                            // The hit cost no executor time: refund all
                            // but `cache_hit_cost` of the admission
                            // credit the shed-check spent.
                            self.fairness
                                .refund(tenant, (1.0 - self.cfg.cache_hit_cost).clamp(0.0, 1.0));
                        }
                        return Ok(Served::Logits { cached: true });
                    }
                    match cache.lead_or_wait(key) {
                        LeadOrWait::Lead(guard) => {
                            let r = self.features_tail(conn_id, scratch, deadline, tenant);
                            if r.is_ok() {
                                // Publish before the guard releases so
                                // woken followers' store re-check hits.
                                cache.publish(guard, &scratch.floats);
                            }
                            // On error the guard drops here: the key is
                            // released, nothing is cached, and a parked
                            // follower leads (and fails) on its own.
                            return r.map(|()| Served::Logits { cached: false });
                        }
                        // A leader finished (or failed) while we
                        // parked: loop back to the store check.
                        LeadOrWait::Waited => continue,
                    }
                }
            }
        }
        self.features_tail(conn_id, scratch, deadline, tenant)
            .map(|()| Served::Logits { cached: false })
    }

    /// The uncached feature-serving tail: full decode, native
    /// dequantize, batched tail inference. Logits land in
    /// `scratch.floats`.
    fn features_tail(
        &self,
        conn_id: usize,
        scratch: &mut Scratch,
        deadline: Option<Instant>,
        tenant: u64,
    ) -> Result<()> {
        let (model_id, from) = {
            let Scratch { frame, values, floats, codec, .. } = scratch;
            let h = feature::decode_into(frame, codec, values).map_err(anyhow::Error::new)?;
            let m = self
                .manifest
                .models
                .get(h.model as usize)
                .ok_or_else(|| anyhow!("bad model id {}", h.model))?;
            let i = h.stage as usize;
            if i == 0 || i > m.num_stages() {
                return Err(anyhow!("bad stage {i}"));
            }
            // Validate geometry *before* enqueueing: a malformed
            // request must fail alone, never poison a batch it would
            // have joined.
            let stage = &m.stages[i - 1];
            if values.len() != stage.out_elems {
                return Err(anyhow!(
                    "stage {i} feature map has {} elements, frame carried {}",
                    stage.out_elems,
                    values.len()
                ));
            }
            // Native dequant on the connection worker: the executor
            // shard never spends its lock time widening u16s.
            quant::dequantize_into(values, h.lo, h.hi, h.c, floats);
            (h.model, i + 1)
        };
        let activation = scratch.lend_floats();
        let out =
            self.engine.infer_tail_for(conn_id, model_id, from, activation, deadline, tenant)?;
        scratch.restore_floats(out);
        Ok(())
    }

    fn handle_image(
        &self,
        conn_id: usize,
        model_id: u16,
        png_bytes: &[u8],
        logits: &mut Vec<f32>,
    ) -> Result<()> {
        let model = &self
            .manifest
            .models
            .get(model_id as usize)
            .ok_or_else(|| anyhow!("bad model id {model_id}"))?
            .name;
        let m = self.manifest.model(model)?;
        let img = png::decode(png_bytes).map_err(anyhow::Error::new)?;
        // Validate geometry before building the tensor — a wrong-sized
        // image must produce an Error reply, not a worker panic.
        let expect: usize = m.input_shape.iter().product();
        if img.data.len() != expect {
            return Err(anyhow!(
                "image is {}x{}x{} ({} bytes), {model} expects {:?}",
                img.w,
                img.h,
                img.channels,
                img.data.len(),
                m.input_shape
            ));
        }
        let x = crate::data::gen::from_rgb8(&img.data, m.input_shape.clone());
        let out = self
            .engine
            .pool()
            .run_on(conn_id, |e| e.run_full(model, &x))?;
        logits.clear();
        logits.extend_from_slice(out.tensor.data());
        Ok(())
    }

    /// Ask a running server (possibly in another process) to stop.
    pub fn request_shutdown(addr: std::net::SocketAddr) {
        if let Ok(mut s) = TcpStream::connect(addr) {
            let _ = proto::Frame::Shutdown.write_to(&mut s);
        }
        // One more connect unblocks the accept loop.
        let _ = TcpStream::connect(addr);
    }
}
