//! The cloud server: a `util::threadpool` worker per connection, a
//! sharded + micro-batched inference engine, pooled per-connection
//! scratch.
//!
//! Handles two request kinds:
//! * `Features` — the decoupled path: decode the wire frame (its header
//!   names model + stage + c) into the connection's scratch,
//!   dequantize **natively on the connection worker**
//!   (`quant::dequantize_into` — the executor's critical path never
//!   sees the dequant hop or its staging buffers), then hand the flat
//!   activation to the [`BatchEngine`] which finishes stages
//!   `i*+1..N` and returns the logits;
//! * `Image` — the cloud-only path: decode the PNG-like image, run the
//!   full model on the connection's affinity shard.
//!
//! Concurrency model: the accept loop hands each connection to a fixed
//! [`ThreadPool`]; when every pooled lane is parked on a long-lived
//! connection, further connections run on dedicated overflow threads so
//! control traffic (Stats/Shutdown) can never starve behind data
//! connections. Compute is an [`ExecutorPool`] of independently-locked
//! executors — the connection id is the shard affinity — and
//! concurrent same-shape tails coalesce in the [`BatchEngine`] (one
//! lock acquisition per batch; lone requests bypass the queue).
//! Counters are atomics with an explicit taxonomy (data requests vs
//! control frames vs malformed input — see [`Counters`]); the
//! service-time and queue-wait histograms sit behind their own
//! mutexes. Every connection checks a
//! [`Scratch`](crate::util::pool::Scratch) out of a shared
//! [`BufPool`], so its codec + proto hops reuse warm buffers, and its
//! float buffer is *lent* through the batch engine and restored with
//! the logits in the same allocation.
//!
//! The wire frame being self-describing is what lets the edge
//! re-decouple unilaterally — the "synchronize" step of §III-E costs
//! nothing here. Malformed frames get an `Error` reply instead of a
//! dropped connection; only an unrecoverable length-prefix violation
//! closes the stream (it can no longer be framed).

use std::io::BufReader;
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::compression::feature;
use crate::compression::png;
use crate::compression::quant;
use crate::metrics::{BatchMetrics, Counters, SharedHistogram};
use crate::runtime::{BatchConfig, BatchEngine, ExecutorPool, Manifest, SharedExecutor};
use crate::server::proto::{self, RecvFrame};
use crate::util::json::Json;
use crate::util::pool::{BufPool, Scratch};
use crate::util::threadpool::ThreadPool;

/// Default connection-worker count (the pooled serving lanes).
pub const DEFAULT_WORKERS: usize = 16;

/// Serving configuration: transport lanes + compute batching.
#[derive(Debug, Clone, Copy)]
pub struct ServeConfig {
    /// Pooled connection workers (overflow spawns dedicated threads).
    pub workers: usize,
    /// Micro-batch scheduler knobs (shard count comes from the pool).
    pub batch: BatchConfig,
}

impl Default for ServeConfig {
    fn default() -> Self {
        Self { workers: DEFAULT_WORKERS, batch: BatchConfig::default() }
    }
}

pub struct CloudServer {
    engine: Arc<BatchEngine>,
    manifest: Manifest,
    pub counters: Arc<Counters>,
    /// Per-request service time (frame read → reply written), seconds.
    pub service_hist: Arc<SharedHistogram>,
    /// Construction time — `req_per_sec` is derived from
    /// `counters.requests` over this, not tracked separately (one
    /// counter cannot desynchronize from itself).
    started: Instant,
    stop: Arc<AtomicBool>,
    scratch_pool: Arc<BufPool>,
    workers: ThreadPool,
    worker_count: usize,
    /// Connections currently assigned (queued or serving). When this
    /// reaches `worker_count`, new connections overflow to dedicated
    /// threads so control frames (Stats/Shutdown) can never starve
    /// behind long-lived data connections parked on every worker.
    active_conns: Arc<AtomicUsize>,
    /// Monotonic connection ids — the shard affinity.
    conn_seq: Arc<AtomicUsize>,
}

impl CloudServer {
    /// Single-shard compatibility constructor: wraps one executor as a
    /// one-lane pool with default batching.
    pub fn new(exe: Arc<SharedExecutor>) -> Self {
        Self::with_pool(ExecutorPool::from_shared(exe), ServeConfig::default())
    }

    /// [`CloudServer::new`] with an explicit connection-worker count.
    pub fn with_workers(exe: Arc<SharedExecutor>, workers: usize) -> Self {
        Self::with_pool(
            ExecutorPool::from_shared(exe),
            ServeConfig { workers, ..ServeConfig::default() },
        )
    }

    /// The full constructor: a sharded executor pool plus serving
    /// knobs. This is the production path — shard count scales the
    /// compute half, `cfg.batch` tunes coalescing.
    pub fn with_pool(pool: Arc<ExecutorPool>, cfg: ServeConfig) -> Self {
        let manifest = pool.manifest().clone();
        let workers = cfg.workers.max(1);
        Self {
            engine: BatchEngine::new(pool, cfg.batch),
            manifest,
            counters: Arc::new(Counters::default()),
            service_hist: Arc::new(SharedHistogram::default()),
            started: Instant::now(),
            stop: Arc::new(AtomicBool::new(false)),
            scratch_pool: BufPool::new(workers),
            workers: ThreadPool::new(workers),
            worker_count: workers,
            active_conns: Arc::new(AtomicUsize::new(0)),
            conn_seq: Arc::new(AtomicUsize::new(0)),
        }
    }

    /// Scratch-pool counters (hit rate is the allocation-reuse metric).
    pub fn pool_stats(&self) -> crate::util::pool::PoolStats {
        self.scratch_pool.stats()
    }

    /// Micro-batch scheduler telemetry.
    pub fn batch_metrics(&self) -> &BatchMetrics {
        &self.engine.metrics
    }

    /// The compute pool behind the batch engine.
    pub fn executor_pool(&self) -> &Arc<ExecutorPool> {
        self.engine.pool()
    }

    /// Bind and serve on a background thread; returns the local address
    /// and a join handle. `addr` like "127.0.0.1:0" picks a free port.
    pub fn spawn(self: Arc<Self>, addr: &str) -> Result<(std::net::SocketAddr, std::thread::JoinHandle<()>)> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        let me = Arc::clone(&self);
        let handle = std::thread::spawn(move || {
            for conn in listener.incoming() {
                if me.stop.load(Ordering::Relaxed) {
                    break;
                }
                match conn {
                    Ok(stream) => {
                        me.counters.inc_connections();
                        let me2 = Arc::clone(&me);
                        let conn_id = me.conn_seq.fetch_add(1, Ordering::Relaxed);
                        let assigned =
                            me.active_conns.fetch_add(1, Ordering::SeqCst);
                        let job = move || {
                            // Decrement on all exits, including panics
                            // (a leak here would push every later
                            // connection onto overflow threads).
                            struct Dec(Arc<AtomicUsize>);
                            impl Drop for Dec {
                                fn drop(&mut self) {
                                    self.0.fetch_sub(1, Ordering::SeqCst);
                                }
                            }
                            let _dec = Dec(Arc::clone(&me2.active_conns));
                            if let Err(e) = me2.serve_conn(stream, conn_id) {
                                crate::log_debug!("cloud", "connection ended: {e:#}");
                            }
                        };
                        if assigned < me.worker_count {
                            me.workers.submit(job);
                        } else {
                            // All pooled lanes are parked on long-lived
                            // connections: overflow to a dedicated
                            // thread so this connection (possibly a
                            // Stats/Shutdown control frame) is served.
                            std::thread::spawn(job);
                        }
                    }
                    Err(e) => {
                        crate::log_warn!("cloud", "accept error: {e}");
                    }
                }
            }
        });
        Ok((local, handle))
    }

    fn serve_conn(&self, stream: TcpStream, conn_id: usize) -> Result<()> {
        stream.set_nodelay(true).ok();
        let mut writer = stream.try_clone()?;
        let mut reader = BufReader::new(stream);
        let mut scratch = self.scratch_pool.get();
        loop {
            let recv = match proto::read_frame_into(&mut reader, &mut scratch.frame) {
                Ok(r) => r,
                Err(_) => return Ok(()), // peer closed mid-frame
            };
            let kind = match recv {
                RecvFrame::Data(k) => k,
                RecvFrame::Eof => return Ok(()),
                RecvFrame::Malformed { reason, resync } => {
                    self.counters.inc_malformed();
                    proto::write_frame_raw(&mut writer, proto::KIND_ERROR, reason.as_bytes())?;
                    if resync {
                        continue; // stream still framed; keep serving
                    }
                    return Ok(()); // length prefix unusable; close
                }
            };
            let t0 = Instant::now();
            let sc = &mut *scratch;
            match kind {
                proto::KIND_FEATURES => {
                    self.note_data_request(sc.frame.len());
                    let result = self.handle_features(conn_id, sc);
                    self.reply_data(&mut writer, sc, t0, result)?;
                }
                proto::KIND_IMAGE => {
                    self.note_data_request(sc.frame.len());
                    let result = if sc.frame.len() < 4 {
                        Err(anyhow!("short image frame"))
                    } else {
                        let model_id = u16::from_le_bytes([sc.frame[0], sc.frame[1]]);
                        let Scratch { frame, floats, .. } = sc;
                        self.handle_image(conn_id, model_id, &frame[4..], floats)
                    };
                    self.reply_data(&mut writer, sc, t0, result)?;
                }
                proto::KIND_STATS => {
                    self.counters.inc_control();
                    let json = self.stats_json();
                    proto::write_frame_raw(&mut writer, proto::KIND_STATS_REPLY, json.as_bytes())?;
                }
                proto::KIND_PROBE => {
                    // Bandwidth probe: acknowledge immediately; the edge
                    // times the (throttled) upload of the padding. Probe
                    // padding is accounted separately from data ingress
                    // so req/bytes rates stay honest.
                    self.counters.inc_control();
                    self.counters.add_probe_bytes(sc.frame.len() as u64);
                    proto::write_frame_raw(&mut writer, proto::KIND_PROBE_ACK, &[])?;
                }
                proto::KIND_SHUTDOWN => {
                    self.counters.inc_control();
                    self.stop.store(true, Ordering::Relaxed);
                    // The accept loop unblocks on the next connection
                    // (`request_shutdown` makes one).
                    return Ok(());
                }
                other => {
                    // Framed correctly but nonsensical here (e.g. a
                    // Logits frame sent *to* the server).
                    self.counters.inc_malformed();
                    proto::write_frame_raw(
                        &mut writer,
                        proto::KIND_ERROR,
                        format!("unexpected frame kind {other}").as_bytes(),
                    )?;
                }
            }
        }
    }

    /// Ingress accounting shared by every data-request kind.
    fn note_data_request(&self, payload_len: usize) {
        self.counters.inc_requests();
        self.counters.add_bytes(payload_len as u64);
    }

    /// Reply plumbing shared by every data-request kind: logits frame
    /// on success, error frame (+ error counter) on failure, service
    /// histogram either way.
    fn reply_data(
        &self,
        writer: &mut impl std::io::Write,
        sc: &mut Scratch,
        t0: Instant,
        result: Result<()>,
    ) -> Result<()> {
        match result {
            Ok(()) => {
                proto::write_logits_frame(writer, &sc.floats, &mut sc.wire)?;
            }
            Err(e) => {
                self.counters.inc_errors();
                proto::write_frame_raw(writer, proto::KIND_ERROR, format!("{e:#}").as_bytes())?;
            }
        }
        self.service_hist.record(t0.elapsed().as_secs_f64());
        Ok(())
    }

    fn stats_json(&self) -> String {
        let (req, err, bytes, _) = self.counters.snapshot();
        let ps = self.scratch_pool.stats();
        let hist = self.service_hist.snapshot();
        let (p50, p95) = if hist.is_empty() {
            (0.0, 0.0)
        } else {
            (hist.percentile(50.0) * 1e3, hist.percentile(95.0) * 1e3)
        };
        let bm = &self.engine.metrics;
        let (batches, batched_requests, bypassed, max_occ) = bm.snapshot();
        let qw = bm.queue_wait.snapshot();
        let (qw50, qw95) = if qw.is_empty() {
            (0.0, 0.0)
        } else {
            (qw.percentile(50.0) * 1e3, qw.percentile(95.0) * 1e3)
        };
        let pool = self.engine.pool();
        let shards = pool
            .shard_stats()
            .into_iter()
            .map(|s| {
                Json::obj(vec![
                    ("runs", Json::num(s.runs as f64)),
                    ("busy_ms", Json::num(s.busy_seconds * 1e3)),
                ])
            })
            .collect();
        Json::obj(vec![
            // Data-request taxonomy (see metrics::Counters): `requests`
            // counts Features/Image only; probes and stats queries land
            // in control_frames/probe_bytes.
            ("requests", Json::num(req as f64)),
            ("errors", Json::num(err as f64)),
            ("bytes_rx", Json::num(bytes as f64)),
            ("control_frames", Json::num(self.counters.control() as f64)),
            ("probe_bytes", Json::num(self.counters.probe() as f64)),
            ("malformed", Json::num(self.counters.malformed_count() as f64)),
            ("compiled", Json::num(pool.cached_count() as f64)),
            ("connections", Json::num(self.counters.connections() as f64)),
            ("pool_hits", Json::num(ps.hits as f64)),
            ("pool_misses", Json::num(ps.misses as f64)),
            (
                "req_per_sec",
                Json::num(req as f64 / self.started.elapsed().as_secs_f64().max(1e-9)),
            ),
            ("service_p50_ms", Json::num(p50)),
            ("service_p95_ms", Json::num(p95)),
            // Compute-spine telemetry: shard utilization + batching.
            ("shard_count", Json::num(pool.shard_count() as f64)),
            ("shards", Json::arr(shards)),
            ("batches", Json::num(batches as f64)),
            ("batched_requests", Json::num(batched_requests as f64)),
            ("batch_bypassed", Json::num(bypassed as f64)),
            ("batch_mean_occupancy", Json::num(bm.mean_occupancy())),
            ("batch_max_occupancy", Json::num(max_occ as f64)),
            ("queue_wait_p50_ms", Json::num(qw50)),
            ("queue_wait_p95_ms", Json::num(qw95)),
        ])
        .to_string()
    }

    /// Decode a feature frame, dequantize natively, and finish
    /// inference through the batch engine; the logits land in
    /// `scratch.floats` (reused). The float buffer is lent through the
    /// engine by move and restored as the same allocation.
    fn handle_features(&self, conn_id: usize, scratch: &mut Scratch) -> Result<()> {
        let (model_id, from) = {
            let Scratch { frame, values, floats, codec, .. } = scratch;
            let h = feature::decode_into(frame, codec, values).map_err(anyhow::Error::new)?;
            let m = self
                .manifest
                .models
                .get(h.model as usize)
                .ok_or_else(|| anyhow!("bad model id {}", h.model))?;
            let i = h.stage as usize;
            if i == 0 || i > m.num_stages() {
                return Err(anyhow!("bad stage {i}"));
            }
            // Validate geometry *before* enqueueing: a malformed
            // request must fail alone, never poison a batch it would
            // have joined.
            let stage = &m.stages[i - 1];
            if values.len() != stage.out_elems {
                return Err(anyhow!(
                    "stage {i} feature map has {} elements, frame carried {}",
                    stage.out_elems,
                    values.len()
                ));
            }
            // Native dequant on the connection worker: the executor
            // shard never spends its lock time widening u16s.
            quant::dequantize_into(values, h.lo, h.hi, h.c, floats);
            (h.model, i + 1)
        };
        let activation = scratch.lend_floats();
        let out = self.engine.infer_tail(conn_id, model_id, from, activation)?;
        scratch.restore_floats(out);
        Ok(())
    }

    fn handle_image(
        &self,
        conn_id: usize,
        model_id: u16,
        png_bytes: &[u8],
        logits: &mut Vec<f32>,
    ) -> Result<()> {
        let model = &self
            .manifest
            .models
            .get(model_id as usize)
            .ok_or_else(|| anyhow!("bad model id {model_id}"))?
            .name;
        let m = self.manifest.model(model)?;
        let img = png::decode(png_bytes).map_err(anyhow::Error::new)?;
        // Validate geometry before building the tensor — a wrong-sized
        // image must produce an Error reply, not a worker panic.
        let expect: usize = m.input_shape.iter().product();
        if img.data.len() != expect {
            return Err(anyhow!(
                "image is {}x{}x{} ({} bytes), {model} expects {:?}",
                img.w,
                img.h,
                img.channels,
                img.data.len(),
                m.input_shape
            ));
        }
        let x = crate::data::gen::from_rgb8(&img.data, m.input_shape.clone());
        let out = self
            .engine
            .pool()
            .run_on(conn_id, |e| e.run_full(model, &x))?;
        logits.clear();
        logits.extend_from_slice(out.tensor.data());
        Ok(())
    }

    /// Ask a running server (possibly in another process) to stop.
    pub fn request_shutdown(addr: std::net::SocketAddr) {
        if let Ok(mut s) = TcpStream::connect(addr) {
            let _ = proto::Frame::Shutdown.write_to(&mut s);
        }
        // One more connect unblocks the accept loop.
        let _ = TcpStream::connect(addr);
    }
}
