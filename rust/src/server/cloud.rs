//! The cloud server: a `util::threadpool` worker per connection,
//! PJRT-backed inference, pooled per-connection scratch.
//!
//! Handles two request kinds:
//! * `Features` — the decoupled path: decode the wire frame (its header
//!   names model + stage + c) into the connection's scratch, dequantize
//!   through the L1 artifact, run stages `i*+1..N`, reply with logits;
//! * `Image` — the cloud-only path: decode the PNG-like image, run the
//!   full model.
//!
//! Concurrency model: the accept loop hands each connection to a fixed
//! [`ThreadPool`]; when every pooled lane is parked on a long-lived
//! connection, further connections run on dedicated overflow threads so
//! control traffic (Stats/Shutdown) can never starve behind data
//! connections. The
//! PJRT executor is `Arc`-shared and serialized behind the
//! `SharedExecutor` mutex; counters are atomics and the service-time
//! histogram sits behind its own mutex. Every connection checks a
//! [`Scratch`](crate::util::pool::Scratch) out of a shared
//! [`BufPool`], so its codec + proto hops reuse warm buffers — the
//! steady-state request performs no heap allocations in those hops.
//!
//! The wire frame being self-describing is what lets the edge
//! re-decouple unilaterally — the "synchronize" step of §III-E costs
//! nothing here. Malformed frames get an `Error` reply instead of a
//! dropped connection; only an unrecoverable length-prefix violation
//! closes the stream (it can no longer be framed).

use std::io::BufReader;
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::compression::feature::{self, CodecScratch};
use crate::compression::png;
use crate::metrics::{Counters, SharedHistogram, Throughput};
use crate::runtime::{Manifest, SharedExecutor};
use crate::server::proto::{self, RecvFrame};
use crate::util::json::Json;
use crate::util::pool::{BufPool, Scratch};
use crate::util::threadpool::ThreadPool;

/// Default connection-worker count (the pooled serving lanes).
pub const DEFAULT_WORKERS: usize = 16;

pub struct CloudServer {
    exe: Arc<SharedExecutor>,
    manifest: Manifest,
    pub counters: Arc<Counters>,
    /// Per-request service time (frame read → reply written), seconds.
    pub service_hist: Arc<SharedHistogram>,
    /// Requests per second since the server was constructed.
    pub throughput: Arc<Throughput>,
    stop: Arc<AtomicBool>,
    scratch_pool: Arc<BufPool>,
    workers: ThreadPool,
    worker_count: usize,
    /// Connections currently assigned (queued or serving). When this
    /// reaches `worker_count`, new connections overflow to dedicated
    /// threads so control frames (Stats/Shutdown) can never starve
    /// behind long-lived data connections parked on every worker.
    active_conns: Arc<AtomicUsize>,
}

impl CloudServer {
    pub fn new(exe: Arc<SharedExecutor>) -> Self {
        Self::with_workers(exe, DEFAULT_WORKERS)
    }

    /// A server whose accept loop fans out to `workers` pooled
    /// connection workers (min 1); connections beyond that run on
    /// dedicated overflow threads.
    pub fn with_workers(exe: Arc<SharedExecutor>, workers: usize) -> Self {
        let manifest = exe.manifest_clone();
        Self {
            exe,
            manifest,
            counters: Arc::new(Counters::default()),
            service_hist: Arc::new(SharedHistogram::default()),
            throughput: Arc::new(Throughput::new()),
            stop: Arc::new(AtomicBool::new(false)),
            scratch_pool: BufPool::new(workers.max(1)),
            workers: ThreadPool::new(workers.max(1)),
            worker_count: workers.max(1),
            active_conns: Arc::new(AtomicUsize::new(0)),
        }
    }

    /// Scratch-pool counters (hit rate is the allocation-reuse metric).
    pub fn pool_stats(&self) -> crate::util::pool::PoolStats {
        self.scratch_pool.stats()
    }

    /// Bind and serve on a background thread; returns the local address
    /// and a join handle. `addr` like "127.0.0.1:0" picks a free port.
    pub fn spawn(self: Arc<Self>, addr: &str) -> Result<(std::net::SocketAddr, std::thread::JoinHandle<()>)> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        let me = Arc::clone(&self);
        let handle = std::thread::spawn(move || {
            for conn in listener.incoming() {
                if me.stop.load(Ordering::Relaxed) {
                    break;
                }
                match conn {
                    Ok(stream) => {
                        me.counters.inc_connections();
                        let me2 = Arc::clone(&me);
                        let assigned =
                            me.active_conns.fetch_add(1, Ordering::SeqCst);
                        let job = move || {
                            // Decrement on all exits, including panics
                            // (a leak here would push every later
                            // connection onto overflow threads).
                            struct Dec(Arc<AtomicUsize>);
                            impl Drop for Dec {
                                fn drop(&mut self) {
                                    self.0.fetch_sub(1, Ordering::SeqCst);
                                }
                            }
                            let _dec = Dec(Arc::clone(&me2.active_conns));
                            if let Err(e) = me2.serve_conn(stream) {
                                crate::log_debug!("cloud", "connection ended: {e:#}");
                            }
                        };
                        if assigned < me.worker_count {
                            me.workers.submit(job);
                        } else {
                            // All pooled lanes are parked on long-lived
                            // connections: overflow to a dedicated
                            // thread so this connection (possibly a
                            // Stats/Shutdown control frame) is served.
                            std::thread::spawn(job);
                        }
                    }
                    Err(e) => {
                        crate::log_warn!("cloud", "accept error: {e}");
                    }
                }
            }
        });
        Ok((local, handle))
    }

    fn serve_conn(&self, stream: TcpStream) -> Result<()> {
        stream.set_nodelay(true).ok();
        let mut writer = stream.try_clone()?;
        let mut reader = BufReader::new(stream);
        let mut scratch = self.scratch_pool.get();
        loop {
            let recv = match proto::read_frame_into(&mut reader, &mut scratch.frame) {
                Ok(r) => r,
                Err(_) => return Ok(()), // peer closed mid-frame
            };
            let kind = match recv {
                RecvFrame::Data(k) => k,
                RecvFrame::Eof => return Ok(()),
                RecvFrame::Malformed { reason, resync } => {
                    self.counters.inc_errors();
                    proto::write_frame_raw(&mut writer, proto::KIND_ERROR, reason.as_bytes())?;
                    if resync {
                        continue; // stream still framed; keep serving
                    }
                    return Ok(()); // length prefix unusable; close
                }
            };
            let t0 = Instant::now();
            let Scratch { frame, values, floats, codec, wire } = &mut *scratch;
            match kind {
                proto::KIND_FEATURES => {
                    self.counters.inc_requests();
                    self.throughput.observe(1);
                    self.counters.add_bytes(frame.len() as u64);
                    match self.handle_features(frame, codec, values, floats) {
                        Ok(()) => {
                            proto::write_logits_frame(&mut writer, floats, wire)?;
                        }
                        Err(e) => {
                            self.counters.inc_errors();
                            proto::write_frame_raw(
                                &mut writer,
                                proto::KIND_ERROR,
                                format!("{e:#}").as_bytes(),
                            )?;
                        }
                    }
                    self.service_hist.record(t0.elapsed().as_secs_f64());
                }
                proto::KIND_IMAGE => {
                    self.counters.inc_requests();
                    self.throughput.observe(1);
                    self.counters.add_bytes(frame.len() as u64);
                    let result = if frame.len() < 4 {
                        Err(anyhow!("short image frame"))
                    } else {
                        let model_id = u16::from_le_bytes([frame[0], frame[1]]);
                        self.handle_image(model_id, &frame[4..], floats)
                    };
                    match result {
                        Ok(()) => {
                            proto::write_logits_frame(&mut writer, floats, wire)?;
                        }
                        Err(e) => {
                            self.counters.inc_errors();
                            proto::write_frame_raw(
                                &mut writer,
                                proto::KIND_ERROR,
                                format!("{e:#}").as_bytes(),
                            )?;
                        }
                    }
                    self.service_hist.record(t0.elapsed().as_secs_f64());
                }
                proto::KIND_STATS => {
                    let json = self.stats_json();
                    proto::write_frame_raw(&mut writer, proto::KIND_STATS_REPLY, json.as_bytes())?;
                }
                proto::KIND_PROBE => {
                    // Bandwidth probe: acknowledge immediately; the edge
                    // times the (throttled) upload of the padding.
                    self.counters.add_bytes(frame.len() as u64);
                    proto::write_frame_raw(&mut writer, proto::KIND_PROBE_ACK, &[])?;
                }
                proto::KIND_SHUTDOWN => {
                    self.stop.store(true, Ordering::Relaxed);
                    // The accept loop unblocks on the next connection
                    // (`request_shutdown` makes one).
                    return Ok(());
                }
                other => {
                    proto::write_frame_raw(
                        &mut writer,
                        proto::KIND_ERROR,
                        format!("unexpected frame kind {other}").as_bytes(),
                    )?;
                }
            }
        }
    }

    fn stats_json(&self) -> String {
        let (req, err, bytes, _) = self.counters.snapshot();
        let ps = self.scratch_pool.stats();
        let hist = self.service_hist.snapshot();
        let (p50, p95) = if hist.is_empty() {
            (0.0, 0.0)
        } else {
            (hist.percentile(50.0) * 1e3, hist.percentile(95.0) * 1e3)
        };
        Json::obj(vec![
            ("requests", Json::num(req as f64)),
            ("errors", Json::num(err as f64)),
            ("bytes_rx", Json::num(bytes as f64)),
            ("compiled", Json::num(self.exe.cached_count() as f64)),
            ("connections", Json::num(self.counters.connections() as f64)),
            ("pool_hits", Json::num(ps.hits as f64)),
            ("pool_misses", Json::num(ps.misses as f64)),
            ("req_per_sec", Json::num(self.throughput.per_second())),
            ("service_p50_ms", Json::num(p50)),
            ("service_p95_ms", Json::num(p95)),
        ])
        .to_string()
    }

    /// Decode a feature frame and finish inference; the logits land in
    /// `logits` (reused). All buffers are the connection's scratch.
    fn handle_features(
        &self,
        bytes: &[u8],
        ws: &mut CodecScratch,
        values: &mut Vec<u16>,
        logits: &mut Vec<f32>,
    ) -> Result<()> {
        let h = feature::decode_into(bytes, ws, values).map_err(anyhow::Error::new)?;
        let model = &self
            .manifest
            .models
            .get(h.model as usize)
            .ok_or_else(|| anyhow!("bad model id {}", h.model))?
            .name;
        let m = self.manifest.model(model)?;
        let i = h.stage as usize;
        if i == 0 || i > m.num_stages() {
            return Err(anyhow!("bad stage {i}"));
        }
        let out_shape = &m.stages[i - 1].out_shape;
        let n = m.num_stages();
        // One locked region for the whole tail keeps per-request lock
        // traffic to a single acquisition.
        self.exe.with(|e| {
            let mut cur = e.run_dequant_parts(values, h.lo, h.hi, h.c, out_shape)?;
            for j in i + 1..=n {
                cur = e.run_stage(model, j, &cur)?.tensor;
            }
            logits.clear();
            logits.extend_from_slice(cur.data());
            Ok(())
        })
    }

    fn handle_image(&self, model_id: u16, png_bytes: &[u8], logits: &mut Vec<f32>) -> Result<()> {
        let model = &self
            .manifest
            .models
            .get(model_id as usize)
            .ok_or_else(|| anyhow!("bad model id {model_id}"))?
            .name;
        let m = self.manifest.model(model)?;
        let img = png::decode(png_bytes).map_err(anyhow::Error::new)?;
        let x = crate::data::gen::from_rgb8(&img.data, m.input_shape.clone());
        let out = self.exe.run_full(model, &x)?;
        logits.clear();
        logits.extend_from_slice(out.tensor.data());
        Ok(())
    }

    /// Ask a running server (possibly in another process) to stop.
    pub fn request_shutdown(addr: std::net::SocketAddr) {
        if let Ok(mut s) = TcpStream::connect(addr) {
            let _ = proto::Frame::Shutdown.write_to(&mut s);
        }
        // One more connect unblocks the accept loop.
        let _ = TcpStream::connect(addr);
    }
}
