//! Length-prefixed wire protocol between edge and cloud.
//!
//! Frame layout: `[len: u32 LE][kind: u8][payload: len-1 bytes]`.
//! `len` counts kind + payload. Payloads:
//!
//! * `Features` — a `compression::feature` frame (self-describing:
//!   model id, stage, c, range, entropy-coded values);
//! * `Image` — `[model_id u16][hw u16][png-like bytes]` for the
//!   cloud-only path;
//! * `Logits` — `[count u16][count × f32]`, optionally followed by a
//!   self-describing [`CloudTelemetry`] block (the control plane's
//!   piggyback channel). Telemetry-aware readers accept frames with
//!   or without the block and skip unknown trailing fields inside it,
//!   so writers can omit it or extend it freely; note the cloud
//!   attaches it unconditionally, so in a mixed-version rollout the
//!   *edges* must be upgraded first (a pre-telemetry reader rejects
//!   trailing bytes);
//! * `Busy` — admission control shed the request; payload is the same
//!   telemetry block so the edge can re-decouple off the refusal;
//! * `Stats` / `StatsReply` — queries the cloud's counters;
//! * `Shutdown` — graceful server stop (tests).
//!
//! Two API levels:
//!
//! * the raw functions ([`read_frame_into`], [`write_frame_raw`],
//!   [`write_frame_parts`], [`write_logits_frame`]) move borrowed bytes
//!   in and out of caller-owned buffers — the serving hot path; zero
//!   allocations once the connection's buffer is warm;
//! * the typed [`Frame`] enum wraps them for tests, tools and cold
//!   paths.
//!
//! Malformed input (oversized length, unknown kind) is reported as data
//! — [`RecvFrame::Malformed`] / [`Frame::Error`] — rather than an `Err`
//! that tears down the connection; only genuine I/O failures are errors.

use std::io::{Read, Write};

use anyhow::{anyhow, Result};

pub const KIND_FEATURES: u8 = 1;
pub const KIND_IMAGE: u8 = 2;
pub const KIND_LOGITS: u8 = 3;
pub const KIND_STATS: u8 = 4;
pub const KIND_STATS_REPLY: u8 = 5;
pub const KIND_SHUTDOWN: u8 = 6;
pub const KIND_ERROR: u8 = 7;
pub const KIND_PROBE: u8 = 8;
pub const KIND_PROBE_ACK: u8 = 9;
pub const KIND_BUSY: u8 = 10;
/// Integrity envelope: `[crc32 u32 LE][inner kind u8][inner payload]`.
/// An edge running under a lossy uplink (or a fault plan) wraps its
/// requests so silent byte corruption is *detected* at the cloud — the
/// entropy codecs happily decode flipped bits into valid-but-wrong
/// values — and answered with an `Error` frame the edge can retry,
/// instead of a wrong prediction. Opt-in per connection; an unwrapped
/// frame is served exactly as before.
pub const KIND_CHECKED: u8 = 11;
/// Registry control plane (see `server::registry`). Request a signed
/// manifest: payload is a UTF-8 version name, empty = active version.
pub const KIND_MANIFEST_REQ: u8 = 12;
/// Signed manifest reply: `[sig hi u64 LE][sig lo u64 LE][manifest JSON]`.
/// The detached signature covers exactly the JSON bytes; the edge
/// verifies it *before* parsing, and parses nothing unsigned.
pub const KIND_MANIFEST: u8 = 13;
/// Request a content-addressed chunk: `[hash hi u64 LE][hash lo u64 LE]`.
pub const KIND_CHUNK_REQ: u8 = 14;
/// Chunk reply: `[hash hi u64 LE][hash lo u64 LE][chunk bytes]`. The
/// edge re-hashes the bytes while reading and rejects on mismatch with
/// the *requested* hash — the echoed header is routing, not trust.
pub const KIND_CHUNK: u8 = 15;
/// Subscribe to version announcements (empty payload). The registry
/// answers with the active version immediately and pushes a
/// [`KIND_VERSION`] frame on every activate/rollback thereafter.
pub const KIND_SUBSCRIBE: u8 = 16;
/// Version announcement: payload is the active version name (UTF-8).
/// One of these is the entire rollback path: edges that subscribed
/// flip their active pointer on receipt.
pub const KIND_VERSION: u8 = 17;

/// Hard cap on frame size. Our largest legitimate payload is a VGG
/// stage-1 feature map (224·224·64 values) bit-packed at c=16 ≈ 6.4 MB;
/// 16 MB leaves headroom without letting a corrupt length prefix commit
/// us to a quarter-gigabyte read (the seed cap was 256 MB).
pub const MAX_FRAME: usize = 16 * 1024 * 1024;

/// Outcome of [`read_frame_into`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum RecvFrame {
    /// A well-formed frame of this kind; the payload bytes are in the
    /// caller's buffer.
    Data(u8),
    /// Protocol violation. `resync` says whether the stream is still
    /// aligned on a frame boundary (unknown kind: payload was consumed,
    /// keep serving) or not (bad length prefix: reply then close).
    Malformed { reason: &'static str, resync: bool },
    /// Clean EOF before the first byte of a new frame.
    Eof,
}

/// `read_exact` that distinguishes clean EOF at a frame boundary
/// (returns `Ok(false)`) from truncation mid-read (an error).
fn read_exact_or_eof(r: &mut impl Read, buf: &mut [u8]) -> Result<bool> {
    let mut got = 0;
    while got < buf.len() {
        let n = r.read(&mut buf[got..])?;
        if n == 0 {
            if got == 0 {
                return Ok(false);
            }
            return Err(anyhow!("connection closed mid-frame"));
        }
        got += n;
    }
    Ok(true)
}

/// Read one frame into `buf` (cleared and reused — the connection's
/// receive path allocates nothing once the buffer is warm). On success
/// `buf` holds the payload and the kind byte is returned.
pub fn read_frame_into(r: &mut impl Read, buf: &mut Vec<u8>) -> Result<RecvFrame> {
    let mut lenb = [0u8; 4];
    if !read_exact_or_eof(r, &mut lenb)? {
        return Ok(RecvFrame::Eof);
    }
    let len = u32::from_le_bytes(lenb) as usize;
    if len == 0 || len > MAX_FRAME {
        return Ok(RecvFrame::Malformed { reason: "bad frame length", resync: false });
    }
    let mut kind = [0u8; 1];
    r.read_exact(&mut kind)?;
    buf.clear();
    // `take` + `read_to_end` appends straight into the reused capacity —
    // no zero-fill of up to MAX_FRAME bytes that `resize` would memset
    // only for `read_exact` to overwrite.
    let want = (len - 1) as u64;
    let got = r.by_ref().take(want).read_to_end(buf)?;
    if (got as u64) < want {
        return Err(anyhow!("connection closed mid-frame"));
    }
    if !(KIND_FEATURES..=KIND_VERSION).contains(&kind[0]) {
        return Ok(RecvFrame::Malformed { reason: "unknown frame kind", resync: true });
    }
    Ok(RecvFrame::Data(kind[0]))
}

/// Outcome of one [`FrameAssembler::poll_frame`] call.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Assembled {
    /// A complete frame, with exactly the semantics of
    /// [`read_frame_into`]'s return (payload in the caller's buffer
    /// for `Data`).
    Frame(RecvFrame),
    /// The transport ran dry mid-frame (`WouldBlock`); progress is
    /// saved — call again when the fd is readable.
    NeedMore,
}

/// Incremental, resumable reader of the wire framing — the same
/// protocol as [`read_frame_into`], restated as a state machine over a
/// *nonblocking* transport. `ErrorKind::WouldBlock` pauses the frame
/// (header progress is kept internally, payload progress in the
/// caller's buffer) instead of erroring, so one reactor thread can
/// interleave thousands of half-received frames.
///
/// Guarantees the reactor leans on:
/// * never reads past the current frame's end (pausing a connection
///   mid-stream cannot swallow the next frame's bytes);
/// * every call either makes progress, returns a frame, or reports
///   `NeedMore` after the transport returned `WouldBlock` — a caller
///   that only polls on readiness cannot busy-loop;
/// * malformed input surfaces exactly like the blocking reader:
///   unknown kind consumes its payload and resyncs, a bad length
///   prefix is sticky ([`RecvFrame::Malformed`] with `resync: false`
///   from then on — the stream can no longer be framed).
#[derive(Debug, Default)]
pub struct FrameAssembler {
    /// The 5 header bytes (`len u32 LE` + `kind`) as received so far.
    head: [u8; 5],
    head_got: usize,
    state: AsmState,
}

#[derive(Debug, Clone, Copy, Default)]
enum AsmState {
    /// Collecting the header; `head_got` bytes so far.
    #[default]
    Head,
    /// Header complete; collecting `want` payload bytes into the
    /// caller's buffer.
    Payload { kind: u8, want: usize },
    /// An unrecoverable length-prefix violation was seen; the stream
    /// cannot be re-framed.
    Broken(&'static str),
}

impl FrameAssembler {
    pub fn new() -> Self {
        Self::default()
    }

    /// At a frame boundary with nothing buffered? (Used to distinguish
    /// an idle connection from one that died mid-frame.)
    pub fn is_idle(&self) -> bool {
        matches!(self.state, AsmState::Head) && self.head_got == 0
    }

    /// Drive the assembler over whatever `r` has right now. `buf` is
    /// the frame's payload accumulator — the caller passes the same
    /// (per-connection) buffer until a frame completes; like
    /// [`read_frame_into`] it is cleared at each frame start and holds
    /// the full payload when `Frame(Data(_))` returns.
    pub fn poll_frame(&mut self, r: &mut impl Read, buf: &mut Vec<u8>) -> Result<Assembled> {
        loop {
            match self.state {
                AsmState::Broken(reason) => {
                    return Ok(Assembled::Frame(RecvFrame::Malformed { reason, resync: false }))
                }
                AsmState::Head => {
                    // Length first: a bad prefix must be rejected as
                    // soon as its 4 bytes are in, before demanding a
                    // kind byte that may never come (exactly when
                    // `read_frame_into` rejects it).
                    while self.head_got < 4 {
                        match r.read(&mut self.head[self.head_got..4]) {
                            Ok(0) if self.head_got == 0 => {
                                return Ok(Assembled::Frame(RecvFrame::Eof))
                            }
                            Ok(0) => return Err(anyhow!("connection closed mid-frame")),
                            Ok(n) => self.head_got += n,
                            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                                return Ok(Assembled::NeedMore)
                            }
                            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                            Err(e) => return Err(e.into()),
                        }
                    }
                    let len = u32::from_le_bytes(self.head[..4].try_into().unwrap()) as usize;
                    if len == 0 || len > MAX_FRAME {
                        self.state = AsmState::Broken("bad frame length");
                        continue;
                    }
                    while self.head_got < 5 {
                        match r.read(&mut self.head[4..5]) {
                            Ok(0) => return Err(anyhow!("connection closed mid-frame")),
                            Ok(n) => self.head_got += n,
                            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                                return Ok(Assembled::NeedMore)
                            }
                            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                            Err(e) => return Err(e.into()),
                        }
                    }
                    buf.clear();
                    self.state = AsmState::Payload { kind: self.head[4], want: len - 1 };
                }
                AsmState::Payload { kind, want } => {
                    if buf.len() < want {
                        // `take` + `read_to_end` appends straight into the
                        // reused capacity and — per its contract — keeps
                        // the bytes already appended when it errors, so a
                        // WouldBlock pause loses nothing and never reads
                        // past the frame boundary.
                        match r.by_ref().take((want - buf.len()) as u64).read_to_end(buf) {
                            Ok(_) if buf.len() < want => {
                                return Err(anyhow!("connection closed mid-frame"))
                            }
                            Ok(_) => {}
                            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                                return Ok(Assembled::NeedMore)
                            }
                            Err(e) => return Err(e.into()),
                        }
                    }
                    self.state = AsmState::Head;
                    self.head_got = 0;
                    if !(KIND_FEATURES..=KIND_VERSION).contains(&kind) {
                        return Ok(Assembled::Frame(RecvFrame::Malformed {
                            reason: "unknown frame kind",
                            resync: true,
                        }));
                    }
                    return Ok(Assembled::Frame(RecvFrame::Data(kind)));
                }
            }
        }
    }
}

/// Buffered partial writes for a nonblocking socket: reply bytes are
/// staged here (it implements `Write`, so the reply builders target it
/// directly), then [`Outbox::flush_to`] moves as much as the kernel
/// will take and keeps the rest for the next writability event. The
/// threadpool transport never needs this — its sockets block — but the
/// reactor must never park its one thread in `write_all`.
#[derive(Debug, Default)]
pub struct Outbox {
    buf: Vec<u8>,
    /// Bytes of `buf` already written to the socket.
    pos: usize,
}

/// Compact the outbox once the flushed prefix passes this (keeps one
/// slow reader from pinning every reply it ever drained).
const OUTBOX_COMPACT_BYTES: usize = 64 * 1024;

impl Outbox {
    pub fn new() -> Self {
        Self::default()
    }

    /// Nothing left to write?
    pub fn is_empty(&self) -> bool {
        self.pos >= self.buf.len()
    }

    /// Bytes still awaiting the socket.
    pub fn pending(&self) -> usize {
        self.buf.len() - self.pos
    }

    /// Queue reply bytes (no I/O — call [`Outbox::flush_to`] after).
    pub fn push(&mut self, bytes: &[u8]) {
        if self.pos == self.buf.len() {
            self.buf.clear();
            self.pos = 0;
        } else if self.pos > OUTBOX_COMPACT_BYTES {
            self.buf.drain(..self.pos);
            self.pos = 0;
        }
        self.buf.extend_from_slice(bytes);
    }

    /// Write as much as `w` accepts right now. `Ok(true)` when the
    /// outbox drained, `Ok(false)` when the socket pushed back
    /// (`WouldBlock` — re-arm for writability); genuine I/O failures
    /// are errors.
    pub fn flush_to(&mut self, w: &mut impl Write) -> std::io::Result<bool> {
        while self.pos < self.buf.len() {
            match w.write(&self.buf[self.pos..]) {
                Ok(0) => {
                    return Err(std::io::Error::new(
                        std::io::ErrorKind::WriteZero,
                        "socket accepted no bytes",
                    ))
                }
                Ok(n) => self.pos += n,
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => return Ok(false),
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                Err(e) => return Err(e),
            }
        }
        self.buf.clear();
        self.pos = 0;
        Ok(true)
    }
}

impl Write for Outbox {
    fn write(&mut self, bytes: &[u8]) -> std::io::Result<usize> {
        self.push(bytes);
        Ok(bytes.len())
    }

    fn flush(&mut self) -> std::io::Result<()> {
        Ok(())
    }
}

/// Write one frame whose payload is the concatenation of `parts` — no
/// staging buffer, whatever the part count (the Image path prepends a
/// 4-byte header, a tenant-scoped edge appends a trailer).
pub fn write_frame_vec(w: &mut impl Write, kind: u8, parts: &[&[u8]]) -> Result<usize> {
    let payload_len: usize = parts.iter().map(|p| p.len()).sum();
    if payload_len + 1 > MAX_FRAME {
        return Err(anyhow!("frame too large: {payload_len} bytes"));
    }
    let len = (payload_len + 1) as u32;
    w.write_all(&len.to_le_bytes())?;
    w.write_all(&[kind])?;
    for p in parts {
        if !p.is_empty() {
            w.write_all(p)?;
        }
    }
    w.flush()?;
    Ok(4 + 1 + payload_len)
}

/// Write one frame whose payload is `head` followed by `body` (lets the
/// Image path prepend its 4-byte header without assembling a payload).
pub fn write_frame_parts(w: &mut impl Write, kind: u8, head: &[u8], body: &[u8]) -> Result<usize> {
    write_frame_vec(w, kind, &[head, body])
}

/// Write one frame from a borrowed payload (no clone, no staging Vec).
pub fn write_frame_raw(w: &mut impl Write, kind: u8, payload: &[u8]) -> Result<usize> {
    write_frame_parts(w, kind, &[], payload)
}

/// CRC-32 (IEEE 802.3, the zlib polynomial), table-driven and built at
/// compile time — the vendor set has no checksum crate.
const fn crc32_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

static CRC32_TABLE: [u32; 256] = crc32_table();

/// Incremental CRC-32 over scattered byte slices.
#[derive(Debug, Clone, Copy)]
pub struct Crc32(u32);

impl Default for Crc32 {
    fn default() -> Self {
        Self::new()
    }
}

impl Crc32 {
    pub fn new() -> Self {
        Self(0xFFFF_FFFF)
    }

    pub fn update(&mut self, bytes: &[u8]) {
        let mut c = self.0;
        for &b in bytes {
            c = CRC32_TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
        }
        self.0 = c;
    }

    pub fn finish(&self) -> u32 {
        !self.0
    }
}

/// One-shot CRC-32 of a contiguous slice.
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut c = Crc32::new();
    c.update(bytes);
    c.finish()
}

/// Bytes [`write_checked_frame_vec`] prepends to the inner payload
/// (`crc32 u32 LE` + inner kind).
pub const CHECKED_HEAD_LEN: usize = 5;

/// Error-frame payload the cloud answers a failed [`unwrap_checked`]
/// with. The edge matches this exact message to tell "your bytes
/// arrived damaged, send them again" apart from semantic errors that
/// a re-send can never fix.
pub const INTEGRITY_REJECT: &[u8] = b"checked frame integrity failure";

/// Write an integrity-wrapped frame: the inner frame's kind and payload
/// (as scattered `parts`) are shipped under [`KIND_CHECKED`] with a
/// CRC-32 over `[inner kind][inner payload]` leading the envelope. No
/// staging buffer — the CRC streams over the same borrowed parts the
/// socket write does.
pub fn write_checked_frame_vec(w: &mut impl Write, inner_kind: u8, parts: &[&[u8]]) -> Result<usize> {
    let mut c = Crc32::new();
    c.update(&[inner_kind]);
    for p in parts {
        c.update(p);
    }
    let mut head = [0u8; CHECKED_HEAD_LEN];
    head[..4].copy_from_slice(&c.finish().to_le_bytes());
    head[4] = inner_kind;
    let mut all: Vec<&[u8]> = Vec::with_capacity(parts.len() + 1);
    all.push(&head);
    all.extend_from_slice(parts);
    write_frame_vec(w, KIND_CHECKED, &all)
}

/// Verify and open a [`KIND_CHECKED`] payload. Returns the inner kind
/// and the offset where the inner payload starts; a CRC mismatch, a
/// short envelope, or a nested/unknown inner kind is an `Err` (the
/// server answers it with an `Error` frame — the stream itself is still
/// aligned, so the connection survives and the edge retries).
pub fn unwrap_checked(payload: &[u8]) -> Result<(u8, usize)> {
    if payload.len() < CHECKED_HEAD_LEN {
        return Err(anyhow!("short checked frame"));
    }
    let want = u32::from_le_bytes(payload[..4].try_into().unwrap());
    let got = crc32(&payload[4..]);
    if want != got {
        return Err(anyhow!("checked frame integrity failure"));
    }
    let kind = payload[4];
    if !(KIND_FEATURES..=KIND_BUSY).contains(&kind) {
        return Err(anyhow!("checked frame wraps unknown kind {kind}"));
    }
    Ok((kind, CHECKED_HEAD_LEN))
}

/// Marker byte opening a [`CloudTelemetry`] block. Chosen outside the
/// printable range so a truncated/garbage tail cannot masquerade as
/// telemetry by accident *and* fail to length-check.
pub const TELEMETRY_MAGIC: u8 = 0xC7;

/// Three-byte magic closing a tenant trailer ("J", "T", then a byte
/// outside the printable range). The trailer is parsed from the *end*
/// of a request payload, so it needs its own framing rather than an
/// offset from the front: the Image payload's deflate stream is not
/// self-delimiting, and the trailer must be findable without decoding
/// the body it rides behind. Three magic bytes plus a validated length
/// byte push the odds of a pre-tenant payload masquerading as a
/// trailer below ~2⁻²⁴ per frame — and the Features path eliminates
/// even that by cross-checking the codec header's declared length
/// (`feature::frame_len`) before looking for a trailer at all.
pub const TENANT_MAGIC: [u8; 3] = [0x4A, 0x54, 0xA9];

/// Byte length of the current tenant-trailer field set (just the
/// tenant id today; future writers may append fields and bump the
/// declared length — readers take the prefix they know).
const TENANT_FIELDS_LEN: usize = 4;

/// Total wire bytes [`append_tenant_trailer`] adds.
pub const TENANT_TRAILER_LEN: usize = TENANT_FIELDS_LEN + 4;

/// Append a tenant trailer to a request payload:
/// `[fields: len bytes][len u8][0x4A][0x54][0xA9]`, where the fields
/// are currently `tenant u32 LE`. A request without a trailer is
/// exactly the pre-tenant wire format, so a zero-config edge ships
/// bit-identical frames; the cloud then scopes the request to an
/// implicit per-connection tenant.
pub fn append_tenant_trailer(tenant: u32, buf: &mut Vec<u8>) {
    buf.extend_from_slice(&tenant.to_le_bytes());
    buf.push(TENANT_FIELDS_LEN as u8);
    buf.extend_from_slice(&TENANT_MAGIC);
}

/// Split a request payload into `(body_len, tenant)`: when the payload
/// ends with a well-formed tenant trailer, `body_len` is the payload
/// length without it and the tenant id is returned; otherwise the whole
/// payload is body. New-format senders always append a real trailer,
/// which — being parsed from the absolute end — wins unambiguously over
/// any trailer-looking bytes inside the body.
pub fn split_tenant_trailer(payload: &[u8]) -> (usize, Option<u32>) {
    let n = payload.len();
    if n < TENANT_TRAILER_LEN || payload[n - 3..] != TENANT_MAGIC {
        return (n, None);
    }
    let len = payload[n - 4] as usize;
    if len < TENANT_FIELDS_LEN || len + 4 > n {
        return (n, None);
    }
    let fields = &payload[n - 4 - len..n - 4];
    let tenant = u32::from_le_bytes(fields[..4].try_into().unwrap());
    (n - 4 - len, Some(tenant))
}

/// Compact cloud-load block piggybacked on every `Logits` reply and
/// carried as the whole payload of a `Busy` shed. This is the signal
/// half of the §III-E closed loop: the edge fuses it with its own
/// bandwidth estimate and re-solves the decoupling ILP when either
/// drifts.
///
/// Wire layout: `[0xC7][len u8][fields: len bytes]` where the fields
/// are `queue_wait_p95_ms f32 | utilization f32 | batch_occupancy f32
/// | flags u8 (bit 0 = shedding) | sheds u32 | tenant_backoff_ms f32`,
/// all LE. The explicit length makes the block self-describing:
/// readers skip fields they don't know, accept blocks shorter than the
/// current set (a pre-tenant writer's 17-byte block parses with the
/// new fields at their defaults), writers may append new ones, and a
/// logits frame without any block stays exactly the pre-telemetry
/// format.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct CloudTelemetry {
    /// p95 of the batch-engine queue wait over the last sampling
    /// window, milliseconds.
    pub queue_wait_p95_ms: f32,
    /// Busiest shard's busy fraction over the last sampling window,
    /// 0..1 (can exceed 1 transiently when a hold spans the window).
    pub utilization: f32,
    /// Recent mean requests per executed micro-batch (EWMA).
    pub batch_occupancy: f32,
    /// Admission control is currently over budget (new data requests
    /// are being shed).
    pub shedding: bool,
    /// Total requests shed since the server started.
    pub sheds: u32,
    /// Per-tenant backoff hint, milliseconds: on a `Busy` shed, how
    /// long *this* tenant should pace its next attempt (≈ the time
    /// until its fair-share admission credit refills). 0 means no
    /// hint — the legacy immediate-retry contract.
    pub tenant_backoff_ms: f32,
}

/// Byte length of the pre-tenant telemetry field set (excluding the
/// 2-byte magic+len header) — the minimum a well-formed block carries.
const TELEMETRY_FIELDS_LEN: usize = 4 + 4 + 4 + 1 + 4;

/// Byte length of the full current field set (adds the per-tenant
/// backoff hint).
const TELEMETRY_FIELDS_LEN_FULL: usize = TELEMETRY_FIELDS_LEN + 4;

impl CloudTelemetry {
    /// Append the block to `buf` (magic + length + fields).
    pub fn encode_into(&self, buf: &mut Vec<u8>) {
        buf.push(TELEMETRY_MAGIC);
        buf.push(TELEMETRY_FIELDS_LEN_FULL as u8);
        buf.extend_from_slice(&self.queue_wait_p95_ms.to_le_bytes());
        buf.extend_from_slice(&self.utilization.to_le_bytes());
        buf.extend_from_slice(&self.batch_occupancy.to_le_bytes());
        buf.push(self.shedding as u8);
        buf.extend_from_slice(&self.sheds.to_le_bytes());
        buf.extend_from_slice(&self.tenant_backoff_ms.to_le_bytes());
    }

    /// Decode a block from the front of `bytes`; returns the telemetry
    /// and the total bytes consumed (header + declared length), or
    /// `None` when `bytes` does not start with a well-formed block.
    /// Unknown trailing fields inside the declared length are skipped;
    /// fields a shorter (older) block omits decode to their defaults.
    pub fn decode(bytes: &[u8]) -> Option<(CloudTelemetry, usize)> {
        if bytes.len() < 2 || bytes[0] != TELEMETRY_MAGIC {
            return None;
        }
        let len = bytes[1] as usize;
        if len < TELEMETRY_FIELDS_LEN || bytes.len() < 2 + len {
            return None;
        }
        let f = &bytes[2..];
        let f32_at = |o: usize| f32::from_le_bytes(f[o..o + 4].try_into().unwrap());
        Some((
            CloudTelemetry {
                queue_wait_p95_ms: f32_at(0),
                utilization: f32_at(4),
                batch_occupancy: f32_at(8),
                shedding: f[12] != 0,
                sheds: u32::from_le_bytes(f[13..17].try_into().unwrap()),
                tenant_backoff_ms: if len >= TELEMETRY_FIELDS_LEN_FULL { f32_at(17) } else { 0.0 },
            },
            2 + len,
        ))
    }
}

/// Serialize `logits` into `scratch` (reused) and ship a Logits frame.
pub fn write_logits_frame(w: &mut impl Write, logits: &[f32], scratch: &mut Vec<u8>) -> Result<usize> {
    write_logits_frame_with(w, logits, None, scratch)
}

/// [`write_logits_frame`] with an optional piggybacked telemetry block.
pub fn write_logits_frame_with(
    w: &mut impl Write,
    logits: &[f32],
    telemetry: Option<&CloudTelemetry>,
    scratch: &mut Vec<u8>,
) -> Result<usize> {
    if logits.len() > u16::MAX as usize {
        return Err(anyhow!("too many logits: {}", logits.len()));
    }
    scratch.clear();
    scratch.extend_from_slice(&(logits.len() as u16).to_le_bytes());
    for x in logits {
        scratch.extend_from_slice(&x.to_le_bytes());
    }
    if let Some(t) = telemetry {
        t.encode_into(scratch);
    }
    write_frame_raw(w, KIND_LOGITS, scratch)
}

/// Parse a Logits payload into `out` (cleared, capacity reused). A
/// trailing telemetry block, if present, is validated and ignored —
/// use [`parse_logits_telemetry_into`] to read it.
pub fn parse_logits_into(payload: &[u8], out: &mut Vec<f32>) -> Result<()> {
    parse_logits_telemetry_into(payload, out).map(|_| ())
}

/// Parse a Logits payload into `out` and return the piggybacked
/// [`CloudTelemetry`] when the sender attached one.
pub fn parse_logits_telemetry_into(
    payload: &[u8],
    out: &mut Vec<f32>,
) -> Result<Option<CloudTelemetry>> {
    if payload.len() < 2 {
        return Err(anyhow!("short logits frame"));
    }
    let n = u16::from_le_bytes([payload[0], payload[1]]) as usize;
    let logits_end = 2 + n * 4;
    if payload.len() < logits_end {
        return Err(anyhow!("logits length mismatch"));
    }
    let telemetry = if payload.len() == logits_end {
        None
    } else {
        match CloudTelemetry::decode(&payload[logits_end..]) {
            Some((t, consumed)) if logits_end + consumed == payload.len() => Some(t),
            _ => return Err(anyhow!("logits length mismatch")),
        }
    };
    out.clear();
    out.reserve(n);
    for i in 0..n {
        out.push(f32::from_le_bytes(payload[2 + i * 4..6 + i * 4].try_into().unwrap()));
    }
    Ok(telemetry)
}

#[derive(Debug, Clone, PartialEq)]
pub enum Frame {
    Features(Vec<u8>),
    Image { model_id: u16, hw: u16, png: Vec<u8> },
    Logits(Vec<f32>),
    Stats,
    StatsReply(Vec<u8>),
    Shutdown,
    Error(String),
    /// Active bandwidth probe: opaque padding the cloud discards. Used
    /// when the serving plan's frames are too small to estimate from
    /// (`edge::MIN_ESTIMATE_BYTES`).
    Probe(Vec<u8>),
    ProbeAck,
    /// Admission control refused the request; the telemetry says why
    /// (queue wait / utilization over budget). The edge's contract is
    /// to retry *edge-ward*: re-solve with the reported load and ship
    /// a later cut (§III-E).
    Busy(CloudTelemetry),
}

impl Frame {
    pub fn kind(&self) -> u8 {
        match self {
            Frame::Features(_) => KIND_FEATURES,
            Frame::Image { .. } => KIND_IMAGE,
            Frame::Logits(_) => KIND_LOGITS,
            Frame::Stats => KIND_STATS,
            Frame::StatsReply(_) => KIND_STATS_REPLY,
            Frame::Shutdown => KIND_SHUTDOWN,
            Frame::Error(_) => KIND_ERROR,
            Frame::Probe(_) => KIND_PROBE,
            Frame::ProbeAck => KIND_PROBE_ACK,
            Frame::Busy(_) => KIND_BUSY,
        }
    }

    pub fn write_to(&self, w: &mut impl Write) -> Result<usize> {
        match self {
            Frame::Features(b) => write_frame_raw(w, KIND_FEATURES, b),
            Frame::Image { model_id, hw, png } => {
                let mut head = [0u8; 4];
                head[..2].copy_from_slice(&model_id.to_le_bytes());
                head[2..].copy_from_slice(&hw.to_le_bytes());
                write_frame_parts(w, KIND_IMAGE, &head, png)
            }
            Frame::Logits(v) => {
                let mut scratch = Vec::with_capacity(2 + v.len() * 4);
                write_logits_frame(w, v, &mut scratch)
            }
            Frame::Stats => write_frame_raw(w, KIND_STATS, &[]),
            Frame::StatsReply(b) => write_frame_raw(w, KIND_STATS_REPLY, b),
            Frame::Shutdown => write_frame_raw(w, KIND_SHUTDOWN, &[]),
            Frame::Error(s) => write_frame_raw(w, KIND_ERROR, s.as_bytes()),
            Frame::Probe(b) => write_frame_raw(w, KIND_PROBE, b),
            Frame::ProbeAck => write_frame_raw(w, KIND_PROBE_ACK, &[]),
            Frame::Busy(t) => {
                let mut scratch = Vec::with_capacity(2 + TELEMETRY_FIELDS_LEN_FULL);
                t.encode_into(&mut scratch);
                write_frame_raw(w, KIND_BUSY, &scratch)
            }
        }
    }

    /// Parse a payload read by [`read_frame_into`] into a typed frame.
    pub fn parse(kind: u8, payload: Vec<u8>) -> Result<Frame> {
        Ok(match kind {
            KIND_FEATURES => Frame::Features(payload),
            KIND_IMAGE => {
                if payload.len() < 4 {
                    return Err(anyhow!("short image frame"));
                }
                let model_id = u16::from_le_bytes([payload[0], payload[1]]);
                let hw = u16::from_le_bytes([payload[2], payload[3]]);
                Frame::Image { model_id, hw, png: payload[4..].to_vec() }
            }
            KIND_LOGITS => {
                let mut v = Vec::new();
                parse_logits_into(&payload, &mut v)?;
                Frame::Logits(v)
            }
            KIND_STATS => Frame::Stats,
            KIND_STATS_REPLY => Frame::StatsReply(payload),
            KIND_SHUTDOWN => Frame::Shutdown,
            KIND_ERROR => Frame::Error(String::from_utf8_lossy(&payload).into_owned()),
            KIND_PROBE => Frame::Probe(payload),
            KIND_PROBE_ACK => Frame::ProbeAck,
            KIND_BUSY => {
                // An empty payload is a valid (telemetry-less) shed so
                // a minimal sender can still refuse work.
                if payload.is_empty() {
                    Frame::Busy(CloudTelemetry::default())
                } else {
                    let (t, consumed) = CloudTelemetry::decode(&payload)
                        .ok_or_else(|| anyhow!("malformed busy telemetry"))?;
                    if consumed != payload.len() {
                        return Err(anyhow!("malformed busy telemetry"));
                    }
                    Frame::Busy(t)
                }
            }
            KIND_CHECKED => {
                let (inner, off) = unwrap_checked(&payload)?;
                return Frame::parse(inner, payload[off..].to_vec());
            }
            k => return Err(anyhow!("unknown frame kind {k}")),
        })
    }

    /// Typed read. Malformed frames (bad length prefix, unknown kind)
    /// come back as `Ok(Frame::Error(..))` so a server can answer and —
    /// where the stream is still aligned — keep the connection; only
    /// I/O failures and EOF are `Err`.
    pub fn read_from(r: &mut impl Read) -> Result<Frame> {
        let mut buf = Vec::new();
        match read_frame_into(r, &mut buf)? {
            RecvFrame::Eof => Err(anyhow!("connection closed")),
            RecvFrame::Malformed { reason, .. } => Ok(Frame::Error(reason.to_string())),
            RecvFrame::Data(kind) => Frame::parse(kind, buf),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(f: Frame) {
        let mut buf = Vec::new();
        f.write_to(&mut buf).unwrap();
        let mut r = &buf[..];
        assert_eq!(Frame::read_from(&mut r).unwrap(), f);
        assert!(r.is_empty(), "trailing bytes");
    }

    fn telemetry() -> CloudTelemetry {
        CloudTelemetry {
            queue_wait_p95_ms: 12.5,
            utilization: 0.875,
            batch_occupancy: 3.25,
            shedding: true,
            sheds: 42,
            tenant_backoff_ms: 7.5,
        }
    }

    #[test]
    fn all_frames_roundtrip() {
        roundtrip(Frame::Features(vec![1, 2, 3, 255]));
        roundtrip(Frame::Image { model_id: 3, hw: 32, png: vec![9; 100] });
        roundtrip(Frame::Logits(vec![1.5, -2.25, 0.0]));
        roundtrip(Frame::Stats);
        roundtrip(Frame::StatsReply(b"{}".to_vec()));
        roundtrip(Frame::Shutdown);
        roundtrip(Frame::Error("boom".into()));
        roundtrip(Frame::Probe(vec![0xAB; 64]));
        roundtrip(Frame::ProbeAck);
        roundtrip(Frame::Busy(telemetry()));
        roundtrip(Frame::Busy(CloudTelemetry::default()));
    }

    #[test]
    fn telemetry_block_roundtrips_and_skips_future_fields() {
        let t = telemetry();
        let mut buf = Vec::new();
        t.encode_into(&mut buf);
        let (back, consumed) = CloudTelemetry::decode(&buf).unwrap();
        assert_eq!(back, t);
        assert_eq!(consumed, buf.len());
        // A future writer appends fields and bumps the length: the
        // current reader must consume the whole block and keep the
        // fields it knows.
        let mut extended = buf.clone();
        extended[1] += 3;
        extended.extend_from_slice(&[1, 2, 3]);
        let (back, consumed) = CloudTelemetry::decode(&extended).unwrap();
        assert_eq!(back, t);
        assert_eq!(consumed, extended.len());
        // Truncated or mis-tagged blocks are rejected, not misread.
        assert!(CloudTelemetry::decode(&buf[..buf.len() - 1]).is_none());
        assert!(CloudTelemetry::decode(&[0x00, 17]).is_none());
        assert!(CloudTelemetry::decode(&[]).is_none());
    }

    #[test]
    fn pre_tenant_telemetry_block_still_decodes() {
        // A 17-byte block is exactly what a pre-tenant writer emits:
        // it must parse with the tenant fields at their defaults and
        // consume exactly its declared length.
        let t = telemetry();
        let mut old = Vec::new();
        old.push(TELEMETRY_MAGIC);
        old.push(TELEMETRY_FIELDS_LEN as u8);
        old.extend_from_slice(&t.queue_wait_p95_ms.to_le_bytes());
        old.extend_from_slice(&t.utilization.to_le_bytes());
        old.extend_from_slice(&t.batch_occupancy.to_le_bytes());
        old.push(t.shedding as u8);
        old.extend_from_slice(&t.sheds.to_le_bytes());
        let (back, consumed) = CloudTelemetry::decode(&old).unwrap();
        assert_eq!(consumed, old.len());
        assert_eq!(back, CloudTelemetry { tenant_backoff_ms: 0.0, ..t });
        // And the typed Busy path accepts the old block too.
        let mut framed = Vec::new();
        write_frame_raw(&mut framed, KIND_BUSY, &old).unwrap();
        let f = Frame::read_from(&mut &framed[..]).unwrap();
        assert_eq!(f, Frame::Busy(CloudTelemetry { tenant_backoff_ms: 0.0, ..t }));
    }

    #[test]
    fn tenant_trailer_roundtrips_and_absent_is_pre_tenant() {
        for (body, tenant) in
            [(vec![], 0u32), (vec![1, 2, 3], 7), (vec![0xA9; 40], u32::MAX), (vec![0x4A], 1)]
        {
            let mut p = body.clone();
            append_tenant_trailer(tenant, &mut p);
            assert_eq!(split_tenant_trailer(&p), (body.len(), Some(tenant)), "body {body:?}");
            // Stripping yields exactly the pre-tenant payload.
            assert_eq!(&p[..body.len()], &body[..]);
        }
        // No trailer ⇒ the whole payload is body, no tenant.
        assert_eq!(split_tenant_trailer(&[1, 2, 3, 4, 5, 6, 7, 8]), (8, None));
        assert_eq!(split_tenant_trailer(&[]), (0, None));
        // Magic present but the declared length is impossible: not a
        // trailer (too-short payload, or len below the known fields).
        assert_eq!(split_tenant_trailer(&[9, 0x4A, 0x54, 0xA9]), (4, None));
        let mut bad = vec![0u8; 6];
        bad.extend_from_slice(&[3, 0x4A, 0x54, 0xA9]); // len 3 < TENANT_FIELDS_LEN
        assert_eq!(split_tenant_trailer(&bad), (10, None));
        let mut deep = vec![0u8; 4];
        deep.extend_from_slice(&[200, 0x4A, 0x54, 0xA9]); // len 200 > payload
        assert_eq!(split_tenant_trailer(&deep), (8, None));
        // A truncated magic is body, not a trailer.
        let mut cut = vec![0u8; 5];
        cut.extend_from_slice(&[4, 0x4A, 0xA9]);
        assert_eq!(split_tenant_trailer(&cut), (8, None));
    }

    #[test]
    fn prop_tenant_trailer_exact_on_random_payloads() {
        use crate::util::prop;
        prop::check(
            "tenant trailer splits exactly on arbitrary bodies",
            prop::pair(prop::bytes(0, 512), prop::u64_in(0, u32::MAX as u64)),
            |(body, tenant)| {
                let tenant = *tenant as u32;
                let mut p = body.clone();
                append_tenant_trailer(tenant, &mut p);
                let (n, t) = split_tenant_trailer(&p);
                // A future longer trailer must also strip exactly.
                let mut p2 = body.clone();
                p2.extend_from_slice(&tenant.to_le_bytes());
                p2.extend_from_slice(&[0xEE, 0xFF]); // unknown future fields
                p2.push(6);
                p2.extend_from_slice(&TENANT_MAGIC);
                let (n2, t2) = split_tenant_trailer(&p2);
                n == body.len() && t == Some(tenant) && n2 == body.len() && t2 == Some(tenant)
            },
        );
    }

    #[test]
    fn logits_telemetry_piggyback_is_backward_compatible() {
        let logits = vec![0.5f32, -1.25, 3.75];
        let t = telemetry();
        let mut scratch = Vec::new();
        let mut framed = Vec::new();
        write_logits_frame_with(&mut framed, &logits, Some(&t), &mut scratch).unwrap();

        // A telemetry-aware reader gets both halves.
        let mut parsed = Vec::new();
        let got = parse_logits_telemetry_into(&scratch, &mut parsed).unwrap();
        assert_eq!(parsed, logits);
        assert_eq!(got, Some(t));

        // A legacy-style read (logits only) still parses the same frame.
        let mut legacy = Vec::new();
        parse_logits_into(&scratch, &mut legacy).unwrap();
        assert_eq!(legacy, logits);
        // And the typed reader sees a Logits frame, not an error.
        assert!(matches!(Frame::read_from(&mut &framed[..]).unwrap(), Frame::Logits(v) if v == logits));

        // A frame without the block reports no telemetry.
        let mut bare = Vec::new();
        write_logits_frame(&mut Vec::new(), &logits, &mut bare).unwrap();
        assert_eq!(parse_logits_telemetry_into(&bare, &mut legacy).unwrap(), None);

        // Garbage after the logits is still a length mismatch.
        let mut corrupt = bare.clone();
        corrupt.extend_from_slice(&[1, 2, 3]);
        assert!(parse_logits_telemetry_into(&corrupt, &mut legacy).is_err());
    }

    #[test]
    fn crc32_golden() {
        // The canonical IEEE check value.
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
        // Incremental over scattered slices matches one-shot.
        let mut c = Crc32::new();
        c.update(b"1234");
        c.update(b"");
        c.update(b"56789");
        assert_eq!(c.finish(), 0xCBF4_3926);
    }

    #[test]
    fn checked_frame_roundtrips_and_detects_corruption() {
        let body = vec![7u8; 120];
        let mut framed = Vec::new();
        write_checked_frame_vec(&mut framed, KIND_FEATURES, &[&body[..40], &body[40..]]).unwrap();

        let mut raw = Vec::new();
        let mut r = &framed[..];
        assert_eq!(read_frame_into(&mut r, &mut raw).unwrap(), RecvFrame::Data(KIND_CHECKED));
        let (kind, off) = unwrap_checked(&raw).unwrap();
        assert_eq!(kind, KIND_FEATURES);
        assert_eq!(&raw[off..], &body[..]);

        // The typed reader unwraps transparently.
        let f = Frame::read_from(&mut &framed[..]).unwrap();
        assert_eq!(f, Frame::Features(body.clone()));

        // Any single flipped payload byte fails the CRC, loudly.
        for at in [5, 9, 20, framed.len() - 1] {
            let mut bad = framed.clone();
            bad[at] ^= 0xA5;
            let mut raw = Vec::new();
            let got = read_frame_into(&mut &bad[..], &mut raw).unwrap();
            assert_eq!(got, RecvFrame::Data(KIND_CHECKED), "at={at}");
            assert!(unwrap_checked(&raw).is_err(), "flip at {at} must fail the CRC");
        }

        // Short and nested envelopes are rejected.
        assert!(unwrap_checked(&[1, 2, 3]).is_err());
        let mut nested = Vec::new();
        write_checked_frame_vec(&mut nested, KIND_CHECKED, &[&[0u8; 8]]).unwrap();
        let mut raw = Vec::new();
        read_frame_into(&mut &nested[..], &mut raw).unwrap();
        assert!(unwrap_checked(&raw).is_err(), "nesting is not a thing");
    }

    #[test]
    fn multiple_frames_stream() {
        let mut buf = Vec::new();
        Frame::Features(vec![1]).write_to(&mut buf).unwrap();
        Frame::Logits(vec![2.0]).write_to(&mut buf).unwrap();
        let mut r = &buf[..];
        assert!(matches!(Frame::read_from(&mut r).unwrap(), Frame::Features(_)));
        assert!(matches!(Frame::read_from(&mut r).unwrap(), Frame::Logits(_)));
    }

    #[test]
    fn corrupt_length_reported_not_fatal() {
        let mut buf = Vec::new();
        Frame::Stats.write_to(&mut buf).unwrap();
        buf[0..4].copy_from_slice(&(u32::MAX).to_le_bytes());
        // The bad prefix is data (an Error frame), not a connection-fatal Err.
        assert!(matches!(Frame::read_from(&mut &buf[..]).unwrap(), Frame::Error(_)));
        let mut raw = Vec::new();
        assert_eq!(
            read_frame_into(&mut &buf[..], &mut raw).unwrap(),
            RecvFrame::Malformed { reason: "bad frame length", resync: false }
        );
    }

    #[test]
    fn oversized_length_rejected_before_reading() {
        let mut buf = Vec::new();
        buf.extend_from_slice(&((MAX_FRAME as u32) + 1).to_le_bytes());
        buf.push(KIND_STATS);
        let mut raw = Vec::new();
        let r = read_frame_into(&mut &buf[..], &mut raw).unwrap();
        assert!(matches!(r, RecvFrame::Malformed { resync: false, .. }));
        assert!(raw.is_empty(), "nothing may be buffered for an oversized frame");
    }

    #[test]
    fn unknown_kind_consumes_payload_and_resyncs() {
        let mut buf = Vec::new();
        write_frame_raw(&mut buf, 200, &[1, 2, 3]).unwrap();
        Frame::Stats.write_to(&mut buf).unwrap();
        let mut r = &buf[..];
        let mut raw = Vec::new();
        assert_eq!(
            read_frame_into(&mut r, &mut raw).unwrap(),
            RecvFrame::Malformed { reason: "unknown frame kind", resync: true }
        );
        // The stream is still aligned: the next frame parses cleanly.
        assert_eq!(read_frame_into(&mut r, &mut raw).unwrap(), RecvFrame::Data(KIND_STATS));
    }

    #[test]
    fn truncated_stream_errors() {
        let mut buf = Vec::new();
        Frame::Features(vec![0; 50]).write_to(&mut buf).unwrap();
        assert!(Frame::read_from(&mut &buf[..10]).is_err());
    }

    #[test]
    fn eof_at_boundary_is_clean() {
        let empty: &[u8] = &[];
        let mut raw = Vec::new();
        assert_eq!(read_frame_into(&mut &empty[..], &mut raw).unwrap(), RecvFrame::Eof);
    }

    #[test]
    fn raw_write_matches_typed_write() {
        let payload = vec![7u8; 33];
        let mut typed = Vec::new();
        Frame::Features(payload.clone()).write_to(&mut typed).unwrap();
        let mut raw = Vec::new();
        let n = write_frame_raw(&mut raw, KIND_FEATURES, &payload).unwrap();
        assert_eq!(raw, typed);
        assert_eq!(n, raw.len());

        let logits = vec![0.5f32, -1.25, 3.75];
        let mut typed = Vec::new();
        Frame::Logits(logits.clone()).write_to(&mut typed).unwrap();
        let mut scratch = Vec::new();
        let mut raw = Vec::new();
        write_logits_frame(&mut raw, &logits, &mut scratch).unwrap();
        assert_eq!(raw, typed);
        let mut parsed = Vec::new();
        parse_logits_into(&scratch, &mut parsed).unwrap();
        assert_eq!(parsed, logits);
    }

    #[test]
    fn read_into_reuses_buffer() {
        let mut stream = Vec::new();
        Frame::Features(vec![1; 1000]).write_to(&mut stream).unwrap();
        Frame::Features(vec![2; 10]).write_to(&mut stream).unwrap();
        let mut r = &stream[..];
        let mut buf = Vec::new();
        assert_eq!(read_frame_into(&mut r, &mut buf).unwrap(), RecvFrame::Data(KIND_FEATURES));
        let cap = buf.capacity();
        assert_eq!(read_frame_into(&mut r, &mut buf).unwrap(), RecvFrame::Data(KIND_FEATURES));
        assert_eq!(buf, vec![2; 10]);
        assert_eq!(buf.capacity(), cap, "second read must reuse the first read's buffer");
    }

    /// Serves a byte stream in scripted chunk sizes with a `WouldBlock`
    /// between consecutive chunks — a deterministic stand-in for a
    /// nonblocking socket whose peer dribbles data.
    struct Trickle<'a> {
        data: &'a [u8],
        pos: usize,
        chunk: usize,
        /// Alternates: next read yields data (false) or WouldBlock (true).
        starve: bool,
    }

    impl<'a> Trickle<'a> {
        fn new(data: &'a [u8], chunk: usize) -> Self {
            Self { data, pos: 0, chunk, starve: false }
        }
    }

    impl Read for Trickle<'_> {
        fn read(&mut self, out: &mut [u8]) -> std::io::Result<usize> {
            if self.pos < self.data.len() && self.starve {
                self.starve = false;
                return Err(std::io::ErrorKind::WouldBlock.into());
            }
            self.starve = true;
            let n = self.chunk.min(out.len()).min(self.data.len() - self.pos);
            out[..n].copy_from_slice(&self.data[self.pos..self.pos + n]);
            self.pos += n;
            Ok(n)
        }
    }

    /// Run the assembler over a trickled stream until EOF, collecting
    /// every completed frame (with its payload for `Data`).
    fn assemble_all(stream: &[u8], chunk: usize) -> Vec<(RecvFrame, Vec<u8>)> {
        let mut r = Trickle::new(stream, chunk);
        let mut asm = FrameAssembler::new();
        let mut buf = Vec::new();
        let mut out = Vec::new();
        loop {
            match asm.poll_frame(&mut r, &mut buf).unwrap() {
                Assembled::NeedMore => continue,
                Assembled::Frame(RecvFrame::Eof) => {
                    out.push((RecvFrame::Eof, Vec::new()));
                    return out;
                }
                Assembled::Frame(f) => {
                    let payload =
                        if matches!(f, RecvFrame::Data(_)) { buf.clone() } else { Vec::new() };
                    let stop = matches!(f, RecvFrame::Malformed { resync: false, .. });
                    out.push((f, payload));
                    if stop {
                        return out;
                    }
                }
            }
        }
    }

    #[test]
    fn assembler_matches_blocking_reader_at_any_chunk_size() {
        let mut stream = Vec::new();
        Frame::Features(vec![9u8; 300]).write_to(&mut stream).unwrap();
        Frame::Stats.write_to(&mut stream).unwrap();
        Frame::Logits(vec![1.0, -2.0]).write_to(&mut stream).unwrap();

        // Reference: the blocking reader over the same bytes.
        let mut r = &stream[..];
        let mut buf = Vec::new();
        let mut want = Vec::new();
        loop {
            let f = read_frame_into(&mut r, &mut buf).unwrap();
            let eof = f == RecvFrame::Eof;
            let payload = if matches!(f, RecvFrame::Data(_)) { buf.clone() } else { Vec::new() };
            want.push((f, payload));
            if eof {
                break;
            }
        }

        for chunk in [1, 2, 3, 4, 5, 7, 64, 4096] {
            assert_eq!(assemble_all(&stream, chunk), want, "chunk={chunk}");
        }
    }

    #[test]
    fn assembler_reports_unknown_kind_and_resyncs() {
        let mut stream = Vec::new();
        write_frame_raw(&mut stream, 200, &[1, 2, 3]).unwrap();
        Frame::Stats.write_to(&mut stream).unwrap();
        let frames = assemble_all(&stream, 1);
        assert_eq!(
            frames[0].0,
            RecvFrame::Malformed { reason: "unknown frame kind", resync: true }
        );
        assert_eq!(frames[1].0, RecvFrame::Data(KIND_STATS));
        assert_eq!(frames[2].0, RecvFrame::Eof);
    }

    #[test]
    fn assembler_bad_length_is_sticky() {
        let mut stream = ((MAX_FRAME + 1) as u32).to_le_bytes().to_vec();
        stream.extend_from_slice(&[0u8; 32]);
        let mut r = Trickle::new(&stream, 2);
        let mut asm = FrameAssembler::new();
        let mut buf = Vec::new();
        let bad = RecvFrame::Malformed { reason: "bad frame length", resync: false };
        let mut seen = 0;
        while seen < 2 {
            match asm.poll_frame(&mut r, &mut buf).unwrap() {
                Assembled::NeedMore => continue,
                Assembled::Frame(f) => {
                    assert_eq!(f, bad, "a bad length prefix must be sticky");
                    seen += 1;
                }
            }
        }
        assert!(!asm.is_idle());
    }

    #[test]
    fn assembler_mid_frame_disconnect_is_an_error() {
        let mut stream = Vec::new();
        Frame::Features(vec![5u8; 100]).write_to(&mut stream).unwrap();
        for cut in [1, 4, 5, 50] {
            let mut r = Trickle::new(&stream[..cut], 3);
            let mut asm = FrameAssembler::new();
            let mut buf = Vec::new();
            let err = loop {
                match asm.poll_frame(&mut r, &mut buf) {
                    Ok(Assembled::NeedMore) => continue,
                    Ok(Assembled::Frame(f)) => panic!("cut={cut}: unexpected frame {f:?}"),
                    Err(e) => break e,
                }
            };
            assert!(err.to_string().contains("mid-frame"), "cut={cut}: {err}");
            assert!(!asm.is_idle(), "cut={cut}");
        }
    }

    #[test]
    fn outbox_resumes_partial_writes() {
        struct Throttle {
            sink: Vec<u8>,
            accept: usize,
            starve: bool,
        }
        impl Write for Throttle {
            fn write(&mut self, bytes: &[u8]) -> std::io::Result<usize> {
                if self.starve {
                    self.starve = false;
                    return Err(std::io::ErrorKind::WouldBlock.into());
                }
                self.starve = true;
                let n = self.accept.min(bytes.len());
                self.sink.extend_from_slice(&bytes[..n]);
                Ok(n)
            }
            fn flush(&mut self) -> std::io::Result<()> {
                Ok(())
            }
        }

        let mut frame = Vec::new();
        write_frame_raw(&mut frame, KIND_LOGITS, &[7u8; 90]).unwrap();
        let mut outbox = Outbox::new();
        // Reply builders write straight into the outbox via `Write`.
        write_frame_raw(&mut outbox, KIND_LOGITS, &[7u8; 90]).unwrap();
        outbox.push(&frame);
        assert_eq!(outbox.pending(), 2 * frame.len());

        let mut w = Throttle { sink: Vec::new(), accept: 7, starve: false };
        let mut rounds = 0;
        while !outbox.flush_to(&mut w).unwrap() {
            rounds += 1;
            assert!(rounds < 1000, "flush_to must make progress");
        }
        assert!(outbox.is_empty());
        assert_eq!(outbox.pending(), 0);
        let mut both = frame.clone();
        both.extend_from_slice(&frame);
        assert_eq!(w.sink, both, "bytes must arrive unreordered and complete");
    }

    #[test]
    fn registry_kinds_pass_framing() {
        // The registry frames ride the same `[len][kind][payload]`
        // transport; both receive paths (blocking and incremental) must
        // accept kinds 12..=17, and the byte just past the range must
        // still resync as malformed.
        for kind in [
            KIND_MANIFEST_REQ,
            KIND_MANIFEST,
            KIND_CHUNK_REQ,
            KIND_CHUNK,
            KIND_SUBSCRIBE,
            KIND_VERSION,
        ] {
            let mut buf = Vec::new();
            write_frame_vec(&mut buf, kind, &[b"payload"]).unwrap();

            let mut r = std::io::Cursor::new(buf.clone());
            let mut raw = Vec::new();
            assert_eq!(read_frame_into(&mut r, &mut raw).unwrap(), RecvFrame::Data(kind));
            assert_eq!(raw, b"payload");

            let mut asm = FrameAssembler::new();
            let mut src = std::io::Cursor::new(buf.clone());
            let mut abuf = Vec::new();
            match asm.poll_frame(&mut src, &mut abuf).unwrap() {
                Assembled::Frame(RecvFrame::Data(k)) => {
                    assert_eq!(k, kind);
                    assert_eq!(abuf, b"payload");
                }
                other => panic!("assembler rejected registry kind {kind}: {other:?}"),
            }
        }

        let mut buf = Vec::new();
        write_frame_vec(&mut buf, KIND_VERSION + 1, &[b"x"]).unwrap();
        let mut r = std::io::Cursor::new(buf);
        let mut raw = Vec::new();
        assert_eq!(
            read_frame_into(&mut r, &mut raw).unwrap(),
            RecvFrame::Malformed { reason: "unknown frame kind", resync: true }
        );
    }
}
