//! Length-prefixed wire protocol between edge and cloud.
//!
//! Frame layout: `[len: u32 LE][kind: u8][payload: len-1 bytes]`.
//! `len` counts kind + payload. Payloads:
//!
//! * `Features` — a `compression::feature` frame (self-describing:
//!   model id, stage, c, range, entropy-coded values);
//! * `Image` — `[model_id u16][hw u16][png-like bytes]` for the
//!   cloud-only path;
//! * `Logits` — `[count u16][count × f32]` response;
//! * `Stats` / `StatsReply` — queries the cloud's counters;
//! * `Shutdown` — graceful server stop (tests).

use std::io::{Read, Write};

use anyhow::{anyhow, Result};

pub const KIND_FEATURES: u8 = 1;
pub const KIND_IMAGE: u8 = 2;
pub const KIND_LOGITS: u8 = 3;
pub const KIND_STATS: u8 = 4;
pub const KIND_STATS_REPLY: u8 = 5;
pub const KIND_SHUTDOWN: u8 = 6;
pub const KIND_ERROR: u8 = 7;
pub const KIND_PROBE: u8 = 8;
pub const KIND_PROBE_ACK: u8 = 9;

/// Hard cap on frame size (a 224²·512-channel f32 map is ~100 MB; our
/// frames are far smaller — reject anything absurd).
pub const MAX_FRAME: usize = 256 * 1024 * 1024;

#[derive(Debug, Clone, PartialEq)]
pub enum Frame {
    Features(Vec<u8>),
    Image { model_id: u16, hw: u16, png: Vec<u8> },
    Logits(Vec<f32>),
    Stats,
    StatsReply(Vec<u8>),
    Shutdown,
    Error(String),
    /// Active bandwidth probe: opaque padding the cloud discards. Used
    /// when the serving plan's frames are too small to estimate from
    /// (`edge::MIN_ESTIMATE_BYTES`).
    Probe(Vec<u8>),
    ProbeAck,
}

impl Frame {
    pub fn kind(&self) -> u8 {
        match self {
            Frame::Features(_) => KIND_FEATURES,
            Frame::Image { .. } => KIND_IMAGE,
            Frame::Logits(_) => KIND_LOGITS,
            Frame::Stats => KIND_STATS,
            Frame::StatsReply(_) => KIND_STATS_REPLY,
            Frame::Shutdown => KIND_SHUTDOWN,
            Frame::Error(_) => KIND_ERROR,
            Frame::Probe(_) => KIND_PROBE,
            Frame::ProbeAck => KIND_PROBE_ACK,
        }
    }

    pub fn write_to(&self, w: &mut impl Write) -> Result<usize> {
        let payload: Vec<u8> = match self {
            Frame::Features(b) => b.clone(),
            Frame::Image { model_id, hw, png } => {
                let mut p = Vec::with_capacity(4 + png.len());
                p.extend_from_slice(&model_id.to_le_bytes());
                p.extend_from_slice(&hw.to_le_bytes());
                p.extend_from_slice(png);
                p
            }
            Frame::Logits(v) => {
                let mut p = Vec::with_capacity(2 + v.len() * 4);
                p.extend_from_slice(&(v.len() as u16).to_le_bytes());
                for x in v {
                    p.extend_from_slice(&x.to_le_bytes());
                }
                p
            }
            Frame::Stats | Frame::Shutdown | Frame::ProbeAck => Vec::new(),
            Frame::StatsReply(b) => b.clone(),
            Frame::Error(s) => s.as_bytes().to_vec(),
            Frame::Probe(b) => b.clone(),
        };
        let len = (payload.len() + 1) as u32;
        w.write_all(&len.to_le_bytes())?;
        w.write_all(&[self.kind()])?;
        w.write_all(&payload)?;
        w.flush()?;
        Ok(4 + 1 + payload.len())
    }

    pub fn read_from(r: &mut impl Read) -> Result<Frame> {
        let mut lenb = [0u8; 4];
        r.read_exact(&mut lenb)?;
        let len = u32::from_le_bytes(lenb) as usize;
        if len == 0 || len > MAX_FRAME {
            return Err(anyhow!("bad frame length {len}"));
        }
        let mut kind = [0u8; 1];
        r.read_exact(&mut kind)?;
        let mut payload = vec![0u8; len - 1];
        r.read_exact(&mut payload)?;
        Ok(match kind[0] {
            KIND_FEATURES => Frame::Features(payload),
            KIND_IMAGE => {
                if payload.len() < 4 {
                    return Err(anyhow!("short image frame"));
                }
                let model_id = u16::from_le_bytes([payload[0], payload[1]]);
                let hw = u16::from_le_bytes([payload[2], payload[3]]);
                Frame::Image { model_id, hw, png: payload[4..].to_vec() }
            }
            KIND_LOGITS => {
                if payload.len() < 2 {
                    return Err(anyhow!("short logits frame"));
                }
                let n = u16::from_le_bytes([payload[0], payload[1]]) as usize;
                if payload.len() != 2 + n * 4 {
                    return Err(anyhow!("logits length mismatch"));
                }
                let v = (0..n)
                    .map(|i| {
                        f32::from_le_bytes(
                            payload[2 + i * 4..6 + i * 4].try_into().unwrap(),
                        )
                    })
                    .collect();
                Frame::Logits(v)
            }
            KIND_STATS => Frame::Stats,
            KIND_STATS_REPLY => Frame::StatsReply(payload),
            KIND_SHUTDOWN => Frame::Shutdown,
            KIND_ERROR => Frame::Error(String::from_utf8_lossy(&payload).into_owned()),
            KIND_PROBE => Frame::Probe(payload),
            KIND_PROBE_ACK => Frame::ProbeAck,
            k => return Err(anyhow!("unknown frame kind {k}")),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(f: Frame) {
        let mut buf = Vec::new();
        f.write_to(&mut buf).unwrap();
        let mut r = &buf[..];
        assert_eq!(Frame::read_from(&mut r).unwrap(), f);
        assert!(r.is_empty(), "trailing bytes");
    }

    #[test]
    fn all_frames_roundtrip() {
        roundtrip(Frame::Features(vec![1, 2, 3, 255]));
        roundtrip(Frame::Image { model_id: 3, hw: 32, png: vec![9; 100] });
        roundtrip(Frame::Logits(vec![1.5, -2.25, 0.0]));
        roundtrip(Frame::Stats);
        roundtrip(Frame::StatsReply(b"{}".to_vec()));
        roundtrip(Frame::Shutdown);
        roundtrip(Frame::Error("boom".into()));
    }

    #[test]
    fn multiple_frames_stream() {
        let mut buf = Vec::new();
        Frame::Features(vec![1]).write_to(&mut buf).unwrap();
        Frame::Logits(vec![2.0]).write_to(&mut buf).unwrap();
        let mut r = &buf[..];
        assert!(matches!(Frame::read_from(&mut r).unwrap(), Frame::Features(_)));
        assert!(matches!(Frame::read_from(&mut r).unwrap(), Frame::Logits(_)));
    }

    #[test]
    fn corrupt_length_rejected() {
        let mut buf = Vec::new();
        Frame::Stats.write_to(&mut buf).unwrap();
        buf[0..4].copy_from_slice(&(u32::MAX).to_le_bytes());
        assert!(Frame::read_from(&mut &buf[..]).is_err());
    }

    #[test]
    fn truncated_stream_errors() {
        let mut buf = Vec::new();
        Frame::Features(vec![0; 50]).write_to(&mut buf).unwrap();
        assert!(Frame::read_from(&mut &buf[..10]).is_err());
    }
}
