//! Edge-side registry consumption: hash-keyed artifact cache with
//! in-flight dedup, verify-on-receipt chunk fetch, and per-request
//! atomic hot-swap between model versions.
//!
//! Trust boundary: everything that arrives from the registry is
//! checked *before* it can influence execution. The manifest's
//! detached signature is verified over the exact wire bytes prior to
//! JSON parsing (`util::sign`); every chunk body is re-hashed while
//! being copied into its owned buffer ([`HashingReader`] — the digest
//! rides the copy, there is no unhashed path into the cache) and must
//! equal the *requested* [`Hash128`], which itself came out of a
//! verified manifest. A mismatch anywhere is counted, surfaced, and
//! the bytes are dropped — never cached, never executed.
//!
//! [`ArtifactCache`] reuses the in-flight-dedup idiom from
//! `server::cache` (`lead_or_wait` / guard / publish-before-release):
//! when N fetchers want the same chunk, one downloads and N−1 park on
//! a condvar and reuse the published entry — the registry sees exactly
//! one request. Unlike the logits cache, keys here are already content
//! hashes, so the store is a flat LRU (byte-bounded, stamp-based)
//! rather than a segmented one: an edge holds tens of artifacts, not
//! hundreds of thousands of replies, and an O(n) eviction scan over
//! that is noise.
//!
//! [`HotSwap`] is the fleet-rollout contract: versions *stage* (warm,
//! invisible) behind the active one, [`HotSwap::model_for`] hands out
//! one `Arc<ModelVersion>` that the caller holds for the whole request
//! — so a cut-over mid-request cannot mix versions within a reply —
//! and per-tenant pins override the fleet default. Applying a
//! [`KIND_VERSION`] announce can only *select among already-staged,
//! already-verified versions*, which is why the announce frame itself
//! needs no signature: an attacker who forges one can at worst pick a
//! version the operator published and the edge verified.

use std::collections::{HashMap, HashSet};
use std::io::{BufReader, Read};
use std::net::{TcpStream, ToSocketAddrs};
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};

use anyhow::{anyhow, Context, Result};

use crate::runtime::artifacts::Manifest;
use crate::runtime::executor::{Executor, SharedExecutor};
use crate::util::hash::{Hash128, HashingReader};
use crate::util::json::Json;
use crate::util::sign::{SigKey, Signature};

use super::proto::{
    self, RecvFrame, KIND_CHUNK, KIND_CHUNK_REQ, KIND_ERROR, KIND_MANIFEST, KIND_MANIFEST_REQ,
    KIND_SUBSCRIBE, KIND_VERSION,
};

/// Accounting charge per cache entry beyond the payload itself (key,
/// stamp, map slot) — same order as `server::cache`'s constant.
const ENTRY_OVERHEAD: usize = 96;

struct Entry {
    data: Arc<Vec<u8>>,
    /// Lazy LRU stamp: bumped from a shared clock on every hit.
    stamp: u64,
}

#[derive(Default)]
struct Inner {
    map: HashMap<Hash128, Entry>,
    clock: u64,
    bytes: usize,
}

/// Counter snapshot (see [`ArtifactCache::stats`]).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ArtifactCacheStats {
    pub hits: u64,
    pub downloads: u64,
    pub coalesced: u64,
    pub evictions: u64,
    pub rejected_oversize: u64,
    pub bytes: u64,
    pub entries: u64,
}

/// Byte-bounded, hash-keyed LRU store for artifact chunks, shared by
/// every [`RegistryClient`] on an edge.
pub struct ArtifactCache {
    budget: usize,
    inner: Mutex<Inner>,
    inflight: Mutex<HashSet<Hash128>>,
    cv: Condvar,
    hits: AtomicU64,
    downloads: AtomicU64,
    coalesced: AtomicU64,
    evictions: AtomicU64,
    rejected_oversize: AtomicU64,
}

/// Held by the one fetcher that owns an in-flight download. Dropping
/// it — on success *after* [`ArtifactCache::publish`] stored the
/// entry, or on any error/panic path — releases the key and wakes
/// every parked follower (so a failed lead never strands them; one
/// follower becomes the new lead).
pub struct InflightGuard<'a> {
    cache: &'a ArtifactCache,
    key: Hash128,
}

impl Drop for InflightGuard<'_> {
    fn drop(&mut self) {
        self.cache.inflight.lock().unwrap().remove(&self.key);
        self.cache.cv.notify_all();
    }
}

pub enum LeadOrWait<'a> {
    /// You fetch; everyone else is parked behind you.
    Lead(InflightGuard<'a>),
    /// A lead finished (or failed) while you waited — re-check the
    /// cache and retry.
    Waited,
}

impl ArtifactCache {
    pub fn new(budget_bytes: usize) -> Arc<Self> {
        Arc::new(Self {
            budget: budget_bytes,
            inner: Mutex::new(Inner::default()),
            inflight: Mutex::new(HashSet::new()),
            cv: Condvar::new(),
            hits: AtomicU64::new(0),
            downloads: AtomicU64::new(0),
            coalesced: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
            rejected_oversize: AtomicU64::new(0),
        })
    }

    pub fn get(&self, key: Hash128) -> Option<Arc<Vec<u8>>> {
        let mut inner = self.inner.lock().unwrap();
        inner.clock += 1;
        let clock = inner.clock;
        let entry = inner.map.get_mut(&key)?;
        entry.stamp = clock;
        let data = Arc::clone(&entry.data);
        drop(inner);
        self.hits.fetch_add(1, Ordering::Relaxed);
        Some(data)
    }

    /// Claim the in-flight slot for `key`, or park until the current
    /// holder releases it. Callers loop `get → lead_or_wait → (Lead:
    /// download + publish | Waited: continue)`.
    pub fn lead_or_wait(&self, key: Hash128) -> LeadOrWait<'_> {
        let mut inflight = self.inflight.lock().unwrap();
        if inflight.insert(key) {
            return LeadOrWait::Lead(InflightGuard { cache: self, key });
        }
        self.coalesced.fetch_add(1, Ordering::Relaxed);
        while inflight.contains(&key) {
            inflight = self.cv.wait(inflight).unwrap();
        }
        LeadOrWait::Waited
    }

    /// Store a verified download and release the lead. The entry is
    /// inserted *before* the guard drops, so a follower woken by the
    /// release finds it on re-check. An entry that alone exceeds the
    /// whole budget is handed back uncached (the byte bound is an
    /// invariant, not a soft target).
    pub fn publish(&self, lead: InflightGuard<'_>, data: Vec<u8>) -> Arc<Vec<u8>> {
        let key = lead.key;
        let data = Arc::new(data);
        let cost = data.len() + ENTRY_OVERHEAD;
        self.downloads.fetch_add(1, Ordering::Relaxed);
        if cost > self.budget {
            self.rejected_oversize.fetch_add(1, Ordering::Relaxed);
            return data; // guard drops here: key released, waiters retry
        }
        let mut inner = self.inner.lock().unwrap();
        inner.clock += 1;
        let stamp = inner.clock;
        if let Some(old) = inner.map.insert(key, Entry { data: Arc::clone(&data), stamp }) {
            // Benign double-publish (lead raced a direct insert): the
            // bytes are content-addressed, so old == new.
            inner.bytes -= old.data.len() + ENTRY_OVERHEAD;
        }
        inner.bytes += cost;
        while inner.bytes > self.budget {
            // The just-inserted entry carries the freshest stamp, so
            // the min-scan can never pick it while others remain.
            let victim = *inner.map.iter().min_by_key(|(_, e)| e.stamp).unwrap().0;
            let gone = inner.map.remove(&victim).unwrap();
            inner.bytes -= gone.data.len() + ENTRY_OVERHEAD;
            self.evictions.fetch_add(1, Ordering::Relaxed);
        }
        drop(inner);
        drop(lead);
        data
    }

    pub fn bytes(&self) -> usize {
        self.inner.lock().unwrap().bytes
    }

    pub fn entries(&self) -> usize {
        self.inner.lock().unwrap().map.len()
    }

    pub fn budget(&self) -> usize {
        self.budget
    }

    pub fn stats(&self) -> ArtifactCacheStats {
        let (bytes, entries) = {
            let inner = self.inner.lock().unwrap();
            (inner.bytes as u64, inner.map.len() as u64)
        };
        ArtifactCacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            downloads: self.downloads.load(Ordering::Relaxed),
            coalesced: self.coalesced.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
            rejected_oversize: self.rejected_oversize.load(Ordering::Relaxed),
            bytes,
            entries,
        }
    }
}

/// One chunk a verified manifest says exists: where it belongs and
/// what its content address is.
#[derive(Debug, Clone)]
pub struct ChunkRef {
    pub model: String,
    pub stage: usize,
    pub hash: Hash128,
    pub bytes: usize,
}

/// A signature-verified manifest, assembled and ready to fetch.
pub struct FetchedManifest {
    pub version: String,
    pub manifest: Manifest,
    pub chunks: Vec<ChunkRef>,
}

/// Counter snapshot (see [`RegistryClient::stats`]).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ClientStats {
    pub manifests_verified: u64,
    pub manifest_rejects: u64,
    pub chunks_verified: u64,
    pub chunk_rejects: u64,
}

fn hash_from_hex(s: &str) -> Option<Hash128> {
    if s.len() != 32 {
        return None;
    }
    let hi = u64::from_str_radix(&s[..16], 16).ok()?;
    let lo = u64::from_str_radix(&s[16..], 16).ok()?;
    Some(Hash128 { hi, lo })
}

/// One edge's connection to the registry. Request/reply over the frame
/// protocol; all verification happens here, on this side of the trust
/// boundary.
pub struct RegistryClient {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
    key: SigKey,
    cache: Arc<ArtifactCache>,
    buf: Vec<u8>,
    manifests_verified: u64,
    manifest_rejects: u64,
    chunks_verified: u64,
    chunk_rejects: u64,
}

impl RegistryClient {
    pub fn connect(addr: impl ToSocketAddrs, key: SigKey, cache: Arc<ArtifactCache>) -> Result<Self> {
        let stream = TcpStream::connect(addr).context("connecting to registry")?;
        let writer = stream.try_clone()?;
        Ok(Self {
            reader: BufReader::new(stream),
            writer,
            key,
            cache,
            buf: Vec::new(),
            manifests_verified: 0,
            manifest_rejects: 0,
            chunks_verified: 0,
            chunk_rejects: 0,
        })
    }

    pub fn stats(&self) -> ClientStats {
        ClientStats {
            manifests_verified: self.manifests_verified,
            manifest_rejects: self.manifest_rejects,
            chunks_verified: self.chunks_verified,
            chunk_rejects: self.chunk_rejects,
        }
    }

    pub fn cache(&self) -> &Arc<ArtifactCache> {
        &self.cache
    }

    fn recv(&mut self) -> Result<u8> {
        match proto::read_frame_into(&mut self.reader, &mut self.buf)? {
            RecvFrame::Data(k) => Ok(k),
            RecvFrame::Malformed { reason, .. } => {
                Err(anyhow!("registry sent a malformed frame: {reason}"))
            }
            RecvFrame::Eof => Err(anyhow!("registry closed the connection")),
        }
    }

    /// Fetch + verify the manifest for `version` (`None` = whatever is
    /// active fleet-wide). The signature is checked over the exact
    /// wire bytes **before** any parsing; a bad tag rejects the whole
    /// document.
    pub fn fetch_manifest(&mut self, version: Option<&str>) -> Result<FetchedManifest> {
        proto::write_frame_vec(
            &mut self.writer,
            KIND_MANIFEST_REQ,
            &[version.unwrap_or("").as_bytes()],
        )?;
        let kind = self.recv()?;
        if kind == KIND_ERROR {
            return Err(anyhow!("registry: {}", String::from_utf8_lossy(&self.buf)));
        }
        if kind != KIND_MANIFEST {
            return Err(anyhow!("expected manifest frame, got kind {kind}"));
        }
        let sig = Signature::from_wire(&self.buf)
            .ok_or_else(|| anyhow!("manifest frame shorter than its signature"))?;
        let verified = self.key.verify(&self.buf[Signature::WIRE_LEN..], sig);
        if !verified {
            self.manifest_rejects += 1;
            return Err(anyhow!(
                "manifest signature verification failed — refusing to parse or execute"
            ));
        }
        let doc = {
            let text = std::str::from_utf8(&self.buf[Signature::WIRE_LEN..])
                .context("signed manifest is not UTF-8")?;
            Json::parse(text).map_err(|e| anyhow!("signed manifest JSON: {e}"))?
        };
        let version = doc
            .get("version")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("signed manifest has no version field"))?
            .to_string();
        let manifest = Manifest::from_json(PathBuf::from("registry"), &doc)?;
        let mut chunks = Vec::new();
        for m in doc.get("models").and_then(Json::as_arr).unwrap_or(&[]) {
            let model = m.get("name").and_then(Json::as_str).unwrap_or_default().to_string();
            for s in m.get("stages").and_then(Json::as_arr).unwrap_or(&[]) {
                let hex = s
                    .get("chunk")
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow!("manifest stage missing chunk hash"))?;
                let hash = hash_from_hex(hex)
                    .ok_or_else(|| anyhow!("manifest chunk hash {hex:?} is not 32 hex chars"))?;
                chunks.push(ChunkRef {
                    model: model.clone(),
                    stage: s.get("index").and_then(Json::as_u64).unwrap_or(0) as usize,
                    hash,
                    bytes: s.get("chunk_bytes").and_then(Json::as_u64).unwrap_or(0) as usize,
                });
            }
        }
        self.manifests_verified += 1;
        Ok(FetchedManifest { version, manifest, chunks })
    }

    /// Fetch one chunk by content address: cache-first, in-flight
    /// deduped, hash-verified on receipt.
    pub fn fetch_chunk(&mut self, hash: Hash128) -> Result<Arc<Vec<u8>>> {
        loop {
            if let Some(data) = self.cache.get(hash) {
                return Ok(data);
            }
            let cache = Arc::clone(&self.cache);
            match cache.lead_or_wait(hash) {
                LeadOrWait::Lead(guard) => {
                    // An error drops `guard` → parked followers wake,
                    // re-miss, and one of them becomes the new lead.
                    let data = self.download_verified(hash)?;
                    return Ok(cache.publish(guard, data));
                }
                LeadOrWait::Waited => continue,
            }
        }
    }

    fn download_verified(&mut self, hash: Hash128) -> Result<Vec<u8>> {
        proto::write_frame_vec(
            &mut self.writer,
            KIND_CHUNK_REQ,
            &[&hash.hi.to_le_bytes(), &hash.lo.to_le_bytes()],
        )?;
        let kind = self.recv()?;
        if kind == KIND_ERROR {
            return Err(anyhow!("registry: {}", String::from_utf8_lossy(&self.buf)));
        }
        if kind != KIND_CHUNK {
            return Err(anyhow!("expected chunk frame, got kind {kind}"));
        }
        if self.buf.len() < 16 {
            self.chunk_rejects += 1;
            return Err(anyhow!("chunk frame shorter than its hash header"));
        }
        // The body is copied into its owned buffer *through* the
        // hashing reader, so the digest covers exactly the bytes kept.
        let (data, digest) = {
            let mut hr = HashingReader::new(std::io::Cursor::new(&self.buf[16..]));
            let mut data = Vec::with_capacity(self.buf.len() - 16);
            hr.read_to_end(&mut data)?;
            (data, hr.digest())
        };
        // Verification is against the hash *we asked for* (out of the
        // signed manifest) — the frame's echoed header is routing, not
        // trust, and a server lying in either place is caught here.
        if digest != hash {
            self.chunk_rejects += 1;
            return Err(anyhow!(
                "chunk {} failed content verification (got {}) — dropped, not cached",
                hash.to_hex(),
                digest.to_hex()
            ));
        }
        self.chunks_verified += 1;
        Ok(data)
    }

    /// Fetch, verify, and assemble a complete executable model
    /// version: manifest first (signature gate), then every chunk it
    /// references (hash gate), then an executor over the assembled
    /// [`Manifest`] — the same structure a local artifact dir yields.
    pub fn fetch_model(&mut self, version: Option<&str>, fanin: usize) -> Result<Arc<ModelVersion>> {
        let fetched = self.fetch_manifest(version)?;
        for c in &fetched.chunks {
            let data = self.fetch_chunk(c.hash)?;
            if data.len() != c.bytes {
                return Err(anyhow!(
                    "chunk {} for {}/stage{}: manifest says {} bytes, got {}",
                    c.hash.to_hex(),
                    c.model,
                    c.stage,
                    c.bytes,
                    data.len()
                ));
            }
        }
        let exe = SharedExecutor::from_executor(Executor::sim_with(fetched.manifest.clone(), fanin));
        Ok(Arc::new(ModelVersion { version: fetched.version, manifest: fetched.manifest, exe }))
    }
}

/// A fully fetched, verified, executable model version.
pub struct ModelVersion {
    pub version: String,
    pub manifest: Manifest,
    pub exe: SharedExecutor,
}

struct SwapState {
    active: String,
    previous: Option<String>,
}

/// Counter snapshot (see [`HotSwap::stats`]).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct SwapStats {
    pub cutovers: u64,
    pub rollbacks: u64,
    pub announces_applied: u64,
    pub announces_ignored: u64,
}

/// Edge-side version control: staged versions, one active pointer,
/// per-tenant pins. Cut-over is atomic **per request** because
/// [`HotSwap::model_for`] returns one `Arc<ModelVersion>` the caller
/// holds end-to-end — flipping the active pointer mid-request cannot
/// retarget a request that already resolved its version.
pub struct HotSwap {
    versions: Mutex<HashMap<String, Arc<ModelVersion>>>,
    state: Mutex<SwapState>,
    pins: Mutex<HashMap<u32, String>>,
    cutovers: AtomicU64,
    rollbacks: AtomicU64,
    announces_applied: AtomicU64,
    announces_ignored: AtomicU64,
}

impl HotSwap {
    pub fn new(initial: Arc<ModelVersion>) -> Arc<Self> {
        let mut versions = HashMap::new();
        let active = initial.version.clone();
        versions.insert(active.clone(), initial);
        Arc::new(Self {
            versions: Mutex::new(versions),
            state: Mutex::new(SwapState { active, previous: None }),
            pins: Mutex::new(HashMap::new()),
            cutovers: AtomicU64::new(0),
            rollbacks: AtomicU64::new(0),
            announces_applied: AtomicU64::new(0),
            announces_ignored: AtomicU64::new(0),
        })
    }

    /// Warm a version behind the active one: fetchable, pinnable,
    /// invisible to unpinned traffic until [`Self::cut_over`].
    pub fn stage(&self, mv: Arc<ModelVersion>) {
        self.versions.lock().unwrap().insert(mv.version.clone(), mv);
    }

    pub fn staged_versions(&self) -> Vec<String> {
        let mut v: Vec<String> = self.versions.lock().unwrap().keys().cloned().collect();
        v.sort();
        v
    }

    /// Resolve the version this request executes on: the tenant's pin
    /// if set, the fleet active otherwise. The returned `Arc` **is**
    /// the atomicity: hold it for the whole request.
    pub fn model_for(&self, tenant: Option<u32>) -> Arc<ModelVersion> {
        let name = tenant
            .and_then(|t| self.pins.lock().unwrap().get(&t).cloned())
            .unwrap_or_else(|| self.state.lock().unwrap().active.clone());
        let versions = self.versions.lock().unwrap();
        versions
            .get(&name)
            // A pin to a version that was never staged falls back to
            // active rather than failing the request.
            .or_else(|| {
                let state = self.state.lock().unwrap();
                versions.get(&state.active)
            })
            .cloned()
            .expect("active version always staged")
    }

    pub fn cut_over(&self, version: &str) -> Result<()> {
        if !self.versions.lock().unwrap().contains_key(version) {
            return Err(anyhow!("cannot cut over to unstaged version {version:?}"));
        }
        let mut state = self.state.lock().unwrap();
        if state.active == version {
            return Ok(());
        }
        state.previous = Some(std::mem::replace(&mut state.active, version.to_string()));
        drop(state);
        self.cutovers.fetch_add(1, Ordering::Relaxed);
        Ok(())
    }

    /// Swap active and previous — the local half of one-frame rollback.
    pub fn rollback(&self) -> Result<()> {
        let mut state = self.state.lock().unwrap();
        let prev = state
            .previous
            .take()
            .ok_or_else(|| anyhow!("no previous version to roll back to"))?;
        state.previous = Some(std::mem::replace(&mut state.active, prev));
        drop(state);
        self.rollbacks.fetch_add(1, Ordering::Relaxed);
        Ok(())
    }

    /// Apply a registry [`KIND_VERSION`] announce. Only flips among
    /// already-staged (hence already-verified) versions; an announce
    /// naming anything else is counted and ignored.
    pub fn apply_announce(&self, version: &str) -> bool {
        if version.is_empty() || !self.versions.lock().unwrap().contains_key(version) {
            self.announces_ignored.fetch_add(1, Ordering::Relaxed);
            return false;
        }
        let mut state = self.state.lock().unwrap();
        if state.active != version {
            state.previous = Some(std::mem::replace(&mut state.active, version.to_string()));
        }
        drop(state);
        self.announces_applied.fetch_add(1, Ordering::Relaxed);
        true
    }

    pub fn pin(&self, tenant: u32, version: &str) -> Result<()> {
        if !self.versions.lock().unwrap().contains_key(version) {
            return Err(anyhow!("cannot pin tenant {tenant} to unstaged version {version:?}"));
        }
        self.pins.lock().unwrap().insert(tenant, version.to_string());
        Ok(())
    }

    pub fn unpin(&self, tenant: u32) {
        self.pins.lock().unwrap().remove(&tenant);
    }

    pub fn active_version(&self) -> String {
        self.state.lock().unwrap().active.clone()
    }

    pub fn stats(&self) -> SwapStats {
        SwapStats {
            cutovers: self.cutovers.load(Ordering::Relaxed),
            rollbacks: self.rollbacks.load(Ordering::Relaxed),
            announces_applied: self.announces_applied.load(Ordering::Relaxed),
            announces_ignored: self.announces_ignored.load(Ordering::Relaxed),
        }
    }
}

/// Subscribe to the registry's version announcements and apply each to
/// `swap`. Runs until the registry closes the connection. The thread
/// sends [`KIND_SUBSCRIBE`] once, then drains [`KIND_VERSION`] pushes;
/// see [`HotSwap::apply_announce`] for why these frames are safe to
/// act on unsigned.
pub fn subscribe_announcements(
    addr: impl ToSocketAddrs,
    swap: Arc<HotSwap>,
) -> Result<std::thread::JoinHandle<()>> {
    let stream = TcpStream::connect(addr).context("connecting to registry for subscribe")?;
    let mut writer = stream.try_clone()?;
    proto::write_frame_vec(&mut writer, KIND_SUBSCRIBE, &[&[]])?;
    Ok(std::thread::spawn(move || {
        let mut reader = BufReader::new(stream);
        let mut buf = Vec::new();
        loop {
            match proto::read_frame_into(&mut reader, &mut buf) {
                Ok(RecvFrame::Data(KIND_VERSION)) => {
                    let version = String::from_utf8_lossy(&buf).to_string();
                    swap.apply_announce(&version);
                }
                Ok(RecvFrame::Data(_)) | Ok(RecvFrame::Malformed { .. }) => continue,
                Ok(RecvFrame::Eof) | Err(_) => return,
            }
        }
    }))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::hash::hash128;

    fn h(n: u8) -> Hash128 {
        hash128(&[n])
    }

    #[test]
    fn cache_lru_eviction_honors_byte_budget() {
        // Budget fits ~3 entries of 100 payload bytes (+96 overhead).
        let cache = ArtifactCache::new(3 * (100 + 96));
        for n in 0..5u8 {
            match cache.lead_or_wait(h(n)) {
                LeadOrWait::Lead(g) => {
                    cache.publish(g, vec![n; 100]);
                }
                LeadOrWait::Waited => unreachable!("single thread"),
            }
            assert!(cache.bytes() <= cache.budget(), "after insert {n}");
        }
        let s = cache.stats();
        assert_eq!(s.downloads, 5);
        assert_eq!(s.evictions, 2);
        assert_eq!(cache.entries(), 3);
        // Oldest two evicted; survivors intact and bit-correct.
        assert!(cache.get(h(0)).is_none());
        assert!(cache.get(h(1)).is_none());
        for n in 2..5u8 {
            assert_eq!(cache.get(h(n)).unwrap().as_slice(), &[n; 100][..]);
        }
    }

    #[test]
    fn cache_hit_refreshes_lru_position() {
        let cache = ArtifactCache::new(3 * (10 + 96));
        for n in 0..3u8 {
            if let LeadOrWait::Lead(g) = cache.lead_or_wait(h(n)) {
                cache.publish(g, vec![n; 10]);
            }
        }
        // Touch the oldest; the next insert must evict h(1), not h(0).
        assert!(cache.get(h(0)).is_some());
        if let LeadOrWait::Lead(g) = cache.lead_or_wait(h(3)) {
            cache.publish(g, vec![3; 10]);
        }
        assert!(cache.get(h(0)).is_some());
        assert!(cache.get(h(1)).is_none());
    }

    #[test]
    fn cache_rejects_oversize_entries_instead_of_blowing_the_budget() {
        let cache = ArtifactCache::new(64);
        if let LeadOrWait::Lead(g) = cache.lead_or_wait(h(1)) {
            let data = cache.publish(g, vec![7; 1000]);
            assert_eq!(data.len(), 1000, "caller still gets the bytes");
        }
        assert_eq!(cache.entries(), 0);
        assert_eq!(cache.bytes(), 0);
        assert_eq!(cache.stats().rejected_oversize, 1);
        // And the in-flight key was released.
        assert!(matches!(cache.lead_or_wait(h(1)), LeadOrWait::Lead(_)));
    }

    #[test]
    fn failed_lead_releases_followers() {
        let cache = ArtifactCache::new(1 << 20);
        let key = h(9);
        let guard = match cache.lead_or_wait(key) {
            LeadOrWait::Lead(g) => g,
            LeadOrWait::Waited => unreachable!(),
        };
        let c2 = Arc::clone(&cache);
        let follower = std::thread::spawn(move || match c2.lead_or_wait(key) {
            LeadOrWait::Lead(g) => {
                c2.publish(g, vec![1, 2, 3]);
                true
            }
            LeadOrWait::Waited => c2.get(key).is_some(),
        });
        std::thread::sleep(std::time::Duration::from_millis(30));
        drop(guard); // the lead "failed" — no publish
        assert!(follower.join().unwrap(), "follower must recover, as new lead or via cache");
    }

    #[test]
    fn hex_hash_roundtrip() {
        let orig = hash128(b"some chunk");
        assert_eq!(hash_from_hex(&orig.to_hex()), Some(orig));
        assert_eq!(hash_from_hex("xyz"), None);
        assert_eq!(hash_from_hex(&"f".repeat(31)), None);
        assert_eq!(hash_from_hex(&"g".repeat(32)), None);
    }
}
