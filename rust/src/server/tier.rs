//! The middle-tier role: one process that is a server to the hop below
//! and a client to the hop above.
//!
//! `jalad serve-edge` embeds an [`EdgeTier`] into a regular
//! [`CloudServer`] via the [`TierForwarder`] hook: device connections
//! terminate on the existing transport (threads or epoll — the frame
//! core is shared), and every data frame is offered to the tier before
//! local handling. Per the tier's own multi-hop plan
//! ([`ControlPlane`](crate::coordinator::ControlPlane) over the
//! edge→cloud hop) the frame is either:
//!
//! * **passed through** — the plan's cut equals the frame's incoming
//!   stage, so the original bytes are relayed verbatim (a `CloudOnly`
//!   image chain reaches the cloud bit-for-bit, which is what the
//!   three-tier e2e oracle asserts);
//! * **deepened** — the tier decodes the features (or image), runs its
//!   stage span `from+1..=k` on its own executor, re-quantizes at the
//!   plan's bit-width, and forwards the later cut (any device tenant
//!   trailer is re-attached, so fair admission stays per-device);
//! * **absorbed** — the upstream path is down (breaker open, transport
//!   fault) or the cloud shed with `Busy`: the tier returns `None` and
//!   the embedding server's own handlers answer locally — the
//!   surviving device↔edge pair, bit-identical on the sim backend.
//!
//! The upstream link is an embedded [`EdgeClient`], so the breaker,
//! CRC-checked framing, fault plans and reconnects compose per hop
//! exactly as they do for a device. Replies re-wrap the piggybacked
//! telemetry: the cloud's block drives *this* tier's control plane,
//! and the block sent down carries *this* tier's load (sampled from
//! the embedding server), so each hop's feedback loop observes the hop
//! it actually talks to.
//!
//! Known headroom: the upstream link is serialized behind one mutex —
//! fine at edge-site fan-in rates; a connection-pooled upstream is the
//! obvious next rung.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, Weak};
use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::compression::{feature, png, quant};
use crate::coordinator::cut_depth;
use crate::ilp::Decision;
use crate::runtime::{Executor, Manifest, Tensor};
use crate::server::cloud::{CloudServer, TierForwarder};
use crate::server::edge::{EdgeClient, MIN_ESTIMATE_BYTES};
use crate::server::proto::{self, CloudTelemetry};
use crate::util::json::Json;
use crate::util::pool::Scratch;

/// The upstream half of the tier: the embedded client plus the codec
/// scratch its span-runs reuse. One mutex serializes both (see module
/// docs).
struct TierLink {
    client: EdgeClient<'static>,
    exe: &'static Executor,
    sc: Scratch,
    logits: Vec<f32>,
}

pub struct EdgeTier {
    inner: Mutex<TierLink>,
    manifest: Manifest,
    /// The embedding server, attached after construction — the source
    /// of this tier's own telemetry for downstream replies. `Weak`
    /// breaks the `CloudServer` ↔ forwarder Arc cycle.
    local: Mutex<Weak<CloudServer>>,
    /// Data frames answered through the upstream hop.
    forwarded: AtomicU64,
    /// ... of which relayed verbatim (plan cut == incoming stage).
    passthrough: AtomicU64,
    /// ... of which deepened by running a local stage span first.
    span_runs: AtomicU64,
    /// Frames handed back to the embedding server's local handlers
    /// (upstream down or errored).
    local_fallbacks: AtomicU64,
    /// `Busy` refusals absorbed from upstream (each also deepens the
    /// tier's plan via `on_busy` — the edge-ward shed direction).
    upstream_sheds: AtomicU64,
    /// Packed `(i << 8) | c` of the last plan consulted — lock-free
    /// for stats.
    cut_cache: AtomicU64,
}

impl EdgeTier {
    /// Build the tier around an already-connected upstream client.
    /// Both borrows are `'static` because the tier outlives every
    /// connection worker that may call it; a serve-edge process leaks
    /// one executor for its lifetime (`Box::leak`) — see `main.rs`.
    pub fn new(exe: &'static Executor, client: EdgeClient<'static>) -> Self {
        Self {
            manifest: exe.manifest().clone(),
            inner: Mutex::new(TierLink { client, exe, sc: Scratch::new(), logits: Vec::new() }),
            local: Mutex::new(Weak::new()),
            forwarded: AtomicU64::new(0),
            passthrough: AtomicU64::new(0),
            span_runs: AtomicU64::new(0),
            local_fallbacks: AtomicU64::new(0),
            upstream_sheds: AtomicU64::new(0),
            cut_cache: AtomicU64::new(0),
        }
    }

    /// Attach the embedding server (after both Arcs exist) so
    /// downstream replies carry this tier's own telemetry.
    pub fn attach(&self, server: &std::sync::Arc<CloudServer>) {
        *self.local.lock().unwrap() = std::sync::Arc::downgrade(server);
    }

    /// Mutate the embedded upstream client (breaker config, checked
    /// framing, fault plan, timeouts) — test and CLI plumbing.
    pub fn with_client<R>(&self, f: impl FnOnce(&mut EdgeClient<'static>) -> R) -> R {
        f(&mut self.inner.lock().unwrap().client)
    }

    /// (forwarded, passthrough, span_runs, local_fallbacks,
    /// upstream_sheds) counter snapshot.
    pub fn counters(&self) -> (u64, u64, u64, u64, u64) {
        (
            self.forwarded.load(Ordering::Relaxed),
            self.passthrough.load(Ordering::Relaxed),
            self.span_runs.load(Ordering::Relaxed),
            self.local_fallbacks.load(Ordering::Relaxed),
            self.upstream_sheds.load(Ordering::Relaxed),
        )
    }

    /// One relay attempt. `Ok(Some(reply))` goes to the device
    /// verbatim; `Ok(None)` and `Err` fall back to local handling (the
    /// caller maps both; `Err` is also logged and counted).
    fn relay(&self, link: &mut TierLink, kind: u8, frame: &[u8]) -> Result<Option<(u8, Vec<u8>)>> {
        let TierLink { client, exe, sc, logits } = link;
        let plan = client.controller.plan().decision();
        let k_plan = cut_depth(plan);

        // Route: which model, and how deep has the device already run?
        let (model_id, from) = match kind {
            proto::KIND_FEATURES => {
                let (m, s) =
                    feature::peek_route(frame).ok_or_else(|| anyhow!("unpeekable frame"))?;
                (m, s as usize)
            }
            proto::KIND_IMAGE => {
                if frame.len() < 4 {
                    return Err(anyhow!("short image frame"));
                }
                (u16::from_le_bytes([frame[0], frame[1]]), 0)
            }
            k => return Err(anyhow!("unforwardable kind {k}")),
        };
        let m = self
            .manifest
            .models
            .get(model_id as usize)
            .ok_or_else(|| anyhow!("bad model id {model_id}"))?;
        let n = m.num_stages();
        if from > n {
            return Err(anyhow!("bad stage {from}"));
        }
        // The tier can only deepen a cut, never undo the device's
        // stages; and never past the last stage.
        let k_eff = k_plan.clamp(from, n);
        let c_used = match plan {
            Decision::Cut { c, .. } if k_eff > from => c,
            _ => 0,
        };
        self.cut_cache
            .store(((k_eff as u64) << 8) | c_used as u64, Ordering::Relaxed);

        let t0 = Instant::now();
        let (rk, sent, payload) = if k_eff == from {
            // Passthrough: the original frame bytes, bit-for-bit.
            self.passthrough.fetch_add(1, Ordering::Relaxed);
            let (rk, sent, p) = client.forward_raw(kind, &[frame])?;
            (rk, sent, p.to_vec())
        } else {
            // Deepen: run stages `from+1..=k_eff` here, re-encode at
            // the plan's bit-width, forward the later cut. The device
            // tenant trailer (if any) rides along so fair admission
            // upstream stays scoped per device.
            self.span_runs.fetch_add(1, Ordering::Relaxed);
            let (x, wire_tenant) = if kind == proto::KIND_FEATURES {
                let (body_len, t) = match feature::frame_len(frame) {
                    Some(flen) if frame.len() <= flen => (frame.len(), None),
                    _ => proto::split_tenant_trailer(frame),
                };
                let h = feature::decode_into(&frame[..body_len], &mut sc.codec, &mut sc.values)
                    .map_err(anyhow::Error::new)?;
                if h.model != model_id || h.stage as usize != from || from == 0 {
                    return Err(anyhow!("inconsistent feature header"));
                }
                let stage = &m.stages[from - 1];
                quant::dequantize_into(&sc.values, h.lo, h.hi, h.c, &mut sc.floats);
                if sc.floats.len() != stage.out_elems {
                    return Err(anyhow!(
                        "stage {from} feature map has {} elements, frame carried {}",
                        stage.out_elems,
                        sc.floats.len()
                    ));
                }
                (Tensor::new(stage.out_shape.clone(), sc.floats.clone()), t)
            } else {
                let (body_len, t) = proto::split_tenant_trailer(frame);
                let img = png::decode(&frame[4..body_len]).map_err(anyhow::Error::new)?;
                let expect: usize = m.input_shape.iter().product();
                if img.data.len() != expect {
                    return Err(anyhow!("image has {} bytes, model expects {expect}", img.data.len()));
                }
                (crate::data::gen::from_rgb8(&img.data, m.input_shape.clone()), t)
            };
            let out = exe.run_stages(&m.name, from + 1, k_eff, &x)?;
            let (lo, hi) = quant::quantize_into(out.tensor.data(), c_used, &mut sc.values);
            feature::encode_parts_into(
                &sc.values,
                c_used,
                lo,
                hi,
                k_eff as u16,
                model_id,
                &mut sc.codec,
                &mut sc.wire,
            );
            if let Some(t) = wire_tenant {
                proto::append_tenant_trailer(t, &mut sc.wire);
            }
            let (rk, sent, p) = client.forward_raw(proto::KIND_FEATURES, &[&sc.wire])?;
            (rk, sent, p.to_vec())
        };
        // Feed this hop's bandwidth estimate exactly as a device does.
        if sent >= MIN_ESTIMATE_BYTES {
            client
                .controller
                .observe_transfer(sent, t0.elapsed().as_secs_f64().max(1e-9));
        }

        match rk {
            proto::KIND_LOGITS => {
                // The upstream telemetry drives *this* tier's loop; the
                // hop below gets this tier's own load instead, so each
                // control plane observes the hop it talks to. The
                // logits bytes themselves are preserved bit-for-bit.
                let t_up = proto::parse_logits_telemetry_into(&payload, logits)?;
                if let Some(t) = t_up {
                    client.controller.observe_telemetry(&t);
                }
                let logits_end = 2 + logits.len() * 4;
                let mut down = payload[..logits_end].to_vec();
                match self.local.lock().unwrap().upgrade() {
                    Some(srv) => srv.telemetry().encode_into(&mut down),
                    // Unattached (tests driving the tier bare): relay
                    // the upstream block unchanged.
                    None => down = payload,
                }
                Ok(Some((proto::KIND_LOGITS, down)))
            }
            proto::KIND_BUSY => {
                // Cloud shed: adopt its telemetry, deepen this tier's
                // cut (the edge absorbs work), and answer the current
                // request locally.
                self.upstream_sheds.fetch_add(1, Ordering::Relaxed);
                let t = CloudTelemetry::decode(&payload).map(|(t, _)| t).unwrap_or_default();
                client.controller.on_busy(&t);
                Ok(None)
            }
            // A semantic refusal must reach the device unmasked.
            proto::KIND_ERROR => Ok(Some((proto::KIND_ERROR, payload))),
            k => Err(anyhow!("unexpected upstream reply kind {k}")),
        }
    }
}

impl TierForwarder for EdgeTier {
    fn forward(&self, kind: u8, frame: &[u8], _conn_id: usize) -> Option<(u8, Vec<u8>)> {
        let mut link = self.inner.lock().unwrap();
        match self.relay(&mut link, kind, frame) {
            Ok(Some(reply)) => {
                self.forwarded.fetch_add(1, Ordering::Relaxed);
                Some(reply)
            }
            Ok(None) => {
                self.local_fallbacks.fetch_add(1, Ordering::Relaxed);
                None
            }
            Err(e) => {
                crate::log_debug!("tier", "upstream relay failed, serving locally: {e:#}");
                self.local_fallbacks.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    fn tier_stats(&self) -> Json {
        let (fwd, pass, span, local, sheds) = self.counters();
        let cut = self.cut_cache.load(Ordering::Relaxed);
        // Never block stats behind a stalled upstream attempt: on
        // contention the upstream view is simply null this scrape.
        let upstream = match self.inner.try_lock() {
            Ok(link) => link.client.control_stats(),
            Err(_) => Json::Null,
        };
        crate::server::stats::render(
            crate::server::stats::TIER_SCHEMA,
            vec![
                ("role", Json::str("edge")),
                ("forwarded", Json::num(fwd as f64)),
                ("passthrough", Json::num(pass as f64)),
                ("span_runs", Json::num(span as f64)),
                ("local_fallbacks", Json::num(local as f64)),
                ("upstream_sheds", Json::num(sheds as f64)),
                ("cut_i", Json::num((cut >> 8) as f64)),
                ("cut_c", Json::num((cut & 0xFF) as f64)),
                ("upstream", upstream),
            ],
        )
    }
}
