//! The edge client: head stages + L1 quantize + Huffman + throttled TCP.
//!
//! One `EdgeClient` models the paper's edge device: it executes stages
//! `1..=i*` locally, compresses the cut feature map, ships it through a
//! token-bucket-paced socket (the controlled uplink of the testbed), and
//! adapts `(i*, c)` as its bandwidth estimate drifts (§III-E).

use std::io::BufReader;
use std::net::TcpStream;
use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::compression::{feature, png};
use crate::coordinator::AdaptationController;
use crate::data::gen::Sample;
use crate::ilp::Decision;
use crate::metrics::Breakdown;
use crate::network::throttle::{RateHandle, ThrottledWriter};
use crate::runtime::Executor;
use crate::server::proto::Frame;

/// Transfers below this size are RTT/compute-dominated and excluded
/// from bandwidth estimation.
pub const MIN_ESTIMATE_BYTES: usize = 4096;

pub struct EdgeClient<'a> {
    exe: &'a Executor,
    model: String,
    model_id: u16,
    reader: BufReader<TcpStream>,
    writer: ThrottledWriter<TcpStream>,
    pub controller: AdaptationController,
}

/// One served request's outcome on the edge side.
#[derive(Debug, Clone)]
pub struct EdgeResult {
    pub prediction: usize,
    pub correct: bool,
    pub decision: Decision,
    pub breakdown: Breakdown,
    pub replanned: bool,
}

impl<'a> EdgeClient<'a> {
    pub fn connect(
        exe: &'a Executor,
        model: &str,
        addr: std::net::SocketAddr,
        uplink: RateHandle,
        controller: AdaptationController,
    ) -> Result<Self> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true)?;
        let reader = BufReader::new(stream.try_clone()?);
        // Small burst: feature frames are a few KB, so a default 64 KiB
        // bucket would swallow whole frames and defeat the throttle
        // (§Perf log — this showed up as bimodal latencies).
        let writer = ThrottledWriter::with_burst(stream, uplink, 2048);
        let model_id = exe
            .manifest()
            .model_id(model)
            .ok_or_else(|| anyhow!("model {model} not in manifest"))?;
        Ok(Self { exe, model: model.to_string(), model_id, reader, writer, controller })
    }

    /// Serve one request end-to-end; blocks for the cloud reply.
    pub fn infer(&mut self, sample: &Sample) -> Result<EdgeResult> {
        let plan = self.controller.plan().clone();
        let mut bd = Breakdown::default();
        let (frame, sent_decision) = match plan.decision {
            Decision::CloudOnly => {
                let t0 = Instant::now();
                let hw = sample.image.shape()[1];
                let rgb = crate::data::gen::to_rgb8(&sample.image);
                let wire = png::encode(&png::Image8::new(hw, hw, 3, rgb));
                bd.encode = t0.elapsed().as_secs_f64();
                (
                    Frame::Image { model_id: self.model_id, hw: hw as u16, png: wire },
                    Decision::CloudOnly,
                )
            }
            Decision::Cut { i, c } => {
                let mut cur = sample.image.clone();
                for j in 1..=i {
                    let out = self.exe.run_stage(&self.model, j, &cur)?;
                    cur = out.tensor;
                    bd.edge_compute += out.seconds;
                }
                let t0 = Instant::now();
                let q = self.exe.run_quant(&cur, c)?;
                bd.quantize = t0.elapsed().as_secs_f64();
                let t1 = Instant::now();
                let wire = feature::encode(&q, i as u16, self.model_id);
                bd.encode = t1.elapsed().as_secs_f64();
                (Frame::Features(wire), Decision::Cut { i, c })
            }
        };

        // Transmit through the paced socket and await the reply.
        let t2 = Instant::now();
        let sent = frame.write_to(&mut self.writer)?;
        bd.tx_bytes = sent;
        let reply = Frame::read_from(&mut self.reader)?;
        // Transmit time ≈ send + queueing; the cloud compute is inside
        // this round trip too, but at our throttled rates (≤ a few MB/s)
        // the wire dominates by an order of magnitude.
        bd.transmit = t2.elapsed().as_secs_f64();

        let logits = match reply {
            Frame::Logits(v) => v,
            Frame::Error(e) => return Err(anyhow!("cloud error: {e}")),
            other => return Err(anyhow!("unexpected reply kind {}", other.kind())),
        };
        let prediction = logits
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i)
            .unwrap_or(0);

        // Feed the adaptation loop with the observed uplink throughput.
        // Only transfers large enough to be bandwidth-dominated count:
        // for a 33-byte logits frame the round trip is all RTT + cloud
        // compute, and folding those in collapsed the estimate and sent
        // the controller into pathological early cuts (§Perf log).
        let replanned = if sent >= MIN_ESTIMATE_BYTES {
            self.controller.observe_transfer(sent, bd.transmit.max(1e-9)).is_some()
        } else {
            false
        };

        Ok(EdgeResult {
            prediction,
            correct: prediction == sample.label,
            decision: sent_decision,
            breakdown: bd,
            replanned,
        })
    }

    /// Active bandwidth probe: upload `bytes` of padding through the
    /// throttled socket and feed the observed throughput to the
    /// adaptation controller. Used when the current plan's frames are
    /// too small to estimate from (e.g. logits-only cuts); returns the
    /// new plan when the probe triggered a re-decoupling.
    pub fn probe_bandwidth(&mut self, bytes: usize) -> Result<bool> {
        let t0 = Instant::now();
        let sent = Frame::Probe(vec![0xAB; bytes]).write_to(&mut self.writer)?;
        match Frame::read_from(&mut self.reader)? {
            Frame::ProbeAck => {}
            other => return Err(anyhow!("unexpected probe reply {}", other.kind())),
        }
        let dt = t0.elapsed().as_secs_f64().max(1e-9);
        Ok(self.controller.observe_transfer(sent, dt).is_some())
    }

    /// Query the cloud's stats endpoint.
    pub fn stats(&mut self) -> Result<String> {
        Frame::Stats.write_to(&mut self.writer)?;
        match Frame::read_from(&mut self.reader)? {
            Frame::StatsReply(b) => Ok(String::from_utf8_lossy(&b).into_owned()),
            other => Err(anyhow!("unexpected reply {}", other.kind())),
        }
    }
}

#[cfg(test)]
mod tests {
    //! Full-stack loopback test: real sockets, real PJRT on both sides.
    use super::*;
    use crate::coordinator::decision::{DecisionEngine, Scale};
    use crate::predictor::Tables;
    use crate::profiler::LatencyTables;
    use crate::runtime::{Manifest, SharedExecutor};
    use crate::server::cloud::CloudServer;
    use std::sync::Arc;

    fn artifacts_dir() -> Option<std::path::PathBuf> {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        dir.join("manifest.json").exists().then_some(dir)
    }

    #[test]
    fn loopback_feature_and_image_paths() {
        let Some(dir) = artifacts_dir() else { return };
        // Two PJRT clients in one process: the cloud's (shared, behind
        // the server threads) and the edge's (plain, this thread).
        let cloud_exe =
            Arc::new(SharedExecutor::new(Manifest::load(&dir).unwrap()).unwrap());
        let server = Arc::new(CloudServer::new(Arc::clone(&cloud_exe)));
        let (addr, _h) = Arc::clone(&server).spawn("127.0.0.1:0").unwrap();

        let exe = Executor::new(Manifest::load(&dir).unwrap()).unwrap();
        let tables = Tables::load_or_build(&exe, "tinyconv", &dir).unwrap();
        let latency = LatencyTables::measured(&exe, "tinyconv", 2, 4.0).unwrap();
        let engine =
            DecisionEngine::new("tinyconv", tables, latency, Scale::Measured, 0.10).unwrap();
        let controller = AdaptationController::new(engine, 1_000_000.0);
        let rate = RateHandle::new(10_000_000);
        let mut edge =
            EdgeClient::connect(&exe, "tinyconv", addr, rate, controller).unwrap();

        // Whatever the plan says, predictions must match local execution.
        for id in 7000..7006 {
            let s = crate::data::gen::sample_image(id, 32);
            let r = edge.infer(&s).unwrap();
            assert!(r.breakdown.tx_bytes > 0);
            if let Decision::Cut { c, .. } = r.decision {
                if c >= 4 {
                    let clean = exe.run_full("tinyconv", &s.image).unwrap().tensor.argmax();
                    assert_eq!(r.prediction, clean, "id {id}");
                }
            }
        }
        let stats = edge.stats().unwrap();
        assert!(stats.contains("\"requests\""), "stats: {stats}");
        CloudServer::request_shutdown(addr);
    }
}
