//! The edge client: head stages + L1 quantize + Huffman + throttled TCP.
//!
//! One `EdgeClient` models the paper's edge device: it executes stages
//! `1..=i*` locally, compresses the cut feature map, ships it through a
//! token-bucket-paced socket (the controlled uplink of the testbed), and
//! adapts `(i*, c)` through the
//! [`ControlPlane`](crate::coordinator::ControlPlane) as its bandwidth
//! estimate *or* the cloud's piggybacked load telemetry drifts
//! (§III-E, closed over both signals). A `Busy` shed is handled inside
//! [`EdgeClient::infer`]: the plane adopts the refusal's telemetry,
//! shifts the cut edge-ward, and the request is re-encoded and resent
//! under the new plan (bounded retries — the march terminates at the
//! logits-forward cut the cloud always admits).
//!
//! The encode half runs through the shared
//! [`coordinator::session::Session`](crate::coordinator::session::Session)
//! — the exact code `LocalPipeline` drives over the simulated channel —
//! and the transport uses the raw `proto` functions over the session's
//! wire buffer plus a reusable receive buffer, so a steady-state request
//! performs no heap allocations in the codec + proto hops.

use std::io::BufReader;
use std::net::TcpStream;
use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::coordinator::session::{EncodedRequest, Session};
use crate::coordinator::ControlPlane;
use crate::data::gen::Sample;
use crate::ilp::Decision;
use crate::metrics::Breakdown;
use crate::network::throttle::{RateHandle, ThrottledWriter};
use crate::runtime::Executor;
use crate::server::proto::{self, Frame, RecvFrame};
use crate::util::json::Json;

/// Transfers below this size are RTT/compute-dominated and excluded
/// from bandwidth estimation.
pub const MIN_ESTIMATE_BYTES: usize = 4096;

/// How many `Busy` sheds one request tolerates before giving up when
/// the cloud sends no backoff hint. Each shed moves the plan at least
/// one stage edge-ward, so any model whose stage count exceeds this
/// still converges across requests — and the shed-everything
/// pathological server can't wedge a caller.
pub const MAX_BUSY_RETRIES: usize = 4;

/// Retry bounds when the cloud *does* hint a per-tenant backoff: the
/// edge paces itself instead of marching edge-ward as fast as it can
/// re-encode, so it tolerates more attempts — bounded by count and by
/// total time slept so a hostile hint can't wedge a caller either.
pub const MAX_PACED_RETRIES: usize = 16;
const MAX_PACED_SLEEP_TOTAL: f64 = 1.0; // seconds per request
const MAX_SINGLE_SLEEP: f64 = 0.25; // seconds per retry (pre-jitter)

/// Additive jitter on paced retry sleeps, as a fraction of the hinted
/// backoff: each nap is stretched by up to this much so a fleet of
/// edges shed in the same admission window doesn't retry in the same
/// window too (synchronized retries re-create the very overload the
/// backoff hint is draining). Additive-only — a nap is never *shorter*
/// than the hint, so the cloud's "your share refills in this long"
/// contract holds.
pub const BACKOFF_JITTER_FRAC: f64 = 0.5;

/// How long a blocked `connect` may hang before the edge gives up. A
/// cloud refusing at the accept boundary answers fast (Busy or RST);
/// only a black-holed address leaves the edge in SYN retry — bound it
/// well under the paper's end-to-end latency scale instead of the
/// kernel's minutes-long default.
pub const CONNECT_TIMEOUT: std::time::Duration = std::time::Duration::from_secs(5);

/// Per-process seed counter so concurrently-built edge clients jitter
/// independently (golden-ratio stride keeps seeds well spread).
static JITTER_SEED: std::sync::atomic::AtomicU64 =
    std::sync::atomic::AtomicU64::new(0x9E37_79B9_7F4A_7C15);

pub struct EdgeClient<'a> {
    session: Session<'a>,
    reader: BufReader<TcpStream>,
    writer: ThrottledWriter<TcpStream>,
    pub controller: ControlPlane,
    /// Explicit tenant identity: appended to every request as a wire
    /// trailer so the cloud scopes admission to this tenant across
    /// all of its connections. `None` (the default) sends the exact
    /// pre-tenant frames and the cloud falls back to a per-connection
    /// tenant.
    tenant: Option<u32>,
    /// Reusable encoded tenant trailer (empty when `tenant` is None).
    trailer: Vec<u8>,
    /// Reusable receive buffer (reply payloads).
    rx_buf: Vec<u8>,
    /// Reusable decoded logits.
    logits: Vec<f32>,
    /// Private jitter stream for paced retry sleeps (never part of the
    /// deterministic data-generation streams).
    jitter: crate::util::rng::XorShift64Star,
}

/// One served request's outcome on the edge side.
#[derive(Debug, Clone)]
pub struct EdgeResult {
    pub prediction: usize,
    pub correct: bool,
    /// The decision that was actually served (after any shed-driven
    /// edge-ward retries).
    pub decision: Decision,
    pub breakdown: Breakdown,
    pub replanned: bool,
    /// `Busy` sheds absorbed (and retried edge-ward) serving this
    /// request.
    pub sheds: usize,
}

impl<'a> EdgeClient<'a> {
    pub fn connect(
        exe: &'a Executor,
        model: &str,
        addr: std::net::SocketAddr,
        uplink: RateHandle,
        controller: ControlPlane,
    ) -> Result<Self> {
        // Bounded connect: see [`CONNECT_TIMEOUT`].
        let stream = TcpStream::connect_timeout(&addr, CONNECT_TIMEOUT)?;
        stream.set_nodelay(true)?;
        let reader = BufReader::new(stream.try_clone()?);
        // Small burst: feature frames are a few KB, so a default 64 KiB
        // bucket would swallow whole frames and defeat the throttle
        // (§Perf log — this showed up as bimodal latencies).
        let writer = ThrottledWriter::with_burst(stream, uplink, 2048);
        let session = Session::new(exe, model)?;
        let seed = JITTER_SEED
            .fetch_add(0x9E37_79B9_7F4A_7C15, std::sync::atomic::Ordering::Relaxed)
            ^ u64::from(addr.port());
        Ok(Self {
            session,
            reader,
            writer,
            controller,
            tenant: None,
            trailer: Vec::new(),
            rx_buf: Vec::new(),
            logits: Vec::new(),
            jitter: crate::util::rng::XorShift64Star::new(seed),
        })
    }

    /// Set (or clear) this edge's explicit tenant identity. With a
    /// tenant, every request carries a wire trailer the cloud's fair
    /// admission scopes budgets by; without one, frames are bit-
    /// identical to the pre-tenant format.
    pub fn set_tenant(&mut self, tenant: Option<u32>) {
        self.tenant = tenant;
        self.trailer.clear();
        if let Some(t) = tenant {
            proto::append_tenant_trailer(t, &mut self.trailer);
        }
    }

    pub fn tenant(&self) -> Option<u32> {
        self.tenant
    }

    /// Serve one request end-to-end; blocks for the cloud reply.
    /// `Busy` sheds are absorbed here: the control plane shifts the
    /// cut edge-ward and the request is re-encoded and resent, up to
    /// [`MAX_BUSY_RETRIES`] times.
    pub fn infer(&mut self, sample: &Sample) -> Result<EdgeResult> {
        let mut bd = Breakdown::default();
        let mut sheds = 0usize;
        let mut paced_sheds = 0usize;
        let mut hintless_sheds = 0usize;
        let mut replanned = false;
        let mut slept = 0.0f64;
        loop {
            let decision = self.controller.plan().decision;
            let req = self.session.encode_request(sample, decision, &mut bd)?;

            // Transmit through the paced socket and await the reply.
            // With an explicit tenant, the trailer rides behind the
            // payload (no staging copy); without one, these are the
            // exact pre-tenant frames.
            let t2 = Instant::now();
            let sent = match req {
                EncodedRequest::Features { .. } => proto::write_frame_vec(
                    &mut self.writer,
                    proto::KIND_FEATURES,
                    &[self.session.wire(), &self.trailer],
                )?,
                EncodedRequest::Image { hw } => {
                    let mut head = [0u8; 4];
                    head[..2].copy_from_slice(&self.session.model_id().to_le_bytes());
                    head[2..].copy_from_slice(&hw.to_le_bytes());
                    proto::write_frame_vec(
                        &mut self.writer,
                        proto::KIND_IMAGE,
                        &[&head, self.session.wire(), &self.trailer],
                    )?
                }
            };
            // Across retries the breakdown accumulates edge compute
            // and counts the bytes of every attempt — the shed
            // attempts were really paid for.
            bd.tx_bytes += sent;
            let kind = self.read_reply()?;
            // Transmit time ≈ send + queueing; the cloud compute is
            // inside this round trip too, but at our throttled rates
            // (≤ a few MB/s) the wire dominates by an order of
            // magnitude.
            bd.transmit += t2.elapsed().as_secs_f64();

            // Feed the adaptation loop with the observed uplink
            // throughput. Only transfers large enough to be
            // bandwidth-dominated count: for a 33-byte logits frame
            // the round trip is all RTT + cloud compute, and folding
            // those in collapsed the estimate and sent the controller
            // into pathological early cuts (§Perf log).
            if sent >= MIN_ESTIMATE_BYTES {
                replanned |= self
                    .controller
                    .observe_transfer(sent, t2.elapsed().as_secs_f64().max(1e-9))
                    .is_some();
            }

            match kind {
                proto::KIND_LOGITS => {
                    // The reply's piggybacked telemetry is the load
                    // half of the closed loop.
                    let telemetry =
                        proto::parse_logits_telemetry_into(&self.rx_buf, &mut self.logits)?;
                    if let Some(t) = telemetry {
                        replanned |= self.controller.observe_telemetry(&t).is_some();
                    }
                }
                proto::KIND_BUSY => {
                    // Shed: adopt the refusal's telemetry, move the
                    // cut edge-ward, retry under the new plan. A
                    // telemetry-less (or garbled) refusal still counts
                    // — the shed itself is the signal.
                    sheds += 1;
                    let t = proto::CloudTelemetry::decode(&self.rx_buf)
                        .map(|(t, _)| t)
                        .unwrap_or_default();
                    let before = decision;
                    self.controller.on_busy(&t);
                    replanned = true;
                    // Tenant-scoped retry pacing: a backoff hint means
                    // "your fair share refills in this long" — sleep
                    // it off (bounded per retry and in total) and the
                    // retry budget stretches accordingly. Hint-less
                    // refusals keep the legacy fixed retry count with
                    // no sleep, bit-identical to the pre-tenant edge.
                    // The two budgets are tracked separately: a single
                    // hint-less shed arriving after several paced ones
                    // (the cloud's fairness flipping to the global
                    // path mid-episode) must not abort a request whose
                    // hint-less budget is untouched.
                    let backoff = self.controller.advised_backoff();
                    if backoff > 0.0 {
                        paced_sheds += 1;
                        if paced_sheds > MAX_PACED_RETRIES || slept >= MAX_PACED_SLEEP_TOTAL {
                            return Err(anyhow!(
                                "cloud shed the request {sheds} times despite pacing \
                                 (slept {slept:.3}s, last plan {before:?})"
                            ));
                        }
                        // Jitter de-synchronizes a fleet that was all
                        // shed in the same window; applied before the
                        // caps so the per-retry and total budgets
                        // still hold exactly.
                        let jittered = backoff
                            * (1.0 + BACKOFF_JITTER_FRAC * self.jitter.next_f64());
                        let nap = jittered
                            .min(MAX_SINGLE_SLEEP * (1.0 + BACKOFF_JITTER_FRAC))
                            .min(MAX_PACED_SLEEP_TOTAL - slept);
                        std::thread::sleep(std::time::Duration::from_secs_f64(nap));
                        slept += nap;
                    } else {
                        hintless_sheds += 1;
                        if hintless_sheds > MAX_BUSY_RETRIES {
                            return Err(anyhow!(
                                "cloud shed the request {sheds} times (last plan {before:?})"
                            ));
                        }
                    }
                    continue;
                }
                proto::KIND_ERROR => {
                    return Err(anyhow!(
                        "cloud error: {}",
                        String::from_utf8_lossy(&self.rx_buf)
                    ))
                }
                k => return Err(anyhow!("unexpected reply kind {k}")),
            }

            let prediction = self
                .logits
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .map(|(i, _)| i)
                .unwrap_or(0);

            return Ok(EdgeResult {
                prediction,
                correct: prediction == sample.label,
                decision,
                breakdown: bd,
                replanned,
                sheds,
            });
        }
    }

    /// Read one reply frame into the reusable receive buffer; returns
    /// its kind.
    fn read_reply(&mut self) -> Result<u8> {
        match proto::read_frame_into(&mut self.reader, &mut self.rx_buf)? {
            RecvFrame::Data(k) => Ok(k),
            RecvFrame::Eof => Err(anyhow!("cloud closed the connection")),
            RecvFrame::Malformed { reason, .. } => Err(anyhow!("malformed reply: {reason}")),
        }
    }

    /// Active bandwidth probe: upload `bytes` of padding through the
    /// throttled socket and feed the observed throughput to the
    /// adaptation controller. Used when the current plan's frames are
    /// too small to estimate from (e.g. logits-only cuts); returns
    /// whether the probe triggered a re-decoupling.
    pub fn probe_bandwidth(&mut self, bytes: usize) -> Result<bool> {
        let t0 = Instant::now();
        let sent = Frame::Probe(vec![0xAB; bytes]).write_to(&mut self.writer)?;
        match self.read_reply()? {
            proto::KIND_PROBE_ACK => {}
            k => return Err(anyhow!("unexpected probe reply {k}")),
        }
        let dt = t0.elapsed().as_secs_f64().max(1e-9);
        Ok(self.controller.observe_transfer(sent, dt).is_some())
    }

    /// Query the cloud's stats endpoint and merge this edge's
    /// adaptation counters in as an `"edge"` object — one JSON
    /// document describes both halves of the control loop (re-solves,
    /// plan changes, sheds observed, the current `(i*, c)` and the
    /// fused bandwidth/load estimates alongside the cloud's per-shard
    /// stats).
    pub fn stats(&mut self) -> Result<String> {
        Frame::Stats.write_to(&mut self.writer)?;
        let cloud = match self.read_reply()? {
            proto::KIND_STATS_REPLY => String::from_utf8_lossy(&self.rx_buf).into_owned(),
            k => return Err(anyhow!("unexpected reply {k}")),
        };
        let mut obj = match Json::parse(&cloud) {
            Ok(Json::Obj(map)) => map,
            // A cloud that serves something unexpected still gets its
            // payload through, nested verbatim.
            _ => {
                let mut map = std::collections::BTreeMap::new();
                map.insert("cloud_raw".to_string(), Json::str(&cloud));
                map
            }
        };
        let (cut_i, cut_c) = match self.controller.plan().decision {
            Decision::CloudOnly => (0usize, 0u8),
            Decision::Cut { i, c } => (i, c),
        };
        let load = self.controller.cloud_load();
        obj.insert(
            "edge".to_string(),
            Json::obj(vec![
                ("resolves", Json::num(self.controller.resolves() as f64)),
                ("plan_changes", Json::num(self.controller.plan_changes() as f64)),
                ("sheds_observed", Json::num(self.controller.sheds_observed() as f64)),
                ("cut_i", Json::num(cut_i as f64)),
                ("cut_c", Json::num(cut_c as f64)),
                (
                    "bandwidth_est",
                    Json::num(self.controller.bandwidth_estimate().unwrap_or(0.0)),
                ),
                ("cloud_queue_wait_ms", Json::num(load.queue_wait * 1e3)),
                ("cloud_utilization", Json::num(load.utilization)),
                (
                    "tenant",
                    match self.tenant {
                        Some(t) => Json::num(t as f64),
                        None => Json::Null,
                    },
                ),
                (
                    "advised_backoff_ms",
                    Json::num(self.controller.advised_backoff() * 1e3),
                ),
            ]),
        );
        Ok(Json::Obj(obj).to_string())
    }
}

#[cfg(test)]
mod tests {
    //! Full-stack loopback test: real sockets, real PJRT on both sides.
    use super::*;
    use crate::coordinator::decision::{DecisionEngine, Scale};
    use crate::predictor::Tables;
    use crate::profiler::LatencyTables;
    use crate::runtime::{Manifest, SharedExecutor};
    use crate::server::cloud::CloudServer;
    use std::sync::Arc;

    fn artifacts_dir() -> Option<std::path::PathBuf> {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        dir.join("manifest.json").exists().then_some(dir)
    }

    #[test]
    fn loopback_feature_and_image_paths() {
        let Some(dir) = artifacts_dir() else { return };
        // Two PJRT clients in one process: the cloud's (shared, behind
        // the server threads) and the edge's (plain, this thread).
        let cloud_exe =
            Arc::new(SharedExecutor::new(Manifest::load(&dir).unwrap()).unwrap());
        let server = Arc::new(CloudServer::new(Arc::clone(&cloud_exe)));
        let (addr, _h) = Arc::clone(&server).spawn("127.0.0.1:0").unwrap();

        let exe = Executor::new(Manifest::load(&dir).unwrap()).unwrap();
        let tables = Tables::load_or_build(&exe, "tinyconv", &dir).unwrap();
        let latency = LatencyTables::measured(&exe, "tinyconv", 2, 4.0).unwrap();
        let engine =
            DecisionEngine::new("tinyconv", tables, latency, Scale::Measured, 0.10).unwrap();
        let controller = ControlPlane::new(engine, 1_000_000.0);
        let rate = RateHandle::new(10_000_000);
        let mut edge =
            EdgeClient::connect(&exe, "tinyconv", addr, rate, controller).unwrap();

        // Whatever the plan says, predictions must match local execution.
        for id in 7000..7006 {
            let s = crate::data::gen::sample_image(id, 32);
            let r = edge.infer(&s).unwrap();
            assert!(r.breakdown.tx_bytes > 0);
            if let Decision::Cut { c, .. } = r.decision {
                if c >= 4 {
                    let clean = exe.run_full("tinyconv", &s.image).unwrap().tensor.argmax();
                    assert_eq!(r.prediction, clean, "id {id}");
                }
            }
        }
        let stats = edge.stats().unwrap();
        assert!(stats.contains("\"requests\""), "stats: {stats}");
        assert!(stats.contains("\"pool_hits\""), "stats: {stats}");
        CloudServer::request_shutdown(addr);
    }
}
