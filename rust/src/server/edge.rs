//! The edge client: head stages + L1 quantize + Huffman + throttled TCP.
//!
//! One `EdgeClient` models the paper's edge device: it executes stages
//! `1..=i*` locally, compresses the cut feature map, ships it through a
//! token-bucket-paced socket (the controlled uplink of the testbed), and
//! adapts `(i*, c)` as its bandwidth estimate drifts (§III-E).
//!
//! The encode half runs through the shared
//! [`coordinator::session::Session`](crate::coordinator::session::Session)
//! — the exact code `LocalPipeline` drives over the simulated channel —
//! and the transport uses the raw `proto` functions over the session's
//! wire buffer plus a reusable receive buffer, so a steady-state request
//! performs no heap allocations in the codec + proto hops.

use std::io::BufReader;
use std::net::TcpStream;
use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::coordinator::session::{EncodedRequest, Session};
use crate::coordinator::AdaptationController;
use crate::data::gen::Sample;
use crate::ilp::Decision;
use crate::metrics::Breakdown;
use crate::network::throttle::{RateHandle, ThrottledWriter};
use crate::runtime::Executor;
use crate::server::proto::{self, Frame, RecvFrame};

/// Transfers below this size are RTT/compute-dominated and excluded
/// from bandwidth estimation.
pub const MIN_ESTIMATE_BYTES: usize = 4096;

pub struct EdgeClient<'a> {
    session: Session<'a>,
    reader: BufReader<TcpStream>,
    writer: ThrottledWriter<TcpStream>,
    pub controller: AdaptationController,
    /// Reusable receive buffer (reply payloads).
    rx_buf: Vec<u8>,
    /// Reusable decoded logits.
    logits: Vec<f32>,
}

/// One served request's outcome on the edge side.
#[derive(Debug, Clone)]
pub struct EdgeResult {
    pub prediction: usize,
    pub correct: bool,
    pub decision: Decision,
    pub breakdown: Breakdown,
    pub replanned: bool,
}

impl<'a> EdgeClient<'a> {
    pub fn connect(
        exe: &'a Executor,
        model: &str,
        addr: std::net::SocketAddr,
        uplink: RateHandle,
        controller: AdaptationController,
    ) -> Result<Self> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true)?;
        let reader = BufReader::new(stream.try_clone()?);
        // Small burst: feature frames are a few KB, so a default 64 KiB
        // bucket would swallow whole frames and defeat the throttle
        // (§Perf log — this showed up as bimodal latencies).
        let writer = ThrottledWriter::with_burst(stream, uplink, 2048);
        let session = Session::new(exe, model)?;
        Ok(Self { session, reader, writer, controller, rx_buf: Vec::new(), logits: Vec::new() })
    }

    /// Serve one request end-to-end; blocks for the cloud reply.
    pub fn infer(&mut self, sample: &Sample) -> Result<EdgeResult> {
        let plan = self.controller.plan().clone();
        let mut bd = Breakdown::default();
        let req = self.session.encode_request(sample, plan.decision, &mut bd)?;

        // Transmit through the paced socket and await the reply.
        let t2 = Instant::now();
        let sent = match req {
            EncodedRequest::Features { .. } => {
                proto::write_frame_raw(&mut self.writer, proto::KIND_FEATURES, self.session.wire())?
            }
            EncodedRequest::Image { hw } => {
                let mut head = [0u8; 4];
                head[..2].copy_from_slice(&self.session.model_id().to_le_bytes());
                head[2..].copy_from_slice(&hw.to_le_bytes());
                proto::write_frame_parts(&mut self.writer, proto::KIND_IMAGE, &head, self.session.wire())?
            }
        };
        bd.tx_bytes = sent;
        let kind = self.read_reply()?;
        // Transmit time ≈ send + queueing; the cloud compute is inside
        // this round trip too, but at our throttled rates (≤ a few MB/s)
        // the wire dominates by an order of magnitude.
        bd.transmit = t2.elapsed().as_secs_f64();

        match kind {
            proto::KIND_LOGITS => proto::parse_logits_into(&self.rx_buf, &mut self.logits)?,
            proto::KIND_ERROR => {
                return Err(anyhow!("cloud error: {}", String::from_utf8_lossy(&self.rx_buf)))
            }
            k => return Err(anyhow!("unexpected reply kind {k}")),
        }
        let prediction = self
            .logits
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i)
            .unwrap_or(0);

        // Feed the adaptation loop with the observed uplink throughput.
        // Only transfers large enough to be bandwidth-dominated count:
        // for a 33-byte logits frame the round trip is all RTT + cloud
        // compute, and folding those in collapsed the estimate and sent
        // the controller into pathological early cuts (§Perf log).
        let replanned = if sent >= MIN_ESTIMATE_BYTES {
            self.controller.observe_transfer(sent, bd.transmit.max(1e-9)).is_some()
        } else {
            false
        };

        Ok(EdgeResult {
            prediction,
            correct: prediction == sample.label,
            decision: plan.decision,
            breakdown: bd,
            replanned,
        })
    }

    /// Read one reply frame into the reusable receive buffer; returns
    /// its kind.
    fn read_reply(&mut self) -> Result<u8> {
        match proto::read_frame_into(&mut self.reader, &mut self.rx_buf)? {
            RecvFrame::Data(k) => Ok(k),
            RecvFrame::Eof => Err(anyhow!("cloud closed the connection")),
            RecvFrame::Malformed { reason, .. } => Err(anyhow!("malformed reply: {reason}")),
        }
    }

    /// Active bandwidth probe: upload `bytes` of padding through the
    /// throttled socket and feed the observed throughput to the
    /// adaptation controller. Used when the current plan's frames are
    /// too small to estimate from (e.g. logits-only cuts); returns
    /// whether the probe triggered a re-decoupling.
    pub fn probe_bandwidth(&mut self, bytes: usize) -> Result<bool> {
        let t0 = Instant::now();
        let sent = Frame::Probe(vec![0xAB; bytes]).write_to(&mut self.writer)?;
        match self.read_reply()? {
            proto::KIND_PROBE_ACK => {}
            k => return Err(anyhow!("unexpected probe reply {k}")),
        }
        let dt = t0.elapsed().as_secs_f64().max(1e-9);
        Ok(self.controller.observe_transfer(sent, dt).is_some())
    }

    /// Query the cloud's stats endpoint.
    pub fn stats(&mut self) -> Result<String> {
        Frame::Stats.write_to(&mut self.writer)?;
        match self.read_reply()? {
            proto::KIND_STATS_REPLY => Ok(String::from_utf8_lossy(&self.rx_buf).into_owned()),
            k => Err(anyhow!("unexpected reply {k}")),
        }
    }
}

#[cfg(test)]
mod tests {
    //! Full-stack loopback test: real sockets, real PJRT on both sides.
    use super::*;
    use crate::coordinator::decision::{DecisionEngine, Scale};
    use crate::predictor::Tables;
    use crate::profiler::LatencyTables;
    use crate::runtime::{Manifest, SharedExecutor};
    use crate::server::cloud::CloudServer;
    use std::sync::Arc;

    fn artifacts_dir() -> Option<std::path::PathBuf> {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        dir.join("manifest.json").exists().then_some(dir)
    }

    #[test]
    fn loopback_feature_and_image_paths() {
        let Some(dir) = artifacts_dir() else { return };
        // Two PJRT clients in one process: the cloud's (shared, behind
        // the server threads) and the edge's (plain, this thread).
        let cloud_exe =
            Arc::new(SharedExecutor::new(Manifest::load(&dir).unwrap()).unwrap());
        let server = Arc::new(CloudServer::new(Arc::clone(&cloud_exe)));
        let (addr, _h) = Arc::clone(&server).spawn("127.0.0.1:0").unwrap();

        let exe = Executor::new(Manifest::load(&dir).unwrap()).unwrap();
        let tables = Tables::load_or_build(&exe, "tinyconv", &dir).unwrap();
        let latency = LatencyTables::measured(&exe, "tinyconv", 2, 4.0).unwrap();
        let engine =
            DecisionEngine::new("tinyconv", tables, latency, Scale::Measured, 0.10).unwrap();
        let controller = AdaptationController::new(engine, 1_000_000.0);
        let rate = RateHandle::new(10_000_000);
        let mut edge =
            EdgeClient::connect(&exe, "tinyconv", addr, rate, controller).unwrap();

        // Whatever the plan says, predictions must match local execution.
        for id in 7000..7006 {
            let s = crate::data::gen::sample_image(id, 32);
            let r = edge.infer(&s).unwrap();
            assert!(r.breakdown.tx_bytes > 0);
            if let Decision::Cut { c, .. } = r.decision {
                if c >= 4 {
                    let clean = exe.run_full("tinyconv", &s.image).unwrap().tensor.argmax();
                    assert_eq!(r.prediction, clean, "id {id}");
                }
            }
        }
        let stats = edge.stats().unwrap();
        assert!(stats.contains("\"requests\""), "stats: {stats}");
        assert!(stats.contains("\"pool_hits\""), "stats: {stats}");
        CloudServer::request_shutdown(addr);
    }
}
